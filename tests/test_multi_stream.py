"""Multi-tenant serving suite: N sessions on one dispatcher vs N
dedicated engines.

The session/dispatch split may multiplex N cameras' closed segments onto
shared device sweeps — cross-stream coalescing, fairness anchoring,
per-session flush — but it may never change any session's numbers: for
every dispatch policy x interleaving schedule (balanced round-robin,
bursty, pose-starved) x fairness setting x sweep backend, each session's
flushed result must equal a dedicated single-stream reference
bit-for-bit on the nearest/integer datapath (float tolerance on
bilinear).

Also pinned here:
  * the tagged coalescing planner's invariants (hypothesis: any tagged
    arrival order, both fairness policies -> per-stream FIFO preserved,
    nothing dropped/duplicated, valid S buckets; round_robin bounds any
    stream's wait to O(streams) dispatches; single tag reduces to the
    untagged planner);
  * `pad_segment_rows` row-for-row bitwise equality with `pad_segments`;
  * cross-stream coalescing actually engaging (fewer dispatches than N
    dedicated engines under concurrent trickle streams);
  * the input-hygiene fixes (empty-push accounting, inconsistent chunk
    shapes, bad `chunk_events`) and `_FrameStore` live/peak byte
    accounting.
"""
from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.dsi import DSIConfig
from repro.core.pipeline import (
    EMVSOptions,
    bucket_capacity,
    dispatch_group_head_tagged,
    pad_segment_rows,
    pad_segments,
    plan_dispatch_groups,
    plan_dispatch_groups_tagged,
    run_emvs,
)
from repro.events.aggregation import aggregate
from repro.events.simulator import EventStream
from repro.serving.emvs_stream import (
    DISPATCH_POLICIES,
    EMVSStreamEngine,
    MultiStreamEngine,
    StreamConfig,
    iter_event_chunks,
)
from repro.serving.stream_session import _FrameStore
from test_segment_batching import _assert_results_match, _synthetic_frames

EVENTS_PER_FRAME = 224  # does not divide the streams -> exercises tails

# Session interleaving schedules for two sessions A and B:
#   * "balanced" — strict frame-by-frame alternation, the steady rig;
#   * "bursty"   — A lands its whole stream in one chunk before B trickles
#     frame-by-frame: A's backlog floods the shared queue first;
#   * "starved"  — B is pose-gated and receives ALL its events up front
#     with no poses (every frame stalls), A then streams and flushes
#     completely before B's poses flood in one chunk — the adversarial
#     case where one session is silent for the other's entire lifetime.
SCHEDULES = ("balanced", "bursty", "starved")

GRID_OPTS = dict(formulation="matmul", voting="nearest", quantized=True,
                 keyframe_dist_frac=0.03)
BILINEAR_OPTS = dict(formulation="scatter", voting="bilinear",
                     quantized=False, keyframe_dist_frac=0.03)


def _trim(ev: EventStream, keep: int) -> EventStream:
    return EventStream(xy=ev.xy[:keep], t=ev.t[:keep],
                       polarity=ev.polarity[:keep], valid=ev.valid[:keep])


@pytest.fixture(scope="module")
def rig_scene(cam, small_scene):
    """Two sessions cut from small_scene with different lengths (13 vs 9
    full frames plus partial tails), so their segment schedules differ
    and same-capacity segments from both exist for the coalescer."""
    ev = small_scene["events"]
    traj = small_scene["traj"]
    n = int(ev.t.shape[0])
    evs = (_trim(ev, min(n, 13 * EVENTS_PER_FRAME + 32)),
           _trim(ev, min(n, 9 * EVENTS_PER_FRAME + 17)))
    dsi_cfg = DSIConfig.for_camera(cam, num_planes=10, z_min=0.6, z_max=4.5)
    refs = {}
    for key, opts in (("nearest", GRID_OPTS), ("bilinear", BILINEAR_OPTS)):
        refs[key] = []
        for e in evs:
            frames = aggregate(cam, e, traj,
                               events_per_frame=EVENTS_PER_FRAME)
            refs[key].append(run_emvs(cam, dsi_cfg, frames,
                                      EMVSOptions(**opts)))
    assert all(len(r.segments) >= 2 for r in refs["nearest"]), \
        "both sessions must close several segments"
    return evs, traj, refs, dsi_cfg


def _make_multi(cam, dsi_cfg, opts, *, policy, fairness, sweep="batched"):
    return MultiStreamEngine(
        cam, dsi_cfg, EMVSOptions(**opts),
        StreamConfig(events_per_frame=EVENTS_PER_FRAME,
                     dispatch_policy=policy, fairness=fairness, sweep=sweep))


def _drive_rig(engine: MultiStreamEngine, evs, traj, schedule: str):
    """Run two sessions through one schedule; returns per-session results."""
    ev_a, ev_b = evs
    if schedule == "balanced":
        a = engine.add_session("a", traj=traj)
        b = engine.add_session("b", traj=traj)
        chunks_a = list(iter_event_chunks(ev_a, EVENTS_PER_FRAME))
        chunks_b = list(iter_event_chunks(ev_b, EVENTS_PER_FRAME))
        for k in range(max(len(chunks_a), len(chunks_b))):
            if k < len(chunks_a):
                a.push(chunks_a[k])
            if k < len(chunks_b):
                b.push(chunks_b[k])
        return {"a": a.flush(), "b": b.flush()}
    if schedule == "bursty":
        a = engine.add_session("a", traj=traj)
        b = engine.add_session("b", traj=traj)
        a.push(next(iter_event_chunks(ev_a, int(ev_a.t.shape[0]))))
        for c in iter_event_chunks(ev_b, EVENTS_PER_FRAME):
            b.push(c)
        return {"b": b.flush(), "a": a.flush()}
    if schedule == "starved":
        a = engine.add_session("a", traj=traj)
        b = engine.add_session("b", traj=None)  # pose-gated, starved
        for c in iter_event_chunks(ev_b, 997):
            b.push(c)  # all of B's frames stall: no poses yet
        for c in iter_event_chunks(ev_a, EVENTS_PER_FRAME):
            a.push(c)
        res_a = a.flush()  # A completes while B is still fully stalled
        b.push_poses(traj)  # the flood releases B's whole backlog at once
        b.finalize_poses()
        return {"a": res_a, "b": b.flush()}
    raise AssertionError(f"unknown schedule {schedule}")


def _assert_drained(engine: MultiStreamEngine) -> None:
    stats = engine.stats
    d = stats["dispatcher"]
    assert d["pending_segments"] == 0, "shared queue not drained"
    solo = d["dispatches"] - d["coalesced_dispatches"]
    assert d["segments"] == d["coalesced_segments"] + solo, d
    assert d["segments"] == sum(s["segments"]
                                for s in stats["sessions"].values())


# --- the headline equivalence grid ----------------------------------------


@pytest.mark.parametrize("schedule", SCHEDULES)
@pytest.mark.parametrize("policy", DISPATCH_POLICIES)
def test_multi_matches_dedicated_grid(cam, rig_scene, policy, schedule):
    """Every dispatch policy x interleaving schedule: each session of the
    shared engine reproduces its dedicated single-stream reference (==
    offline run_emvs) bit-for-bit on the nearest/integer datapath."""
    evs, traj, refs, dsi_cfg = rig_scene
    engine = _make_multi(cam, dsi_cfg, GRID_OPTS, policy=policy,
                         fairness="fifo")
    results = _drive_rig(engine, evs, traj, schedule)
    _assert_results_match(results["a"], refs["nearest"][0], exact_dsi=True)
    _assert_results_match(results["b"], refs["nearest"][1], exact_dsi=True)
    _assert_drained(engine)


@pytest.mark.parametrize("schedule", SCHEDULES)
def test_round_robin_fairness_bitwise(cam, rig_scene, schedule):
    """round_robin anchoring reorders dispatch groups across sessions but
    never changes any session's numbers (adaptive policy, all schedules;
    latency/throughput are covered by the balanced schedule below)."""
    evs, traj, refs, dsi_cfg = rig_scene
    engine = _make_multi(cam, dsi_cfg, GRID_OPTS, policy="adaptive",
                         fairness="round_robin")
    results = _drive_rig(engine, evs, traj, schedule)
    _assert_results_match(results["a"], refs["nearest"][0], exact_dsi=True)
    _assert_results_match(results["b"], refs["nearest"][1], exact_dsi=True)
    _assert_drained(engine)


@pytest.mark.parametrize("policy", ("latency", "throughput"))
def test_round_robin_other_policies_bitwise(cam, rig_scene, policy):
    evs, traj, refs, dsi_cfg = rig_scene
    engine = _make_multi(cam, dsi_cfg, GRID_OPTS, policy=policy,
                         fairness="round_robin")
    results = _drive_rig(engine, evs, traj, "balanced")
    _assert_results_match(results["a"], refs["nearest"][0], exact_dsi=True)
    _assert_results_match(results["b"], refs["nearest"][1], exact_dsi=True)
    _assert_drained(engine)


@pytest.mark.parametrize("fairness", ("fifo", "round_robin"))
def test_multi_sharded_backend_bitwise(cam, rig_scene, fairness):
    """The sharded sweep backend (single-device mesh in-process; the
    multi-device grid lives in test_sharded_sweep's subprocess) agrees
    bitwise through the shared dispatcher too."""
    evs, traj, refs, dsi_cfg = rig_scene
    engine = _make_multi(cam, dsi_cfg, GRID_OPTS, policy="adaptive",
                         fairness=fairness, sweep="sharded")
    results = _drive_rig(engine, evs, traj, "balanced")
    _assert_results_match(results["a"], refs["nearest"][0], exact_dsi=True)
    _assert_results_match(results["b"], refs["nearest"][1], exact_dsi=True)
    _assert_drained(engine)


def test_multi_bilinear_allclose(cam, rig_scene):
    """Float datapath: shared-engine sessions match their references to
    tolerance (bitwise is reserved for the integer/nearest path)."""
    evs, traj, refs, dsi_cfg = rig_scene
    engine = _make_multi(cam, dsi_cfg, BILINEAR_OPTS, policy="adaptive",
                         fairness="fifo")
    results = _drive_rig(engine, evs, traj, "bursty")
    _assert_results_match(results["a"], refs["bilinear"][0], exact_dsi=False)
    _assert_results_match(results["b"], refs["bilinear"][1], exact_dsi=False)


def test_single_session_multi_equals_dedicated_engine(cam, rig_scene):
    """MultiStreamEngine with one session IS the single-stream engine:
    same results, same dispatch counters."""
    evs, traj, refs, dsi_cfg = rig_scene
    cfg = StreamConfig(events_per_frame=EVENTS_PER_FRAME,
                       dispatch_policy="adaptive")
    multi = MultiStreamEngine(cam, dsi_cfg, EMVSOptions(**GRID_OPTS), cfg)
    sess = multi.add_session(traj=traj)
    dedicated = EMVSStreamEngine(cam, dsi_cfg, traj,
                                 EMVSOptions(**GRID_OPTS), cfg)
    for c in iter_event_chunks(evs[0], EVENTS_PER_FRAME):
        sess.push(c)
        dedicated.push(c)
    res_multi = sess.flush()
    res_dedicated = dedicated.flush()
    _assert_results_match(res_multi, res_dedicated, exact_dsi=True)
    d = multi.stats["dispatcher"]
    for key in ("segments", "dispatches", "coalesced_dispatches",
                "coalesced_segments", "padded_segments"):
        assert d[key] == dedicated.stats[key], key
    assert d["cross_stream_dispatches"] == 0


# --- cross-stream coalescing engages --------------------------------------


def test_cross_stream_coalescing_reduces_dispatches(cam, rig_scene):
    """Two lockstep trickle sessions under "throughput": the shared
    engine fills S buckets across streams, so it dispatches strictly
    fewer sweeps than two dedicated engines fed identically — the
    structural claim the multi_stream_sweep benchmark gates on."""
    evs, traj, _, dsi_cfg = rig_scene
    ev = evs[0]
    cfg = StreamConfig(events_per_frame=EVENTS_PER_FRAME,
                       dispatch_policy="throughput")

    def trickle_dedicated():
        eng = EMVSStreamEngine(cam, dsi_cfg, traj, EMVSOptions(**GRID_OPTS),
                               cfg)
        for c in iter_event_chunks(ev, EVENTS_PER_FRAME):
            eng.push(c)
        eng.flush()
        return eng.stats["dispatches"]

    dedicated_total = 2 * trickle_dedicated()

    multi = MultiStreamEngine(cam, dsi_cfg, EMVSOptions(**GRID_OPTS), cfg)
    a = multi.add_session("a", traj=traj)
    b = multi.add_session("b", traj=traj)
    for c in iter_event_chunks(ev, EVENTS_PER_FRAME):
        a.push(c)
        b.push(c)
    a.flush()
    b.flush()
    d = multi.stats["dispatcher"]
    assert d["cross_stream_dispatches"] >= 1, \
        "no dispatch ever mixed sessions"
    assert d["dispatches"] < dedicated_total, (
        f"cross-stream coalescing saved nothing: {d['dispatches']} vs "
        f"{dedicated_total} dedicated")
    _assert_drained(multi)


def test_flush_one_session_leaves_other_streaming(cam, rig_scene):
    evs, traj, refs, dsi_cfg = rig_scene
    engine = _make_multi(cam, dsi_cfg, GRID_OPTS, policy="adaptive",
                         fairness="fifo")
    a = engine.add_session("a", traj=traj)
    b = engine.add_session("b", traj=traj)
    chunks_b = list(iter_event_chunks(evs[1], EVENTS_PER_FRAME))
    half = len(chunks_b) // 2
    for c in chunks_b[:half]:
        b.push(c)
    for c in iter_event_chunks(evs[0], EVENTS_PER_FRAME):
        a.push(c)
    res_a = a.flush()
    # A is drained; B keeps streaming on the same dispatcher
    for c in chunks_b[half:]:
        b.push(c)
    res_b = b.flush()
    _assert_results_match(res_a, refs["nearest"][0], exact_dsi=True)
    _assert_results_match(res_b, refs["nearest"][1], exact_dsi=True)
    with pytest.raises(RuntimeError, match="push after flush"):
        a.push(next(iter_event_chunks(evs[0], 64)))


# --- session admission API ------------------------------------------------


def test_session_admission_errors(cam, rig_scene):
    _, traj, _, dsi_cfg = rig_scene
    engine = _make_multi(cam, dsi_cfg, GRID_OPTS, policy="adaptive",
                         fairness="fifo")
    engine.add_session("left", traj=traj)
    with pytest.raises(ValueError, match="duplicate session id"):
        engine.add_session("left", traj=traj)
    with pytest.raises(KeyError, match="unknown session"):
        engine.session("right")
    auto = engine.add_session(traj=traj)
    assert auto.session_id == "cam1"
    assert sorted(engine.sessions) == ["cam1", "left"]


# --- input hygiene (satellite) --------------------------------------------


def test_empty_push_is_counted(cam, rig_scene):
    _, traj, _, dsi_cfg = rig_scene
    engine = EMVSStreamEngine(
        cam, dsi_cfg, traj, EMVSOptions(**GRID_OPTS),
        StreamConfig(events_per_frame=EVENTS_PER_FRAME))
    empty = EventStream(xy=np.zeros((0, 2), np.float32),
                        t=np.zeros((0,), np.float32),
                        polarity=np.zeros((0,), np.int8),
                        valid=np.zeros((0,), bool))
    engine.push(empty)
    assert engine.stats["chunks"] == 1
    assert engine.stats["empty_chunks"] == 1
    assert engine.stats["frames"] == 0


def test_inconsistent_chunk_rejected(cam, rig_scene):
    evs, traj, _, dsi_cfg = rig_scene
    engine = EMVSStreamEngine(
        cam, dsi_cfg, traj, EMVSOptions(**GRID_OPTS),
        StreamConfig(events_per_frame=EVENTS_PER_FRAME))
    ev = evs[0]
    bad = EventStream(xy=ev.xy[:5], t=ev.t[:7], polarity=ev.polarity[:7],
                      valid=ev.valid[:6])
    with pytest.raises(ValueError,
                       match=r"t has 7 event\(s\) but.*valid has 6.*xy has 5"):
        engine.push(bad)
    # the malformed chunk must not have touched the aggregator
    assert engine.stats["chunks"] == 0
    assert engine.stats["frames"] == 0


@pytest.mark.parametrize("bad", (0, -3, 2.5, "64", None, True))
def test_iter_event_chunks_rejects_bad_chunk_events(cam, rig_scene, bad):
    evs, _, _, _ = rig_scene
    with pytest.raises(ValueError, match="chunk_events"):
        next(iter_event_chunks(evs[0], bad))


# --- frame-store memory accounting (satellite) ----------------------------


def test_frame_store_byte_accounting():
    store = _FrameStore()
    frames = _synthetic_frames([0.0, 0.1, 0.2], events=32)
    per_frame = (np.asarray(frames.xy[0]).nbytes
                 + np.asarray(frames.valid[0]).nbytes
                 + np.float32(0).nbytes
                 + np.asarray(frames.poses.R[0]).nbytes
                 + np.asarray(frames.poses.t[0]).nbytes)
    store.extend(frames)
    assert store.live_bytes == 3 * per_frame
    assert store.peak_bytes == 3 * per_frame
    store.evict_before(2)
    assert store.live_bytes == per_frame
    assert store.peak_bytes == 3 * per_frame  # high-water mark sticks
    store.extend(_synthetic_frames([0.3] * 4, events=32))
    assert store.live_bytes == 5 * per_frame
    assert store.peak_bytes == 5 * per_frame


def test_engine_reports_frame_store_bytes(cam, rig_scene):
    evs, traj, _, dsi_cfg = rig_scene
    engine = EMVSStreamEngine(
        cam, dsi_cfg, traj, EMVSOptions(**GRID_OPTS),
        StreamConfig(events_per_frame=EVENTS_PER_FRAME))
    peak_seen = 0
    for c in iter_event_chunks(evs[0], EVENTS_PER_FRAME):
        engine.push(c)
        peak_seen = max(peak_seen, engine.stats["frame_store_bytes"])
    engine.flush()
    stats = engine.stats
    assert peak_seen > 0
    assert stats["frame_store_peak_bytes"] >= peak_seen
    # after flush the planner moved past every frame: window fully evicted
    assert stats["frame_store_bytes"] == 0


# --- pad_segment_rows == pad_segments, row for row ------------------------


def test_pad_segment_rows_matches_pad_segments():
    frames = _synthetic_frames([0.0, 0.05, 0.1, 0.2, 0.3, 0.35, 0.4, 0.5],
                               events=48, seed=3)
    segs = [(0, 3), (3, 5), (5, 8)]
    cap = 4
    ref = pad_segments(frames, segs, cap)
    # each row brings its own window, indices relative to it — the
    # multi-session gather path
    import jax

    rows = [(jax.tree.map(lambda a, s=start, e=end: a[s:e], frames),
             (0, end - start)) for start, end in segs]
    got = pad_segment_rows(rows, cap)
    for name in ref._fields:
        np.testing.assert_array_equal(np.asarray(getattr(ref, name)),
                                      np.asarray(getattr(got, name)),
                                      err_msg=name)


# --- tagged coalescing planner: property tests (satellite) ----------------


def _random_tagged(rng: np.random.Generator, n: int, n_tags: int):
    """A tagged arrival order: per-tag segments are abutting and ascending
    (the shape each session's planner emits), interleaved arbitrarily."""
    tags = [f"s{k}" for k in range(n_tags)]
    owners = [tags[int(rng.integers(n_tags))] for _ in range(n)]
    cursor = {t: 0 for t in tags}
    items = []
    for owner in owners:
        length = int(rng.integers(1, 14))
        start = cursor[owner]
        cursor[owner] = start + length
        items.append((owner, (start, start + length)))
    return items


@settings(max_examples=40)
@given(seed=st.integers(0, 2**32 - 1), n=st.integers(1, 40),
       n_tags=st.integers(1, 5), max_group=st.integers(1, 6),
       fairness=st.sampled_from(("fifo", "round_robin")))
def test_tagged_plan_is_valid_per_session_partition(seed, n, n_tags,
                                                    max_group, fairness):
    """Both fairness policies: groups partition the tagged input with
    per-tag FIFO order preserved, 1..max_group segments per group, one
    shared bucket capacity per group."""
    rng = np.random.default_rng(seed)
    items = _random_tagged(rng, n, n_tags)
    groups = plan_dispatch_groups_tagged(items, max_group,
                                         fairness=fairness)
    flat = [it for g, _ in groups for it in g]
    assert sorted(map(repr, flat)) == sorted(map(repr, items)), \
        "dropped, duplicated, or cross-tagged work"
    for g, cap in groups:
        assert 1 <= len(g) <= max_group
        assert all(bucket_capacity(e - s) == cap for _, (s, e) in g)
    for tag in {t for t, _ in items}:
        arrival = [seg for t, seg in items if t == tag]
        released = [seg for it_g, _ in groups for t, seg in it_g if t == tag]
        assert released == arrival, f"per-stream FIFO broken for {tag}"


@settings(max_examples=40)
@given(seed=st.integers(0, 2**32 - 1), n=st.integers(1, 40),
       max_group=st.integers(1, 6),
       fairness=st.sampled_from(("fifo", "round_robin")))
def test_tagged_plan_single_tag_reduces_to_untagged(seed, n, max_group,
                                                    fairness):
    rng = np.random.default_rng(seed)
    items = _random_tagged(rng, n, 1)
    segs = [seg for _, seg in items]
    tagged = plan_dispatch_groups_tagged(items, max_group, fairness=fairness)
    untagged = plan_dispatch_groups(segs, max_group)
    assert [[seg for _, seg in g] for g, _ in tagged] == \
        [g for g, _ in untagged]
    assert [cap for _, cap in tagged] == [cap for _, cap in untagged]


@settings(max_examples=40)
@given(seed=st.integers(0, 2**32 - 1), n=st.integers(2, 60),
       n_tags=st.integers(2, 5), max_group=st.integers(1, 6))
def test_round_robin_bounds_wait_to_o_sessions(seed, n, n_tags, max_group):
    """Adversarial interleavings: under round_robin, while a stream has
    queued work it is served at least once every (#streams) dispatched
    groups — the starvation bound FIFO deliberately does not offer."""
    rng = np.random.default_rng(seed)
    items = _random_tagged(rng, n, n_tags)
    groups = plan_dispatch_groups_tagged(items, max_group,
                                         fairness="round_robin")
    remaining = {tag: sum(1 for t, _ in items if t == tag)
                 for tag in {t for t, _ in items}}
    bound = len(remaining)
    waits = {tag: 0 for tag in remaining}
    for g, _ in groups:
        served = {t for t, _ in g}
        for tag in list(remaining):
            if remaining[tag] <= 0:
                continue
            if tag in served:
                waits[tag] = 0
                remaining[tag] -= sum(1 for t, _ in g if t == tag)
            else:
                waits[tag] += 1
                assert waits[tag] < bound, (
                    f"stream {tag} waited {waits[tag]} dispatches with work "
                    f"queued (bound {bound})")


@settings(max_examples=40)
@given(seed=st.integers(0, 2**32 - 1), n=st.integers(1, 40),
       n_tags=st.integers(1, 5), max_group=st.integers(1, 6))
def test_fifo_fairness_always_anchors_queue_head(seed, n, n_tags, max_group):
    """fifo fairness: replaying the plan against the queue, every group
    contains the current global queue head (strict arrival order)."""
    rng = np.random.default_rng(seed)
    items = _random_tagged(rng, n, n_tags)
    groups = plan_dispatch_groups_tagged(items, max_group, fairness="fifo")
    queue = list(items)
    for g, _ in groups:
        assert queue[0] == g[0], "fifo plan skipped the queue head"
        for it in g:
            queue.remove(it)
    assert not queue


def test_tagged_head_rejects_non_oldest_anchor():
    items = [("a", (0, 2)), ("b", (0, 4)), ("a", (2, 5))]
    with pytest.raises(ValueError, match="oldest queued segment"):
        dispatch_group_head_tagged(items, 4, anchor=2)
    # anchoring b is fine: index 1 is b's oldest
    idx, cap, sealed = dispatch_group_head_tagged(items, 4, anchor=1)
    assert idx == [1] and cap == 4 and sealed
