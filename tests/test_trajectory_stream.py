"""Streamed trajectory: TrajectoryBuffer watermark semantics, strict
pose interpolation, and the pose-gated StreamingAggregator stall/release
path. The core guarantee under test: no code path silently extrapolates
a pose beyond the received trajectory, and released frames are posed
bit-identically to the offline oracle for any event x pose interleaving.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.geometry import SE3, so3_exp
from repro.events.aggregation import (
    StreamingAggregator,
    aggregate,
    concat_event_frames,
)
from repro.events.simulator import (
    EventStream,
    Trajectory,
    iter_trajectory_chunks,
    slice_trajectory,
)
from repro.events.trajectory_stream import (
    PoseExtrapolationError,
    PoseExtrapolationWarning,
    TrajectoryBuffer,
    enforce_pose_span,
    pose_at_times,
)


def _traj(n: int, t0: float = 0.0, t1: float = 1.0, seed: int = 0) -> Trajectory:
    rng = np.random.default_rng(seed)
    times = np.linspace(t0, t1, n).astype(np.float32)
    w = rng.uniform(-0.1, 0.1, (n, 3)).astype(np.float32)
    R = np.asarray(so3_exp(w), np.float32)
    t = np.cumsum(rng.uniform(-0.05, 0.05, (n, 3)), axis=0).astype(np.float32)
    return Trajectory(times=times, poses=SE3(R, t))


_slice = slice_trajectory


def _events(n: int, t0: float = 0.0, t1: float = 1.0, seed: int = 0) -> EventStream:
    rng = np.random.default_rng(seed)
    return EventStream(
        xy=rng.uniform(0, 200, (n, 2)).astype(np.float32),
        t=np.sort(rng.uniform(t0, t1, n).astype(np.float32)),
        polarity=rng.choice([-1, 1], n).astype(np.int8),
        valid=np.ones(n, bool),
    )


# --- pose_at_times: strict mode + degenerate trajectories -----------------


def test_pose_at_times_strict_raises_outside_span():
    traj = _traj(8)
    inside = np.asarray([0.2, 0.9], np.float32)
    p = pose_at_times(traj, inside, strict=True)
    assert p.R.shape == (2, 3, 3)
    with pytest.raises(PoseExtrapolationError, match="outside the trajectory"):
        pose_at_times(traj, np.asarray([0.2, 1.2], np.float32), strict=True)
    with pytest.raises(PoseExtrapolationError, match="outside the trajectory"):
        pose_at_times(traj, np.asarray([-0.1], np.float32), strict=True)
    # span endpoints are bracketed, not extrapolated
    pose_at_times(traj, np.asarray([0.0, 1.0], np.float32), strict=True)


def test_pose_at_times_single_sample_raises():
    """The seed clipped idx to [0, -1] and read times[idx + 1] out of
    range for a 1-pose trajectory; now it must refuse up front."""
    one = _slice(_traj(4), 0, 1)
    with pytest.raises(ValueError, match="at least 2 trajectory samples"):
        pose_at_times(one, np.asarray([0.0], np.float32))
    empty = _slice(_traj(4), 0, 0)
    with pytest.raises(ValueError, match="at least 2 trajectory samples"):
        pose_at_times(empty, np.asarray([0.0], np.float32))


def test_enforce_pose_span_policies():
    times = np.asarray([0.0, 1.0], np.float32)
    enforce_pose_span(times, np.asarray([1.5]), "clamp")  # silent by request
    with pytest.warns(PoseExtrapolationWarning, match="outside the trajectory"):
        enforce_pose_span(times, np.asarray([1.5]), "warn")
    with pytest.raises(PoseExtrapolationError):
        enforce_pose_span(times, np.asarray([-1.0]), "raise")
    with pytest.raises(ValueError, match="unknown pose_extrapolation"):
        enforce_pose_span(times, np.asarray([0.5]), "never")


# --- TrajectoryBuffer ------------------------------------------------------


def test_buffer_watermark_advances_monotonically():
    traj = _traj(12)
    buf = TrajectoryBuffer()
    assert buf.watermark == float("-inf") and buf.num_samples == 0
    assert not buf.covers(0.0)
    seen = float("-inf")
    for chunk in iter_trajectory_chunks(traj, 5):
        wm = buf.push(chunk)
        assert wm >= seen, "watermark must only advance"
        seen = wm
    assert buf.num_samples == 12
    assert seen == float(np.asarray(traj.times)[-1])
    assert bool(buf.covers(0.5)) and not bool(buf.covers(1.5))


def test_buffer_single_sample_has_no_coverage():
    traj = _traj(6)
    buf = TrajectoryBuffer(_slice(traj, 0, 1))
    assert buf.num_samples == 1
    assert buf.watermark == float("-inf")
    assert not bool(buf.covers(float(np.asarray(traj.times)[0])))
    with pytest.raises(PoseExtrapolationError, match="needs at least 2"):
        buf.pose_at_times(np.asarray([0.0], np.float32))


def test_buffer_rejects_out_of_order_and_malformed_chunks():
    traj = _traj(10)
    buf = TrajectoryBuffer(_slice(traj, 0, 4))
    with pytest.raises(ValueError, match="time order"):
        buf.push(_slice(traj, 2, 6))  # overlaps what is already buffered
    with pytest.raises(ValueError, match="strictly increasing"):
        buf.push(Trajectory(times=np.asarray([2.0, 2.0], np.float32),
                            poses=SE3(np.zeros((2, 3, 3), np.float32),
                                      np.zeros((2, 3), np.float32))))
    with pytest.raises(ValueError, match="shape mismatch"):
        buf.push(Trajectory(times=np.asarray([3.0], np.float32),
                            poses=SE3(np.zeros((2, 3, 3), np.float32),
                                      np.zeros((2, 3), np.float32))))
    # rejected chunks must not corrupt the buffer
    assert buf.num_samples == 4
    buf.push(_slice(traj, 4, 10))
    assert buf.num_samples == 10
    # empty chunks are a tracker tick with no keyposes: allowed, no-op
    assert buf.push(_slice(traj, 10, 10)) == buf.watermark


def test_buffer_prefix_interpolation_is_bitwise_stable():
    """For queries strictly below the watermark, interpolating against
    the received prefix must equal interpolating against the eventual
    full trajectory — bitwise. (This is what lets the aggregator release
    stalled frames before the trajectory ends.)"""
    traj = _traj(16, seed=3)
    times = np.asarray(traj.times)
    q = np.asarray(
        np.sort(np.random.default_rng(1).uniform(0.0, times[9] - 1e-4, 13)),
        np.float32)
    full = pose_at_times(traj, q)
    buf = TrajectoryBuffer(_slice(traj, 0, 10))  # covers beyond every query
    got = buf.pose_at_times(q)
    np.testing.assert_array_equal(np.asarray(got.R), np.asarray(full.R))
    np.testing.assert_array_equal(np.asarray(got.t), np.asarray(full.t))


def test_buffer_query_past_watermark_raises_with_watermark_context():
    traj = _traj(8)
    buf = TrajectoryBuffer(_slice(traj, 0, 4))
    wm = buf.watermark
    with pytest.raises(PoseExtrapolationError, match="watermark"):
        buf.pose_at_times(np.asarray([wm + 0.05], np.float32))


# --- pose-gated StreamingAggregator ----------------------------------------


@pytest.fixture()
def gated_setup(cam):
    traj = _traj(12, seed=2)
    ev = _events(100, seed=2)
    ref = aggregate(cam, ev, traj, events_per_frame=16)
    return traj, ev, ref


def _collect(parts) -> list:
    return [p for p in parts if p.xy.shape[0] > 0]


def test_gated_aggregator_stalls_then_releases_bitwise(cam, gated_setup):
    traj, ev, ref = gated_setup
    agg = StreamingAggregator(cam, TrajectoryBuffer(), events_per_frame=16)
    parts = [agg.push(ev)]
    assert parts[0].xy.shape[0] == 0, "no poses received -> everything stalls"
    assert agg.stalled_frames == 100 // 16
    released = 0
    for chunk in iter_trajectory_chunks(traj, 3):
        part = agg.push_poses(chunk)
        released += part.xy.shape[0]
        parts.append(part)
    parts.append(agg.flush())
    parts.append(agg.finalize_poses())
    assert agg.stalled_frames == 0
    got = concat_event_frames(_collect(parts))
    assert released >= 1, "interior pose chunks must release stalled frames"
    for name in ("xy", "valid", "t_mid"):
        np.testing.assert_array_equal(np.asarray(getattr(got, name)),
                                      np.asarray(getattr(ref, name)),
                                      err_msg=name)
    np.testing.assert_array_equal(np.asarray(got.poses.R),
                                  np.asarray(ref.poses.R))
    np.testing.assert_array_equal(np.asarray(got.poses.t),
                                  np.asarray(ref.poses.t))


def test_gated_aggregator_one_pose_chunk_releases_many(cam, gated_setup):
    traj, ev, _ = gated_setup
    agg = StreamingAggregator(cam, TrajectoryBuffer(), events_per_frame=16)
    agg.push(ev)
    n_stalled = agg.stalled_frames
    assert n_stalled >= 4
    part = agg.push_poses(traj)  # whole trajectory in one chunk
    assert part.xy.shape[0] >= n_stalled - 1, (
        "a single chunk advancing the watermark past many frames must "
        "release them all at once")
    assert agg.stalled_frames <= 1  # only a frame at/past the watermark may stall


def test_gated_aggregator_release_is_fifo(cam, gated_setup):
    traj, ev, _ = gated_setup
    agg = StreamingAggregator(cam, TrajectoryBuffer(), events_per_frame=16)
    agg.push(ev)
    t_mids = []
    for chunk in iter_trajectory_chunks(traj, 2):
        t_mids.extend(np.asarray(agg.push_poses(chunk).t_mid).tolist())
    t_mids.extend(np.asarray(agg.finalize_poses().t_mid).tolist())
    assert t_mids == sorted(t_mids), "stalled frames must release in order"


def test_gated_finalize_applies_policy_to_beyond_end_frames(cam):
    """Events past the final pose sample: warn-clamp by default, raise on
    strict pipelines — never a silent freeze."""
    traj = _traj(6, t0=0.0, t1=0.5)
    ev = _events(32, t0=0.0, t1=1.0, seed=5)  # second half past the poses
    agg = StreamingAggregator(cam, TrajectoryBuffer(), events_per_frame=8)
    agg.push(ev)
    agg.push_poses(traj)
    assert agg.stalled_frames > 0, "frames past the pose end must stall"
    with pytest.warns(PoseExtrapolationWarning, match="outside the trajectory"):
        released = agg.finalize_poses()
    assert agg.stalled_frames == 0
    # the clamped numerics equal the offline oracle's (warn != different values)
    ref = aggregate(cam, ev, traj, events_per_frame=8,
                    pose_extrapolation="clamp")
    np.testing.assert_array_equal(np.asarray(released.poses.t)[-1],
                                  np.asarray(ref.poses.t)[-1])

    strict = StreamingAggregator(cam, TrajectoryBuffer(), events_per_frame=8,
                                 pose_extrapolation="raise")
    strict.push(ev)
    strict.push_poses(traj)
    with pytest.raises(PoseExtrapolationError):
        strict.finalize_poses()


def test_gated_finalize_without_enough_samples_raises(cam):
    ev = _events(16, seed=7)
    agg = StreamingAggregator(cam, TrajectoryBuffer(), events_per_frame=8)
    agg.push(ev)
    with pytest.raises(PoseExtrapolationError, match="can never be posed"):
        agg.finalize_poses()


def test_oracle_aggregator_rejects_pose_stream_calls(cam):
    traj = _traj(4)
    agg = StreamingAggregator(cam, traj, events_per_frame=8)
    with pytest.raises(RuntimeError, match="TrajectoryBuffer"):
        agg.push_poses(traj)
    with pytest.raises(RuntimeError, match="TrajectoryBuffer"):
        agg.finalize_poses()


def test_interleaving_invariance_bitwise(cam):
    """Any interleaving of event chunks and pose chunks produces the same
    frames, bit-identical to the offline oracle aggregation."""
    traj = _traj(10, seed=4)
    ev = _events(120, seed=4)
    ref = aggregate(cam, ev, traj, events_per_frame=16)
    rng = np.random.default_rng(11)
    for trial in range(4):
        agg = StreamingAggregator(cam, TrajectoryBuffer(), events_per_frame=16)
        parts = []
        ev_cuts = np.sort(rng.integers(0, 121, size=3)).tolist()
        pose_cuts = np.sort(rng.integers(0, 11, size=2)).tolist()
        ev_slices = list(zip([0] + ev_cuts, ev_cuts + [120]))
        pose_slices = list(zip([0] + pose_cuts, pose_cuts + [10]))
        # alternate event and pose chunks (ragged: lists differ in length)
        while ev_slices or pose_slices:
            if ev_slices:
                lo, hi = ev_slices.pop(0)
                parts.append(agg.push(EventStream(
                    xy=ev.xy[lo:hi], t=ev.t[lo:hi],
                    polarity=ev.polarity[lo:hi], valid=ev.valid[lo:hi])))
            if pose_slices:
                lo, hi = pose_slices.pop(0)
                parts.append(agg.push_poses(_slice(traj, lo, hi)))
        parts.append(agg.flush())
        parts.append(agg.finalize_poses())
        got = concat_event_frames(_collect(parts))
        np.testing.assert_array_equal(np.asarray(got.xy), np.asarray(ref.xy),
                                      err_msg=f"trial {trial}")
        np.testing.assert_array_equal(np.asarray(got.poses.t),
                                      np.asarray(ref.poses.t),
                                      err_msg=f"trial {trial}")
        np.testing.assert_array_equal(np.asarray(got.poses.R),
                                      np.asarray(ref.poses.R),
                                      err_msg=f"trial {trial}")


# --- host/device contract ---------------------------------------------------


def test_emitted_frames_are_host_numpy_with_jnp_median_values(cam):
    """The aggregator's docstring promises frames stay on the host; t_mid
    must come out of np.median yet stay bit-identical to the previous
    jnp.median datapath."""
    import jax
    import jax.numpy as jnp

    n, e = 70, 16  # 4 full frames + a 6-event tail
    traj = _traj(6, seed=9)
    ev = _events(n, seed=9)
    agg = StreamingAggregator(cam, traj, events_per_frame=e)
    frames = agg.push(ev)
    tail = agg.flush()
    assert frames.xy.shape[0] == n // e and tail.xy.shape[0] == 1
    for f in (frames, tail):
        for field in (f.xy, f.valid, f.t_mid, f.poses.R, f.poses.t):
            assert isinstance(field, np.ndarray), type(field)
            assert not isinstance(field, jax.Array)
    # values: np.median == jnp.median bitwise on the same event times
    t_full = np.asarray(ev.t)[:n - n % e].reshape(-1, e)
    np.testing.assert_array_equal(
        frames.t_mid, np.asarray(jnp.median(jnp.asarray(t_full), axis=1)))
    np.testing.assert_array_equal(
        tail.t_mid,
        np.asarray(jnp.median(jnp.asarray(np.asarray(ev.t)[n - n % e:])))[None])
