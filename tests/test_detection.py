"""Scene-structure detection (stage D) unit behaviour."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.detection import DepthMap, detect_structure, median_filter3


def test_detects_planted_maxima():
    nz, h, w = 16, 32, 48
    dsi = np.ones((nz, h, w), np.float32)
    planes = jnp.linspace(1.0, 4.0, nz)
    # plant strong ray concentrations at known (z, y, x)
    spots = [(3, 10, 12), (8, 20, 30), (12, 5, 40)]
    for z, y, x in spots:
        dsi[z, y, x] = 50.0
    dm = detect_structure(jnp.asarray(dsi), planes, threshold_c=6.0,
                          min_votes=3.0)
    for z, y, x in spots:
        assert bool(dm.mask[y, x]), (z, y, x)
        assert abs(float(dm.depth[y, x]) - float(planes[z])) < 0.25
    # flat background is rejected
    assert int(dm.mask.sum()) <= len(spots) + 2


def test_subvoxel_refinement_interpolates():
    nz, h, w = 8, 4, 4
    dsi = np.zeros((nz, h, w), np.float32)
    # asymmetric peak: parabola vertex between planes 3 and 4
    dsi[2, 1, 1], dsi[3, 1, 1], dsi[4, 1, 1] = 10, 30, 28
    planes = jnp.linspace(1.0, 8.0, nz)
    dm = detect_structure(jnp.asarray(dsi), planes, threshold_c=1.0,
                          min_votes=1.0)
    d = float(dm.depth[1, 1])
    assert float(planes[3]) < d < float(planes[4])


def test_median_filter_smooths_outlier():
    depth = np.full((8, 8), 2.0, np.float32)
    depth[4, 4] = 50.0  # outlier
    mask = np.ones((8, 8), bool)
    out = median_filter3(jnp.asarray(depth), jnp.asarray(mask))
    assert abs(float(out[4, 4]) - 2.0) < 1e-5
    # masked-out pixels pass through untouched
    mask2 = mask.copy()
    mask2[4, 4] = False
    out2 = median_filter3(jnp.asarray(depth), jnp.asarray(mask2))
    assert float(out2[4, 4]) == 50.0


def test_confidence_is_depthwise_max():
    nz, h, w = 4, 3, 3
    rng = np.random.default_rng(0)
    dsi = rng.integers(0, 9, (nz, h, w)).astype(np.float32)
    dm = detect_structure(jnp.asarray(dsi), jnp.linspace(1, 2, nz))
    np.testing.assert_allclose(np.asarray(dm.confidence), dsi.max(0))
