"""Sharded-vs-batched segment-sweep equivalence.

`process_segments_sharded` runs the exact sweep body of
`process_segments_batched` with the segment axis sharded across mesh
devices, so the two backends must agree bitwise on the integer/nearest
datapaths and to float tolerance on bilinear — the same discipline PRs
1–2 imposed between looped/batched and offline/streaming.

Fast checks (1 device, main process) cover the `sweep=` wiring in
`run_emvs` and the streaming engine; the real test runs the 12-combo
grid on a forced-8-device host mesh in ONE subprocess (the dry-run
isolation rule: the main process must stay at one device), including
padded frames (uneven segment lengths) and padded segment rows (S not a
multiple of the mesh).
"""
from __future__ import annotations

import os
import subprocess
import sys

import pytest

from repro.core.dsi import DSIConfig
from repro.core.pipeline import EMVSOptions, plan_segments, run_emvs
from repro.serving.emvs_stream import EMVSStreamEngine, StreamConfig
from test_segment_batching import _assert_results_match, _synthetic_frames


def test_run_emvs_rejects_unknown_sweep(cam):
    dsi_cfg = DSIConfig.for_camera(cam, num_planes=8, z_min=0.5, z_max=3.5)
    frames = _synthetic_frames([0.0, 0.1, 0.2])
    with pytest.raises(ValueError, match="unknown sweep backend"):
        run_emvs(cam, dsi_cfg, frames, EMVSOptions(), sweep="looped")


def test_stream_config_rejects_unknown_sweep():
    with pytest.raises(ValueError, match="unknown sweep backend"):
        StreamConfig(sweep="magic")


def test_mesh_requires_sharded_sweep(cam):
    """mesh= with the batched sweep would be silently ignored — reject it."""
    from repro.distributed.emvs import make_segment_mesh
    from repro.events.simulator import Trajectory
    from repro.core.geometry import SE3
    import jax.numpy as jnp

    dsi_cfg = DSIConfig.for_camera(cam, num_planes=8, z_min=0.5, z_max=3.5)
    frames = _synthetic_frames([0.0, 0.1, 0.2])
    mesh = make_segment_mesh()
    with pytest.raises(ValueError, match="only meaningful"):
        run_emvs(cam, dsi_cfg, frames, EMVSOptions(), mesh=mesh)
    traj = Trajectory(times=jnp.asarray([0.0, 1.0]),
                      poses=SE3(jnp.broadcast_to(jnp.eye(3), (2, 3, 3)),
                                jnp.zeros((2, 3))))
    with pytest.raises(ValueError, match="only meaningful"):
        EMVSStreamEngine(cam, dsi_cfg, traj, mesh=mesh)


def test_mesh_without_segment_axis_rejected(cam):
    """A user mesh must name its segment axis 'segments' — otherwise the
    wiring would die with an opaque KeyError deep inside the sweep."""
    import jax
    import jax.numpy as jnp
    from repro.events.simulator import Trajectory
    from repro.core.geometry import SE3

    bad = jax.make_mesh((1,), ("segs",))
    dsi_cfg = DSIConfig.for_camera(cam, num_planes=8, z_min=0.5, z_max=3.5)
    frames = _synthetic_frames([0.0, 0.04, 0.08, 0.12])
    with pytest.raises(ValueError, match="'segments' axis"):
        run_emvs(cam, dsi_cfg, frames,
                 EMVSOptions(keyframe_dist_frac=0.05),
                 sweep="sharded", mesh=bad)
    traj = Trajectory(times=jnp.asarray([0.0, 1.0]),
                      poses=SE3(jnp.broadcast_to(jnp.eye(3), (2, 3, 3)),
                                jnp.zeros((2, 3))))
    with pytest.raises(ValueError, match="'segments' axis"):
        EMVSStreamEngine(cam, dsi_cfg, traj,
                         stream_cfg=StreamConfig(sweep="sharded"), mesh=bad)


def test_run_emvs_sharded_matches_batched_one_device(cam):
    """The sweep="sharded" wiring end to end on the (single-device) host
    mesh: same segments, bitwise-equal nearest DSIs, same clouds."""
    frames = _synthetic_frames(
        [0.0, 0.04, 0.08, 0.12, 0.16, 0.20, 0.24, 0.28, 0.32], events=48)
    dsi_cfg = DSIConfig.for_camera(cam, num_planes=8, z_min=0.5, z_max=3.5)
    opts = EMVSOptions(keyframe_dist_frac=0.05)
    assert len(plan_segments(frames, dsi_cfg, opts)) >= 2
    ref = run_emvs(cam, dsi_cfg, frames, opts)
    got = run_emvs(cam, dsi_cfg, frames, opts, sweep="sharded")
    _assert_results_match(got, ref, exact_dsi=True)


def test_stream_engine_sharded_one_device(cam, small_scene):
    """StreamConfig(sweep="sharded") drives dispatches through the sharded
    backend (single-device mesh) and still reproduces run_emvs bitwise."""
    from repro.serving.emvs_stream import iter_event_chunks

    ev, traj = small_scene["events"], small_scene["traj"]
    keep = 6 * 224
    from repro.events.simulator import EventStream

    ev = EventStream(xy=ev.xy[:keep], t=ev.t[:keep],
                     polarity=ev.polarity[:keep], valid=ev.valid[:keep])
    dsi_cfg = DSIConfig.for_camera(cam, num_planes=8, z_min=0.6, z_max=4.5)
    opts = EMVSOptions(keyframe_dist_frac=0.03)
    from repro.events.aggregation import aggregate

    frames = aggregate(cam, ev, traj, events_per_frame=224)
    ref = run_emvs(cam, dsi_cfg, frames, opts)
    engine = EMVSStreamEngine(
        cam, dsi_cfg, traj, opts,
        StreamConfig(events_per_frame=224, sweep="sharded"))
    # single-device mesh: rounding the S buckets to multiples of 1 is a no-op
    assert engine._segment_buckets == engine.stream_cfg.segment_buckets
    for c in iter_event_chunks(ev, 997):
        engine.push(c)
    res = engine.flush()
    _assert_results_match(res, ref, exact_dsi=True)


# ---------------------------------------------------------------------------
# The real equivalence grid: 8 host devices in one subprocess
# ---------------------------------------------------------------------------

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")  # subprocess cwd = repo root
import numpy as np
import jax, jax.numpy as jnp

from repro.core.camera import CameraModel
from repro.core.dsi import DSIConfig
from repro.core.geometry import SE3
from repro.core.pipeline import (EMVSOptions, pad_segments, plan_segments,
                                 process_segments_batched, run_emvs)
from repro.distributed.emvs import (SEGMENT_AXIS, make_segment_mesh,
                                    process_segments_sharded)
from repro.events.aggregation import EventFrames

mesh = make_segment_mesh()
assert mesh.shape[SEGMENT_AXIS] == 8, mesh

# Small sensor keeps the 12-combo grid affordable: the sweep body is the
# same code whatever the resolution.
cam = CameraModel(width=64, height=48, fx=60.0, fy=60.0, cx=32.0, cy=24.0)
dsi_cfg = DSIConfig.for_camera(cam, num_planes=8, z_min=0.6, z_max=4.5)

def synthetic_frames(n, events=48, seed=0):
    r = np.random.default_rng(seed)
    xy = np.stack([r.uniform(0, cam.width - 1, (n, events)),
                   r.uniform(0, cam.height - 1, (n, events))],
                  axis=-1).astype(np.float32)
    t = np.zeros((n, 3), np.float32)
    t[:, 0] = np.linspace(0.0, 0.4, n)
    return EventFrames(
        xy=jnp.asarray(xy), valid=jnp.ones((n, events), jnp.float32),
        t_mid=jnp.arange(n, dtype=jnp.float32),
        poses=SE3(jnp.broadcast_to(jnp.eye(3, dtype=jnp.float32), (n, 3, 3)),
                  jnp.asarray(t)))

# --- 1. 12-combo grid on one padded SegmentBatch --------------------------
# 8 segments alternating 3/4 frames at capacity 4: padded FRAME slots in
# every other row, S exactly the mesh size.
lens = [3, 4] * 4
bounds, start = [], 0
for L in lens:
    bounds.append((start, start + L)); start += L
frames = synthetic_frames(start)
batch = pad_segments(frames, bounds, capacity=4)
assert batch.xy.shape[0] == 8

GRID = [(f, v, q)
        for f in ("scatter", "matmul", "kernel")
        for v in ("nearest", "bilinear")
        for q in (False, True)]
for f, v, q in GRID:
    opts = EMVSOptions(formulation=f, voting=v, quantized=q,
                       keyframe_dist_frac=0.05)
    dsis_b, dms_b = process_segments_batched(cam, dsi_cfg, batch, opts)
    dsis_s, dms_s = process_segments_sharded(cam, dsi_cfg, batch, opts,
                                             mesh=mesh)
    if v == "nearest":
        np.testing.assert_array_equal(np.asarray(dsis_s), np.asarray(dsis_b))
        np.testing.assert_array_equal(np.asarray(dms_s.depth),
                                      np.asarray(dms_b.depth))
    else:
        np.testing.assert_allclose(np.asarray(dsis_s, np.float32),
                                   np.asarray(dsis_b, np.float32), atol=1e-4)
        m = np.asarray(dms_b.mask)
        np.testing.assert_allclose(np.asarray(dms_s.depth)[m],
                                   np.asarray(dms_b.depth)[m], atol=1e-5)
    np.testing.assert_array_equal(np.asarray(dms_s.mask),
                                  np.asarray(dms_b.mask))
    np.testing.assert_allclose(np.asarray(dms_s.confidence),
                               np.asarray(dms_b.confidence), atol=1e-4)
    print(f"OK grid {f}/{v}/{'q' if q else 'f'}")

# --- 2. S not divisible by the mesh -> clear error ------------------------
small = pad_segments(frames, bounds[:3], capacity=4)
try:
    process_segments_sharded(cam, dsi_cfg, small, EMVSOptions(), mesh=mesh)
    raise AssertionError("expected ValueError for S=3 on an 8-way mesh")
except ValueError as e:
    assert "multiple" in str(e), e
print("OK divisibility_error")

# --- 3. run_emvs(sweep='sharded'): padded SEGMENT ROWS --------------------
# planner yields a segment count that is NOT a multiple of 8, so the
# sharded path pads S internally and must discard the padded rows.
opts = EMVSOptions(keyframe_dist_frac=0.05)
segs = plan_segments(frames, dsi_cfg, opts)
assert len(segs) % 8 != 0, segs
ref = run_emvs(cam, dsi_cfg, frames, opts)
got = run_emvs(cam, dsi_cfg, frames, opts, sweep="sharded", mesh=mesh)
assert [s.frame_range for s in got.segments] == \
       [s.frame_range for s in ref.segments]
for sa, sb in zip(got.segments, ref.segments):
    np.testing.assert_array_equal(np.asarray(sa.dsi), np.asarray(sb.dsi))
    np.testing.assert_array_equal(np.asarray(sa.depth_map.mask),
                                  np.asarray(sb.depth_map.mask))
    np.testing.assert_array_equal(np.asarray(sa.depth_map.depth),
                                  np.asarray(sb.depth_map.depth))
for ca, cb in zip(got.clouds, ref.clouds):
    np.testing.assert_array_equal(np.asarray(ca.valid), np.asarray(cb.valid))
print("OK run_emvs_sharded")

# --- 4. streaming engine on the sharded backend ---------------------------
from repro.events.simulator import (SceneConfig, make_scene, make_trajectory,
                                    simulate_events)
from repro.events.aggregation import aggregate
from repro.serving.emvs_stream import (EMVSStreamEngine, StreamConfig,
                                       iter_event_chunks)
scene = make_scene(SceneConfig(name="simulation_3planes", points_per_plane=40))
traj = make_trajectory("simulation_3planes", 12)
ev = simulate_events(cam, scene, traj, noise_fraction=0.0)
e_frame = 160
frames2 = aggregate(cam, ev, traj, events_per_frame=e_frame)
opts2 = EMVSOptions(keyframe_dist_frac=0.03)
ref2 = run_emvs(cam, dsi_cfg, frames2, opts2)
assert len(ref2.segments) >= 2
scfg = StreamConfig(events_per_frame=e_frame, segment_buckets=(1, 2, 4),
                    sweep="sharded")
engine = EMVSStreamEngine(cam, dsi_cfg, traj, opts2, scfg, mesh=mesh)
# S buckets rounded up to multiples of the mesh: shard-stable shapes
assert engine._segment_buckets == (8,), engine._segment_buckets
for c in iter_event_chunks(ev, 731):
    engine.push(c)
res2 = engine.flush()
assert [s.frame_range for s in res2.segments] == \
       [s.frame_range for s in ref2.segments]
for sa, sb in zip(res2.segments, ref2.segments):
    np.testing.assert_array_equal(np.asarray(sa.dsi), np.asarray(sb.dsi))
    np.testing.assert_array_equal(np.asarray(sa.depth_map.depth),
                                  np.asarray(sb.depth_map.depth))
assert engine.stats["dispatches"] >= 1
print("OK stream_sharded")
print("ALL_SHARDED_OK")
"""


@pytest.mark.slow
def test_sharded_sweep_suite():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=1500, env=env,
                       cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert "ALL_SHARDED_OK" in r.stdout, (
        f"STDOUT:\n{r.stdout[-3000:]}\nSTDERR:\n{r.stderr[-5000:]}")
