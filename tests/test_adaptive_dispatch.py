"""Load-profile equivalence suite for the dispatch policies.

The coalescing queue may reschedule closed segments — one sweep per
segment ("latency"), largest-fitting-S-bucket batches ("throughput"),
or depth-dependent switching ("adaptive") — but it may never change the
numbers: for every policy x queue-depth profile (steady trickle, burst,
starve-then-flood) x sweep backend, the streamed result must equal
offline `run_emvs` bit-for-bit on the nearest/integer datapaths and to
float tolerance on bilinear.

Also pinned here:
  * the coalescing planner's partition invariants (hypothesis: any
    segment sequence, any gating policy -> valid S buckets, nothing
    dropped, duplicated, or reordered across the FIFO release order);
  * `_FrameStore` eviction and `PoseStallError` recovery under coalesced
    dispatch (stalled frames never dispatch past the pose watermark; a
    late pose chunk drains the coalesced queue bit-identically);
  * the stats counters (queue depth, coalesce counts) reconciling with
    the number of dispatches and segments across a stream;
  * the aggregator's max-stall back-pressure bound (raise on a tracker
    too far behind; recover without losing events).
"""
from __future__ import annotations

from collections import deque

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.dsi import DSIConfig
from repro.core.pipeline import (
    EMVSOptions,
    bucket_capacity,
    dispatch_group_head,
    plan_dispatch_groups,
    run_emvs,
)
from repro.events.aggregation import StreamingAggregator, aggregate
from repro.events.simulator import EventStream
from repro.events.trajectory_stream import PoseStallError
from repro.serving.emvs_stream import (
    DISPATCH_POLICIES,
    EMVSStreamEngine,
    StreamConfig,
    iter_event_chunks,
)
from test_segment_batching import _assert_results_match

EVENTS_PER_FRAME = 224  # does not divide the stream -> exercises the tail

# Queue-depth profiles: how fast closed segments pile up in front of the
# dispatcher. "trickle" pushes one frame of events at a time, so segments
# close one by one and the in-flight queue stays shallow; "burst" pushes
# the whole stream in a single chunk, closing every segment in one
# planner pass; "starve_flood" starves a pose-gated engine of poses (all
# frames stall, nothing may dispatch), then floods it with the entire
# trajectory in one chunk — the stall queue drains into the coalescing
# queue at once.
LOAD_PROFILES = ("trickle", "burst", "starve_flood")

GRID_OPTS = dict(formulation="matmul", voting="nearest", quantized=True,
                 keyframe_dist_frac=0.03)
BILINEAR_OPTS = dict(formulation="scatter", voting="bilinear",
                     quantized=False, keyframe_dist_frac=0.03)


@pytest.fixture(scope="module")
def dispatch_scene(cam, small_scene):
    """small_scene re-aggregated small enough that the 3-policy x
    3-profile x 2-backend grid stays affordable, with a partial tail and
    several same-capacity segments for the coalescer to batch."""
    ev = small_scene["events"]
    traj = small_scene["traj"]
    n = int(ev.t.shape[0])
    keep = min(n, 13 * EVENTS_PER_FRAME + 32)  # 13 full frames + a tail
    ev = EventStream(xy=ev.xy[:keep], t=ev.t[:keep],
                     polarity=ev.polarity[:keep], valid=ev.valid[:keep])
    frames = aggregate(cam, ev, traj, events_per_frame=EVENTS_PER_FRAME)
    dsi_cfg = DSIConfig.for_camera(cam, num_planes=12, z_min=0.6, z_max=4.5)
    refs = {
        "nearest": run_emvs(cam, dsi_cfg, frames, EMVSOptions(**GRID_OPTS)),
        "bilinear": run_emvs(cam, dsi_cfg, frames,
                             EMVSOptions(**BILINEAR_OPTS)),
    }
    assert len(refs["nearest"].segments) >= 3, \
        "scene must close several segments for coalescing to matter"
    return ev, traj, refs, dsi_cfg


def _drive(engine: EMVSStreamEngine, ev: EventStream, traj, profile: str):
    """Run one stream under the given queue-depth profile and flush."""
    if profile == "trickle":
        for c in iter_event_chunks(ev, EVENTS_PER_FRAME):
            engine.push(c)
    elif profile == "burst":
        engine.push(next(iter_event_chunks(ev, int(ev.t.shape[0]))))
    elif profile == "starve_flood":
        for c in iter_event_chunks(ev, 997):
            engine.push(c)  # starve: no poses, every frame stalls
        engine.push_poses(traj)  # flood: one chunk releases everything
        engine.finalize_poses()
    else:
        raise AssertionError(f"unknown profile {profile}")
    return engine.flush()


def _make_engine(cam, dsi_cfg, traj, opts, profile, policy, sweep):
    pose_gated = profile == "starve_flood"
    return EMVSStreamEngine(
        cam, dsi_cfg, None if pose_gated else traj, EMVSOptions(**opts),
        StreamConfig(events_per_frame=EVENTS_PER_FRAME,
                     dispatch_policy=policy, sweep=sweep))


def _assert_stats_reconcile(stats: dict, n_segments: int) -> None:
    """The counter identities every stream must satisfy after flush:
    each dispatched group is either solo or coalesced, groups partition
    the segments, and the coalescing queue has fully drained."""
    solo = stats["dispatches"] - stats["coalesced_dispatches"]
    assert solo >= 0
    assert stats["segments"] == stats["coalesced_segments"] + solo, stats
    assert stats["segments"] == n_segments
    assert stats["coalesced_segments"] >= 2 * stats["coalesced_dispatches"]
    assert stats["pending_segments"] == 0, "coalescing queue not drained"
    assert stats["max_pending"] >= 0
    _assert_hist_reconciles(stats["queue_wait_s"], stats["segments"])
    _assert_hist_reconciles(stats["sweep_time_s"], stats["dispatches"])


def _assert_hist_reconciles(hist: dict, expected_count: int) -> None:
    """The latency-histogram identities: one sample per event, every
    sample binned exactly once, and the sum of waits equal to the sum of
    out-timestamps minus the sum of in-timestamps — a histogram that
    lost, duplicated, or clock-skewed a sample cannot satisfy all
    three. (The raw signal the cost recorder consumes; see
    docs/dispatch_planning.md.)"""
    assert hist["count"] == expected_count, hist
    assert sum(hist["bins"]) == hist["count"], hist
    assert len(hist["bins"]) == len(hist["bin_edges_s"]) + 1
    assert hist["total_s"] >= 0.0
    assert 0.0 <= hist["max_s"] <= hist["total_s"] + 1e-12 or hist["count"] == 0
    # sum of waits == sum of dispatch timestamps - sum of enqueue
    # timestamps (resp. harvest - dispatch): the reconciliation identity
    assert abs(hist["total_s"] - (hist["t_out_sum"] - hist["t_in_sum"])) < 1e-6, hist


# --- the headline grid ----------------------------------------------------


@pytest.mark.parametrize("sweep", ("batched", "sharded"))
@pytest.mark.parametrize("profile", LOAD_PROFILES)
@pytest.mark.parametrize("policy", DISPATCH_POLICIES)
def test_policy_profile_backend_bitwise(cam, dispatch_scene, policy, profile,
                                        sweep):
    """Every policy x load profile x backend reproduces offline run_emvs
    bit-for-bit on the nearest/integer datapath: the dispatch schedule
    may change, the numbers may not."""
    ev, traj, refs, dsi_cfg = dispatch_scene
    ref = refs["nearest"]
    engine = _make_engine(cam, dsi_cfg, traj, GRID_OPTS, profile, policy,
                          sweep)
    res = _drive(engine, ev, traj, profile)
    _assert_results_match(res, ref, exact_dsi=True)
    _assert_stats_reconcile(engine.stats, len(ref.segments))


@pytest.mark.parametrize("policy", DISPATCH_POLICIES)
def test_policy_bilinear_allclose(cam, dispatch_scene, policy):
    """Bilinear voting accumulates float weights, so policies must agree
    with offline to float tolerance (burst maximizes coalescing)."""
    ev, traj, refs, dsi_cfg = dispatch_scene
    engine = _make_engine(cam, dsi_cfg, traj, BILINEAR_OPTS, "burst", policy,
                          "batched")
    res = _drive(engine, ev, traj, "burst")
    _assert_results_match(res, refs["bilinear"], exact_dsi=False)


# --- schedule shape: the policies do what they claim ----------------------


def test_latency_policy_dispatches_per_segment(cam, dispatch_scene):
    """The per-segment baseline: one dispatch per segment, never a
    coalesced batch, regardless of how many segments a push closes."""
    ev, traj, refs, dsi_cfg = dispatch_scene
    engine = _make_engine(cam, dsi_cfg, traj, GRID_OPTS, "burst", "latency",
                          "batched")
    _drive(engine, ev, traj, "burst")
    assert engine.stats["dispatches"] == engine.stats["segments"]
    assert engine.stats["coalesced_dispatches"] == 0
    assert engine.stats["coalesced_segments"] == 0


@pytest.mark.parametrize("profile", LOAD_PROFILES)
def test_throughput_policy_matches_planner_partition(cam, dispatch_scene,
                                                     profile):
    """The throughput schedule is exactly `plan_dispatch_groups` over the
    full closed-segment sequence, for every load profile: deferring an
    unsealed head group until it can no longer grow reproduces the
    offline partition online."""
    ev, traj, refs, dsi_cfg = dispatch_scene
    segs = [s.frame_range for s in refs["nearest"].segments]
    scfg = StreamConfig(events_per_frame=EVENTS_PER_FRAME,
                        dispatch_policy="throughput")
    groups = plan_dispatch_groups(segs, scfg.segment_buckets[-1])
    engine = _make_engine(cam, dsi_cfg, traj, GRID_OPTS, profile,
                          "throughput", "batched")
    _drive(engine, ev, traj, profile)
    assert engine.stats["dispatches"] == len(groups)
    coalesced = [g for g, _ in groups if len(g) > 1]
    assert engine.stats["coalesced_dispatches"] == len(coalesced)
    assert engine.stats["coalesced_segments"] == sum(map(len, coalesced))


def test_burst_coalesces_under_adaptive_and_throughput(cam, dispatch_scene):
    """A burst must actually exercise the coalescing path: with every
    segment closing in one planner pass, throughput (always) and
    adaptive (once the in-flight queue saturates) dispatch batched
    groups, and the queue's high-water mark shows segments waited."""
    ev, traj, refs, dsi_cfg = dispatch_scene
    segs = [s.frame_range for s in refs["nearest"].segments]
    groups = plan_dispatch_groups(segs, StreamConfig().segment_buckets[-1])
    if not any(len(g) > 1 for g, _ in groups):
        pytest.skip("scene closed no coalescible run (fixture guards this)")
    for policy in ("throughput", "adaptive"):
        engine = _make_engine(cam, dsi_cfg, traj, GRID_OPTS, "burst", policy,
                              "batched")
        _drive(engine, ev, traj, "burst")
        assert engine.stats["max_pending"] >= 2, (
            f"{policy}: burst never deepened the coalescing queue")
        assert engine.stats["dispatches"] < engine.stats["segments"], (
            f"{policy}: burst dispatched per-segment, nothing coalesced")
        assert engine.stats["coalesced_dispatches"] >= 1


# --- stall x coalescing: frames never dispatch past the watermark ---------


def test_stalled_frames_never_reach_coalescing_queue(cam, dispatch_scene):
    """Pose-starved frames stall upstream of the planner: neither the
    coalescing queue nor the dispatcher may see a frame whose pose is
    past the watermark, under any policy."""
    ev, traj, refs, dsi_cfg = dispatch_scene
    for policy in DISPATCH_POLICIES:
        engine = _make_engine(cam, dsi_cfg, traj, GRID_OPTS, "starve_flood",
                              policy, "batched")
        for c in iter_event_chunks(ev, 997):
            engine.push(c)
        assert engine.stats["dispatches"] == 0, policy
        assert engine.stats["pending_segments"] == 0, (
            f"{policy}: unposed frames leaked into the coalescing queue")
        assert engine.stats["max_pending"] == 0, policy
        assert engine.aggregator.stalled_frames > 0
        engine.push_poses(traj)
        engine.finalize_poses()
        engine.flush()


def test_stall_recovery_drains_coalesced_queue_bitwise(cam, dispatch_scene):
    """flush with poses missing raises PoseStallError without dispatching
    anything; the late pose chunk then drains the whole coalesced
    backlog bit-identically, and the frame store's eviction window ends
    exactly at the open segment (no underflow through the burst)."""
    ev, traj, refs, dsi_cfg = dispatch_scene
    ref = refs["nearest"]
    engine = _make_engine(cam, dsi_cfg, traj, GRID_OPTS, "starve_flood",
                          "throughput", "batched")
    for c in iter_event_chunks(ev, 997):
        engine.push(c)
    with pytest.raises(PoseStallError):
        engine.flush()
    assert engine.stats["dispatches"] == 0, (
        "a failed flush must not dispatch stalled frames")
    engine.push_poses(traj)  # late chunk: releases the whole backlog
    engine.finalize_poses()
    res = engine.flush()
    _assert_results_match(res, ref, exact_dsi=True)
    _assert_stats_reconcile(engine.stats, len(ref.segments))
    # eviction ran through the released backlog without underflow
    assert engine._store.base == engine.planner.open_start
    assert engine._store.base <= engine._store.end


# --- max-stall back-pressure ----------------------------------------------


def test_max_stall_bound_raises_and_recovers_bitwise(cam, dispatch_scene):
    """With `max_stalled_frames` set, an event front outrunning the
    tracker raises PoseStallError mid-stream; the stalled frames stay
    buffered, so pushing the missing poses and resuming the event stream
    finishes bit-identical to offline."""
    ev, traj, refs, dsi_cfg = dispatch_scene
    ref = refs["nearest"]
    bound = 3
    engine = EMVSStreamEngine(
        cam, dsi_cfg, None, EMVSOptions(**GRID_OPTS),
        StreamConfig(events_per_frame=EVENTS_PER_FRAME,
                     dispatch_policy="adaptive", max_stalled_frames=bound))
    chunks = list(iter_event_chunks(ev, 997))
    resume_from = None
    for i, c in enumerate(chunks):
        try:
            engine.push(c)
        except PoseStallError as err:
            assert f"max_stalled={bound}" in str(err)
            resume_from = i + 1
            break
    assert resume_from is not None, (
        f"{len(chunks)} pose-less chunks never tripped the {bound}-frame "
        f"stall bound")
    # the failed push still recorded the true stall peak (the raise must
    # not skip the stats update — max_stalled is exported by benchmarks)
    assert engine.stats["max_stalled"] > bound
    assert engine.stats["stalled_frames"] == engine.aggregator.stalled_frames
    # the offending chunk's frames were buffered, not dropped: poses
    # drain the stall queue and the stream resumes where it left off
    engine.push_poses(traj)
    assert engine.aggregator.stalled_frames <= bound
    for c in chunks[resume_from:]:
        engine.push(c)
    engine.finalize_poses()
    res = engine.flush()
    _assert_results_match(res, ref, exact_dsi=True)
    _assert_stats_reconcile(engine.stats, len(ref.segments))


def test_flush_tripping_stall_bound_still_updates_stats(cam, dispatch_scene):
    """The tail frame emitted by flush() can itself trip the max-stall
    bound; the raise must not leave the engine's stall stats stale."""
    ev, traj, refs, dsi_cfg = dispatch_scene
    n_frames = int(ev.t.shape[0]) // EVENTS_PER_FRAME
    engine = EMVSStreamEngine(
        cam, dsi_cfg, None, EMVSOptions(**GRID_OPTS),
        StreamConfig(events_per_frame=EVENTS_PER_FRAME,
                     max_stalled_frames=n_frames))  # full frames fit exactly
    for c in iter_event_chunks(ev, int(ev.t.shape[0])):
        engine.push(c)
    assert engine.stats["max_stalled"] == n_frames
    with pytest.raises(PoseStallError, match=f"max_stalled={n_frames}"):
        engine.flush()  # the padded tail frame overflows the bound
    assert engine.stats["max_stalled"] == n_frames + 1, (
        "the failed flush must record the true stall peak")
    assert engine.stats["stalled_frames"] == n_frames + 1
    # recovery is unchanged: poses release everything, results bitwise
    engine.push_poses(traj)
    engine.finalize_poses()
    res = engine.flush()
    _assert_results_match(res, refs["nearest"], exact_dsi=True)


def test_max_stall_bound_validation(cam, dispatch_scene):
    _, traj, _, dsi_cfg = dispatch_scene
    with pytest.raises(ValueError, match="max_stalled_frames"):
        StreamConfig(max_stalled_frames=0)
    with pytest.raises(ValueError, match="max_stalled"):
        StreamingAggregator(cam, traj, 64, max_stalled=-1)
    with pytest.raises(ValueError, match="dispatch_policy"):
        StreamConfig(dispatch_policy="asap")
    # the bound is pose-gated-only: a Trajectory oracle never stalls, so
    # accepting it would make the flag a silent no-op
    with pytest.raises(ValueError, match="pose-gated"):
        EMVSStreamEngine(cam, dsi_cfg, traj,
                         stream_cfg=StreamConfig(max_stalled_frames=4))
    with pytest.raises(ValueError, match="TrajectoryBuffer"):
        StreamingAggregator(cam, traj, 64, max_stalled=4)


# --- the coalescing planner (pure, host-side) -----------------------------


def _random_segments(rng: np.random.Generator, n: int) -> list[tuple[int, int]]:
    """n consecutive closed segments with random lengths (1..13 frames),
    the shape the planner emits: half-open, abutting, ascending."""
    lens = rng.integers(1, 14, size=n)
    starts = np.concatenate([[0], np.cumsum(lens)])
    return [(int(starts[i]), int(starts[i + 1])) for i in range(n)]


def test_dispatch_group_head_basics():
    # run capped by max_group; sealed by cap change or full group
    segs = [(0, 2), (2, 4), (4, 8), (8, 13)]  # caps 4, 4, 4, 8
    assert dispatch_group_head(segs, 4) == (3, 4, True)  # sealed by (8,13)
    assert dispatch_group_head(segs, 2) == (2, 4, True)  # sealed: full
    assert dispatch_group_head(segs[:2], 4) == (2, 4, False)  # can grow
    assert dispatch_group_head(segs[3:], 4) == (1, 8, False)
    with pytest.raises(ValueError, match="non-empty"):
        dispatch_group_head([], 4)
    with pytest.raises(ValueError, match="max_group"):
        dispatch_group_head(segs, 0)


@settings(deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(0, 40),
       max_group=st.sampled_from([1, 2, 3, 4, 8]))
def test_plan_dispatch_groups_is_valid_partition(seed, n, max_group):
    """Any segment sequence partitions into valid S buckets: groups
    concatenate back to the input (no drop/dup/reorder), each group
    holds 1..max_group segments of one shared capacity, and a group only
    ends because it was full or the capacity changed (maximality)."""
    rng = np.random.default_rng(seed)
    segs = _random_segments(rng, n)
    groups = plan_dispatch_groups(segs, max_group)
    flat = [s for g, _ in groups for s in g]
    assert flat == segs
    for g, cap in groups:
        assert 1 <= len(g) <= max_group
        assert all(bucket_capacity(e - s) == cap for s, e in g)
    for (g1, c1), (_, c2) in zip(groups, groups[1:]):
        assert len(g1) == max_group or c1 != c2, (
            "planner split a growable same-capacity run")


@settings(deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(0, 24),
       gates=st.lists(st.booleans(), max_size=80))
def test_fifo_release_under_arbitrary_dispatch_gating(seed, n, gates):
    """Model EVERY dispatch policy as an arbitrary gate sequence over the
    coalescing queue (dispatch-the-head-group vs keep-coalescing,
    interleaved with arrivals): whatever the gating, the released groups
    are valid S buckets and concatenate to the arrival order — segments
    are never dropped, duplicated, or reordered across FIFO release."""
    rng = np.random.default_rng(seed)
    segs = _random_segments(rng, n)
    max_group = 4
    pending: deque[tuple[int, int]] = deque()
    arrived: list[tuple[int, int]] = []
    released: list[list[tuple[int, int]]] = []
    it = iter(segs)
    for open_gate in gates:
        if open_gate and pending:
            k, cap, _ = dispatch_group_head(pending, max_group)
            g = [pending.popleft() for _ in range(k)]
            assert all(bucket_capacity(e - s) == cap for s, e in g)
            released.append(g)
        else:
            nxt = next(it, None)
            if nxt is not None:
                pending.append(nxt)
                arrived.append(nxt)
    for nxt in it:  # remaining arrivals
        pending.append(nxt)
        arrived.append(nxt)
    while pending:  # final drain (flush)
        k, cap, _ = dispatch_group_head(pending, max_group)
        g = [pending.popleft() for _ in range(k)]
        assert all(bucket_capacity(e - s) == cap for s, e in g)
        released.append(g)
    assert [s for g in released for s in g] == arrived == segs
    assert all(1 <= len(g) <= max_group for g in released)
