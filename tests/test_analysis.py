"""Quantization-contract linter: the dtype-flow interpreter, the rule
set, the baseline mechanics, and the CLI grid.

The acceptance contract (ISSUE 8): the shipped sweep grid lints clean,
and a *fixture* program that re-introduces the PR 3 bug pattern — an
integer-dtype psum/accumulate of fractional bilinear votes — is caught
as a dtype-flow finding with jaxpr provenance. The fixtures here are
deliberately broken programs, never the shipped code.
"""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src import core as jcore

from repro.analysis.dtype_flow import absval_from_aval, analyze_program
from repro.analysis.findings import (
    Finding,
    Provenance,
    load_baseline,
    split_by_baseline,
    write_baseline,
)
from repro.analysis.rules import audit_variant_space, default_rules
from repro.analysis import lint as lint_cli


def _contract(shape, dtype, lo, hi, integral=False):
    base = absval_from_aval(jcore.ShapedArray(shape, dtype))
    return base.with_(lo=float(lo), hi=float(hi), integral=integral, known=True)


SAT_INT16 = frozenset({(-32768.0, 32767.0)})


# ---------------------------------------------------------------------------
# the PR 3 bug class: fixtures must be caught, the sanctioned store must not
# ---------------------------------------------------------------------------


def test_pr3_fixture_int_psum_of_fractional_votes_is_caught():
    """The exact PR 3 pattern: bilinear (fractional) votes narrowed to an
    integer dtype before an integer psum inside a shard_map body."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P

    mesh = Mesh(np.array(jax.devices()[:1]), ("segments",))

    def fixture(votes):  # (S, E) fractional bilinear weights in [0, 1]
        def local(v):
            dsi = v.sum(axis=0)
            # BUG (on purpose): narrows fractional votes to int before psum
            return jax.lax.psum(dsi.astype(jnp.int32), "segments")

        return shard_map(local, mesh=mesh, in_specs=(P("segments"),),
                         out_specs=P(), check_rep=False)(votes)

    ctx = analyze_program(
        fixture,
        (jax.ShapeDtypeStruct((1, 8), jnp.float32),),
        [_contract((1, 8), jnp.float32, 0.0, 1.0)],
        entry="fixture-pr3",
        rules=default_rules(),
        sanctioned_clips=SAT_INT16,
    )
    truncs = [f for f in ctx.findings if f.kind == "float-to-int-truncation"]
    assert truncs, "the PR 3 bug pattern must be a dtype-flow finding"
    f = truncs[0]
    # jaxpr provenance: primitive, source equation, enclosing call stack
    assert f.rule == "dtype-flow"
    assert f.provenance.primitive == "convert_element_type"
    assert "shard_map" in f.provenance.call_stack
    assert f.provenance.source and f.provenance.source != "<unknown>"
    assert "test_analysis" in f.provenance.source


def test_sanctioned_saturating_store_is_clean():
    """round + clamp-to-declared-format + cast is the Table 1 store, not a
    bug: clamp provenance sanctions the cast."""

    def store(votes):
        v = jnp.clip(jnp.round(votes), -32768, 32767)
        return v.astype(jnp.int16)

    ctx = analyze_program(
        store,
        (jax.ShapeDtypeStruct((8,), jnp.float32),),
        [_contract((8,), jnp.float32, 0.0, 1e6)],
        entry="store",
        rules=default_rules(),
        sanctioned_clips=SAT_INT16,
    )
    assert ctx.findings == []


def test_unclamped_fractional_cast_is_caught_even_in_range():
    """Interval containment is NOT sanction: a fractional value whose range
    happens to fit int16 still loses its fractional part."""

    def fixture(votes):
        return votes.astype(jnp.int16)  # bounds fit, fraction discarded

    ctx = analyze_program(
        fixture,
        (jax.ShapeDtypeStruct((8,), jnp.float32),),
        [_contract((8,), jnp.float32, 0.0, 0.75)],
        entry="fixture-inrange",
        rules=default_rules(),
        sanctioned_clips=SAT_INT16,
    )
    assert [f.kind for f in ctx.findings] == ["float-to-int-truncation"]


def test_clamp_to_undeclared_bounds_is_not_sanctioned():
    """A clamp only sanctions the cast if its bounds match a declared
    format — clip(x, 0, 100) before an int cast is still a truncation."""

    def fixture(votes):
        return jnp.clip(votes, 0.0, 100.0).astype(jnp.int16)

    ctx = analyze_program(
        fixture,
        (jax.ShapeDtypeStruct((8,), jnp.float32),),
        [_contract((8,), jnp.float32, 0.0, 1e6)],
        entry="fixture-undeclared-clip",
        rules=default_rules(),
        sanctioned_clips=SAT_INT16,
    )
    assert [f.kind for f in ctx.findings] == ["float-to-int-truncation"]


# ---------------------------------------------------------------------------
# overflow proofs
# ---------------------------------------------------------------------------


def test_int16_scan_accumulator_overflow_is_proven():
    """600 frames x up-to-64 votes/frame cannot fit int16: the scan
    closed-form linear-growth bound must prove the wrap statically."""

    def fixture(frames_votes):  # (600, 64) 0/1 vote mask
        def body(acc, v):
            votes = jnp.sum(v).astype(jnp.int16)
            return acc + votes, None

        return jax.lax.scan(body, jnp.zeros((), jnp.int16), frames_votes)[0]

    ctx = analyze_program(
        fixture,
        (jax.ShapeDtypeStruct((600, 64), jnp.float32),),
        [_contract((600, 64), jnp.float32, 0.0, 1.0, integral=True)],
        entry="fixture-overflow",
        rules=default_rules(),
        sanctioned_clips=SAT_INT16,
    )
    kinds = {f.kind for f in ctx.findings}
    assert "int-overflow" in kinds
    # 600 * 64 = 38400 > 32767, caught at the accumulating add
    prims = {f.provenance.primitive for f in ctx.findings if f.kind == "int-overflow"}
    assert "add" in prims or "scan" in prims


def test_int32_accumulator_headroom_is_proven_not_flagged():
    """The same accumulation into int32 is within range: no finding, and
    the proven bound is published as a fact."""

    def ok(frames_votes):
        def body(acc, v):
            votes = jnp.sum(v).astype(jnp.int32)
            return acc + votes, None

        return jax.lax.scan(body, jnp.zeros((), jnp.int32), frames_votes)[0]

    ctx = analyze_program(
        ok,
        (jax.ShapeDtypeStruct((600, 64), jnp.float32),),
        [_contract((600, 64), jnp.float32, 0.0, 1.0, integral=True)],
        entry="ok-int32",
        rules=default_rules(),
        sanctioned_clips=SAT_INT16,
    )
    assert [f for f in ctx.findings if f.kind == "int-overflow"] == []
    lo, hi = ctx.facts["int_bounds"]["int32"]
    assert hi >= 600 * 64  # the closed-form bound actually propagated
    assert hi < np.iinfo(np.int32).max


def test_unknown_ranges_do_not_produce_noise_findings():
    """Unconstrained int inputs carry the dtype-default interval; adding
    two must NOT be reported — overflow findings are proofs only."""

    def f(a, b):
        return a + b

    s = jax.ShapeDtypeStruct((4,), jnp.int32)
    ctx = analyze_program(f, (s, s), None, entry="unknown", rules=default_rules())
    assert ctx.findings == []


# ---------------------------------------------------------------------------
# host-sync / f64 / weak_type
# ---------------------------------------------------------------------------


def test_host_sync_callback_is_caught():
    def fixture(x):
        jax.debug.print("x={x}", x=x)
        return x * 2

    ctx = analyze_program(
        fixture,
        (jax.ShapeDtypeStruct((4,), jnp.float32),),
        None,
        entry="fixture-hostsync",
        rules=default_rules(),
    )
    hs = [f for f in ctx.findings if f.rule == "host-sync"]
    assert len(hs) == 1
    assert hs[0].provenance.primitive == "debug_callback"


def test_f64_promotion_is_caught():
    import jax.experimental

    with jax.experimental.enable_x64():

        def fixture(x):
            return x.astype(jnp.float64) * 2.0

        ctx = analyze_program(
            fixture,
            (jax.ShapeDtypeStruct((4,), jnp.float32),),
            None,
            entry="fixture-f64",
            rules=default_rules(),
        )
    assert "f64-promotion" in {f.kind for f in ctx.findings}


def test_weak_type_output_is_warned():
    def fixture(x):
        return jnp.sum(x), 6.0  # unanchored python scalar output

    ctx = analyze_program(
        fixture,
        (jax.ShapeDtypeStruct((4,), jnp.float32),),
        None,
        entry="fixture-weak",
        rules=default_rules(),
    )
    weak = [f for f in ctx.findings if f.kind == "weak-type-leak"]
    assert weak and all(f.severity == "warning" for f in weak)


# ---------------------------------------------------------------------------
# recompilation audit
# ---------------------------------------------------------------------------


def test_variant_space_bound_holds_for_default_config():
    from repro.serving.emvs_stream import StreamConfig

    cfg = StreamConfig()
    findings, summary = audit_variant_space(cfg, 64)
    assert findings == []
    assert summary["variants"] <= summary["bound"]
    assert summary["s_buckets"] == tuple(cfg.segment_buckets)
    # capacities are the bucketed frame counts, deduped
    assert all(c % 4 == 0 for c in summary["capacities"])


def test_variant_space_shard_rounding_merges_buckets():
    from repro.serving.emvs_stream import StreamConfig
    from repro.serving.sweep_dispatcher import enumerate_variant_space

    cfg = StreamConfig(sweep="sharded")
    space = enumerate_variant_space(cfg, 16, mesh_segments=8)
    # (1, 2, 4) all round up to 8 on an 8-way mesh: one shard-stable bucket
    assert space["s_buckets"] == (8,)
    assert len(space["variants"]) == len(space["capacities"])
    findings, summary = audit_variant_space(cfg, 16, mesh_segments=8)
    assert findings == []
    assert summary["variants"] <= summary["bound"]


def test_unbounded_variant_space_is_a_finding():
    from repro.serving.emvs_stream import StreamConfig

    findings, _ = audit_variant_space(StreamConfig(), None)
    assert [f.kind for f in findings] == ["unbounded-variant-space"]
    assert findings[0].rule == "recompilation"


# ---------------------------------------------------------------------------
# baseline / suppression mechanics
# ---------------------------------------------------------------------------


def _dummy_finding(kind="float-to-int-truncation", line=10):
    return Finding(
        rule="dtype-flow",
        kind=kind,
        entry="sweep[matmul,batched,bilinear,quant]",
        message="m",
        provenance=Provenance(
            primitive="convert_element_type",
            source=f"repro/core/voting.py:{line} (vote_onehot_matmul)",
        ),
    )


def test_fingerprint_is_stable_across_line_churn():
    assert _dummy_finding(line=10).fingerprint == _dummy_finding(line=99).fingerprint


def test_baseline_roundtrip_and_suppression(tmp_path):
    f1 = _dummy_finding()
    f2 = _dummy_finding(kind="int-overflow")
    path = tmp_path / "baseline.json"
    write_baseline(str(path), [f1])
    baseline = load_baseline(str(path))
    new, suppressed = split_by_baseline([f1, f2], baseline)
    assert suppressed == [f1]
    assert new == [f2]


# ---------------------------------------------------------------------------
# the shipped grid: every sweep program lints clean (the CI gate's core)
# ---------------------------------------------------------------------------


def test_quick_grid_lints_clean(tmp_path):
    out = tmp_path / "findings.json"
    rc = lint_cli.main(
        ["--grid", "quick", "--baseline", "analysis_baseline.json", "--json", str(out)]
    )
    assert rc == 0
    data = json.loads(out.read_text())
    assert data["new"] == []
    assert data["report"]["entries"]  # something actually ran


@pytest.mark.slow
def test_full_grid_lints_clean_with_proofs():
    findings, report = lint_cli.run_lint("full")
    assert findings == [], [f.render() for f in findings]
    # every formulation x backend x voting x quantization combo traced
    assert len(report["entries"]) == 3 * 2 * 2 * 2 + 4
    # the int32 accumulator proof at the paper-scale capacity
    proofs = report["int_bound_proofs"]
    assert proofs["int32"]["headroom"] >= 0
    assert proofs["int16"]["headroom"] >= 0
    for summary in report["variant_space"].values():
        assert summary["variants"] <= summary["bound"]


def test_broken_policy_would_be_caught_end_to_end():
    """End-to-end negative control for the gate: linting a quantized sweep
    with the sanctioned clamp set emptied must surface the int16 store as
    a truncation finding — proving the grid test can actually fail."""
    entry = next(
        e
        for e in lint_cli.build_entries("quick")
        if e["name"] == "sweep[matmul,batched,bilinear,quant]"
    )

    class NoSanction:
        @staticmethod
        def sanctioned_clip_bounds():
            return frozenset()

    entry["policy"] = NoSanction()
    findings, _ = lint_cli.lint_entry(entry)
    assert "float-to-int-truncation" in {f.kind for f in findings}


# ---------------------------------------------------------------------------
# S2: boundary-inclusive saturation monitor
# ---------------------------------------------------------------------------


def test_store_saturation_fraction_sees_clipped_volumes():
    from repro.core import dsi as dsi_lib

    info = np.iinfo(np.int16)
    hot = jnp.full((4, 4), 10 * info.max, jnp.int32)
    stored = dsi_lib.storage_roundtrip(hot)
    # the strict pre-store probe is blind after the clip...
    assert float(dsi_lib.saturation_fraction(stored)) == 0.0
    # ...the boundary-inclusive streaming monitor is not
    assert float(dsi_lib.store_saturation_fraction(stored)) == 1.0
    cold = jnp.zeros((4, 4), jnp.int32)
    assert float(dsi_lib.store_saturation_fraction(cold)) == 0.0
