"""Adversarial ingest: stream hygiene + memory-budget admission.

Two contracts from the hardened ingest path:

  * Hygiene: every `simulator.corrupt_stream` fault (shuffled events,
    swapped chunks, duplicate chunk, out-of-bounds pixels, hot-pixel
    storm) through `StreamHygiene` and the full engine must be rejected
    with a typed `StreamHygieneError` naming the offense, shed exactly
    (policy "drop"), or absorbed bitwise (policy "reorder" within its
    slack) — never silently corrupt a depth map.
  * Budget: with `StreamConfig(frame_store_budget_bytes=...)` set,
    `_FrameStore.live_bytes` never exceeds the budget — not even
    transiently — under both admission policies ("stall" back-pressures,
    "reject" raises `MemoryBudgetError` and retries on `poll`), while
    results stay bitwise-equal to offline `run_emvs`; an infeasible
    budget (below the largest segment's working set) is a typed fatal
    error, never a deadlock or a silent eviction of queued frames.
"""
from __future__ import annotations

import warnings

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.dsi import DSIConfig
from repro.core.pipeline import EMVSOptions, run_emvs
from repro.events.aggregation import aggregate
from repro.events.simulator import (
    EVENT_CORRUPTIONS,
    EventStream,
    corrupt_stream,
)
from repro.events.stream_hygiene import (
    DuplicateChunkError,
    HotPixelError,
    HygieneConfig,
    NonMonotoneEventError,
    OutOfBoundsEventError,
    StreamHygiene,
    StreamHygieneError,
    StreamHygieneWarning,
    StreamOverlapError,
    check_chunk_monotone,
)
from repro.serving.emvs_stream import (
    EMVSStreamEngine,
    MemoryBudgetError,
    StreamConfig,
    _FrameStore,
    iter_event_chunks,
)
from test_segment_batching import _assert_results_match

EVENTS_PER_FRAME = 224
W, H = 32, 24  # synthetic sensor for unit-level hygiene tests


@pytest.fixture(scope="module")
def hygiene_scene(cam, small_scene):
    """A short stream (11 full frames + tail), its offline reference at
    nearest voting (bitwise-comparable), and the DSI config."""
    ev = small_scene["events"]
    traj = small_scene["traj"]
    keep = min(int(ev.t.shape[0]), 11 * EVENTS_PER_FRAME + 32)
    ev = EventStream(xy=ev.xy[:keep], t=ev.t[:keep],
                     polarity=ev.polarity[:keep], valid=ev.valid[:keep])
    frames = aggregate(cam, ev, traj, events_per_frame=EVENTS_PER_FRAME)
    dsi_cfg = DSIConfig.for_camera(cam, num_planes=16, z_min=0.6, z_max=4.5)
    opts = EMVSOptions(keyframe_dist_frac=0.03)
    ref = run_emvs(cam, dsi_cfg, frames, opts)
    assert len(ref.segments) >= 2, "scene must close several segments"
    return ev, traj, dsi_cfg, opts, ref


def _chunk(t, xy=None, pol=None, valid=None) -> EventStream:
    t = np.asarray(t, np.float32)
    n = t.shape[0]
    if xy is None:
        xy = np.stack([np.arange(n) % W, np.arange(n) % H], 1)
    xy = np.asarray(xy, np.float32).reshape(n, 2)
    pol = (np.ones(n, np.int8) if pol is None
           else np.asarray(pol, np.int8))
    valid = (np.ones(n, bool) if valid is None
             else np.asarray(valid, bool))
    return EventStream(xy=xy, t=t, polarity=pol, valid=valid)


def _scrub_all(hyg: StreamHygiene, chunks) -> np.ndarray:
    """Scrub + flush; returns the concatenated released timestamps."""
    out = [hyg.scrub(c) for c in chunks]
    out.append(hyg.flush())
    return np.concatenate([np.asarray(o.t) for o in out if o.t.shape[0]]
                          or [np.empty(0, np.float32)])


# --- unit level: each check, each policy ----------------------------------


def test_monotone_check_names_first_offender():
    with pytest.raises(NonMonotoneEventError, match=r"event 3 at"):
        check_chunk_monotone(np.float32([0.0, 1.0, 2.0, 1.5, 3.0]),
                             float("-inf"))
    # in-order chunks pass, including ties
    check_chunk_monotone(np.float32([1.0, 1.0, 2.0]), 1.0)


def test_watermark_regression_is_overlap():
    with pytest.raises(StreamOverlapError, match="watermark"):
        check_chunk_monotone(np.float32([0.5, 0.6]), 1.0)


def test_duplicate_chunk_rejected_atomically():
    hyg = StreamHygiene("raise", width=W, height=H)
    c1 = _chunk([0.1, 0.2])
    hyg.scrub(c1)
    with pytest.raises(DuplicateChunkError, match="byte-identically"):
        hyg.scrub(_chunk([0.1, 0.2]))
    # the rejection touched no state: the next clean chunk still flows
    hyg.scrub(_chunk([0.3, 0.4]))
    assert hyg.watermark == np.float32(0.4)
    assert hyg.stats["events_out"] == 4


def test_out_of_bounds_rejected_naming_event():
    hyg = StreamHygiene("raise", width=W, height=H)
    bad = _chunk([0.1, 0.2, 0.3], xy=[[1, 1], [W + 3, 1], [2, 2]])
    with pytest.raises(OutOfBoundsEventError, match=r"event 1 .*sensor"):
        hyg.scrub(bad)
    # parked/invalid events are exempt: only valid=True coords are checked
    parked = _chunk([0.1, 0.2], xy=[[1, 1], [-1e4, -1e4]],
                    valid=[True, False])
    hyg.scrub(parked)
    assert hyg.stats["events_out"] == 2


def test_drop_sheds_exactly_the_offenders():
    hyg = StreamHygiene("drop", width=W, height=H)
    bad = _chunk([0.0, 1.0, 0.5, 2.0], xy=[[1, 1], [2, 2], [3, 3], [-7, 1]])
    with pytest.warns(StreamHygieneWarning, match="dropped"):
        out = hyg.scrub(bad)
    # event 2 regresses (prefix-max), event 3 is out of bounds
    assert np.asarray(out.t).tolist() == [0.0, 1.0]
    assert hyg.stats["dropped_out_of_order"] == 1
    assert hyg.stats["dropped_out_of_bounds"] == 1
    # a duplicate chunk is shed whole, counted once
    c = _chunk([3.0, 4.0])
    hyg.scrub(c)
    with pytest.warns(StreamHygieneWarning):
        out = hyg.scrub(_chunk([3.0, 4.0]))
    assert out.t.shape[0] == 0
    assert hyg.stats["dropped_duplicate_chunks"] == 1


def test_hot_pixel_guard_raise_and_drop():
    t = np.linspace(0.0, 0.01, 30, dtype=np.float32)  # one 0.05s window
    storm = _chunk(t, xy=np.tile([[5, 5]], (30, 1)))
    cfg = HygieneConfig(policy="raise", hot_pixel_limit=8)
    with pytest.raises(HotPixelError, match="events/pixel"):
        StreamHygiene(cfg, width=W, height=H).scrub(storm)
    # drop: the first 8 in-window events survive, the rest are shed
    hyg = StreamHygiene(HygieneConfig(policy="drop", hot_pixel_limit=8),
                        width=W, height=H)
    with pytest.warns(StreamHygieneWarning, match="hot-pixel"):
        out = hyg.scrub(storm)
    assert out.t.shape[0] == 8
    assert hyg.stats["dropped_hot_pixel"] == 22
    # a quiet pixel in the same window is untouched
    calm = hyg.scrub(_chunk(np.float32([0.02, 0.03]), xy=[[1, 1], [2, 2]]))
    assert calm.t.shape[0] == 2


def test_reorder_restores_sorted_order_bitwise():
    rng = np.random.default_rng(0)
    t = np.sort(rng.uniform(0, 1, 256)).astype(np.float32)
    clean = _chunk(t, xy=np.stack([rng.integers(0, W, 256),
                                   rng.integers(0, H, 256)], 1))
    chunks = list(iter_event_chunks(clean, 64))
    chunks[1], chunks[2] = chunks[2], chunks[1]  # transport swap
    hyg = StreamHygiene(HygieneConfig(policy="reorder", reorder_slack=1.0),
                        width=W, height=H)
    out = [hyg.scrub(c) for c in chunks]
    out.append(hyg.flush())
    got_t = np.concatenate([np.asarray(o.t) for o in out])
    got_xy = np.concatenate([np.asarray(o.xy) for o in out])
    assert np.array_equal(got_t, np.asarray(clean.t))
    assert np.array_equal(got_xy, np.asarray(clean.xy))
    assert hyg.stats["reorder_peak_held"] > 0
    # every released event respects the release watermark
    assert np.all(np.diff(got_t) >= 0)


def test_reorder_slack_exceeded_is_typed():
    hyg = StreamHygiene(HygieneConfig(policy="reorder", reorder_slack=0.01),
                        width=W, height=H)
    # releases t <= 0.99: [0.0, 0.5] are out, the watermark sits at 0.5
    hyg.scrub(_chunk(np.float32([0.0, 0.5, 1.0])))
    with pytest.raises(StreamOverlapError, match="reorder window exceeded"):
        hyg.scrub(_chunk(np.float32([0.2])))  # its slot already released


def test_empty_chunks_are_noops():
    hyg = StreamHygiene("raise", width=W, height=H)
    out = hyg.scrub(_chunk(np.empty(0, np.float32)))
    assert out.t.shape[0] == 0
    assert hyg.flush().t.shape[0] == 0
    assert hyg.stats["events_in"] == 0


# --- fault injection: corrupt_stream is a faithful adversary --------------


def test_corrupt_stream_modes_are_faults(hygiene_scene, cam):
    ev = hygiene_scene[0]
    n = int(ev.t.shape[0])
    for mode in EVENT_CORRUPTIONS:
        chunks = corrupt_stream(ev, mode, EVENTS_PER_FRAME, seed=3,
                                width=cam.width, height=cam.height, burst=16)
        total = sum(int(c.t.shape[0]) for c in chunks)
        cat = np.concatenate([np.asarray(c.t) for c in chunks])
        if mode == "shuffle_events":
            assert total == n and np.any(np.diff(cat) < 0)
        elif mode == "swap_chunks":
            assert total == n and np.any(np.diff(cat) < 0)
            # stable re-sort reconstructs the clean stream exactly
            assert np.array_equal(np.sort(cat, kind="stable"),
                                  np.asarray(ev.t))
        elif mode == "duplicate_chunk":
            assert total == n + EVENTS_PER_FRAME
        elif mode == "out_of_bounds":
            assert total > n
            xy = np.concatenate([np.asarray(c.xy) for c in chunks])
            v = np.concatenate([np.asarray(c.valid) for c in chunks])
            oob = v & ((xy[:, 0] < 0) | (xy[:, 0] > cam.width - 1))
            assert oob.sum() == total - n
        elif mode == "hot_pixel":
            assert total == n + 16


@given(mode=st.sampled_from(EVENT_CORRUPTIONS),
       policy=st.sampled_from(("raise", "drop", "reorder")),
       seed=st.integers(0, 63))
@settings(max_examples=30)
def test_hygiene_never_passes_corruption_silently(mode, policy, seed):
    """Property: any corruption under any policy either raises a typed
    StreamHygieneError or yields a clean (monotone, in-bounds, fully
    accounted) stream — and reorder reconstructs pure misorderings
    bitwise."""
    rng = np.random.default_rng(7)
    t = np.sort(rng.uniform(0, 1, 400)).astype(np.float32)
    clean = _chunk(t, xy=np.stack([rng.integers(0, W, 400),
                                   rng.integers(0, H, 400)], 1))
    chunks = corrupt_stream(clean, mode, 64, seed=seed,
                            width=W, height=H, burst=40)
    hyg = StreamHygiene(
        HygieneConfig(policy=policy, reorder_slack=0.8, hot_pixel_limit=12),
        width=W, height=H)
    try:
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", StreamHygieneWarning)
            got = _scrub_all(hyg, chunks)
    except StreamHygieneError:
        assert policy in ("raise", "reorder")  # drop never raises
        return
    assert np.all(np.diff(got) >= 0), "released events must be sorted"
    s = hyg.stats
    dropped = (s["dropped_out_of_order"] + s["dropped_duplicate_events"]
               + s["dropped_out_of_bounds"] + s["dropped_hot_pixel"])
    assert s["events_in"] == got.shape[0] + dropped, "every event accounted"
    if policy == "reorder" and mode in ("shuffle_events", "swap_chunks"):
        assert np.array_equal(got, t), "reorder must reconstruct bitwise"


# --- engine level: corruption grid x policy x sweep backend ---------------

# expected engine response: an error type (typed rejection), "bitwise"
# (results equal the clean stream's), or "survives" (flush completes,
# offenders shed)
ENGINE_EXPECT = {
    "shuffle_events": {"raise": NonMonotoneEventError, "drop": "survives",
                       "reorder": "bitwise"},
    "swap_chunks": {"raise": StreamOverlapError, "drop": "survives",
                    "reorder": "bitwise"},
    "duplicate_chunk": {"raise": DuplicateChunkError, "drop": "bitwise",
                        "reorder": DuplicateChunkError},
    "out_of_bounds": {"raise": OutOfBoundsEventError, "drop": "bitwise",
                      "reorder": OutOfBoundsEventError},
    "hot_pixel": {"raise": HotPixelError, "drop": "survives",
                  "reorder": HotPixelError},
}


@pytest.mark.parametrize("mode", EVENT_CORRUPTIONS)
@pytest.mark.parametrize("sweep", ("batched", "sharded"))
def test_engine_corrupt_grid(cam, hygiene_scene, mode, sweep):
    ev, traj, dsi_cfg, opts, ref = hygiene_scene
    chunks = corrupt_stream(ev, mode, EVENTS_PER_FRAME, seed=3,
                            width=cam.width, height=cam.height, burst=96)
    spans = [float(np.asarray(c.t).max() - np.asarray(c.t).min())
             for c in chunks if c.t.shape[0]]
    slack = 2.0 * max(spans)
    for policy, want in ENGINE_EXPECT[mode].items():
        hyg = HygieneConfig(policy=policy, reorder_slack=slack,
                            hot_pixel_limit=24)
        engine = EMVSStreamEngine(
            cam, dsi_cfg, traj, opts,
            StreamConfig(events_per_frame=EVENTS_PER_FRAME, sweep=sweep,
                         hygiene=hyg))

        def drive():
            with warnings.catch_warnings():
                warnings.simplefilter("ignore", StreamHygieneWarning)
                for c in chunks:
                    engine.push(c)
                return engine.flush()

        if want == "bitwise":
            _assert_results_match(drive(), ref, exact_dsi=True)
        elif want == "survives":
            res = drive()
            assert len(res.segments) >= 1
            h = engine.stats["hygiene"]
            assert (h["dropped_out_of_order"] + h["dropped_duplicate_chunks"]
                    + h["dropped_out_of_bounds"] + h["dropped_hot_pixel"]) > 0
        else:
            with pytest.raises(want):
                drive()


def test_engine_hygiene_off_is_transparent(cam, hygiene_scene):
    """hygiene="off" must not alter the clean path (bitwise) nor touch
    the corruption — the pre-hardening behavior, kept for benchmarks."""
    ev, traj, dsi_cfg, opts, ref = hygiene_scene
    engine = EMVSStreamEngine(
        cam, dsi_cfg, traj, opts,
        StreamConfig(events_per_frame=EVENTS_PER_FRAME, hygiene="off"))
    for c in iter_event_chunks(ev, EVENTS_PER_FRAME):
        engine.push(c)
    _assert_results_match(engine.flush(), ref, exact_dsi=True)
    h = engine.stats["hygiene"]
    # pure pass-through: every event counted in and out, nothing judged
    assert h["events_in"] == h["events_out"] == int(ev.t.shape[0])
    assert h["dropped_out_of_order"] == h["dropped_out_of_bounds"] == 0


# --- memory budget: admission policies ------------------------------------


def _budget_for(ref, frames0) -> tuple[int, int]:
    """(feasible budget, per-frame bytes): the largest segment's working
    set plus the one frame whose arrival closes it — the documented
    feasibility floor: tight enough that admissions beyond a closed
    segment must wait for it to be dispatched, harvested, and evicted."""
    fb = _FrameStore._frame_bytes(*[np.asarray(a) for a in frames0])
    max_seg = max(hi - lo for (lo, hi) in
                  (s.frame_range for s in ref.segments))
    return (max_seg + 1) * fb, fb


def _frames0(cam, ev, traj):
    f = aggregate(cam, ev, traj, events_per_frame=EVENTS_PER_FRAME)
    return (f.xy[0], f.valid[0], f.t_mid[0], f.poses.R[0], f.poses.t[0])


def test_budget_stall_is_bitwise_and_bounded(cam, hygiene_scene):
    ev, traj, dsi_cfg, opts, ref = hygiene_scene
    budget, _ = _budget_for(ref, _frames0(cam, ev, traj))
    engine = EMVSStreamEngine(
        cam, dsi_cfg, traj, opts,
        StreamConfig(events_per_frame=EVENTS_PER_FRAME,
                     frame_store_budget_bytes=budget, budget_policy="stall"))
    # one burst push: every frame is admitted in a single drain, so the
    # over-budget admissions MUST go through make_room (deterministic
    # stalls, no chance for an interleaved harvest to slim the store)
    engine.push(ev)
    assert engine.stats["frame_store_bytes"] <= budget
    res = engine.flush()
    _assert_results_match(res, ref, exact_dsi=True)
    assert engine.stats["frame_store_peak_bytes"] <= budget
    assert engine.stats["budget_stalls"] >= 1, "budget must have bitten"
    assert engine.stats["backlog_frames"] == 0


def test_budget_reject_raises_then_recovers(cam, hygiene_scene):
    """Policy "reject": over-budget pushes raise MemoryBudgetError with
    the frames retained in the backlog; poll() retries admission and
    flush() drains — the result stays bitwise-equal to offline."""
    ev, traj, dsi_cfg, opts, ref = hygiene_scene
    budget, _ = _budget_for(ref, _frames0(cam, ev, traj))
    engine = EMVSStreamEngine(
        cam, dsi_cfg, traj, opts,
        StreamConfig(events_per_frame=EVENTS_PER_FRAME,
                     frame_store_budget_bytes=budget, budget_policy="reject"))
    rejects = 0
    for c in iter_event_chunks(ev, EVENTS_PER_FRAME):
        for attempt in range(200):
            try:
                if attempt == 0:
                    engine.push(c)
                else:
                    engine.poll()  # documented recovery: retry admission
                break
            except MemoryBudgetError as e:
                rejects += 1
                assert "reject" in str(e) and str(budget) in str(e)
                assert engine.stats["backlog_frames"] >= 1  # nothing lost
        assert engine.stats["frame_store_bytes"] <= budget
    res = engine.flush()
    _assert_results_match(res, ref, exact_dsi=True)
    assert engine.stats["frame_store_peak_bytes"] <= budget
    assert engine.stats["budget_rejects"] == rejects


def test_infeasible_budget_is_fatal_not_deadlock(cam, hygiene_scene):
    """A budget below the largest segment's working set cannot be honored
    without diverging from offline; both policies must say so, typed."""
    ev, traj, dsi_cfg, opts, ref = hygiene_scene
    _, fb = _budget_for(ref, _frames0(cam, ev, traj))
    for policy, match in (("stall", "working set"), ("reject", "reject")):
        engine = EMVSStreamEngine(
            cam, dsi_cfg, traj, opts,
            StreamConfig(events_per_frame=EVENTS_PER_FRAME,
                         frame_store_budget_bytes=3 * fb,
                         budget_policy=policy))
        with pytest.raises(MemoryBudgetError, match=match):
            for c in iter_event_chunks(ev, EVENTS_PER_FRAME):
                engine.push(c)
            engine.flush()


@given(extra_frames=st.integers(0, 6),
       policy=st.sampled_from(("stall", "reject")),
       chunk=st.sampled_from((EVENTS_PER_FRAME, 997, 10_000)))
@settings(max_examples=10)
def test_budget_never_exceeded_property(cam, hygiene_scene, extra_frames,
                                        policy, chunk):
    """Property: for any feasible budget, admission policy, and chunking,
    frame_store_bytes never exceeds the budget at any observation point,
    and the flushed result is bitwise-equal to offline."""
    ev, traj, dsi_cfg, opts, ref = hygiene_scene
    floor, fb = _budget_for(ref, _frames0(cam, ev, traj))
    budget = floor + extra_frames * fb
    engine = EMVSStreamEngine(
        cam, dsi_cfg, traj, opts,
        StreamConfig(events_per_frame=EVENTS_PER_FRAME,
                     frame_store_budget_bytes=budget, budget_policy=policy))
    for c in iter_event_chunks(ev, chunk):
        try:
            engine.push(c)
        except MemoryBudgetError:
            assert policy == "reject"  # frames retained; flush will drain
        assert engine.stats["frame_store_bytes"] <= budget
    res = engine.flush()
    assert engine.stats["frame_store_peak_bytes"] <= budget
    _assert_results_match(res, ref, exact_dsi=True)


def test_stream_config_validates_new_fields():
    with pytest.raises(ValueError, match="hygiene"):
        StreamConfig(hygiene="shrug")
    with pytest.raises(ValueError, match="budget"):
        StreamConfig(frame_store_budget_bytes=0)
    with pytest.raises(ValueError, match="budget_policy"):
        StreamConfig(frame_store_budget_bytes=1 << 20,
                     budget_policy="hope")
