"""Voting formulations must agree: scatter (FPGA semantics) vs one-hot
matmul (TPU semantics) vs the Pallas kernel — for nearest AND bilinear,
including out-of-bounds, NaN/Inf coords, and masked events."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.voting import vote_onehot_matmul, vote_scatter

W, H, NZ, E = 32, 24, 4, 64


def _coords(rng, spread=1.4):
    """Coords spilling beyond bounds on purpose."""
    x = rng.uniform(-0.2 * W, spread * W, (NZ, E)).astype(np.float32)
    y = rng.uniform(-0.2 * H, spread * H, (NZ, E)).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(y)


@pytest.mark.parametrize("mode", ["nearest", "bilinear"])
def test_scatter_equals_matmul(mode):
    rng = np.random.default_rng(0)
    x, y = _coords(rng)
    dsi0 = jnp.zeros((NZ, H, W), jnp.float32)
    a = vote_scatter(dsi0, x, y, w=W, h=H, mode=mode)
    b = vote_onehot_matmul(dsi0, x, y, w=W, h=H, mode=mode)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


@given(seed=st.integers(0, 500))
@settings(max_examples=15)
def test_scatter_equals_matmul_hypothesis(seed):
    rng = np.random.default_rng(seed)
    x, y = _coords(rng, spread=2.0)
    dsi0 = jnp.zeros((NZ, H, W), jnp.float32)
    for mode in ("nearest", "bilinear"):
        a = vote_scatter(dsi0, x, y, w=W, h=H, mode=mode)
        b = vote_onehot_matmul(dsi0, x, y, w=W, h=H, mode=mode)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_nonfinite_coords_never_vote():
    x = jnp.array([[jnp.nan, jnp.inf, -jnp.inf, 5.0]], jnp.float32)
    y = jnp.array([[2.0, 2.0, 2.0, jnp.nan]], jnp.float32)
    dsi0 = jnp.zeros((1, H, W), jnp.float32)
    for mode in ("nearest", "bilinear"):
        for f in (vote_scatter, vote_onehot_matmul):
            out = f(dsi0, x, y, w=W, h=H, mode=mode)
            assert float(jnp.sum(out)) == 0.0, (mode, f.__name__)
            assert bool(jnp.all(jnp.isfinite(out)))


def test_weights_mask_events():
    rng = np.random.default_rng(3)
    x, y = _coords(rng, spread=0.8)
    wts = jnp.asarray((rng.random((NZ, E)) > 0.5).astype(np.float32))
    dsi0 = jnp.zeros((NZ, H, W), jnp.float32)
    a = vote_scatter(dsi0, x, y, w=W, h=H, mode="nearest", weights=wts)
    b = vote_onehot_matmul(dsi0, x, y, w=W, h=H, mode="nearest", weights=wts)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)
    # total votes == number of in-bounds, unmasked events
    xr, yr = jnp.round(x), jnp.round(y)
    inb = (xr >= 0) & (xr <= W - 1) & (yr >= 0) & (yr <= H - 1)
    assert float(jnp.sum(a)) == float(jnp.sum(wts * inb))


def test_bilinear_votes_sum_to_one_per_event():
    """Bilinear contributions of one in-bounds event must total 1."""
    x = jnp.array([[10.3]], jnp.float32)
    y = jnp.array([[7.8]], jnp.float32)
    dsi0 = jnp.zeros((1, H, W), jnp.float32)
    out = vote_onehot_matmul(dsi0, x, y, w=W, h=H, mode="bilinear")
    assert abs(float(jnp.sum(out)) - 1.0) < 1e-5
    # exactly 4 voxels touched
    assert int(jnp.sum(out > 0)) == 4


def test_int16_dsi_accumulation_and_saturation():
    from repro.core import dsi as dsi_lib

    acc = jnp.full((1, 2, 2), 40000, dsi_lib.DSI_ACCUM_DTYPE)
    stored = dsi_lib.to_storage(acc)
    assert stored.dtype == jnp.int16
    assert int(stored[0, 0, 0]) == 32767  # saturating store
    assert float(dsi_lib.saturation_fraction(acc)) == 1.0
    ok = jnp.full((1, 2, 2), 1000, dsi_lib.DSI_ACCUM_DTYPE)
    assert float(dsi_lib.saturation_fraction(ok)) == 0.0


def test_integer_vote_rounding_half_away():
    """Half-integer votes must round half-AWAY-from-zero (the RTL and
    `quant/fixed_point` convention), not half-to-even like `jnp.round`.

    A bilinear event at x = n + 0.5 produces exact 0.5-weight votes; with
    an integer DSI those used to round 0.5 -> 0 and 2.5 -> 2 (half-even),
    diverging from the fixed-point quantizers one vote at a time."""
    from repro.quant.fixed_point import round_half_away

    halves = jnp.array([-2.5, -1.5, -0.5, 0.5, 1.5, 2.5], jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(round_half_away(halves)), [-3.0, -2.0, -1.0, 1.0, 2.0, 3.0]
    )
    # and NOT the half-even results [-2, -2, -0, 0, 2, 2]
    assert not np.array_equal(np.asarray(jnp.round(halves)),
                              np.asarray(round_half_away(halves)))

    # end to end: one event exactly between two columns, integer DSI
    x = jnp.full((NZ, 1), 10.5, jnp.float32)
    y = jnp.full((NZ, 1), 7.0, jnp.float32)
    dsi0 = jnp.zeros((NZ, H, W), jnp.int32)
    out = vote_onehot_matmul(dsi0, x, y, w=W, h=H, mode="bilinear")
    # both 0.5-weight voxels round up to 1 (half-even would drop both to 0)
    assert int(out[0, 7, 10]) == 1
    assert int(out[0, 7, 11]) == 1
