"""Streaming-vs-offline equivalence: the online engine must reproduce
`run_emvs` exactly, for every chunking of the input.

The engine (incremental aggregation -> frame-by-frame K criterion ->
double-buffered padded dispatch) shares the padded batched sweep with the
offline path, so nearest/integer datapaths must match bitwise and
bilinear to float tolerance — the same split `test_segment_batching`
enforces between the batched and looped offline paths. Also covered:
the compiled-variant bound (|segment_buckets| x |capacities|), planner
equivalence on random trajectories, and aggregator chunking invariance.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.dsi import DSIConfig
from repro.core.geometry import SE3
from repro.core.pipeline import (
    EMVSOptions,
    SegmentPlanner,
    bucket_capacity,
    plan_segments,
    process_segments_batched,
    run_emvs,
    segment_keyframes,
)
from repro.events.aggregation import StreamingAggregator, aggregate
from repro.events.simulator import EventStream, Trajectory, slice_trajectory
from repro.events.trajectory_stream import (
    PoseStallError,
    TrajectoryBuffer,
)
from repro.serving.emvs_stream import (
    EMVSStreamEngine,
    StreamConfig,
    iter_event_chunks,
)
from test_segment_batching import GRID, _assert_results_match

EVENTS_PER_FRAME = 224  # does not divide the stream -> exercises the tail


@pytest.fixture(scope="module")
def stream_scene(cam, small_scene):
    """small_scene's stream re-aggregated at a size that keeps the
    12-combo x 3-chunking grid affordable and leaves a partial tail."""
    ev = small_scene["events"]
    traj = small_scene["traj"]
    n = int(ev.t.shape[0])
    keep = min(n, 17 * EVENTS_PER_FRAME + 32)  # 17 full frames + a tail
    ev = EventStream(xy=ev.xy[:keep], t=ev.t[:keep],
                     polarity=ev.polarity[:keep], valid=ev.valid[:keep])
    frames = aggregate(cam, ev, traj, events_per_frame=EVENTS_PER_FRAME)
    assert int(frames.xy.shape[0]) * EVENTS_PER_FRAME > keep, "tail expected"
    dsi_cfg = DSIConfig.for_camera(cam, num_planes=16, z_min=0.6, z_max=4.5)
    return ev, traj, frames, dsi_cfg


def _stream(engine: EMVSStreamEngine, ev: EventStream, chunk: int):
    for c in iter_event_chunks(ev, chunk):
        engine.push(c)
    return engine.flush()


@pytest.mark.parametrize("formulation,voting,quantized", GRID)
def test_stream_matches_offline_all_chunkings(cam, stream_scene, formulation,
                                              voting, quantized):
    ev, traj, frames, dsi_cfg = stream_scene
    opts = EMVSOptions(formulation=formulation, voting=voting,
                       quantized=quantized, keyframe_dist_frac=0.03)
    ref = run_emvs(cam, dsi_cfg, frames, opts)
    assert len(ref.segments) >= 2, "scene must close several segments"
    n = int(ev.t.shape[0])
    for chunk in (EVENTS_PER_FRAME, 997, n):  # one frame, prime, whole
        engine = EMVSStreamEngine(
            cam, dsi_cfg, traj, opts,
            StreamConfig(events_per_frame=EVENTS_PER_FRAME))
        res = _stream(engine, ev, chunk)
        _assert_results_match(res, ref, exact_dsi=(voting == "nearest"))


# --- streamed trajectory: event x pose chunk interleavings ----------------

# Pose-lag profiles: how far the pose stream runs relative to the event
# front. "ahead" = the whole trajectory is known before the first event
# (an oracle delivered in one chunk); "tracking" = pose chunks trail the
# event front by a small lag (the realistic VIO tracker); "behind" = every
# pose arrives after the last event (worst case: everything stalls, then
# one burst of releases).
POSE_PROFILES = ("ahead", "tracking", "behind")


def _stream_gated(engine: EMVSStreamEngine, ev: EventStream,
                  traj: Trajectory, chunk: int, profile: str,
                  lag: float = 0.06):
    """Drive a pose-gated engine with the given event chunking and
    pose-lag profile; returns the flushed result."""
    times = np.asarray(traj.times)
    n_pose = times.shape[0]
    sent = 0

    def send_up_to(hi: int):
        nonlocal sent
        if hi > sent:
            engine.push_poses(slice_trajectory(traj, sent, hi))
            sent = hi

    if profile == "ahead":
        send_up_to(n_pose)
    for c in iter_event_chunks(ev, chunk):
        engine.push(c)
        if profile == "tracking":
            front = float(np.asarray(c.t)[-1]) - lag
            send_up_to(int(np.searchsorted(times, front, side="right")))
    send_up_to(n_pose)  # tracker drains after the sensor stops
    engine.finalize_poses()
    return engine.flush()


@pytest.mark.parametrize("formulation,voting,quantized", GRID)
def test_pose_streamed_matches_offline_grid(cam, stream_scene, formulation,
                                            voting, quantized):
    """Full option grid with the trajectory arriving in chunks behind the
    event front: per-segment results must equal the offline oracle path
    exactly (nearest/integer bitwise, bilinear allclose)."""
    ev, traj, frames, dsi_cfg = stream_scene
    opts = EMVSOptions(formulation=formulation, voting=voting,
                       quantized=quantized, keyframe_dist_frac=0.03)
    ref = run_emvs(cam, dsi_cfg, frames, opts)
    engine = EMVSStreamEngine(
        cam, dsi_cfg, None, opts,
        StreamConfig(events_per_frame=EVENTS_PER_FRAME))
    res = _stream_gated(engine, ev, traj, 997, "tracking")
    _assert_results_match(res, ref, exact_dsi=(voting == "nearest"))
    assert engine.stats["stalled_frames"] == 0
    assert engine.stats["pose_watermark"] == float(np.asarray(traj.times)[-1])


@pytest.mark.parametrize("profile", POSE_PROFILES)
@pytest.mark.parametrize("formulation,voting,quantized",
                         [("matmul", "nearest", True),
                          ("scatter", "bilinear", False)])
def test_pose_event_interleavings(cam, stream_scene, formulation, voting,
                                  quantized, profile):
    """3 event chunkings x 3 pose-lag profiles: any interleaving of event
    and pose chunks reproduces the offline result. Covers poses arriving
    far ahead of, slightly behind, and entirely after the events."""
    ev, traj, frames, dsi_cfg = stream_scene
    opts = EMVSOptions(formulation=formulation, voting=voting,
                       quantized=quantized, keyframe_dist_frac=0.03)
    ref = run_emvs(cam, dsi_cfg, frames, opts)
    n = int(ev.t.shape[0])
    for chunk in (EVENTS_PER_FRAME, 997, n):  # one frame, prime, whole
        engine = EMVSStreamEngine(
            cam, dsi_cfg, None, opts,
            StreamConfig(events_per_frame=EVENTS_PER_FRAME))
        res = _stream_gated(engine, ev, traj, chunk, profile)
        _assert_results_match(res, ref, exact_dsi=(voting == "nearest"))
        if profile == "behind":
            # every full frame stalls (the flushed tail frame arrives
            # after finalize_poses, so it alone never waits)
            assert engine.stats["max_stalled"] >= engine.stats["frames"] - 1, (
                "with every pose arriving after the events, all full "
                "frames must have stalled at some point")


def test_pose_streamed_results_arrive_before_event_end(cam, stream_scene):
    """Online operation survives pose gating: with a tracker lagging the
    event front, segments still complete while events arrive."""
    ev, traj, _, dsi_cfg = stream_scene
    opts = EMVSOptions(keyframe_dist_frac=0.03)
    engine = EMVSStreamEngine(cam, dsi_cfg, None, opts,
                              StreamConfig(events_per_frame=EVENTS_PER_FRAME))
    times = np.asarray(traj.times)
    early, sent = [], 0
    for c in iter_event_chunks(ev, EVENTS_PER_FRAME):
        early.extend(engine.push(c))
        hi = int(np.searchsorted(times, float(np.asarray(c.t)[-1]) - 0.05,
                                 side="right"))
        if hi > sent:
            early.extend(engine.push_poses(slice_trajectory(traj, sent, hi)))
            sent = hi
    engine.push_poses(slice_trajectory(traj, sent, times.shape[0]))
    engine.finalize_poses()
    res = engine.flush()
    assert len(early) >= 1, "no segment completed before end of events"
    assert len(res.segments) > len(early)


def test_flush_with_missing_poses_raises_and_recovers(cam, stream_scene):
    """flush while frames still await poses: explicit PoseStallError
    naming the stalled frame count and the watermark — and the engine
    stays usable (late pose chunks still release the frames)."""
    ev, traj, frames, dsi_cfg = stream_scene
    opts = EMVSOptions(keyframe_dist_frac=0.03)
    ref = run_emvs(cam, dsi_cfg, frames, opts)
    engine = EMVSStreamEngine(cam, dsi_cfg, None, opts,
                              StreamConfig(events_per_frame=EVENTS_PER_FRAME))
    for c in iter_event_chunks(ev, 997):
        engine.push(c)
    n_frames = engine.stats["frames"] + engine.aggregator.stalled_frames
    with pytest.raises(PoseStallError) as ei:
        engine.flush()
    # the error names the stalled count (all frames incl. the flushed
    # tail) and the watermark (-inf: no pose sample ever arrived)
    assert f"{n_frames + 1} frame(s)" in str(ei.value)
    assert "watermark" in str(ei.value)
    # the failed flush already emitted the padded tail frame: more events
    # would silently shift every later frame boundary, so push is rejected
    with pytest.raises(RuntimeError, match="tail was already emitted"):
        engine.push(next(iter_event_chunks(ev, 64)))
    engine.push_poses(traj)
    engine.finalize_poses()
    res = engine.flush()
    _assert_results_match(res, ref, exact_dsi=True)


def test_one_pose_chunk_closes_multiple_stalled_segments(cam, stream_scene):
    """A single pose chunk advancing the watermark far enough must
    release a burst of stalled frames, close several segments at once,
    and leave the frame store consistent (eviction can run through the
    released backlog without window underflow)."""
    ev, traj, frames, dsi_cfg = stream_scene
    opts = EMVSOptions(keyframe_dist_frac=0.03)
    ref = run_emvs(cam, dsi_cfg, frames, opts)
    assert len(ref.segments) >= 2
    engine = EMVSStreamEngine(cam, dsi_cfg, None, opts,
                              StreamConfig(events_per_frame=EVENTS_PER_FRAME))
    for c in iter_event_chunks(ev, EVENTS_PER_FRAME):
        engine.push(c)
    assert engine.stats["dispatches"] == 0, "nothing can dispatch unposed"
    engine.push_poses(traj)  # one chunk covers every stalled frame
    assert engine.stats["segments"] >= 2, (
        "the pose burst must close multiple segments in one push_poses")
    # eviction ran through the released backlog: the retained window
    # starts exactly at the open segment, and never underflowed
    assert engine._store.base == engine.planner.open_start
    assert engine._store.base <= engine._store.end
    engine.finalize_poses()
    res = engine.flush()
    _assert_results_match(res, ref, exact_dsi=True)


def test_pose_stream_calls_require_gated_engine(cam, stream_scene):
    ev, traj, _, dsi_cfg = stream_scene
    engine = EMVSStreamEngine(cam, dsi_cfg, traj)  # oracle mode
    with pytest.raises(RuntimeError, match="pose-gated"):
        engine.push_poses(traj)
    with pytest.raises(RuntimeError, match="pose-gated"):
        engine.finalize_poses()
    # a pre-filled TrajectoryBuffer is a valid streamed source
    buf = TrajectoryBuffer(traj)
    gated = EMVSStreamEngine(cam, dsi_cfg, buf)
    assert gated.pose_gated
    assert gated.stats["pose_watermark"] == float(np.asarray(traj.times)[-1])


def test_stream_results_arrive_before_flush(cam, stream_scene):
    """Online operation: segments finish while events still arrive."""
    ev, traj, _, dsi_cfg = stream_scene
    opts = EMVSOptions(keyframe_dist_frac=0.03)
    engine = EMVSStreamEngine(cam, dsi_cfg, traj, opts,
                              StreamConfig(events_per_frame=EVENTS_PER_FRAME))
    early = []
    for c in iter_event_chunks(ev, EVENTS_PER_FRAME):
        early.extend(engine.push(c))
    res = engine.flush()
    assert len(early) >= 1, "no segment completed before end of stream"
    assert len(res.segments) > len(early), "flush must add the tail segments"
    ranges = [s.frame_range for s in res.segments]
    assert ranges == sorted(ranges)
    assert engine.stats["frames"] == engine.planner.num_frames


def test_stream_compile_cache_bounded(cam, stream_scene):
    """Streaming any chunking compiles at most |S buckets| x |capacities|
    variants of process_segments_batched — the jit cache cannot grow with
    the stream."""
    ev, traj, frames, dsi_cfg = stream_scene
    opts = EMVSOptions(keyframe_dist_frac=0.02)  # more, varied segments
    caps = {bucket_capacity(b - a)
            for a, b in plan_segments(frames, dsi_cfg, opts)}
    scfg = StreamConfig(events_per_frame=EVENTS_PER_FRAME,
                        segment_buckets=(1, 2, 4))
    jax.clear_caches()
    for chunk in (EVENTS_PER_FRAME, 997, int(ev.t.shape[0])):
        engine = EMVSStreamEngine(cam, dsi_cfg, traj, opts, scfg)
        _stream(engine, ev, chunk)
    bound = len(scfg.segment_buckets) * len(caps)
    assert process_segments_batched._cache_size() <= bound, (
        process_segments_batched._cache_size(), bound)


def test_flush_without_events(cam):
    dsi_cfg = DSIConfig.for_camera(cam, num_planes=8, z_min=0.6, z_max=4.5)
    traj = Trajectory(times=jnp.asarray([0.0, 1.0]),
                      poses=SE3(jnp.broadcast_to(jnp.eye(3), (2, 3, 3)),
                                jnp.zeros((2, 3))))
    engine = EMVSStreamEngine(cam, dsi_cfg, traj)
    res = engine.flush()
    assert res.segments == [] and res.clouds == []
    with pytest.raises(RuntimeError, match="push after flush"):
        engine.push(EventStream(xy=jnp.zeros((1, 2)), t=jnp.zeros((1,)),
                                polarity=jnp.zeros((1,), jnp.int8),
                                valid=jnp.ones((1,), bool)))


# --- poll / dispatch / frame-store semantics ------------------------------


def _engine(cam, n_planes=8):
    dsi_cfg = DSIConfig.for_camera(cam, num_planes=n_planes, z_min=0.6,
                                   z_max=4.5)
    traj = Trajectory(times=jnp.asarray([0.0, 1.0]),
                      poses=SE3(jnp.broadcast_to(jnp.eye(3), (2, 3, 3)),
                                jnp.zeros((2, 3))))
    return EMVSStreamEngine(cam, dsi_cfg, traj)


class _StubArray:
    """Array stand-in with controllable device-completion state."""

    def __init__(self, a, ready):
        self._a = np.asarray(a)
        self.ready = ready

    def is_ready(self):
        return self.ready

    def block_until_ready(self):
        self.ready = True
        return self

    def __getitem__(self, k):
        return self._a[k]


def _stub_inflight(seg, ready):
    from repro.core.detection import DepthMap
    from repro.core.pointcloud import PointCloud
    from repro.serving.emvs_stream import _InFlight

    h, w = 4, 6
    arr = lambda *s: _StubArray(np.zeros((1,) + s, np.float32), ready)
    return _InFlight(
        segs=[seg], ref_R=arr(3, 3), ref_t=arr(3), dsis=arr(2, h, w),
        dms=DepthMap(depth=arr(h, w), mask=arr(h, w), confidence=arr(h, w)),
        pcs=PointCloud(points=arr(h * w, 3), weights=arr(h * w),
                       valid=arr(h * w)))


def test_poll_is_nonblocking_and_head_of_line(cam):
    """poll returns only sweeps the device has completed, in dispatch
    order: a finished sweep behind an unfinished one is NOT surfaced
    (head-of-line), and poll never blocks on the unfinished head."""
    engine = _engine(cam)
    head = _stub_inflight((0, 2), ready=False)
    tail = _stub_inflight((2, 4), ready=True)
    engine._inflight.extend([head, tail])
    assert engine.poll() == []  # head not device-complete -> nothing
    assert not head.dms.depth.ready, "poll must not block on the head"
    head.dms.depth.ready = True
    out = engine.poll()
    assert [r.frame_range for r in out] == [(0, 2), (2, 4)]
    assert not engine._inflight
    assert engine.poll() == []  # nothing new


def test_dispatch_rejects_empty_segment_group(cam):
    """_dispatch can never see an empty group: _dispatch_all only forms
    groups from non-empty closed-segment runs, and the guard (plus
    pad_segments' ValueError underneath) makes the invariant explicit."""
    engine = _engine(cam)
    with pytest.raises(AssertionError, match="at least one closed segment"):
        engine._dispatch([], 4)
    engine._dispatch_all([])  # no closed segments -> no dispatch, no error
    assert engine.stats["dispatches"] == 0


def test_frame_store_boundaries(cam):
    from repro.serving.emvs_stream import _FrameStore
    from test_segment_batching import _synthetic_frames

    store = _FrameStore()
    store.extend(_synthetic_frames([0.0, 0.1, 0.2, 0.3, 0.4], events=8))
    assert (store.base, store.end) == (0, 5)
    win = store.window(1, 4)
    assert win.xy.shape[0] == 3
    store.evict_before(2)
    assert (store.base, store.end) == (2, 5)
    with pytest.raises(IndexError, match="outside retained"):
        store.window(1, 4)  # lo evicted
    with pytest.raises(IndexError, match="outside retained"):
        store.window(3, 6)  # hi beyond newest
    with pytest.raises(IndexError):
        store.window(3, 3)  # empty ranges are never valid
    np.testing.assert_array_equal(np.asarray(store.window(2, 5).t_mid),
                                  [2.0, 3.0, 4.0])


# --- property tests -------------------------------------------------------


def _reference_segments(t: np.ndarray, thresh: float) -> list[tuple[int, int]]:
    """The seed's offline K-criterion loop, kept inline as an independent
    reference so planner and segment_keyframes are checked against the
    original algorithm, not against each other."""
    if t.shape[0] == 0:
        return []
    bounds, start, ref = [], 0, t[0]
    for i in range(1, t.shape[0]):
        if np.linalg.norm(t[i] - ref) > thresh:
            bounds.append((start, i))
            start, ref = i, t[i]
    bounds.append((start, t.shape[0]))
    return bounds


@settings(deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(0, 48),
       thresh=st.sampled_from([0.02, 0.05, 0.1, 0.25]))
def test_incremental_segmentation_matches_offline(seed, n, thresh):
    rng = np.random.default_rng(seed)
    t = np.cumsum(rng.uniform(-0.08, 0.08, (n, 3)).astype(np.float32), axis=0)

    ref = _reference_segments(t, thresh)

    planner = SegmentPlanner(thresh, min_frames=1)
    got: list[tuple[int, int]] = []
    for i in range(n):
        closed = planner.push(t[i])
        if closed is not None:
            got.append(closed)
    tail = planner.flush()
    if tail is not None:
        got.append(tail)
    assert got == ref

    poses = SE3(np.broadcast_to(np.eye(3, dtype=np.float32), (n, 3, 3)), t)
    assert segment_keyframes(poses, mean_depth=1.0, frac=thresh) == ref


@settings(deadline=None, max_examples=20)
@given(seed=st.integers(0, 10_000), e=st.sampled_from([16, 64, 100]),
       n_cuts=st.integers(0, 6))
def test_aggregator_chunking_invariance(cam, seed, e, n_cuts):
    """Any chunk split of a stream aggregates to bitwise-identical frames."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(0, 600))
    ev = EventStream(
        xy=jnp.asarray(rng.uniform(0, 200, (n, 2)).astype(np.float32)),
        t=jnp.asarray(np.sort(rng.uniform(0, 1, n).astype(np.float32))),
        polarity=jnp.asarray(rng.choice([-1, 1], n).astype(np.int8)),
        valid=jnp.asarray(rng.random(n) > 0.1),
    )
    traj = Trajectory(
        times=jnp.asarray([0.0, 0.5, 1.0]),
        poses=SE3(jnp.broadcast_to(jnp.eye(3), (3, 3, 3)),
                  jnp.asarray(np.linspace(0, 0.3, 9, dtype=np.float32)
                              .reshape(3, 3))),
    )
    ref = aggregate(cam, ev, traj, events_per_frame=e)

    cuts = sorted(rng.integers(0, n + 1, size=n_cuts).tolist())
    agg = StreamingAggregator(cam, traj, events_per_frame=e)
    parts = []
    for lo, hi in zip([0] + cuts, cuts + [n]):
        chunk = EventStream(xy=ev.xy[lo:hi], t=ev.t[lo:hi],
                            polarity=ev.polarity[lo:hi], valid=ev.valid[lo:hi])
        parts.append(agg.push(chunk))
    parts.append(agg.flush())

    got_xy = np.concatenate([np.asarray(p.xy) for p in parts])
    got_valid = np.concatenate([np.asarray(p.valid) for p in parts])
    got_tmid = np.concatenate([np.asarray(p.t_mid) for p in parts])
    got_t = np.concatenate([np.asarray(p.poses.t) for p in parts])
    np.testing.assert_array_equal(got_xy, np.asarray(ref.xy))
    np.testing.assert_array_equal(got_valid, np.asarray(ref.valid))
    np.testing.assert_array_equal(got_tmid, np.asarray(ref.t_mid))
    np.testing.assert_array_equal(got_t, np.asarray(ref.poses.t))


def test_dsi_saturation_peak_is_monitored_and_zero_on_healthy_stream(
        cam, stream_scene):
    """The per-session saturation monitor (paper's "16 bits never
    saturate" claim, live edition): present from session start, updated
    by the dispatcher on every harvest, and exactly 0.0 on a scene whose
    vote counts sit far below the int16 store limits."""
    ev, traj, frames, dsi_cfg = stream_scene
    opts = EMVSOptions(quantized=True, keyframe_dist_frac=0.03)
    engine = EMVSStreamEngine(
        cam, dsi_cfg, traj, opts,
        StreamConfig(events_per_frame=EVENTS_PER_FRAME))
    assert engine.stats["dsi_saturation_peak"] == 0.0  # present pre-dispatch
    _stream(engine, ev, EVENTS_PER_FRAME)
    assert engine.stats["dispatches"] > 0  # the harvest path actually ran
    peak = engine.stats["dsi_saturation_peak"]
    assert isinstance(peak, float) and peak == 0.0
