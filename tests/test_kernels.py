"""Per-kernel shape/dtype sweeps: Pallas (interpret=True on CPU) vs the
pure-jnp ref.py oracle, per the assignment contract."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.backproject_vote.kernel import backproject_vote_pallas
from repro.kernels.backproject_vote.ops import backproject_vote
from repro.kernels.backproject_vote.ref import backproject_vote_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.local_max.kernel import depth_argmax_pallas
from repro.kernels.local_max.ref import depth_argmax_ref

CX, CY, W, H = 16.0, 12.0, 40, 24


def _bpv_inputs(rng, F, E, NZ):
    xy0 = jnp.asarray(
        rng.uniform((-5, -5), (W + 5, H + 5), (F, E, 2)).astype(np.float32))
    valid = jnp.asarray((rng.random((F, E)) > 0.2).astype(np.float32))
    alpha = rng.uniform(0.7, 1.3, (F, NZ)).astype(np.float32)
    beta = rng.uniform(-4, 4, (F, NZ, 2)).astype(np.float32)
    phi = jnp.asarray(np.concatenate([alpha[..., None], beta], axis=-1))
    return xy0, valid, phi


def _run_fused(xy0, valid, phi, *, mode, BZ=8, FS=1, quantized=False,
               onehot_dtype=jnp.float32, interpret=True):
    """Run the fused kernel; returns cropped (dsi f32, conf, zf) + pads."""
    dsi_pad, conf_pad, zf_pad = backproject_vote_pallas(
        xy0[..., 0], xy0[..., 1], valid, phi, cx=CX, cy=CY, w=W, h=H,
        block_z=BZ, frames_per_step=FS, mode=mode, quantized=quantized,
        onehot_dtype=onehot_dtype, interpret=interpret)
    return (dsi_pad[:, :H, :W].astype(jnp.float32), conf_pad[:H, :W],
            zf_pad[:H, :W], dsi_pad)


@pytest.mark.parametrize("mode", ["nearest", "bilinear"])
@pytest.mark.parametrize("F,E,NZ,BZ,FS", [
    (2, 64, 8, 4, 1),
    (4, 128, 16, 8, 2),
    (1, 256, 8, 8, 1),
])
def test_backproject_vote_kernel_vs_ref(mode, F, E, NZ, BZ, FS):
    rng = np.random.default_rng(F * 100 + E + NZ)
    xy0, valid, phi = _bpv_inputs(rng, F, E, NZ)
    ref = backproject_vote_ref(xy0, valid, phi, cx=CX, cy=CY, w=W, h=H,
                               mode=mode)
    got, conf, zf, dsi_pad = _run_fused(xy0, valid, phi, mode=mode, BZ=BZ,
                                        FS=FS)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-3, rtol=1e-5)
    # padding region must never receive votes (miss-judgement correctness)
    assert float(jnp.sum(dsi_pad[:, H:, :].astype(jnp.float32))) == 0.0
    assert float(jnp.sum(dsi_pad[:, :, W:].astype(jnp.float32))) == 0.0
    # fused detection outputs must match the local_max oracle on the
    # kernel's own stored DSI (streaming argmax crossing z-block bounds)
    conf_r, zf_r = depth_argmax_ref(got)
    np.testing.assert_array_equal(np.asarray(conf), np.asarray(conf_r))
    np.testing.assert_array_equal(np.asarray(zf), np.asarray(zf_r))


@pytest.mark.parametrize("mode", ["nearest", "bilinear"])
def test_backproject_vote_kernel_vs_ref_quantized(mode):
    """Quantized fused path vs the oracle with the SAME Table-1 plane-coord
    contract (the headline divergence bug: the kernel used to skip the
    int8 plane-coord quantization entirely)."""
    from repro.core.dsi import storage_roundtrip

    rng = np.random.default_rng(42)
    F, E, NZ, BZ = 4, 128, 16, 8
    xy0, valid, phi = _bpv_inputs(rng, F, E, NZ)
    ref = backproject_vote_ref(
        xy0, valid, phi, cx=CX, cy=CY, w=W, h=H, mode=mode,
        quantize_plane_coords=(mode == "nearest"))
    ref_stored = storage_roundtrip(ref)  # truncating int16 store semantics
    got, conf, zf, _ = _run_fused(xy0, valid, phi, mode=mode, BZ=BZ,
                                  quantized=True)
    assert got.dtype == jnp.float32  # helper widens the int16 output
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref_stored, np.float32))
    conf_r, zf_r = depth_argmax_ref(got)
    np.testing.assert_array_equal(np.asarray(conf), np.asarray(conf_r))
    np.testing.assert_array_equal(np.asarray(zf), np.asarray(zf_r))


@pytest.mark.parametrize("quantized", [False, True])
def test_backproject_vote_all_frames_invalid(quantized):
    """Every frame fully padded (valid=0): the DSI must be exactly zero
    and the fused detection must still agree with the oracle on the
    all-zero volume (degenerate argmax + parabola at plane 0)."""
    rng = np.random.default_rng(7)
    F, E, NZ = 3, 64, 8
    xy0, _, phi = _bpv_inputs(rng, F, E, NZ)
    valid = jnp.zeros((F, E), jnp.float32)
    got, conf, zf, dsi_pad = _run_fused(xy0, valid, phi, mode="nearest",
                                        quantized=quantized)
    assert float(jnp.sum(jnp.abs(dsi_pad.astype(jnp.float32)))) == 0.0
    conf_r, zf_r = depth_argmax_ref(got)
    np.testing.assert_array_equal(np.asarray(conf), np.asarray(conf_r))
    np.testing.assert_array_equal(np.asarray(zf), np.asarray(zf_r))


@pytest.mark.parametrize("quantized", [False, True])
def test_backproject_vote_boundary_events(quantized):
    """Boundary-event grid: events exactly ON w-1/h-1, exact half-integer
    coordinates (half-away vs half-up rounding seam), one fully-padded
    frame, and frames_per_step > 1 — all against the oracle."""
    F, E, NZ, BZ, FS = 4, 16, 8, 4, 2
    # alpha=1, beta=0: plane coords = canonical coords for every plane
    phi = jnp.concatenate([jnp.ones((F, NZ, 1)), jnp.zeros((F, NZ, 2))], -1)
    specials = np.array([
        [W - 1.0, H - 1.0],   # exactly the last valid pixel
        [W - 1.0, 0.0],
        [0.0, H - 1.0],
        [W - 0.5, H - 0.5],   # rounds to (W, H): out of bounds, dropped
        [W - 1.5, H - 1.5],   # half-integer: rounds UP to (W-1, H-1)
        [0.5, 0.5],           # half-integer at the origin -> (1, 1)
        [-0.5, -0.5],         # exact -0.5: rounds to 0 in BOTH datapaths
        [-0.51, 7.0],         # just outside: dropped (park-at-max if quant)
        [0.49, 0.51],
        [W + 100.0, 3.0],     # far out: dropped
        [3.0, H + 100.0],
        [7.25, 7.75],
        [W - 1.25, H - 1.75],
        [13.5, 2.5],          # more half-integers across the tile
        [2.5, 13.5],
        [0.0, 0.0],
    ], dtype=np.float32)
    xy0 = jnp.asarray(np.tile(specials[None], (F, 1, 1)))
    valid = jnp.ones((F, E), jnp.float32)
    # frame 3 fully padded (valid = 0 everywhere): must contribute nothing
    valid = valid.at[3].set(0.0)
    for mode in ("nearest", "bilinear"):
        ref = backproject_vote_ref(
            xy0, valid, phi, cx=CX, cy=CY, w=W, h=H, mode=mode,
            quantize_plane_coords=(quantized and mode == "nearest"))
        if quantized:
            from repro.core.dsi import storage_roundtrip

            ref = storage_roundtrip(ref)
        got, conf, zf, dsi_pad = _run_fused(
            xy0, valid, phi, mode=mode, BZ=BZ, FS=FS, quantized=quantized)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(ref, np.float32))
        assert float(jnp.sum(dsi_pad[:, H:, :].astype(jnp.float32))) == 0.0
        assert float(jnp.sum(dsi_pad[:, :, W:].astype(jnp.float32))) == 0.0


def test_backproject_vote_interpret_vs_compiled_parity():
    """Bitwise interpret-vs-compiled parity — only meaningful where a
    Pallas compile path exists (TPU/GPU); skipped on CPU CI."""
    from repro.kernels.platform import compiled_kernels_supported

    if not compiled_kernels_supported():
        pytest.skip("no Pallas compile path on this platform")
    rng = np.random.default_rng(3)
    xy0, valid, phi = _bpv_inputs(rng, 2, 128, 8)
    for quantized in (False, True):
        a = _run_fused(xy0, valid, phi, mode="nearest", quantized=quantized,
                       interpret=True)
        b = _run_fused(xy0, valid, phi, mode="nearest", quantized=quantized,
                       interpret=False)
        for x, y in zip(a[:3], b[:3]):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_resolve_interpret_contract():
    """The single decision point: None probes the platform, False raises
    (never silently falls back) where compiled Pallas is unavailable."""
    from repro.kernels.platform import compiled_kernels_supported, resolve_interpret

    assert resolve_interpret(True) is True
    if compiled_kernels_supported():
        assert resolve_interpret(None) is False
        assert resolve_interpret(False) is False
    else:
        assert resolve_interpret(None) is True
        with pytest.raises(ValueError, match="no Pallas compile path"):
            resolve_interpret(False)
        with pytest.raises(ValueError):
            backproject_vote_pallas(
                jnp.zeros((1, 8)), jnp.zeros((1, 8)), jnp.ones((1, 8)),
                jnp.ones((1, 8, 3)), cx=CX, cy=CY, w=W, h=H, block_z=8,
                interpret=False)


def test_backproject_vote_wrapper_matches_pipeline_votes(cam):
    """ops.backproject_vote == voting.vote_onehot_matmul over a scan."""
    from repro.core.voting import vote_onehot_matmul

    rng = np.random.default_rng(7)
    F, E, NZ = 3, 128, 8
    xy0, valid, phi = _bpv_inputs(rng, F, E, NZ)
    got = backproject_vote(xy0, valid, phi, cx=CX, cy=CY, w=W, h=H,
                           mode="nearest", interpret=True)
    dsi = jnp.zeros((NZ, H, W), jnp.float32)
    for f in range(F):
        x_i = phi[f, :, 0:1] * (xy0[f, :, 0][None] - CX) + phi[f, :, 1:2] + CX
        y_i = phi[f, :, 0:1] * (xy0[f, :, 1][None] - CY) + phi[f, :, 2:3] + CY
        wts = jnp.broadcast_to(valid[f][None], x_i.shape)
        dsi = vote_onehot_matmul(dsi, x_i, y_i, w=W, h=H, mode="nearest",
                                 weights=wts)
    np.testing.assert_allclose(np.asarray(got), np.asarray(dsi), atol=1e-3)


@pytest.mark.parametrize("NZ,h,w,th,tw", [
    (8, 24, 40, 8, 128),
    (16, 16, 128, 8, 128),
    (32, 9, 33, 8, 128),  # ragged -> padding path
])
def test_depth_argmax_kernel_vs_ref(NZ, h, w, th, tw):
    rng = np.random.default_rng(NZ + h)
    dsi = jnp.asarray(rng.integers(0, 50, (NZ, h, w)).astype(np.float32))
    conf_r, zf_r = depth_argmax_ref(dsi)
    conf_k, zf_k = depth_argmax_pallas(dsi, tile_h=th, tile_w=tw,
                                       interpret=True)
    np.testing.assert_allclose(np.asarray(conf_k), np.asarray(conf_r), atol=1e-5)
    np.testing.assert_allclose(np.asarray(zf_k), np.asarray(zf_r), atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Hq,Hkv,Sq,Skv,D,BQ,BK", [
    (1, 2, 2, 128, 128, 32, 64, 64),  # MHA square
    (2, 4, 2, 128, 128, 16, 128, 32),  # GQA 2:1
    (1, 8, 2, 64, 256, 32, 64, 128),  # decode-ish: Sq < Skv, GQA 4:1
])
def test_flash_attention_kernel_vs_ref(dtype, B, Hq, Hkv, Sq, Skv, D, BQ, BK):
    rng = np.random.default_rng(B + Hq + Sq)
    q = jnp.asarray(rng.normal(size=(B, Hq, Sq, D)).astype(np.float32)).astype(dtype)
    k = jnp.asarray(rng.normal(size=(B, Hkv, Skv, D)).astype(np.float32)).astype(dtype)
    v = jnp.asarray(rng.normal(size=(B, Hkv, Skv, D)).astype(np.float32)).astype(dtype)
    ref = attention_ref(q, k, v, causal=True)
    got = flash_attention(q, k, v, causal=True, block_q=BQ, block_k=BK,
                          interpret=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


def test_flash_attention_non_causal():
    rng = np.random.default_rng(11)
    q = jnp.asarray(rng.normal(size=(1, 2, 64, 16)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 2, 64, 16)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 2, 64, 16)).astype(np.float32))
    ref = attention_ref(q, k, v, causal=False)
    got = flash_attention(q, k, v, causal=False, block_q=32, block_k=32,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)


def test_blockwise_attention_matches_full():
    """models.attention blockwise (training long-seq path) vs einsum core."""
    from repro.models.attention import attention_blockwise, attention_full

    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.normal(size=(2, 128, 4, 16)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(2, 128, 2, 16)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(2, 128, 2, 16)).astype(np.float32))
    a = attention_full(q, k, v, causal=True)
    b = attention_blockwise(q, k, v, causal=True, chunk=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5)
