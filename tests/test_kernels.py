"""Per-kernel shape/dtype sweeps: Pallas (interpret=True on CPU) vs the
pure-jnp ref.py oracle, per the assignment contract."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.backproject_vote.kernel import backproject_vote_pallas
from repro.kernels.backproject_vote.ops import backproject_vote
from repro.kernels.backproject_vote.ref import backproject_vote_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.local_max.kernel import depth_argmax_pallas
from repro.kernels.local_max.ref import depth_argmax_ref

CX, CY, W, H = 16.0, 12.0, 40, 24


def _bpv_inputs(rng, F, E, NZ):
    xy0 = jnp.asarray(
        rng.uniform((-5, -5), (W + 5, H + 5), (F, E, 2)).astype(np.float32))
    valid = jnp.asarray((rng.random((F, E)) > 0.2).astype(np.float32))
    alpha = rng.uniform(0.7, 1.3, (F, NZ)).astype(np.float32)
    beta = rng.uniform(-4, 4, (F, NZ, 2)).astype(np.float32)
    phi = jnp.asarray(np.concatenate([alpha[..., None], beta], axis=-1))
    return xy0, valid, phi


@pytest.mark.parametrize("mode", ["nearest", "bilinear"])
@pytest.mark.parametrize("F,E,NZ,BZ,FS", [
    (2, 64, 8, 4, 1),
    (4, 128, 16, 8, 2),
    (1, 256, 8, 8, 1),
])
def test_backproject_vote_kernel_vs_ref(mode, F, E, NZ, BZ, FS):
    rng = np.random.default_rng(F * 100 + E + NZ)
    xy0, valid, phi = _bpv_inputs(rng, F, E, NZ)
    ref = backproject_vote_ref(xy0, valid, phi, cx=CX, cy=CY, w=W, h=H,
                               mode=mode)
    dsi_pad = backproject_vote_pallas(
        xy0[..., 0], xy0[..., 1], valid, phi, cx=CX, cy=CY, w=W, h=H,
        block_z=BZ, frames_per_step=FS, mode=mode,
        onehot_dtype=jnp.float32, interpret=True)
    got = dsi_pad[:, :H, :W]
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-3, rtol=1e-5)
    # padding region must never receive votes (miss-judgement correctness)
    assert float(jnp.sum(dsi_pad[:, H:, :])) == 0.0
    assert float(jnp.sum(dsi_pad[:, :, W:])) == 0.0


def test_backproject_vote_wrapper_matches_pipeline_votes(cam):
    """ops.backproject_vote == voting.vote_onehot_matmul over a scan."""
    from repro.core.voting import vote_onehot_matmul

    rng = np.random.default_rng(7)
    F, E, NZ = 3, 128, 8
    xy0, valid, phi = _bpv_inputs(rng, F, E, NZ)
    got = backproject_vote(xy0, valid, phi, cx=CX, cy=CY, w=W, h=H,
                           mode="nearest", interpret=True)
    dsi = jnp.zeros((NZ, H, W), jnp.float32)
    for f in range(F):
        x_i = phi[f, :, 0:1] * (xy0[f, :, 0][None] - CX) + phi[f, :, 1:2] + CX
        y_i = phi[f, :, 0:1] * (xy0[f, :, 1][None] - CY) + phi[f, :, 2:3] + CY
        wts = jnp.broadcast_to(valid[f][None], x_i.shape)
        dsi = vote_onehot_matmul(dsi, x_i, y_i, w=W, h=H, mode="nearest",
                                 weights=wts)
    np.testing.assert_allclose(np.asarray(got), np.asarray(dsi), atol=1e-3)


@pytest.mark.parametrize("NZ,h,w,th,tw", [
    (8, 24, 40, 8, 128),
    (16, 16, 128, 8, 128),
    (32, 9, 33, 8, 128),  # ragged -> padding path
])
def test_depth_argmax_kernel_vs_ref(NZ, h, w, th, tw):
    rng = np.random.default_rng(NZ + h)
    dsi = jnp.asarray(rng.integers(0, 50, (NZ, h, w)).astype(np.float32))
    conf_r, zf_r = depth_argmax_ref(dsi)
    conf_k, zf_k = depth_argmax_pallas(dsi, tile_h=th, tile_w=tw,
                                       interpret=True)
    np.testing.assert_allclose(np.asarray(conf_k), np.asarray(conf_r), atol=1e-5)
    np.testing.assert_allclose(np.asarray(zf_k), np.asarray(zf_r), atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Hq,Hkv,Sq,Skv,D,BQ,BK", [
    (1, 2, 2, 128, 128, 32, 64, 64),  # MHA square
    (2, 4, 2, 128, 128, 16, 128, 32),  # GQA 2:1
    (1, 8, 2, 64, 256, 32, 64, 128),  # decode-ish: Sq < Skv, GQA 4:1
])
def test_flash_attention_kernel_vs_ref(dtype, B, Hq, Hkv, Sq, Skv, D, BQ, BK):
    rng = np.random.default_rng(B + Hq + Sq)
    q = jnp.asarray(rng.normal(size=(B, Hq, Sq, D)).astype(np.float32)).astype(dtype)
    k = jnp.asarray(rng.normal(size=(B, Hkv, Skv, D)).astype(np.float32)).astype(dtype)
    v = jnp.asarray(rng.normal(size=(B, Hkv, Skv, D)).astype(np.float32)).astype(dtype)
    ref = attention_ref(q, k, v, causal=True)
    got = flash_attention(q, k, v, causal=True, block_q=BQ, block_k=BK,
                          interpret=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


def test_flash_attention_non_causal():
    rng = np.random.default_rng(11)
    q = jnp.asarray(rng.normal(size=(1, 2, 64, 16)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 2, 64, 16)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 2, 64, 16)).astype(np.float32))
    ref = attention_ref(q, k, v, causal=False)
    got = flash_attention(q, k, v, causal=False, block_q=32, block_k=32,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)


def test_blockwise_attention_matches_full():
    """models.attention blockwise (training long-seq path) vs einsum core."""
    from repro.models.attention import attention_blockwise, attention_full

    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.normal(size=(2, 128, 4, 16)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(2, 128, 2, 16)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(2, 128, 2, 16)).astype(np.float32))
    a = attention_full(q, k, v, causal=True)
    b = attention_blockwise(q, k, v, causal=True, chunk=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5)
