"""Serving engine: continuous batching must equal direct greedy decode;
int8 KV cache must stay close."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.serving.engine import Engine, EngineConfig, Request


@pytest.fixture(scope="module")
def qwen():
    cfg = get_config("qwen3-8b").reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _direct_greedy(cfg, params, prompt: np.ndarray, n: int,
                   kv_quantized=False) -> list[int]:
    ctx = M.ModelCtx(kv_quantized=kv_quantized)
    lp, st = M.prefill(params, jnp.asarray(prompt)[None, :], cfg,
                       max_len=64, ctx=ctx)
    out = [int(jnp.argmax(lp[0, -1]))]
    cur = len(prompt)
    for _ in range(n - 1):
        ld, st = M.decode_step(params, st,
                               jnp.asarray([[out[-1]]], dtype=jnp.int32),
                               jnp.int32(cur), cfg, ctx=ctx)
        out.append(int(jnp.argmax(ld[0, 0])))
        cur += 1
    return out


def test_engine_matches_direct_decode(qwen):
    cfg, params = qwen
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, size=p).astype(np.int32)
               for p in (5, 9, 14, 7, 11)]
    reqs = [Request(rid=i, prompt=p, max_new_tokens=6)
            for i, p in enumerate(prompts)]
    eng = Engine(cfg, params, EngineConfig(slots=2, max_len=64,
                                           prefill_buckets=(16,)), eos_id=-1)
    for r in reqs:
        eng.submit(r)
    eng.run_until_done(1000)
    for r in reqs:
        assert r.done
        want = _direct_greedy(cfg, params, r.prompt, 6)
        assert r.generated == want, (r.rid, r.generated, want)


def test_engine_int8_kv_close_to_bf16(qwen):
    cfg, params = qwen
    rng = np.random.default_rng(1)
    prompt = rng.integers(1, cfg.vocab_size, size=10).astype(np.int32)
    a = _direct_greedy(cfg, params, prompt, 8, kv_quantized=False)
    b = _direct_greedy(cfg, params, prompt, 8, kv_quantized=True)
    # int8 KV may flip a late low-margin token; prefix must agree
    agree = sum(x == y for x, y in zip(a, b))
    assert agree >= 6, (a, b)


def test_engine_eos_stops_early(qwen):
    cfg, params = qwen
    rng = np.random.default_rng(2)
    prompt = rng.integers(1, cfg.vocab_size, size=8).astype(np.int32)
    ref = _direct_greedy(cfg, params, prompt, 8)
    eos = ref[3]  # force the 4th generated token to be "eos"
    eng = Engine(cfg, params, EngineConfig(slots=1, max_len=64,
                                           prefill_buckets=(16,)), eos_id=eos)
    req = Request(rid=0, prompt=prompt, max_new_tokens=8)
    eng.submit(req)
    eng.run_until_done(100)
    assert req.done and req.generated == ref[:4]


def test_engine_mamba_exact_length_prefill():
    cfg = get_config("mamba2-2.7b").reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    prompt = rng.integers(1, cfg.vocab_size, size=11).astype(np.int32)
    eng = Engine(cfg, params, EngineConfig(slots=2, max_len=64,
                                           prefill_buckets=(16,)), eos_id=-1)
    req = Request(rid=0, prompt=prompt, max_new_tokens=5)
    eng.submit(req)
    eng.run_until_done(100)
    want = _direct_greedy(cfg, params, prompt, 5)
    assert req.generated == want
