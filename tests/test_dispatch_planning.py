"""Cost-aware dispatch planning: planner, cost model, replayer, SLO.

What this file pins (docs/dispatch_planning.md):

  * `DispatchPlanner` partitions are bitwise-equal to the PR 5/6
    module-level planners for ANY cost model — a cost model may change
    WHEN the scheduler dispatches, never WHICH groups form;
  * partition validity and per-session FIFO hold under any cost model
    (hypothesis, random affine models included);
  * the cost table round-trips through its schema-versioned JSON and
    rejects malformed payloads with typed errors;
  * the affine fit recovers exact affine data and the table model
    prefers measured means over the fallback;
  * the replayer reproduces scheduling decisions deterministically, and
    SLO monotonicity holds on burst traces: tightening
    `target_latency_s` never increases the replayed predicted p99
    (burst-scoped deliberately — under sustained overload an eagerly
    split schedule can pay more total overhead, so the general-trace
    claim is false; the CI gate replays the burst profile);
  * live engines: a null cost model (or no deadline) leaves the
    adaptive schedule bitwise-identical to the pre-SLO engine, a real
    model + deadline keeps results bitwise-equal to offline while the
    SLO counters show deadline-driven decisions, and the opt-in
    profiler records a coherent trace + warm cost samples.
"""
from __future__ import annotations

import json

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.dsi import DSIConfig
from repro.core.pipeline import (
    DispatchPlanner,
    EMVSOptions,
    bucket_capacity,
    plan_dispatch_groups,
    plan_dispatch_groups_tagged,
    run_emvs,
)
from repro.events.aggregation import aggregate
from repro.events.simulator import EventStream
from repro.profiling import (
    AffineCostModel,
    CostTable,
    CostTableError,
    NullCostModel,
    SweepProfiler,
    TableCostModel,
    VariantKey,
    fit_affine_model,
)
from repro.profiling.calibrate import main as calibrate_main
from repro.profiling.cost_model import model_from_table
from repro.serving.dispatch_replay import (
    Arrival,
    ReplayConfig,
    check_slo_burst,
    percentile,
    planner_for,
    replay_schedule,
)
from repro.serving.emvs_stream import EMVSStreamEngine, StreamConfig
from test_segment_batching import _assert_results_match

EVENTS_PER_FRAME = 224
GRID_OPTS = dict(formulation="matmul", voting="nearest", quantized=True,
                 keyframe_dist_frac=0.03)


def _random_segments(rng: np.random.Generator, n: int) -> list[tuple[int, int]]:
    lens = rng.integers(1, 14, size=n)
    starts = np.concatenate([[0], np.cumsum(lens)])
    return [(int(starts[i]), int(starts[i + 1])) for i in range(n)]


def _affine_model(rng: np.random.Generator) -> AffineCostModel:
    return AffineCostModel(params={
        backend: (float(rng.uniform(1e-4, 2e-2)),
                  float(rng.uniform(1e-6, 1e-3)))
        for backend in ("batched", "sharded")})


def _variant_of(s_bucket: int, capacity: int) -> VariantKey:
    return VariantKey(s_bucket=s_bucket, capacity=capacity,
                      backend="batched", interpolation="nearest",
                      quantized=False)


# --- planner: partitions are cost-model-independent -----------------------


@settings(deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(0, 40),
       model_kind=st.sampled_from(["none", "null", "affine"]))
def test_planner_partition_matches_module_planner(seed, n, model_kind):
    """For any cost model, DispatchPlanner.plan is bitwise-equal to
    plan_dispatch_groups (which itself now delegates to a null-model
    planner): the cost model must never change the partition."""
    rng = np.random.default_rng(seed)
    segs = _random_segments(rng, n)
    model = {"none": None, "null": NullCostModel(),
             "affine": _affine_model(rng)}[model_kind]
    planner = DispatchPlanner((1, 2, 4), cost_model=model,
                              variant_of=_variant_of)
    assert planner.plan(segs) == plan_dispatch_groups(segs, 4)


@settings(deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(0, 30),
       n_tags=st.integers(1, 4),
       fairness=st.sampled_from(["fifo", "round_robin"]),
       model_kind=st.sampled_from(["none", "affine"]))
def test_planner_tagged_partition_valid_and_fifo_for_any_model(
        seed, n, n_tags, fairness, model_kind):
    """Tagged partitions: bitwise-equal to the module planner, valid S
    buckets, and per-session FIFO preserved — for any cost model and
    both fairness policies."""
    rng = np.random.default_rng(seed)
    segs = _random_segments(rng, n)
    items = [(int(rng.integers(n_tags)), seg) for seg in segs]
    model = None if model_kind == "none" else _affine_model(rng)
    planner = DispatchPlanner((1, 2, 4), cost_model=model,
                              variant_of=_variant_of)
    groups = planner.plan_tagged(items, fairness=fairness)
    assert groups == plan_dispatch_groups_tagged(items, 4, fairness=fairness)
    flat = [it for g, _ in groups for it in g]
    assert sorted(flat) == sorted(items)  # nothing dropped or duplicated
    for g, cap in groups:
        assert 1 <= len(g) <= 4
        assert all(bucket_capacity(e - s) == cap for _, (s, e) in g)
    for tag in set(t for t, _ in items):
        released = [seg for g, _ in groups for t, seg in g if t == tag]
        arrived = [seg for t, seg in items if t == tag]
        assert released == arrived, "per-session FIFO violated"


def test_planner_validation_and_prediction():
    with pytest.raises(ValueError, match="non-empty"):
        DispatchPlanner(())
    with pytest.raises(ValueError, match="ascending"):
        DispatchPlanner((4, 2, 1))
    planner = DispatchPlanner((1, 2, 4))
    assert planner.s_bucket(3) == 4
    with pytest.raises(ValueError, match="exceeds top"):
        planner.s_bucket(5)
    # no model, no variant factory -> predictions are None (null planner)
    assert planner.predict_group_s(2, 8) is None
    model = AffineCostModel(params={"batched": (0.01, 1e-4)})
    priced = DispatchPlanner((1, 2, 4), cost_model=model,
                             variant_of=_variant_of)
    # padded rows are charged: a group of 3 pads to the 4-bucket
    assert priced.predict_group_s(3, 8) == pytest.approx(0.01 + 1e-4 * 32)
    assert priced.predict_drain_s([(0, (0, 8)), (0, (8, 16))]) == (
        pytest.approx(0.01 + 1e-4 * 2 * 8))
    # one unpredictable group poisons the whole drain estimate
    sharded_only = AffineCostModel(params={"sharded": (0.01, 1e-4)})
    blind = DispatchPlanner((1, 2, 4), cost_model=sharded_only,
                            variant_of=_variant_of)
    assert blind.predict_drain_s([(0, (0, 8))]) is None


# --- cost table: schema, round-trip, atomic persistence -------------------


def test_cost_table_roundtrip_and_stats(tmp_path):
    table = CostTable()
    key = _variant_of(2, 8)
    for wall in (0.010, 0.030, 0.020):
        table.record(key, wall)
    stats = table.entry_stats(key)
    assert stats["count"] == 3
    assert stats["mean_s"] == pytest.approx(0.020)
    assert stats["min_s"] == 0.010 and stats["max_s"] == 0.030
    path = tmp_path / "cost_table.json"
    table.save(str(path))
    loaded = CostTable.load(str(path))
    assert loaded.mean_s(key) == pytest.approx(0.020)
    assert len(loaded) == 1
    # merge folds samples count-weighted
    other = CostTable()
    other.record(key, 0.040)
    loaded.merge(other)
    assert loaded.entry_stats(key)["count"] == 4
    assert loaded.mean_s(key) == pytest.approx(0.025)


@pytest.mark.parametrize("mutate, match", [
    (lambda p: p.update(schema_version=99), "schema version"),
    (lambda p: p.update(entries="nope"), "not an object"),
    (lambda p: p["entries"].update({"bad-key": {"count": 1, "mean_s": 1.0,
                                                "min_s": 1.0, "max_s": 1.0}}),
     "malformed variant key"),
    (lambda p: next(iter(p["entries"].values())).pop("mean_s"),
     "missing fields"),
    (lambda p: next(iter(p["entries"].values())).update(count=0),
     "invalid count"),
    (lambda p: next(iter(p["entries"].values())).update(min_s=9.0),
     "min <= mean <= max"),
])
def test_cost_table_schema_validation_rejects(mutate, match):
    table = CostTable()
    table.record(_variant_of(1, 4), 0.01)
    payload = json.loads(json.dumps(table.to_json()))
    mutate(payload)
    with pytest.raises(CostTableError, match=match):
        CostTable.from_json(payload)


def test_variant_key_validation():
    with pytest.raises(CostTableError, match="backend"):
        VariantKey(1, 4, "gpu", "nearest", False)
    with pytest.raises(CostTableError, match="interpolation"):
        VariantKey(1, 4, "batched", "cubic", False)
    with pytest.raises(CostTableError, match="s_bucket"):
        VariantKey(0, 4, "batched", "nearest", False)
    key = VariantKey(2, 8, "sharded", "bilinear", True)
    assert key.rows == 16
    assert VariantKey.from_str(key.to_str()) == key
    with pytest.raises(CostTableError, match="malformed"):
        VariantKey.from_str("s2/c8/sharded/bilinear")


# --- cost model: fit, fallback, calibration -------------------------------


def test_affine_fit_recovers_exact_affine_data():
    table = CostTable()
    for s in (1, 2, 4):
        for c in (4, 8, 12):
            key = _variant_of(s, c)
            table.record(key, 0.005 + 3e-4 * key.rows)
    model, report = fit_affine_model(table)
    overhead, rate = model.params["batched"]
    assert overhead == pytest.approx(0.005, abs=1e-9)
    assert rate == pytest.approx(3e-4, abs=1e-12)
    assert report["backends"]["batched"]["max_rel_error"] < 1e-9
    # prediction clamps at zero outside the support
    assert model.predict_sweep_s(_variant_of(1, 4)) >= 0.0
    assert model.predict_sweep_s(
        VariantKey(1, 4, "sharded", "nearest", False)) is None


def test_table_model_prefers_measured_over_fallback():
    table = CostTable()
    measured = _variant_of(2, 8)
    table.record(measured, 0.5)  # far off any affine trend
    fallback = AffineCostModel(params={"batched": (0.01, 1e-5)})
    model = TableCostModel(table=table, fallback=fallback)
    assert model.predict_sweep_s(measured) == pytest.approx(0.5)
    out_of_dist = _variant_of(4, 16)
    assert model.predict_sweep_s(out_of_dist) == pytest.approx(
        fallback.predict_sweep_s(out_of_dist))
    assert NullCostModel().predict_sweep_s(measured) is None


def test_calibrate_dry_run_smoke(capsys):
    assert calibrate_main(["--dry-run"]) == 0
    out = capsys.readouterr().out
    assert "dry run OK" in out


# --- replayer: determinism, policies, SLO ---------------------------------


def _burst(n: int, cap: int, *, tag=0, t: float = 0.0) -> list[Arrival]:
    return [Arrival(t=t, tag=tag, seg=(k * cap, (k + 1) * cap))
            for k in range(n)]


def test_replay_latency_vs_throughput_schedules():
    model = AffineCostModel(params={"batched": (0.01, 1e-4)})
    planner = planner_for(model, (1, 2, 4), backend="batched")
    arrivals = _burst(8, 4)
    lat = replay_schedule(arrivals, planner, ReplayConfig(policy="latency"))
    tp = replay_schedule(arrivals, planner, ReplayConfig(policy="throughput"))
    assert lat.dispatch_count == 8
    assert tp.dispatch_count == 2  # two full 4-buckets
    # per-sweep overhead is why coalescing wins throughput
    assert tp.makespan_s < lat.makespan_s
    # determinism: same inputs, identical schedule
    again = replay_schedule(arrivals, planner,
                            ReplayConfig(policy="throughput"))
    assert again.to_json() == tp.to_json()


def test_replay_rejects_unpredictable_variants():
    planner = planner_for(AffineCostModel(params={"sharded": (0.01, 1e-4)}),
                          (1, 2, 4), backend="batched")
    with pytest.raises(ValueError, match="cannot predict"):
        replay_schedule(_burst(2, 4), planner, ReplayConfig(policy="latency"))
    with pytest.raises(ValueError, match="cost model"):
        replay_schedule(_burst(1, 4),
                        DispatchPlanner((1, 2, 4)),
                        ReplayConfig(policy="latency"))


def test_percentile_nearest_rank():
    assert percentile([], 0.99) == 0.0
    assert percentile([3.0, 1.0, 2.0], 0.5) == 2.0
    assert percentile([3.0, 1.0, 2.0], 0.99) == 3.0
    with pytest.raises(ValueError):
        percentile([1.0], 0.0)


@settings(deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 24),
       flush_after=st.floats(0.0, 2.0),
       d_lo=st.floats(1e-3, 5.0), d_hi=st.floats(1e-3, 5.0))
def test_slo_monotone_on_burst_traces(seed, n, flush_after, d_lo, d_hi):
    """Burst-scoped SLO monotonicity: all segments arrive at t=0 and
    flush comes at t>=0, so the partition is fixed by the full queue and
    only WHEN held groups dispatch varies with the deadline — tightening
    `target_latency_s` can then only dispatch earlier, never later, so
    the replayed predicted p99 never increases. (General traces do NOT
    satisfy this — eager dispatch under overload splits coalescible
    groups and pays more total overhead — which is why the property and
    the CI gate are burst-scoped.)"""
    rng = np.random.default_rng(seed)
    model = _affine_model(rng)
    planner = planner_for(model, (1, 2, 4), backend="batched")
    # runs of same-capacity segments, all arriving at t=0
    arrivals = []
    frame = 0
    for seg_len in rng.integers(1, 14, size=n):
        arrivals.append(Arrival(t=0.0, tag=0,
                                seg=(frame, frame + int(seg_len))))
        frame += int(seg_len)
    tight, loose = sorted((d_lo, d_hi))
    p99 = {}
    for d in (tight, loose):
        res = replay_schedule(arrivals, planner, ReplayConfig(
            policy="adaptive", target_latency_s=d, flush_t=flush_after))
        p99[d] = res.predicted_p99_s()
    assert p99[tight] <= p99[loose] + 1e-12, (
        f"tightening the deadline {loose} -> {tight} RAISED predicted "
        f"p99: {p99[loose]} -> {p99[tight]}")


def test_check_slo_burst_gate_passes_on_synthetic_table():
    from repro.profiling.calibrate import synthesize_table

    record = check_slo_burst(synthesize_table(), backend="batched")
    slo, tp = record["slo_adaptive"], record["throughput"]
    assert slo["dispatch_count"] <= tp["dispatch_count"]
    assert slo["predicted_p99_s"] <= record["target_latency_s"] + 1e-12
    # the burst actually coalesces — a degenerate per-segment schedule
    # would make the gate vacuous
    assert tp["dispatch_count"] < record["segments"]


# --- live engines: SLO + profiler end to end ------------------------------


@pytest.fixture(scope="module")
def planning_scene(cam, small_scene):
    ev = small_scene["events"]
    traj = small_scene["traj"]
    n = int(ev.t.shape[0])
    keep = min(n, 13 * EVENTS_PER_FRAME + 32)
    ev = EventStream(xy=ev.xy[:keep], t=ev.t[:keep],
                     polarity=ev.polarity[:keep], valid=ev.valid[:keep])
    frames = aggregate(cam, ev, traj, events_per_frame=EVENTS_PER_FRAME)
    dsi_cfg = DSIConfig.for_camera(cam, num_planes=12, z_min=0.6, z_max=4.5)
    ref = run_emvs(cam, dsi_cfg, frames, EMVSOptions(**GRID_OPTS))
    return ev, traj, ref, dsi_cfg


def _run_burst(engine, ev):
    from repro.serving.emvs_stream import iter_event_chunks

    engine.push(next(iter_event_chunks(ev, int(ev.t.shape[0]))))
    return engine.flush()


def _schedule_fingerprint(stats: dict) -> dict:
    return {k: stats[k] for k in ("segments", "dispatches",
                                  "coalesced_dispatches",
                                  "coalesced_segments", "padded_segments",
                                  "max_pending")}


def test_null_model_slo_schedule_is_bitwise_identical(cam, planning_scene):
    """target_latency_s with a null cost model (or no model at all) must
    leave the adaptive schedule — counters and results — exactly as the
    pre-SLO engine produced it: the depth-based fallback."""
    ev, traj, ref, dsi_cfg = planning_scene
    cfg = dict(events_per_frame=EVENTS_PER_FRAME, dispatch_policy="adaptive")
    base = EMVSStreamEngine(cam, dsi_cfg, traj, EMVSOptions(**GRID_OPTS),
                            StreamConfig(**cfg))
    res_base = _run_burst(base, ev)
    for extra in ({"cost_model": None},
                  {"cost_model": NullCostModel()}):
        engine = EMVSStreamEngine(
            cam, dsi_cfg, traj, EMVSOptions(**GRID_OPTS),
            StreamConfig(**cfg, target_latency_s=0.050), **extra)
        res = _run_burst(engine, ev)
        _assert_results_match(res, res_base, exact_dsi=True)
        assert (_schedule_fingerprint(engine.stats)
                == _schedule_fingerprint(base.stats))
        assert engine.stats["slo_dispatches"] == 0
        assert engine.stats["slo_holds"] == 0
    _assert_results_match(res_base, ref, exact_dsi=True)


def test_slo_adaptive_with_model_stays_bitwise_and_counts(cam,
                                                          planning_scene):
    """A real cost model + deadline changes WHEN groups dispatch (the
    SLO counters must show it) but never the numbers: results stay
    bitwise-equal to offline run_emvs."""
    ev, traj, ref, dsi_cfg = planning_scene
    model = AffineCostModel(params={"batched": (1e-3, 1e-6),
                                    "sharded": (1e-3, 1e-6)})
    for target, expect in ((1e-6, "slo_dispatches"), (10.0, "slo_holds")):
        engine = EMVSStreamEngine(
            cam, dsi_cfg, traj, EMVSOptions(**GRID_OPTS),
            StreamConfig(events_per_frame=EVENTS_PER_FRAME,
                         dispatch_policy="adaptive", target_latency_s=target),
            cost_model=model)
        res = _run_burst(engine, ev)
        _assert_results_match(res, ref, exact_dsi=True)
        assert engine.stats[expect] > 0, (
            f"target={target}: expected {expect} > 0, got {engine.stats}")


def test_profiler_records_trace_and_warm_samples(cam, planning_scene):
    """The opt-in recorder captures a coherent dispatch trace (every
    dispatched segment arrived first) and only warm, unshadowed wall
    times enter the cost table."""
    ev, traj, ref, dsi_cfg = planning_scene
    profiler = SweepProfiler()
    engine = EMVSStreamEngine(
        cam, dsi_cfg, traj, EMVSOptions(**GRID_OPTS),
        StreamConfig(events_per_frame=EVENTS_PER_FRAME,
                     dispatch_policy="latency"),
        profiler=profiler)
    _run_burst(engine, ev)
    trace = profiler.trace_json()
    arrived = {(a["tag"], tuple(a["seg"])) for a in trace["arrivals"]}
    dispatched = [(tag, tuple(seg)) for d in trace["dispatches"]
                  for tag, seg in d["segs"]]
    assert len(trace["arrivals"]) == engine.stats["segments"]
    assert len(trace["dispatches"]) == engine.stats["dispatches"]
    assert set(dispatched) <= arrived
    assert len(dispatched) == len(set(dispatched)), "segment dispatched twice"
    for d in trace["dispatches"]:
        VariantKey.from_str(d["key"])  # keys are schema-valid
    # warm samples: the first observation per variant (cold compile) is
    # skipped, so sample count <= dispatches - distinct variants
    total = sum(profiler.table.entry_stats(k)["count"]
                for k in profiler.table.keys())
    assert total + profiler.skipped_cold + profiler.skipped_shadowed == sum(
        1 for _ in trace["dispatches"])
    assert profiler.skipped_cold >= len(set(d["key"]
                                            for d in trace["dispatches"]))
    # and a model fitted from live samples predicts every live variant
    if len(profiler.table):
        model = model_from_table(profiler.table)
        for key in profiler.table.keys():
            assert model.predict_sweep_s(key) is not None


def test_stream_config_target_latency_validation():
    with pytest.raises(ValueError, match="target_latency_s"):
        StreamConfig(target_latency_s=0.0)
    with pytest.raises(ValueError, match="target_latency_s"):
        StreamConfig(target_latency_s=-1.0)
    assert StreamConfig(target_latency_s=0.25).target_latency_s == 0.25
