"""Plane-sweep geometry: the H_Z0 + phi factorization must agree with
direct 3D reprojection — the correctness core of the paper's P stage."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st

from repro.core.camera import CameraModel, project, unproject
from repro.core.geometry import (
    SE3,
    apply_homography,
    canonical_homography,
    depth_planes,
    interpolate_pose,
    pose_distance,
    proportional_coeffs,
    propagate_to_planes,
    relative_pose_ref_from_cam,
    so3_exp,
    so3_log,
)


def _random_pose(rng, t_scale=0.1, r_scale=0.1) -> SE3:
    w = jnp.asarray(rng.uniform(-r_scale, r_scale, 3).astype(np.float32))
    t = jnp.asarray(rng.uniform(-t_scale, t_scale, 3).astype(np.float32))
    return SE3(so3_exp(w), t)


def test_se3_compose_inverse():
    rng = np.random.default_rng(0)
    a, b = _random_pose(rng), _random_pose(rng)
    ident = a.compose(a.inverse())
    assert np.allclose(ident.R, np.eye(3), atol=1e-5)
    assert np.allclose(ident.t, 0, atol=1e-5)
    pts = jnp.asarray(rng.normal(size=(1, 10, 3)).astype(np.float32))
    ab = a.compose(b)
    assert np.allclose(ab.apply(pts), a.apply(b.apply(pts)), atol=1e-4)


@given(seed=st.integers(0, 10_000))
def test_so3_log_exp_roundtrip(seed):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.uniform(-1.0, 1.0, 3).astype(np.float32))
    R = so3_exp(w)
    w2 = so3_log(R)
    assert np.allclose(np.asarray(w), np.asarray(w2), atol=1e-4)


def test_homography_plus_phi_equals_direct_reprojection(cam):
    """Back-project pixels from the current camera onto plane Zi in the
    reference frame two ways: (a) H_Z0 then the phi multiply-add (the
    paper's P(Z0) + P(Z0->Zi)), (b) full 3D ray-plane intersection."""
    rng = np.random.default_rng(1)
    T_w_ref = SE3.identity()
    T_w_cam = _random_pose(rng, t_scale=0.15, r_scale=0.08)
    T_ref_cam = relative_pose_ref_from_cam(T_w_ref, T_w_cam)

    planes = depth_planes(0.8, 4.0, 8)
    z0 = planes[4]
    H = canonical_homography(cam, T_ref_cam, z0)
    phi = proportional_coeffs(cam, T_ref_cam, z0, planes)

    xy = jnp.asarray(rng.uniform((20, 20), (220, 160), (64, 2)).astype(np.float32))
    xy0 = apply_homography(H, xy)
    x_i, y_i = propagate_to_planes(cam, xy0, phi)  # (Nz, E)

    # direct: ray through current camera centre and the pixel, intersected
    # with plane z = Zi in the reference frame, projected by the reference
    C = T_ref_cam.t  # camera centre in ref frame
    dirs_cam = unproject(cam, xy, jnp.float32(1.0))  # (E, 3) in current frame
    dirs_ref = jnp.einsum("ij,ej->ei", T_ref_cam.R, dirs_cam)  # direction
    for i, zi in enumerate(np.asarray(planes)):
        s = (zi - C[2]) / dirs_ref[:, 2]
        pts = C[None, :] + s[:, None] * dirs_ref  # (E, 3), z == zi
        uv = project(cam, pts)
        assert np.allclose(np.asarray(x_i[i]), np.asarray(uv[:, 0]), atol=2e-2), i
        assert np.allclose(np.asarray(y_i[i]), np.asarray(uv[:, 1]), atol=2e-2), i


def test_interpolate_pose_endpoints():
    rng = np.random.default_rng(2)
    p0, p1 = _random_pose(rng), _random_pose(rng)
    a = interpolate_pose(p0, p1, jnp.float32(0.0))
    b = interpolate_pose(p0, p1, jnp.float32(1.0))
    assert np.allclose(a.R, p0.R, atol=1e-5) and np.allclose(a.t, p0.t, atol=1e-6)
    assert np.allclose(b.R, p1.R, atol=1e-4) and np.allclose(b.t, p1.t, atol=1e-6)
    mid = interpolate_pose(p0, p1, jnp.float32(0.5))
    assert np.allclose(mid.t, (p0.t + p1.t) / 2, atol=1e-6)


def test_pose_distance_is_keyframe_criterion():
    p0 = SE3.identity()
    p1 = SE3(jnp.eye(3), jnp.array([0.3, 0.4, 0.0]))
    assert abs(float(pose_distance(p0, p1)) - 0.5) < 1e-6
