"""MoE routing/capacity semantics (single device; EP in test_distributed)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models.moe import _capacity, init_moe, moe_apply, router_probs


def _cfg(capacity_factor=1.25, top_k=2):
    cfg = get_config("deepseek-moe-16b").reduced()
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=capacity_factor,
                                     top_k=top_k))


def test_router_gates_normalized():
    cfg = _cfg()
    params = init_moe(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (32, cfg.d_model))
    gates, idx, aux = router_probs(params, x, cfg.moe)
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, atol=1e-5)
    assert bool((idx >= 0).all()) and bool((idx < cfg.moe.num_experts).all())
    # top-k ids are distinct per token
    assert bool((idx[:, 0] != idx[:, 1]).all())
    assert float(aux) > 0.0


def test_no_drops_at_high_capacity():
    cfg = _cfg(capacity_factor=16.0)
    params = init_moe(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, cfg.d_model))
    _, metrics = moe_apply(params, x, cfg)
    assert float(metrics["moe_drop_frac"]) == 0.0


@given(t=st.sampled_from([16, 64, 256]), cf=st.sampled_from([0.5, 1.0, 2.0]))
@settings(max_examples=9)
def test_capacity_formula(t, cf):
    cfg = _cfg(capacity_factor=cf)
    c = _capacity(t, cfg.moe)
    assert c >= 4
    assert c >= int(t * cfg.moe.top_k * cf / cfg.moe.num_experts)


def test_moe_output_is_gated_expert_mix():
    """With capacity ample, y = sum_k gate_k * expert_k(x) + shared(x)."""
    cfg = _cfg(capacity_factor=16.0)
    params = init_moe(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (8, cfg.d_model))
    y, _ = moe_apply(params, x, cfg)
    gates, idx, _ = router_probs(params, x, cfg.moe)

    from repro.models.layers import mlp

    we = params["experts"]
    want = np.zeros((8, cfg.d_model), np.float32)
    for t in range(8):
        acc = np.zeros(cfg.d_model, np.float32)
        for j in range(cfg.moe.top_k):
            e = int(idx[t, j])
            h = np.asarray(x[t]) @ np.asarray(we["w_gate"][e], np.float32)
            u = np.asarray(x[t]) @ np.asarray(we["w_up"][e], np.float32)
            act = h / (1 + np.exp(-h)) * u  # silu * up
            acc += float(gates[t, j]) * (act @ np.asarray(we["w_down"][e], np.float32))
        want[t] = acc
    if cfg.moe.num_shared_experts:
        want += np.asarray(mlp(params["shared"], x, cfg.mlp_variant))
    np.testing.assert_allclose(np.asarray(y), want, atol=5e-4, rtol=1e-3)


def test_drop_frac_increases_as_capacity_shrinks():
    params = init_moe(jax.random.PRNGKey(0), _cfg().reduced() if False else _cfg(),
                      dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (256, _cfg().d_model))
    drops = []
    for cf in (4.0, 1.0, 0.25):
        cfg = _cfg(capacity_factor=cf)
        _, m = moe_apply(params, x, cfg)
        drops.append(float(m["moe_drop_frac"]))
    assert drops[0] <= drops[1] <= drops[2]
    assert drops[2] > 0.0
