"""int8 gradient compression: round-trip bounds + error-feedback property
(the bias vanishes over repeated steps — Seide'14 semantics)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.distributed.compression import (
    BLOCK,
    CompressionState,
    compress_decompress,
    compression_error,
    init_state,
)


@given(seed=st.integers(0, 1000), scale=st.sampled_from([1e-4, 1.0, 1e4]))
@settings(max_examples=20)
def test_roundtrip_error_bounded(seed, scale):
    rng = np.random.default_rng(seed)
    g = jnp.asarray((rng.normal(size=300) * scale).astype(np.float32))
    rt = compress_decompress(g)
    # per-block max-abs scaling: error <= scale/2 = blockmax/254 per element
    blocks = np.pad(np.asarray(g), (0, (-len(g)) % BLOCK)).reshape(-1, BLOCK)
    bmax = np.abs(blocks).max(axis=1, keepdims=True)
    bound = np.repeat(bmax / 127.0 / 2.0, BLOCK, axis=1).reshape(-1)[:len(g)]
    assert np.all(np.abs(np.asarray(rt) - np.asarray(g)) <= bound + 1e-12)
    assert float(compression_error(g)) < 0.01  # ~8-bit SNR


def test_zero_and_constant_grads_exact():
    z = jnp.zeros(512)
    assert float(jnp.max(jnp.abs(compress_decompress(z)))) == 0.0
    c = jnp.full(512, 3.25)
    np.testing.assert_allclose(np.asarray(compress_decompress(c)), 3.25,
                               rtol=1e-6)


def test_error_feedback_removes_bias():
    """Accumulated (compressed + residual) updates converge to the true
    sum: || sum_t true_g - sum_t sent_g || stays bounded by one step's
    quantization error, not t * error."""
    rng = np.random.default_rng(0)
    true_sum = np.zeros(256, np.float32)
    sent_sum = np.zeros(256, np.float32)
    residual = jnp.zeros(256, jnp.float32)
    for t in range(50):
        g = jnp.asarray(rng.normal(size=256).astype(np.float32))
        gf = g + residual
        sent = compress_decompress(gf)
        residual = gf - sent
        true_sum += np.asarray(g)
        sent_sum += np.asarray(sent)
    # bias bounded by the residual (single-step error), not accumulated
    gap = np.abs(true_sum - sent_sum).max()
    assert gap <= float(jnp.max(jnp.abs(residual))) + 1e-5
    assert gap < 0.05  # vs ~50 steps * per-step error if bias accumulated


def test_compressed_psum_single_axis():
    """shard_map over a size-1 axis exercises the wire path end to end."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.distributed.compression import compressed_psum

    mesh = jax.make_mesh((1,), ("data",))
    g = {"w": jnp.asarray(np.random.default_rng(1)
                          .normal(size=(8, 8)).astype(np.float32))}
    state = init_state(g)

    def body(g, r):
        return compressed_psum(g, CompressionState(residual=r), "data")

    fn = shard_map(body, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
                   check_rep=False)
    mean, new_state = fn(g, state.residual)
    np.testing.assert_allclose(np.asarray(mean["w"]), np.asarray(g["w"]),
                               atol=np.abs(np.asarray(g["w"])).max() / 127)
