"""The padded batched segment sweep must reproduce the looped path exactly.

Covers the full option grid (scatter/matmul/kernel x nearest/bilinear x
float/quantized) on a small multi-segment sequence, plus the host-side
segment planning edge cases (single segment, trailing short segment,
bucket capacities, padding masks).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dsi import DSIConfig
from repro.core.geometry import SE3
from repro.core.pipeline import (
    EMVSOptions,
    bucket_capacity,
    pad_segments,
    plan_segments,
    run_emvs,
    run_emvs_looped,
    segment_keyframes,
)
from repro.events.aggregation import EventFrames


@pytest.fixture(scope="module")
def mini(cam):
    """Tiny multi-segment sequence: small event frames keep the 12-combo
    grid affordable while still spanning several bucket shapes."""
    from repro.events.aggregation import aggregate
    from repro.events.simulator import (
        SceneConfig,
        make_scene,
        make_trajectory,
        simulate_events,
    )

    scene = make_scene(SceneConfig(name="simulation_3planes", points_per_plane=80))
    traj = make_trajectory("simulation_3planes", 16)
    ev = simulate_events(cam, scene, traj, noise_fraction=0.0)
    frames = aggregate(cam, ev, traj, events_per_frame=192)
    dsi_cfg = DSIConfig.for_camera(cam, num_planes=16, z_min=0.6, z_max=4.5)
    return frames, dsi_cfg


def _synthetic_frames(t_x: list[float], events: int = 64, seed: int = 0) -> EventFrames:
    """Identity-rotation frames translating along x; random in-bounds events."""
    n = len(t_x)
    r = np.random.default_rng(seed)
    xy = np.stack([r.uniform(0, 239, (n, events)), r.uniform(0, 179, (n, events))],
                  axis=-1).astype(np.float32)
    t = np.zeros((n, 3), np.float32)
    t[:, 0] = t_x
    return EventFrames(
        xy=jnp.asarray(xy),
        valid=jnp.ones((n, events), jnp.float32),
        t_mid=jnp.arange(n, dtype=jnp.float32),
        poses=SE3(jnp.broadcast_to(jnp.eye(3, dtype=jnp.float32), (n, 3, 3)),
                  jnp.asarray(t)),
    )


def _assert_results_match(a, b, exact_dsi=False):
    """exact_dsi: nearest voting accumulates integral counts, so the padded
    sweep must match the looped path bitwise, not just within tolerance."""
    assert len(a.segments) == len(b.segments)
    assert len(a.clouds) == len(b.clouds) == len(a.segments)
    for sa, sb in zip(a.segments, b.segments):
        assert sa.frame_range == sb.frame_range
        if exact_dsi:
            np.testing.assert_array_equal(np.asarray(sa.dsi), np.asarray(sb.dsi))
        else:
            np.testing.assert_allclose(np.asarray(sa.dsi, np.float32),
                                       np.asarray(sb.dsi, np.float32), atol=1e-4)
        np.testing.assert_array_equal(np.asarray(sa.depth_map.mask),
                                      np.asarray(sb.depth_map.mask))
        m = np.asarray(sa.depth_map.mask)
        np.testing.assert_allclose(np.asarray(sa.depth_map.depth)[m],
                                   np.asarray(sb.depth_map.depth)[m], atol=1e-5)
        np.testing.assert_allclose(np.asarray(sa.T_w_ref.t),
                                   np.asarray(sb.T_w_ref.t), atol=0)
    for ca, cb in zip(a.clouds, b.clouds):
        np.testing.assert_array_equal(np.asarray(ca.valid), np.asarray(cb.valid))
        v = np.asarray(ca.valid)
        np.testing.assert_allclose(np.asarray(ca.points)[v],
                                   np.asarray(cb.points)[v], atol=1e-5)


GRID = [(f, v, q)
        for f in ("scatter", "matmul", "kernel")
        for v in ("nearest", "bilinear")
        for q in (False, True)]


@pytest.mark.parametrize("formulation,voting,quantized", GRID)
def test_batched_matches_looped(cam, mini, formulation, voting, quantized):
    frames, dsi_cfg = mini
    opts = EMVSOptions(formulation=formulation, voting=voting,
                       quantized=quantized, keyframe_dist_frac=0.03)
    segs = plan_segments(frames, dsi_cfg, opts)
    assert len(segs) >= 2, "scene must produce several segments to batch"
    _assert_results_match(run_emvs(cam, dsi_cfg, frames, opts),
                          run_emvs_looped(cam, dsi_cfg, frames, opts),
                          exact_dsi=(voting == "nearest"))


def test_single_segment_trajectory(cam, mini):
    """A threshold no frame ever crosses -> one segment covering everything."""
    frames, dsi_cfg = mini
    opts = EMVSOptions(keyframe_dist_frac=100.0)
    segs = plan_segments(frames, dsi_cfg, opts)
    assert segs == [(0, frames.xy.shape[0])]
    a = run_emvs(cam, dsi_cfg, frames, opts)
    b = run_emvs_looped(cam, dsi_cfg, frames, opts)
    assert len(a.segments) == 1
    _assert_results_match(a, b)


def test_trailing_short_segment_dropped(cam):
    """A trailing 1-frame segment is dropped identically by both paths."""
    # thresh = mean_depth * frac = 2.0 * 0.05 = 0.1; x steps of 0.04 break
    # after every 3rd frame -> [(0,3), (3,6), (6,7)] with a 1-frame tail.
    frames = _synthetic_frames([0.0, 0.04, 0.08, 0.12, 0.16, 0.20, 0.24])
    segs = segment_keyframes(frames.poses, mean_depth=2.0, frac=0.05)
    assert segs == [(0, 3), (3, 6), (6, 7)]
    dsi_cfg = DSIConfig.for_camera(cam, num_planes=8, z_min=0.5, z_max=3.5)
    opts = EMVSOptions(keyframe_dist_frac=0.05)
    assert plan_segments(frames, dsi_cfg, opts) == [(0, 3), (3, 6)]
    a = run_emvs(cam, dsi_cfg, frames, opts)
    b = run_emvs_looped(cam, dsi_cfg, frames, opts)
    assert [s.frame_range for s in a.segments] == [(0, 3), (3, 6)]
    _assert_results_match(a, b)


def test_all_segments_too_short(cam):
    """Every frame its own key frame -> nothing to reconstruct, both paths."""
    frames = _synthetic_frames([0.0, 0.2, 0.4, 0.6])
    dsi_cfg = DSIConfig.for_camera(cam, num_planes=8, z_min=0.5, z_max=3.5)
    opts = EMVSOptions(keyframe_dist_frac=0.05)  # thresh 0.1 < step 0.2
    assert plan_segments(frames, dsi_cfg, opts) == []
    for res in (run_emvs(cam, dsi_cfg, frames, opts),
                run_emvs_looped(cam, dsi_cfg, frames, opts)):
        assert res.segments == [] and res.clouds == []


def test_zero_frames_empty_result(cam):
    """Regression: zero-frame EventFrames used to crash segment_keyframes
    (t[0] IndexError); run_emvs must return an empty EMVSResult instead."""
    from repro.events.aggregation import empty_event_frames

    frames = empty_event_frames(64)
    assert segment_keyframes(frames.poses, mean_depth=2.0, frac=0.05) == []
    dsi_cfg = DSIConfig.for_camera(cam, num_planes=8, z_min=0.5, z_max=3.5)
    assert plan_segments(frames, dsi_cfg, EMVSOptions()) == []
    for fn in (run_emvs, run_emvs_looped):
        res = fn(cam, dsi_cfg, frames, EMVSOptions())
        assert res.segments == [] and res.clouds == []


def test_bucket_capacity():
    assert bucket_capacity(1) == 4
    assert bucket_capacity(4) == 4
    assert bucket_capacity(5) == 8
    assert bucket_capacity(9) == 12
    assert bucket_capacity(13) == 16
    with pytest.raises(ValueError):
        bucket_capacity(0)


def test_pad_segments_masks_and_clamping():
    frames = _synthetic_frames([0.0, 0.1, 0.2, 0.3, 0.4, 0.5], events=8)
    batch = pad_segments(frames, [(0, 2), (2, 6)], capacity=4)
    np.testing.assert_array_equal(np.asarray(batch.frame_valid),
                                  [[1, 1, 0, 0], [1, 1, 1, 1]])
    # padded slots repeat the last real frame (finite geometry, zero weight)
    np.testing.assert_array_equal(np.asarray(batch.xy[0, 2]),
                                  np.asarray(frames.xy[1]))
    np.testing.assert_array_equal(np.asarray(batch.xy[0, 3]),
                                  np.asarray(frames.xy[1]))
    np.testing.assert_array_equal(np.asarray(batch.poses_t[0, 3]),
                                  np.asarray(frames.poses.t[1]))
    # reference pose = first frame of each segment
    np.testing.assert_array_equal(np.asarray(batch.ref_t),
                                  np.asarray(frames.poses.t[jnp.asarray([0, 2])]))
    with pytest.raises(ValueError):
        pad_segments(frames, [(0, 5)], capacity=4)


def test_pad_segments_empty_list_raises():
    """Regression: an empty segment list used to die inside np.stack with
    an opaque "need at least one array" error; it must be a clear
    ValueError at the API boundary instead."""
    frames = _synthetic_frames([0.0, 0.1], events=8)
    with pytest.raises(ValueError, match="at least one segment"):
        pad_segments(frames, [], capacity=4)
