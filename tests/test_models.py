"""Model zoo: per-arch smoke (reduced configs, the assignment contract),
prefill/decode consistency, and the SSD-vs-sequential oracle."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import ARCH_IDS, ArchConfig, SSMConfig, get_config
from repro.models import model as M


def _frontend(cfg, B, S):
    if cfg.frontend == "vision_patches":
        return jnp.ones((B, cfg.n_frontend_tokens, cfg.d_model), jnp.float32)
    if cfg.frontend == "audio_frames":
        return jnp.ones((B, S, cfg.d_model), jnp.float32)
    return None


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch):
    """Assignment: reduced config, one forward/train step on CPU, output
    shapes + no NaNs."""
    from repro.training.optimizer import AdamWConfig
    from repro.training.train_step import TrainOptions, init_train_state, make_train_step

    cfg = get_config(arch).reduced()
    B, S = 2, 32
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    fe = _frontend(cfg, B, S)
    logits, aux = M.forward(params, toks, cfg, frontend_embed=fe)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))

    opts = TrainOptions(remat=False, opt=AdamWConfig(warmup_steps=1, total_steps=4))
    state = init_train_state(key, cfg, opts)
    step = make_train_step(cfg, opts)
    batch = {"tokens": toks, "targets": toks}
    if fe is not None:
        batch["frontend_embed"] = fe
    state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))


@pytest.mark.parametrize("arch", ["qwen3-8b", "mamba2-2.7b",
                                  "jamba-1.5-large-398b", "deepseek-moe-16b"])
def test_prefill_decode_matches_forward_fp32(arch):
    cfg = get_config(arch).reduced()
    if cfg.moe is not None:  # avoid capacity-drop mismatch; semantics tested in moe tests
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg, dtype=jnp.float32)
    B, S, P = 2, 32, 24
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    full, _ = M.forward(params, toks, cfg)
    lp, state = M.prefill(params, toks[:, :P], cfg, max_len=S)
    errs = [float(jnp.max(jnp.abs(lp[:, 0] - full[:, P - 1])))]
    for i in range(P, S):
        ld, state = M.decode_step(params, state, toks[:, i:i + 1],
                                  jnp.int32(i), cfg)
        errs.append(float(jnp.max(jnp.abs(ld[:, 0] - full[:, i]))))
    scale = float(jnp.max(jnp.abs(full)))
    assert max(errs) < 1e-3 * max(scale, 1.0), (arch, max(errs), scale)


@given(seed=st.integers(0, 1000), chunk=st.sampled_from([4, 8, 16]),
       s=st.integers(5, 40))
@settings(max_examples=15)
def test_ssd_chunked_matches_sequential(seed, chunk, s):
    """Property: the chunked dual form == the sequential SSM recurrence,
    for any sequence length (incl. non-multiples of the chunk)."""
    from repro.models.mamba2 import ssd_chunked

    rng = np.random.default_rng(seed)
    bt, h, p, n = 2, 2, 4, 8
    x = jnp.asarray(rng.normal(size=(bt, s, h, p)).astype(np.float32))
    dt = jnp.asarray(rng.normal(size=(bt, s, h)).astype(np.float32))
    A = jnp.asarray(np.abs(rng.normal(size=h)).astype(np.float32) + 0.3)
    B = jnp.asarray(rng.normal(size=(bt, s, n)).astype(np.float32))
    C = jnp.asarray(rng.normal(size=(bt, s, n)).astype(np.float32))
    D = jnp.asarray(rng.normal(size=h).astype(np.float32))
    y, hf = ssd_chunked(x, dt, A, B, C, D, chunk)

    dtp = jax.nn.softplus(dt)
    hs = jnp.zeros((bt, h, n, p))
    ys = []
    for t in range(s):
        a = jnp.exp(-dtp[:, t] * A[None, :])
        hs = hs * a[..., None, None] + jnp.einsum(
            "bn,bh,bhp->bhnp", B[:, t], dtp[:, t], x[:, t])
        ys.append(jnp.einsum("bn,bhnp->bhp", C[:, t], hs) + x[:, t] * D[None, :, None])
    yref = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref),
                               atol=3e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(hs),
                               atol=3e-4, rtol=1e-3)


def test_ssd_gradients_finite():
    """Regression: masked-exp overflow used to NaN the backward pass."""
    from repro.models.mamba2 import init_mamba2, mamba2_forward

    cfg = get_config("mamba2-2.7b").reduced()
    params = init_mamba2(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))

    def loss(p):
        return jnp.sum(mamba2_forward(p, x, cfg) ** 2)

    g = jax.grad(loss)(params)
    for leaf in jax.tree.leaves(g):
        assert bool(jnp.all(jnp.isfinite(leaf)))


def test_rope_positions_shift_invariance():
    """RoPE attention depends only on relative positions."""
    from repro.models.attention import apply_rope, rope_sincos

    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(1, 8, 2, 16)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 8, 2, 16)).astype(np.float32))

    def scores(offset):
        pos = jnp.arange(8)[None, :] + offset
        sin, cos = rope_sincos(pos, 16, 10000.0)
        qr = apply_rope(q, sin, cos)
        kr = apply_rope(k, sin, cos)
        return jnp.einsum("bqhd,bkhd->bhqk", qr, kr)

    np.testing.assert_allclose(np.asarray(scores(0)), np.asarray(scores(700)),
                               atol=2e-4)


def test_param_counts_match_published_sizes():
    """The registry's total_params() should land near each arch's name."""
    expected = {
        "kimi-k2-1t-a32b": 1.04e12,
        "deepseek-moe-16b": 16.9e9,
        "stablelm-3b": 2.8e9,
        "qwen3-8b": 8.2e9,
        "starcoder2-15b": 16.0e9,
        "jamba-1.5-large-398b": 398e9,
        "llava-next-mistral-7b": 7.2e9,
        "mamba2-2.7b": 2.7e9,
    }
    for name, want in expected.items():
        got = get_config(name).total_params()
        assert 0.8 * want < got < 1.25 * want, (name, got, want)


def test_kv_cache_int8_roundtrip():
    from repro.models.kv_cache import init_cache, read_cache, write_cache

    rng = np.random.default_rng(0)
    k = jnp.asarray(rng.normal(size=(2, 4, 2, 16)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(2, 4, 2, 16)).astype(np.float32))
    cache = init_cache(2, 8, 2, 16, quantized=True)
    cache = write_cache(cache, k, v, jnp.int32(0))
    kd, vd = read_cache(cache, jnp.float32)
    err = float(jnp.max(jnp.abs(kd[:, :4] - k)))
    amax = float(jnp.max(jnp.abs(k)))
    assert err <= amax / 127 * 1.01  # one int8 quantization step
    # unwritten region stays zero
    assert float(jnp.max(jnp.abs(kd[:, 4:]))) == 0.0


def test_head_padding_exact():
    """§Perf H1: padding head counts to the TP degree must not change the
    model function (padded outputs are masked) nor real-head gradients."""
    import numpy as np

    base = ArchConfig(name="odd-heads", family="dense", n_layers=2,
                      d_model=32, n_heads=5, n_kv_heads=5, d_ff=64,
                      vocab_size=97, d_head=16)
    padded = base.pad_heads_to(4)
    assert padded.n_heads_eff % 4 == 0 and padded.n_kv_heads_eff % 4 == 0
    assert padded.n_heads_eff % padded.n_kv_heads_eff == 0

    key = jax.random.PRNGKey(0)
    p0 = M.init_params(key, base, dtype=jnp.float32)
    pp = M.init_params(key, padded, dtype=jnp.float32)
    hd = base.head_dim
    # graft the unpadded weights into the real-head slices
    for i in range(len(base.pattern())):
        a0 = p0["blocks"][i]["attn"]
        ap = pp["blocks"][i]["attn"]
        for w, n in (("wq", base.n_heads), ("wk", base.n_kv_heads),
                     ("wv", base.n_kv_heads)):
            ap[w]["w"] = ap[w]["w"].at[:, :, :n * hd].set(a0[w]["w"])
        ap["wo"]["w"] = ap["wo"]["w"].at[:, :base.n_heads * hd, :].set(
            a0["wo"]["w"])
        for k in ("norm1", "norm2", "ffn"):
            pp["blocks"][i][k] = p0["blocks"][i][k]
    pp["embed"], pp["final_norm"] = p0["embed"], p0["final_norm"]
    pp["lm_head"] = p0["lm_head"]

    toks = jax.random.randint(key, (2, 16), 0, base.vocab_size)
    l0, _ = M.forward(p0, toks, base)
    lp, _ = M.forward(pp, toks, padded)
    assert float(jnp.max(jnp.abs(l0 - lp))) == 0.0

    g0 = jax.grad(lambda p: M.loss_fn(p, toks, toks, base)[0])(p0)
    gp = jax.grad(lambda p: M.loss_fn(p, toks, toks, padded)[0])(pp)
    real = slice(None, base.n_heads * hd)
    np.testing.assert_allclose(
        np.asarray(g0["blocks"][0]["attn"]["wq"]["w"]),
        np.asarray(gp["blocks"][0]["attn"]["wq"]["w"][:, :, real]), atol=1e-6)
    pad = slice(base.n_heads * hd, None)
    assert float(jnp.max(jnp.abs(gp["blocks"][0]["attn"]["wq"]["w"][:, :, pad]))) == 0.0
    assert float(jnp.max(jnp.abs(gp["blocks"][0]["attn"]["wo"]["w"][:, pad, :]))) == 0.0
