"""Training substrate: optimizer math, schedules, microbatching,
checkpoint/restart, preemption, stragglers, elastic re-meshing."""
from __future__ import annotations

import os
import signal

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.distributed import fault_tolerance as ft
from repro.training import checkpoint as ckpt
from repro.training.data import DataConfig, TokenStream
from repro.training.optimizer import (
    AdamWConfig,
    adamw_update,
    cosine_lr,
    global_norm,
    init_opt_state,
)
from repro.training.train_step import TrainOptions, init_train_state, make_train_step


def test_adamw_matches_reference_step():
    """One AdamW step vs a literal numpy transcription."""
    cfg = AdamWConfig(peak_lr=1e-2, warmup_steps=0, total_steps=100,
                      weight_decay=0.1, grad_clip=1e9)
    p = {"w": jnp.asarray(np.arange(6, dtype=np.float32).reshape(2, 3))}
    g = {"w": jnp.full((2, 3), 0.5, jnp.float32)}
    st_ = init_opt_state(p, cfg)
    newp, newst, m = adamw_update(p, g, st_, cfg)
    lr = float(cosine_lr(cfg, jnp.int32(1)))
    m1 = 0.1 * 0.5 / (1 - 0.9)
    v1 = 0.05 * 0.25 / (1 - 0.95)
    want = np.asarray(p["w"]) - lr * (m1 / (np.sqrt(v1) + cfg.eps)
                                      + 0.1 * np.asarray(p["w"]))
    np.testing.assert_allclose(np.asarray(newp["w"]), want, rtol=1e-5)
    assert int(newst.step) == 1


def test_weight_decay_skips_vectors():
    cfg = AdamWConfig(peak_lr=1e-3, warmup_steps=0, total_steps=10,
                      weight_decay=1.0)
    p = {"w": jnp.ones((2, 2)), "scale": jnp.ones((4,))}
    g = {"w": jnp.zeros((2, 2)), "scale": jnp.zeros((4,))}
    newp, _, _ = adamw_update(p, g, init_opt_state(p, cfg), cfg)
    assert float(jnp.max(jnp.abs(newp["scale"] - 1.0))) == 0.0  # no decay
    assert float(jnp.max(jnp.abs(newp["w"] - 1.0))) > 0.0  # decayed


@given(step=st.integers(0, 10_000))
@settings(max_examples=20)
def test_cosine_schedule_bounds(step):
    cfg = AdamWConfig(peak_lr=3e-4, warmup_steps=100, total_steps=10_000)
    lr = float(cosine_lr(cfg, jnp.int32(step)))
    assert 0.0 <= lr <= cfg.peak_lr * (1 + 1e-6)
    if step >= cfg.total_steps:
        assert lr == pytest.approx(cfg.peak_lr * cfg.min_lr_frac, rel=1e-3)


def test_grad_clip_caps_update_norm():
    cfg = AdamWConfig(peak_lr=1.0, warmup_steps=0, total_steps=10,
                      grad_clip=1.0, weight_decay=0.0)
    p = {"w": jnp.zeros((4, 4))}
    g = {"w": jnp.full((4, 4), 100.0)}
    _, stt, m = adamw_update(p, g, init_opt_state(p, cfg), cfg)
    assert float(m["grad_norm"]) == pytest.approx(400.0)
    # effective m is the clipped gradient
    assert float(jnp.max(jnp.abs(stt.m["w"]))) <= 0.1 * (100.0 / 400.0) * 1.01


def test_loss_decreases_and_microbatch_equivalence():
    cfg = get_config("stablelm-3b").reduced()
    opt = AdamWConfig(warmup_steps=2, total_steps=20)
    o1 = TrainOptions(microbatches=1, remat=False, opt=opt)
    o2 = TrainOptions(microbatches=2, remat=True, opt=opt)
    s1 = init_train_state(jax.random.PRNGKey(0), cfg, o1)
    s2 = jax.tree.map(lambda x: x, s1)
    f1 = jax.jit(make_train_step(cfg, o1))
    f2 = jax.jit(make_train_step(cfg, o2))
    ds = TokenStream(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                global_batch=8))
    losses = []
    for i in range(6):
        b = {k: jnp.asarray(v) for k, v in ds.batch(i).items()}
        s1, m1 = f1(s1, b)
        s2, m2 = f2(s2, b)
        losses.append(float(m1["loss"]))
        assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=2e-2)
    assert losses[-1] < losses[0]


def test_data_stream_deterministic_and_shifted():
    ds = TokenStream(DataConfig(vocab_size=97, seq_len=16, global_batch=4,
                                seed=3))
    a, b = ds.batch(7), ds.batch(7)
    assert (a["tokens"] == b["tokens"]).all()
    assert (a["targets"][:, :-1] == a["tokens"][:, 1:]).all()
    assert not (ds.batch(8)["tokens"] == a["tokens"]).all()


def test_checkpoint_roundtrip_bf16(tmp_path):
    cfg = get_config("qwen3-8b").reduced()
    opts = TrainOptions()
    state = init_train_state(jax.random.PRNGKey(0), cfg, opts)
    ckpt.save(str(tmp_path), 5, state)
    ckpt.save(str(tmp_path), 10, state)
    assert ckpt.latest(str(tmp_path)) == 10
    like = jax.eval_shape(lambda: state)
    restored = ckpt.restore(str(tmp_path), 10, like)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype, (a.dtype, b.dtype)
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_rolling_cleanup(tmp_path):
    tree = {"x": jnp.arange(4.0)}
    for s in (1, 2, 3, 4, 5):
        ft.save_checkpoint(str(tmp_path), s, tree, keep_last=2)
    assert ft.all_steps(str(tmp_path)) == [4, 5]


def test_preemption_handler():
    h = ft.PreemptionHandler(signals=(signal.SIGUSR1,))
    assert not h.should_drain
    os.kill(os.getpid(), signal.SIGUSR1)
    assert h.should_drain
    h.restore()


def test_straggler_monitor():
    mon = ft.StragglerMonitor(window=16, factor=2.0, patience=2)
    for _ in range(10):
        assert mon.observe(1.0) is None
    assert mon.observe(5.0) == "warn"
    assert mon.observe(5.0) == "drain"
    assert mon.observe(1.0) is None  # streak reset


def test_elastic_mesh_shapes():
    axes, used = ft.elastic_mesh_shape(512, model=16, pod_size=256)
    assert axes == {"pod": 2, "data": 16, "model": 16} and used == 512
    # lose 64 chips: one pod shrinks -> re-mesh into fewer data rows
    axes, used = ft.elastic_mesh_shape(448, model=16, pod_size=256)
    assert used <= 448 and axes["model"] == 16
    axes, used = ft.elastic_mesh_shape(240, model=16, pod_size=256)
    assert axes == {"data": 15, "model": 16} and used == 240
    with pytest.raises(ValueError):
        ft.elastic_mesh_shape(8, model=16)


def test_elastic_restart_plan(tmp_path):
    tree = {"x": jnp.arange(4.0)}
    ft.save_checkpoint(str(tmp_path), 123, tree)
    plan = ft.plan_elastic_restart(str(tmp_path), old_devices=512,
                                   surviving=448, model=16)
    assert plan.resume_step == 123
    assert plan.new_devices <= 448
    assert "re-mesh" in plan.describe()
