"""Distributed semantics on a multi-(host-)device mesh.

These run in ONE subprocess with XLA_FLAGS=--xla_force_host_platform_
device_count=8 (tests themselves must keep the main process at 1 device,
per the dry-run isolation rule). The subprocess asserts internally and
prints a marker per check.
"""
from __future__ import annotations

import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")  # subprocess cwd = repo root
import dataclasses
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

mesh = jax.make_mesh((4, 2), ("data", "model"))

# --- 1. flash decode == reference ---------------------------------------
from repro.distributed.flash_decode import SeqShard
from repro.models.attention import attention_decode
key = jax.random.PRNGKey(0)
q = jax.random.normal(key, (2, 1, 4, 16))
k = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 2, 16))
v = jax.random.normal(jax.random.PRNGKey(2), (2, 64, 2, 16))
out_s = SeqShard(mesh).decode_attention(q, k, v, jnp.int32(37))
out_r = attention_decode(q, k, v, jnp.int32(37))
assert float(jnp.max(jnp.abs(out_s - out_r))) < 1e-5
print("OK flash_decode")

# --- 2. EP (psum + a2a) == single-device MoE ------------------------------
from repro.distributed.expert_parallel import EPShard
from repro.models.moe import moe_apply, init_moe
from repro.configs import get_config
cfg = get_config("deepseek-moe-16b").reduced()
cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
params = init_moe(jax.random.PRNGKey(3), cfg, dtype=jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(4), (64, cfg.d_model))
y_ref, _ = moe_apply(params, x, cfg)
for disp in ("psum", "a2a"):
    with mesh:
        y_ep, m = EPShard(mesh, dispatch=disp).moe(params, x, cfg)
    assert float(jnp.max(jnp.abs(y_ep - y_ref))) < 1e-4, disp
    assert float(m["moe_drop_frac"]) == 0.0
print("OK expert_parallel")

# --- 3. distributed EMVS votes == single-device pipeline -----------------
from repro.core.camera import CameraModel
from repro.core.dsi import DSIConfig
from repro.core.pipeline import (EMVSOptions, precompute_segment_geometry,
                                 process_segment)
from repro.core.geometry import SE3
from repro.events.simulator import SceneConfig, make_scene, make_trajectory, simulate_events
from repro.events.aggregation import aggregate
from repro.distributed.emvs import emvs_input_specs, make_emvs_step
cam = CameraModel()
scene = make_scene(SceneConfig(points_per_plane=120))
traj = make_trajectory("simulation_3planes", 20)
ev = simulate_events(cam, scene, traj, noise_fraction=0.0)
frames = aggregate(cam, ev, traj, 1024)
dsi_cfg = DSIConfig.for_camera(cam, num_planes=16, z_min=0.6, z_max=4.5)
T_w_ref = SE3(frames.poses.R[0], frames.poses.t[0])
F = int(frames.xy.shape[0])
# pad F up to a multiple of the data axis with repeats of the last frame:
# frame_valid zeroes their votes, so no truncation is needed any more
F_pad = -(-F // 4) * 4
pad = jax.tree.map(lambda a: np.concatenate(
    [np.asarray(a)] + [np.asarray(a)[-1:]] * (F_pad - F)), frames)
frame_valid = jnp.asarray((np.arange(F_pad) < F).astype(np.float32))
planes = dsi_cfg.planes()
geoms = precompute_segment_geometry(cam, pad, T_w_ref, planes,
                                    planes[dsi_cfg.num_planes // 2])
phi = jnp.stack([geoms.phi.alpha, geoms.phi.beta_x, geoms.phi.beta_y], axis=-1)
for voting in ("nearest", "bilinear"):
    dsi_ref, dm_ref = process_segment(cam, dsi_cfg, frames, T_w_ref,
                                      EMVSOptions(formulation="matmul",
                                                  voting=voting,
                                                  median_filter=False))
    step = make_emvs_step(cam, dsi_cfg, mesh, mode=voting)
    with mesh:
        dsi_d, depth, mask, conf = step(pad.xy, pad.valid.astype(jnp.float32),
                                        frame_valid, geoms.H, phi)
    if voting == "nearest":
        # integral counts + integer psum: exact
        assert int(jnp.max(jnp.abs(dsi_d.astype(jnp.int32)
                                    - dsi_ref.astype(jnp.int32)))) == 0
    else:
        # fractional bilinear weights stay float32 through the psum
        # (regression: an integer-narrowed merge truncated them to zero
        # error ~1); only summation order differs from the reference
        assert dsi_d.dtype == jnp.float32, dsi_d.dtype
        err = float(jnp.max(jnp.abs(dsi_d - dsi_ref.astype(jnp.float32))))
        assert err < 1e-3, err
    assert bool(jnp.all(mask == dm_ref.mask)), voting
print("OK distributed_emvs")

# --- 3b. emvs_input_specs match the step signature (dry-run lowering) -----
specs = emvs_input_specs(dsi_cfg, frames=F_pad, events=int(frames.xy.shape[1]))
assert list(specs) == ["xy", "valid", "frame_valid", "H", "phi"]
assert specs["frame_valid"].shape == (F_pad,)
with mesh:
    jax.jit(make_emvs_step(cam, dsi_cfg, mesh)).lower(*specs.values())
mesh3 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
specs3 = emvs_input_specs(dsi_cfg, frames=4, events=64, segments=2)
assert all(s.shape[0] == 2 for s in specs3.values())
with mesh3:
    jax.jit(make_emvs_step(cam, dsi_cfg, mesh3, pod_axis="pod")).lower(
        *specs3.values())
print("OK emvs_input_specs")

# --- 4. sharded train step == single-device step --------------------------
from repro.training.train_step import (TrainOptions, init_train_state,
                                       make_train_step, state_specs)
from repro.training.optimizer import AdamWConfig
from repro.distributed import sharding as shd
from jax.sharding import NamedSharding
cfg2 = get_config("qwen3-8b").reduced()
opts = TrainOptions(microbatches=2, remat=True,
                    opt=AdamWConfig(warmup_steps=1, total_steps=8))
state = init_train_state(jax.random.PRNGKey(0), cfg2, opts)
batch = {"tokens": jax.random.randint(key, (8, 32), 0, cfg2.vocab_size),
         "targets": jax.random.randint(key, (8, 32), 0, cfg2.vocab_size)}
s_ref, m_ref = jax.jit(make_train_step(cfg2, opts))(
    jax.tree.map(lambda x: x, state), batch)
plan = shd.ShardingPlan.for_mesh(mesh)
sspec = state_specs(cfg2, jax.eval_shape(lambda: state), mesh, plan)
state_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), sspec,
                        is_leaf=lambda x: isinstance(x, P))
step_sharded = jax.jit(make_train_step(cfg2, opts, mesh),
                       in_shardings=(state_sh, None),
                       out_shardings=(state_sh, None))
with mesh:
    s_shd, m_shd = step_sharded(state, batch)
assert abs(float(m_ref["loss"]) - float(m_shd["loss"])) < 2e-2, (
    float(m_ref["loss"]), float(m_shd["loss"]))
print("OK sharded_train_step")

# --- 5. elastic restore onto a DIFFERENT mesh -----------------------------
import tempfile
from repro.training import checkpoint as ckpt
with tempfile.TemporaryDirectory() as d:
    ckpt.save(d, 7, s_shd)
    mesh2 = jax.make_mesh((2, 2), ("data", "model"))  # "lost" half the devices
    sspec2 = state_specs(cfg2, jax.eval_shape(lambda: state), mesh2,
                         shd.ShardingPlan.for_mesh(mesh2))
    sh2 = jax.tree.map(lambda s: NamedSharding(mesh2, s), sspec2,
                       is_leaf=lambda x: isinstance(x, P))
    restored = ckpt.restore(d, 7, jax.eval_shape(lambda: state), sh2)
    for a, b in zip(jax.tree.leaves(s_shd.params), jax.tree.leaves(restored.params)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
print("OK elastic_restore")
print("ALL_DISTRIBUTED_OK")
"""


@pytest.mark.slow
def test_distributed_suite():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=1500, env=env,
                       cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert "ALL_DISTRIBUTED_OK" in r.stdout, (
        f"STDOUT:\n{r.stdout[-3000:]}\nSTDERR:\n{r.stderr[-5000:]}")
