"""Shared fixtures. Tests run on ONE (real) device — the 512-device flag
lives only in launch/dryrun.py; distributed tests spawn subprocesses.

`hypothesis` is an optional test dependency: when it is missing we install
a minimal stub into `sys.modules` *before* collection so `@given`-based
tests are collected and skipped instead of crashing every test file that
imports it.
"""
from __future__ import annotations

import os
import sys
import types

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:
    from hypothesis import HealthCheck, settings

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised by the no-hypothesis CI job
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    settings.register_profile(
        "repro",
        max_examples=25,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    settings.load_profile("repro")
else:
    class _AnyStrategy:
        """Permissive stand-in for `hypothesis.strategies`: any attribute is
        callable and returns another _AnyStrategy, so strategy-construction
        expressions at module import time never fail."""

        def __getattr__(self, name):
            return self

        def __call__(self, *args, **kwargs):
            return self

    class _StubSettings:
        """Accepts both `@settings(...)` decoration and profile management."""

        def __init__(self, *args, **kwargs):
            pass

        def __call__(self, fn):
            return fn

        @staticmethod
        def register_profile(*args, **kwargs):
            pass

        @staticmethod
        def load_profile(*args, **kwargs):
            pass

    def _stub_given(*_args, **_kwargs):
        def decorate(fn):
            def skipped(*args, **kwargs):
                pytest.skip("hypothesis is not installed")

            skipped.__name__ = getattr(fn, "__name__", "hypothesis_test")
            skipped.__doc__ = getattr(fn, "__doc__", None)
            return skipped

        return decorate

    def _stub_assume(condition):
        return bool(condition)

    _strategies = _AnyStrategy()
    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _stub_given
    _hyp.settings = _StubSettings
    _hyp.assume = _stub_assume
    _hyp.HealthCheck = _AnyStrategy()
    _hyp.strategies = _strategies
    _st_mod = types.ModuleType("hypothesis.strategies")
    _st_mod.__getattr__ = lambda name: getattr(_strategies, name)
    sys.modules.setdefault("hypothesis", _hyp)
    sys.modules.setdefault("hypothesis.strategies", _st_mod)


@pytest.fixture(scope="session")
def cam():
    from repro.core.camera import CameraModel

    return CameraModel()


@pytest.fixture(scope="session")
def small_scene(cam):
    """Small 3-planes scene + trajectory + event frames (shared, ~seconds)."""
    from repro.events.aggregation import aggregate
    from repro.events.simulator import (
        SceneConfig,
        make_scene,
        make_trajectory,
        simulate_events,
    )

    scene = make_scene(SceneConfig(name="simulation_3planes", points_per_plane=150))
    traj = make_trajectory("simulation_3planes", 24)
    ev = simulate_events(cam, scene, traj, noise_fraction=0.0)
    frames = aggregate(cam, ev, traj, events_per_frame=1024)
    return {"scene": scene, "traj": traj, "events": ev, "frames": frames}


def rng(seed: int = 0) -> np.random.Generator:
    return np.random.default_rng(seed)
