"""Shared fixtures. Tests run on ONE (real) device — the 512-device flag
lives only in launch/dryrun.py; distributed tests spawn subprocesses."""
from __future__ import annotations

import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from hypothesis import HealthCheck, settings

settings.register_profile(
    "repro",
    max_examples=25,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)
settings.load_profile("repro")


@pytest.fixture(scope="session")
def cam():
    from repro.core.camera import CameraModel

    return CameraModel()


@pytest.fixture(scope="session")
def small_scene(cam):
    """Small 3-planes scene + trajectory + event frames (shared, ~seconds)."""
    from repro.events.aggregation import aggregate
    from repro.events.simulator import (
        SceneConfig,
        make_scene,
        make_trajectory,
        simulate_events,
    )

    scene = make_scene(SceneConfig(name="simulation_3planes", points_per_plane=150))
    traj = make_trajectory("simulation_3planes", 24)
    ev = simulate_events(cam, scene, traj, noise_fraction=0.0)
    frames = aggregate(cam, ev, traj, events_per_frame=1024)
    return {"scene": scene, "traj": traj, "events": ev, "frames": frames}


def rng(seed: int = 0) -> np.random.Generator:
    return np.random.default_rng(seed)
