"""HLO analyzer: trip-count-aware flops/collectives (the roofline's data
source) validated on known-flops programs."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import analyze_text, parse_module, multiplicities


def test_plain_matmul_flops_exact():
    f = jax.jit(lambda a, b: a @ b)
    c = f.lower(jax.ShapeDtypeStruct((256, 512), jnp.float32),
                jax.ShapeDtypeStruct((512, 128), jnp.float32)).compile()
    st = analyze_text(c.as_text())
    assert st.flops == 2 * 256 * 512 * 128


def test_scanned_matmul_flops_count_trips():
    def body(cr, w):
        return jnp.tanh(cr @ w), None

    f = jax.jit(lambda cr, ws: jax.lax.scan(body, cr, ws)[0])
    c = f.lower(jax.ShapeDtypeStruct((64, 64), jnp.float32),
                jax.ShapeDtypeStruct((9, 64, 64), jnp.float32)).compile()
    st = analyze_text(c.as_text())
    assert st.flops == 9 * 2 * 64 ** 3
    assert 9 in st.trip_counts


def test_grad_of_scan_counts_both_passes():
    def body(cr, w):
        return jnp.tanh(cr @ w), None

    f = jax.jit(jax.grad(lambda cr, ws: jax.lax.scan(body, cr, ws)[0].sum()))
    c = f.lower(jax.ShapeDtypeStruct((32, 32), jnp.float32),
                jax.ShapeDtypeStruct((5, 32, 32), jnp.float32)).compile()
    st = analyze_text(c.as_text())
    assert st.flops == 2 * 5 * 2 * 32 ** 3  # fwd + bwd-dx matmuls


def test_nested_scan_multiplicity():
    def inner(c, w):
        return c @ w, None

    def outer(c, ws):
        return jax.lax.scan(inner, c, ws)[0], None

    f = jax.jit(lambda c, wss: jax.lax.scan(outer, c, wss)[0])
    c = f.lower(jax.ShapeDtypeStruct((16, 16), jnp.float32),
                jax.ShapeDtypeStruct((3, 4, 16, 16), jnp.float32)).compile()
    st = analyze_text(c.as_text())
    assert st.flops == 3 * 4 * 2 * 16 ** 3


def test_parser_handles_tuple_signatures():
    txt = """
HloModule test

%cond (p: (s32[], f32[4])) -> pred[] {
  %p = (s32[], f32[4]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(11)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

%body (p: (s32[], f32[4])) -> (s32[], f32[4]) {
  %p = (s32[], f32[4]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[4] get-tuple-element(%p), index=1
  %ar = f32[4] all-reduce(%x), to_apply=%add
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[4]) tuple(%ip, %ar)
}

ENTRY %main (a: f32[4]) -> f32[4] {
  %a = f32[4] parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[4]) tuple(%z, %a)
  %w = (s32[], f32[4]) while(%t0), condition=%cond, body=%body
  ROOT %out = f32[4] get-tuple-element(%w), index=1
}
"""
    st = analyze_text(txt)
    assert st.collective_counts["all-reduce"] == 11
    assert st.collective_bytes == 11 * 16


def test_multiplicities_entry_is_one():
    f = jax.jit(lambda a: a * 2)
    c = f.lower(jax.ShapeDtypeStruct((4,), jnp.float32)).compile()
    comps = parse_module(c.as_text())
    mult = multiplicities(comps)
    entry = [n for n, c_ in comps.items() if c_.is_entry]
    assert mult[entry[0]] == 1.0


# ---------------------------------------------------------------------------
# Quantization-contract cross-check: the lowered sweep's stored-DSI bytes
# must match what the quant policy declares (docs/quantization_contracts.md)
# ---------------------------------------------------------------------------


def _stored_dsi_bytes_of_lowered_sweep(sweep: str) -> tuple[int, int]:
    """Lower the sweep with the int16 store as the program root (so XLA
    cannot fold the narrow tensor away) and return (hlo_bytes, predicted)."""
    from repro.core.camera import CameraModel
    from repro.core.dsi import DSIConfig
    from repro.core import dsi as dsi_lib
    from repro.core.pipeline import EMVSOptions, sweep_trace_spec
    from repro.quant.policies import TABLE1

    cam = CameraModel(width=32, height=24, cx=15.5, cy=11.5)
    dsi_cfg = DSIConfig.for_camera(cam, num_planes=8)
    opts = EMVSOptions(voting="nearest", formulation="matmul", quantized=True)
    segments, capacity, events = 2, 4, 16
    fn, args, _ = sweep_trace_spec(
        cam, dsi_cfg, opts, segments=segments, capacity=capacity,
        events=events, sweep=sweep,
    )
    g = jax.jit(lambda b: dsi_lib.to_storage(fn(b)[0]))
    c = g.lower(*args).compile()
    comps = parse_module(c.as_text())
    entry = next(c_ for c_ in comps.values() if c_.is_entry)

    dsi_shape = (segments, *dsi_cfg.shape)
    hlo_bytes = 0
    for shapes in entry.symbols.values():
        for dtype, dims in shapes:
            if dtype == "s16" and dims == dsi_shape:
                n = 1
                for d in dims:
                    n *= d
                hlo_bytes = max(hlo_bytes, n * 2)

    fmt = TABLE1.declared_formats()["dsi"]
    assert fmt.total_bits % 8 == 0 and fmt.signed
    n = 1
    for d in dsi_shape:
        n *= d
    predicted = n * (fmt.total_bits // 8)
    return hlo_bytes, predicted


def test_batched_sweep_stored_dsi_bytes_match_quant_policy():
    hlo_bytes, predicted = _stored_dsi_bytes_of_lowered_sweep("batched")
    assert hlo_bytes == predicted != 0


def test_sharded_sweep_stored_dsi_bytes_match_quant_policy():
    hlo_bytes, predicted = _stored_dsi_bytes_of_lowered_sweep("sharded")
    assert hlo_bytes == predicted != 0


# ---------------------------------------------------------------------------
# Fused-kernel store contract: under quantized=True + formulation="kernel"
# the int16 DSI must be produced INSIDE the pallas_call (in-VMEM saturating
# store) with NO post-kernel storage_roundtrip left in the program.
# ---------------------------------------------------------------------------


def _walk_eqns(jaxpr, in_pallas=False):
    """Yield (eqn, in_pallas) over a jaxpr and every nested sub-jaxpr."""
    from jax._src import core as jcore

    for eqn in jaxpr.eqns:
        yield eqn, in_pallas
        inside = in_pallas or eqn.primitive.name == "pallas_call"
        for val in eqn.params.values():
            subs = val if isinstance(val, (tuple, list)) else (val,)
            for sub in subs:
                if isinstance(sub, jcore.ClosedJaxpr):
                    yield from _walk_eqns(sub.jaxpr, inside)
                elif isinstance(sub, jcore.Jaxpr):
                    yield from _walk_eqns(sub, inside)


def _int16_convert_census(formulation: str):
    """(converts-to-int16 outside pallas, inside pallas, pallas has s16 out)."""
    from repro.core.camera import CameraModel
    from repro.core.dsi import DSIConfig
    from repro.core.pipeline import EMVSOptions, sweep_trace_spec

    cam = CameraModel(width=32, height=24, cx=15.5, cy=11.5)
    dsi_cfg = DSIConfig.for_camera(cam, num_planes=8)
    opts = EMVSOptions(voting="nearest", formulation=formulation,
                       quantized=True, kernel_interpret=True)
    fn, args, _ = sweep_trace_spec(cam, dsi_cfg, opts, segments=1,
                                   capacity=4, events=16)
    jaxpr = jax.make_jaxpr(fn)(*args).jaxpr

    outside = inside = 0
    pallas_s16_out = False
    for eqn, in_pallas in _walk_eqns(jaxpr):
        if eqn.primitive.name == "pallas_call":
            for ov in eqn.outvars:
                if (ov.aval.dtype == jnp.int16 and ov.aval.ndim >= 3):
                    pallas_s16_out = True
        if eqn.primitive.name == "convert_element_type":
            if eqn.params.get("new_dtype") == jnp.int16:
                if in_pallas:
                    inside += 1
                else:
                    outside += 1
    return outside, inside, pallas_s16_out


def test_quantized_kernel_sweep_stores_int16_in_vmem_no_roundtrip():
    outside, inside, pallas_s16 = _int16_convert_census("kernel")
    assert pallas_s16, "pallas_call must emit the int16 DSI directly"
    assert inside >= 1, "fused saturating store missing from kernel body"
    assert outside == 0, (
        f"{outside} float->int16 convert(s) outside the pallas body: the "
        "quantized kernel path has regrown a post-kernel HBM storage "
        "round-trip")


def test_quantized_matmul_sweep_still_roundtrips_outside():
    """Positive control: the unfused XLA formulation stores via the
    explicit storage_roundtrip, so the census must see it (proves the
    detector in the test above can actually catch a regression)."""
    outside, inside, pallas_s16 = _int16_convert_census("matmul")
    assert outside >= 1 and inside == 0 and not pallas_s16


def test_emvs_fusion_ladder_strictly_closer_to_bound():
    """Acceptance gate: every fusion rung sits strictly closer to the
    roofline bound than the previous one, with identical flops (fusion
    only deletes HBM traffic)."""
    import os
    import sys

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                    "benchmarks"))
    try:
        from roofline_report import fusion_report
    finally:
        sys.path.pop(0)

    rep = fusion_report()
    assert rep["violations"] == []
    names = [s["name"] for s in rep["stages"]]
    assert names == ["unfused", "fused-store", "fused-detect"]
    gaps = [s["bound_gap"] for s in rep["stages"]]
    hbm = [s["hbm_bytes"] for s in rep["stages"]]
    assert gaps[0] > gaps[1] > gaps[2] >= 1.0
    assert hbm[0] > hbm[1] > hbm[2]
    assert len({s["flops"] for s in rep["stages"]}) == 1
