"""BENCH_emvs.json writer contracts: atomic replace + dry-run isolation.

`update_bench_json` is shared by every benchmark and read by the CI
gates, so its two hygiene rules get their own tests: a crashing or
concurrent write can never tear the file (tempfile + os.replace), and a
`--dry-run` record can never overwrite a full-size record at the top
level (it lands under the "dry_run" namespace; legacy top-level dry-run
records migrate there on the next write). `read_bench_section` is the
matching lookup: full-run records first, the namespace as fallback.
"""
from __future__ import annotations

import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                "benchmarks"))
from _emvs_common import read_bench_section, update_bench_json  # noqa: E402


def _load(path):
    with open(path) as f:
        return json.load(f)


def test_sections_merge_and_survive(tmp_path):
    path = str(tmp_path / "bench.json")
    update_bench_json("alpha", {"x": 1}, path=path)
    update_bench_json("beta", {"y": 2}, path=path)
    data = _load(path)
    assert data["alpha"] == {"x": 1} and data["beta"] == {"y": 2}
    # overwriting one section leaves the other intact
    update_bench_json("alpha", {"x": 3}, path=path)
    data = _load(path)
    assert data["alpha"] == {"x": 3} and data["beta"] == {"y": 2}


def test_write_is_atomic_no_temp_droppings(tmp_path):
    """The target is replaced in one os.replace: no partial writes left
    behind, and the tempfile is cleaned up on every path."""
    path = str(tmp_path / "bench.json")
    update_bench_json("alpha", {"x": list(range(1000))}, path=path)
    assert [p.name for p in tmp_path.iterdir()] == ["bench.json"]
    # a reader mid-update sees either the old or the new file — never a
    # torn one; simulate by re-writing and checking full validity
    update_bench_json("alpha", {"x": 0}, path=path)
    assert _load(path)["alpha"] == {"x": 0}
    assert [p.name for p in tmp_path.iterdir()] == ["bench.json"]


def test_unserializable_record_leaves_file_intact(tmp_path):
    path = str(tmp_path / "bench.json")
    update_bench_json("alpha", {"x": 1}, path=path)
    with pytest.raises(TypeError):
        update_bench_json("beta", {"bad": object()}, path=path)
    # the failed write neither corrupted the file nor left a tempfile
    assert _load(path) == {"alpha": {"x": 1}}
    assert [p.name for p in tmp_path.iterdir()] == ["bench.json"]


def test_corrupt_file_is_replaced_not_fatal(tmp_path):
    path = str(tmp_path / "bench.json")
    with open(path, "w") as f:
        f.write('{"alpha": {  TORN MID-WRITE')
    update_bench_json("beta", {"y": 2}, path=path)
    assert _load(path) == {"beta": {"y": 2}}


def test_dry_run_records_cannot_shadow_full_runs(tmp_path):
    """A dry-run record lands under data["dry_run"][section]; the
    full-size record at data[section] is untouched — the smoke can no
    longer poison the tracked perf trajectory."""
    path = str(tmp_path / "bench.json")
    update_bench_json("sweep", {"dry_run": False, "segs_per_s": 100.0},
                      path=path)
    update_bench_json("sweep", {"dry_run": True, "segs_per_s": 3.0},
                      path=path)
    data = _load(path)
    assert data["sweep"]["segs_per_s"] == 100.0
    assert data["dry_run"]["sweep"]["segs_per_s"] == 3.0
    # read-back prefers the full-size record...
    assert read_bench_section("sweep", path=path)["segs_per_s"] == 100.0
    # ...and falls back to the namespace when no full run exists yet
    update_bench_json("smoke_only", {"dry_run": True, "v": 1}, path=path)
    assert read_bench_section("smoke_only", path=path) == {"dry_run": True,
                                                           "v": 1}
    assert read_bench_section("missing", path=path) is None


def test_legacy_top_level_dry_run_records_migrate(tmp_path):
    """Pre-namespace files have dry-run records at the top level (the
    committed BENCH_emvs.json regression); the next write moves them."""
    path = str(tmp_path / "bench.json")
    with open(path, "w") as f:
        json.dump({"old_sweep": {"dry_run": True, "v": 1},
                   "full_sweep": {"dry_run": False, "v": 2}}, f)
    update_bench_json("new", {"v": 3}, path=path)
    data = _load(path)
    assert "old_sweep" not in data
    assert data["dry_run"]["old_sweep"] == {"dry_run": True, "v": 1}
    assert data["full_sweep"]["v"] == 2  # full runs stay at the top level
    assert data["new"] == {"v": 3}
