"""End-to-end EMVS integration: the paper's accuracy claims.

  * Fig 4a: nearest vs bilinear voting AbsRel gap is small (~1%-level)
  * Fig 4b: Table-1 quantized vs float AbsRel gap is small
  * all three voting formulations land on the same depth map
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dsi import DSIConfig
from repro.core.geometry import SE3
from repro.core.pipeline import EMVSOptions, process_segment, run_emvs, segment_keyframes
from repro.events.simulator import absrel, ground_truth_depth


@pytest.fixture(scope="module")
def dsi_cfg(cam):
    return DSIConfig.for_camera(cam, num_planes=32, z_min=0.6, z_max=4.5)


def _first_segment(frames):
    return jax.tree.map(lambda a: a[: min(8, a.shape[0])], frames)


def _absrel_for(cam, dsi_cfg, small_scene, opts) -> float:
    frames = _first_segment(small_scene["frames"])
    T_w_ref = SE3(frames.poses.R[0], frames.poses.t[0])
    _, dm = process_segment(cam, dsi_cfg, frames, T_w_ref, opts)
    gt, gtm = ground_truth_depth(cam, small_scene["scene"], T_w_ref)
    return float(absrel(dm.depth, dm.mask, gt, gtm))


def test_reconstruction_reasonable(cam, dsi_cfg, small_scene):
    err = _absrel_for(cam, dsi_cfg, small_scene, EMVSOptions())
    assert err < 0.25, f"AbsRel {err} too high for a clean synthetic scene"


def test_formulations_agree(cam, dsi_cfg, small_scene):
    frames = _first_segment(small_scene["frames"])
    T_w_ref = SE3(frames.poses.R[0], frames.poses.t[0])
    outs = {}
    for f in ("scatter", "matmul", "kernel"):
        dsi, dm = process_segment(cam, dsi_cfg, frames, T_w_ref,
                                  EMVSOptions(formulation=f))
        outs[f] = (np.asarray(dsi, np.float32), np.asarray(dm.depth),
                   np.asarray(dm.mask))
    np.testing.assert_allclose(outs["scatter"][0], outs["matmul"][0], atol=1e-3)
    assert (outs["scatter"][2] == outs["matmul"][2]).all()
    # fused kernel path on the integer (nearest) datapath: bitwise —
    # votes are integral f32 accumulations, and the in-kernel projection
    # now runs the same traced ops as project_frame.
    np.testing.assert_array_equal(outs["matmul"][0], outs["kernel"][0])
    np.testing.assert_array_equal(outs["matmul"][1], outs["kernel"][1])
    np.testing.assert_array_equal(outs["matmul"][2], outs["kernel"][2])


@pytest.mark.parametrize("voting", ["nearest", "bilinear"])
def test_formulations_agree_quantized(cam, dsi_cfg, small_scene, voting):
    """Regression for the headline divergence bug: under quantized=True
    the kernel path used to skip the Table-1 int8 plane-coord
    quantization that project_frame applies, silently shifting votes.
    The quantized datapath is integer end-to-end (int16 store), so all
    three formulations must agree BITWISE — including depth and mask."""
    frames = _first_segment(small_scene["frames"])
    T_w_ref = SE3(frames.poses.R[0], frames.poses.t[0])
    outs = {}
    for f in ("scatter", "matmul", "kernel"):
        dsi, dm = process_segment(
            cam, dsi_cfg, frames, T_w_ref,
            EMVSOptions(formulation=f, voting=voting, quantized=True))
        outs[f] = (np.asarray(dsi, np.float32), np.asarray(dm.depth),
                   np.asarray(dm.mask))
    for other in ("scatter", "kernel"):
        for i, what in enumerate(("dsi", "depth", "mask")):
            np.testing.assert_array_equal(
                outs["matmul"][i], outs[other][i],
                err_msg=f"{other} vs matmul diverges on {what} ({voting})")


def test_nearest_vs_bilinear_gap_small(cam, dsi_cfg, small_scene):
    """Paper Fig 4a: max AbsRel difference ~1.18% (abs gap in error)."""
    e_near = _absrel_for(cam, dsi_cfg, small_scene, EMVSOptions(voting="nearest"))
    e_bil = _absrel_for(cam, dsi_cfg, small_scene, EMVSOptions(voting="bilinear"))
    assert abs(e_near - e_bil) < 0.04, (e_near, e_bil)


def test_quantized_vs_float_gap_small(cam, dsi_cfg, small_scene):
    """Paper Fig 4b: quantization costs ~1% AbsRel."""
    e_f = _absrel_for(cam, dsi_cfg, small_scene, EMVSOptions(quantized=False))
    e_q = _absrel_for(cam, dsi_cfg, small_scene, EMVSOptions(quantized=True))
    assert abs(e_f - e_q) < 0.04, (e_f, e_q)


def test_keyframe_segmentation(small_scene):
    segs = segment_keyframes(small_scene["frames"].poses, mean_depth=2.0,
                             frac=0.05)
    # covers all frames, in order, non-overlapping
    f = small_scene["frames"].xy.shape[0]
    assert segs[0][0] == 0 and segs[-1][1] == f
    for (a, b), (c, d) in zip(segs, segs[1:]):
        assert b == c and a < b
    # smaller threshold -> at least as many segments
    segs2 = segment_keyframes(small_scene["frames"].poses, mean_depth=2.0,
                              frac=0.02)
    assert len(segs2) >= len(segs)


def test_run_emvs_end_to_end(cam, dsi_cfg, small_scene):
    res = run_emvs(cam, dsi_cfg, small_scene["frames"],
                   EMVSOptions(keyframe_dist_frac=0.05))
    assert len(res.segments) >= 1
    assert len(res.clouds) == len(res.segments)
    for seg, cloud in zip(res.segments, res.clouds):
        assert seg.depth_map.depth.shape == (cam.height, cam.width)
        n_pts = int(seg.depth_map.mask.sum())
        assert int(cloud.valid.sum()) == n_pts  # cloud mirrors the mask


def test_int16_dsi_never_saturates_in_practice(cam, dsi_cfg, small_scene):
    """Paper's implicit claim behind Table-1's int16 DSI scores: real
    key-frame segments never clip 16 bits (max votes/voxel is bounded by
    the events between key frames)."""
    from repro.core import dsi as dsi_lib

    frames = _first_segment(small_scene["frames"])
    T_w_ref = SE3(frames.poses.R[0], frames.poses.t[0])
    dsi, _ = process_segment(cam, dsi_cfg, frames, T_w_ref,
                             EMVSOptions(quantized=True))
    assert float(dsi_lib.saturation_fraction(dsi.astype("int32"))) == 0.0
