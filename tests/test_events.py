"""Event pipeline: simulator, streaming rectification, aggregation."""
from __future__ import annotations

import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.camera import CameraModel, in_bounds_mask, undistort_events, distort_normalized
from repro.events.aggregation import (
    PARKED_COORD,
    PoseExtrapolationWarning,
    StreamingAggregator,
    aggregate,
    empty_event_frames,
    pose_at_times,
)
from repro.events.simulator import EventStream
from repro.events.simulator import (
    SceneConfig,
    absrel,
    ground_truth_depth,
    make_scene,
    make_trajectory,
    simulate_events,
)


def test_event_stream_sorted_and_masked(cam, small_scene):
    ev = small_scene["events"]
    t = np.asarray(ev.t)
    assert (np.diff(t) >= 0).all()
    xy = np.asarray(ev.xy)
    v = np.asarray(ev.valid)
    assert (xy[~v] == -1e4).all()  # parked
    inb = (xy[v][:, 0] >= 0) & (xy[v][:, 0] <= cam.width - 1)
    assert inb.all()


def test_aggregation_shapes_and_poses(cam, small_scene):
    frames = small_scene["frames"]
    F, E, _ = frames.xy.shape
    assert E == 1024
    assert frames.poses.R.shape == (F, 3, 3)
    # frame mid-times increase
    assert (np.diff(np.asarray(frames.t_mid)) > 0).all()


def test_aggregate_keeps_tail(cam, small_scene):
    """The stream's tail must become a final padded frame, not be dropped."""
    ev, traj = small_scene["events"], small_scene["traj"]
    n = int(ev.t.shape[0])
    assert n % 1024 != 0, "fixture must leave a partial tail"
    frames = aggregate(cam, ev, traj, events_per_frame=1024)
    assert frames.xy.shape[0] == -(-n // 1024)  # ceil: tail kept
    dropped = aggregate(cam, ev, traj, events_per_frame=1024, keep_tail=False)
    assert dropped.xy.shape[0] == n // 1024  # the seed's behavior, opt-in
    # tail frame: real events first, then parked invalid padding
    r = n % 1024
    tail_xy = np.asarray(frames.xy[-1])
    tail_valid = np.asarray(frames.valid[-1])
    np.testing.assert_array_equal(tail_xy[:r], np.asarray(ev.xy[-r:]))
    assert (tail_xy[r:] == PARKED_COORD).all()
    assert not tail_valid[r:].any()
    # every frame before the tail is untouched by the fix
    np.testing.assert_array_equal(np.asarray(frames.xy[:-1]),
                                  np.asarray(dropped.xy))


def test_streaming_aggregator_carries_remainder(cam, small_scene):
    """Ragged pushes: remainder events cross chunk boundaries, none lost."""
    ev, traj = small_scene["events"], small_scene["traj"]
    n = int(ev.t.shape[0])
    agg = StreamingAggregator(cam, traj, events_per_frame=1024)
    sizes = [700, 1311, 257, 2048]
    parts, i, k = [], 0, 0
    while i < n:
        j = min(i + sizes[k % len(sizes)], n)
        parts.append(agg.push(EventStream(
            xy=ev.xy[i:j], t=ev.t[i:j],
            polarity=ev.polarity[i:j], valid=ev.valid[i:j])))
        i, k = j, k + 1
    assert agg.pending_events == n % 1024
    parts.append(agg.flush())
    assert agg.pending_events == 0
    got_xy = np.concatenate([np.asarray(p.xy) for p in parts])
    ref = small_scene["frames"]
    assert got_xy.shape[0] == -(-n // 1024)
    np.testing.assert_array_equal(got_xy, np.asarray(ref.xy))
    np.testing.assert_array_equal(
        np.concatenate([np.asarray(p.t_mid) for p in parts]),
        np.asarray(ref.t_mid))
    np.testing.assert_array_equal(
        np.concatenate([np.asarray(p.poses.t) for p in parts]),
        np.asarray(ref.poses.t))


def test_aggregator_max_stall_backpressure(cam, small_scene):
    """Pose-gated mode with `max_stalled`: a push that leaves more than
    the bound stalled raises PoseStallError AFTER buffering the frames,
    so nothing is lost and pushing the poses drains bit-identically."""
    from repro.events.aggregation import PoseStallError, TrajectoryBuffer

    ev, traj = small_scene["events"], small_scene["traj"]
    ref = small_scene["frames"]
    e, bound = 1024, 2
    agg = StreamingAggregator(cam, TrajectoryBuffer(), events_per_frame=e,
                              max_stalled=bound)
    n = (bound + 2) * e  # enough events to overflow the bound in one push
    chunk = EventStream(xy=ev.xy[:n], t=ev.t[:n],
                        polarity=ev.polarity[:n], valid=ev.valid[:n])
    with pytest.raises(PoseStallError, match=f"max_stalled={bound}"):
        agg.push(chunk)
    # every completed frame was buffered before the raise: the late pose
    # chunk releases them all, posed bit-identically to the offline path
    assert agg.stalled_frames == bound + 2
    released = agg.push_poses(traj)
    assert agg.stalled_frames == 0
    np.testing.assert_array_equal(np.asarray(released.xy),
                                  np.asarray(ref.xy[:bound + 2]))
    np.testing.assert_array_equal(np.asarray(released.poses.t),
                                  np.asarray(ref.poses.t[:bound + 2]))
    # drained below the bound: the event stream may resume
    agg.push(EventStream(xy=ev.xy[n:n + e], t=ev.t[n:n + e],
                         polarity=ev.polarity[n:n + e],
                         valid=ev.valid[n:n + e]))
    with pytest.raises(ValueError, match="max_stalled"):
        StreamingAggregator(cam, TrajectoryBuffer(), max_stalled=0)

    # Only frames the current watermark CANNOT release count toward the
    # bound, and the check precedes the release: a push whose backlog
    # fits must return the releasable frames (not raise, not drop them).
    from repro.events.simulator import slice_trajectory

    agg2 = StreamingAggregator(cam, TrajectoryBuffer(), events_per_frame=e,
                               max_stalled=bound)
    t_mid_all = np.asarray(ref.t_mid)
    times = np.asarray(traj.times)
    # poses covering the first 3 frame mid-times strictly
    hi = int(np.searchsorted(times, t_mid_all[2], side="right")) + 1
    agg2.push_poses(slice_trajectory(traj, 0, hi))
    wm = agg2.pose_watermark
    releasable = int((t_mid_all[:bound + 2] < wm).sum())
    assert releasable >= 3 and (bound + 2) - releasable <= bound, \
        "fixture: the backlog must fit the bound for this scenario"
    released = agg2.push(chunk)  # same 4 frames as above — no raise now
    assert released.xy.shape[0] == releasable
    np.testing.assert_array_equal(np.asarray(released.poses.t),
                                  np.asarray(ref.poses.t[:releasable]))
    assert agg2.stalled_frames == (bound + 2) - releasable


def test_aggregate_empty_stream(cam, small_scene):
    traj = small_scene["traj"]
    ev = EventStream(xy=jnp.zeros((0, 2)), t=jnp.zeros((0,)),
                     polarity=jnp.zeros((0,), jnp.int8),
                     valid=jnp.zeros((0,), bool))
    frames = aggregate(cam, ev, traj, events_per_frame=64)
    assert frames.xy.shape == (0, 64, 2)
    assert empty_event_frames(64).xy.shape == (0, 64, 2)


def test_pose_interpolation_monotone(small_scene):
    traj = small_scene["traj"]
    q = jnp.linspace(0.05, 0.95, 7)
    poses = pose_at_times(traj, q)
    # x-translation follows the trajectory's smooth arc: bounded by extremes
    tx = np.asarray(poses.t[:, 0])
    lo, hi = np.asarray(traj.poses.t[:, 0]).min(), np.asarray(traj.poses.t[:, 0]).max()
    assert (tx >= lo - 1e-5).all() and (tx <= hi + 1e-5).all()


def test_aggregate_pose_extrapolation_policies(cam, small_scene):
    """Offline aggregation no longer freezes out-of-span poses silently:
    the default warns (clamped numerics kept for equivalence), "raise"
    refuses, and the seed's silent clamp needs explicit opt-in."""
    from repro.events.aggregation import PoseExtrapolationError
    from repro.events.simulator import Trajectory

    ev = small_scene["events"]
    traj = small_scene["traj"]
    # truncate the trajectory so the stream's tail lies beyond the poses
    times = np.asarray(traj.times)
    cut = int(times.shape[0]) // 2
    short = Trajectory(times=traj.times[:cut],
                       poses=type(traj.poses)(traj.poses.R[:cut],
                                              traj.poses.t[:cut]))
    with pytest.warns(PoseExtrapolationWarning, match="outside the trajectory"):
        warned = aggregate(cam, ev, short, events_per_frame=1024)
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # "clamp" must stay silent
        clamped = aggregate(cam, ev, short, events_per_frame=1024,
                            pose_extrapolation="clamp")
    # the warning changes visibility, never numerics (seed equivalence)
    np.testing.assert_array_equal(np.asarray(warned.poses.R),
                                  np.asarray(clamped.poses.R))
    np.testing.assert_array_equal(np.asarray(warned.poses.t),
                                  np.asarray(clamped.poses.t))
    with pytest.raises(PoseExtrapolationError, match="outside the trajectory"):
        aggregate(cam, ev, short, events_per_frame=1024,
                  pose_extrapolation="raise")
    with pytest.raises(ValueError, match="unknown pose_extrapolation"):
        aggregate(cam, ev, short, events_per_frame=1024,
                  pose_extrapolation="freeze")


def test_undistort_inverts_distortion():
    cam = CameraModel(k1=-0.35, k2=0.15, p1=0.001, p2=-0.0005)
    rng = np.random.default_rng(0)
    xy_true = jnp.asarray(rng.uniform((40, 40), (200, 140), (256, 2))
                          .astype(np.float32))
    xn = (xy_true[:, 0] - cam.cx) / cam.fx
    yn = (xy_true[:, 1] - cam.cy) / cam.fy
    xd, yd = distort_normalized(cam, xn, yn)
    xy_d = jnp.stack([xd * cam.fx + cam.cx, yd * cam.fy + cam.cy], axis=-1)
    xy_u = undistort_events(cam, xy_d)
    np.testing.assert_allclose(np.asarray(xy_u), np.asarray(xy_true), atol=0.05)


def test_ground_truth_depth_zbuffer(cam):
    # two points on the same pixel: nearer one wins
    pts = np.array([[0.0, 0.0, 2.0], [0.0, 0.0, 1.0]], np.float32)
    from repro.core.geometry import SE3

    d, m = ground_truth_depth(cam, pts, SE3.identity())
    yx = int(cam.cy), int(cam.cx)
    assert bool(m[yx])
    assert abs(float(d[yx]) - 1.0) < 1e-5


def test_absrel_metric():
    d = jnp.array([[1.0, 2.0]])
    gt = jnp.array([[2.0, 2.0]])
    m = jnp.array([[True, True]])
    assert abs(float(absrel(d, m, gt, m)) - 0.25) < 1e-6
    # masked-out pixels don't contribute
    m2 = jnp.array([[True, False]])
    assert abs(float(absrel(d, m2, gt, m2)) - 0.5) < 1e-6


def test_all_four_sequences_generate(cam):
    for name in ("simulation_3planes", "simulation_3walls", "slider_close",
                 "slider_far"):
        scene = make_scene(SceneConfig(name=name, points_per_plane=60))
        traj = make_trajectory(name, 8)
        ev = simulate_events(cam, scene, traj, noise_fraction=0.05, seed=1)
        assert bool(ev.valid.any()), name
        frac_valid = float(ev.valid.mean())
        assert frac_valid > 0.3, (name, frac_valid)


# --- ingest validation: the sorted/contiguous contract is enforced --------


def test_aggregator_push_rejects_non_monotone_naming_index(cam, small_scene):
    """A chunk with an intra-chunk timestamp regression must be rejected
    with a ValueError naming the first offending event index — not
    silently mis-binned into frames."""
    from repro.events.stream_hygiene import NonMonotoneEventError

    traj = small_scene["traj"]
    agg = StreamingAggregator(cam, traj, events_per_frame=64)
    t = np.float32([0.10, 0.11, 0.09, 0.12])
    bad = EventStream(xy=jnp.zeros((4, 2), jnp.float32), t=jnp.asarray(t),
                      polarity=jnp.ones((4,), jnp.int8),
                      valid=jnp.ones((4,), bool))
    with pytest.raises(NonMonotoneEventError, match=r"event 2 at"):
        agg.push(bad)
    assert isinstance(NonMonotoneEventError("x"), ValueError)


def test_aggregator_push_rejects_overlapping_chunks(cam, small_scene):
    """A chunk that regresses behind the previous push's last timestamp
    overlaps time already committed — a typed ValueError, state intact."""
    from repro.events.stream_hygiene import StreamOverlapError

    ev, traj = small_scene["events"], small_scene["traj"]
    agg = StreamingAggregator(cam, traj, events_per_frame=64)

    def part(i, j):
        return EventStream(xy=ev.xy[i:j], t=ev.t[i:j],
                           polarity=ev.polarity[i:j], valid=ev.valid[i:j])

    agg.push(part(0, 256))
    with pytest.raises(StreamOverlapError, match="watermark"):
        agg.push(part(128, 384))  # replays times 128..255
    agg.push(part(256, 512))  # the rejection did not poison the stream
    assert agg.pending_events == 512 % 64


def test_offline_aggregate_rejects_unsorted_stream(cam, small_scene):
    """aggregate() shares push()'s validation: an unsorted stream is a
    loud error, not a silently scrambled frame tensor."""
    ev, traj = small_scene["events"], small_scene["traj"]
    perm = np.arange(int(ev.t.shape[0]))
    perm[10], perm[20] = perm[20], perm[10]
    bad = EventStream(xy=ev.xy[perm], t=ev.t[perm],
                      polarity=ev.polarity[perm], valid=ev.valid[perm])
    with pytest.raises(ValueError, match="non-monotone"):
        aggregate(cam, bad, traj, events_per_frame=64)


# --- chunk iterators: edge cases + bitwise reassembly ---------------------


def test_iter_event_chunks_edge_cases(cam, small_scene):
    from repro.serving.emvs_stream import iter_event_chunks

    ev = small_scene["events"]
    n = int(ev.t.shape[0])
    # empty stream -> no chunks at all
    empty = EventStream(xy=ev.xy[:0], t=ev.t[:0],
                        polarity=ev.polarity[:0], valid=ev.valid[:0])
    assert list(iter_event_chunks(empty, 128)) == []
    # chunk larger than the stream -> exactly one chunk, the whole stream
    whole = list(iter_event_chunks(ev, n + 999))
    assert len(whole) == 1 and int(whole[0].t.shape[0]) == n
    # ragged tail: n % chunk != 0 -> last chunk carries the remainder
    chunk = 257
    assert n % chunk != 0, "fixture must leave a ragged tail"
    parts = list(iter_event_chunks(ev, chunk))
    assert [int(p.t.shape[0]) for p in parts[:-1]] == [chunk] * (len(parts) - 1)
    assert int(parts[-1].t.shape[0]) == n % chunk
    # bitwise reassembly: concatenating the chunks is the identity
    for field in ("xy", "t", "polarity", "valid"):
        np.testing.assert_array_equal(
            np.concatenate([np.asarray(getattr(p, field)) for p in parts]),
            np.asarray(getattr(ev, field)))
    # invalid sizes are loud
    for sz in (0, -1, 1.5, True):
        with pytest.raises(ValueError):
            list(iter_event_chunks(ev, sz))


def test_iter_trajectory_chunks_edge_cases(small_scene):
    from repro.events.simulator import Trajectory, iter_trajectory_chunks
    from repro.core.geometry import SE3

    traj = small_scene["traj"]
    n = int(traj.times.shape[0])
    # empty trajectory -> no chunks
    empty = Trajectory(times=traj.times[:0],
                       poses=SE3(traj.poses.R[:0], traj.poses.t[:0]))
    assert list(iter_trajectory_chunks(empty, 4)) == []
    # chunk larger than the trajectory -> one chunk, everything
    whole = list(iter_trajectory_chunks(traj, n + 5))
    assert len(whole) == 1 and int(whole[0].times.shape[0]) == n
    # ragged tail + bitwise reassembly
    chunk = 5
    assert n % chunk != 0, "fixture must leave a ragged tail"
    parts = list(iter_trajectory_chunks(traj, chunk))
    assert int(parts[-1].times.shape[0]) == n % chunk
    np.testing.assert_array_equal(
        np.concatenate([np.asarray(p.times) for p in parts]),
        np.asarray(traj.times))
    np.testing.assert_array_equal(
        np.concatenate([np.asarray(p.poses.R) for p in parts]),
        np.asarray(traj.poses.R))
    np.testing.assert_array_equal(
        np.concatenate([np.asarray(p.poses.t) for p in parts]),
        np.asarray(traj.poses.t))
    with pytest.raises(ValueError, match="chunk_poses"):
        list(iter_trajectory_chunks(traj, 0))
