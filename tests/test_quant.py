"""Fixed-point quantization: bit-exactness vs a pure-Python integer model
(hypothesis), roundtrip bounds, saturation, and the Table-1 policy."""
from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.quant.fixed_point import (
    INT8,
    INT16,
    Q9_7,
    Q11_21,
    FixedPointFormat,
    dequantize,
    quantize,
    quantize_roundtrip,
    storage_bytes,
)
from repro.quant.policies import TABLE1, memory_report

FORMATS = [Q9_7, Q11_21, INT8, INT16]


def python_int_model(x: float, fmt: FixedPointFormat) -> int:
    """Reference: round-half-away-from-zero + saturate, in exact Python."""
    scaled = x * (2 ** fmt.frac_bits)
    q = math.floor(abs(scaled) + 0.5)
    q = int(math.copysign(q, scaled))
    return max(fmt.q_min, min(fmt.q_max, q))


@given(
    x=st.floats(min_value=-4096, max_value=4096, allow_nan=False,
                width=32),
    fmt_ix=st.integers(0, len(FORMATS) - 1),
)
def test_quantize_matches_python_int_model(x, fmt_ix):
    fmt = FORMATS[fmt_ix]
    got = int(quantize(jnp.float32(x), fmt))
    want = python_int_model(np.float32(x), fmt)
    # fp32 scaling can land exactly on .5 boundaries differently than exact
    # arithmetic for huge Q11.21 values; allow 1 ulp there only
    assert abs(got - want) <= (1 if fmt.frac_bits >= 21 else 0), (x, fmt)


@given(x=st.floats(min_value=-255, max_value=255, allow_nan=False, width=32))
def test_roundtrip_error_within_half_lsb(x):
    for fmt in (Q9_7, Q11_21):
        err = abs(float(quantize_roundtrip(jnp.float32(x), fmt)) - np.float32(x))
        assert err <= fmt.lsb / 2 + 1e-6, (x, fmt)


def test_saturation():
    assert int(quantize(jnp.float32(1e9), Q9_7)) == Q9_7.q_max
    assert int(quantize(jnp.float32(-1e9), Q9_7)) == Q9_7.q_min
    assert int(quantize(jnp.float32(-5.0), INT8)) == 0  # unsigned floor
    assert int(quantize(jnp.float32(300.0), INT8)) == INT8.q_max


def test_formats_match_paper_table1():
    assert Q9_7 == FixedPointFormat(16, 7)
    assert Q11_21 == FixedPointFormat(32, 21)
    assert INT8.total_bits == 8 and INT8.frac_bits == 0 and not INT8.signed
    assert INT16.total_bits == 16 and INT16.frac_bits == 0
    assert storage_bytes(1024 * 2, Q9_7) == 4096  # 16-bit pairs -> 32b words


def test_plane_coord_park_at_max():
    """Out-of-range plane coords must park at q_max (a miss), never alias
    to pixel 0 (a fabricated vote)."""
    x = jnp.array([-3.0, -0.6, 0.0, 120.4, 255.0, 300.0], jnp.float32)
    qx, qy = TABLE1.quantize_plane_coords(x, x)
    assert float(qx[0]) == INT8.q_max  # negative -> park
    assert float(qx[1]) == INT8.q_max
    assert float(qx[2]) == 0.0
    assert float(qx[3]) == 120.0
    assert float(qx[4]) == 255.0
    assert float(qx[5]) == INT8.q_max


def test_memory_report_50pct_claim(cam):
    """Paper §2.3: hybrid quantization saves ~50% of memory/bandwidth."""
    rep = memory_report(cam, num_planes=128)
    fp32 = sum(rep["float32"].values())
    q = sum(rep["table1"].values())
    assert q <= 0.55 * fp32, (q, fp32)  # dominated by int16 DSI: ~2x saving
