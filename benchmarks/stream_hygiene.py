"""Ingest-hygiene benchmark: what the guard costs, and what it survives.

Three parts, one `"stream_hygiene"` section in `BENCH_emvs.json`:

  * CLEAN-STREAM OVERHEAD — the same trickle stream (per-frame event
    chunks, the worst case for per-chunk guard overhead) through the
    streaming engine with `hygiene="off"` vs the default
    `hygiene="raise"` (watermark + monotonicity + duplicate digest +
    out-of-bounds checks on every chunk), measured WARM (every sweep
    variant precompiled) as best-of-N wall time. The gate: the guard
    may cost at most `max_overhead` of end-to-end time — 5% full-size
    per the acceptance criteria, a loose crash barrier on the
    sub-second `--dry-run` smoke whose timings jitter ~10% even idle.
    A scrub-only microbenchmark (Mevents/s through `StreamHygiene.scrub`
    alone) rides along as a timing-noise-resistant secondary.

  * ADVERSARIAL GRID — every `simulator.corrupt_stream` mode through
    the full engine under `hygiene="raise"` and `"reorder"`: each run
    must either be REJECTED LOUDLY (the expected typed
    `StreamHygieneError` subclass) or produce results bitwise-equal to
    the clean stream's (reorder absorbing the misordering inside its
    slack). Structural — no timing — so CI noise cannot flip it.

  * HOT-PIXEL STORM SURVIVAL — a `corrupt_stream("hot_pixel")` burst
    under `hygiene="drop"` with a per-pixel rate limit: the engine must
    SURVIVE (flush cleanly, produce segments) while shedding the storm
    (dropped hot-pixel events counted in stats), the
    degrade-gracefully mode a production rig with a damaged sensel
    needs.

Sections persist to BENCH_emvs.json BEFORE the gates assert (the repo's
artifact-first contract: a failing gate still ships the numbers that
explain it); `ci.yml` re-checks the gates from the artifact.

    PYTHONPATH=src python benchmarks/stream_hygiene.py [--dry-run]
"""
from __future__ import annotations

import argparse
import time
import warnings

import numpy as np

try:  # script invocation (python benchmarks/stream_hygiene.py)
    from _emvs_common import update_bench_json
    from streaming_latency import (
        _assert_bitwise,
        _precompile_variants,
        build_sequence,
    )
except ImportError:  # module invocation
    from benchmarks._emvs_common import update_bench_json
    from benchmarks.streaming_latency import (
        _assert_bitwise,
        _precompile_variants,
        build_sequence,
    )

from repro.core.pipeline import EMVSOptions, plan_segments, run_emvs
from repro.events.aggregation import aggregate
from repro.events.simulator import EVENT_CORRUPTIONS, corrupt_stream
from repro.events.stream_hygiene import (
    DuplicateChunkError,
    HotPixelError,
    HygieneConfig,
    NonMonotoneEventError,
    OutOfBoundsEventError,
    StreamHygiene,
    StreamHygieneError,
    StreamHygieneWarning,
    StreamOverlapError,
)
from repro.serving.emvs_stream import (
    EMVSStreamEngine,
    StreamConfig,
    iter_event_chunks,
)

# expected response per (corruption mode, hygiene policy): an error type
# (must raise exactly it) or "bitwise" (must reproduce the clean result)
GRID_EXPECT = {
    ("shuffle_events", "raise"): NonMonotoneEventError,
    ("swap_chunks", "raise"): StreamOverlapError,
    ("duplicate_chunk", "raise"): DuplicateChunkError,
    ("out_of_bounds", "raise"): OutOfBoundsEventError,
    ("hot_pixel", "raise"): HotPixelError,
    ("shuffle_events", "reorder"): "bitwise",
    ("swap_chunks", "reorder"): "bitwise",
    ("duplicate_chunk", "reorder"): DuplicateChunkError,
    ("out_of_bounds", "reorder"): OutOfBoundsEventError,
    ("hot_pixel", "reorder"): HotPixelError,
}
HOT_PIXEL_LIMIT = 24
HOT_PIXEL_BURST = 96


def _stream_once(cam, dsi_cfg, traj, opts, scfg, chunks):
    engine = EMVSStreamEngine(cam, dsi_cfg, traj, opts, scfg)
    t0 = time.perf_counter()
    for c in chunks:
        engine.push(c)
    res = engine.flush()
    return res, time.perf_counter() - t0, engine.stats


def clean_overhead(cam, dsi_cfg, traj, ev, opts, e_frame, frames,
                   ref, repeats: int) -> dict:
    """Warm best-of-N trickle runs, hygiene off vs raise (both bitwise)."""
    segs = plan_segments(frames, dsi_cfg, opts)
    chunks = list(iter_event_chunks(ev, e_frame))
    cfgs = {p: StreamConfig(events_per_frame=e_frame, hygiene=p)
            for p in ("off", "raise")}
    _precompile_variants(cam, dsi_cfg, frames, segs, opts,
                         next(iter(cfgs.values())))
    best = {p: float("inf") for p in cfgs}
    for _ in range(repeats):  # round-robin so machine noise spreads evenly
        for p, scfg in cfgs.items():
            res, dt, _ = _stream_once(cam, dsi_cfg, traj, opts, scfg, chunks)
            _assert_bitwise(res, ref, f"hygiene={p} trickle")
            best[p] = min(best[p], dt)
    # scrub-only microbenchmark: the guard's own per-event cost, no engine
    hyg = StreamHygiene(HygieneConfig(policy="raise"),
                        width=cam.width, height=cam.height)
    n_events = int(ev.t.shape[0])
    t0 = time.perf_counter()
    for c in chunks:
        hyg.scrub(c)
    scrub_s = time.perf_counter() - t0
    return {
        "off_best_s": round(best["off"], 4),
        "raise_best_s": round(best["raise"], 4),
        "overhead_ratio": round(best["raise"] / best["off"] - 1.0, 4),
        "scrub_mevents_per_s": round(n_events / scrub_s / 1e6, 3),
        "chunks": len(chunks),
        "events": n_events,
    }


def adversarial_grid(cam, dsi_cfg, traj, ev, opts, e_frame, ref) -> list[dict]:
    """Every corruption x {raise, reorder} through the full engine:
    rejected loudly with the expected type, or bitwise-equal to clean."""
    rows = []
    for mode in EVENT_CORRUPTIONS:
        bad = corrupt_stream(ev, mode, e_frame, seed=7,
                             width=cam.width, height=cam.height,
                             burst=HOT_PIXEL_BURST)
        spans = [float(np.asarray(c.t).max() - np.asarray(c.t).min())
                 for c in bad if c.t.shape[0]]
        slack = 2.0 * max(spans)
        for policy in ("raise", "reorder"):
            hyg = HygieneConfig(policy=policy, reorder_slack=slack,
                                hot_pixel_limit=HOT_PIXEL_LIMIT)
            scfg = StreamConfig(events_per_frame=e_frame, hygiene=hyg)
            want = GRID_EXPECT[(mode, policy)]
            outcome = None
            try:
                res, _, _ = _stream_once(cam, dsi_cfg, traj, opts, scfg, bad)
                _assert_bitwise(res, ref, f"{mode}/{policy}")
                outcome = "bitwise"
            except StreamHygieneError as e:
                outcome = f"raised:{type(e).__name__}"
            expected = (want if isinstance(want, str)
                        else f"raised:{want.__name__}")
            rows.append({"mode": mode, "policy": policy,
                         "outcome": outcome, "expected": expected,
                         "ok": outcome == expected})
            print(f"  {mode:<16}{policy:<9}{outcome:<28}"
                  f"{'OK' if outcome == expected else 'UNEXPECTED'}")
    return rows


def storm_survival(cam, dsi_cfg, traj, ev, opts, e_frame, ref) -> dict:
    """A hot-pixel storm under hygiene="drop": the engine must survive,
    shed the storm, and keep producing depth maps."""
    bad = corrupt_stream(ev, "hot_pixel", e_frame, seed=7,
                         width=cam.width, height=cam.height,
                         burst=HOT_PIXEL_BURST)
    hyg = HygieneConfig(policy="drop", hot_pixel_limit=HOT_PIXEL_LIMIT)
    scfg = StreamConfig(events_per_frame=e_frame, hygiene=hyg)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", StreamHygieneWarning)
        res, dt, stats = _stream_once(cam, dsi_cfg, traj, opts, scfg, bad)
    h = stats["hygiene"]
    return {
        "burst_events": HOT_PIXEL_BURST,
        "hot_pixel_limit": HOT_PIXEL_LIMIT,
        "dropped_hot_pixel": int(h["dropped_hot_pixel"]),
        "segments": len(res.segments),
        "clean_segments": len(ref.segments),
        "end_to_end_s": round(dt, 3),
    }


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--dry-run", action="store_true",
                        help="CI-sized smoke (same asserts, looser gate)")
    parser.add_argument("--json-out", default=None,
                        help="BENCH json path (default: repo BENCH_emvs.json)")
    args = parser.parse_args()

    cam, traj, ev, e_frame, dsi_cfg = build_sequence(args.dry_run)
    opts = EMVSOptions()
    frames = aggregate(cam, ev, traj, events_per_frame=e_frame)
    ref = run_emvs(cam, dsi_cfg, frames, opts)
    print(f"sequence: {int(ev.t.shape[0])} events, "
          f"{int(frames.xy.shape[0])} frames, {len(ref.segments)} segments")

    repeats = 3 if args.dry_run else 5
    overhead = clean_overhead(cam, dsi_cfg, traj, ev, opts, e_frame,
                              frames, ref, repeats)
    print(f"\nclean-stream overhead (warm, best of {repeats}): "
          f"off={overhead['off_best_s']}s raise={overhead['raise_best_s']}s "
          f"-> {100 * overhead['overhead_ratio']:.1f}% "
          f"(scrub alone: {overhead['scrub_mevents_per_s']} Mevents/s)")

    print("\nadversarial grid (full engine):")
    grid = adversarial_grid(cam, dsi_cfg, traj, ev, opts, e_frame, ref)

    storm = storm_survival(cam, dsi_cfg, traj, ev, opts, e_frame, ref)
    print(f"\nhot-pixel storm under drop: {storm['segments']} segments "
          f"(clean: {storm['clean_segments']}), "
          f"{storm['dropped_hot_pixel']} storm events shed")

    # the acceptance gate is 5% on full-size runs; the sub-second smoke
    # jitters ~10% even on an idle machine, so its timing gate is only a
    # crash barrier — the structural grid/storm gates stay strict there
    max_overhead = 0.5 if args.dry_run else 0.05
    gate = {
        "max_overhead": max_overhead,
        "overhead_ratio": overhead["overhead_ratio"],
        "grid_ok": all(r["ok"] for r in grid),
        "storm_survived": storm["segments"] == storm["clean_segments"]
        and storm["dropped_hot_pixel"] > 0,
    }
    path = update_bench_json("stream_hygiene", {
        "dry_run": bool(args.dry_run),
        "overhead": overhead,
        "adversarial_grid": grid,
        "hot_pixel_storm": storm,
        "gate": gate,
    }, path=args.json_out)
    print(f"\nwrote {path}")

    # gate LAST, after every section is persisted
    assert gate["grid_ok"], (
        "adversarial grid: unexpected outcome(s): "
        + str([r for r in grid if not r["ok"]]))
    assert gate["storm_survived"], f"hot-pixel storm not survived: {storm}"
    assert overhead["overhead_ratio"] <= max_overhead, (
        f"hygiene overhead {100 * overhead['overhead_ratio']:.1f}% exceeds "
        f"the {100 * max_overhead:.0f}% gate")


if __name__ == "__main__":
    main()
