"""Paper §2.3: "our hybrid data quantization strategy can save up to 50%
of the memory requirement and data transferring bandwidth"."""
from __future__ import annotations

from repro.core.camera import CameraModel
from repro.quant.policies import memory_report


def run() -> dict:
    cam = CameraModel()
    rep = memory_report(cam, num_planes=128, events_per_frame=1024)
    f32 = sum(rep["float32"].values())
    q = sum(rep["table1"].values())
    return {"float32_bytes_per_frame": f32, "table1_bytes_per_frame": q,
            "saving": 1 - q / f32, "detail": rep,
            "claim_ok": bool(q <= 0.55 * f32)}


def main() -> None:
    out = run()
    print("== §2.3 memory footprint (bytes per 1024-event frame + DSI) ==")
    print(f"{'item':14s} {'float32':>12s} {'table1':>12s}")
    for k in out["detail"]["float32"]:
        print(f"{k:14s} {out['detail']['float32'][k]:12d} "
              f"{out['detail']['table1'][k]:12d}")
    print(f"total: {out['float32_bytes_per_frame']} -> "
          f"{out['table1_bytes_per_frame']} bytes "
          f"({out['saving']*100:.1f}% saved; paper: 'up to 50%'; "
          f"{'OK' if out['claim_ok'] else 'VIOLATED'})")


if __name__ == "__main__":
    main()
