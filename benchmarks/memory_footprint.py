"""Paper §2.3: "our hybrid data quantization strategy can save up to 50%
of the memory requirement and data transferring bandwidth".

Second table: the STREAMING HOST WINDOW. Each streaming session keeps
its aggregated frames in a host-side `_FrameStore` until the planner's
open segment no longer needs them; the store counts its live and peak
resident bytes exactly (`frame_store_bytes` / `frame_store_peak_bytes`
in the engine stats). A tiny end-to-end streaming run here shows the two
invariants that make the window a *window* rather than a leak: the peak
stays below the whole sequence's resident footprint (eviction works
mid-stream), and the live count returns to exactly zero after `flush`
(nothing survives the stream).
"""
from __future__ import annotations

from repro.core.camera import CameraModel
from repro.quant.policies import memory_report


def run() -> dict:
    cam = CameraModel()
    rep = memory_report(cam, num_planes=128, events_per_frame=1024)
    f32 = sum(rep["float32"].values())
    q = sum(rep["table1"].values())
    return {"float32_bytes_per_frame": f32, "table1_bytes_per_frame": q,
            "saving": 1 - q / f32, "detail": rep,
            "claim_ok": bool(q <= 0.55 * f32)}


def run_streaming_window() -> dict:
    """Stream a tiny sequence and report the host frame-window footprint:
    peak resident bytes vs the un-evicted whole-sequence cost (measured
    by filling a reference `_FrameStore` with every frame), and the
    post-flush live count (must be exactly 0)."""
    from repro.core.dsi import DSIConfig
    from repro.core.pipeline import EMVSOptions
    from repro.events.aggregation import aggregate
    from repro.events.simulator import (
        SceneConfig,
        make_scene,
        make_trajectory,
        simulate_events,
    )
    from repro.serving.emvs_stream import (
        EMVSStreamEngine,
        StreamConfig,
        _FrameStore,
        iter_event_chunks,
    )

    cam = CameraModel()
    e_frame = 256
    scene = make_scene(SceneConfig(name="simulation_3planes",
                                   points_per_plane=80))
    traj = make_trajectory("simulation_3planes", 64)
    ev = simulate_events(cam, scene, traj, noise_fraction=0.02, seed=0)
    dsi_cfg = DSIConfig.for_camera(cam, num_planes=8, z_min=0.6, z_max=4.5)
    opts = EMVSOptions(keyframe_dist_frac=0.02)

    engine = EMVSStreamEngine(cam, dsi_cfg, traj, opts,
                              StreamConfig(events_per_frame=e_frame))
    for chunk in iter_event_chunks(ev, e_frame):
        engine.push(chunk)
    res = engine.flush()
    stats = engine.stats

    # the counterfactual: every aggregated frame resident at once, counted
    # by the same accounting the engine uses
    whole_store = _FrameStore()
    whole_store.extend(aggregate(cam, ev, traj, events_per_frame=e_frame))
    whole = whole_store.live_bytes

    return {
        "frames": int(stats["frames"]),
        "segments": len(res.segments),
        "live_bytes_after_flush": int(stats["frame_store_bytes"]),
        "peak_bytes": int(stats["frame_store_peak_bytes"]),
        "whole_sequence_bytes": int(whole),
        "peak_fraction_of_sequence": round(
            stats["frame_store_peak_bytes"] / whole, 4) if whole else 0.0,
        "window_ok": bool(stats["frame_store_bytes"] == 0
                          and 0 < stats["frame_store_peak_bytes"] <= whole),
    }


def main() -> None:
    out = run()
    print("== §2.3 memory footprint (bytes per 1024-event frame + DSI) ==")
    print(f"{'item':14s} {'float32':>12s} {'table1':>12s}")
    for k in out["detail"]["float32"]:
        print(f"{k:14s} {out['detail']['float32'][k]:12d} "
              f"{out['detail']['table1'][k]:12d}")
    print(f"total: {out['float32_bytes_per_frame']} -> "
          f"{out['table1_bytes_per_frame']} bytes "
          f"({out['saving']*100:.1f}% saved; paper: 'up to 50%'; "
          f"{'OK' if out['claim_ok'] else 'VIOLATED'})")

    win = run_streaming_window()
    print("\n== streaming host frame-window (live/peak byte accounting) ==")
    print(f"frames aggregated:       {win['frames']}")
    print(f"segments swept:          {win['segments']}")
    print(f"whole sequence resident: {win['whole_sequence_bytes']} bytes")
    print(f"peak window resident:    {win['peak_bytes']} bytes "
          f"({win['peak_fraction_of_sequence']*100:.1f}% of sequence)")
    print(f"live after flush:        {win['live_bytes_after_flush']} bytes")
    print("OK: eviction bounds the window and flush drains it"
          if win["window_ok"] else "VIOLATED: window accounting broken")


if __name__ == "__main__":
    main()
