"""Roofline report: renders the dry-run sweep (results/dryrun) into the
EXPERIMENTS.md §Roofline table. Run the sweep first:

    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""
from __future__ import annotations

import os

from benchmarks.summarize_dryrun import HEADER, fmt_row, load

DEFAULT_DIR = "results/dryrun"


def run(out_dir: str = DEFAULT_DIR) -> dict:
    if not os.path.isdir(out_dir):
        return {"error": f"no dry-run results in {out_dir}; run the sweep first",
                "rows": []}
    recs = load(out_dir)
    compiled = [r for r in recs if "skipped" not in r]
    doms = {}
    for r in compiled:
        doms[r["roofline"]["dominant"]] = doms.get(r["roofline"]["dominant"], 0) + 1
    return {"rows": recs, "n": len(recs), "n_compiled": len(compiled),
            "dominant_histogram": doms}


def main() -> None:
    out = run()
    if "error" in out:
        print(out["error"])
        return
    print("== Roofline (from the 512-device dry-run artifacts) ==")
    print(HEADER)
    for r in out["rows"]:
        print(fmt_row(r))
    print(f"\n{out['n']} cells ({out['n_compiled']} compiled); dominant-term "
          f"histogram: {out['dominant_histogram']}")


if __name__ == "__main__":
    main()
