"""Roofline report: the EMVS kernel-fusion ladder (analytic, always
available) plus the LM dry-run sweep table when its artifacts exist.

The fusion section gates the tentpole claim of the fused Pallas sweep:
each fusion stage (unfused -> fused int16 store -> fused detection) must
sit STRICTLY closer to the roofline bound than the previous one — fusion
only deletes HBM traffic, so a rung that fails the gate means the model
(or the kernel) has regrown a round-trip.

    PYTHONPATH=src python -m benchmarks.roofline_report [--dry-run]

`--dry-run` additionally writes the ladder into the namespaced
`"dry_run"` section of BENCH_emvs.json (never the top level, so the CI
smoke cannot poison tracked full-run records).

The LM table needs the dry-run sweep artifacts first:

    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""
from __future__ import annotations

import argparse
import os

from repro.launch.roofline import emvs_fusion_ladder

DEFAULT_DIR = "results/dryrun"

# Eventor operating point: DAVIS240 sensor, paper's 64-plane sweep
FUSION_SHAPE = dict(nz=64, h=180, w=240, events=1024, frames=8)


def fusion_report(shape: dict | None = None) -> dict:
    """Compute the ladder and enforce the strictly-closer gate."""
    shape = dict(shape or FUSION_SHAPE)
    ladder = emvs_fusion_ladder(**shape)
    violations = []
    for prev, cur in zip(ladder, ladder[1:]):
        if not cur.bound_gap < prev.bound_gap:
            violations.append(
                f"{cur.name} (gap {cur.bound_gap:.3f}) is not strictly "
                f"closer to the roofline bound than {prev.name} "
                f"(gap {prev.bound_gap:.3f})")
    return {
        "shape": shape,
        "stages": [r.to_json() for r in ladder],
        "violations": violations,
        "fused_vs_unfused_bytes_ratio": (
            ladder[-1].hbm_bytes / ladder[0].hbm_bytes),
    }


def _print_fusion(rep: dict) -> None:
    print("== EMVS sweep fusion ladder (analytic two-term roofline) ==")
    s = rep["shape"]
    print(f"shape: nz={s['nz']} h={s['h']} w={s['w']} events={s['events']} "
          f"frames={s['frames']} quantized={s.get('quantized', True)}")
    print(f"{'stage':<14} {'HBM MiB':>9} {'intensity':>10} "
          f"{'memory us':>10} {'compute us':>11} {'bound gap':>10}")
    for st in rep["stages"]:
        print(f"{st['name']:<14} {st['hbm_bytes'] / 2**20:>9.2f} "
              f"{st['intensity']:>10.1f} {st['memory_s'] * 1e6:>10.2f} "
              f"{st['compute_s'] * 1e6:>11.2f} {st['bound_gap']:>10.2f}")
    ratio = rep["fused_vs_unfused_bytes_ratio"]
    print(f"fused kernel moves {ratio:.2%} of the unfused HBM traffic")
    for v in rep["violations"]:
        print(f"VIOLATION: {v}")


def run(out_dir: str = DEFAULT_DIR) -> dict:
    if not os.path.isdir(out_dir):
        return {"error": f"no dry-run results in {out_dir}; run the sweep first",
                "rows": []}
    from benchmarks.summarize_dryrun import load

    recs = load(out_dir)
    compiled = [r for r in recs if "skipped" not in r]
    doms = {}
    for r in compiled:
        doms[r["roofline"]["dominant"]] = doms.get(r["roofline"]["dominant"], 0) + 1
    return {"rows": recs, "n": len(recs), "n_compiled": len(compiled),
            "dominant_histogram": doms}


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dry-run", action="store_true",
                    help="record the fusion ladder into the dry_run "
                         "namespace of BENCH_emvs.json (CI smoke)")
    ap.add_argument("--out-dir", default=DEFAULT_DIR,
                    help="LM dry-run artifact directory")
    args = ap.parse_args(argv)

    rep = fusion_report()
    _print_fusion(rep)

    if args.dry_run:
        try:
            from _emvs_common import update_bench_json
        except ImportError:
            from benchmarks._emvs_common import update_bench_json
        path = update_bench_json("roofline_report", {
            "dry_run": True,
            "fusion": rep,
        })
        print(f"\nwrote dry_run/roofline_report -> {path}")

    out = run(args.out_dir)
    if "error" in out:
        print(f"\n{out['error']}")
    else:
        from benchmarks.summarize_dryrun import HEADER, fmt_row

        print("\n== Roofline (from the 512-device dry-run artifacts) ==")
        print(HEADER)
        for r in out["rows"]:
            print(fmt_row(r))
        print(f"\n{out['n']} cells ({out['n_compiled']} compiled); dominant-"
              f"term histogram: {out['dominant_histogram']}")

    if rep["violations"]:
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
