"""Paper Fig 4a: depth-estimation AbsRel, Bilinear vs Nearest voting.

Claim reproduced: "The maximum AbsRel difference between Nearest Voting
and original Bilinear Voting is about 1.18%."
"""
from __future__ import annotations

from benchmarks._emvs_common import SEQUENCES, absrel_for
from repro.core.pipeline import EMVSOptions


def run() -> dict:
    rows = {}
    worst_gap = 0.0
    for seq in SEQUENCES:
        e_bil = absrel_for(seq, EMVSOptions(voting="bilinear"))
        e_nea = absrel_for(seq, EMVSOptions(voting="nearest"))
        gap = abs(e_nea - e_bil)
        worst_gap = max(worst_gap, gap)
        rows[seq] = {"bilinear": e_bil, "nearest": e_nea, "gap": gap}
    return {"rows": rows, "max_gap": worst_gap,
            "paper_claim_max_gap": 0.0118,
            "claim_ok": bool(worst_gap < 0.025)}


def main() -> None:
    out = run()
    print("== Fig 4a: nearest vs bilinear voting (AbsRel) ==")
    print(f"{'sequence':22s} {'bilinear':>9s} {'nearest':>9s} {'gap':>8s}")
    for seq, r in out["rows"].items():
        print(f"{seq:22s} {r['bilinear']:9.4f} {r['nearest']:9.4f} "
              f"{r['gap']:8.4f}")
    print(f"max gap {out['max_gap']:.4f} "
          f"(paper: ~{out['paper_claim_max_gap']:.4f}; "
          f"{'OK' if out['claim_ok'] else 'VIOLATED'})")


if __name__ == "__main__":
    main()
