"""Paper Fig 7a: AbsRel per sequence — original EMVS vs our reformulated
framework (rescheduled + nearest voting + Table-1 quantization).

Claim reproduced: sims favour the original slightly (max diff < 1.78%);
slider sequences can even favour the reformulated framework.
"""
from __future__ import annotations

from benchmarks._emvs_common import SEQUENCES, absrel_for
from repro.core.pipeline import EMVSOptions

ORIGINAL = EMVSOptions(voting="bilinear", quantized=False,
                       formulation="scatter")
REFORMULATED = EMVSOptions(voting="nearest", quantized=True,
                           formulation="matmul")


def run() -> dict:
    rows = {}
    worst = 0.0
    for seq in SEQUENCES:
        e_o = absrel_for(seq, ORIGINAL)
        e_r = absrel_for(seq, REFORMULATED)
        rows[seq] = {"original_emvs": e_o, "reformulated": e_r,
                     "diff": e_r - e_o}
        worst = max(worst, e_r - e_o)
    return {"rows": rows, "max_regression": worst,
            "paper_claim_max_diff": 0.0178,
            "claim_ok": bool(worst < 0.05)}


def main() -> None:
    out = run()
    print("== Fig 7a: original EMVS vs reformulated (AbsRel) ==")
    print(f"{'sequence':22s} {'original':>9s} {'reformed':>9s} {'diff':>8s}")
    for seq, r in out["rows"].items():
        print(f"{seq:22s} {r['original_emvs']:9.4f} {r['reformulated']:9.4f} "
              f"{r['diff']:+8.4f}")
    print(f"max regression {out['max_regression']:+.4f} "
          f"(paper: <{out['paper_claim_max_diff']:.4f}; "
          f"{'OK' if out['claim_ok'] else 'VIOLATED'})")


if __name__ == "__main__":
    main()
