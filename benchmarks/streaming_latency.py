"""Streaming EMVS latency: time-to-first-depth-map vs the offline sweep.

The offline batched path (`run_emvs`) cannot emit anything until the
whole trajectory has arrived and every bucket has been swept; the
streaming engine closes a key-frame segment the moment the K criterion
trips and dispatches it while later events are still arriving. The
headline metric is therefore FIRST-SEGMENT LATENCY (stream start ->
first harvested depth map), which must be strictly below the offline
end-to-end time on the same sequence — otherwise streaming buys nothing.

Also reported: per-segment completion timeline, sustained events/s, and
the number of compiled sweep variants (must stay at
|segment_buckets| x |capacities| — the double-buffered dispatch pads
both the frame and the segment axes to fixed sizes).

Second axis: the POSE-LAG SWEEP. The realistic system receives poses
from a tracker running *behind* the event front; the engine's
pose-gated mode stalls frames past the pose-lag watermark until their
bracketing pose chunk arrives. The sweep streams the same sequence with
the pose stream lagging the event front by several delays and reports
first-depth latency and peak stall-queue depth per lag (results must
stay bit-identical to offline `run_emvs` at every lag). Both tables are
emitted into `BENCH_emvs.json` ("streaming_latency" section, with a
"pose_lag_sweep" list) for CI artifact tracking.

Both paths are measured cold (fresh jit caches): that is what a newly
started sensor pipeline pays.

Third axis: the DISPATCH-POLICY SWEEP (its own "dispatch_policy_sweep"
section in `BENCH_emvs.json`). Each `StreamConfig.dispatch_policy`
("latency" = one sweep per closed segment, "throughput" = fill the
largest S bucket before dispatching, "adaptive" = per-segment while the
in-flight queue is shallow, coalesce when it saturates) streams the same
sequence under a steady per-frame trickle and a single whole-stream
burst. Unlike the cold headline numbers, the policy runs are measured
WARM (every sweep variant precompiled, best of N repeats): the policies
differ in dispatch overhead and batching, not in compile behavior, and
the sustained segments/s comparison must not drown in one-off compile
noise. Results must stay bitwise-equal to offline under every policy,
and the REGRESSION GATE at the end fails the run if the adaptive policy
stops coalescing under burst (structural: fewer dispatches than
segments — deterministic, so CI noise cannot flip it) or if its
sustained segments/s falls below min_ratio x the per-segment
("latency") baseline — strict on full-size runs, a loose crash barrier
on the sub-second smoke, whose timings jitter ~10% even idle.

Fourth axis: the MULTI-STREAM SWEEP ("multi_stream_sweep" section). N
identical trickle sessions stream through ONE `MultiStreamEngine`
(shared `SweepDispatcher`) and through N dedicated single-stream
engines; both run the "throughput" policy so the dispatch schedule is
load-shaped, not timing-shaped. Reported per arrangement: aggregate
(sessions x segments)/s, per-session p99 first-depth latency, dispatch
counts, and the coalesced-bucket FILL RATE (real segment rows / total
rows incl. S-axis padding) — cross-stream coalescing packs
shape-compatible segments from different sessions into one bucket, so
the multi engine must fill buckets the dedicated engines pad. Its
REGRESSION GATE is purely structural (dispatch counters, no timing):
the shared dispatcher must issue at least one cross-stream group and
strictly fewer total dispatches than the N dedicated engines combined.
The run picks an S bucket that does not divide the per-session segment
count, which makes the reduction a load-shape invariant rather than a
lucky draw. Every session's result is asserted bitwise-equal to
offline. `ci.yml` re-applies both this gate and the dispatch-policy
gate from the persisted artifact.

Fifth axis: SESSION CHURN ("session_churn" section). The multi-stream
sweep holds membership fixed; real rigs do not — cameras join and drop
while the dispatcher is saturated. Here a MultiStreamEngine runs
`n_stayers` steady trickle sessions while one "leaver" session streams
its whole sequence at double rate and flushes out mid-run, after which
a "joiner" session is admitted on the fly and streams its whole
sequence at double rate — so the membership changes under load but
every session still pushes the full sequence and must come out
bitwise-equal to offline. The gate is structural: the dispatcher must
keep cross-stream coalescing alive across the membership change
(cross-stream groups both before the leave and after the join) and end
with an empty queue.

Sixth axis: the measured COST TABLE + "cost_model" section. The warm
policy and churn runs carry an opt-in `SweepProfiler` that records
warm, unshadowed per-variant sweep wall times into one shared
`CostTable`, persisted to `cost_table.json` (same atomic-write
discipline as BENCH_emvs.json). The section records the affine
calibration report (per-backend dispatch overhead + per-row rate and
fit error) and the burst replay gate (`check_slo_burst`): on the
recorded table the SLO-aware adaptive policy must dispatch no more
groups than "throughput" and meet its predicted p99 deadline —
deterministic, because the replay runs in virtual time against the
persisted table (docs/dispatch_planning.md).

    PYTHONPATH=src python benchmarks/streaming_latency.py [--dry-run]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

try:  # script invocation (python benchmarks/streaming_latency.py)
    from _emvs_common import update_bench_json
except ImportError:  # module invocation
    from benchmarks._emvs_common import update_bench_json

from repro.core.camera import CameraModel
from repro.core.dsi import DSIConfig
from repro.core.pipeline import (
    EMVSOptions,
    bucket_capacity,
    pad_segments,
    plan_segments,
    process_segments_batched,
    run_emvs,
)
from repro.events.aggregation import aggregate
from repro.events.simulator import (
    SceneConfig,
    make_scene,
    make_trajectory,
    simulate_events,
    slice_trajectory,
)
from repro.profiling import CostTable, SweepProfiler, fit_affine_model
from repro.serving.dispatch_replay import check_slo_burst
from repro.serving.emvs_stream import (
    EMVSStreamEngine,
    MultiStreamEngine,
    StreamConfig,
    iter_event_chunks,
)


def build_sequence(dry_run: bool):
    cam = CameraModel()
    # Dry-run stays CI-sized but long enough that offline end-to-end
    # (which scales with the sequence) clearly separates from
    # first-segment latency (which does not): the gating assert below
    # must not sit within scheduler noise of a shared runner.
    steps, points, e_frame, planes = (
        (96, 100, 256, 8) if dry_run else (144, 200, 512, 16))
    scene = make_scene(SceneConfig(name="simulation_3planes",
                                   points_per_plane=points))
    traj = make_trajectory("simulation_3planes", steps)
    ev = simulate_events(cam, scene, traj, noise_fraction=0.02, seed=0)
    dsi_cfg = DSIConfig.for_camera(cam, num_planes=planes, z_min=0.6, z_max=4.5)
    return cam, traj, ev, e_frame, dsi_cfg


def stream_with_pose_lag(cam, dsi_cfg, traj, ev, opts, scfg,
                         lag_s: float, chunk_events: int):
    """Stream events with the pose stream trailing the event front by
    `lag_s` seconds (tracker model). Returns (result, first-depth
    latency, end-to-end time, engine stats)."""
    engine = EMVSStreamEngine(cam, dsi_cfg, None, opts, scfg)
    pose_t = np.asarray(traj.times)
    sent = 0
    first = None
    t0 = time.perf_counter()
    for chunk in iter_event_chunks(ev, chunk_events):
        if engine.push(chunk) and first is None:
            first = time.perf_counter() - t0
        front = float(np.asarray(chunk.t)[-1]) - lag_s
        hi = int(np.searchsorted(pose_t, front, side="right"))
        if hi > sent:
            got = engine.push_poses(slice_trajectory(traj, sent, hi))
            sent = hi
            if got and first is None:
                first = time.perf_counter() - t0
    # tracker drains after the sensor: deliver the rest, close the stream
    if sent < pose_t.shape[0]:
        got = engine.push_poses(slice_trajectory(traj, sent, pose_t.shape[0]))
        if got and first is None:
            first = time.perf_counter() - t0
    engine.finalize_poses()
    res = engine.flush()
    t_total = time.perf_counter() - t0
    return res, (t_total if first is None else first), t_total, engine.stats


def _assert_bitwise(res, ref, what: str) -> None:
    assert [s.frame_range for s in res.segments] == \
        [s.frame_range for s in ref.segments], f"{what}: boundaries diverged"
    worst = 0.0
    for sa, sb in zip(res.segments, ref.segments):
        worst = max(worst, float(np.abs(
            np.asarray(sa.dsi, np.float32) - np.asarray(sb.dsi, np.float32)
        ).max()))
    assert worst == 0.0, f"{what}: max DSI delta {worst} (must be bitwise)"


def _precompile_variants(cam, dsi_cfg, frames, segs, opts, scfg) -> None:
    """Compile every (S bucket x frame capacity) sweep variant a policy
    run could dispatch — including the per-dispatch depth-map -> point
    -cloud conversion, which is jit'd per S-bucket shape too — so the
    timed runs measure scheduling, not compilation (the adaptive
    schedule is timing-dependent; a cold variant mid-run would corrupt
    the A/B)."""
    from repro.core.geometry import SE3
    from repro.core.pointcloud import depth_maps_to_points

    for cap in sorted({bucket_capacity(b - a) for a, b in segs}):
        seg = next(s for s in segs if bucket_capacity(s[1] - s[0]) == cap)
        for s_bucket in scfg.segment_buckets:
            batch = pad_segments(frames, [seg] * s_bucket, cap)
            _, dms = process_segments_batched(cam, dsi_cfg, batch, opts)
            pcs = depth_maps_to_points(cam, dms,
                                       SE3(batch.ref_R, batch.ref_t))
            dms.depth.block_until_ready()
            pcs.points.block_until_ready()


def _stream_policy_once(cam, dsi_cfg, traj, ev, opts, scfg, chunk_events,
                        profiler=None):
    """One timed streaming run: per-segment completion timeline + stats."""
    engine = EMVSStreamEngine(cam, dsi_cfg, traj, opts, scfg,
                              profiler=profiler)
    timeline: list[tuple[float, tuple[int, int]]] = []
    t0 = time.perf_counter()
    for c in iter_event_chunks(ev, chunk_events):
        for seg in engine.push(c):
            timeline.append((time.perf_counter() - t0, seg.frame_range))
    res = engine.flush()
    t_total = time.perf_counter() - t0
    seen = {fr for _, fr in timeline}
    timeline += [(t_total, s.frame_range) for s in res.segments
                 if s.frame_range not in seen]
    return res, t_total, timeline, engine.stats


def dispatch_policy_sweep(cam, dsi_cfg, traj, ev, opts, e_frame, frames,
                          ref, repeats: int,
                          table: CostTable | None = None) -> list[dict]:
    """Policy A/B: sustained segments/s and p50/p99 per-segment
    first-depth latency per (load profile x dispatch policy), measured
    warm, best of `repeats`. Every run is asserted bitwise-equal to the
    offline reference — the policies may only move the schedule. When
    `table` is given, every run carries a fresh `SweepProfiler` feeding
    it: warm unshadowed sweep wall times become the measured cost model
    (each run re-pays the one-per-variant cold-skip, which only makes
    the table more conservative)."""
    n_events = int(ev.t.shape[0])
    segs = plan_segments(frames, dsi_cfg, opts)
    scfg_by_policy = {
        policy: StreamConfig(events_per_frame=e_frame, dispatch_policy=policy)
        for policy in ("latency", "throughput", "adaptive")}
    # one precompile covers every config: the sweep/point-cloud variants
    # depend only on the S buckets and capacities, not on the policy
    _precompile_variants(cam, dsi_cfg, frames, segs, opts,
                         next(iter(scfg_by_policy.values())))
    configs = [(profile, chunk_events, policy)
               for profile, chunk_events in (("burst", n_events),
                                             ("trickle", e_frame))
               for policy in scfg_by_policy]
    # Repeats run ROUND-ROBIN over the configs (not back-to-back per
    # config) so slow phases of a shared machine spread across all
    # policies instead of sinking whichever config they landed on; the
    # reported number is each config's best (min-time) repeat.
    best: dict = {}
    for _ in range(repeats):
        for cfg in configs:
            profile, chunk_events, policy = cfg
            profiler = SweepProfiler(table=table) if table is not None \
                else None
            res, t_total, timeline, stats = _stream_policy_once(
                cam, dsi_cfg, traj, ev, opts, scfg_by_policy[policy],
                chunk_events, profiler=profiler)
            _assert_bitwise(res, ref, f"policy={policy} {profile}")
            if cfg not in best or t_total < best[cfg][0]:
                best[cfg] = (t_total, timeline, stats, len(res.segments))
    rows = []
    print(f"\ndispatch-policy sweep (warm, best of {repeats}, interleaved):")
    print(f"{'profile':<10}{'policy':<12}{'seg/s':>8}{'p50 s':>8}"
          f"{'p99 s':>8}{'dispatches':>11}{'coalesced':>10}{'max queue':>10}")
    for cfg in configs:
        profile, _, policy = cfg
        t_total, timeline, stats, n_segs = best[cfg]
        lat = np.asarray([t for t, _ in timeline], np.float64)
        row = {
            "profile": profile,
            "policy": policy,
            "segments_per_s": round(n_segs / t_total, 3),
            "end_to_end_s": round(t_total, 3),
            "first_depth_p50_s": round(float(np.percentile(lat, 50)), 3),
            "first_depth_p99_s": round(float(np.percentile(lat, 99)), 3),
            "dispatches": int(stats["dispatches"]),
            "coalesced_dispatches": int(stats["coalesced_dispatches"]),
            "coalesced_segments": int(stats["coalesced_segments"]),
            "max_pending": int(stats["max_pending"]),
        }
        rows.append(row)
        print(f"{profile:<10}{policy:<12}{row['segments_per_s']:>8.2f}"
              f"{row['first_depth_p50_s']:>8.3f}"
              f"{row['first_depth_p99_s']:>8.3f}"
              f"{row['dispatches']:>11d}"
              f"{row['coalesced_dispatches']:>10d}"
              f"{row['max_pending']:>10d}")
    print("OK: every policy x profile is bitwise-equal to offline")
    return rows


def multi_stream_sweep(cam, dsi_cfg, traj, ev, opts, e_frame, frames,
                       ref, n_sessions: int) -> dict:
    """N concurrent trickle streams: one shared dispatcher vs N dedicated
    engines. Structural comparison — the "throughput" policy makes the
    dispatch schedule a function of load shape alone, so the gate
    (cross-stream coalescing must cut the dispatch count) is
    deterministic. Timings ride along as reporting, not as the gate."""
    segs = plan_segments(frames, dsi_cfg, opts)
    n_ref = len(ref.segments)
    # Pick the top S bucket so it does NOT divide the per-session segment
    # count: if it did, every same-capacity run could fill buckets exactly
    # and the dedicated engines would tie the shared dispatcher by luck of
    # the load shape. With S % top != 0 some run leaves a partial bucket,
    # which only cross-stream coalescing can fill — the reduction the gate
    # asserts becomes an invariant of the arrangement. (S cannot be
    # divisible by all of 4, 3, 5 and 7 below ~400 segments.)
    top = next(b for b in (4, 3, 5, 7) if n_ref % b != 0)
    scfg = StreamConfig(events_per_frame=e_frame,
                        dispatch_policy="throughput",
                        segment_buckets=(1, 2, top) if top > 2 else (1, 2))
    _precompile_variants(cam, dsi_cfg, frames, segs, opts, scfg)
    chunk_events = e_frame

    # --- N dedicated single-stream engines (run back-to-back, warm) ----
    ded_stats: list[dict] = []
    ded_p99: list[float] = []
    t_ded = 0.0
    for i in range(n_sessions):
        res, t_total, timeline, stats = _stream_policy_once(
            cam, dsi_cfg, traj, ev, opts, scfg, chunk_events)
        _assert_bitwise(res, ref, f"dedicated[{i}]")
        lat = np.asarray([t for t, _ in timeline], np.float64)
        ded_p99.append(float(np.percentile(lat, 99)))
        ded_stats.append(stats)
        t_ded += t_total

    # --- one MultiStreamEngine, lockstep round-robin interleave --------
    engine = MultiStreamEngine(cam, dsi_cfg, opts, scfg)
    handles = [engine.add_session(traj=traj) for _ in range(n_sessions)]
    times: dict[str, list[float]] = {h.session_id: [] for h in handles}
    t0 = time.perf_counter()
    for chunk in iter_event_chunks(ev, chunk_events):
        for h in handles:
            for _seg in h.push(chunk):
                times[h.session_id].append(time.perf_counter() - t0)
    for h in handles:
        res = h.flush()
        t_now = time.perf_counter() - t0
        _assert_bitwise(res, ref, f"multi session {h.session_id}")
        # segments drained by this flush complete at flush time
        times[h.session_id] += \
            [t_now] * (len(res.segments) - len(times[h.session_id]))
    t_multi = time.perf_counter() - t0
    d = engine.stats["dispatcher"]
    assert d["pending_segments"] == 0, "multi engine left work queued"

    def _fill(seg_total: int, padded: int) -> float:
        return seg_total / (seg_total + padded) if seg_total + padded else 1.0

    multi_p99 = {sid: round(float(np.percentile(np.asarray(ts), 99)), 3)
                 for sid, ts in times.items()}
    dedicated = {
        "dispatches": sum(s["dispatches"] for s in ded_stats),
        "padded_segments": sum(s["padded_segments"] for s in ded_stats),
        "segments": sum(s["segments"] for s in ded_stats),
        "aggregate_segments_per_s": round(n_sessions * n_ref / t_ded, 3),
        "end_to_end_s": round(t_ded, 3),
        "per_session_p99_s": [round(p, 3) for p in ded_p99],
    }
    dedicated["bucket_fill_rate"] = round(
        _fill(dedicated["segments"], dedicated["padded_segments"]), 4)
    multi = {
        "dispatches": int(d["dispatches"]),
        "padded_segments": int(d["padded_segments"]),
        "segments": int(d["segments"]),
        "cross_stream_dispatches": int(d["cross_stream_dispatches"]),
        "coalesced_dispatches": int(d["coalesced_dispatches"]),
        "aggregate_segments_per_s": round(n_sessions * n_ref / t_multi, 3),
        "end_to_end_s": round(t_multi, 3),
        "per_session_p99_s": multi_p99,
        "bucket_fill_rate": round(_fill(int(d["segments"]),
                                        int(d["padded_segments"])), 4),
    }
    record = {
        "sessions": n_sessions,
        "segments_per_session": n_ref,
        "segment_buckets": list(scfg.segment_buckets),
        "policy": "throughput",
        "multi": multi,
        "dedicated": dedicated,
    }
    print(f"\nmulti-stream sweep ({n_sessions} trickle sessions x "
          f"{n_ref} segments, policy=throughput, "
          f"buckets {scfg.segment_buckets}):")
    print(f"{'arrangement':<14}{'agg seg/s':>10}{'p99 s':>8}"
          f"{'dispatches':>11}{'fill rate':>10}{'cross':>7}")
    print(f"{'dedicated xN':<14}{dedicated['aggregate_segments_per_s']:>10.2f}"
          f"{max(ded_p99):>8.3f}{dedicated['dispatches']:>11d}"
          f"{dedicated['bucket_fill_rate']:>10.3f}{'-':>7}")
    print(f"{'multi-stream':<14}{multi['aggregate_segments_per_s']:>10.2f}"
          f"{max(multi_p99.values()):>8.3f}{multi['dispatches']:>11d}"
          f"{multi['bucket_fill_rate']:>10.3f}"
          f"{multi['cross_stream_dispatches']:>7d}")
    print(f"OK: all {n_sessions} multi-stream sessions are bitwise-equal "
          f"to offline")
    return record


def session_churn_sweep(cam, dsi_cfg, traj, ev, opts, e_frame, frames,
                        ref, n_stayers: int,
                        table: CostTable | None = None) -> dict:
    """Membership churn under load: `n_stayers` steady trickle sessions
    plus one double-rate "leaver" that flushes out mid-run and one
    double-rate "joiner" admitted on the fly after the leave. Every
    session — including the churned ones — pushes the full sequence, so
    all results must be bitwise-equal to offline; the dispatcher-level
    gate is structural (cross-stream coalescing alive on both sides of
    the membership change, empty queue at the end)."""
    segs = plan_segments(frames, dsi_cfg, opts)
    n_ref = len(ref.segments)
    top = next(b for b in (4, 3, 5, 7) if n_ref % b != 0)
    scfg = StreamConfig(events_per_frame=e_frame,
                        dispatch_policy="throughput",
                        segment_buckets=(1, 2, top) if top > 2 else (1, 2))
    _precompile_variants(cam, dsi_cfg, frames, segs, opts, scfg)
    profiler = SweepProfiler(table=table) if table is not None else None
    engine = MultiStreamEngine(cam, dsi_cfg, opts, scfg, profiler=profiler)
    chunks = list(iter_event_chunks(ev, e_frame))
    half = len(chunks) // 2

    times: dict[str, list[float]] = {}
    t0 = time.perf_counter()

    def _track(handle, emitted) -> None:
        now = time.perf_counter() - t0
        times.setdefault(handle.session_id, []).extend([now] * len(emitted))

    def _settle(handle, res) -> None:
        t_now = time.perf_counter() - t0
        done = times.setdefault(handle.session_id, [])
        done += [t_now] * (len(res.segments) - len(done))

    stayers = [engine.add_session(f"stay{i}", traj=traj)
               for i in range(n_stayers)]
    # phase A: stayers at 1x over the first half, leaver at 2x over the
    # whole sequence — it finishes its stream while the stayers are
    # mid-flight, then flushes out (the dispatcher keeps serving them)
    leaver = engine.add_session("leaver", traj=traj)
    for i, chunk in enumerate(chunks[:half]):
        for h in stayers:
            _track(h, h.push(chunk))
        for j in (2 * i, 2 * i + 1):
            if j < len(chunks):
                _track(leaver, leaver.push(chunks[j]))
    for j in range(2 * half, len(chunks)):  # odd chunk-count remainder
        _track(leaver, leaver.push(chunks[j]))
    cross_before = int(engine.stats["dispatcher"]["cross_stream_dispatches"])
    res_leaver = leaver.flush()
    _settle(leaver, res_leaver)
    _assert_bitwise(res_leaver, ref, "churn leaver")

    # phase B: joiner admitted mid-flight, streams the full sequence at
    # 2x while the stayers finish their second half
    joiner = engine.add_session("joiner", traj=traj)
    rest = chunks[half:]
    for i, chunk in enumerate(rest):
        for h in stayers:
            _track(h, h.push(chunk))
        for j in (2 * i, 2 * i + 1):
            if j < len(chunks):
                _track(joiner, joiner.push(chunks[j]))
    for j in range(2 * len(rest), len(chunks)):
        _track(joiner, joiner.push(chunks[j]))
    for h in [*stayers, joiner]:
        res = h.flush()
        _settle(h, res)
        _assert_bitwise(res, ref, f"churn session {h.session_id}")
    t_total = time.perf_counter() - t0

    d = engine.stats["dispatcher"]
    n_sessions_total = n_stayers + 2
    record = {
        "stayers": n_stayers,
        "segments_per_session": n_ref,
        "segment_buckets": list(scfg.segment_buckets),
        "policy": "throughput",
        "end_to_end_s": round(t_total, 3),
        "aggregate_segments_per_s": round(
            n_sessions_total * n_ref / t_total, 3),
        "per_session_p99_s": {
            sid: round(float(np.percentile(np.asarray(ts), 99)), 3)
            for sid, ts in times.items()},
        "dispatches": int(d["dispatches"]),
        "segments": int(d["segments"]),
        "coalesced_dispatches": int(d["coalesced_dispatches"]),
        "cross_stream_dispatches": int(d["cross_stream_dispatches"]),
        "cross_stream_before_leave": cross_before,
        "cross_stream_after_join": int(d["cross_stream_dispatches"])
        - cross_before,
        "pending_segments": int(d["pending_segments"]),
    }
    print(f"\nsession-churn sweep ({n_stayers} stayers + leaver + joiner x "
          f"{n_ref} segments, policy=throughput, "
          f"buckets {scfg.segment_buckets}):")
    print(f"  {record['dispatches']} dispatches / {record['segments']} "
          f"segments, cross-stream {cross_before} before leave + "
          f"{record['cross_stream_after_join']} after join, "
          f"agg {record['aggregate_segments_per_s']:.2f} seg/s, "
          f"p99 {max(record['per_session_p99_s'].values()):.3f}s")
    print(f"OK: all {n_sessions_total} churned sessions are bitwise-equal "
          f"to offline")
    return record


def cost_model_section(table: CostTable, table_path: str) -> dict:
    """Persist the measured cost table, fit the affine model, and run
    the burst replay gate per measured backend. Returns the
    "cost_model" section record; the gate asserts are applied by the
    caller AFTER the section persists (same discipline as the policy
    gate)."""
    table.save(table_path)
    _, report = fit_affine_model(table)
    print(f"\ncost model ({len(table)} measured variants -> {table_path}):")
    for backend, rec in sorted(report["backends"].items()):
        print(f"  [{backend}] overhead {rec['overhead_s'] * 1e3:.3f} ms + "
              f"{rec['rate_s_per_row'] * 1e6:.2f} us/row; rel error mean "
              f"{100 * rec['mean_rel_error']:.1f}% max "
              f"{100 * rec['max_rel_error']:.1f}%")
    gates = []
    for backend in sorted({key.backend for key in table.keys()}):
        try:
            g = check_slo_burst(table, backend=backend)
        except AssertionError as exc:
            # record the regression so the persisted artifact explains
            # it; the caller re-raises after update_bench_json
            gates.append({"backend": backend, "failure": str(exc)})
            continue
        gates.append(g)
        tp, slo = g["throughput"], g["slo_adaptive"]
        print(f"  [{g['backend']}] burst replay: throughput "
              f"{tp['dispatch_count']} dispatches p99 "
              f"{tp['predicted_p99_s']:.4f}s; SLO-adaptive "
              f"{slo['dispatch_count']} dispatches p99 "
              f"{slo['predicted_p99_s']:.4f}s (deadline "
              f"{g['target_latency_s']:.4f}s)")
    return {
        "table_path": table_path,
        "measured_variants": len(table),
        "calibration": report,
        "slo_burst_gates": gates,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny sequence for CI smoke (same code path)")
    ap.add_argument("--chunk-frames", type=int, default=1,
                    help="chunk size in aggregated frames")
    ap.add_argument("--json-out", default=None,
                    help="BENCH_emvs.json path (default: repo cwd)")
    ap.add_argument("--cost-table", default="cost_table.json",
                    help="where to persist the measured sweep cost table "
                         "(default: ./cost_table.json)")
    args = ap.parse_args()

    cam, traj, ev, e_frame, dsi_cfg = build_sequence(args.dry_run)
    opts = EMVSOptions(keyframe_dist_frac=0.02)
    frames = aggregate(cam, ev, traj, events_per_frame=e_frame)
    segs = plan_segments(frames, dsi_cfg, opts)
    caps = sorted({bucket_capacity(b - a) for a, b in segs})
    n_events = int(ev.t.shape[0])
    print(f"sequence: {n_events} events -> {frames.xy.shape[0]} frames x "
          f"{e_frame} events, {len(segs)} segments, capacities {caps}")

    # --- offline reference: nothing before the end of the trajectory ------
    jax.clear_caches()
    t0 = time.perf_counter()
    ref = run_emvs(cam, dsi_cfg, frames, opts)
    for seg in ref.segments:
        seg.depth_map.depth.block_until_ready()
    t_offline = time.perf_counter() - t0

    # --- streaming: depth maps while events still arrive ------------------
    scfg = StreamConfig(events_per_frame=e_frame)
    jax.clear_caches()
    res, t_total, timeline, stream_stats = _stream_policy_once(
        cam, dsi_cfg, traj, ev, opts, scfg, args.chunk_frames * e_frame)

    # --- checks -----------------------------------------------------------
    _assert_bitwise(res, ref, "streaming (nearest voting)")
    variants = process_segments_batched._cache_size()
    bound = len(scfg.segment_buckets) * len(caps)
    assert variants <= bound, f"jit cache {variants} exceeds bound {bound}"

    first = timeline[0][0]
    gaps = [t for t, _ in timeline]
    print(f"\nnumerical match: bitwise ({len(res.segments)} segments); "
          f"compiled sweep variants: {variants} (bound {bound})")
    print(f"\n{'metric':<34}{'offline':>12}{'streaming':>12}")
    print(f"{'end-to-end s':<34}{t_offline:>12.2f}{t_total:>12.2f}")
    print(f"{'first depth map s':<34}{t_offline:>12.2f}{first:>12.2f}")
    print(f"{'events/s (M)':<34}{n_events / t_offline / 1e6:>12.3f}"
          f"{n_events / t_total / 1e6:>12.3f}")
    print(f"\nper-segment completion times (s): "
          f"{', '.join(f'{t:.2f}' for t in gaps)}")
    print(f"streaming stats: {stream_stats}")
    print(f"\nfirst-segment latency speedup vs offline end-to-end: "
          f"{t_offline / first:.2f}x")
    assert first < t_offline, (
        f"first-segment latency {first:.2f}s not below offline "
        f"end-to-end {t_offline:.2f}s")
    print("OK: first depth map arrives before the offline path finishes")

    # --- pose-lag sweep: tracker trailing the event front -----------------
    duration = float(np.asarray(ev.t)[-1]) - float(np.asarray(ev.t)[0])
    lags = [0.0, round(0.1 * duration, 4), round(0.3 * duration, 4)]
    print(f"\npose-lag sweep (sequence duration {duration:.2f}s):")
    print(f"{'lag s':<10}{'first depth s':>14}{'end-to-end s':>14}"
          f"{'max stalled':>12}{'watermark':>12}")
    pose_lag_rows = []
    for lag in lags:
        jax.clear_caches()
        lag_res, lag_first, lag_total, stats = stream_with_pose_lag(
            cam, dsi_cfg, traj, ev, opts, scfg, lag,
            args.chunk_frames * e_frame)
        _assert_bitwise(lag_res, ref, f"pose lag {lag}s")
        print(f"{lag:<10.3f}{lag_first:>14.2f}{lag_total:>14.2f}"
              f"{stats['max_stalled']:>12d}{stats['pose_watermark']:>12.3f}")
        pose_lag_rows.append({
            "lag_s": lag,
            "first_depth_latency_s": round(lag_first, 3),
            "end_to_end_s": round(lag_total, 3),
            "max_stalled_frames": int(stats["max_stalled"]),
            "pose_watermark": round(float(stats["pose_watermark"]), 4),
            "pose_chunks": int(stats["pose_chunks"]),
        })
    print("OK: reconstruction is pose-lag invariant (bitwise)")

    # --- dispatch-policy sweep + regression gate --------------------------
    cost_table = CostTable()
    policy_rows = dispatch_policy_sweep(cam, dsi_cfg, traj, ev, opts, e_frame,
                                        frames, ref,
                                        repeats=3 if args.dry_run else 5,
                                        table=cost_table)
    burst = {r["policy"]: r for r in policy_rows if r["profile"] == "burst"}
    # The gate has two parts. STRUCTURAL (all run sizes): under burst the
    # adaptive policy must actually coalesce — fewer dispatches than
    # segments — which is deterministic, immune to timing noise, and
    # catches the real regression class (the coalescer silently
    # degenerating to per-segment dispatch). TIMING: adaptive sustained
    # segments/s must not fall below min_ratio x the per-segment
    # baseline; strict (1.0) on the full-size run, but the CI smoke's
    # sub-second burst runs have been measured to jitter by ~10% even on
    # an idle machine, so the dry-run timing check is a loose crash
    # barrier (0.85) against gross slowdowns, not a tie-breaker the
    # noise can flip. Both travel in the gate record so the ci.yml
    # re-check applies the same rules.
    gate = {
        "profile": "burst",
        "adaptive_segments_per_s": burst["adaptive"]["segments_per_s"],
        "latency_segments_per_s": burst["latency"]["segments_per_s"],
        "adaptive_dispatches": burst["adaptive"]["dispatches"],
        "adaptive_coalesced_dispatches":
            burst["adaptive"]["coalesced_dispatches"],
        "segments": len(ref.segments),
        "min_ratio": 0.85 if args.dry_run else 1.0,
    }
    update_bench_json("dispatch_policy_sweep", {
        "dry_run": bool(args.dry_run),
        "rows": policy_rows,
        "gate": gate,
    }, path=args.json_out)

    # --- multi-stream sweep: shared dispatcher vs N dedicated engines -----
    multi_rec = multi_stream_sweep(cam, dsi_cfg, traj, ev, opts, e_frame,
                                   frames, ref,
                                   n_sessions=3 if args.dry_run else 4)
    multi_rec["dry_run"] = bool(args.dry_run)
    update_bench_json("multi_stream_sweep", multi_rec, path=args.json_out)

    # --- session churn: membership changes under load ---------------------
    churn_rec = session_churn_sweep(cam, dsi_cfg, traj, ev, opts, e_frame,
                                    frames, ref,
                                    n_stayers=2 if args.dry_run else 3,
                                    table=cost_table)
    churn_rec["dry_run"] = bool(args.dry_run)
    update_bench_json("session_churn", churn_rec, path=args.json_out)

    # --- measured cost model + burst replay gate --------------------------
    cost_rec = cost_model_section(cost_table, args.cost_table)
    cost_rec["dry_run"] = bool(args.dry_run)
    update_bench_json("cost_model", cost_rec, path=args.json_out)

    path = update_bench_json("streaming_latency", {
        "dry_run": bool(args.dry_run),
        "events": n_events,
        "segments": len(res.segments),
        "offline_end_to_end_s": round(t_offline, 3),
        "streaming_end_to_end_s": round(t_total, 3),
        "first_depth_latency_s": round(first, 3),
        "first_depth_speedup": round(t_offline / first, 3),
        "compiled_variants": int(variants),
        "pose_lag_sweep": pose_lag_rows,
    }, path=args.json_out)
    print(f"wrote {path}")

    # gate LAST, after every section is persisted: a failing gate must
    # not cost the artifact the comparison data that explains it
    assert (gate["adaptive_coalesced_dispatches"] >= 1
            and gate["adaptive_dispatches"] < gate["segments"]), (
        f"REGRESSION: adaptive policy stopped coalescing under burst "
        f"({gate['adaptive_dispatches']} dispatches for "
        f"{gate['segments']} segments, "
        f"{gate['adaptive_coalesced_dispatches']} coalesced) — it has "
        f"degenerated to per-segment dispatch")
    floor = gate["min_ratio"] * gate["latency_segments_per_s"]
    assert gate["adaptive_segments_per_s"] >= floor, (
        f"REGRESSION: adaptive policy sustains "
        f"{gate['adaptive_segments_per_s']} segments/s under burst, below "
        f"{gate['min_ratio']:g}x the per-segment baseline "
        f"{gate['latency_segments_per_s']} — coalescing must not cost "
        f"throughput")
    print(f"OK: adaptive coalesces under burst "
          f"({gate['adaptive_dispatches']} dispatches / "
          f"{gate['segments']} segments) and sustains "
          f"{gate['adaptive_segments_per_s']:.2f} segments/s vs the "
          f"per-segment baseline {gate['latency_segments_per_s']:.2f} "
          f"(min ratio {gate['min_ratio']:g})")

    # multi-stream gate: structural like the coalescing gate above —
    # dispatch counters, never timings, so CI noise cannot flip it
    m, ded = multi_rec["multi"], multi_rec["dedicated"]
    assert m["cross_stream_dispatches"] >= 1, (
        f"REGRESSION: the shared dispatcher never issued a cross-stream "
        f"group over {multi_rec['sessions']} concurrent trickle sessions "
        f"— cross-stream coalescing is dead")
    assert m["dispatches"] < ded["dispatches"], (
        f"REGRESSION: cross-stream coalescing stopped reducing dispatches "
        f"({m['dispatches']} shared vs {ded['dispatches']} across "
        f"{multi_rec['sessions']} dedicated engines)")
    print(f"OK: cross-stream coalescing cuts dispatches "
          f"{ded['dispatches']} -> {m['dispatches']} across "
          f"{multi_rec['sessions']} sessions "
          f"({m['cross_stream_dispatches']} cross-stream groups, bucket "
          f"fill rate {ded['bucket_fill_rate']:.3f} -> "
          f"{m['bucket_fill_rate']:.3f})")

    # session-churn gate: structural — membership change must not kill
    # cross-stream coalescing on either side, nor strand queued work
    assert churn_rec["pending_segments"] == 0, (
        "REGRESSION: dispatcher left work queued after session churn")
    assert (churn_rec["cross_stream_before_leave"] >= 1
            and churn_rec["cross_stream_after_join"] >= 1), (
        f"REGRESSION: cross-stream coalescing died across the membership "
        f"change ({churn_rec['cross_stream_before_leave']} groups before "
        f"the leave, {churn_rec['cross_stream_after_join']} after the "
        f"join)")
    assert churn_rec["dispatches"] < churn_rec["segments"], (
        f"REGRESSION: no coalescing under churn "
        f"({churn_rec['dispatches']} dispatches for "
        f"{churn_rec['segments']} segments)")
    print(f"OK: coalescing survives session churn "
          f"({churn_rec['cross_stream_before_leave']} cross-stream groups "
          f"before the leave, {churn_rec['cross_stream_after_join']} after "
          f"the join)")

    # cost-model gate: the burst replay must have passed per backend —
    # re-raise any failure recorded before the section persisted
    failed = [g for g in cost_rec["slo_burst_gates"] if "failure" in g]
    assert not failed, (
        "REGRESSION: SLO burst replay gate failed: "
        + "; ".join(f"[{g['backend']}] {g['failure']}" for g in failed))
    assert cost_rec["measured_variants"] >= 1, (
        "REGRESSION: profiler recorded no warm sweep samples")
    print(f"OK: SLO burst replay gate passed on the measured table "
          f"({cost_rec['measured_variants']} variants -> "
          f"{cost_rec['table_path']})")


if __name__ == "__main__":
    main()
