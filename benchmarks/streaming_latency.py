"""Streaming EMVS latency: time-to-first-depth-map vs the offline sweep.

The offline batched path (`run_emvs`) cannot emit anything until the
whole trajectory has arrived and every bucket has been swept; the
streaming engine closes a key-frame segment the moment the K criterion
trips and dispatches it while later events are still arriving. The
headline metric is therefore FIRST-SEGMENT LATENCY (stream start ->
first harvested depth map), which must be strictly below the offline
end-to-end time on the same sequence — otherwise streaming buys nothing.

Also reported: per-segment completion timeline, sustained events/s, and
the number of compiled sweep variants (must stay at
|segment_buckets| x |capacities| — the double-buffered dispatch pads
both the frame and the segment axes to fixed sizes).

Second axis: the POSE-LAG SWEEP. The realistic system receives poses
from a tracker running *behind* the event front; the engine's
pose-gated mode stalls frames past the pose-lag watermark until their
bracketing pose chunk arrives. The sweep streams the same sequence with
the pose stream lagging the event front by several delays and reports
first-depth latency and peak stall-queue depth per lag (results must
stay bit-identical to offline `run_emvs` at every lag). Both tables are
emitted into `BENCH_emvs.json` ("streaming_latency" section, with a
"pose_lag_sweep" list) for CI artifact tracking.

Both paths are measured cold (fresh jit caches): that is what a newly
started sensor pipeline pays.

    PYTHONPATH=src python benchmarks/streaming_latency.py [--dry-run]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

try:  # script invocation (python benchmarks/streaming_latency.py)
    from _emvs_common import update_bench_json
except ImportError:  # module invocation
    from benchmarks._emvs_common import update_bench_json

from repro.core.camera import CameraModel
from repro.core.dsi import DSIConfig
from repro.core.pipeline import (
    EMVSOptions,
    bucket_capacity,
    plan_segments,
    process_segments_batched,
    run_emvs,
)
from repro.events.aggregation import aggregate
from repro.events.simulator import (
    SceneConfig,
    make_scene,
    make_trajectory,
    simulate_events,
    slice_trajectory,
)
from repro.serving.emvs_stream import (
    EMVSStreamEngine,
    StreamConfig,
    iter_event_chunks,
)


def build_sequence(dry_run: bool):
    cam = CameraModel()
    # Dry-run stays CI-sized but long enough that offline end-to-end
    # (which scales with the sequence) clearly separates from
    # first-segment latency (which does not): the gating assert below
    # must not sit within scheduler noise of a shared runner.
    steps, points, e_frame, planes = (
        (96, 100, 256, 8) if dry_run else (144, 200, 512, 16))
    scene = make_scene(SceneConfig(name="simulation_3planes",
                                   points_per_plane=points))
    traj = make_trajectory("simulation_3planes", steps)
    ev = simulate_events(cam, scene, traj, noise_fraction=0.02, seed=0)
    dsi_cfg = DSIConfig.for_camera(cam, num_planes=planes, z_min=0.6, z_max=4.5)
    return cam, traj, ev, e_frame, dsi_cfg


def stream_with_pose_lag(cam, dsi_cfg, traj, ev, opts, scfg,
                         lag_s: float, chunk_events: int):
    """Stream events with the pose stream trailing the event front by
    `lag_s` seconds (tracker model). Returns (result, first-depth
    latency, end-to-end time, engine stats)."""
    engine = EMVSStreamEngine(cam, dsi_cfg, None, opts, scfg)
    pose_t = np.asarray(traj.times)
    sent = 0
    first = None
    t0 = time.perf_counter()
    for chunk in iter_event_chunks(ev, chunk_events):
        if engine.push(chunk) and first is None:
            first = time.perf_counter() - t0
        front = float(np.asarray(chunk.t)[-1]) - lag_s
        hi = int(np.searchsorted(pose_t, front, side="right"))
        if hi > sent:
            got = engine.push_poses(slice_trajectory(traj, sent, hi))
            sent = hi
            if got and first is None:
                first = time.perf_counter() - t0
    # tracker drains after the sensor: deliver the rest, close the stream
    if sent < pose_t.shape[0]:
        got = engine.push_poses(slice_trajectory(traj, sent, pose_t.shape[0]))
        if got and first is None:
            first = time.perf_counter() - t0
    engine.finalize_poses()
    res = engine.flush()
    t_total = time.perf_counter() - t0
    return res, (t_total if first is None else first), t_total, engine.stats


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny sequence for CI smoke (same code path)")
    ap.add_argument("--chunk-frames", type=int, default=1,
                    help="chunk size in aggregated frames")
    ap.add_argument("--json-out", default=None,
                    help="BENCH_emvs.json path (default: repo cwd)")
    args = ap.parse_args()

    cam, traj, ev, e_frame, dsi_cfg = build_sequence(args.dry_run)
    opts = EMVSOptions(keyframe_dist_frac=0.02)
    frames = aggregate(cam, ev, traj, events_per_frame=e_frame)
    segs = plan_segments(frames, dsi_cfg, opts)
    caps = sorted({bucket_capacity(b - a) for a, b in segs})
    n_events = int(ev.t.shape[0])
    print(f"sequence: {n_events} events -> {frames.xy.shape[0]} frames x "
          f"{e_frame} events, {len(segs)} segments, capacities {caps}")

    # --- offline reference: nothing before the end of the trajectory ------
    jax.clear_caches()
    t0 = time.perf_counter()
    ref = run_emvs(cam, dsi_cfg, frames, opts)
    for seg in ref.segments:
        seg.depth_map.depth.block_until_ready()
    t_offline = time.perf_counter() - t0

    # --- streaming: depth maps while events still arrive ------------------
    scfg = StreamConfig(events_per_frame=e_frame)
    jax.clear_caches()
    engine = EMVSStreamEngine(cam, dsi_cfg, traj, opts, scfg)
    timeline: list[tuple[float, tuple[int, int]]] = []
    t0 = time.perf_counter()
    for chunk in iter_event_chunks(ev, args.chunk_frames * e_frame):
        for seg in engine.push(chunk):
            timeline.append((time.perf_counter() - t0, seg.frame_range))
    res = engine.flush()
    t_total = time.perf_counter() - t0
    done = {fr for _, fr in timeline}
    timeline += [(t_total, s.frame_range) for s in res.segments
                 if s.frame_range not in done]

    # --- checks -----------------------------------------------------------
    assert [s.frame_range for s in res.segments] == \
        [s.frame_range for s in ref.segments], "segment boundaries diverged"
    worst = 0.0
    for sa, sb in zip(res.segments, ref.segments):
        worst = max(worst, float(np.abs(
            np.asarray(sa.dsi, np.float32) - np.asarray(sb.dsi, np.float32)
        ).max()))
    assert worst == 0.0, f"nearest voting must match offline bitwise: {worst}"
    variants = process_segments_batched._cache_size()
    bound = len(scfg.segment_buckets) * len(caps)
    assert variants <= bound, f"jit cache {variants} exceeds bound {bound}"

    first = timeline[0][0]
    gaps = [t for t, _ in timeline]
    print(f"\nnumerical match: bitwise ({len(res.segments)} segments); "
          f"compiled sweep variants: {variants} (bound {bound})")
    print(f"\n{'metric':<34}{'offline':>12}{'streaming':>12}")
    print(f"{'end-to-end s':<34}{t_offline:>12.2f}{t_total:>12.2f}")
    print(f"{'first depth map s':<34}{t_offline:>12.2f}{first:>12.2f}")
    print(f"{'events/s (M)':<34}{n_events / t_offline / 1e6:>12.3f}"
          f"{n_events / t_total / 1e6:>12.3f}")
    print(f"\nper-segment completion times (s): "
          f"{', '.join(f'{t:.2f}' for t in gaps)}")
    print(f"streaming stats: {engine.stats}")
    print(f"\nfirst-segment latency speedup vs offline end-to-end: "
          f"{t_offline / first:.2f}x")
    assert first < t_offline, (
        f"first-segment latency {first:.2f}s not below offline "
        f"end-to-end {t_offline:.2f}s")
    print("OK: first depth map arrives before the offline path finishes")

    # --- pose-lag sweep: tracker trailing the event front -----------------
    duration = float(np.asarray(ev.t)[-1]) - float(np.asarray(ev.t)[0])
    lags = [0.0, round(0.1 * duration, 4), round(0.3 * duration, 4)]
    print(f"\npose-lag sweep (sequence duration {duration:.2f}s):")
    print(f"{'lag s':<10}{'first depth s':>14}{'end-to-end s':>14}"
          f"{'max stalled':>12}{'watermark':>12}")
    pose_lag_rows = []
    for lag in lags:
        jax.clear_caches()
        lag_res, lag_first, lag_total, stats = stream_with_pose_lag(
            cam, dsi_cfg, traj, ev, opts, scfg, lag,
            args.chunk_frames * e_frame)
        assert [s.frame_range for s in lag_res.segments] == \
            [s.frame_range for s in ref.segments], \
            f"pose lag {lag}s changed segment boundaries"
        lag_worst = 0.0
        for sa, sb in zip(lag_res.segments, ref.segments):
            lag_worst = max(lag_worst, float(np.abs(
                np.asarray(sa.dsi, np.float32)
                - np.asarray(sb.dsi, np.float32)).max()))
        assert lag_worst == 0.0, (
            f"pose lag {lag}s must not change the reconstruction "
            f"(max DSI delta {lag_worst})")
        print(f"{lag:<10.3f}{lag_first:>14.2f}{lag_total:>14.2f}"
              f"{stats['max_stalled']:>12d}{stats['pose_watermark']:>12.3f}")
        pose_lag_rows.append({
            "lag_s": lag,
            "first_depth_latency_s": round(lag_first, 3),
            "end_to_end_s": round(lag_total, 3),
            "max_stalled_frames": int(stats["max_stalled"]),
            "pose_watermark": round(float(stats["pose_watermark"]), 4),
            "pose_chunks": int(stats["pose_chunks"]),
        })
    print("OK: reconstruction is pose-lag invariant (bitwise)")

    path = update_bench_json("streaming_latency", {
        "dry_run": bool(args.dry_run),
        "events": n_events,
        "segments": len(res.segments),
        "offline_end_to_end_s": round(t_offline, 3),
        "streaming_end_to_end_s": round(t_total, 3),
        "first_depth_latency_s": round(first, 3),
        "first_depth_speedup": round(t_offline / first, 3),
        "compiled_variants": int(variants),
        "pose_lag_sweep": pose_lag_rows,
    }, path=args.json_out)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
