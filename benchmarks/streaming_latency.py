"""Streaming EMVS latency: time-to-first-depth-map vs the offline sweep.

The offline batched path (`run_emvs`) cannot emit anything until the
whole trajectory has arrived and every bucket has been swept; the
streaming engine closes a key-frame segment the moment the K criterion
trips and dispatches it while later events are still arriving. The
headline metric is therefore FIRST-SEGMENT LATENCY (stream start ->
first harvested depth map), which must be strictly below the offline
end-to-end time on the same sequence — otherwise streaming buys nothing.

Also reported: per-segment completion timeline, sustained events/s, and
the number of compiled sweep variants (must stay at
|segment_buckets| x |capacities| — the double-buffered dispatch pads
both the frame and the segment axes to fixed sizes).

Both paths are measured cold (fresh jit caches): that is what a newly
started sensor pipeline pays.

    PYTHONPATH=src python benchmarks/streaming_latency.py [--dry-run]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

try:  # script invocation (python benchmarks/streaming_latency.py)
    from _emvs_common import update_bench_json
except ImportError:  # module invocation
    from benchmarks._emvs_common import update_bench_json

from repro.core.camera import CameraModel
from repro.core.dsi import DSIConfig
from repro.core.pipeline import (
    EMVSOptions,
    bucket_capacity,
    plan_segments,
    process_segments_batched,
    run_emvs,
)
from repro.events.aggregation import aggregate
from repro.events.simulator import (
    SceneConfig,
    make_scene,
    make_trajectory,
    simulate_events,
)
from repro.serving.emvs_stream import (
    EMVSStreamEngine,
    StreamConfig,
    iter_event_chunks,
)


def build_sequence(dry_run: bool):
    cam = CameraModel()
    # Dry-run stays CI-sized but long enough that offline end-to-end
    # (which scales with the sequence) clearly separates from
    # first-segment latency (which does not): the gating assert below
    # must not sit within scheduler noise of a shared runner.
    steps, points, e_frame, planes = (
        (96, 100, 256, 8) if dry_run else (144, 200, 512, 16))
    scene = make_scene(SceneConfig(name="simulation_3planes",
                                   points_per_plane=points))
    traj = make_trajectory("simulation_3planes", steps)
    ev = simulate_events(cam, scene, traj, noise_fraction=0.02, seed=0)
    dsi_cfg = DSIConfig.for_camera(cam, num_planes=planes, z_min=0.6, z_max=4.5)
    return cam, traj, ev, e_frame, dsi_cfg


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny sequence for CI smoke (same code path)")
    ap.add_argument("--chunk-frames", type=int, default=1,
                    help="chunk size in aggregated frames")
    ap.add_argument("--json-out", default=None,
                    help="BENCH_emvs.json path (default: repo cwd)")
    args = ap.parse_args()

    cam, traj, ev, e_frame, dsi_cfg = build_sequence(args.dry_run)
    opts = EMVSOptions(keyframe_dist_frac=0.02)
    frames = aggregate(cam, ev, traj, events_per_frame=e_frame)
    segs = plan_segments(frames, dsi_cfg, opts)
    caps = sorted({bucket_capacity(b - a) for a, b in segs})
    n_events = int(ev.t.shape[0])
    print(f"sequence: {n_events} events -> {frames.xy.shape[0]} frames x "
          f"{e_frame} events, {len(segs)} segments, capacities {caps}")

    # --- offline reference: nothing before the end of the trajectory ------
    jax.clear_caches()
    t0 = time.perf_counter()
    ref = run_emvs(cam, dsi_cfg, frames, opts)
    for seg in ref.segments:
        seg.depth_map.depth.block_until_ready()
    t_offline = time.perf_counter() - t0

    # --- streaming: depth maps while events still arrive ------------------
    scfg = StreamConfig(events_per_frame=e_frame)
    jax.clear_caches()
    engine = EMVSStreamEngine(cam, dsi_cfg, traj, opts, scfg)
    timeline: list[tuple[float, tuple[int, int]]] = []
    t0 = time.perf_counter()
    for chunk in iter_event_chunks(ev, args.chunk_frames * e_frame):
        for seg in engine.push(chunk):
            timeline.append((time.perf_counter() - t0, seg.frame_range))
    res = engine.flush()
    t_total = time.perf_counter() - t0
    done = {fr for _, fr in timeline}
    timeline += [(t_total, s.frame_range) for s in res.segments
                 if s.frame_range not in done]

    # --- checks -----------------------------------------------------------
    assert [s.frame_range for s in res.segments] == \
        [s.frame_range for s in ref.segments], "segment boundaries diverged"
    worst = 0.0
    for sa, sb in zip(res.segments, ref.segments):
        worst = max(worst, float(np.abs(
            np.asarray(sa.dsi, np.float32) - np.asarray(sb.dsi, np.float32)
        ).max()))
    assert worst == 0.0, f"nearest voting must match offline bitwise: {worst}"
    variants = process_segments_batched._cache_size()
    bound = len(scfg.segment_buckets) * len(caps)
    assert variants <= bound, f"jit cache {variants} exceeds bound {bound}"

    first = timeline[0][0]
    gaps = [t for t, _ in timeline]
    print(f"\nnumerical match: bitwise ({len(res.segments)} segments); "
          f"compiled sweep variants: {variants} (bound {bound})")
    print(f"\n{'metric':<34}{'offline':>12}{'streaming':>12}")
    print(f"{'end-to-end s':<34}{t_offline:>12.2f}{t_total:>12.2f}")
    print(f"{'first depth map s':<34}{t_offline:>12.2f}{first:>12.2f}")
    print(f"{'events/s (M)':<34}{n_events / t_offline / 1e6:>12.3f}"
          f"{n_events / t_total / 1e6:>12.3f}")
    print(f"\nper-segment completion times (s): "
          f"{', '.join(f'{t:.2f}' for t in gaps)}")
    print(f"streaming stats: {engine.stats}")
    print(f"\nfirst-segment latency speedup vs offline end-to-end: "
          f"{t_offline / first:.2f}x")
    assert first < t_offline, (
        f"first-segment latency {first:.2f}s not below offline "
        f"end-to-end {t_offline:.2f}s")
    print("OK: first depth map arrives before the offline path finishes")

    path = update_bench_json("streaming_latency", {
        "dry_run": bool(args.dry_run),
        "events": n_events,
        "segments": len(res.segments),
        "offline_end_to_end_s": round(t_offline, 3),
        "streaming_end_to_end_s": round(t_total, 3),
        "first_depth_latency_s": round(first, 3),
        "first_depth_speedup": round(t_offline / first, 3),
        "compiled_variants": int(variants),
    }, path=args.json_out)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
