"""Paper Table 3: per-frame runtime breakdown and event processing rate.

The paper's columns are Intel i5 (software EMVS) vs Eventor (FPGA). The
portable analogue here:

  * "software path"   — scatter-formulation EMVS (the CPU-idiomatic
                         algorithm the paper ran on the i5), jit-compiled
  * "accelerated path" — our TPU-native one-hot-matmul formulation (the
                         Eventor analogue; on real v5e hardware this is
                         the path the dry-run/roofline characterizes)

Both are measured wall-clock on this host for the *structure* of Table 3
(P(Z0) vs P(Z0->Zi)&R split, normal vs key frames, Mev/s). Absolute
numbers are CPU-host numbers, not TPU numbers — the roofline report
covers the target-hardware projection.

Pipelining (paper Fig 6): for normal frames the P(Z0) stage of frame
f+1 overlaps the PE_Zi work of frame f, so the effective per-frame time
is max(stages) for normal frames and sum(stages) for key frames.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks._emvs_common import sequence
from repro.core.geometry import SE3, apply_homography, propagate_to_planes
from repro.core.pipeline import EMVSOptions, precompute_segment_geometry
from repro.core.voting import vote_onehot_matmul, vote_scatter

EVENTS_PER_FRAME = 1024


def _time(fn, *args, reps: int = 20) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def run() -> dict:
    cam, scene, frames, dsi_cfg = sequence("simulation_3planes")
    planes = dsi_cfg.planes()
    z0 = planes[dsi_cfg.num_planes // 2]
    T_w_ref = SE3(frames.poses.R[0], frames.poses.t[0])
    geoms = precompute_segment_geometry(cam, frames, T_w_ref, planes, z0)
    xy, valid = frames.xy[0], frames.valid[0].astype(jnp.float32)
    H, phi = geoms.H[0], jax.tree.map(lambda a: a[0], geoms.phi)

    # stage P(Z0)
    p_z0 = jax.jit(lambda H, xy: apply_homography(H, xy))
    t_pz0 = _time(p_z0, H, xy)

    # stage P(Z0->Zi) + R, both formulations
    @jax.jit
    def prop_and_vote_scatter(xy0, valid, alpha, bx, by):
        from repro.core.geometry import PlaneSweepCoeffs

        x_i, y_i = propagate_to_planes(cam, xy0, PlaneSweepCoeffs(alpha, bx, by))
        dsi = jnp.zeros(dsi_cfg.shape, jnp.int32)
        w = jnp.broadcast_to(valid[None, :], x_i.shape)
        return vote_scatter(dsi, x_i, y_i, w=cam.width, h=cam.height,
                            mode="nearest", weights=w)

    @jax.jit
    def prop_and_vote_matmul(xy0, valid, alpha, bx, by):
        from repro.core.geometry import PlaneSweepCoeffs

        x_i, y_i = propagate_to_planes(cam, xy0, PlaneSweepCoeffs(alpha, bx, by))
        dsi = jnp.zeros((dsi_cfg.num_planes, cam.height, cam.width), jnp.float32)
        w = jnp.broadcast_to(valid[None, :], x_i.shape)
        return vote_onehot_matmul(dsi, x_i, y_i, w=cam.width, h=cam.height,
                                  mode="nearest", weights=w)

    xy0 = p_z0(H, xy)
    t_sw = _time(prop_and_vote_scatter, xy0, valid, phi.alpha, phi.beta_x,
                 phi.beta_y)
    t_hw = _time(prop_and_vote_matmul, xy0, valid, phi.alpha, phi.beta_x,
                 phi.beta_y)

    def pack(t_stage2):
        normal = max(t_pz0, t_stage2)  # pipelined (Fig 6 upper)
        key = t_pz0 + t_stage2  # serial (Fig 6 lower)
        return {
            "P(Z0) us": t_pz0 * 1e6,
            "P(Z0->Zi)&R us": t_stage2 * 1e6,
            "normal frame us": normal * 1e6,
            "key frame us": key * 1e6,
            "normal Mev/s": EVENTS_PER_FRAME / normal / 1e6,
            "key Mev/s": EVENTS_PER_FRAME / key / 1e6,
        }

    # --- TPU v5e projection of the matmul formulation -------------------
    # votes = Oy^T @ Ox per plane: 2 * E * (h + w) * min(h,w)-free matmul
    # ~= 2 * E * h_pad * w_pad MACs per plane. With Nz=dsi planes:
    from repro.launch.roofline import HBM_BW, PEAK_FLOPS

    e, nz = EVENTS_PER_FRAME, dsi_cfg.num_planes
    h_pad, w_pad = 184, 256  # kernel tile padding (SUBLANE/LANE aligned)
    flops_frame = 2.0 * e * h_pad * w_pad * nz  # one-hot matmul votes
    bytes_frame = (nz * h_pad * w_pad * 4  # DSI block revisit (fp32 acc)
                   + e * 4 * 4)  # event coords + phi traffic
    t_mxu = flops_frame / PEAK_FLOPS
    t_hbm = bytes_frame / HBM_BW
    t_frame_v5e = max(t_mxu, t_hbm)
    # §Perf E1: int8 one-hot rows are exact (0/1 values, int32 accumulate)
    # and run the MXU at 2x the bf16 rate (v5e: 394 TOPS int8)
    t_mxu_int8 = flops_frame / (2 * PEAK_FLOPS)
    t_frame_int8 = max(t_mxu_int8, t_hbm)
    v5e = {
        "flops/frame": flops_frame,
        "bytes/frame": bytes_frame,
        "MXU-bound us": t_mxu * 1e6,
        "HBM-bound us": t_hbm * 1e6,
        "projected us/frame": t_frame_v5e * 1e6,
        "projected Mev/s/chip": e / t_frame_v5e / 1e6,
        "speedup vs paper Eventor": e / t_frame_v5e / 1e6 / 1.86,
        "int8 votes us/frame (E1)": t_frame_int8 * 1e6,
        "int8 votes Mev/s/chip (E1)": e / t_frame_int8 / 1e6,
        "int8 speedup vs Eventor": e / t_frame_int8 / 1e6 / 1.86,
    }

    return {"software_scatter": pack(t_sw), "matmul_eventor_analogue": pack(t_hw),
            "v5e_projection": v5e,
            "paper": {"cpu_normal_Mev/s": 1.76, "eventor_normal_Mev/s": 1.86,
                      "eventor_power_W": 1.86, "cpu_power_W": 45.0}}


def main() -> None:
    out = run()
    print("== Table 3: runtime per 1024-event frame (host measurements) ==")
    for name in ("software_scatter", "matmul_eventor_analogue"):
        r = out[name]
        print(f"-- {name} --")
        for k, v in r.items():
            print(f"   {k:18s} {v:12.2f}")
    print("-- v5e roofline projection (matmul formulation, per chip) --")
    for k, v in out["v5e_projection"].items():
        print(f"   {k:26s} {v:14.2f}")
    print("   NOTE: the matmul formulation is an MXU algorithm; its host-CPU")
    print("   wall time above is expected to LOSE to scatter on CPU.")
    p = out["paper"]
    print(f"paper reference: CPU {p['cpu_normal_Mev/s']} Mev/s @ "
          f"{p['cpu_power_W']} W; Eventor {p['eventor_normal_Mev/s']} Mev/s @ "
          f"{p['eventor_power_W']} W (24x energy efficiency)")


if __name__ == "__main__":
    main()
