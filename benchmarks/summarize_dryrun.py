"""Summarize dry-run JSON records into the EXPERIMENTS.md tables."""
from __future__ import annotations

import glob
import json
import os
import sys


def load(out_dir: str) -> list[dict]:
    recs = []
    for p in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def fmt_row(r: dict) -> str:
    tag = f"{r['arch']} × {r['cell']} × {r['mesh']}"
    if "skipped" in r:
        return f"| {tag} | SKIP: {r['skipped'][:60]} |||||||"
    rf = r["roofline"]
    mem = r["memory"].get("temp_bytes")
    mem_gb = f"{mem/2**30:.1f}" if isinstance(mem, (int, float)) else "?"
    frac = max(rf["compute_s"], 1e-12) / max(
        rf["compute_s"], rf["memory_s"], rf["collective_s"], 1e-12)
    return (f"| {tag} | {rf['flops']:.2e} | {rf['compute_s']:.3f} "
            f"| {rf['memory_s']:.3f} | {rf['collective_s']:.3f} "
            f"| {rf['dominant']} | {rf['useful_fraction']:.2f} | {frac:.2f} "
            f"| {mem_gb} | {r['compile_s']:.0f}s |")


HEADER = ("| cell | HLO flops/dev | compute s | memory s | collective s "
          "| dominant | useful | roofline-frac | temp GiB | compile |\n"
          "|---|---|---|---|---|---|---|---|---|---|")


def main() -> None:
    out_dir = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    recs = load(out_dir)
    print(HEADER)
    for r in recs:
        print(fmt_row(r))
    done = [r for r in recs if "skipped" not in r]
    print(f"\n{len(recs)} records, {len(done)} compiled, "
          f"{len(recs) - len(done)} skipped")


if __name__ == "__main__":
    main()
