"""Record cost_table.json entries for the fused-kernel sweep backend.

The DispatchPlanner can only price a variant it has samples for; the
table's pre-existing rows cover the matmul formulation ("batched"), so
without this recorder a `formulation="kernel"` stream would fall back to
the planner's uncalibrated prior. This measures warm wall times of
`sweep_segment_batch` with `formulation="kernel"` at the same
(s_bucket, capacity) grid points as the existing matmul rows and merges
them under the `batched+kernel` backend axis (`cost_table.backend_name`).

    PYTHONPATH=src python -m benchmarks.record_kernel_costs [--dry-run]

On CPU the kernel runs under the Pallas interpreter (the capability-
probed default), so the recorded costs price exactly what a CPU stream
would dispatch; on TPU/GPU the same command records the compiled kernel.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.camera import CameraModel
from repro.core.dsi import DSIConfig
from repro.core.pipeline import EMVSOptions, SegmentBatch, sweep_segment_batch
from repro.profiling.cost_table import CostTable, VariantKey, backend_name

# the (s_bucket, capacity) points the matmul rows already cover
GRID = ((1, 4), (1, 8), (1, 12), (2, 8), (2, 12), (4, 8), (4, 12))


def _synthetic_batch(s: int, c: int, e: int, cam: CameraModel,
                     seed: int = 0) -> SegmentBatch:
    rng = np.random.default_rng(seed)
    xy = rng.uniform((0, 0), (cam.width - 1, cam.height - 1),
                     (s, c, e, 2)).astype(np.float32)
    R = np.broadcast_to(np.eye(3, dtype=np.float32), (s, c, 3, 3)).copy()
    t = np.zeros((s, c, 3), np.float32)
    t[..., 0] = np.linspace(0.0, 0.05 * c, c, dtype=np.float32)
    return SegmentBatch(
        xy=jnp.asarray(xy),
        valid=jnp.ones((s, c, e), jnp.float32),
        frame_valid=jnp.ones((s, c), jnp.float32),
        poses_R=jnp.asarray(R),
        poses_t=jnp.asarray(t),
        ref_R=jnp.asarray(R[:, 0]),
        ref_t=jnp.asarray(t[:, 0]),
    )


def record(table: CostTable, *, events: int, repeats: int,
           quantized_points: tuple[tuple[int, int], ...],
           grid: tuple[tuple[int, int], ...] = GRID) -> list[str]:
    cam = CameraModel()
    dsi_cfg = DSIConfig.for_camera(cam, num_planes=32)
    backend = backend_name("batched", "kernel")
    rows = []
    jobs = [(s, c, False) for s, c in grid]
    jobs += [(s, c, True) for s, c in quantized_points]
    for s, c, quantized in jobs:
        opts = EMVSOptions(voting="nearest", formulation="kernel",
                           quantized=quantized)
        batch = _synthetic_batch(s, c, events, cam)
        key = VariantKey(s_bucket=s, capacity=c, backend=backend,
                         interpolation="nearest", quantized=quantized)

        def run_once():
            out = sweep_segment_batch(cam, dsi_cfg, batch, opts)
            jax.tree.map(
                lambda a: a.block_until_ready() if hasattr(
                    a, "block_until_ready") else a, out)

        run_once()  # cold compile — never recorded
        for _ in range(repeats):
            t0 = time.perf_counter()
            run_once()
            table.record(key, time.perf_counter() - t0)
        stats = table.entry_stats(key)
        rows.append(f"{key.to_str()}: mean {stats['mean_s']:.4f}s "
                    f"over {stats['count']} warm run(s)")
        print(rows[-1], flush=True)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-run", action="store_true",
                    help="one tiny grid point, few events (CI smoke); "
                         "does NOT write the table")
    ap.add_argument("--table", default="cost_table.json")
    ap.add_argument("--events", type=int, default=1024,
                    help="events per aggregated frame")
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args()

    table = CostTable()
    if args.dry_run:
        record(table, events=64, repeats=1, grid=((1, 4),),
               quantized_points=((1, 4),))
        print("dry run: table not written")
        return
    record(table, events=args.events, repeats=args.repeats,
           quantized_points=((1, 4), (1, 8), (1, 12)))
    try:
        merged = CostTable.load(args.table)
    except FileNotFoundError:
        merged = CostTable()
    merged.merge(table)
    merged.save(args.table)
    print(f"merged {len(table)} kernel-backend variant(s) into {args.table} "
          f"({len(merged)} total)")


if __name__ == "__main__":
    main()
