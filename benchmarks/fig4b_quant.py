"""Paper Fig 4b: AbsRel with vs without Table-1 hybrid quantization.

Claim reproduced: "The maximum AbsRel difference before and after
quantization is about 1.01%."
"""
from __future__ import annotations

from benchmarks._emvs_common import SEQUENCES, absrel_for
from repro.core.pipeline import EMVSOptions


def run() -> dict:
    rows = {}
    worst_gap = 0.0
    for seq in SEQUENCES:
        e_f = absrel_for(seq, EMVSOptions(quantized=False))
        e_q = absrel_for(seq, EMVSOptions(quantized=True))
        gap = abs(e_q - e_f)
        worst_gap = max(worst_gap, gap)
        rows[seq] = {"float32": e_f, "table1_quantized": e_q, "gap": gap}
    return {"rows": rows, "max_gap": worst_gap,
            "paper_claim_max_gap": 0.0101,
            "claim_ok": bool(worst_gap < 0.04)}


def main() -> None:
    out = run()
    print("== Fig 4b: Table-1 quantization impact (AbsRel) ==")
    print(f"{'sequence':22s} {'float32':>9s} {'quant':>9s} {'gap':>8s}")
    for seq, r in out["rows"].items():
        print(f"{seq:22s} {r['float32']:9.4f} {r['table1_quantized']:9.4f} "
              f"{r['gap']:8.4f}")
    print(f"max gap {out['max_gap']:.4f} "
          f"(paper: ~{out['paper_claim_max_gap']:.4f}; "
          f"{'OK' if out['claim_ok'] else 'VIOLATED'})")


if __name__ == "__main__":
    main()
