"""Benchmark driver: one section per paper table/figure + the roofline.

    PYTHONPATH=src python -m benchmarks.run [--skip-slow]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-slow", action="store_true")
    args = ap.parse_args()

    from benchmarks import (
        fig4a_voting,
        fig4b_quant,
        fig7a_accuracy,
        memory_footprint,
        roofline_report,
        segment_batching,
        table3_runtime,
    )

    sections = [
        ("Table 3 (runtime per event frame)", table3_runtime.main),
        ("Segment batching (looped vs batched sweep)", segment_batching.main),
        ("Fig 4a (nearest vs bilinear voting)", fig4a_voting.main),
        ("Fig 4b (hybrid quantization)", fig4b_quant.main),
        ("Fig 7a (original vs reformulated)", fig7a_accuracy.main),
        ("§2.3 (memory footprint)", memory_footprint.main),
        ("Roofline (dry-run artifacts)", roofline_report.main),
    ]
    failures = 0
    for title, fn in sections:
        print("\n" + "=" * 72)
        print(f"### {title}")
        print("=" * 72)
        t0 = time.time()
        try:
            fn()
        except Exception:
            failures += 1
            traceback.print_exc()
        print(f"[{time.time() - t0:.1f}s]")
    print("\n" + ("ALL BENCHMARKS OK" if failures == 0
                  else f"{failures} BENCHMARK SECTIONS FAILED"))
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
