"""A/B: host-looped per-segment EMVS vs the padded batched segment sweep.

The seed's `run_emvs` processed key-frame segments in a host-side Python
loop: one device dispatch per segment and one retrace/compile per
distinct segment length — the "many small dispatches" pathology for
event-rate processing. The batched sweep pads segments into
multiple-of-four frame-capacity buckets and runs ONE compiled program
per bucket.

Reported per path:
  * cold: fresh jit caches, one full run (includes tracing/compilation —
    this is what a new sequence costs, and where per-length retraces hurt);
  * warm: best of WARM_REPEATS steady-state runs.
Headline metric is cold segments/s; Mev/s counts real (unpadded) events.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

try:  # script invocation (python benchmarks/segment_batching.py)
    from _emvs_common import update_bench_json
except ImportError:  # module invocation (python -m benchmarks.segment_batching)
    from benchmarks._emvs_common import update_bench_json

from repro.core.camera import CameraModel
from repro.core.dsi import DSIConfig
from repro.core.pipeline import (
    EMVSOptions,
    plan_segments,
    run_emvs,
    run_emvs_looped,
)
from repro.events.aggregation import aggregate
from repro.events.simulator import (
    SceneConfig,
    make_scene,
    make_trajectory,
    simulate_events,
)

WARM_REPEATS = 3


def build_sequence():
    cam = CameraModel()
    scene = make_scene(SceneConfig(name="simulation_3planes", points_per_plane=200))
    traj = make_trajectory("simulation_3planes", 144)
    ev = simulate_events(cam, scene, traj, noise_fraction=0.02, seed=0)
    frames = aggregate(cam, ev, traj, events_per_frame=512)
    dsi_cfg = DSIConfig.for_camera(cam, num_planes=16, z_min=0.6, z_max=4.5)
    return cam, frames, dsi_cfg


def _block(res):
    for seg in res.segments:
        seg.depth_map.depth.block_until_ready()
    return res


def _measure(fn):
    jax.clear_caches()
    t0 = time.perf_counter()
    res = _block(fn())
    cold = time.perf_counter() - t0
    warm = float("inf")
    for _ in range(WARM_REPEATS):
        t0 = time.perf_counter()
        _block(fn())
        warm = min(warm, time.perf_counter() - t0)
    return res, cold, warm


def _check_match(a, b):
    assert len(a.segments) == len(b.segments), "segment count mismatch"
    worst = 0.0
    for sa, sb in zip(a.segments, b.segments):
        assert sa.frame_range == sb.frame_range
        worst = max(worst, float(np.abs(
            np.asarray(sa.dsi, np.float32) - np.asarray(sb.dsi, np.float32)).max()))
        assert (np.asarray(sa.depth_map.mask) == np.asarray(sb.depth_map.mask)).all()
    # default opts vote nearest: integral counts, so the match must be exact
    assert worst == 0.0, f"nearest-voting DSIs must match bitwise, got {worst}"
    return worst


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json-out", default=None,
                    help="BENCH_emvs.json path (default: repo cwd)")
    # parse_known_args: benchmarks.run invokes this main() with the
    # driver's own flags (e.g. --skip-slow) still on sys.argv
    args, _ = ap.parse_known_args()

    cam, frames, dsi_cfg = build_sequence()
    opts = EMVSOptions(keyframe_dist_frac=0.02)
    segs = plan_segments(frames, dsi_cfg, opts)
    lengths = sorted({b - a for a, b in segs})
    n_seg = len(segs)
    n_ev = sum(b - a for a, b in segs) * frames.xy.shape[1]
    print(f"sequence: {frames.xy.shape[0]} frames x {frames.xy.shape[1]} events, "
          f"{n_seg} segments, lengths {lengths} "
          f"({len(lengths)} distinct -> {len(lengths)} looped retraces)")

    res_l, cold_l, warm_l = _measure(lambda: run_emvs_looped(cam, dsi_cfg, frames, opts))
    res_b, cold_b, warm_b = _measure(lambda: run_emvs(cam, dsi_cfg, frames, opts))
    worst = _check_match(res_l, res_b)
    print(f"numerical match: max |DSI_looped - DSI_batched| = {worst:g}, masks equal")

    print(f"\n{'path':<10}{'cold s':>10}{'cold seg/s':>12}{'cold Mev/s':>12}"
          f"{'warm s':>10}{'warm seg/s':>12}{'warm Mev/s':>12}")
    for name, cold, warm in (("looped", cold_l, warm_l), ("batched", cold_b, warm_b)):
        print(f"{name:<10}{cold:>10.2f}{n_seg / cold:>12.2f}{n_ev / cold / 1e6:>12.3f}"
              f"{warm:>10.2f}{n_seg / warm:>12.2f}{n_ev / warm / 1e6:>12.3f}")

    cold_speedup = cold_l / cold_b
    warm_speedup = warm_l / warm_b
    print(f"\nbatched sweep speedup: {cold_speedup:.2f}x cold (segments/s), "
          f"{warm_speedup:.2f}x warm")
    if cold_speedup < 1.5:
        print("WARNING: cold speedup below the 1.5x acceptance threshold")

    path = update_bench_json("segment_batching", {
        "segments": n_seg,
        "events": n_ev,
        "looped": {"cold_s": round(cold_l, 3), "warm_s": round(warm_l, 3),
                   "cold_segments_per_s": round(n_seg / cold_l, 3),
                   "warm_segments_per_s": round(n_seg / warm_l, 3)},
        "batched": {"cold_s": round(cold_b, 3), "warm_s": round(warm_b, 3),
                    "cold_segments_per_s": round(n_seg / cold_b, 3),
                    "warm_segments_per_s": round(n_seg / warm_b, 3)},
        "cold_speedup": round(cold_speedup, 3),
        "warm_speedup": round(warm_speedup, 3),
    }, path=args.json_out)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
