"""A/B: batched (lax.map) segment sweep vs the device-sharded sweep.

`run_emvs(sweep="batched")` runs every segment of a bucket serially
inside one `lax.map` program; `run_emvs(sweep="sharded")` shards the
segment axis across mesh devices (`process_segments_sharded`), so
concurrent key-frame segments vote on different devices — the paper's
key-frame-level parallelism, the axis a serial sweep cannot exploit.

On a real multi-chip backend the sharded path buys near-linear
cross-segment speedup; on a CPU host with forced host devices
(`--devices N`, XLA's host-platform partitioning) the devices share the
same cores, so the interesting outputs here are (a) the bitwise
nearest-datapath equality check between the two backends and (b) the
machine-readable segments/s trajectory in BENCH_emvs.json. Both paths
are measured cold (fresh jit caches) and warm.

    PYTHONPATH=src python benchmarks/sharded_sweep.py [--dry-run]
        [--devices 8] [--json-out BENCH_emvs.json]
"""
from __future__ import annotations

import argparse
import os
import time


def _parse_args():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny sequence for CI smoke (same code path)")
    ap.add_argument("--devices", type=int, default=8,
                    help="forced host device count (0 = leave XLA alone)")
    ap.add_argument("--json-out", default=None,
                    help="BENCH_emvs.json path (default: repo cwd)")
    return ap.parse_args()


ARGS = _parse_args()
if ARGS.devices > 0:
    # must precede any jax import: jax locks the device count on first init
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={ARGS.devices} "
        + os.environ.get("XLA_FLAGS", ""))

import jax  # noqa: E402
import numpy as np  # noqa: E402

try:  # script invocation (python benchmarks/sharded_sweep.py)
    from _emvs_common import update_bench_json
except ImportError:  # module invocation
    from benchmarks._emvs_common import update_bench_json

from repro.core.camera import CameraModel  # noqa: E402
from repro.core.dsi import DSIConfig  # noqa: E402
from repro.core.pipeline import (  # noqa: E402
    EMVSOptions,
    plan_segments,
    run_emvs,
)
from repro.distributed.emvs import SEGMENT_AXIS, make_segment_mesh  # noqa: E402
from repro.events.aggregation import aggregate  # noqa: E402
from repro.events.simulator import (  # noqa: E402
    SceneConfig,
    make_scene,
    make_trajectory,
    simulate_events,
)

WARM_REPEATS = 2


def build_sequence(dry_run: bool):
    cam = CameraModel()
    steps, points, e_frame, planes = (
        (64, 80, 256, 8) if dry_run else (144, 200, 512, 16))
    scene = make_scene(SceneConfig(name="simulation_3planes",
                                   points_per_plane=points))
    traj = make_trajectory("simulation_3planes", steps)
    ev = simulate_events(cam, scene, traj, noise_fraction=0.02, seed=0)
    frames = aggregate(cam, ev, traj, events_per_frame=e_frame)
    dsi_cfg = DSIConfig.for_camera(cam, num_planes=planes, z_min=0.6, z_max=4.5)
    return cam, frames, dsi_cfg


def _block(res):
    for seg in res.segments:
        seg.depth_map.depth.block_until_ready()
    return res


def _measure(fn):
    jax.clear_caches()
    t0 = time.perf_counter()
    res = _block(fn())
    cold = time.perf_counter() - t0
    warm = float("inf")
    for _ in range(WARM_REPEATS):
        t0 = time.perf_counter()
        _block(fn())
        warm = min(warm, time.perf_counter() - t0)
    return res, cold, warm


def main() -> None:
    mesh = make_segment_mesh()
    n_dev = mesh.shape[SEGMENT_AXIS]
    cam, frames, dsi_cfg = build_sequence(ARGS.dry_run)
    opts = EMVSOptions(keyframe_dist_frac=0.02)
    segs = plan_segments(frames, dsi_cfg, opts)
    n_seg = len(segs)
    n_ev = sum(b - a for a, b in segs) * frames.xy.shape[1]
    print(f"sequence: {frames.xy.shape[0]} frames x {frames.xy.shape[1]} "
          f"events, {n_seg} segments; mesh: {n_dev} device(s) on the "
          f"'{SEGMENT_AXIS}' axis")

    res_b, cold_b, warm_b = _measure(
        lambda: run_emvs(cam, dsi_cfg, frames, opts))
    res_s, cold_s, warm_s = _measure(
        lambda: run_emvs(cam, dsi_cfg, frames, opts, sweep="sharded",
                         mesh=mesh))

    # default opts vote nearest: the backends must agree bitwise
    assert len(res_b.segments) == len(res_s.segments) == n_seg
    worst = 0.0
    for sb, ss in zip(res_b.segments, res_s.segments):
        assert sb.frame_range == ss.frame_range
        worst = max(worst, float(np.abs(
            np.asarray(sb.dsi, np.float32)
            - np.asarray(ss.dsi, np.float32)).max()))
        assert (np.asarray(sb.depth_map.mask)
                == np.asarray(ss.depth_map.mask)).all()
    assert worst == 0.0, f"nearest-voting DSIs must match bitwise, got {worst}"
    print(f"numerical match: max |DSI_batched - DSI_sharded| = {worst:g}, "
          f"masks equal")

    print(f"\n{'path':<10}{'cold s':>10}{'cold seg/s':>12}"
          f"{'warm s':>10}{'warm seg/s':>12}")
    for name, cold, warm in (("batched", cold_b, warm_b),
                             ("sharded", cold_s, warm_s)):
        print(f"{name:<10}{cold:>10.2f}{n_seg / cold:>12.2f}"
              f"{warm:>10.2f}{n_seg / warm:>12.2f}")
    print(f"\nsharded/batched warm ratio: {warm_b / warm_s:.2f}x "
          f"(host devices share cores; expect ~1x on CPU, ~{n_dev}x on a "
          f"real {n_dev}-chip mesh)")

    path = update_bench_json("sharded_sweep", {
        "dry_run": bool(ARGS.dry_run),
        "devices": n_dev,
        "segments": n_seg,
        "events": n_ev,
        "batched": {"cold_s": round(cold_b, 3), "warm_s": round(warm_b, 3),
                    "cold_segments_per_s": round(n_seg / cold_b, 3),
                    "warm_segments_per_s": round(n_seg / warm_b, 3)},
        "sharded": {"cold_s": round(cold_s, 3), "warm_s": round(warm_s, 3),
                    "cold_segments_per_s": round(n_seg / cold_s, 3),
                    "warm_segments_per_s": round(n_seg / warm_s, 3)},
        "bitwise_match": True,
    }, path=ARGS.json_out)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
