"""Shared benchmark scaffolding: the four evaluation sequences of the
paper (simulation_3planes, simulation_3walls, slider_close, slider_far)
at a size that runs in seconds on CPU, plus the machine-readable
`BENCH_emvs.json` emitter the perf-tracking benchmarks share."""
from __future__ import annotations

import dataclasses
import json
import os
from functools import lru_cache

import jax

from repro.core.camera import CameraModel
from repro.core.dsi import DSIConfig
from repro.core.geometry import SE3
from repro.core.pipeline import EMVSOptions, process_segment
from repro.events.aggregation import aggregate
from repro.events.simulator import (
    SceneConfig,
    absrel,
    ground_truth_depth,
    make_scene,
    make_trajectory,
    simulate_events,
)

SEQUENCES = ("simulation_3planes", "simulation_3walls", "slider_close",
             "slider_far")

BENCH_JSON = "BENCH_emvs.json"


def update_bench_json(section: str, record: dict,
                      path: str | None = None) -> str:
    """Merge one benchmark's record into the shared BENCH_emvs.json.

    Each benchmark owns a top-level section ("segment_batching",
    "sharded_sweep", "streaming_latency") so CI and later sessions can
    track the perf trajectory (segments/s, first-depth latency) without
    parsing stdout. Existing sections from other benchmarks survive;
    a corrupt file is replaced rather than crashing the run.
    """
    path = path or BENCH_JSON
    data: dict = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                data = json.load(f)
            if not isinstance(data, dict):
                data = {}
        except (OSError, json.JSONDecodeError):
            data = {}
    data[section] = record
    with open(path, "w") as f:
        json.dump(data, f, indent=1, sort_keys=True)
        f.write("\n")
    return path


@lru_cache(maxsize=None)
def sequence(name: str, points_per_plane: int = 400, steps: int = 48):
    cam = CameraModel()
    scene = make_scene(SceneConfig(name=name, points_per_plane=points_per_plane))
    traj = make_trajectory(name, steps)
    ev = simulate_events(cam, scene, traj, noise_fraction=0.02, seed=0)
    frames = aggregate(cam, ev, traj, events_per_frame=1024)
    z_rng = (0.5, 1.8) if name == "slider_close" else (0.6, 4.5)
    dsi_cfg = DSIConfig.for_camera(cam, num_planes=64, z_min=z_rng[0],
                                   z_max=z_rng[1])
    return cam, scene, frames, dsi_cfg


def absrel_for(name: str, opts: EMVSOptions, max_frames: int = 24) -> float:
    cam, scene, frames, dsi_cfg = sequence(name)
    frames = jax.tree.map(lambda a: a[:max_frames], frames)
    T_w_ref = SE3(frames.poses.R[0], frames.poses.t[0])
    _, dm = process_segment(cam, dsi_cfg, frames, T_w_ref, opts)
    gt, gtm = ground_truth_depth(cam, scene, T_w_ref)
    return float(absrel(dm.depth, dm.mask, gt, gtm))
