"""Shared benchmark scaffolding: the four evaluation sequences of the
paper (simulation_3planes, simulation_3walls, slider_close, slider_far)
at a size that runs in seconds on CPU, plus the machine-readable
`BENCH_emvs.json` emitter the perf-tracking benchmarks share."""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
from functools import lru_cache

import jax

from repro.core.camera import CameraModel
from repro.core.dsi import DSIConfig
from repro.core.geometry import SE3
from repro.core.pipeline import EMVSOptions, process_segment
from repro.events.aggregation import aggregate
from repro.events.simulator import (
    SceneConfig,
    absrel,
    ground_truth_depth,
    make_scene,
    make_trajectory,
    simulate_events,
)

SEQUENCES = ("simulation_3planes", "simulation_3walls", "slider_close",
             "slider_far")

BENCH_JSON = "BENCH_emvs.json"


def update_bench_json(section: str, record: dict,
                      path: str | None = None) -> str:
    """Merge one benchmark's record into the shared BENCH_emvs.json.

    Each benchmark owns a top-level section ("segment_batching",
    "sharded_sweep", "streaming_latency") so CI and later sessions can
    track the perf trajectory (segments/s, first-depth latency) without
    parsing stdout. Existing sections from other benchmarks survive;
    a corrupt file is replaced rather than crashing the run.

    Two hygiene rules this writer enforces:

      * Dry-run isolation: a record carrying `"dry_run": true` (the CI
        smoke sizes) lands under the top-level `"dry_run"` namespace —
        `data["dry_run"][section]` — NEVER at `data[section]`, so a
        smoke run can no longer overwrite a full-size record and poison
        the tracked perf trajectory. Legacy top-level sections that are
        really dry-run records (they carry `"dry_run": true`) are
        migrated into the namespace on the next write. CI gates read
        full-run records at the top level first and fall back to the
        dry-run namespace explicitly.
      * Atomic replace: the merged file is written to a tempfile in the
        same directory and `os.replace`d over the target, so concurrent
        benchmark invocations (e.g. two CI steps, or a benchmark racing
        the artifact upload) can lose an update but can never interleave
        writes into a torn/corrupt file, and a reader never observes a
        half-written JSON.
    """
    path = path or BENCH_JSON
    data: dict = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                data = json.load(f)
            if not isinstance(data, dict):
                data = {}
        except (OSError, json.JSONDecodeError):
            data = {}
    # migrate legacy top-level dry-run records into the namespace (the
    # "dry_run" key itself is the namespace, not a record)
    legacy = [name for name, rec in data.items()
              if name != "dry_run" and isinstance(rec, dict)
              and rec.get("dry_run")]
    for name in legacy:
        data.setdefault("dry_run", {})[name] = data.pop(name)
    if isinstance(record, dict) and record.get("dry_run"):
        data.setdefault("dry_run", {})[section] = record
    else:
        data[section] = record
    out_dir = os.path.dirname(os.path.abspath(path))
    fd, tmp = tempfile.mkstemp(dir=out_dir, prefix=".bench_emvs_",
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(data, f, indent=1, sort_keys=True)
            f.write("\n")
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    return path


def read_bench_section(section: str, path: str | None = None) -> dict | None:
    """Read one section back, full-run records first.

    Returns `data[section]` when present (a full-size record), else the
    dry-run namespace's copy, else None — the lookup order CI gates use
    so a smoke record never masquerades as the tracked trajectory."""
    path = path or BENCH_JSON
    if not os.path.exists(path):
        return None
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, json.JSONDecodeError):
        return None
    if not isinstance(data, dict):
        return None
    if section in data:
        return data[section]
    return data.get("dry_run", {}).get(section)


@lru_cache(maxsize=None)
def sequence(name: str, points_per_plane: int = 400, steps: int = 48):
    cam = CameraModel()
    scene = make_scene(SceneConfig(name=name, points_per_plane=points_per_plane))
    traj = make_trajectory(name, steps)
    ev = simulate_events(cam, scene, traj, noise_fraction=0.02, seed=0)
    frames = aggregate(cam, ev, traj, events_per_frame=1024)
    z_rng = (0.5, 1.8) if name == "slider_close" else (0.6, 4.5)
    dsi_cfg = DSIConfig.for_camera(cam, num_planes=64, z_min=z_rng[0],
                                   z_max=z_rng[1])
    return cam, scene, frames, dsi_cfg


def absrel_for(name: str, opts: EMVSOptions, max_frames: int = 24) -> float:
    cam, scene, frames, dsi_cfg = sequence(name)
    frames = jax.tree.map(lambda a: a[:max_frames], frames)
    T_w_ref = SE3(frames.poses.R[0], frames.poses.t[0])
    _, dm = process_segment(cam, dsi_cfg, frames, T_w_ref, opts)
    gt, gtm = ground_truth_depth(cam, scene, T_w_ref)
    return float(absrel(dm.depth, dm.mask, gt, gtm))
