"""Synthetic event-camera simulator with ground-truth depth.

Reproduces the evaluation setting of the paper: the DAVIS 240x180 event
camera moving along a known trajectory through simple structured scenes
(`simulation_3planes`, `simulation_3walls`) plus slider-style linear
motions in front of near/far structure (`slider_close`, `slider_far`).

Event model: event cameras respond to moving intensity edges. Scene
texture is represented by 3D points sampled densely along edge segments
drawn on each surface. The trajectory is sampled finely enough that the
inter-step image displacement of any point is ~1 px; each visible point
then emits one event per step at its (integer) pixel location, which is
the standard point-based event simulation used for EMVS-style geometric
evaluation [Rebecq IJCV'18 uses the same planar scenes].

Everything returns fixed-size arrays with validity masks (jit-friendly).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.camera import CameraModel, distort_normalized, project
from repro.core.geometry import SE3, so3_exp

Array = jax.Array


class EventStream(NamedTuple):
    xy: Array  # (N, 2) float32 raw pixel coords (integer-valued + sensor noise)
    t: Array  # (N,) float32 timestamps, sorted
    polarity: Array  # (N,) int8 in {-1, +1}
    valid: Array  # (N,) bool


class Trajectory(NamedTuple):
    times: Array  # (F,)
    poses: SE3  # batched (F, 3, 3), (F, 3): T_w_cam


def slice_trajectory(traj: Trajectory, lo: int, hi: int) -> Trajectory:
    """Samples [lo, hi) of a trajectory, poses included.

    The building block for replaying a tracker feed: pair it with a
    cursor over `traj.times` (e.g. `np.searchsorted(times, event_front -
    lag)`) to push exactly the poses a lagging tracker would have
    delivered by now.
    """
    return Trajectory(times=traj.times[lo:hi],
                      poses=SE3(traj.poses.R[lo:hi], traj.poses.t[lo:hi]))


def iter_trajectory_chunks(traj: Trajectory, chunk_poses: int):
    """Split a trajectory into contiguous chunks of `chunk_poses` samples.

    The pose-stream analogue of `iter_event_chunks`: feeding the chunks
    to `TrajectoryBuffer.push` (or `EMVSStreamEngine.push_poses`) in
    order reconstructs the trajectory exactly, so tests and benchmarks
    can replay a tracker that delivers poses in bursts.
    """
    if chunk_poses < 1:
        raise ValueError(f"chunk_poses must be >= 1, got {chunk_poses}")
    n = int(traj.times.shape[0])
    for i in range(0, n, chunk_poses):
        yield slice_trajectory(traj, i, min(i + chunk_poses, n))


@dataclasses.dataclass(frozen=True)
class SceneConfig:
    name: str = "simulation_3planes"
    points_per_plane: int = 600
    edge_segments_per_plane: int = 12
    noise_fraction: float = 0.02  # spurious events (sensor noise)
    seed: int = 0


def _sample_edge_points(rng: np.random.Generator, n_segments: int, n_points: int,
                        extent: float) -> np.ndarray:
    """Sample points along random line segments in a plane's local (u,v)."""
    seg_ends = rng.uniform(-extent, extent, size=(n_segments, 2, 2))
    pts = []
    per_seg = max(n_points // n_segments, 2)
    for a, b in seg_ends:
        s = np.linspace(0.0, 1.0, per_seg)[:, None]
        pts.append(a[None, :] * (1 - s) + b[None, :] * s)
    uv = np.concatenate(pts, axis=0)[:n_points]
    if uv.shape[0] < n_points:  # pad by repeating
        reps = int(np.ceil(n_points / uv.shape[0]))
        uv = np.tile(uv, (reps, 1))[:n_points]
    return uv


def make_scene(cfg: SceneConfig) -> np.ndarray:
    """Return (P, 3) world-frame scene points for the named scene."""
    rng = np.random.default_rng(cfg.seed)
    n, k = cfg.points_per_plane, cfg.edge_segments_per_plane
    planes: list[np.ndarray] = []
    if cfg.name == "simulation_3planes":
        # three fronto-parallel planes at different depths (z along +view)
        for depth, extent in ((1.0, 0.5), (2.0, 0.9), (3.5, 1.4)):
            uv = _sample_edge_points(rng, k, n, extent)
            planes.append(np.stack([uv[:, 0], uv[:, 1], np.full(n, depth)], axis=1))
    elif cfg.name == "simulation_3walls":
        # back wall + two side walls (a corridor corner)
        uv = _sample_edge_points(rng, k, n, 1.2)
        planes.append(np.stack([uv[:, 0], uv[:, 1], np.full(n, 3.0)], axis=1))
        uv = _sample_edge_points(rng, k, n, 1.2)
        planes.append(np.stack([np.full(n, -1.4), uv[:, 0], 1.8 + 0.9 * uv[:, 1]], axis=1))
        uv = _sample_edge_points(rng, k, n, 1.2)
        planes.append(np.stack([np.full(n, 1.4), uv[:, 0], 1.8 + 0.9 * uv[:, 1]], axis=1))
    elif cfg.name in ("slider_close", "slider_far"):
        depth = 0.8 if cfg.name == "slider_close" else 2.8
        for dz, extent in ((0.0, 0.7), (0.35, 0.9), (0.8, 1.1)):
            uv = _sample_edge_points(rng, k, n, extent)
            planes.append(np.stack([uv[:, 0], uv[:, 1], np.full(n, depth + dz)], axis=1))
    else:
        raise ValueError(f"unknown scene {cfg.name}")
    return np.concatenate(planes, axis=0).astype(np.float32)


def make_trajectory(name: str, num_steps: int, seed: int = 0) -> Trajectory:
    """Camera trajectory T_w_cam(t). Slider: pure x-translation; sim: 6-DOF arc."""
    ts = np.linspace(0.0, 1.0, num_steps).astype(np.float32)
    if name.startswith("slider"):
        # linear slider: 25 cm sweep, no rotation (like the DAVIS slider rig)
        t = np.stack([0.25 * ts - 0.125, np.zeros_like(ts), np.zeros_like(ts)], axis=1)
        R = np.tile(np.eye(3, dtype=np.float32), (num_steps, 1, 1))
    else:
        # smooth arc with gentle rotation
        t = np.stack(
            [0.30 * np.sin(np.pi * ts) - 0.15,
             0.10 * np.sin(2 * np.pi * ts),
             0.06 * (1 - np.cos(np.pi * ts))], axis=1).astype(np.float32)
        w = np.stack(
            [0.05 * np.sin(np.pi * ts), 0.12 * ts, 0.04 * np.sin(2 * np.pi * ts)],
            axis=1).astype(np.float32)
        R = np.asarray(so3_exp(jnp.asarray(w)))
    return Trajectory(times=jnp.asarray(ts), poses=SE3(jnp.asarray(R), jnp.asarray(t)))


def simulate_events(
    cam: CameraModel,
    scene_points: np.ndarray,
    traj: Trajectory,
    noise_fraction: float = 0.02,
    seed: int = 0,
    integer_pixels: bool = True,
) -> EventStream:
    """Generate the event stream for a scene + trajectory.

    Returns ~num_steps * P events (fixed size, invalid ones masked).
    """
    pts = jnp.asarray(scene_points)  # (P, 3)

    def per_step(pose_R, pose_t, time):
        T_cw = SE3(pose_R, pose_t).inverse()
        pc = T_cw.apply(pts[None])[0]  # (P, 3) camera frame
        infront = pc[:, 2] > 0.05
        xy = project(cam, pc)
        if cam.has_distortion():
            xn = (xy[:, 0] - cam.cx) / cam.fx
            yn = (xy[:, 1] - cam.cy) / cam.fy
            xd, yd = distort_normalized(cam, xn, yn)
            xy = jnp.stack([xd * cam.fx + cam.cx, yd * cam.fy + cam.cy], axis=-1)
        inb = (
            (xy[:, 0] >= 0) & (xy[:, 0] <= cam.width - 1)
            & (xy[:, 1] >= 0) & (xy[:, 1] <= cam.height - 1)
        )
        valid = infront & inb
        if integer_pixels:
            xy = jnp.round(xy)
        return xy, valid

    R, t = traj.poses.R, traj.poses.t
    xys, valids = jax.vmap(per_step)(R, t, traj.times)  # (F, P, 2), (F, P)
    F, P = valids.shape
    times = jnp.repeat(traj.times[:, None], P, axis=1)

    rng = np.random.default_rng(seed)
    # timestamp jitter within a step keeps ordering realistic but stable
    jitter = jnp.asarray(
        rng.uniform(0, 1.0 / max(F - 1, 1) * 0.45, size=(F, P)).astype(np.float32))
    times = times + jitter
    pol = jnp.asarray(rng.choice(np.array([-1, 1], dtype=np.int8), size=(F, P)))

    xy = xys.reshape(-1, 2)
    tt = times.reshape(-1)
    vv = valids.reshape(-1)
    pp = pol.reshape(-1)

    # noise events: uniform random pixels replacing a small fraction
    n_total = xy.shape[0]
    n_noise = int(noise_fraction * n_total)
    if n_noise > 0:
        noise_idx = jnp.asarray(rng.choice(n_total, size=n_noise, replace=False))
        noise_xy = jnp.asarray(
            np.stack([rng.uniform(0, cam.width - 1, n_noise),
                      rng.uniform(0, cam.height - 1, n_noise)], axis=1)
            .astype(np.float32))
        if integer_pixels:
            noise_xy = jnp.round(noise_xy)
        xy = xy.at[noise_idx].set(noise_xy)
        vv = vv.at[noise_idx].set(True)

    order = jnp.argsort(tt)
    xy, tt, vv, pp = xy[order], tt[order], vv[order], pp[order]
    # park invalid events far outside the image so every stage drops them
    xy = jnp.where(vv[:, None], xy, jnp.float32(-1e4))
    return EventStream(xy=xy.astype(jnp.float32), t=tt, polarity=pp, valid=vv)


EVENT_CORRUPTIONS = ("shuffle_events", "swap_chunks", "duplicate_chunk",
                     "out_of_bounds", "hot_pixel")


def corrupt_stream(stream: EventStream, mode: str, chunk_events: int, *,
                   seed: int = 0, width: int | None = None,
                   height: int | None = None,
                   burst: int = 32) -> list[EventStream]:
    """Fault injection: chunk a clean stream, then break one thing.

    Returns the stream split into host-side chunks of `chunk_events`
    with exactly one adversarial corruption applied — the noise modes
    the event-vision survey (Gallego et al., arXiv 1904.08405) catalogs
    for production ingest, shaped so `stream_hygiene` tests can assert
    the precise expected response per policy:

      * `"shuffle_events"` — one mid-stream chunk's events permuted
        (misordered transport). Detectable as non-monotone; fully
        reversible by a reorder slack covering the chunk's time span.
      * `"swap_chunks"` — two adjacent chunks delivered in the wrong
        order (packet reordering). The late chunk regresses behind the
        watermark; reversible by a slack covering both chunks' span.
      * `"duplicate_chunk"` — one chunk replayed byte-identically right
        after itself (retrying link). Dropping the replay restores the
        clean stream bit-exactly.
      * `"out_of_bounds"` — a few spurious events marked valid injected
        at off-sensor coordinates (requires `width`/`height`), at
        timestamps tied to their insertion point so ordering stays
        legal. Dropping them restores the clean stream bit-exactly.
      * `"hot_pixel"` — a `burst` of events at one in-bounds pixel and
        one timestamp spliced into a mid-stream chunk (a storming
        sensel; requires `width`/`height`). Any per-window rate limit
        below `burst` catches it.

    Injection sites are chosen from `seed` (deterministic). Dropped
    *pose* chunks — the fourth adversarial mode the roadmap names —
    live on the trajectory side: drop chunks from
    `iter_trajectory_chunks` and the pose-stall machinery
    (`PoseStallError`) takes over, so no event-side corruption exists
    for it here.
    """
    if mode not in EVENT_CORRUPTIONS:
        raise ValueError(f"unknown corruption mode {mode!r}: expected one "
                         f"of {EVENT_CORRUPTIONS}")
    if chunk_events < 1:
        raise ValueError(f"chunk_events must be >= 1, got {chunk_events}")
    if mode in ("out_of_bounds", "hot_pixel") and (width is None
                                                   or height is None):
        raise ValueError(f"mode {mode!r} needs the sensor size: pass "
                         f"width= and height=")
    xy = np.asarray(stream.xy, np.float32)
    t = np.asarray(stream.t, np.float32)
    pol = np.asarray(stream.polarity, np.int8)
    val = np.asarray(stream.valid, bool)
    chunks = [EventStream(xy=xy[i:i + chunk_events], t=t[i:i + chunk_events],
                          polarity=pol[i:i + chunk_events],
                          valid=val[i:i + chunk_events])
              for i in range(0, t.shape[0], chunk_events)]
    if not chunks:
        raise ValueError("cannot corrupt an empty stream")
    rng = np.random.default_rng(seed)
    k = len(chunks) // 2  # a mid-stream site: past warm-up, before flush
    c = chunks[k]
    nc = int(c.t.shape[0])
    if mode == "shuffle_events":
        if nc < 2 or np.unique(c.t).size < 2:
            raise ValueError("shuffle_events needs a chunk with >= 2 "
                             "distinct timestamps")
        # Permute, but keep tied timestamps in their original relative
        # order: hygiene's reorder buffer restores sort with a *stable*
        # sort, which can only reproduce the clean chunk bit-exactly if
        # the corruption never reordered within a tie group.
        while True:
            perm = rng.permutation(nc)
            vals = c.t[perm]
            _, inv = np.unique(vals, return_inverse=True)
            for g in range(int(inv.max()) + 1):
                pos = np.flatnonzero(inv == g)
                if pos.size > 1:
                    perm[pos] = np.sort(perm[pos])
            if not np.array_equal(perm, np.arange(nc)):  # reject no-ops
                break
        chunks[k] = EventStream(xy=c.xy[perm], t=c.t[perm],
                                polarity=c.polarity[perm],
                                valid=c.valid[perm])
    elif mode == "swap_chunks":
        if len(chunks) < 2:
            raise ValueError("swap_chunks needs >= 2 chunks")
        j = min(k, len(chunks) - 2)
        chunks[j], chunks[j + 1] = chunks[j + 1], chunks[j]
    elif mode == "duplicate_chunk":
        chunks.insert(k + 1, EventStream(
            xy=c.xy.copy(), t=c.t.copy(), polarity=c.polarity.copy(),
            valid=c.valid.copy()))
    elif mode == "out_of_bounds":
        m = min(4, nc)
        pos = np.sort(rng.integers(1, nc + 1, size=m))
        off_x = np.where(rng.random(m) < 0.5, -7.0, float(width) + 3.0)
        inj_xy = np.stack(
            [off_x, rng.uniform(0, height - 1, m)], axis=1).astype(np.float32)
        chunks[k] = EventStream(
            xy=np.insert(c.xy, pos, inj_xy, axis=0),
            t=np.insert(c.t, pos, c.t[pos - 1]),
            polarity=np.insert(c.polarity, pos, np.ones(m, np.int8)),
            valid=np.insert(c.valid, pos, np.ones(m, bool)))
    elif mode == "hot_pixel":
        p = max(1, nc // 2)
        px = np.asarray([rng.integers(0, width), rng.integers(0, height)],
                        np.float32)
        chunks[k] = EventStream(
            xy=np.insert(c.xy, p, np.tile(px, (burst, 1)), axis=0),
            t=np.insert(c.t, p, np.full(burst, c.t[p - 1], np.float32)),
            polarity=np.insert(c.polarity, p, np.ones(burst, np.int8)),
            valid=np.insert(c.valid, p, np.ones(burst, bool)))
    return chunks


def ground_truth_depth(cam: CameraModel, scene_points: np.ndarray, T_w_ref: SE3
                       ) -> tuple[Array, Array]:
    """Z-buffer the scene points into the reference view.

    Returns (depth (h,w), valid (h,w)). Pixels with no point are invalid.
    """
    pts = jnp.asarray(scene_points)
    T_cw = T_w_ref.inverse()
    pc = T_cw.apply(pts[None])[0]
    z = pc[:, 2]
    xy = project(cam, pc)
    xi = jnp.round(xy[:, 0]).astype(jnp.int32)
    yi = jnp.round(xy[:, 1]).astype(jnp.int32)
    ok = (z > 0.05) & (xi >= 0) & (xi < cam.width) & (yi >= 0) & (yi < cam.height)
    xi = jnp.clip(xi, 0, cam.width - 1)
    yi = jnp.clip(yi, 0, cam.height - 1)
    big = jnp.full((cam.height, cam.width), jnp.inf, dtype=jnp.float32)
    zbuf = big.at[yi, xi].min(jnp.where(ok, z, jnp.inf))
    valid = jnp.isfinite(zbuf)
    return jnp.where(valid, zbuf, 0.0), valid


def absrel(depth_est: Array, mask_est: Array, depth_gt: Array, mask_gt: Array) -> Array:
    """Absolute relative depth error over jointly-valid pixels (paper metric)."""
    m = mask_est & mask_gt
    err = jnp.abs(depth_est - depth_gt) / jnp.maximum(depth_gt, 1e-6)
    return jnp.sum(jnp.where(m, err, 0.0)) / jnp.maximum(jnp.sum(m), 1)
