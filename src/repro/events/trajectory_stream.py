"""Streamed trajectory: incremental pose ingestion with a safety watermark.

The paper's heterogeneous system assumes poses arrive from an external
tracker (a VIO/SLAM pipeline on the ARM side) while events stream in. In
real event-based pipelines (e.g. Event-based Stereo Visual Odometry,
Zhou et al. 2020) that tracker runs asynchronously and *behind* the
event front — so the pose source cannot be a fully-known `Trajectory`
oracle. `TrajectoryBuffer` is the streamed replacement: pose chunks are
pushed incrementally (in time order), and the buffer maintains a
monotonically advancing **pose-lag watermark** — the latest time at
which interpolation is safe, i.e. bracketed by received samples.
Queries outside the covered span raise `PoseExtrapolationError` instead
of silently clamping to a stale, frozen pose (the seed's latent bug:
`pose_at_times` clipped `frac` to [0, 1], so a frame past the pose
front got the last pose with no error and back-projected quietly
wrong).

`pose_at_times` (the interpolation core, re-exported by
`repro.events.aggregation` for compatibility) lives here too, with the
`strict=` mode and the single-sample validation; `enforce_pose_span`
is the shared out-of-span policy ("clamp" — the seed behavior, opt-in
only — / "warn" / "raise") used by the offline aggregation path and by
the streaming release path alike.
"""
from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.geometry import SE3, interpolate_pose
from repro.events.simulator import Trajectory

Array = jax.Array

# Out-of-span pose-query policies: "clamp" silently freezes the pose at
# the nearest trajectory endpoint (the seed behavior, kept only behind
# this explicit flag), "warn" clamps but emits PoseExtrapolationWarning,
# "raise" refuses with PoseExtrapolationError.
POSE_EXTRAPOLATION_POLICIES = ("clamp", "warn", "raise")


class PoseExtrapolationError(RuntimeError):
    """A pose query fell outside the span covered by trajectory samples."""


class PoseStallError(RuntimeError):
    """A streaming flush was asked to finish while frames still await poses."""


class PoseExtrapolationWarning(UserWarning):
    """A pose query outside the trajectory span was clamped to an endpoint."""


def enforce_pose_span(times: np.ndarray, t_query, policy: str,
                      context: str = "pose query") -> None:
    """Apply the out-of-span policy for queries against `times`.

    `times` must be a host (numpy) array of at least 2 sorted sample
    times; `t_query` may be scalar or vector (converted to host — strict
    checking is inherently a host-side decision).
    """
    if policy not in POSE_EXTRAPOLATION_POLICIES:
        raise ValueError(
            f"unknown pose_extrapolation policy {policy!r}: expected one of "
            f"{POSE_EXTRAPOLATION_POLICIES}")
    if policy == "clamp":
        return
    tq = np.atleast_1d(np.asarray(t_query))
    t0, t1 = float(times[0]), float(times[-1])
    below = tq < t0
    above = tq > t1
    n_out = int(below.sum() + above.sum())
    if n_out == 0:
        return
    worst = float(tq.max()) if above.any() else float(tq.min())
    msg = (f"{context}: {n_out} of {tq.shape[0]} query time(s) outside the "
           f"trajectory span [{t0:.6g}, {t1:.6g}] (worst t={worst:.6g}); "
           f"interpolation would freeze the pose at the span endpoint")
    if policy == "raise":
        raise PoseExtrapolationError(msg)
    warnings.warn(msg, PoseExtrapolationWarning, stacklevel=2)


def pose_at_times(traj: Trajectory, t_query: Array, *,
                  strict: bool = False) -> SE3:
    """Interpolate trajectory poses at query times (vectorized).

    With `strict=True`, queries outside `[times[0], times[-1]]` raise
    `PoseExtrapolationError` (host-side check) instead of clamping to the
    span endpoint. The default keeps the clamping numerics (callers that
    want a warning instead route through `enforce_pose_span`).

    Trajectories with fewer than two samples are rejected: a single
    sample cannot bracket any query, and the seed's index clip
    (`clip(idx, 0, shape[0] - 2)`) would produce an inverted [0, -1]
    bound and read `times[idx + 1]` out of range.
    """
    n = int(traj.times.shape[0])
    if n < 2:
        raise ValueError(
            f"pose interpolation needs at least 2 trajectory samples, got "
            f"{n}: one sample cannot bracket any query time")
    if strict:
        enforce_pose_span(np.asarray(traj.times), t_query, "raise")
    # stage the samples (host callers — TrajectoryBuffer, the aggregator —
    # hold numpy; the vmapped gather below needs device arrays)
    times = jnp.asarray(traj.times)
    R, t = jnp.asarray(traj.poses.R), jnp.asarray(traj.poses.t)
    # locate bracketing samples
    idx = jnp.clip(jnp.searchsorted(times, t_query, side="right") - 1,
                   0, n - 2)
    t0, t1 = times[idx], times[idx + 1]
    frac = jnp.clip((t_query - t0) / jnp.maximum(t1 - t0, 1e-9), 0.0, 1.0)

    def interp_one(i, f):
        p0 = SE3(R[i], t[i])
        p1 = SE3(R[i + 1], t[i + 1])
        return interpolate_pose(p0, p1, f)

    poses = jax.vmap(interp_one)(idx, frac)
    return poses


class TrajectoryBuffer:
    """Incrementally received trajectory with a pose-lag watermark.

    Pose chunks are pushed in time order (each chunk strictly after the
    previous one; times strictly increasing within a chunk). The
    **watermark** is the latest time at which interpolation is bracketed
    by received samples — `times[-1]` once at least two samples exist,
    `-inf` before that — and it only ever advances. `pose_at_times`
    answers queries strictly within the covered span
    `[times[0], watermark]` and raises `PoseExtrapolationError` outside
    it: a streamed pose source never silently extrapolates.

    Note the bitwise subtlety the streaming release logic leans on: for
    a query `t < watermark` the bracketing interval can never change
    when later chunks arrive, so interpolating from a prefix of the
    trajectory is bit-identical to interpolating from the full one. At
    `t == watermark` the bracket still depends on whether another sample
    will arrive, so callers that need bitwise offline equivalence gate
    on strict inequality until the pose stream is finalized.
    """

    def __init__(self, chunk: Trajectory | None = None):
        self._times = np.zeros((0,), np.float32)
        self._R = np.zeros((0, 3, 3), np.float32)
        self._t = np.zeros((0, 3), np.float32)
        if chunk is not None:
            self.push(chunk)

    @property
    def num_samples(self) -> int:
        return int(self._times.shape[0])

    @property
    def watermark(self) -> float:
        """Latest safely interpolable time; -inf until 2 samples exist."""
        if self.num_samples < 2:
            return float("-inf")
        return float(self._times[-1])

    @property
    def start_time(self) -> float:
        """Earliest covered time; +inf until 2 samples exist."""
        if self.num_samples < 2:
            return float("inf")
        return float(self._times[0])

    def push(self, chunk: Trajectory) -> float:
        """Append one pose chunk; returns the (possibly advanced) watermark.

        Chunks must arrive in time order: strictly increasing times
        within the chunk, and strictly after everything already
        buffered. Empty chunks are allowed (a tracker tick with no new
        keyposes).
        """
        times = np.asarray(chunk.times, np.float32).reshape(-1)
        R = np.asarray(chunk.poses.R, np.float32)
        t = np.asarray(chunk.poses.t, np.float32)
        m = times.shape[0]
        if R.shape != (m, 3, 3) or t.shape != (m, 3):
            raise ValueError(
                f"pose chunk shape mismatch: {m} times vs R {R.shape}, "
                f"t {t.shape}")
        if m == 0:
            return self.watermark
        if np.any(np.diff(times) <= 0):
            raise ValueError("pose chunk times must be strictly increasing")
        if self.num_samples and times[0] <= self._times[-1]:
            raise ValueError(
                f"pose chunk starts at t={float(times[0]):.6g} but the "
                f"buffer already covers up to t={float(self._times[-1]):.6g}: "
                f"chunks must arrive in time order")
        self._times = np.concatenate([self._times, times])
        self._R = np.concatenate([self._R, R])
        self._t = np.concatenate([self._t, t])
        return self.watermark

    @property
    def times(self) -> np.ndarray:
        """Host-side view of the received sample times (do not mutate)."""
        return self._times

    def covers(self, t_query) -> np.ndarray:
        """Elementwise: is the query bracketed by received samples?"""
        tq = np.asarray(t_query)
        if self.num_samples < 2:
            return np.zeros(tq.shape, bool)
        return (tq >= self._times[0]) & (tq <= self._times[-1])

    def trajectory(self, lo: int = 0, hi: int | None = None) -> Trajectory:
        """Host-side view of samples [lo, hi) (everything by default).

        Callers that interpolate repeatedly over an unbounded stream
        should pass the bracketing slice of their queries — staging the
        whole history to the device on every release would grow
        quadratically with stream length."""
        sl = slice(lo, hi)
        return Trajectory(times=self._times[sl],
                          poses=SE3(self._R[sl], self._t[sl]))

    def pose_at_times(self, t_query) -> SE3:
        """Interpolate within the covered span only.

        Raises `PoseExtrapolationError` for any query outside
        `[start_time, watermark]` — including every query while fewer
        than two samples have been received.
        """
        if self.num_samples < 2:
            raise PoseExtrapolationError(
                f"trajectory buffer holds {self.num_samples} pose sample(s); "
                f"interpolation needs at least 2 (watermark {self.watermark})")
        enforce_pose_span(
            self._times, t_query, "raise",
            context=f"streamed trajectory (watermark t={self.watermark:.6g})")
        return pose_at_times(self.trajectory(), t_query)
