"""Adversarial stream hygiene: validate event chunks before they vote.

Eventor's premise is real-time EMVS on a resource-bounded platform, but a
production ingest path cannot assume the sensor feed is the simulator's:
the event-vision survey (Gallego et al., arXiv 1904.08405) catalogs the
noise modes real pipelines see — out-of-order delivery from lossy
transports, duplicated packets from retrying links, hot-pixel storms
from damaged sensels, and spurious events at impossible coordinates.
Before this layer existed, `StreamingAggregator.push` documented
"sorted, contiguous with prior pushes" and validated nothing, so any of
those modes silently corrupted every frame downstream of the first bad
chunk.

`StreamHygiene` is the per-session guard the streaming engine puts in
front of the aggregator. Each chunk is checked against an event-time
watermark (the last timestamp this stream has committed) for:

  * intra-chunk non-monotone timestamps;
  * regression/overlap against prior pushes (chunk starts before the
    watermark);
  * exact-duplicate chunks (content digest matched against a bounded
    history of recently accepted chunks);
  * out-of-bounds pixel coordinates on events marked valid (the parked
    `PARKED_COORD` convention for *invalid* events is exempt);
  * hot-pixel storms: a per-pixel event-rate guard over tumbling time
    windows (`hot_pixel_limit` events per pixel per
    `hot_pixel_window` seconds; disabled by default because a sane
    threshold is scene- and sensor-dependent).

What happens on an offense is the `HygieneConfig.policy`:

  * `"raise"` (default) — reject the chunk atomically with a typed error
    (`NonMonotoneEventError`, `StreamOverlapError`,
    `DuplicateChunkError`, `OutOfBoundsEventError`, `HotPixelError`; all
    subclass `StreamHygieneError`, a `ValueError`) naming the first
    offending index. The guard's state is untouched, so the caller can
    continue with clean chunks.
  * `"drop"` — warn (`StreamHygieneWarning`) and discard exactly the
    offending events (whole chunk for a duplicate), counted per offense
    in `stats`. Injected garbage (duplicates, out-of-bounds events) is
    removed bit-exactly, so a stream that is clean apart from the
    injection reproduces its clean counterpart bitwise; genuinely
    misordered events are shed (not resorted) and the stream stays
    sorted at the cost of losing them.
  * `"reorder"` — a bounded reorder buffer restores sort order: events
    are held until the stream's maximum observed time has advanced
    `reorder_slack` seconds past them, then released in stable time
    order — bit-identical to a pre-sorted stream for any misordering
    whose displacement fits the slack. Ordering is the *only* offense
    this policy absorbs; duplicates, out-of-bounds coordinates and
    hot pixels still raise. An event older than what has already been
    released cannot be restored and raises `StreamOverlapError` naming
    the slack that was exceeded.
  * `"off"` — trust the feed, check nothing (the pre-hygiene behavior,
    for benchmarking the guard's overhead).

The guard is host-side numpy end to end (like the aggregator it
protects) and stateful per stream; `flush()` drains the reorder buffer
at end of stream. `check_chunk_monotone` is the standalone sorted/
contiguous check `StreamingAggregator.push` applies as a backstop for
callers that bypass the engine entirely.
"""
from __future__ import annotations

import dataclasses
import hashlib
import warnings

import numpy as np

from repro.events.simulator import EventStream

__all__ = [
    "DuplicateChunkError",
    "HotPixelError",
    "HYGIENE_POLICIES",
    "HygieneConfig",
    "NonMonotoneEventError",
    "OutOfBoundsEventError",
    "StreamHygiene",
    "StreamHygieneError",
    "StreamHygieneWarning",
    "StreamOverlapError",
    "check_chunk_monotone",
    "empty_event_stream",
]

HYGIENE_POLICIES = ("off", "raise", "drop", "reorder")


class StreamHygieneError(ValueError):
    """Base of every typed ingest-hygiene offense (a `ValueError`)."""


class NonMonotoneEventError(StreamHygieneError):
    """Timestamps within one chunk go backwards."""


class StreamOverlapError(StreamHygieneError):
    """A chunk regresses into (overlaps) time already committed."""


class DuplicateChunkError(StreamHygieneError):
    """A chunk is an exact byte-for-byte replay of a recent chunk."""


class OutOfBoundsEventError(StreamHygieneError):
    """An event marked valid lies outside the sensor array."""


class HotPixelError(StreamHygieneError):
    """A pixel exceeded the configured per-window event-rate limit."""


class StreamHygieneWarning(UserWarning):
    """Offending events were discarded under the "drop" policy."""


@dataclasses.dataclass(frozen=True)
class HygieneConfig:
    """Policy + knobs of the ingest guard (see the module docstring).

    `policy` picks the response to an offense ("off" / "raise" / "drop"
    / "reorder"). `reorder_slack` (seconds) bounds how far back the
    "reorder" buffer can restore order: an event is released once the
    stream's max observed time is `reorder_slack` ahead of it, so any
    misordering displaced by at most the slack is absorbed; 0.0 still
    fixes intra-chunk shuffles (each push sorts before releasing) but
    cannot absorb late chunks. `hot_pixel_limit` is the max events one
    pixel may emit per `hot_pixel_window` seconds (tumbling windows;
    None disables the guard — the right threshold depends on the scene
    and sensor). `duplicate_history` bounds how many recently accepted
    chunk digests are remembered for exact-duplicate detection.
    """

    policy: str = "raise"
    reorder_slack: float = 0.0
    hot_pixel_limit: int | None = None
    hot_pixel_window: float = 0.05
    duplicate_history: int = 8

    def __post_init__(self):
        if self.policy not in HYGIENE_POLICIES:
            raise ValueError(
                f"unknown hygiene policy {self.policy!r}: expected one of "
                f"{HYGIENE_POLICIES}")
        if self.reorder_slack < 0.0:
            raise ValueError(
                f"reorder_slack must be >= 0, got {self.reorder_slack}")
        if self.hot_pixel_limit is not None and self.hot_pixel_limit < 1:
            raise ValueError(
                f"hot_pixel_limit must be >= 1 (or None to disable), got "
                f"{self.hot_pixel_limit}")
        if self.hot_pixel_window <= 0.0:
            raise ValueError(
                f"hot_pixel_window must be > 0, got {self.hot_pixel_window}")
        if self.duplicate_history < 1:
            raise ValueError(
                f"duplicate_history must be >= 1, got "
                f"{self.duplicate_history}")


def empty_event_stream() -> EventStream:
    """A zero-event host-side EventStream."""
    return EventStream(xy=np.zeros((0, 2), np.float32),
                       t=np.zeros((0,), np.float32),
                       polarity=np.zeros((0,), np.int8),
                       valid=np.zeros((0,), bool))


def check_chunk_monotone(t: np.ndarray, last_t: float,
                         context: str = "event chunk") -> None:
    """Reject a chunk whose timestamps regress, naming the first offender.

    `t` must be non-decreasing and start no earlier than `last_t` (the
    final timestamp of the previous chunk; -inf for the first). This is
    the sorted/contiguous contract `StreamingAggregator.push` documents,
    enforced instead of assumed: index 0 regressing is an overlap with
    prior pushes (`StreamOverlapError`), a later index is an intra-chunk
    misordering (`NonMonotoneEventError`) — both are `ValueError`s.
    """
    t = np.asarray(t)
    if t.shape[0] == 0:
        return
    prev = np.empty_like(t)
    prev[0] = last_t
    prev[1:] = t[:-1]
    bad = np.nonzero(t < prev)[0]
    if bad.size == 0:
        return
    i = int(bad[0])
    if i == 0:
        raise StreamOverlapError(
            f"{context}: event 0 at t={float(t[0]):.6g} regresses behind "
            f"the stream watermark t={float(last_t):.6g} — the chunk "
            f"overlaps (or repeats) time already committed by prior pushes")
    raise NonMonotoneEventError(
        f"{context}: non-monotone timestamps — event {i} at "
        f"t={float(t[i]):.6g} precedes event {i - 1} at "
        f"t={float(t[i - 1]):.6g}")


def _host_chunk(chunk: EventStream) -> EventStream:
    return EventStream(xy=np.asarray(chunk.xy, np.float32),
                       t=np.asarray(chunk.t, np.float32),
                       polarity=np.asarray(chunk.polarity, np.int8),
                       valid=np.asarray(chunk.valid, bool))


def _take(chunk: EventStream, sel) -> EventStream:
    return EventStream(xy=chunk.xy[sel], t=chunk.t[sel],
                       polarity=chunk.polarity[sel], valid=chunk.valid[sel])


def _concat(a: EventStream, b: EventStream) -> EventStream:
    return EventStream(xy=np.concatenate([a.xy, b.xy]),
                       t=np.concatenate([a.t, b.t]),
                       polarity=np.concatenate([a.polarity, b.polarity]),
                       valid=np.concatenate([a.valid, b.valid]))


class StreamHygiene:
    """Stateful per-stream ingest guard (see the module docstring).

    `scrub(chunk)` returns the events cleared for aggregation as a
    host-side `EventStream` — possibly fewer than pushed ("drop"
    discards offenders; "reorder" holds events inside the slack window)
    and, under "reorder", possibly *more* (previously held events whose
    release time has come ride out in front, in time order). `flush()`
    drains whatever the reorder buffer still holds. Offenses follow the
    policy; a raise leaves the guard's state untouched (the offending
    chunk is rejected atomically).
    """

    def __init__(self, cfg: HygieneConfig | str = "raise", *,
                 width: int | None = None, height: int | None = None):
        if isinstance(cfg, str):
            cfg = HygieneConfig(policy=cfg)
        self.cfg = cfg
        self.width = width
        self.height = height
        # last committed event time: everything at/after it is still legal
        self.watermark = float("-inf")
        self._digests: list[bytes] = []  # recently accepted chunk digests
        # reorder buffer (policy="reorder"): held events, kept time-sorted
        self._held = empty_event_stream()
        # hot-pixel guard: (window, pixel) -> events seen, pruned as the
        # window index advances so memory tracks the window, not the stream
        self._px_counts: dict[int, int] = {}
        self._px_window = -1
        self.stats = {
            "chunks": 0,
            "events_in": 0,
            "events_out": 0,
            "dropped_out_of_order": 0,
            "dropped_duplicate_chunks": 0,
            "dropped_duplicate_events": 0,
            "dropped_out_of_bounds": 0,
            "dropped_hot_pixel": 0,
            "reorder_held_events": 0,
            "reorder_peak_held": 0,
        }

    # --- offense detectors (pure, state-mutation-free) --------------------

    def _digest(self, chunk: EventStream) -> bytes:
        h = hashlib.sha1()
        for field in (chunk.xy, chunk.t, chunk.polarity, chunk.valid):
            h.update(np.ascontiguousarray(field).tobytes())
        return h.digest()

    def _oob_mask(self, chunk: EventStream) -> np.ndarray:
        """True per event marked valid whose coords lie off the sensor."""
        if self.width is None or self.height is None:
            return np.zeros(chunk.t.shape[0], bool)
        x, y = chunk.xy[:, 0], chunk.xy[:, 1]
        off = ((x < 0) | (x > self.width - 1) | (y < 0)
               | (y > self.height - 1) | ~np.isfinite(x) | ~np.isfinite(y))
        return off & chunk.valid

    def _hot_pixel_mask(self, chunk: EventStream,
                        commit: bool) -> np.ndarray:
        """True per event that exceeds its pixel's per-window budget.

        Events are keyed by (tumbling time window, integer pixel); each
        key's running count carries across chunks. The first
        `hot_pixel_limit` events of a key pass, the excess offend — so
        under "drop" a storm is shed down to the budget while the
        healthy pixels' events are untouched. With `commit` the
        surviving counts are folded into the guard's state (set False
        while probing under "raise", where the chunk may be rejected).
        """
        lim = self.cfg.hot_pixel_limit
        n = chunk.t.shape[0]
        if lim is None or n == 0 or self.width is None:
            return np.zeros(n, bool)
        win = np.floor_divide(chunk.t, np.float32(self.cfg.hot_pixel_window)
                              ).astype(np.int64)
        xi = np.clip(np.round(chunk.xy[:, 0]), 0, self.width - 1).astype(
            np.int64)
        yi = np.clip(np.round(chunk.xy[:, 1]), 0, self.height - 1).astype(
            np.int64)
        key = (win * self.height + yi) * self.width + xi
        # occurrence index of each event within its key, in arrival order
        order = np.argsort(key, kind="stable")
        ks = key[order]
        starts = np.flatnonzero(np.r_[True, ks[1:] != ks[:-1]])
        occ_sorted = np.arange(n) - np.repeat(
            starts, np.diff(np.r_[starts, n]))
        occ = np.empty(n, np.int64)
        occ[order] = occ_sorted
        carry = np.asarray([self._px_counts.get(int(k), 0) for k in key],
                           np.int64)
        mask = (occ + carry) >= lim
        mask &= chunk.valid  # parked/invalid events never count
        if commit and n:
            ok = chunk.valid & ~mask
            if ok.any():
                uk, inv = np.unique(key[ok], return_inverse=True)
                added = np.bincount(inv)
                for k, a in zip(uk.tolist(), added.tolist()):
                    self._px_counts[k] = min(
                        self._px_counts.get(k, 0) + int(a), lim)
            w_max = int(win.max())
            if w_max > self._px_window:
                self._px_window = w_max
                floor = (w_max - 1) * self.height * self.width
                self._px_counts = {k: v for k, v in self._px_counts.items()
                                   if k >= floor}
        return mask

    # --- the guard --------------------------------------------------------

    def scrub(self, chunk: EventStream) -> EventStream:
        """Validate one chunk; return the events cleared for aggregation."""
        chunk = _host_chunk(chunk)
        n = chunk.t.shape[0]
        self.stats["chunks"] += 1
        self.stats["events_in"] += n
        policy = self.cfg.policy
        if policy == "off" or n == 0:
            out = self._release(chunk) if policy == "reorder" else chunk
            self.stats["events_out"] += out.t.shape[0]
            if out.t.shape[0] and policy == "off":
                self.watermark = max(self.watermark, float(out.t[-1]))
            return out
        digest = self._digest(chunk)
        duplicate = digest in self._digests
        if policy == "raise" or policy == "reorder":
            out = self._strict(chunk, digest, duplicate,
                               reorder=(policy == "reorder"))
        else:
            out = self._drop(chunk, digest, duplicate)
        self.stats["events_out"] += out.t.shape[0]
        return out

    def flush(self) -> EventStream:
        """Drain the reorder buffer (end of stream); empty otherwise."""
        held, self._held = self._held, empty_event_stream()
        self.stats["reorder_held_events"] = 0
        if held.t.shape[0]:
            self.watermark = max(self.watermark, float(held.t[-1]))
            self.stats["events_out"] += held.t.shape[0]
        return held

    def _remember(self, digest: bytes) -> None:
        self._digests.append(digest)
        if len(self._digests) > self.cfg.duplicate_history:
            self._digests.pop(0)

    def _strict(self, chunk: EventStream, digest: bytes, duplicate: bool,
                reorder: bool) -> EventStream:
        """"raise" (and the non-ordering offenses of "reorder"): typed
        errors, chunk rejected atomically — no state has been touched
        when an error propagates."""
        if duplicate:
            raise DuplicateChunkError(
                f"exact-duplicate chunk: {chunk.t.shape[0]} event(s) "
                f"spanning t=[{float(chunk.t[0]):.6g}, "
                f"{float(chunk.t[-1]):.6g}] byte-identically repeat a chunk "
                f"accepted within the last {len(self._digests)} push(es)")
        oob = self._oob_mask(chunk)
        if oob.any():
            i = int(np.argmax(oob))
            raise OutOfBoundsEventError(
                f"out-of-bounds event: event {i} marked valid at "
                f"xy=({float(chunk.xy[i, 0]):.6g}, "
                f"{float(chunk.xy[i, 1]):.6g}) lies outside the "
                f"{self.width}x{self.height} sensor array")
        if not reorder:
            check_chunk_monotone(chunk.t, self.watermark)
            hot = self._hot_pixel_mask(chunk, commit=False)
            if hot.any():
                i = int(np.argmax(hot))
                raise HotPixelError(
                    f"hot-pixel storm: event {i} at "
                    f"xy=({float(chunk.xy[i, 0]):.6g}, "
                    f"{float(chunk.xy[i, 1]):.6g}) exceeds "
                    f"{self.cfg.hot_pixel_limit} events/pixel per "
                    f"{self.cfg.hot_pixel_window:.6g}s window")
            self._hot_pixel_mask(chunk, commit=True)
            self._remember(digest)
            self.watermark = float(chunk.t[-1])
            return chunk
        # reorder: ordering offenses are absorbed by the buffer instead
        released = np.flatnonzero(chunk.t < self.watermark)
        if released.size:
            i = int(released[0])
            raise StreamOverlapError(
                f"reorder window exceeded: event {i} at "
                f"t={float(chunk.t[i]):.6g} arrives behind the release "
                f"watermark t={self.watermark:.6g} — its slot was already "
                f"released under reorder_slack="
                f"{self.cfg.reorder_slack:.6g}s; increase the slack or "
                f"fix the transport")
        hot = self._hot_pixel_mask(chunk, commit=False)
        if hot.any():
            i = int(np.argmax(hot))
            raise HotPixelError(
                f"hot-pixel storm: event {i} at "
                f"xy=({float(chunk.xy[i, 0]):.6g}, "
                f"{float(chunk.xy[i, 1]):.6g}) exceeds "
                f"{self.cfg.hot_pixel_limit} events/pixel per "
                f"{self.cfg.hot_pixel_window:.6g}s window")
        self._hot_pixel_mask(chunk, commit=True)
        self._remember(digest)
        return self._release(chunk)

    def _release(self, chunk: EventStream) -> EventStream:
        """Merge `chunk` into the reorder buffer (stable time sort) and
        release everything `reorder_slack` behind the max observed time.

        Released events are bit-identical to a pre-sorted stream for any
        misordering whose displacement fits the slack: a stable sort of
        arrival order reproduces the original sequence, and the release
        point only moves forward.
        """
        merged = _concat(self._held, chunk)
        if merged.t.shape[0] == 0:
            return merged
        order = np.argsort(merged.t, kind="stable")
        merged = _take(merged, order)
        horizon = float(merged.t[-1]) - self.cfg.reorder_slack
        cut = int(np.searchsorted(merged.t, np.float32(horizon),
                                  side="right"))
        out = _take(merged, slice(0, cut))
        self._held = _take(merged, slice(cut, merged.t.shape[0]))
        n_held = self._held.t.shape[0]
        self.stats["reorder_held_events"] = n_held
        self.stats["reorder_peak_held"] = max(
            self.stats["reorder_peak_held"], n_held)
        if out.t.shape[0]:
            self.watermark = max(self.watermark, float(out.t[-1]))
        return out

    def _drop(self, chunk: EventStream, digest: bytes,
              duplicate: bool) -> EventStream:
        """"drop": discard exactly the offending events, warn, count."""
        n = chunk.t.shape[0]
        if duplicate:
            self.stats["dropped_duplicate_chunks"] += 1
            self.stats["dropped_duplicate_events"] += n
            warnings.warn(
                f"dropped exact-duplicate chunk of {n} event(s)",
                StreamHygieneWarning, stacklevel=3)
            return empty_event_stream()
        keep = np.ones(n, bool)
        oob = self._oob_mask(chunk)
        keep &= ~oob
        # shed misordered events: keep the longest non-decreasing-from-
        # watermark subsequence an online filter can (each survivor must
        # not precede any earlier arrival or the committed watermark)
        prefix = np.maximum.accumulate(
            np.r_[np.float32(self.watermark), chunk.t[:-1]])
        in_order = chunk.t >= prefix
        keep &= in_order
        hot = np.zeros(n, bool)
        if keep.any():
            survivors = _take(chunk, keep)
            hot_s = self._hot_pixel_mask(survivors, commit=True)
            hot[np.flatnonzero(keep)] = hot_s
            keep &= ~hot
        n_oob = int(oob.sum())
        n_ooo = int((~in_order & ~oob).sum())
        n_hot = int(hot.sum())
        self.stats["dropped_out_of_bounds"] += n_oob
        self.stats["dropped_out_of_order"] += n_ooo
        self.stats["dropped_hot_pixel"] += n_hot
        dropped = n_oob + n_ooo + n_hot
        if dropped:
            parts = [f"{c} {what}" for c, what in (
                (n_ooo, "out-of-order"), (n_oob, "out-of-bounds"),
                (n_hot, "hot-pixel")) if c]
            warnings.warn(
                f"dropped {dropped} offending event(s) of {n}: "
                + ", ".join(parts), StreamHygieneWarning, stacklevel=3)
        self._remember(digest)
        out = _take(chunk, keep)
        if out.t.shape[0]:
            self.watermark = float(out.t[-1])
        return out
