"""Event data pipeline: simulator, streaming correction, incremental
aggregation (`StreamingAggregator` carries partial frames across chunks),
the streamed trajectory (`trajectory_stream.TrajectoryBuffer`: pose
chunks in, pose-lag watermark out; frames past the watermark stall until
their bracketing poses arrive — never silently extrapolated), and ingest
hygiene (`stream_hygiene.StreamHygiene`: adversarial chunks — misordered,
overlapping, duplicate, out-of-bounds, hot-pixel storms — raise typed
errors, shed offenders, or reorder within a bounded slack;
`simulator.corrupt_stream` fault-injects exactly those modes)."""
