"""Event data pipeline: simulator, streaming correction, incremental
aggregation (`StreamingAggregator` carries partial frames across chunks),
and the streamed trajectory (`trajectory_stream.TrajectoryBuffer`: pose
chunks in, pose-lag watermark out; frames past the watermark stall until
their bracketing poses arrive — never silently extrapolated)."""
