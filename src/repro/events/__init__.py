"""Event data pipeline: simulator, streaming correction, incremental
aggregation (`StreamingAggregator` carries partial frames across chunks)."""
