"""Event data pipeline: simulator, streaming correction, aggregation."""
