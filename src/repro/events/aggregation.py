"""Event aggregation (A): stream -> fixed-size event frames.

The paper aggregates 1024 events per frame ("determined according to the
sensor's event rate and storage") and attaches one camera pose per frame
(interpolated at the frame's mid-timestamp).

Per the paper's rescheduling, distortion correction runs *before*
aggregation, per event, in streaming order.

Aggregation is incremental: `StreamingAggregator` accepts raw event
chunks of arbitrary size and carries the partial-frame remainder across
pushes, exactly as the device-side A stage holds a partial frame in its
buffer while waiting for more events. The offline `aggregate` is one big
push plus a flush, so the stream's tail is emitted as a final padded
frame instead of being silently dropped.

Poses come from either a fully-known `Trajectory` (the offline oracle)
or a `TrajectoryBuffer` receiving the tracker's pose stream in chunks
(`repro.events.trajectory_stream`). In the streamed (pose-gated) mode a
completed frame whose mid-time lies beyond the buffer's pose-lag
watermark is *stalled* — held unposed until the bracketing pose chunk
arrives — and then released bitwise-identically posed, so any
interleaving of event and pose chunks yields the same frames. Queries
outside the received span follow the `pose_extrapolation` policy
("warn" by default: clamp + `PoseExtrapolationWarning`; "raise";
"clamp" restores the seed's silent freeze and exists only for
compatibility).
"""
from __future__ import annotations

from collections import deque
from typing import NamedTuple

import jax
import numpy as np

from repro.core.camera import CameraModel, undistort_events
from repro.core.geometry import SE3
from repro.events.simulator import EventStream, Trajectory
from repro.events.stream_hygiene import check_chunk_monotone
from repro.events.trajectory_stream import (
    POSE_EXTRAPOLATION_POLICIES,
    PoseExtrapolationError,
    PoseExtrapolationWarning,
    PoseStallError,
    TrajectoryBuffer,
    enforce_pose_span,
    pose_at_times,
)

__all__ = [
    "EVENTS_PER_FRAME",
    "PARKED_COORD",
    "EventFrames",
    "PoseExtrapolationError",
    "PoseExtrapolationWarning",
    "PoseStallError",
    "StreamingAggregator",
    "TrajectoryBuffer",
    "aggregate",
    "concat_event_frames",
    "empty_event_frames",
    "pose_at_times",
]

Array = jax.Array

EVENTS_PER_FRAME = 1024  # paper §4.3

# Pad coordinate for events that exist only to fill out a frame: parked far
# outside the image (the simulator's convention for invalid events) so every
# downstream stage masks them even before the validity weight zeroes them.
PARKED_COORD = -1e4


class EventFrames(NamedTuple):
    """Aggregated frames. Fields produced by this module are host-side
    (numpy) arrays — staging into device programs happens downstream
    (`pad_segments`, the streaming engine's frame store) — but every
    consumer accepts jax arrays interchangeably."""

    xy: Array  # (F, E, 2) rectified coords
    valid: Array  # (F, E)
    t_mid: Array  # (F,)
    poses: SE3  # batched (F,3,3),(F,3): per-frame camera pose


def empty_event_frames(events_per_frame: int = EVENTS_PER_FRAME) -> EventFrames:
    """A zero-frame EventFrames with the usual field shapes/dtypes."""
    return EventFrames(
        xy=np.zeros((0, events_per_frame, 2), np.float32),
        valid=np.zeros((0, events_per_frame), bool),
        t_mid=np.zeros((0,), np.float32),
        poses=SE3(np.zeros((0, 3, 3), np.float32),
                  np.zeros((0, 3), np.float32)),
    )


def concat_event_frames(parts: list[EventFrames]) -> EventFrames:
    """Concatenate EventFrames along the frame axis (empties dropped)."""
    parts = [p for p in parts if p.xy.shape[0] > 0]
    if not parts:
        return empty_event_frames()
    if len(parts) == 1:
        return parts[0]
    return jax.tree.map(lambda *xs: np.concatenate([np.asarray(x) for x in xs],
                                                   axis=0), *parts)


class _StalledFrame(NamedTuple):
    """A completed frame waiting for its bracketing pose samples."""

    xy: np.ndarray  # (E, 2)
    valid: np.ndarray  # (E,)
    t_mid: float


class StreamingAggregator:
    """Incremental A stage: push raw event chunks, receive completed frames.

    Each `push` applies streaming distortion correction to the chunk,
    prepends the remainder carried from the previous push, and emits every
    completed `events_per_frame`-sized frame (with its interpolated pose).
    The tail that does not fill a frame stays buffered for the next push;
    `flush` emits it as one final frame padded with parked, invalid events.

    Chunk boundaries never change the emitted frames: any chunking of the
    same stream produces bitwise-identical EventFrames (the streaming
    engine's offline-equivalence tests lean on exactly this).

    Pose source (`traj`):
      * a `Trajectory` — the offline oracle; every completed frame is
        posed immediately. Frame mid-times outside the trajectory span
        follow `pose_extrapolation` ("warn" clamps with
        `PoseExtrapolationWarning`; "raise" refuses; "clamp" is the
        seed's silent freeze, opt-in only).
      * a `TrajectoryBuffer` — the streamed tracker. Completed frames
        whose `t_mid` is not yet *strictly below* the buffer's watermark
        stall (see `stalled_frames`) and are released FIFO by
        `push_poses` / `finalize_poses` once the bracketing samples
        arrive; the strict inequality makes the released pose
        bit-identical to interpolating against the full trajectory, for
        any interleaving of event and pose chunks. `finalize_poses`
        declares the pose stream over: remaining frames release through
        the `pose_extrapolation` policy (they can only be beyond-span).

    `max_stalled` (pose-gated mode only) is the max-stall back-pressure
    bound: a push that leaves more than `max_stalled` frames stalled
    *past the current watermark* (i.e. frames the received poses cannot
    release — a tracker that keeps up never trips the bound) raises
    `PoseStallError`. The check runs after buffering the chunk's frames
    and before any release, so no event is lost: the caller recovers by
    pushing the missing pose chunks. Without a bound a tracker that
    silently dies would grow the stall queue (and every queue downstream
    of it) with the event rate, unboundedly.
    """

    def __init__(self, cam: CameraModel, traj: Trajectory | TrajectoryBuffer,
                 events_per_frame: int = EVENTS_PER_FRAME, *,
                 pose_extrapolation: str = "warn",
                 max_stalled: int | None = None):
        if events_per_frame < 1:
            raise ValueError(f"events_per_frame must be >= 1, got {events_per_frame}")
        if pose_extrapolation not in POSE_EXTRAPOLATION_POLICIES:
            raise ValueError(
                f"unknown pose_extrapolation policy {pose_extrapolation!r}: "
                f"expected one of {POSE_EXTRAPOLATION_POLICIES}")
        if max_stalled is not None and max_stalled < 1:
            raise ValueError(
                f"max_stalled must be >= 1 (or None for unbounded), got "
                f"{max_stalled}")
        self.cam = cam
        self.traj = traj
        self.pose_extrapolation = pose_extrapolation
        self.max_stalled = max_stalled
        self._gated = isinstance(traj, TrajectoryBuffer)
        if max_stalled is not None and not self._gated:
            raise ValueError(
                "max_stalled requires a TrajectoryBuffer pose source: a "
                "fully-known Trajectory oracle never stalls frames, so "
                "the bound would silently do nothing")
        # one host copy of the oracle's sample times for span checks
        self._traj_times_host = (None if self._gated
                                 else np.asarray(traj.times, np.float32))
        self.events_per_frame = int(events_per_frame)
        self._rem_xy = np.zeros((0, 2), np.float32)
        self._rem_t = np.zeros((0,), np.float32)
        self._rem_valid = np.zeros((0,), bool)
        self._last_t = float("-inf")
        self._stalled: deque[_StalledFrame] = deque()
        self._pose_final = False

    @property
    def pending_events(self) -> int:
        """Events buffered toward the next (incomplete) frame."""
        return self._rem_xy.shape[0]

    @property
    def pose_gated(self) -> bool:
        """True when the pose source is a streamed `TrajectoryBuffer`."""
        return self._gated

    @property
    def stalled_frames(self) -> int:
        """Completed frames held back waiting for pose chunks."""
        return len(self._stalled)

    @property
    def oldest_stalled_t(self) -> float:
        """Mid-time of the oldest stalled frame (+inf if none)."""
        return self._stalled[0].t_mid if self._stalled else float("inf")

    @property
    def pose_watermark(self) -> float:
        """Latest safely interpolable pose time received so far."""
        if self._gated:
            return self.traj.watermark
        return float(self._traj_times_host[-1])

    def push(self, chunk: EventStream) -> EventFrames:
        """Ingest a chunk (sorted, contiguous with prior pushes) of events.

        The sorted/contiguous contract is enforced, not assumed: a chunk
        with non-monotone timestamps, or one starting before the last
        pushed timestamp, raises a `ValueError`
        (`NonMonotoneEventError` / `StreamOverlapError`) naming the
        first offending index — a frame aggregated from misordered
        events would get a wrong mid-time and vote under a wrong pose,
        silently. Streams that need tolerance (drop, bounded reorder)
        should go through `events.stream_hygiene.StreamHygiene` (the
        streaming engine's `StreamConfig(hygiene=...)` does) before
        this backstop.
        """
        t_chunk = np.asarray(chunk.t, np.float32)
        check_chunk_monotone(t_chunk, self._last_t,
                             context="StreamingAggregator.push")
        if t_chunk.shape[0]:
            self._last_t = float(t_chunk[-1])
        xy = (undistort_events(self.cam, chunk.xy)
              if self.cam.has_distortion() else chunk.xy)
        xy = np.concatenate([self._rem_xy, np.asarray(xy, np.float32)])
        t = np.concatenate([self._rem_t, np.asarray(chunk.t, np.float32)])
        valid = np.concatenate([self._rem_valid, np.asarray(chunk.valid, bool)])
        e = self.events_per_frame
        n_frames = xy.shape[0] // e
        n_keep = n_frames * e
        self._rem_xy, self._rem_t, self._rem_valid = (
            xy[n_keep:], t[n_keep:], valid[n_keep:])
        return self._emit(xy[:n_keep], t[:n_keep], valid[:n_keep], n_frames)

    def push_poses(self, chunk: Trajectory) -> EventFrames:
        """Feed one pose chunk to the streamed trajectory; returns the
        stalled frames the advanced watermark releases (possibly none)."""
        if not self._gated:
            raise RuntimeError(
                "push_poses requires a TrajectoryBuffer pose source; this "
                "aggregator was built with a fully-known Trajectory oracle")
        self.traj.push(chunk)
        return self._release()

    def finalize_poses(self) -> EventFrames:
        """Declare the pose stream complete and release every stalled frame.

        Frames at or beyond the final watermark can no longer gain a
        bracketing sample, so they release through the
        `pose_extrapolation` policy (warn-clamp or raise)."""
        if not self._gated:
            raise RuntimeError(
                "finalize_poses requires a TrajectoryBuffer pose source; "
                "a Trajectory oracle is always complete")
        self._pose_final = True
        return self._release()

    def flush(self) -> EventFrames:
        """Emit the buffered tail as one padded frame (empty if no tail).

        In pose-gated mode the tail frame joins the stall queue like any
        other frame; the returned EventFrames contain only what the
        current watermark releases (check `stalled_frames` afterwards)."""
        e = self.events_per_frame
        n_rem = self._rem_xy.shape[0]
        if n_rem == 0:
            return self._release() if self._gated else empty_event_frames(e)
        # t_mid from the REAL tail events only — the padding exists to fill
        # the frame shape and must not drag the pose toward the last event
        t_mid = np.asarray(np.median(self._rem_t), np.float32).reshape(1)
        pad = e - n_rem
        xy = np.concatenate(
            [self._rem_xy, np.full((pad, 2), PARKED_COORD, np.float32)])
        t = np.concatenate(
            [self._rem_t, np.full((pad,), self._rem_t[-1], np.float32)])
        valid = np.concatenate([self._rem_valid, np.zeros((pad,), bool)])
        self._rem_xy = np.zeros((0, 2), np.float32)
        self._rem_t = np.zeros((0,), np.float32)
        self._rem_valid = np.zeros((0,), bool)
        return self._emit(xy, t, valid, 1, t_mid=t_mid)

    def _emit(self, xy: np.ndarray, t: np.ndarray, valid: np.ndarray,
              n_frames: int, t_mid: np.ndarray | None = None) -> EventFrames:
        e = self.events_per_frame
        if n_frames == 0:
            return self._release() if self._gated else empty_event_frames(e)
        t_f = t.reshape(n_frames, e)
        if t_mid is None:
            # host median: frames stay on the host (numpy) end to end — the
            # consumers (pad_segments, the streaming engine's frame store)
            # stage host-side, so a device round-trip per push would be
            # pure waste. np.median matches jnp.median bitwise on float32.
            t_mid = np.median(t_f, axis=1)
        t_mid = np.asarray(t_mid, np.float32)
        xy_f = xy.reshape(n_frames, e, 2)
        valid_f = valid.reshape(n_frames, e)
        if self._gated:
            for k in range(n_frames):
                self._stalled.append(
                    _StalledFrame(xy_f[k], valid_f[k], float(t_mid[k])))
            # Max-stall back-pressure: an event front running unboundedly
            # ahead of the pose tracker would grow the stall queue (and
            # everything downstream of it — the engine's coalescing queue
            # included) without limit. Only frames the CURRENT watermark
            # cannot release count toward the bound (a tracker that keeps
            # up never trips it), and the check runs after buffering the
            # chunk's frames but BEFORE the release — on overflow nothing
            # has been popped, so no frame is ever dropped and the caller
            # recovers by pushing the missing pose chunks (draining the
            # queue bit-identically) before feeding more events.
            if self.max_stalled is not None:
                wm = self.pose_watermark
                backlog = sum(1 for f in self._stalled if not f.t_mid < wm)
                if backlog > self.max_stalled:
                    raise PoseStallError(
                        f"pose tracker too far behind the event front: "
                        f"{backlog} frame(s) stalled past the watermark "
                        f"exceeds max_stalled={self.max_stalled} (watermark "
                        f"t={wm:.6g}, oldest stalled frame "
                        f"t_mid={self.oldest_stalled_t:.6g}); the frames "
                        f"are buffered — push the missing pose chunks to "
                        f"drain the stall queue before feeding more events")
            return self._release()
        enforce_pose_span(self._traj_times_host, t_mid,
                          self.pose_extrapolation, context="frame mid-times")
        poses = pose_at_times(self.traj, t_mid)
        return EventFrames(
            xy=xy_f,
            valid=valid_f,
            t_mid=t_mid,
            poses=SE3(np.asarray(poses.R, np.float32),
                      np.asarray(poses.t, np.float32)),
        )

    def _release(self) -> EventFrames:
        """Pose and emit the FIFO prefix of stalled frames the watermark
        covers (everything, once the pose stream is finalized)."""
        e = self.events_per_frame
        if not self._stalled:
            return empty_event_frames(e)
        buf: TrajectoryBuffer = self.traj
        if buf.num_samples < 2:
            if self._pose_final:
                raise PoseExtrapolationError(
                    f"pose stream finalized with {buf.num_samples} sample(s) "
                    f"received; {len(self._stalled)} stalled frame(s) can "
                    f"never be posed")
            return empty_event_frames(e)
        if self._pose_final:
            take = len(self._stalled)
        else:
            # strictly below the watermark: the bracketing interval can no
            # longer change, so the interpolated pose is bit-identical to
            # the one the full trajectory will eventually give
            wm = buf.watermark
            take = 0
            while take < len(self._stalled) and self._stalled[take].t_mid < wm:
                take += 1
        if take == 0:
            return empty_event_frames(e)
        frames = [self._stalled.popleft() for _ in range(take)]
        t_mid = np.asarray([f.t_mid for f in frames], np.float32)
        times = buf.times
        n_s = times.shape[0]
        enforce_pose_span(times, t_mid, self.pose_extrapolation,
                          context="stalled frame mid-times")
        # stage only the bracketing slice of the pose history: released
        # t_mid are ascending (FIFO over a sorted event stream), and
        # searchsorted over a slice containing every bracket returns the
        # same intervals — so the pose stays bitwise identical while an
        # unbounded stream no longer re-transfers its whole past
        lo = int(np.clip(np.searchsorted(times, t_mid[0], side="right") - 1,
                         0, n_s - 2))
        hi = max(min(n_s, int(np.searchsorted(times, t_mid[-1],
                                              side="right")) + 1), lo + 2)
        poses = pose_at_times(buf.trajectory(lo, hi), t_mid)
        return EventFrames(
            xy=np.stack([f.xy for f in frames]),
            valid=np.stack([f.valid for f in frames]),
            t_mid=t_mid,
            poses=SE3(np.asarray(poses.R, np.float32),
                      np.asarray(poses.t, np.float32)),
        )


def aggregate(cam: CameraModel, stream: EventStream, traj: Trajectory,
              events_per_frame: int = EVENTS_PER_FRAME,
              keep_tail: bool = True, *,
              pose_extrapolation: str = "warn") -> EventFrames:
    """Slice the (sorted) stream into frames of `events_per_frame`.

    One-big-chunk push through `StreamingAggregator`, so streaming and
    offline aggregation share one code path. With `keep_tail` (default)
    the trailing partial frame is flushed as a final padded frame; with
    `keep_tail=False` it is dropped (the seed's behavior — a device-side
    partial frame that never saw its remaining events).

    Frame mid-times outside the trajectory span no longer freeze the
    pose silently: the default `pose_extrapolation="warn"` keeps the
    clamped numerics but emits `PoseExtrapolationWarning`; "raise"
    refuses with `PoseExtrapolationError`; "clamp" restores the seed's
    silent behavior for callers that explicitly want it.
    """
    agg = StreamingAggregator(cam, traj, events_per_frame,
                              pose_extrapolation=pose_extrapolation)
    full = agg.push(stream)
    if not keep_tail:
        return full
    tail = agg.flush()
    if full.xy.shape[0] == 0 and tail.xy.shape[0] == 0:
        return empty_event_frames(events_per_frame)
    return concat_event_frames([full, tail])
