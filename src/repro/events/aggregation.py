"""Event aggregation (A): stream -> fixed-size event frames.

The paper aggregates 1024 events per frame ("determined according to the
sensor's event rate and storage") and attaches one camera pose per frame
(interpolated at the frame's mid-timestamp).

Per the paper's rescheduling, distortion correction runs *before*
aggregation, per event, in streaming order.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.camera import CameraModel, undistort_events
from repro.core.geometry import SE3, interpolate_pose
from repro.events.simulator import EventStream, Trajectory

Array = jax.Array

EVENTS_PER_FRAME = 1024  # paper §4.3


class EventFrames(NamedTuple):
    xy: Array  # (F, E, 2) rectified coords
    valid: Array  # (F, E)
    t_mid: Array  # (F,)
    poses: SE3  # batched (F,3,3),(F,3): per-frame camera pose


def pose_at_times(traj: Trajectory, t_query: Array) -> SE3:
    """Interpolate trajectory poses at query times (vectorized)."""
    # locate bracketing samples
    idx = jnp.clip(jnp.searchsorted(traj.times, t_query, side="right") - 1,
                   0, traj.times.shape[0] - 2)
    t0, t1 = traj.times[idx], traj.times[idx + 1]
    frac = jnp.clip((t_query - t0) / jnp.maximum(t1 - t0, 1e-9), 0.0, 1.0)

    def interp_one(i, f):
        p0 = SE3(traj.poses.R[i], traj.poses.t[i])
        p1 = SE3(traj.poses.R[i + 1], traj.poses.t[i + 1])
        return interpolate_pose(p0, p1, f)

    poses = jax.vmap(interp_one)(idx, frac)
    return poses


def aggregate(cam: CameraModel, stream: EventStream, traj: Trajectory,
              events_per_frame: int = EVENTS_PER_FRAME) -> EventFrames:
    """Slice the (sorted) stream into frames of `events_per_frame`.

    Streaming distortion correction is applied first (paper rescheduling).
    The tail that does not fill a frame is dropped (as on the device,
    where a partial frame waits for more events).
    """
    xy = undistort_events(cam, stream.xy) if cam.has_distortion() else stream.xy
    n_frames = stream.t.shape[0] // events_per_frame
    n_keep = n_frames * events_per_frame
    xy = xy[:n_keep].reshape(n_frames, events_per_frame, 2)
    valid = stream.valid[:n_keep].reshape(n_frames, events_per_frame)
    t = stream.t[:n_keep].reshape(n_frames, events_per_frame)
    t_mid = jnp.median(t, axis=1)
    poses = pose_at_times(traj, t_mid)
    return EventFrames(xy=xy, valid=valid, t_mid=t_mid, poses=poses)
