"""Event aggregation (A): stream -> fixed-size event frames.

The paper aggregates 1024 events per frame ("determined according to the
sensor's event rate and storage") and attaches one camera pose per frame
(interpolated at the frame's mid-timestamp).

Per the paper's rescheduling, distortion correction runs *before*
aggregation, per event, in streaming order.

Aggregation is incremental: `StreamingAggregator` accepts raw event
chunks of arbitrary size and carries the partial-frame remainder across
pushes, exactly as the device-side A stage holds a partial frame in its
buffer while waiting for more events. The offline `aggregate` is one big
push plus a flush, so the stream's tail is emitted as a final padded
frame instead of being silently dropped.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.camera import CameraModel, undistort_events
from repro.core.geometry import SE3, interpolate_pose
from repro.events.simulator import EventStream, Trajectory

Array = jax.Array

EVENTS_PER_FRAME = 1024  # paper §4.3

# Pad coordinate for events that exist only to fill out a frame: parked far
# outside the image (the simulator's convention for invalid events) so every
# downstream stage masks them even before the validity weight zeroes them.
PARKED_COORD = -1e4


class EventFrames(NamedTuple):
    """Aggregated frames. Fields produced by this module are host-side
    (numpy) arrays — staging into device programs happens downstream
    (`pad_segments`, the streaming engine's frame store) — but every
    consumer accepts jax arrays interchangeably."""

    xy: Array  # (F, E, 2) rectified coords
    valid: Array  # (F, E)
    t_mid: Array  # (F,)
    poses: SE3  # batched (F,3,3),(F,3): per-frame camera pose


def empty_event_frames(events_per_frame: int = EVENTS_PER_FRAME) -> EventFrames:
    """A zero-frame EventFrames with the usual field shapes/dtypes."""
    return EventFrames(
        xy=np.zeros((0, events_per_frame, 2), np.float32),
        valid=np.zeros((0, events_per_frame), bool),
        t_mid=np.zeros((0,), np.float32),
        poses=SE3(np.zeros((0, 3, 3), np.float32),
                  np.zeros((0, 3), np.float32)),
    )


def concat_event_frames(parts: list[EventFrames]) -> EventFrames:
    """Concatenate EventFrames along the frame axis (empties dropped)."""
    parts = [p for p in parts if p.xy.shape[0] > 0]
    if not parts:
        return empty_event_frames()
    if len(parts) == 1:
        return parts[0]
    return jax.tree.map(lambda *xs: np.concatenate([np.asarray(x) for x in xs],
                                                   axis=0), *parts)


def pose_at_times(traj: Trajectory, t_query: Array) -> SE3:
    """Interpolate trajectory poses at query times (vectorized)."""
    # locate bracketing samples
    idx = jnp.clip(jnp.searchsorted(traj.times, t_query, side="right") - 1,
                   0, traj.times.shape[0] - 2)
    t0, t1 = traj.times[idx], traj.times[idx + 1]
    frac = jnp.clip((t_query - t0) / jnp.maximum(t1 - t0, 1e-9), 0.0, 1.0)

    def interp_one(i, f):
        p0 = SE3(traj.poses.R[i], traj.poses.t[i])
        p1 = SE3(traj.poses.R[i + 1], traj.poses.t[i + 1])
        return interpolate_pose(p0, p1, f)

    poses = jax.vmap(interp_one)(idx, frac)
    return poses


class StreamingAggregator:
    """Incremental A stage: push raw event chunks, receive completed frames.

    Each `push` applies streaming distortion correction to the chunk,
    prepends the remainder carried from the previous push, and emits every
    completed `events_per_frame`-sized frame (with its interpolated pose).
    The tail that does not fill a frame stays buffered for the next push;
    `flush` emits it as one final frame padded with parked, invalid events.

    Chunk boundaries never change the emitted frames: any chunking of the
    same stream produces bitwise-identical EventFrames (the streaming
    engine's offline-equivalence tests lean on exactly this).
    """

    def __init__(self, cam: CameraModel, traj: Trajectory,
                 events_per_frame: int = EVENTS_PER_FRAME):
        if events_per_frame < 1:
            raise ValueError(f"events_per_frame must be >= 1, got {events_per_frame}")
        self.cam = cam
        self.traj = traj
        self.events_per_frame = int(events_per_frame)
        self._rem_xy = np.zeros((0, 2), np.float32)
        self._rem_t = np.zeros((0,), np.float32)
        self._rem_valid = np.zeros((0,), bool)

    @property
    def pending_events(self) -> int:
        """Events buffered toward the next (incomplete) frame."""
        return self._rem_xy.shape[0]

    def push(self, chunk: EventStream) -> EventFrames:
        """Ingest a chunk (sorted, contiguous with prior pushes) of events."""
        xy = (undistort_events(self.cam, chunk.xy)
              if self.cam.has_distortion() else chunk.xy)
        xy = np.concatenate([self._rem_xy, np.asarray(xy, np.float32)])
        t = np.concatenate([self._rem_t, np.asarray(chunk.t, np.float32)])
        valid = np.concatenate([self._rem_valid, np.asarray(chunk.valid, bool)])
        e = self.events_per_frame
        n_frames = xy.shape[0] // e
        n_keep = n_frames * e
        self._rem_xy, self._rem_t, self._rem_valid = (
            xy[n_keep:], t[n_keep:], valid[n_keep:])
        return self._emit(xy[:n_keep], t[:n_keep], valid[:n_keep], n_frames)

    def flush(self) -> EventFrames:
        """Emit the buffered tail as one padded frame (empty if no tail)."""
        e = self.events_per_frame
        n_rem = self._rem_xy.shape[0]
        if n_rem == 0:
            return empty_event_frames(e)
        # t_mid from the REAL tail events only — the padding exists to fill
        # the frame shape and must not drag the pose toward the last event
        t_mid = jnp.median(jnp.asarray(self._rem_t))[None]
        pad = e - n_rem
        xy = np.concatenate(
            [self._rem_xy, np.full((pad, 2), PARKED_COORD, np.float32)])
        t = np.concatenate(
            [self._rem_t, np.full((pad,), self._rem_t[-1], np.float32)])
        valid = np.concatenate([self._rem_valid, np.zeros((pad,), bool)])
        self._rem_xy = np.zeros((0, 2), np.float32)
        self._rem_t = np.zeros((0,), np.float32)
        self._rem_valid = np.zeros((0,), bool)
        return self._emit(xy, t, valid, 1, t_mid=t_mid)

    def _emit(self, xy: np.ndarray, t: np.ndarray, valid: np.ndarray,
              n_frames: int, t_mid: Array | None = None) -> EventFrames:
        e = self.events_per_frame
        if n_frames == 0:
            return empty_event_frames(e)
        t_f = t.reshape(n_frames, e)
        if t_mid is None:
            t_mid = jnp.median(jnp.asarray(t_f), axis=1)
        poses = pose_at_times(self.traj, t_mid)
        # frames stay on the host (numpy): the consumers — pad_segments and
        # the streaming engine's frame store — stage host-side, so an eager
        # device round-trip per emitted frame would be pure waste
        return EventFrames(
            xy=xy.reshape(n_frames, e, 2),
            valid=valid.reshape(n_frames, e),
            t_mid=np.asarray(t_mid, np.float32),
            poses=SE3(np.asarray(poses.R, np.float32),
                      np.asarray(poses.t, np.float32)),
        )


def aggregate(cam: CameraModel, stream: EventStream, traj: Trajectory,
              events_per_frame: int = EVENTS_PER_FRAME,
              keep_tail: bool = True) -> EventFrames:
    """Slice the (sorted) stream into frames of `events_per_frame`.

    One-big-chunk push through `StreamingAggregator`, so streaming and
    offline aggregation share one code path. With `keep_tail` (default)
    the trailing partial frame is flushed as a final padded frame; with
    `keep_tail=False` it is dropped (the seed's behavior — a device-side
    partial frame that never saw its remaining events).
    """
    agg = StreamingAggregator(cam, traj, events_per_frame)
    full = agg.push(stream)
    if not keep_tail:
        return full
    tail = agg.flush()
    if full.xy.shape[0] == 0 and tail.xy.shape[0] == 0:
        return empty_event_frames(events_per_frame)
    return concat_event_frames([full, tail])
