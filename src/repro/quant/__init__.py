"""Hybrid data quantization (paper §2.3, Table 1) + LM reuse policies."""

from repro.quant.fixed_point import (  # noqa: F401
    FixedPointFormat,
    Q9_7,
    Q11_21,
    INT8,
    INT16,
    quantize,
    dequantize,
    quantize_roundtrip,
)
