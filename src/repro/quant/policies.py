"""Quantization policies: the paper's Table 1 for EMVS, + LM policies."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.camera import CameraModel
from repro.core.geometry import PlaneSweepCoeffs
from repro.quant.fixed_point import (
    FixedPointFormat,
    INT8,
    INT16,
    Q9_7,
    Q11_21,
    quantize_roundtrip,
)

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class EMVSQuantPolicy:
    """Hybrid quantization strategy of paper Table 1."""

    coords: FixedPointFormat = Q9_7  # (x_k, y_k)
    canonical: FixedPointFormat = Q9_7  # {x_k(Z0), y_k(Z0)}
    plane_coords: FixedPointFormat = INT8  # {x_k(Zi), y_k(Zi)}
    homography: FixedPointFormat = Q11_21  # H_Z0
    phi: FixedPointFormat = Q11_21
    dsi: FixedPointFormat = INT16

    def quantize_events(self, xy: Array) -> Array:
        return quantize_roundtrip(xy, self.coords)

    def quantize_canonical(self, xy0: Array) -> Array:
        return quantize_roundtrip(xy0, self.canonical)

    def quantize_homography(self, H: Array) -> Array:
        return quantize_roundtrip(H, self.homography)

    def quantize_phi(self, phi: PlaneSweepCoeffs) -> PlaneSweepCoeffs:
        return PlaneSweepCoeffs(
            alpha=quantize_roundtrip(phi.alpha, self.phi),
            beta_x=quantize_roundtrip(phi.beta_x, self.phi),
            beta_y=quantize_roundtrip(phi.beta_y, self.phi),
        )

    def quantize_plane_coord_values(self, c: Array) -> Array:
        """Elementwise int8 plane-coord quantization (one coordinate axis).

        Exposed separately from `quantize_plane_coords` because the fused
        Pallas sweep applies it INSIDE the kernel body (per depth plane,
        against VMEM-resident coords) — same traced ops as the XLA
        datapath, so the two formulations agree bitwise by construction.
        """
        fmt = self.plane_coords
        park = jnp.float32(fmt.q_max)
        out_of_range = (c < -0.5) | (c > fmt.q_max + 0.5)
        return jnp.where(out_of_range, park, quantize_roundtrip(c, fmt))

    def quantize_plane_coords(self, x_i: Array, y_i: Array) -> tuple[Array, Array]:
        """Nearest-voxel rounding to 8-bit pixel index.

        Out-of-range coords are parked at the format max so the voting
        bounds check ('projection missing judgement') drops them for any
        sensor narrower than 256 px (DAVIS240: 240x180). Plain saturation
        would alias negative coords to pixel 0 — a *valid* pixel — and
        fabricate votes; the park-at-max rule mirrors the FPGA's Nearest
        Voxel Finder doing the miss-judgement before address generation.
        """
        q = self.quantize_plane_coord_values
        return q(x_i), q(y_i)

    # -- contract declarations for repro.analysis ------------------------

    def declared_formats(self) -> dict[str, FixedPointFormat]:
        """Per-tensor expected fixed-point formats, by datapath name.

        This is the machine-readable form of Table 1 that the
        quantization-contract linter checks against (see
        docs/quantization_contracts.md). The 'dsi' entry doubles as the
        int16 saturating-store contract of `core/dsi.py:to_storage`.
        """
        return {
            "coords": self.coords,
            "canonical": self.canonical,
            "plane_coords": self.plane_coords,
            "homography": self.homography,
            "phi": self.phi,
            "dsi": self.dsi,
        }

    def sanctioned_clip_bounds(self) -> frozenset[tuple[float, float]]:
        """Clamp ranges that sanction a float->int cast.

        The linter treats a float->int conversion as a deliberate
        saturating store — not a fractional-truncation bug — exactly when
        its operand was clamped to one of these (q_min, q_max) ranges,
        i.e. to a format this policy declares. Anything else is the PR 3
        bug class and gets flagged.
        """
        return frozenset(
            (float(fmt.q_min), float(fmt.q_max))
            for fmt in self.declared_formats().values()
        )


TABLE1 = EMVSQuantPolicy()


def memory_report(cam: CameraModel, num_planes: int, events_per_frame: int = 1024
                  ) -> dict[str, dict[str, int]]:
    """Paper §2.3: 'saves up to 50% of memory and bandwidth'. Bytes per frame."""
    n_dsi = cam.width * cam.height * num_planes
    fp32 = {
        "events": events_per_frame * 2 * 4,
        "canonical": events_per_frame * 2 * 4,
        "plane_coords": events_per_frame * 2 * 4,  # per plane, streamed
        "H": 9 * 4,
        "phi": 3 * 128 * 4,
        "dsi": n_dsi * 4,
    }
    q = {
        "events": events_per_frame * 2 * 2,  # Q9.7 pairs packed to 32b
        "canonical": events_per_frame * 2 * 2,
        "plane_coords": events_per_frame * 2 * 1,  # int8
        "H": 9 * 4,  # Q11.21 stays 32b
        "phi": 3 * 128 * 4,
        "dsi": n_dsi * 2,  # int16
    }
    return {"float32": fp32, "table1": q}
