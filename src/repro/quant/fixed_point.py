"""Bit-exact Qm.n fixed-point emulation.

Paper Table 1:

    data                      total  int  frac
    (x_k, y_k)                16     9    7      -> Q9.7
    {x_k(Z0), y_k(Z0)}        16     9    7      -> Q9.7
    {x_k(Zi), y_k(Zi)}        8      8    0      -> int8 (pixel index)
    H_Z0                      32     11   21     -> Q11.21
    phi                       32     11   21     -> Q11.21
    DSI scores                16     16   0      -> int16

Emulation contract: operands are quantized (stored-integer semantics,
round-half-away-from-zero, saturating), arithmetic runs in float32.
FPGA DSP48 MACs carry 48-bit accumulators, so with quantized operands the
hardware MAC is exact; float32's 24-bit mantissa introduces ≤2^-24
relative error — three orders of magnitude below the Q9.7 LSB (2^-7),
so operand/output quantization dominates exactly as on the device.
A hypothesis property test cross-checks `quantize` against a pure-Python
integer model.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class FixedPointFormat(NamedTuple):
    total_bits: int
    frac_bits: int
    signed: bool = True

    @property
    def scale(self) -> float:
        return float(2 ** self.frac_bits)

    @property
    def q_min(self) -> int:
        return -(2 ** (self.total_bits - 1)) if self.signed else 0

    @property
    def q_max(self) -> int:
        return 2 ** (self.total_bits - 1) - 1 if self.signed else 2 ** self.total_bits - 1

    @property
    def lsb(self) -> float:
        return 1.0 / self.scale


Q9_7 = FixedPointFormat(16, 7)  # event coords & canonical coords
Q11_21 = FixedPointFormat(32, 21)  # H_Z0 and phi
INT8 = FixedPointFormat(8, 0, signed=False)  # plane coords (pixel index 0..255)
INT16 = FixedPointFormat(16, 0)  # DSI scores


def round_half_away(x: Array) -> Array:
    """RTL-style rounding: round half away from zero (jnp.round is half-even).

    The single rounding convention of every quantizing datapath: the
    fixed-point quantizers here and the integer vote store in
    `core/voting.py` must agree, or the quantized matmul/scatter
    formulations drift from the RTL semantics at exact half-integer
    values (see tests/test_voting.py's half-integer regression).
    """
    return jnp.sign(x) * jnp.floor(jnp.abs(x) + 0.5)


# original private name, kept for in-repo callers
_round_half_away = round_half_away


def quantize(x: Array, fmt: FixedPointFormat) -> Array:
    """float -> stored integer (int32 carrier), saturating."""
    q = _round_half_away(x.astype(jnp.float32) * fmt.scale)
    return jnp.clip(q, fmt.q_min, fmt.q_max).astype(jnp.int32)


def dequantize(q: Array, fmt: FixedPointFormat) -> Array:
    return q.astype(jnp.float32) / fmt.scale


def quantize_roundtrip(x: Array, fmt: FixedPointFormat) -> Array:
    """float -> quantized float (the value the hardware would see)."""
    return dequantize(quantize(x, fmt), fmt)


def storage_bytes(n_elems: int, fmt: FixedPointFormat) -> int:
    return n_elems * fmt.total_bits // 8
