"""Deterministic replay of dispatch schedules against a cost model.

`replay_schedule` re-simulates the `SweepDispatcher` scheduling rules —
policy, fairness anchoring, in-flight depth, the SLO deadline — over a
recorded (or synthetic) arrival trace in VIRTUAL time: every sweep takes
exactly what the cost model predicts, the device is a serial queue, and
the host reacts at arrival/flush events (the points where the real
engine pumps). Nothing here touches a clock or a device, so CI can
assert scheduling decisions ("SLO-aware dispatches no more groups than
'throughput' and its predicted p99 meets the deadline on the burst
profile") with zero timing-sensitive assertions — the profile-then-plan
replay loop of byteprofile-analysis, specialized to the sweep
dispatcher. See docs/dispatch_planning.md.

Fidelity notes (deliberate simplifications, matched by the dispatcher's
own predictor `SweepDispatcher.predict_drain_s`):

- in-flight sweeps count at FULL predicted cost when the adaptive SLO
  rule prices the queue (their progress is unobservable without a
  device sync);
- host-side staging time is zero: dispatches within one pump happen at
  the same virtual instant, and the `_dispatch` back-pressure block
  (which paces the HOST, not the device) is not modeled — on a serial
  device it cannot change completion times;
- "round_robin" fairness rotates over tags in first-appearance order
  (the dispatcher rotates over registration order; identical whenever
  sessions first enqueue in registration order, which every benchmark
  rig here does).

The CLI is the CI gate:

    python -m repro.serving.dispatch_replay --validate cost_table.json
    python -m repro.serving.dispatch_replay --check-slo-burst cost_table.json

`--validate` only schema-checks the table. `--check-slo-burst` builds a
deterministic burst profile from the table's own in-distribution
variants, replays "throughput" to fix the deadline, then asserts the
SLO-aware adaptive replay dispatches no more groups and meets the
predicted-p99 deadline.
"""

from __future__ import annotations

import argparse
import heapq
import json
import math
import sys
from dataclasses import dataclass, field
from typing import Any, Sequence

from repro.core.pipeline import (
    FAIRNESS_POLICIES,
    DispatchPlanner,
    bucket_capacity,
)
from repro.profiling.cost_model import model_from_table
from repro.profiling.cost_table import CostTable, VariantKey


@dataclass(frozen=True)
class Arrival:
    """One segment joining the tagged queue at virtual time `t`."""

    t: float
    tag: Any
    seg: tuple[int, int]


@dataclass(frozen=True)
class ReplayConfig:
    """The scheduling knobs the replay honors (a `StreamConfig` subset)."""

    policy: str = "adaptive"
    fairness: str = "fifo"
    max_inflight: int = 2
    target_latency_s: float | None = None
    # Virtual time of the end-of-stream flush (`final=True` drain).
    # None = the last arrival's time.
    flush_t: float | None = None

    def __post_init__(self):
        if self.policy not in ("latency", "throughput", "adaptive"):
            raise ValueError(f"unknown policy {self.policy!r}")
        if self.fairness not in FAIRNESS_POLICIES:
            raise ValueError(f"unknown fairness {self.fairness!r}")
        if self.max_inflight < 1:
            raise ValueError("max_inflight must be >= 1")
        if (self.target_latency_s is not None
                and not self.target_latency_s > 0):
            raise ValueError("target_latency_s must be > 0 or None")


@dataclass(frozen=True)
class ReplayDispatch:
    """One dispatched group in the replayed schedule."""

    t: float  # virtual time the scheduler issued the group
    segs: tuple[tuple[Any, tuple[int, int]], ...]
    s_bucket: int
    capacity: int
    predicted_s: float
    start_s: float  # device start (serial queue)
    done_s: float  # device completion


@dataclass
class ReplayResult:
    dispatches: list[ReplayDispatch] = field(default_factory=list)
    # (tag, seg) -> predicted first-result latency (group done - arrival)
    latencies: dict = field(default_factory=dict)

    @property
    def dispatch_count(self) -> int:
        return len(self.dispatches)

    @property
    def makespan_s(self) -> float:
        return max((d.done_s for d in self.dispatches), default=0.0)

    def predicted_p99_s(self) -> float:
        return percentile(list(self.latencies.values()), 0.99)

    def to_json(self) -> dict:
        return {
            "dispatch_count": self.dispatch_count,
            "makespan_s": self.makespan_s,
            "predicted_p99_s": self.predicted_p99_s(),
            "dispatches": [
                {"t": d.t, "s_bucket": d.s_bucket, "capacity": d.capacity,
                 "predicted_s": d.predicted_s, "start_s": d.start_s,
                 "done_s": d.done_s, "segments": len(d.segs)}
                for d in self.dispatches
            ],
        }


def percentile(values: Sequence[float], q: float) -> float:
    """Nearest-rank percentile (deterministic, no interpolation)."""
    if not values:
        return 0.0
    if not 0.0 < q <= 1.0:
        raise ValueError(f"q must be in (0, 1], got {q}")
    ordered = sorted(values)
    return ordered[max(0, math.ceil(q * len(ordered)) - 1)]


class _VirtualDispatcher:
    """The `SweepDispatcher._pop_group` rules over a virtual clock."""

    def __init__(self, planner: DispatchPlanner, cfg: ReplayConfig):
        if planner.cost_model is None or planner.variant_of is None:
            raise ValueError(
                "replay needs a planner with a cost model and variant "
                "factory: every sweep's duration must be predictable")
        self.planner = planner
        self.cfg = cfg
        self.pending: list[tuple[Any, tuple[int, int]]] = []
        self.busy_until = 0.0
        self.inflight_done: list[float] = []  # heap of completion times
        self.result = ReplayResult()
        self.arrival_t: dict = {}
        self._tag_order: list[Any] = []
        self._rr_cursor = 0

    # --- the policy rules, in virtual time --------------------------------

    def _inflight_at(self, t: float) -> int:
        while self.inflight_done and self.inflight_done[0] <= t:
            heapq.heappop(self.inflight_done)
        return len(self.inflight_done)

    def _predict_drain_s(self, t: float) -> float | None:
        # in-flight sweeps at full predicted cost — the live
        # predictor's conservatism, reproduced exactly
        total = sum(d.predicted_s for d in self.result.dispatches
                    if d.done_s > t)
        pending = self.planner.predict_drain_s(
            self.pending, fairness=self.cfg.fairness)
        if pending is None:
            return None
        return total + pending

    def _anchor_candidates(self, t: float) -> list[int]:
        """Anchor queue-indices to try, per the fairness rule."""
        if self.cfg.fairness == "fifo" or len(self._tag_order) <= 1:
            return [0]
        present = {tag for tag, _ in self.pending}
        anchors = []
        n = len(self._tag_order)
        for k in range(n):
            tag = self._tag_order[(self._rr_cursor + k) % n]
            if tag in present:
                anchors.append(next(i for i, (tg, _)
                                    in enumerate(self.pending) if tg == tag))
        return anchors

    def _pop_group(self, t: float, final: bool):
        if not self.pending:
            return None
        policy = self.cfg.policy
        slo_urgent = None
        if policy == "adaptive" and not final:
            if self.cfg.target_latency_s is not None:
                drain = self._predict_drain_s(t)
                if drain is not None:
                    slo_urgent = drain > self.cfg.target_latency_s
            if (slo_urgent is None
                    and self._inflight_at(t) >= self.cfg.max_inflight):
                return None
        for anchor in self._anchor_candidates(t):
            idx, cap, sealed = self.planner.head_tagged(
                self.pending, anchor=anchor)
            if policy == "latency":
                idx = idx[:1]
            elif policy == "throughput" and not (final or sealed):
                continue
            elif slo_urgent is not None and not (slo_urgent or sealed):
                continue
            group = [self.pending[i] for i in idx]
            for i in reversed(idx):
                self.pending.pop(i)
            tag0 = group[0][0]
            try:
                self._rr_cursor = ((self._tag_order.index(tag0) + 1)
                                   % len(self._tag_order))
            except ValueError:
                pass
            return group, cap
        return None

    def _dispatch(self, group, cap: int, t: float) -> None:
        s_bucket = self.planner.s_bucket(len(group))
        predicted = self.planner.predict_group_s(len(group), cap)
        if predicted is None:
            raise ValueError(
                f"cost model cannot predict variant "
                f"(s_bucket={s_bucket}, capacity={cap}): replay needs "
                f"full coverage of the schedule's variants")
        start = max(t, self.busy_until)
        done = start + predicted
        self.busy_until = done
        heapq.heappush(self.inflight_done, done)
        self.result.dispatches.append(ReplayDispatch(
            t=t, segs=tuple(group), s_bucket=s_bucket, capacity=cap,
            predicted_s=predicted, start_s=start, done_s=done))
        for tag, seg in group:
            self.result.latencies[(tag, seg)] = (
                done - self.arrival_t[(tag, seg)])

    def _drain(self, t: float, final: bool) -> None:
        while self.pending:
            group = self._pop_group(t, final)
            if group is None:
                break
            self._dispatch(*group, t)

    # --- the event loop ---------------------------------------------------

    def run(self, arrivals: Sequence[Arrival]) -> ReplayResult:
        ordered = sorted(arrivals, key=lambda a: a.t)
        flush_t = self.cfg.flush_t
        if flush_t is None:
            flush_t = ordered[-1].t if ordered else 0.0
        if ordered and flush_t < ordered[-1].t:
            raise ValueError(
                f"flush_t={flush_t} precedes the last arrival "
                f"at t={ordered[-1].t}")
        i = 0
        while i < len(ordered):
            t = ordered[i].t
            while i < len(ordered) and ordered[i].t == t:
                a = ordered[i]
                if a.tag not in self._tag_order:
                    self._tag_order.append(a.tag)
                if (a.tag, a.seg) in self.arrival_t:
                    raise ValueError(f"duplicate arrival {(a.tag, a.seg)}")
                self.arrival_t[(a.tag, a.seg)] = a.t
                self.pending.append((a.tag, a.seg))
                i += 1
            self._drain(t, final=False)
        self._drain(flush_t, final=True)
        assert not self.pending, "final drain must empty the queue"
        return self.result


def replay_schedule(arrivals: Sequence[Arrival], planner: DispatchPlanner,
                    cfg: ReplayConfig) -> ReplayResult:
    """Replay one arrival trace under one scheduling configuration."""
    return _VirtualDispatcher(planner, cfg).run(arrivals)


def planner_for(table_or_model, s_buckets: Sequence[int], *, backend: str,
                interpolation: str = "nearest",
                quantized: bool = False) -> DispatchPlanner:
    """A cost-aware planner for replays: fixes the non-shape variant axes
    so the replayer can key the model from `(s_bucket, capacity)` alone."""
    model = (model_from_table(table_or_model)
             if isinstance(table_or_model, CostTable) else table_or_model)

    def variant_of(s_bucket: int, capacity: int) -> VariantKey:
        return VariantKey(s_bucket=s_bucket, capacity=capacity,
                          backend=backend, interpolation=interpolation,
                          quantized=quantized)

    return DispatchPlanner(s_buckets, cost_model=model,
                           variant_of=variant_of)


def arrivals_from_trace(trace: dict) -> list[Arrival]:
    """Arrivals from a recorded `SweepProfiler.trace_json()` payload."""
    return [Arrival(t=float(a["t"]), tag=a["tag"],
                    seg=(int(a["seg"][0]), int(a["seg"][1])))
            for a in trace["arrivals"]]


def burst_arrivals(table: CostTable, *, backend: str,
                   segments: int = 24) -> list[Arrival]:
    """A deterministic burst profile drawn from the table's own support.

    All segments arrive at t=0 (the benchmark burst profile's shape) as
    consecutive RUNS of each capacity the table measured for `backend` —
    runs, not an interleave, because per-stream FIFO seals a group at
    the first capacity change: an interleaved burst cannot coalesce at
    all and the check would compare two identical per-segment schedules.
    Every replayed variant is in-distribution by construction.
    """
    caps = sorted({key.capacity for key in table.keys()
                   if key.backend == backend})
    if not caps:
        raise ValueError(f"cost table has no entries for backend "
                         f"{backend!r}")
    arrivals = []
    frame = 0
    run = -(-segments // len(caps))
    for cap in caps:
        assert bucket_capacity(cap) == cap, "capacities are bucket-aligned"
        for _ in range(run):
            if len(arrivals) == segments:
                break
            arrivals.append(Arrival(t=0.0, tag=0, seg=(frame, frame + cap)))
            frame += cap
    return arrivals


def check_slo_burst(table: CostTable, *, backend: str,
                    s_buckets: Sequence[int] = (1, 2, 4),
                    interpolation: str = "nearest", quantized: bool = False,
                    segments: int = 24, max_inflight: int = 2) -> dict:
    """The CI gate: on the burst profile, the SLO-aware adaptive policy
    must dispatch no more groups than "throughput" and its predicted
    p99 must meet the deadline (set to throughput's predicted p99 — the
    best any coalescing schedule can promise on a full burst).

    Returns the gate record; raises `AssertionError` on regression.
    """
    planner = planner_for(table, s_buckets, backend=backend,
                          interpolation=interpolation, quantized=quantized)
    arrivals = burst_arrivals(table, backend=backend, segments=segments)
    tp = replay_schedule(arrivals, planner, ReplayConfig(
        policy="throughput", max_inflight=max_inflight))
    deadline = tp.predicted_p99_s()
    slo = replay_schedule(arrivals, planner, ReplayConfig(
        policy="adaptive", max_inflight=max_inflight,
        target_latency_s=deadline))
    record = {
        "backend": backend,
        "segments": segments,
        "target_latency_s": deadline,
        "throughput": tp.to_json(),
        "slo_adaptive": slo.to_json(),
    }
    assert slo.dispatch_count <= tp.dispatch_count, (
        f"SLO-aware adaptive dispatched {slo.dispatch_count} groups vs "
        f"throughput's {tp.dispatch_count} on the burst profile")
    assert slo.predicted_p99_s() <= deadline + 1e-12, (
        f"SLO-aware adaptive predicted p99 {slo.predicted_p99_s():.6f}s "
        f"misses its own deadline {deadline:.6f}s")
    return record


def main(argv: Sequence[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        description="Validate a sweep cost table and replay-check the "
                    "SLO-aware dispatch schedule (docs/dispatch_planning.md)")
    parser.add_argument("table", help="path to cost_table.json")
    parser.add_argument("--validate", action="store_true",
                        help="schema-validate the table and exit")
    parser.add_argument("--check-slo-burst", action="store_true",
                        help="replay the burst profile: SLO-aware adaptive "
                             "must dispatch <= throughput's groups and meet "
                             "its predicted p99 deadline")
    parser.add_argument("--backend", default=None,
                        help="sweep backend to replay (default: every "
                             "backend present in the table)")
    parser.add_argument("--segments", type=int, default=24)
    args = parser.parse_args(argv)

    try:
        table = CostTable.load(args.table)
    except Exception as exc:  # noqa: BLE001 — the CLI's whole job
        print(f"cost table INVALID: {exc}")
        return 1
    print(f"cost table OK: {len(table)} variant(s), schema v1")
    if args.validate and not args.check_slo_burst:
        return 0

    backends = ([args.backend] if args.backend
                else sorted({key.backend for key in table.keys()}))
    failures = 0
    for backend in backends:
        try:
            record = check_slo_burst(table, backend=backend,
                                     segments=args.segments)
        except AssertionError as exc:
            print(f"[{backend}] REGRESSION: {exc}")
            failures += 1
            continue
        tp, slo = record["throughput"], record["slo_adaptive"]
        print(f"[{backend}] burst x{record['segments']}: throughput "
              f"{tp['dispatch_count']} dispatches p99 "
              f"{tp['predicted_p99_s']:.4f}s; SLO-adaptive "
              f"{slo['dispatch_count']} dispatches p99 "
              f"{slo['predicted_p99_s']:.4f}s (deadline "
              f"{record['target_latency_s']:.4f}s)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
