"""Streaming EMVS engine: segments vote while the trajectory still arrives.

The offline `run_emvs` needs the whole aggregated sequence before it can
plan and bucket key-frame segments. This engine removes that barrier —
the paper's A/P/R pipelining applied across segments, structured like
`serving/engine.py`'s continuous batching:

  * events arrive in chunks of arbitrary size; `StreamingAggregator`
    carries the partial-frame remainder and emits completed frames with
    interpolated poses;
  * `SegmentPlanner` applies the K criterion frame-by-frame and closes a
    segment the moment the translation threshold trips — the same
    boundaries as offline `segment_keyframes`;
  * closed segments are padded into the same multiple-of-four
    frame-capacity buckets as `run_emvs` AND the segment axis S is padded
    to a small fixed set of sizes (`StreamConfig.segment_buckets`), so
    `process_segments_batched`'s jit cache stays bounded at
    |segment_buckets| x |capacities| variants over an unbounded stream;
  * dispatch is double-buffered: JAX's async dispatch returns as soon as
    a sweep is enqueued, so the host stages (`pad_segments` + transfer)
    segment k+1 while segment k is still voting on the device; at most
    `max_inflight` sweeps run ahead before the engine blocks on the
    oldest (back-pressure), and frames behind the open segment are
    evicted from the host window once dispatched;
  * closed segments pass through a FIFO *coalescing queue* before
    dispatch. `StreamConfig.dispatch_policy` decides how the queue
    drains: "latency" dispatches every closed segment immediately as its
    own sweep (the per-segment baseline), "throughput" holds segments
    until the head group fills the largest S bucket, and "adaptive" (the
    default) dispatches immediately while the in-flight queue is shallow
    but coalesces queued segments into the largest fitting S bucket once
    the device falls behind — burst-tolerant buffering between the
    asynchronous front-end and the batch-parallel back-end. The queue
    releases strictly FIFO (`repro.core.pipeline.dispatch_group_head`),
    so the policy changes the dispatch schedule, never the results.

S-axis padding repeats the last real segment; the per-segment sweep
body is independent, so padded rows are discarded on harvest without
touching real outputs — per-segment results are bit-identical to
`run_emvs` on the integer/nearest datapaths for every chunking of the
input (tests/test_streaming.py enforces exactly that).

Sweep backends: `StreamConfig(sweep=...)` picks how each dispatch runs,
mirroring `run_emvs(sweep=...)`. `"batched"` (default) sweeps the
bucket serially in one `lax.map` program; `"sharded"` shards the
bucket's segment axis across the engine's mesh
(`repro.distributed.emvs.process_segments_sharded`), so concurrent
segments vote on different devices. With `"sharded"` the engine rounds
every S bucket up to a multiple of the mesh's segment-axis size, so
dispatch shapes stay shard-stable (and the compiled-variant bound
holds) over an unbounded stream.

Poses arrive either from a fully-known `Trajectory` oracle (offline
replay) or — the realistic mode — as a chunked stream from the tracker
via `push_poses`, mirroring `push` for events. In the streamed mode the
engine's aggregator holds a `TrajectoryBuffer` with a monotonically
advancing **pose-lag watermark**: the latest time at which pose
interpolation is bracketed by received samples. A completed event frame
whose mid-time is not yet strictly below the watermark *stalls* (the
stall queue sits upstream of the frame store, so planner indices and
window eviction never see out-of-order frames) and is released
bitwise-identically posed once the bracketing pose chunk lands — so ANY
interleaving of event and pose chunks reproduces the offline result,
and no code path silently extrapolates a pose beyond the received
trajectory. `finalize_poses` declares the tracker done (remaining
stalled frames release under `StreamConfig.pose_extrapolation`:
warn-clamp by default, raise on strict pipelines); `flush` with poses
still missing raises `PoseStallError` naming the stalled frame count
and the watermark. `stats` tracks the stall queue depth and watermark
("stalled_frames", "max_stalled", "pose_chunks", "pose_watermark").
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import NamedTuple

import jax
import numpy as np

from repro.core.camera import CameraModel
from repro.core.detection import DepthMap
from repro.core.dsi import DSIConfig
from repro.core.geometry import SE3
from repro.core.pipeline import (
    EMVSOptions,
    EMVSResult,
    SegmentPlanner,
    SegmentResult,
    dispatch_group_head,
    pad_segments,
    process_segments_batched,
)
from repro.core.pointcloud import PointCloud, depth_maps_to_points
from repro.events.aggregation import (
    EVENTS_PER_FRAME,
    EventFrames,
    StreamingAggregator,
)
from repro.events.simulator import EventStream, Trajectory
from repro.events.trajectory_stream import (
    POSE_EXTRAPOLATION_POLICIES,
    PoseStallError,
    TrajectoryBuffer,
)

Array = jax.Array

# Dispatch policies for the closed-segment coalescing queue:
#   * "latency"    — every closed segment dispatches immediately as its own
#     sweep (smallest fitting S bucket). Lowest time-to-depth-map per
#     segment; the per-segment baseline the other policies are measured
#     against in benchmarks/streaming_latency.py.
#   * "throughput" — closed segments coalesce until the head group fills
#     the largest S bucket (or can no longer grow: a different-capacity
#     segment queued behind it, or end of stream). Fewest dispatches and
#     the biggest batches — the offline sweep's schedule, reconstructed
#     online at the cost of first-depth latency.
#   * "adaptive"   — never waits while the in-flight queue is shallow:
#     whatever is queued dispatches at once (a lone closed segment goes
#     solo, exactly like "latency" on a quiet stream; a backlog that
#     piled up in one push coalesces into the largest fitting S bucket).
#     Once the device saturates it holds segments like "throughput",
#     coalescing them as soon as an in-flight slot frees. Burst-tolerant
#     without giving up the quiet-stream latency profile; the default.
DISPATCH_POLICIES = ("latency", "throughput", "adaptive")


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    """Knobs of the streaming engine.

    Shape stability: `events_per_frame`, `segment_buckets` and the
    `sweep` backend bound the compiled-variant count over an unbounded
    stream. Scheduling: `dispatch_policy` picks how closed segments leave
    the coalescing queue ("latency" = one sweep per segment, lowest
    first-depth latency; "throughput" = fill the largest S bucket before
    dispatching, highest sustained segments/s; "adaptive" = never wait
    while the device keeps up — a lone closed segment dispatches solo, a
    queued backlog coalesces — and hold-to-coalesce once the in-flight
    queue saturates; pick it unless you need one extreme). Back-pressure:
    `max_inflight` bounds device-side work in flight, and
    `max_stalled_frames` bounds the pose-stall queue — with a stalled
    tracker the event front would otherwise grow the stall queue (and the
    coalescing queue behind it) without limit; exceeding the bound raises
    `PoseStallError` after buffering the offending frames, so pushing the
    missing pose chunks recovers without losing events. Every policy
    produces bit-identical results on the nearest/integer datapaths
    (tests/test_adaptive_dispatch.py) — these knobs trade latency for
    throughput, never numerics.
    """

    events_per_frame: int = EVENTS_PER_FRAME
    # Fixed segment-axis pad sizes (ascending). Groups larger than the top
    # bucket are split, so the compiled-variant bound holds regardless of
    # how many segments a single push closes.
    segment_buckets: tuple[int, ...] = (1, 2, 4)
    # Double-buffer depth: sweeps allowed in flight before dispatch blocks
    # on the oldest. 2 = classic ping-pong (stage k+1 while k votes).
    # Doubles as the adaptive policy's depth threshold: a dispatch that
    # would exceed it switches the policy into coalescing mode.
    max_inflight: int = 2
    # How the closed-segment coalescing queue drains (DISPATCH_POLICIES).
    dispatch_policy: str = "adaptive"
    # Max-stall back-pressure bound (pose-gated mode): maximum frames the
    # aggregator may hold stalled past the pose watermark (unreleasable
    # by the poses received so far) before `push` raises `PoseStallError`
    # — frames are buffered first, so nothing is lost and pushing the
    # missing pose chunks recovers. None = unbounded (trusted tracker).
    max_stalled_frames: int | None = None
    # Segment-sweep backend: "batched" runs each dispatch as one lax.map
    # program (`process_segments_batched`); "sharded" shards the segment
    # axis across the devices of the engine's mesh
    # (`repro.distributed.emvs.process_segments_sharded`), so concurrent
    # segments vote on different devices. With "sharded" the engine
    # rounds every segment bucket up to a multiple of the mesh's
    # segment-axis size, keeping dispatch shapes shard-stable over an
    # unbounded stream.
    sweep: str = "batched"
    # Policy for frame mid-times outside the received trajectory span
    # (only reachable at the stream edges): "warn" clamps to the span
    # endpoint with PoseExtrapolationWarning, "raise" refuses with
    # PoseExtrapolationError, "clamp" is the seed's silent freeze (kept
    # for explicit opt-in only).
    pose_extrapolation: str = "warn"

    def __post_init__(self):
        if not self.segment_buckets:
            raise ValueError("segment_buckets must be non-empty")
        if list(self.segment_buckets) != sorted(set(self.segment_buckets)):
            raise ValueError(
                f"segment_buckets must be strictly ascending, got "
                f"{self.segment_buckets}")
        if self.max_inflight < 1:
            raise ValueError("max_inflight must be >= 1")
        if self.dispatch_policy not in DISPATCH_POLICIES:
            raise ValueError(
                f"unknown dispatch_policy {self.dispatch_policy!r}: "
                f"expected one of {DISPATCH_POLICIES}")
        if self.max_stalled_frames is not None and self.max_stalled_frames < 1:
            raise ValueError(
                f"max_stalled_frames must be >= 1 (or None for unbounded), "
                f"got {self.max_stalled_frames}")
        if self.sweep not in ("batched", "sharded"):
            raise ValueError(
                f"unknown sweep backend {self.sweep!r}: expected 'batched' "
                f"or 'sharded'")
        if self.pose_extrapolation not in POSE_EXTRAPOLATION_POLICIES:
            raise ValueError(
                f"unknown pose_extrapolation policy "
                f"{self.pose_extrapolation!r}: expected one of "
                f"{POSE_EXTRAPOLATION_POLICIES}")


def iter_event_chunks(stream: EventStream, chunk_events: int):
    """Split a stream into contiguous chunks of `chunk_events` events."""
    if chunk_events < 1:
        raise ValueError(f"chunk_events must be >= 1, got {chunk_events}")
    n = stream.t.shape[0]
    for i in range(0, n, chunk_events):
        sl = slice(i, min(i + chunk_events, n))
        yield EventStream(xy=stream.xy[sl], t=stream.t[sl],
                          polarity=stream.polarity[sl], valid=stream.valid[sl])


class _FrameStore:
    """Host-side retention window of aggregated frames, globally indexed.

    Frames are appended as they are emitted and evicted once the planner's
    open segment has moved past them, so memory tracks the open-segment
    length, not the stream length.
    """

    def __init__(self):
        self.base = 0  # global index of the oldest retained frame
        self._xy: deque[np.ndarray] = deque()
        self._valid: deque[np.ndarray] = deque()
        self._t_mid: deque[np.float32] = deque()
        self._R: deque[np.ndarray] = deque()
        self._t: deque[np.ndarray] = deque()

    @property
    def end(self) -> int:
        """One past the newest retained global frame index."""
        return self.base + len(self._xy)

    def extend(self, frames: EventFrames) -> None:
        xy = np.asarray(frames.xy)
        valid = np.asarray(frames.valid)
        t_mid = np.asarray(frames.t_mid)
        r = np.asarray(frames.poses.R)
        t = np.asarray(frames.poses.t)
        for k in range(xy.shape[0]):
            self._xy.append(xy[k])
            self._valid.append(valid[k])
            self._t_mid.append(t_mid[k])
            self._R.append(r[k])
            self._t.append(t[k])

    def window(self, lo: int, hi: int) -> EventFrames:
        """Host EventFrames covering global frames [lo, hi)."""
        if not self.base <= lo < hi <= self.end:
            raise IndexError(
                f"window [{lo}, {hi}) outside retained [{self.base}, {self.end})")
        sel = range(lo - self.base, hi - self.base)
        return EventFrames(
            xy=np.stack([self._xy[k] for k in sel]),
            valid=np.stack([self._valid[k] for k in sel]),
            t_mid=np.asarray([self._t_mid[k] for k in sel], np.float32),
            poses=SE3(np.stack([self._R[k] for k in sel]),
                      np.stack([self._t[k] for k in sel])),
        )

    def evict_before(self, i: int) -> None:
        while self.base < i and self._xy:
            self._xy.popleft()
            self._valid.popleft()
            self._t_mid.popleft()
            self._R.popleft()
            self._t.popleft()
            self.base += 1


class _InFlight(NamedTuple):
    """One dispatched sweep: real segments + async device results."""

    segs: list[tuple[int, int]]  # real (unpadded) segments, global indices
    ref_R: Array  # (S, 3, 3) including padded rows
    ref_t: Array  # (S, 3)
    dsis: Array
    dms: DepthMap
    pcs: PointCloud


class EMVSStreamEngine:
    """Online EMVS: push event chunks, harvest per-keyframe depth maps.

    Usage (pose oracle — offline replay with a fully-known trajectory):
        engine = EMVSStreamEngine(cam, dsi_cfg, traj, opts)
        for chunk in iter_event_chunks(stream, 4096):
            for seg in engine.push(chunk):   # results ready so far
                ...
        result = engine.flush()              # drain; same type as run_emvs

    Usage (streamed trajectory — poses arrive in chunks, like events):
        engine = EMVSStreamEngine(cam, dsi_cfg, None, opts)
        for ev_chunk, pose_chunk in tracker_feed():
            engine.push(ev_chunk)            # frames past the pose-lag
            engine.push_poses(pose_chunk)    # watermark stall until here
        engine.finalize_poses()              # tracker done
        result = engine.flush()
    """

    def __init__(self, cam: CameraModel, dsi_cfg: DSIConfig,
                 traj: Trajectory | TrajectoryBuffer | None,
                 opts: EMVSOptions = EMVSOptions(),
                 stream_cfg: StreamConfig = StreamConfig(), *,
                 mesh=None):
        self.cam = cam
        self.dsi_cfg = dsi_cfg
        self.opts = opts
        self.stream_cfg = stream_cfg
        if stream_cfg.sweep == "sharded":
            from repro.distributed.emvs import (
                make_segment_mesh,
                segment_axis_size,
            )

            self.mesh = mesh if mesh is not None else make_segment_mesh()
            n = segment_axis_size(self.mesh)
            # shard-stable S buckets: every dispatch's segment axis must
            # divide the mesh, so round each bucket up to a multiple of n
            # (deduplicated, still ascending — the compiled-variant bound
            # only shrinks).
            self._segment_buckets = tuple(sorted(
                {-(-b // n) * n for b in stream_cfg.segment_buckets}))
        else:
            if mesh is not None:
                raise ValueError(
                    "mesh= is only meaningful with "
                    "StreamConfig(sweep='sharded'); the batched sweep "
                    "would silently ignore it")
            self.mesh = None
            self._segment_buckets = stream_cfg.segment_buckets
        # traj=None: pose-gated mode with a fresh buffer the caller feeds
        # via push_poses; an existing TrajectoryBuffer (possibly pre-filled)
        # is used as-is; a Trajectory is the offline oracle.
        if traj is None:
            traj = TrajectoryBuffer()
        self.pose_gated = isinstance(traj, TrajectoryBuffer)
        if stream_cfg.max_stalled_frames is not None and not self.pose_gated:
            raise ValueError(
                "max_stalled_frames is only meaningful in pose-gated mode "
                "(traj=None or a TrajectoryBuffer): a fully-known "
                "Trajectory oracle never stalls frames, so the bound "
                "would silently do nothing")
        self.aggregator = StreamingAggregator(
            cam, traj, stream_cfg.events_per_frame,
            pose_extrapolation=stream_cfg.pose_extrapolation,
            max_stalled=stream_cfg.max_stalled_frames)
        mean_depth = 0.5 * (dsi_cfg.z_min + dsi_cfg.z_max)
        # min_frames=2 is plan_segments' parallax filter, applied online.
        self.planner = SegmentPlanner(mean_depth * opts.keyframe_dist_frac,
                                      min_frames=2)
        self._store = _FrameStore()
        self._pending: deque[tuple[int, int]] = deque()  # coalescing queue
        self._inflight: deque[_InFlight] = deque()
        self._fresh: list[SegmentResult] = []  # harvested, not yet polled
        self._done: dict[tuple[int, int], tuple[SegmentResult, PointCloud]] = {}
        self._flushed = False
        self._tail_flushed = False  # aggregator tail emitted (flush began)
        # Counter invariants (asserted by tests/test_adaptive_dispatch.py):
        # segments == sum of dispatched group sizes; coalesced_segments
        # counts segments that left in a group of >= 2, so
        # segments == coalesced_segments + (dispatches -
        # coalesced_dispatches); pending_segments is the live coalescing
        # queue depth (0 after flush), max_pending its high-water mark.
        self.stats = {"chunks": 0, "frames": 0, "segments": 0,
                      "dispatches": 0, "padded_segments": 0,
                      "pending_segments": 0, "max_pending": 0,
                      "coalesced_dispatches": 0, "coalesced_segments": 0,
                      "pose_chunks": 0, "stalled_frames": 0, "max_stalled": 0,
                      "pose_watermark": self.aggregator.pose_watermark}

    # --- ingest -----------------------------------------------------------

    def push(self, chunk: EventStream) -> list[SegmentResult]:
        """Feed one event chunk; returns segment results that became ready
        (without blocking — completed sweeps only). In pose-gated mode,
        frames whose mid-time lies past the pose watermark stall inside
        the aggregator and surface on a later `push_poses`."""
        if self._flushed or self._tail_flushed:
            # once flush() has consumed the aggregator's tail remainder —
            # including a flush that then raised PoseStallError — more
            # events would land AFTER a padded mid-stream tail frame and
            # silently shift every later frame boundary
            raise RuntimeError(
                "push after flush: the event tail was already emitted "
                "(only push_poses / finalize_poses / flush may follow)")
        self.stats["chunks"] += 1
        try:
            self._ingest(self.aggregator.push(chunk))
        finally:
            # runs on the PoseStallError (max-stall bound) path too, so
            # max_stalled records the true peak, not the last quiet push
            self._track_stall()
        return self.poll()

    def push_poses(self, chunk: Trajectory) -> list[SegmentResult]:
        """Feed one pose chunk from the tracker; stalled frames the
        advanced watermark now covers are released (bitwise-identically
        posed), planned, and dispatched. Returns results that became
        ready, exactly like `push`."""
        if self._flushed:
            raise RuntimeError("push_poses after flush: the engine is drained")
        if not self.pose_gated:
            raise RuntimeError(
                "push_poses requires a pose-gated engine: construct with "
                "traj=None (or a TrajectoryBuffer), not a Trajectory oracle")
        self.stats["pose_chunks"] += 1
        self._ingest(self.aggregator.push_poses(chunk))
        self._track_stall()
        return self.poll()

    def finalize_poses(self) -> list[SegmentResult]:
        """Declare the pose stream complete: every still-stalled frame is
        released through `StreamConfig.pose_extrapolation` (its pose can
        no longer gain a bracketing sample). Call before `flush` when the
        tracker ends behind the event front."""
        if self._flushed:
            raise RuntimeError(
                "finalize_poses after flush: the engine is drained")
        if not self.pose_gated:
            raise RuntimeError(
                "finalize_poses requires a pose-gated engine: construct "
                "with traj=None (or a TrajectoryBuffer)")
        self._ingest(self.aggregator.finalize_poses())
        self._track_stall()
        return self.poll()

    def _track_stall(self) -> None:
        n = self.aggregator.stalled_frames
        self.stats["stalled_frames"] = n
        self.stats["max_stalled"] = max(self.stats["max_stalled"], n)
        self.stats["pose_watermark"] = self.aggregator.pose_watermark

    def _ingest(self, frames: EventFrames) -> None:
        n = int(frames.xy.shape[0])
        if n == 0:
            return
        self.stats["frames"] += n
        self._store.extend(frames)
        closed: list[tuple[int, int]] = []
        t_host = np.asarray(frames.poses.t)
        for k in range(n):
            seg = self.planner.push(t_host[k])
            if seg is not None:
                closed.append(seg)
        self._dispatch_all(closed)

    # --- dispatch (double-buffered, policy-scheduled) ---------------------

    def _dispatch_all(self, closed: list[tuple[int, int]]) -> None:
        """Queue newly closed segments; drain per the dispatch policy."""
        self._pending.extend(closed)
        self._note_queue_depth()
        self._drain_pending(final=False)

    def _note_queue_depth(self) -> None:
        d = len(self._pending)
        self.stats["pending_segments"] = d
        self.stats["max_pending"] = max(self.stats["max_pending"], d)

    def _harvest_ready(self) -> list[SegmentResult]:
        """Pop and harvest every device-completed sweep at the head of the
        in-flight queue (non-blocking, dispatch order)."""
        out: list[SegmentResult] = []
        while self._inflight and self._inflight[0].dms.depth.is_ready():
            out.extend(self._harvest(self._inflight.popleft(), block=False))
        return out

    def _pop_group(self, final: bool) -> tuple[list[tuple[int, int]], int] | None:
        """Pop the next dispatchable head group off the coalescing queue,
        or None when the policy says to keep coalescing. Only the FIFO
        head is ever eligible, so results release in segment-close order
        under every policy."""
        if not self._pending:
            return None
        policy = self.stream_cfg.dispatch_policy
        n, cap, sealed = dispatch_group_head(self._pending,
                                             self._segment_buckets[-1])
        if policy == "latency":
            n = 1  # one sweep per segment, always — the baseline schedule
        elif policy == "throughput" and not (final or sealed):
            return None  # the head group can still grow: keep coalescing
        elif (policy == "adaptive" and not final
              and len(self._inflight) >= self.stream_cfg.max_inflight):
            return None  # device saturated: coalesce until a slot frees
        return [self._pending.popleft() for _ in range(n)], cap

    def _drain_pending(self, final: bool) -> None:
        """Dispatch head groups while the policy allows. With `final`
        (flush) every policy drains the whole queue — back-pressure
        blocking in `_dispatch` paces the device."""
        while self._pending:
            if not final:
                # harvest completed sweeps first: results surface sooner
                # and the freed slots un-deepen the in-flight queue the
                # adaptive policy reads
                self._fresh.extend(self._harvest_ready())
            group = self._pop_group(final)
            if group is None:
                break
            self._dispatch(*group)
            self._note_queue_depth()
        # the retention window must cover segments still waiting in the
        # coalescing queue, not just the planner's open segment: a queued
        # head group references frames the planner already moved past
        self._store.evict_before(self._pending[0][0] if self._pending
                                 else self.planner.open_start)

    def _s_bucket(self, n: int) -> int:
        for b in self._segment_buckets:
            if b >= n:
                return b
        raise AssertionError(f"group of {n} exceeds top segment bucket")

    def _sweep(self, batch) -> tuple[Array, DepthMap]:
        if self.stream_cfg.sweep == "sharded":
            from repro.distributed.emvs import process_segments_sharded

            return process_segments_sharded(self.cam, self.dsi_cfg, batch,
                                            self.opts, mesh=self.mesh)
        return process_segments_batched(self.cam, self.dsi_cfg, batch,
                                        self.opts)

    def _dispatch(self, segs: list[tuple[int, int]], cap: int) -> None:
        # _dispatch_all only forms groups from non-empty closed-segment
        # runs, so an empty dispatch is a planner/grouping bug, not a
        # stream condition — and pad_segments would reject it anyway.
        assert segs, "_dispatch requires at least one closed segment"
        s_pad = self._s_bucket(len(segs))
        # padded rows repeat the last real segment: lax.map's body is
        # per-segment independent, so they are pure discarded work
        padded = list(segs) + [segs[-1]] * (s_pad - len(segs))
        lo = min(s for s, _ in padded)
        hi = max(e for _, e in padded)
        win = self._store.window(lo, hi)
        shifted = [(s - lo, e - lo) for s, e in padded]
        batch = pad_segments(win, shifted, cap)
        # async dispatch: both calls below return with the sweep enqueued,
        # so the caller stages the next batch while this one votes
        dsis, dms = self._sweep(batch)
        pcs = depth_maps_to_points(self.cam, dms, SE3(batch.ref_R, batch.ref_t))
        self._inflight.append(
            _InFlight(list(segs), batch.ref_R, batch.ref_t, dsis, dms, pcs))
        self.stats["segments"] += len(segs)
        self.stats["dispatches"] += 1
        self.stats["padded_segments"] += s_pad - len(segs)
        if len(segs) > 1:
            self.stats["coalesced_dispatches"] += 1
            self.stats["coalesced_segments"] += len(segs)
        while len(self._inflight) > self.stream_cfg.max_inflight:
            # back-pressure: block on the oldest sweep; its results are
            # queued for the caller's next poll
            self._fresh.extend(self._harvest(self._inflight.popleft(),
                                             block=True))

    # --- harvest ----------------------------------------------------------

    def _harvest(self, inf: _InFlight, block: bool) -> list[SegmentResult]:
        if block:
            inf.dms.depth.block_until_ready()
        results: list[SegmentResult] = []
        for k, (start, end) in enumerate(inf.segs):
            dm = DepthMap(inf.dms.depth[k], inf.dms.mask[k],
                          inf.dms.confidence[k])
            res = SegmentResult(dm, inf.dsis[k],
                                SE3(inf.ref_R[k], inf.ref_t[k]), (start, end))
            pc = PointCloud(inf.pcs.points[k], inf.pcs.weights[k],
                            inf.pcs.valid[k])
            self._done[(start, end)] = (res, pc)
            results.append(res)
        return results

    def poll(self) -> list[SegmentResult]:
        """Results that became ready since the last poll: back-pressure
        harvests plus every in-flight sweep the device has finished.
        Freed in-flight slots let the coalescing queue drain, so a poll
        can also dispatch segments the adaptive policy was holding."""
        self._fresh.extend(self._harvest_ready())
        self._drain_pending(final=False)
        self._fresh.extend(self._harvest_ready())
        out, self._fresh = self._fresh, []
        return out

    def flush(self) -> EMVSResult:
        """End of stream: flush the partial frame and the open segment,
        drain all in-flight sweeps, and return the accumulated result
        (same ordering and types as offline `run_emvs`).

        In pose-gated mode, flushing while frames still await their pose
        chunks raises `PoseStallError` (naming the stalled frame count
        and the watermark) — either push the missing chunks or call
        `finalize_poses` first. The engine stays usable after the error
        for the pose side only: frames released by later pose chunks are
        not lost, but `push` is rejected from the first flush attempt on
        (the event tail was already emitted as a padded frame)."""
        if not self._flushed:
            try:
                if not self._tail_flushed:
                    self._tail_flushed = True
                    self._ingest(self.aggregator.flush())
            finally:
                # runs when the tail frame trips the max-stall bound too,
                # so max_stalled records the true peak on the raise path
                self._track_stall()
            stalled = self.aggregator.stalled_frames
            if stalled:
                raise PoseStallError(
                    f"flush with {stalled} frame(s) stalled awaiting poses: "
                    f"pose watermark t={self.aggregator.pose_watermark:.6g}, "
                    f"oldest stalled frame t_mid="
                    f"{self.aggregator.oldest_stalled_t:.6g}; push the "
                    f"missing pose chunks or call finalize_poses() first")
            tail = self.planner.flush()
            if tail is not None:
                self._pending.append(tail)
                self._note_queue_depth()
            self._flushed = True
        # end of stream: every policy drains the coalescing queue fully
        self._drain_pending(final=True)
        while self._inflight:
            self._harvest(self._inflight.popleft(), block=True)
        self._fresh.clear()  # flush reports everything via result()
        return self.result()

    def result(self) -> EMVSResult:
        """Results harvested so far, in frame order (complete after flush)."""
        keys = sorted(self._done)
        return EMVSResult(segments=[self._done[k][0] for k in keys],
                          clouds=[self._done[k][1] for k in keys])
