"""Streaming EMVS engine: segments vote while the trajectory still arrives.

The offline `run_emvs` needs the whole aggregated sequence before it can
plan and bucket key-frame segments. This engine removes that barrier —
the paper's A/P/R pipelining applied across segments, structured like
`serving/engine.py`'s continuous batching:

  * events arrive in chunks of arbitrary size; `StreamingAggregator`
    carries the partial-frame remainder and emits completed frames with
    interpolated poses;
  * `SegmentPlanner` applies the K criterion frame-by-frame and closes a
    segment the moment the translation threshold trips — the same
    boundaries as offline `segment_keyframes`;
  * closed segments are padded into the same multiple-of-four
    frame-capacity buckets as `run_emvs` AND the segment axis S is padded
    to a small fixed set of sizes (`StreamConfig.segment_buckets`), so
    `process_segments_batched`'s jit cache stays bounded at
    |segment_buckets| x |capacities| variants over an unbounded stream;
  * dispatch is double-buffered: JAX's async dispatch returns as soon as
    a sweep is enqueued, so the host stages (`pad_segments` + transfer)
    segment k+1 while segment k is still voting on the device; at most
    `max_inflight` sweeps run ahead before the engine blocks on the
    oldest (back-pressure), and frames behind the open segment are
    evicted from the host window once dispatched;
  * closed segments pass through a FIFO *coalescing queue* before
    dispatch. `StreamConfig.dispatch_policy` decides how the queue
    drains: "latency" dispatches every closed segment immediately as its
    own sweep (the per-segment baseline), "throughput" holds segments
    until the head group fills the largest S bucket, and "adaptive" (the
    default) dispatches immediately while the in-flight queue is shallow
    but coalesces queued segments into the largest fitting S bucket once
    the device falls behind — burst-tolerant buffering between the
    asynchronous front-end and the batch-parallel back-end. The queue
    releases per-stream strictly FIFO
    (`repro.core.pipeline.dispatch_group_head_tagged`), so the policy
    changes the dispatch schedule, never the results.

The engine is built from two layers (this module composes them):

  * `repro.serving.stream_session.StreamSession` — everything ONE
    camera's stream owns: aggregator, pose watermark, planner, host
    frame store (with live/peak byte accounting), per-session stats and
    result stores;
  * `repro.serving.sweep_dispatcher.SweepDispatcher` — everything N
    sessions share: the `(session, segment)`-tagged coalescing queue,
    dispatch policy + fairness, in-flight slots, the bounded
    compiled-variant cache, and the batched/sharded sweep backends.

`EMVSStreamEngine` is the N=1 composition (one session over a private
dispatcher) and keeps the original public API and stats identities.
`MultiStreamEngine` serves N cameras over ONE dispatcher, so
shape-compatible segments from different sessions coalesce into one S
bucket — cross-stream coalescing keeps the device saturated when any
single stream goes quiet (the ROADMAP's multi-tenant serving item, and
the use case of multi-camera event rigs).

S-axis padding repeats the last real segment; the per-segment sweep
body is independent, so padded rows are discarded on harvest without
touching real outputs — per-segment results are bit-identical to
`run_emvs` on the integer/nearest datapaths for every chunking of the
input (tests/test_streaming.py enforces exactly that) and for every
session interleaving (tests/test_multi_stream.py).

Sweep backends: `StreamConfig(sweep=...)` picks how each dispatch runs,
mirroring `run_emvs(sweep=...)`. `"batched"` (default) sweeps the
bucket serially in one `lax.map` program; `"sharded"` shards the
bucket's segment axis across the engine's mesh
(`repro.distributed.emvs.process_segments_sharded`), so concurrent
segments vote on different devices. With `"sharded"` the engine rounds
every S bucket up to a multiple of the mesh's segment-axis size, so
dispatch shapes stay shard-stable (and the compiled-variant bound
holds) over an unbounded stream.

Poses arrive either from a fully-known `Trajectory` oracle (offline
replay) or — the realistic mode — as a chunked stream from the tracker
via `push_poses`, mirroring `push` for events. In the streamed mode the
engine's aggregator holds a `TrajectoryBuffer` with a monotonically
advancing **pose-lag watermark**: the latest time at which pose
interpolation is bracketed by received samples. A completed event frame
whose mid-time is not yet strictly below the watermark *stalls* (the
stall queue sits upstream of the frame store, so planner indices and
window eviction never see out-of-order frames) and is released
bitwise-identically posed once the bracketing pose chunk lands — so ANY
interleaving of event and pose chunks reproduces the offline result,
and no code path silently extrapolates a pose beyond the received
trajectory. `finalize_poses` declares the tracker done (remaining
stalled frames release under `StreamConfig.pose_extrapolation`:
warn-clamp by default, raise on strict pipelines); `flush` with poses
still missing raises `PoseStallError` naming the stalled frame count
and the watermark. `stats` tracks the stall queue depth and watermark
("stalled_frames", "max_stalled", "pose_chunks", "pose_watermark").
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.camera import CameraModel
from repro.core.dsi import DSIConfig
from repro.core.pipeline import (
    EMVSOptions,
    EMVSResult,
    FAIRNESS_POLICIES,
    SegmentResult,
)
from repro.events.aggregation import EVENTS_PER_FRAME
from repro.events.simulator import EventStream, Trajectory
from repro.events.stream_hygiene import (
    HYGIENE_POLICIES,
    HygieneConfig,
    StreamHygieneError,
)
from repro.events.trajectory_stream import (
    POSE_EXTRAPOLATION_POLICIES,
    TrajectoryBuffer,
)
from repro.serving.stream_session import (
    BUDGET_POLICIES,
    MemoryBudgetError,
    StreamSession,
    _FrameStore,
)
from repro.serving.sweep_dispatcher import SweepDispatcher, _InFlight

__all__ = [
    "BUDGET_POLICIES",
    "DISPATCH_POLICIES",
    "EMVSStreamEngine",
    "HYGIENE_POLICIES",
    "HygieneConfig",
    "MemoryBudgetError",
    "MultiStreamEngine",
    "StreamConfig",
    "StreamHygieneError",
    "StreamSession",
    "SweepDispatcher",
    "iter_event_chunks",
]

# Dispatch policies for the closed-segment coalescing queue:
#   * "latency"    — every closed segment dispatches immediately as its own
#     sweep (smallest fitting S bucket). Lowest time-to-depth-map per
#     segment; the per-segment baseline the other policies are measured
#     against in benchmarks/streaming_latency.py.
#   * "throughput" — closed segments coalesce until the head group fills
#     the largest S bucket (or can no longer grow: a different-capacity
#     segment queued behind it, or end of stream). Fewest dispatches and
#     the biggest batches — the offline sweep's schedule, reconstructed
#     online at the cost of first-depth latency.
#   * "adaptive"   — never waits while the in-flight queue is shallow:
#     whatever is queued dispatches at once (a lone closed segment goes
#     solo, exactly like "latency" on a quiet stream; a backlog that
#     piled up in one push coalesces into the largest fitting S bucket).
#     Once the device saturates it holds segments like "throughput",
#     coalescing them as soon as an in-flight slot frees. Burst-tolerant
#     without giving up the quiet-stream latency profile; the default.
DISPATCH_POLICIES = ("latency", "throughput", "adaptive")


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    """Knobs of the streaming engine.

    Shape stability: `events_per_frame`, `segment_buckets` and the
    `sweep` backend bound the compiled-variant count over an unbounded
    stream. Scheduling: `dispatch_policy` picks how closed segments leave
    the coalescing queue ("latency" = one sweep per segment, lowest
    first-depth latency; "throughput" = fill the largest S bucket before
    dispatching, highest sustained segments/s; "adaptive" = never wait
    while the device keeps up — a lone closed segment dispatches solo, a
    queued backlog coalesces — and hold-to-coalesce once the in-flight
    queue saturates; pick it unless you need one extreme). With a cost
    model attached and `target_latency_s` set, "adaptive" schedules
    against a predicted drain-time deadline instead of queue depth —
    the policy/fairness/cost-model/SLO decision table lives in
    docs/dispatch_planning.md. Back-pressure:
    `max_inflight` bounds device-side work in flight, and
    `max_stalled_frames` bounds the pose-stall queue — with a stalled
    tracker the event front would otherwise grow the stall queue (and the
    coalescing queue behind it) without limit; exceeding the bound raises
    `PoseStallError` after buffering the offending frames, so pushing the
    missing pose chunks recovers without losing events. Every policy
    produces bit-identical results on the nearest/integer datapaths
    (tests/test_adaptive_dispatch.py) — these knobs trade latency for
    throughput, never numerics.

    Ingest hygiene: `hygiene` guards every pushed event chunk against
    the adversarial stream modes production ingest sees (non-monotone
    timestamps, overlap/regression vs prior pushes, exact-duplicate
    chunks, out-of-bounds coordinates, hot-pixel storms — the
    event-vision survey's noise taxonomy). Pass a policy string —
    "raise" (default: typed `StreamHygieneError` subclasses naming the
    first offending index, the chunk rejected atomically), "drop" (warn
    + discard exactly the offenders, counted in
    `stats["hygiene"]`), "reorder" (bounded reorder buffer restoring
    sort order, bit-identical to a pre-sorted stream within the slack),
    or "off" (trust the feed) — or a full
    `repro.events.stream_hygiene.HygieneConfig` to set the reorder
    slack, the per-pixel rate limit, or the duplicate-detection history.

    Memory budget: `frame_store_budget_bytes` caps each session's host
    frame-store `live_bytes` (None = uncapped). Admission happens
    BEFORE a frame enters the store, so the cap is never exceeded — not
    even transiently. When the next frame does not fit, `budget_policy`
    decides: "stall" (default) applies back-pressure like
    `max_stalled_frames` — the push blocks while the dispatcher makes
    room (harvest completed sweeps, dispatch this session's queued
    segments to raise its eviction floor, evict) and only raises
    `MemoryBudgetError` when the budget cannot hold even the open
    segment's working set (frames below the retention floor — queued
    dispatches and the planner's open segment — are NEVER evicted, the
    floor `SweepDispatcher._evict_all` enforces); "reject" never
    blocks — the push raises `MemoryBudgetError` once non-blocking
    room-making fails, with the frames buffered in an admission backlog
    FIRST (the `PoseStallError` recovery contract: nothing is lost,
    `poll()` retries admission as sweeps complete, `flush()` drains).
    The budget is per session; N sessions of a `MultiStreamEngine`
    each get the full value.

    Shared vs per-session: one `StreamConfig` (with the camera model,
    DSI config and `EMVSOptions`) is shared by every session of a
    `MultiStreamEngine` — that is what lets one compiled sweep program
    per (S bucket, capacity) serve all N cameras and lets their segments
    share device batches. Only the trajectory / pose source (and the
    event feed itself) is per-session, supplied to `add_session`.
    `fairness` only matters with N > 1 sessions: it picks how dispatch
    groups anchor on the shared tagged queue. "fifo" (default) keeps
    strict global arrival order — simplest to reason about, but one
    session's odd-capacity segment at the queue head delays everyone
    else's *anchors* (their shape-compatible segments still ride along
    as group members). "round_robin" rotates anchors over the sessions,
    bounding any session's wait to O(sessions) dispatches behind a
    chatty neighbor, at the cost of leaving global arrival order.
    Neither setting changes any session's numbers — per-session results
    stay bit-identical to a dedicated engine under both.
    """

    events_per_frame: int = EVENTS_PER_FRAME
    # Fixed segment-axis pad sizes (ascending). Groups larger than the top
    # bucket are split, so the compiled-variant bound holds regardless of
    # how many segments a single push closes.
    segment_buckets: tuple[int, ...] = (1, 2, 4)
    # Double-buffer depth: sweeps allowed in flight before dispatch blocks
    # on the oldest. 2 = classic ping-pong (stage k+1 while k votes).
    # Doubles as the adaptive policy's depth threshold: a dispatch that
    # would exceed it switches the policy into coalescing mode.
    max_inflight: int = 2
    # How the closed-segment coalescing queue drains (DISPATCH_POLICIES).
    dispatch_policy: str = "adaptive"
    # Latency SLO for the adaptive policy, in seconds (None = off). With
    # a cost model attached to the engine/dispatcher, "adaptive" becomes
    # deadline-driven instead of depth-driven: it keeps coalescing while
    # the PREDICTED time to drain the queue (in-flight sweeps + the
    # planned partition of everything pending) still fits under this
    # deadline, and dispatches the moment the prediction exceeds it —
    # "dispatch now iff predicted queue-drain time exceeds the
    # deadline". Sealed groups (which can never grow) always dispatch.
    # Without a cost model, or when the model cannot predict the queue
    # (out-of-distribution variant), the policy falls back to the
    # depth-based rule, so schedules are bitwise-identical to the
    # pre-SLO engine. Ignored by "latency"/"throughput". Full decision
    # table: docs/dispatch_planning.md.
    target_latency_s: float | None = None
    # How dispatch groups anchor on the shared multi-session queue
    # (repro.core.pipeline.FAIRNESS_POLICIES): "fifo" = strict global
    # arrival order, "round_robin" = starvation-bounded rotation over
    # sessions. Irrelevant at N=1 (both reduce to the same schedule).
    fairness: str = "fifo"
    # Max-stall back-pressure bound (pose-gated mode): maximum frames the
    # aggregator may hold stalled past the pose watermark (unreleasable
    # by the poses received so far) before `push` raises `PoseStallError`
    # — frames are buffered first, so nothing is lost and pushing the
    # missing pose chunks recovers. None = unbounded (trusted tracker).
    max_stalled_frames: int | None = None
    # Segment-sweep backend: "batched" runs each dispatch as one lax.map
    # program (`process_segments_batched`); "sharded" shards the segment
    # axis across the devices of the engine's mesh
    # (`repro.distributed.emvs.process_segments_sharded`), so concurrent
    # segments vote on different devices. With "sharded" the engine
    # rounds every segment bucket up to a multiple of the mesh's
    # segment-axis size, keeping dispatch shapes shard-stable over an
    # unbounded stream.
    sweep: str = "batched"
    # Policy for frame mid-times outside the received trajectory span
    # (only reachable at the stream edges): "warn" clamps to the span
    # endpoint with PoseExtrapolationWarning, "raise" refuses with
    # PoseExtrapolationError, "clamp" is the seed's silent freeze (kept
    # for explicit opt-in only).
    pose_extrapolation: str = "warn"
    # Ingest-hygiene policy (HYGIENE_POLICIES) or a full HygieneConfig —
    # how adversarial event chunks are met (see the class docstring).
    hygiene: str | HygieneConfig = "raise"
    # Per-session cap on the host frame store's live_bytes (None =
    # uncapped); enforced BEFORE admission, so it is never exceeded.
    frame_store_budget_bytes: int | None = None
    # What a push does when the next frame does not fit under the budget
    # (BUDGET_POLICIES): "stall" = block while the dispatcher makes
    # room; "reject" = raise MemoryBudgetError with the frames buffered
    # first (recover via poll/flush).
    budget_policy: str = "stall"
    # Interpret/compiled override for EMVSOptions(formulation="kernel")
    # sweeps, threaded through the dispatcher into the fused Pallas
    # kernel and resolved in ONE place
    # (repro.kernels.platform.resolve_interpret): None = leave
    # EMVSOptions.kernel_interpret as configured (itself defaulting to
    # compiled-on-TPU/GPU, interpreter elsewhere); True = force the
    # interpreter; False = require the compiled kernel (ValueError on
    # platforms without a Pallas compile path — never a silent
    # interpreter fallback).
    kernel_interpret: bool | None = None

    def __post_init__(self):
        if not self.segment_buckets:
            raise ValueError("segment_buckets must be non-empty")
        if list(self.segment_buckets) != sorted(set(self.segment_buckets)):
            raise ValueError(
                f"segment_buckets must be strictly ascending, got "
                f"{self.segment_buckets}")
        if self.max_inflight < 1:
            raise ValueError("max_inflight must be >= 1")
        if self.dispatch_policy not in DISPATCH_POLICIES:
            raise ValueError(
                f"unknown dispatch_policy {self.dispatch_policy!r}: "
                f"expected one of {DISPATCH_POLICIES}")
        if self.fairness not in FAIRNESS_POLICIES:
            raise ValueError(
                f"unknown fairness {self.fairness!r}: expected one of "
                f"{FAIRNESS_POLICIES}")
        if self.target_latency_s is not None and not self.target_latency_s > 0:
            raise ValueError(
                f"target_latency_s must be > 0 seconds (or None for no "
                f"SLO), got {self.target_latency_s}")
        if self.max_stalled_frames is not None and self.max_stalled_frames < 1:
            raise ValueError(
                f"max_stalled_frames must be >= 1 (or None for unbounded), "
                f"got {self.max_stalled_frames}")
        if self.sweep not in ("batched", "sharded"):
            raise ValueError(
                f"unknown sweep backend {self.sweep!r}: expected 'batched' "
                f"or 'sharded'")
        if self.pose_extrapolation not in POSE_EXTRAPOLATION_POLICIES:
            raise ValueError(
                f"unknown pose_extrapolation policy "
                f"{self.pose_extrapolation!r}: expected one of "
                f"{POSE_EXTRAPOLATION_POLICIES}")
        if isinstance(self.hygiene, str):
            if self.hygiene not in HYGIENE_POLICIES:
                raise ValueError(
                    f"unknown hygiene policy {self.hygiene!r}: expected "
                    f"one of {HYGIENE_POLICIES} or a HygieneConfig")
        elif not isinstance(self.hygiene, HygieneConfig):
            raise ValueError(
                f"hygiene must be a policy string ({HYGIENE_POLICIES}) or "
                f"a HygieneConfig, got {type(self.hygiene).__name__}")
        if (self.frame_store_budget_bytes is not None
                and self.frame_store_budget_bytes < 1):
            raise ValueError(
                f"frame_store_budget_bytes must be >= 1 (or None for "
                f"uncapped), got {self.frame_store_budget_bytes}")
        if self.budget_policy not in BUDGET_POLICIES:
            raise ValueError(
                f"unknown budget_policy {self.budget_policy!r}: expected "
                f"one of {BUDGET_POLICIES}")


def iter_event_chunks(stream: EventStream, chunk_events: int):
    """Split a stream into contiguous chunks of `chunk_events` events."""
    if isinstance(chunk_events, bool) or not isinstance(
            chunk_events, (int, np.integer)):
        raise ValueError(
            f"chunk_events must be an int, got "
            f"{type(chunk_events).__name__} ({chunk_events!r})")
    if chunk_events < 1:
        raise ValueError(f"chunk_events must be >= 1, got {chunk_events}")
    n = stream.t.shape[0]
    for i in range(0, n, chunk_events):
        sl = slice(i, min(i + chunk_events, n))
        yield EventStream(xy=stream.xy[sl], t=stream.t[sl],
                          polarity=stream.polarity[sl], valid=stream.valid[sl])


class EMVSStreamEngine:
    """Online EMVS: push event chunks, harvest per-keyframe depth maps.

    One `StreamSession` composed over a private `SweepDispatcher` — the
    N=1 case of `MultiStreamEngine`, with the original single-stream API.

    Usage (pose oracle — offline replay with a fully-known trajectory):
        engine = EMVSStreamEngine(cam, dsi_cfg, traj, opts)
        for chunk in iter_event_chunks(stream, 4096):
            for seg in engine.push(chunk):   # results ready so far
                ...
        result = engine.flush()              # drain; same type as run_emvs

    Usage (streamed trajectory — poses arrive in chunks, like events):
        engine = EMVSStreamEngine(cam, dsi_cfg, None, opts)
        for ev_chunk, pose_chunk in tracker_feed():
            engine.push(ev_chunk)            # frames past the pose-lag
            engine.push_poses(pose_chunk)    # watermark stall until here
        engine.finalize_poses()              # tracker done
        result = engine.flush()
    """

    def __init__(self, cam: CameraModel, dsi_cfg: DSIConfig,
                 traj: Trajectory | TrajectoryBuffer | None,
                 opts: EMVSOptions = EMVSOptions(),
                 stream_cfg: StreamConfig = StreamConfig(), *,
                 mesh=None, cost_model=None, profiler=None):
        self.cam = cam
        self.dsi_cfg = dsi_cfg
        self.opts = opts
        self.stream_cfg = stream_cfg
        self._dispatcher = SweepDispatcher(cam, dsi_cfg, opts, stream_cfg,
                                           mesh=mesh, cost_model=cost_model,
                                           profiler=profiler)
        self._session = StreamSession("cam0", self._dispatcher, traj)

    # --- delegation to the session/dispatcher layers ----------------------
    # (kept as properties so existing callers and tests see the same
    # objects they used to poke at directly)

    @property
    def mesh(self):
        return self._dispatcher.mesh

    @property
    def _segment_buckets(self) -> tuple[int, ...]:
        return self._dispatcher._segment_buckets

    @property
    def pose_gated(self) -> bool:
        return self._session.pose_gated

    @property
    def aggregator(self):
        return self._session.aggregator

    @property
    def planner(self):
        return self._session.planner

    @property
    def _store(self) -> _FrameStore:
        return self._session._store

    @property
    def _pending(self):
        return self._dispatcher._pending

    @property
    def _inflight(self):
        return self._dispatcher._inflight

    @property
    def _done(self):
        return self._session._done

    @property
    def stats(self) -> dict:
        """Merged per-session + dispatcher counters, with the original
        single-stream keys and identities (tests/test_adaptive_dispatch.py)
        plus the session split's additions ("empty_chunks",
        "frame_store_bytes", "frame_store_peak_bytes",
        "cross_stream_dispatches" — always 0 at N=1)."""
        out = dict(self._session.stats)
        d = self._dispatcher.stats
        for key in ("dispatches", "padded_segments", "pending_segments",
                    "max_pending", "coalesced_dispatches",
                    "coalesced_segments", "cross_stream_dispatches",
                    "slo_dispatches", "slo_holds"):
            out[key] = d[key]
        # latency histograms are dicts: copy so callers can't mutate the
        # dispatcher's accumulators through the stats view
        out["queue_wait_s"] = dict(d["queue_wait_s"])
        out["sweep_time_s"] = dict(d["sweep_time_s"])
        return out

    def predict_drain_s(self) -> float | None:
        """Cost-model prediction of the time to drain everything queued
        and in flight, or None without a predicting cost model
        (docs/dispatch_planning.md)."""
        return self._dispatcher.predict_drain_s()

    # --- the single-stream API, unchanged ---------------------------------

    def push(self, chunk: EventStream) -> list[SegmentResult]:
        """Feed one event chunk; returns segment results that became ready
        (without blocking — completed sweeps only). In pose-gated mode,
        frames whose mid-time lies past the pose watermark stall inside
        the aggregator and surface on a later `push_poses`."""
        return self._session.push(chunk)

    def push_poses(self, chunk: Trajectory) -> list[SegmentResult]:
        """Feed one pose chunk from the tracker; stalled frames the
        advanced watermark now covers are released (bitwise-identically
        posed), planned, and dispatched."""
        return self._session.push_poses(chunk)

    def finalize_poses(self) -> list[SegmentResult]:
        """Declare the pose stream complete: every still-stalled frame is
        released through `StreamConfig.pose_extrapolation`."""
        return self._session.finalize_poses()

    def poll(self) -> list[SegmentResult]:
        """Results that became ready since the last poll: back-pressure
        harvests plus every in-flight sweep the device has finished."""
        return self._session.poll()

    def flush(self) -> EMVSResult:
        """End of stream: flush the partial frame and the open segment,
        drain all in-flight sweeps, and return the accumulated result
        (same ordering and types as offline `run_emvs`). See
        `StreamSession.flush` for the pose-gated error contract."""
        return self._session.flush()

    def result(self) -> EMVSResult:
        """Results harvested so far, in frame order (complete after flush)."""
        return self._session.result()

    # --- private compat shims (exercised by tests/test_streaming.py) ------

    def _dispatch(self, segs: list[tuple[int, int]], cap: int) -> None:
        assert segs, "_dispatch requires at least one closed segment"
        self._dispatcher._dispatch([(self._session, seg) for seg in segs],
                                   cap)

    def _dispatch_all(self, closed: list[tuple[int, int]]) -> None:
        if closed:
            self._dispatcher.enqueue(self._session, closed)
        self._dispatcher.pump()


class MultiStreamEngine:
    """N camera sessions multiplexed onto ONE shared sweep dispatcher.

    Why: a single event stream leaves the accelerator idle whenever its
    camera goes quiet — single-stream dispatches under-fill the S buckets
    the compiled sweep is shaped for. With N sessions on one dispatcher,
    closed segments from different cameras coalesce into the same
    device batch whenever their frame capacities match (cross-stream
    coalescing), so concurrent trickle streams approach the batch
    efficiency of one dense stream: fewer dispatches, fuller buckets,
    higher aggregate segments/s (benchmarks/streaming_latency.py
    `multi_stream_sweep` measures exactly this against N dedicated
    engines). Coalescing helps most when sessions are individually
    sparse but collectively busy; a single saturated stream gains
    nothing (it already fills its buckets) — use `EMVSStreamEngine`.

    Shared vs per-session: the camera model, DSI config, `EMVSOptions`
    and `StreamConfig` are fixed at construction and shared by every
    session — sharing them is what lets one compiled variant per
    (S bucket, capacity) serve all cameras. Per-session: the pose source
    (`add_session(traj=...)`: an oracle `Trajectory`, a pre-filled
    `TrajectoryBuffer`, or None for pose-gated streaming) and the event
    feed. Mixed rigs needing different camera models need separate
    engines — their sweeps could not share compiled programs anyway.

    Fairness (`StreamConfig.fairness`): "fifo" anchors every dispatch
    group at the global arrival head — strict and predictable, but a
    chatty session can make a quiet one wait; "round_robin" rotates
    anchors over sessions, bounding any session's wait to O(sessions)
    dispatches. Neither changes results: every session's outputs are
    bit-identical to a dedicated `EMVSStreamEngine` on the
    integer/nearest datapaths, under every dispatch policy, sweep
    backend, and session interleaving (tests/test_multi_stream.py).

    Usage:
        engine = MultiStreamEngine(cam, dsi_cfg, opts, stream_cfg)
        left = engine.add_session("left", traj=traj_l)
        right = engine.add_session("right", traj=traj_r)
        for chunk_l, chunk_r in rig_feed():
            left.push(chunk_l)     # or engine.push("left", chunk_l)
            right.push(chunk_r)
        results = engine.flush()   # {"left": EMVSResult, "right": ...}

    Sessions are admitted up front or on the fly (`add_session` any time
    before that session's first push); each holds its own fixed slot in
    the dispatcher's fairness rotation, mirroring `serving/engine.py`'s
    fixed-slot admission. One session's `flush` drains only its own
    work — the rig keeps streaming.
    """

    def __init__(self, cam: CameraModel, dsi_cfg: DSIConfig,
                 opts: EMVSOptions = EMVSOptions(),
                 stream_cfg: StreamConfig = StreamConfig(), *,
                 mesh=None, cost_model=None, profiler=None):
        self.cam = cam
        self.dsi_cfg = dsi_cfg
        self.opts = opts
        self.stream_cfg = stream_cfg
        self.dispatcher = SweepDispatcher(cam, dsi_cfg, opts, stream_cfg,
                                          mesh=mesh, cost_model=cost_model,
                                          profiler=profiler)
        self._sessions: dict[str, StreamSession] = {}

    @property
    def mesh(self):
        return self.dispatcher.mesh

    @property
    def sessions(self) -> dict[str, StreamSession]:
        """Admitted sessions by id (insertion = fairness rotation order)."""
        return dict(self._sessions)

    def add_session(self, session_id: str | None = None,
                    traj: Trajectory | TrajectoryBuffer | None = None
                    ) -> StreamSession:
        """Admit one camera stream; returns its `StreamSession` handle.

        `session_id` defaults to "cam<k>" in admission order. `traj` is
        the per-session pose source (None = pose-gated: feed via
        `push_poses`)."""
        if session_id is None:
            session_id = f"cam{len(self._sessions)}"
        if session_id in self._sessions:
            raise ValueError(
                f"duplicate session id {session_id!r}: already admitted "
                f"(have {sorted(self._sessions)})")
        session = StreamSession(session_id, self.dispatcher, traj)
        self._sessions[session_id] = session
        return session

    def session(self, session_id: str) -> StreamSession:
        try:
            return self._sessions[session_id]
        except KeyError:
            raise KeyError(
                f"unknown session {session_id!r}: admitted sessions are "
                f"{sorted(self._sessions)}") from None

    # id-addressed conveniences (the session handles carry the same API)

    def push(self, session_id: str, chunk: EventStream) -> list[SegmentResult]:
        return self.session(session_id).push(chunk)

    def push_poses(self, session_id: str,
                   chunk: Trajectory) -> list[SegmentResult]:
        return self.session(session_id).push_poses(chunk)

    def finalize_poses(self, session_id: str) -> list[SegmentResult]:
        return self.session(session_id).finalize_poses()

    def poll(self) -> dict[str, list[SegmentResult]]:
        """Pump the shared dispatcher once; returns each session's newly
        ready results keyed by session id (possibly empty lists)."""
        self.dispatcher.pump()
        return {sid: sess._take_fresh()
                for sid, sess in self._sessions.items()}

    def flush(self, session_id: str | None = None):
        """Flush one session (returns its `EMVSResult`) or, with no id,
        every admitted session in admission order (returns a dict keyed
        by session id). Flushing one session leaves the others
        streaming."""
        if session_id is not None:
            return self.session(session_id).flush()
        return {sid: sess.flush() for sid, sess in self._sessions.items()}

    def result(self, session_id: str) -> EMVSResult:
        return self.session(session_id).result()

    @property
    def stats(self) -> dict:
        """Dispatcher-level counters plus per-session counters:
        `{"dispatcher": {...}, "sessions": {sid: {...}}}`."""
        return {"dispatcher": dict(self.dispatcher.stats),
                "sessions": {sid: dict(sess.stats)
                             for sid, sess in self._sessions.items()}}
