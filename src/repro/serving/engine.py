"""Serving engine: slot-based continuous batching over jit'd prefill/decode.

vLLM-style structure adapted to JAX/TPU idioms:
  * fixed-shape decode batch (B slots) so one compiled `decode_step`
    serves every iteration — shape stability is the TPU contract;
  * per-slot lengths + active mask; finished slots are refilled by new
    requests between device steps (continuous batching);
  * prefill runs per admitted request (compiled once per bucketed prompt
    length) and its KV is spliced into the slot's cache row;
  * optional int8 KV cache (ModelCtx.kv_quantized) — the paper's
    hybrid-quantization principle, here buying 2x cache capacity.

The decode hot loop is one token per step for ALL active slots; the
paper's double-buffering appears as host-side admission overlapping
device-side decode (the host prepares the next admission while the
device steps).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ArchConfig
from repro.models import model as M

Array = jax.Array


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (P,) int32
    max_new_tokens: int
    generated: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    slots: int = 8  # decode batch size (fixed compiled shape)
    max_len: int = 1024
    temperature: float = 0.0  # 0 => greedy
    kv_quantized: bool = False
    prefill_buckets: tuple[int, ...] = (64, 128, 256, 512)


class Engine:
    """Continuous-batching engine around one model's prefill/decode."""

    def __init__(self, cfg: ArchConfig, params: Any, ecfg: EngineConfig,
                 eos_id: int = 0):
        self.cfg = cfg
        self.params = params
        self.ecfg = ecfg
        self.eos_id = eos_id
        self.ctx = M.ModelCtx(kv_quantized=ecfg.kv_quantized)

        B, L = ecfg.slots, ecfg.max_len
        self.state = M.init_decode_state(cfg, B, L, self.ctx)
        self.lengths = np.zeros(B, np.int32)  # tokens so far per slot
        self.budget = np.zeros(B, np.int32)  # remaining new tokens
        self.active = np.zeros(B, bool)
        self.slot_req: list[Optional[Request]] = [None] * B
        self.queue: list[Request] = []
        self.step_count = 0

        self._decode = jax.jit(partial(self._decode_impl, cfg=cfg, ctx=self.ctx))
        self._prefill = {}  # bucket -> jitted fn

    # --- jitted kernels ---------------------------------------------------

    @staticmethod
    def _decode_impl(params, state, tokens, lengths, cfg, ctx):
        """Per-slot decode: each slot attends to its own `lengths[b]` cache."""
        # decode_step uses a scalar cur_len for cache writes; per-slot
        # lengths require a batched write -> run with the max and mask via
        # per-slot attention lengths. We write each slot's KV at its own
        # position using one-hot masking (shape-stable, no gather).
        logits, new_state = M.decode_step_batched(params, state, tokens,
                                                  lengths, cfg, ctx=ctx)
        return logits, new_state

    def _get_prefill(self, bucket: int) -> Callable:
        if bucket not in self._prefill:
            def fn(params, toks, logit_index):
                return M.prefill(params, toks, self.cfg, self.ecfg.max_len,
                                 ctx=self.ctx, logit_index=logit_index)

            self._prefill[bucket] = jax.jit(fn)
        return self._prefill[bucket]

    # --- host-side orchestration -------------------------------------------

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        """Fill free slots from the queue (prefill + cache splice)."""
        for b in range(self.ecfg.slots):
            if self.active[b] or not self.queue:
                continue
            req = self.queue.pop(0)
            p = len(req.prompt)
            # SSM/hybrid archs prefill at exact length (a right-padded
            # prompt would pollute the recurrent state); attention-only
            # archs use buckets + logit_index (padding is causally inert
            # left of the read position).
            if self.cfg.ssm is not None:
                bucket = p
            else:
                bucket = next((x for x in self.ecfg.prefill_buckets if x >= p),
                              max(self.ecfg.prefill_buckets[-1], p))
            prompt = np.zeros((1, bucket), np.int32)
            prompt[0, :p] = req.prompt
            logits, pstate = self._get_prefill(bucket)(
                self.params, jnp.asarray(prompt), jnp.int32(p - 1))
            logits = jax.device_get(logits)[0, 0]
            self.state = M.splice_slot(self.state, pstate, slot=b)
            first = self._sample_host(logits)
            req.generated.append(int(first))
            self.slot_req[b] = req
            self.lengths[b] = p  # cache holds p tokens; next write at p
            self.budget[b] = req.max_new_tokens - 1
            self.active[b] = True

    def _sample_host(self, logits: np.ndarray) -> int:
        if self.ecfg.temperature <= 0:
            return int(np.argmax(logits))
        z = logits / self.ecfg.temperature
        z = z - z.max()
        p = np.exp(z) / np.exp(z).sum()
        return int(np.random.default_rng(self.step_count).choice(len(p), p=p))

    def step(self) -> dict:
        """One engine iteration: admit, decode one token for active slots."""
        self._admit()
        if not self.active.any():
            return {"active": 0, "queued": len(self.queue)}
        tokens = np.zeros((self.ecfg.slots, 1), np.int32)
        for b in range(self.ecfg.slots):
            if self.active[b]:
                tokens[b, 0] = self.slot_req[b].generated[-1]
        logits, self.state = self._decode(self.params, self.state,
                                          jnp.asarray(tokens),
                                          jnp.asarray(self.lengths))
        logits = jax.device_get(logits)[:, 0]
        for b in range(self.ecfg.slots):
            if not self.active[b]:
                continue
            nxt = self._sample_host(logits[b])
            req = self.slot_req[b]
            req.generated.append(nxt)
            self.lengths[b] += 1
            self.budget[b] -= 1
            hit_eos = (nxt == self.eos_id)
            full = self.lengths[b] + 1 >= self.ecfg.max_len
            if hit_eos or self.budget[b] <= 0 or full:
                req.done = True
                self.active[b] = False
                self.slot_req[b] = None
        self.step_count += 1
        return {"active": int(self.active.sum()), "queued": len(self.queue)}

    def run_until_done(self, max_steps: int = 10000) -> None:
        for _ in range(max_steps):
            st = self.step()
            if st["active"] == 0 and st["queued"] == 0:
                return
