"""Per-camera session layer of the streaming EMVS engine.

`StreamSession` owns everything that belongs to ONE event camera's
stream and nothing that is shared with its neighbors:

  * the `StreamingAggregator` (partial-frame remainder, pose-stall queue,
    pose-lag watermark) and its `TrajectoryBuffer` / `Trajectory` oracle;
  * the `SegmentPlanner` applying the K criterion frame-by-frame;
  * the `_FrameStore` host retention window (with live/peak byte
    accounting — the hook for per-session memory caps);
  * per-session stats and the harvested-result store.

Everything shared — the tagged coalescing queue, dispatch policy, the
in-flight slots, the bounded compiled-variant cache, and the sweep
backends — lives in `repro.serving.sweep_dispatcher.SweepDispatcher`.
A session hands closed segments to its dispatcher tagged with itself and
gets `SegmentResult`s routed back into `_fresh` / `_done` when the
device finishes; `repro.serving.emvs_stream.EMVSStreamEngine` is the
N=1 composition of the two layers, `MultiStreamEngine` the N-camera one.
"""
from __future__ import annotations

from collections import deque

import numpy as np

from repro.core.geometry import SE3
from repro.core.pipeline import EMVSResult, SegmentPlanner, SegmentResult
from repro.core.pointcloud import PointCloud
from repro.events.aggregation import EventFrames, StreamingAggregator
from repro.events.simulator import EventStream, Trajectory
from repro.events.stream_hygiene import HygieneConfig, StreamHygiene
from repro.events.trajectory_stream import PoseStallError, TrajectoryBuffer

# How StreamConfig(frame_store_budget_bytes=...) responds when admitting
# the next aggregated frame would put _FrameStore.live_bytes over budget:
#   * "stall"  — back-pressure, like max_stalled_frames on the pose side:
#     the push blocks while the dispatcher makes room (dispatching this
#     session's queued segments raises its eviction floor; completed
#     sweeps are block-harvested to free dispatch slots). Only when no
#     progress is possible — the *open segment's* working set alone
#     exceeds the budget, and open-segment frames can never be evicted —
#     does the push raise `MemoryBudgetError` (a configuration error:
#     raise the budget or close segments sooner).
#   * "reject" — never block: the push raises `MemoryBudgetError` as soon
#     as non-blocking room-making (harvest-ready + evict + dispatch into
#     free slots) cannot fit the frame. The frames are buffered in the
#     admission backlog FIRST, so nothing is lost — a later `poll()`
#     retries admission quietly as results harvest, and `flush()` drains
#     everything (blocking is inherent to a drain).
BUDGET_POLICIES = ("stall", "reject")


class MemoryBudgetError(RuntimeError):
    """A session's frame-store byte budget cannot admit the next frame.

    Raised per `StreamConfig(budget_policy=...)` — see `BUDGET_POLICIES`.
    The offending frames are buffered in the session's admission backlog
    before the raise, so no events are lost: `poll()` retries admission
    non-blocking, `flush()` drains fully."""


class _FrameStore:
    """Host-side retention window of aggregated frames, globally indexed.

    Frames are appended as they are emitted and evicted once the planner's
    open segment has moved past them, so memory tracks the open-segment
    length, not the stream length. `live_bytes` / `peak_bytes` account the
    retained payload (event coords, validity, mid-times, poses) — the
    number a per-session memory cap would enforce against.
    """

    def __init__(self):
        self.base = 0  # global index of the oldest retained frame
        self.live_bytes = 0
        self.peak_bytes = 0
        self._xy: deque[np.ndarray] = deque()
        self._valid: deque[np.ndarray] = deque()
        self._t_mid: deque[np.float32] = deque()
        self._R: deque[np.ndarray] = deque()
        self._t: deque[np.ndarray] = deque()

    @property
    def end(self) -> int:
        """One past the newest retained global frame index."""
        return self.base + len(self._xy)

    @staticmethod
    def _frame_bytes(xy: np.ndarray, valid: np.ndarray, t_mid: np.ndarray,
                     r: np.ndarray, t: np.ndarray) -> int:
        return (xy.nbytes + valid.nbytes + t_mid.nbytes + r.nbytes + t.nbytes)

    def append_frame(self, xy: np.ndarray, valid: np.ndarray,
                     t_mid: np.ndarray, r: np.ndarray,
                     t: np.ndarray) -> None:
        self._xy.append(xy)
        self._valid.append(valid)
        self._t_mid.append(t_mid)
        self._R.append(r)
        self._t.append(t)
        self.live_bytes += self._frame_bytes(xy, valid, t_mid, r, t)
        self.peak_bytes = max(self.peak_bytes, self.live_bytes)

    def extend(self, frames: EventFrames) -> None:
        xy = np.asarray(frames.xy)
        valid = np.asarray(frames.valid)
        t_mid = np.asarray(frames.t_mid)
        r = np.asarray(frames.poses.R)
        t = np.asarray(frames.poses.t)
        for k in range(xy.shape[0]):
            self.append_frame(xy[k], valid[k], t_mid[k], r[k], t[k])

    def window(self, lo: int, hi: int) -> EventFrames:
        """Host EventFrames covering global frames [lo, hi)."""
        if not self.base <= lo < hi <= self.end:
            raise IndexError(
                f"window [{lo}, {hi}) outside retained [{self.base}, {self.end})")
        sel = range(lo - self.base, hi - self.base)
        return EventFrames(
            xy=np.stack([self._xy[k] for k in sel]),
            valid=np.stack([self._valid[k] for k in sel]),
            t_mid=np.asarray([self._t_mid[k] for k in sel], np.float32),
            poses=SE3(np.stack([self._R[k] for k in sel]),
                      np.stack([self._t[k] for k in sel])),
        )

    def evict_before(self, i: int) -> None:
        while self.base < i and self._xy:
            self.live_bytes -= self._frame_bytes(
                self._xy.popleft(), self._valid.popleft(),
                self._t_mid.popleft(), self._R.popleft(), self._t.popleft())
            self.base += 1


class StreamSession:
    """One camera's streaming state, multiplexed onto a shared dispatcher.

    Construct via `MultiStreamEngine.add_session` (or implicitly through
    the N=1 `EMVSStreamEngine`); the session registers itself with the
    dispatcher. The push/poll/flush lifecycle and error contract are the
    single-stream engine's, per session:

      * `push` / `push_poses` / `finalize_poses` feed this camera only;
        closed segments enter the dispatcher's shared tagged queue, where
        shape-compatible segments from OTHER sessions may share the same
        device sweep (cross-stream coalescing) — grouping never changes
        this session's numbers, so results stay bit-identical to a
        dedicated single-stream engine.
      * `poll` pumps the shared dispatcher (harvest + policy drain) and
        returns THIS session's newly ready results, in segment-close
        order.
      * `flush` drains this session only: its queued segments dispatch
        (same-capacity neighbors may ride along), its in-flight sweeps
        complete, other sessions keep streaming undisturbed.
    """

    def __init__(self, session_id: str,
                 dispatcher,
                 traj: Trajectory | TrajectoryBuffer | None = None):
        cfg = dispatcher.stream_cfg
        self.session_id = session_id
        self.dispatcher = dispatcher
        # traj=None: pose-gated mode with a fresh buffer the caller feeds
        # via push_poses; an existing TrajectoryBuffer (possibly pre-filled)
        # is used as-is; a Trajectory is the offline oracle.
        if traj is None:
            traj = TrajectoryBuffer()
        self.pose_gated = isinstance(traj, TrajectoryBuffer)
        if cfg.max_stalled_frames is not None and not self.pose_gated:
            raise ValueError(
                "max_stalled_frames is only meaningful in pose-gated mode "
                "(traj=None or a TrajectoryBuffer): a fully-known "
                "Trajectory oracle never stalls frames, so the bound "
                "would silently do nothing")
        self.aggregator = StreamingAggregator(
            dispatcher.cam, traj, cfg.events_per_frame,
            pose_extrapolation=cfg.pose_extrapolation,
            max_stalled=cfg.max_stalled_frames)
        mean_depth = 0.5 * (dispatcher.dsi_cfg.z_min + dispatcher.dsi_cfg.z_max)
        # min_frames=2 is plan_segments' parallax filter, applied online.
        self.planner = SegmentPlanner(
            mean_depth * dispatcher.opts.keyframe_dist_frac, min_frames=2)
        self._store = _FrameStore()
        # Ingest hygiene: every event chunk is scrubbed against the
        # stream watermark before it reaches the aggregator (policy per
        # StreamConfig.hygiene; the camera model supplies the sensor
        # bounds for the out-of-bounds check).
        hyg = cfg.hygiene
        if not isinstance(hyg, HygieneConfig):
            hyg = HygieneConfig(policy=hyg)
        self.hygiene = StreamHygiene(hyg, width=dispatcher.cam.width,
                                     height=dispatcher.cam.height)
        # Memory budget: frames emitted by the aggregator pass through an
        # admission backlog before entering the frame store, so
        # live_bytes NEVER exceeds the budget (checked before append,
        # see _drain_backlog / BUDGET_POLICIES).
        self._budget = cfg.frame_store_budget_bytes
        self._budget_policy = cfg.budget_policy
        self._backlog: deque[tuple] = deque()  # per-frame (xy,valid,t_mid,R,t)
        self._fresh: list[SegmentResult] = []  # harvested, not yet polled
        self._done: dict[tuple[int, int], tuple[SegmentResult, PointCloud]] = {}
        self._flushed = False
        self._tail_flushed = False  # aggregator tail emitted (flush began)
        # Ingestion-side counters; the dispatcher owns the shared dispatch
        # counters and attributes "segments" (dispatched, owned by this
        # session) back here. Same identities as the single-stream engine.
        # "hygiene" aliases the guard's live stats dict; "budget_stalls" /
        # "budget_rejects" / "backlog_frames" track the admission policy.
        # "dsi_saturation_peak" is the largest per-segment fraction of DSI
        # voxels at the int16 store limits seen on this stream (inclusive
        # boundary — see core.dsi.store_saturation_fraction): the live
        # monitor for the paper's "16 bits never saturate" claim. Updated
        # by the dispatcher on harvest; stays 0.0 on healthy streams.
        self.stats = {"chunks": 0, "empty_chunks": 0, "frames": 0,
                      "segments": 0, "pose_chunks": 0, "stalled_frames": 0,
                      "max_stalled": 0,
                      "pose_watermark": self.aggregator.pose_watermark,
                      "frame_store_bytes": 0, "frame_store_peak_bytes": 0,
                      "budget_stalls": 0, "budget_rejects": 0,
                      "backlog_frames": 0, "dsi_saturation_peak": 0.0,
                      "hygiene": self.hygiene.stats}
        dispatcher.register(self)

    # --- ingest -----------------------------------------------------------

    @staticmethod
    def _validate_chunk(chunk: EventStream) -> int:
        """Reject inconsistently shaped chunks before they corrupt the
        aggregator's remainder; returns the event count."""
        n = int(np.asarray(chunk.t).shape[0])
        fields = {"xy": np.asarray(chunk.xy).shape[0],
                  "polarity": np.asarray(chunk.polarity).shape[0],
                  "valid": np.asarray(chunk.valid).shape[0]}
        bad = {name: cnt for name, cnt in fields.items() if cnt != n}
        if bad:
            raise ValueError(
                f"inconsistent event chunk: t has {n} event(s) but "
                + ", ".join(f"{k} has {v}" for k, v in sorted(bad.items())))
        return n

    def push(self, chunk: EventStream) -> list[SegmentResult]:
        """Feed one event chunk; returns this session's segment results
        that became ready (without blocking — completed sweeps only). In
        pose-gated mode, frames whose mid-time lies past the pose
        watermark stall inside the aggregator and surface on a later
        `push_poses`.

        The chunk passes through this session's `StreamHygiene` guard
        first (`StreamConfig.hygiene`): an adversarial chunk raises a
        typed `StreamHygieneError` subclass / sheds offenders / waits in
        the reorder buffer per the policy, BEFORE any session state is
        touched — a hygiene raise leaves the session exactly as it was."""
        if self._flushed or self._tail_flushed:
            # once flush() has consumed the aggregator's tail remainder —
            # including a flush that then raised PoseStallError — more
            # events would land AFTER a padded mid-stream tail frame and
            # silently shift every later frame boundary
            raise RuntimeError(
                "push after flush: the event tail was already emitted "
                "(only push_poses / finalize_poses / flush may follow)")
        n = self._validate_chunk(chunk)
        chunk = self.hygiene.scrub(chunk)
        self.stats["chunks"] += 1
        if n == 0:
            # a legal no-op (e.g. a quiet sensor interval), but an easy
            # symptom of a broken feed — counted so callers can notice
            self.stats["empty_chunks"] += 1
        try:
            self._ingest(self.aggregator.push(chunk))
        finally:
            # runs on the PoseStallError (max-stall bound) path too, so
            # max_stalled records the true peak, not the last quiet push
            self._track_stall()
        return self.poll()

    def push_poses(self, chunk: Trajectory) -> list[SegmentResult]:
        """Feed one pose chunk from the tracker; stalled frames the
        advanced watermark now covers are released (bitwise-identically
        posed), planned, and dispatched. Returns results that became
        ready, exactly like `push`."""
        if self._flushed:
            raise RuntimeError("push_poses after flush: the engine is drained")
        if not self.pose_gated:
            raise RuntimeError(
                "push_poses requires a pose-gated engine: construct with "
                "traj=None (or a TrajectoryBuffer), not a Trajectory oracle")
        self.stats["pose_chunks"] += 1
        self._ingest(self.aggregator.push_poses(chunk))
        self._track_stall()
        return self.poll()

    def finalize_poses(self) -> list[SegmentResult]:
        """Declare the pose stream complete: every still-stalled frame is
        released through `StreamConfig.pose_extrapolation` (its pose can
        no longer gain a bracketing sample). Call before `flush` when the
        tracker ends behind the event front."""
        if self._flushed:
            raise RuntimeError(
                "finalize_poses after flush: the engine is drained")
        if not self.pose_gated:
            raise RuntimeError(
                "finalize_poses requires a pose-gated engine: construct "
                "with traj=None (or a TrajectoryBuffer)")
        self._ingest(self.aggregator.finalize_poses())
        self._track_stall()
        return self.poll()

    def _track_stall(self) -> None:
        n = self.aggregator.stalled_frames
        self.stats["stalled_frames"] = n
        self.stats["max_stalled"] = max(self.stats["max_stalled"], n)
        self.stats["pose_watermark"] = self.aggregator.pose_watermark

    def _sync_store_stats(self) -> None:
        self.stats["frame_store_bytes"] = self._store.live_bytes
        self.stats["frame_store_peak_bytes"] = self._store.peak_bytes

    def _ingest(self, frames: EventFrames, *,
                blocking: bool | None = None) -> None:
        n = int(frames.xy.shape[0])
        if n == 0:
            return
        self.stats["frames"] += n
        if self._budget is None:
            self._store.extend(frames)
            self._sync_store_stats()
            closed: list[tuple[int, int]] = []
            t_host = np.asarray(frames.poses.t)
            for k in range(n):
                seg = self.planner.push(t_host[k])
                if seg is not None:
                    closed.append(seg)
            if closed:
                self.dispatcher.enqueue(self, closed)
            self.dispatcher.pump()
            return
        # budgeted admission: frames queue in the backlog and enter the
        # store one at a time, each admitted only once it fits under the
        # budget — live_bytes can never exceed it
        xy = np.asarray(frames.xy)
        valid = np.asarray(frames.valid)
        t_mid = np.asarray(frames.t_mid)
        r = np.asarray(frames.poses.R)
        t = np.asarray(frames.poses.t)
        for k in range(n):
            self._backlog.append((xy[k], valid[k], t_mid[k], r[k], t[k]))
        if blocking is None:
            blocking = self._budget_policy == "stall"
        self._drain_backlog(blocking=blocking, raise_on_full=True)

    def _drain_backlog(self, *, blocking: bool, raise_on_full: bool) -> None:
        """Admit backlogged frames into the store under the byte budget.

        Each frame is admitted only when `live_bytes + frame` fits; when
        it does not, the dispatcher is asked to make room (harvest
        completed sweeps, evict behind the retention floor, dispatch this
        session's queued segments to RAISE that floor — never below it:
        queued segments and the planner's open segment stay resident).
        With `blocking` the room-making may block on in-flight sweeps
        (the "stall" policy's back-pressure); without it the first
        no-progress answer stops the drain — raising `MemoryBudgetError`
        when `raise_on_full` (the "reject" policy's push path) or
        returning quietly (poll's retry path). Admitted frames run the
        planner and enqueue their closed segments immediately, so a
        closed segment can free its own frames for the next admission."""
        budget = self._budget
        while self._backlog:
            fb = self._backlog[0]
            nbytes = _FrameStore._frame_bytes(*fb)
            while self._store.live_bytes + nbytes > budget:
                if self.dispatcher.make_room(self, blocking=blocking):
                    self.stats["budget_stalls"] += 1
                    continue
                self.stats["backlog_frames"] = len(self._backlog)
                if not raise_on_full:
                    return
                live = self._store.live_bytes
                if not blocking:
                    self.stats["budget_rejects"] += 1
                    raise MemoryBudgetError(
                        f"session {self.session_id!r}: admitting the next "
                        f"{nbytes}-byte frame would put the frame store at "
                        f"{live + nbytes} bytes, over the "
                        f"{budget}-byte budget (policy 'reject'; "
                        f"{len(self._backlog)} frame(s) held in the "
                        f"admission backlog — nothing is lost: poll() "
                        f"retries as sweeps complete, flush() drains)")
                raise MemoryBudgetError(
                    f"session {self.session_id!r}: frame-store budget "
                    f"{budget} bytes cannot hold the open segment's "
                    f"working set — {live} bytes are pinned by frames "
                    f"that may not be evicted (the planner's open "
                    f"segment / queued dispatches) and the next frame "
                    f"needs {nbytes} more, with nothing left to dispatch "
                    f"or harvest; raise the budget or close segments "
                    f"sooner (larger keyframe_dist_frac means longer "
                    f"segments)")
            self._backlog.popleft()
            self._store.append_frame(*fb)
            self._sync_store_stats()
            seg = self.planner.push(fb[4])
            if seg is not None:
                self.dispatcher.enqueue(self, [seg])
        self.stats["backlog_frames"] = 0
        self.dispatcher.pump()

    # --- harvest ----------------------------------------------------------

    def _take_fresh(self) -> list[SegmentResult]:
        out, self._fresh = self._fresh, []
        return out

    def poll(self) -> list[SegmentResult]:
        """This session's results that became ready since the last poll:
        back-pressure harvests plus every in-flight sweep the device has
        finished. Freed in-flight slots let the shared coalescing queue
        drain, so a poll can also dispatch segments (of any session) the
        adaptive policy was holding. Under a memory budget, frames a
        rejected push left in the admission backlog retry admission here
        (non-blocking, never raising) as completed sweeps free bytes."""
        if self._backlog:
            self._drain_backlog(blocking=False, raise_on_full=False)
        self.dispatcher.pump()
        return self._take_fresh()

    def flush(self) -> EMVSResult:
        """End of this session's stream: flush the partial frame and the
        open segment, drain this session's queued and in-flight work, and
        return its accumulated result (same ordering and types as offline
        `run_emvs`). Other sessions on the shared dispatcher keep
        streaming — though their same-capacity segments may ride along in
        this session's final dispatches.

        In pose-gated mode, flushing while frames still await their pose
        chunks raises `PoseStallError` (naming the stalled frame count
        and the watermark) — either push the missing chunks or call
        `finalize_poses` first. The session stays usable after the error
        for the pose side only: frames released by later pose chunks are
        not lost, but `push` is rejected from the first flush attempt on
        (the event tail was already emitted as a padded frame)."""
        if not self._flushed:
            try:
                if not self._tail_flushed:
                    self._tail_flushed = True
                    # end of stream for the hygiene guard too: the
                    # reorder buffer's held events precede the tail
                    held = self.hygiene.flush()
                    if held.t.shape[0]:
                        self._ingest(self.aggregator.push(held),
                                     blocking=True)
                    self._ingest(self.aggregator.flush(), blocking=True)
                if self._backlog:
                    # frames a rejected push left behind: a drain is
                    # inherently blocking under either budget policy
                    self._drain_backlog(blocking=True, raise_on_full=True)
            finally:
                # runs when the tail frame trips the max-stall bound too,
                # so max_stalled records the true peak on the raise path
                self._track_stall()
            stalled = self.aggregator.stalled_frames
            if stalled:
                raise PoseStallError(
                    f"flush with {stalled} frame(s) stalled awaiting poses: "
                    f"pose watermark t={self.aggregator.pose_watermark:.6g}, "
                    f"oldest stalled frame t_mid="
                    f"{self.aggregator.oldest_stalled_t:.6g}; push the "
                    f"missing pose chunks or call finalize_poses() first")
            tail = self.planner.flush()
            if tail is not None:
                self.dispatcher.enqueue(self, [tail])
            self._flushed = True
        # end of stream for this session: its share of the coalescing
        # queue drains fully under every policy
        self.dispatcher.drain_session(self)
        self._fresh.clear()  # flush reports everything via result()
        return self.result()

    def result(self) -> EMVSResult:
        """Results harvested so far, in frame order (complete after flush)."""
        keys = sorted(self._done)
        return EMVSResult(segments=[self._done[k][0] for k in keys],
                          clouds=[self._done[k][1] for k in keys])
