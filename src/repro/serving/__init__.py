"""Serving substrate: prefill/decode LM engine with continuous batching
(`engine`), and the streaming EMVS engine split into a per-camera session
layer (`stream_session`) and a shared dispatch layer (`sweep_dispatcher`)
composed by `emvs_stream` — `EMVSStreamEngine` for one camera,
`MultiStreamEngine` for N cameras with cross-stream coalescing of closed
segments into shared S buckets (latency / throughput / adaptive dispatch,
fifo / round_robin fairness)."""
