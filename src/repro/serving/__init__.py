"""Serving substrate: prefill/decode LM engine with continuous batching
(`engine`) and the streaming EMVS engine with double-buffered,
policy-scheduled segment dispatch (`emvs_stream`: latency / throughput /
adaptive coalescing of closed segments into S buckets)."""
