"""Serving substrate: prefill/decode engine with continuous batching."""
