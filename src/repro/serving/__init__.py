"""Serving substrate: prefill/decode LM engine with continuous batching
(`engine`) and the streaming EMVS engine with double-buffered segment
dispatch (`emvs_stream`)."""
