"""Shared dispatch layer of the streaming EMVS engine.

`SweepDispatcher` owns everything N camera sessions share on one
accelerator: the `(session, segment)`-tagged coalescing queue, the
dispatch policy (latency / throughput / adaptive) and fairness anchor
rule (fifo / round_robin), the double-buffered in-flight slots, the
bounded compiled-variant cache (via fixed S buckets and frame-capacity
buckets), and the batched/sharded sweep backends.

Sessions (`repro.serving.stream_session.StreamSession`) `enqueue` their
closed segments tagged with themselves; the dispatcher forms head groups
with `repro.core.pipeline.dispatch_group_head_tagged`, so
`pad_segments`-compatible segments from DIFFERENT sessions fill one S
bucket — the cross-stream coalescing that keeps the device saturated
when any single stream goes quiet. Grouping never changes a segment's
numbers (rows are gathered per session store by `pad_segment_rows` and
the per-segment sweep body is independent), so every session's results
stay bit-identical to a dedicated single-stream engine, under any
interleaving, policy, and fairness setting. Harvested rows are routed
back to their owning session's result stores; one session's `flush`
drains only its share of the queue (same-capacity neighbors may ride
along — legal for the same independence reason).
"""
from __future__ import annotations

from collections import deque
from time import perf_counter
from typing import NamedTuple

import jax

from repro.core import dsi as dsi_lib
from repro.core.camera import CameraModel
from repro.core.detection import DepthMap
from repro.core.dsi import DSIConfig
from repro.core.geometry import SE3
from repro.core.pipeline import (
    DispatchPlanner,
    EMVSOptions,
    SegmentResult,
    pad_segment_rows,
    process_segments_batched,
)
from repro.core.pointcloud import PointCloud, depth_maps_to_points
from repro.profiling.cost_table import VariantKey

Array = jax.Array

# Latency histogram bin edges (seconds): log-decade bins wide enough to
# cover a sub-millisecond warm CPU sweep and a multi-second cold compile.
_HIST_EDGES_S = (1e-4, 1e-3, 1e-2, 1e-1, 1.0, 10.0)


class _LatencyHist:
    """Fixed-log-bin latency histogram over (t_in, t_out) sample pairs.

    Beyond the usual count/total/max, it keeps the raw timestamp sums so
    consumers can verify the reconciliation identity
    ``total_s == t_out_sum - t_in_sum`` — the sum of waits IS the sum of
    dispatch timestamps minus the sum of enqueue timestamps (resp.
    harvest minus dispatch for sweep times), so a histogram that lost or
    double-counted a sample cannot satisfy it
    (tests/test_adaptive_dispatch.py asserts this on live engines).
    """

    def __init__(self) -> None:
        self.count = 0
        self.total_s = 0.0
        self.max_s = 0.0
        self.t_in_sum = 0.0
        self.t_out_sum = 0.0
        self.bins = [0] * (len(_HIST_EDGES_S) + 1)

    def observe(self, t_in: float, t_out: float) -> None:
        dt = t_out - t_in  # perf_counter is monotonic: never negative
        self.count += 1
        self.total_s += dt
        self.max_s = max(self.max_s, dt)
        self.t_in_sum += t_in
        self.t_out_sum += t_out
        i = 0
        while i < len(_HIST_EDGES_S) and dt >= _HIST_EDGES_S[i]:
            i += 1
        self.bins[i] += 1

    def snapshot(self) -> dict:
        return {"count": self.count, "total_s": self.total_s,
                "max_s": self.max_s, "t_in_sum": self.t_in_sum,
                "t_out_sum": self.t_out_sum,
                "bin_edges_s": list(_HIST_EDGES_S), "bins": list(self.bins)}


def enumerate_variant_space(stream_cfg, max_segment_frames: int, *,
                            mesh_segments: int = 1,
                            formulation: str = "matmul") -> dict:
    """Statically enumerate the dispatcher's compiled-variant space.

    Every sweep the dispatcher can stage has its entry shapes determined
    by exactly two numbers: the padded S bucket and the frame capacity.
    This reproduces the dispatcher's own bucket arithmetic (shard
    rounding for the sharded backend, `bucket_capacity` padding) as a
    pure function of config, so `repro.analysis`'s recompilation audit
    can verify the |S buckets| x |capacities| jit-cache bound without
    constructing an engine. Returns `{"s_buckets", "capacities",
    "variants", "backend"}` with `variants` the full (s_bucket, capacity)
    product and `backend` the cost-table backend axis value
    (`cost_table.backend_name`) the dispatcher would key these variants
    under — "batched+kernel" etc. for the non-default formulations.
    """
    from repro.profiling.cost_table import backend_name
    from repro.core.pipeline import bucket_capacity

    if max_segment_frames <= 0:
        raise ValueError("max_segment_frames must be positive")
    if stream_cfg.sweep == "sharded":
        n = max(1, int(mesh_segments))
        # must mirror SweepDispatcher.__init__'s shard rounding exactly
        s_buckets = tuple(sorted({-(-b // n) * n
                                  for b in stream_cfg.segment_buckets}))
    else:
        s_buckets = tuple(stream_cfg.segment_buckets)
    capacities = tuple(sorted({bucket_capacity(f)
                               for f in range(1, max_segment_frames + 1)}))
    variants = tuple((s, c) for s in s_buckets for c in capacities)
    return {"s_buckets": s_buckets, "capacities": capacities,
            "variants": variants,
            "backend": backend_name(stream_cfg.sweep, formulation)}


class _InFlight(NamedTuple):
    """One dispatched sweep: real segments + async device results.

    `owners[k]` is the session that owns `segs[k]` (rows of one sweep may
    belong to different sessions). `owners=None` — e.g. an entry staged
    by test stubs predating the session split — routes every row to the
    dispatcher's default (first-registered) session on harvest.
    """

    segs: list[tuple[int, int]]  # real (unpadded) segments, global indices
    ref_R: Array  # (S, 3, 3) including padded rows
    ref_t: Array  # (S, 3)
    dsis: Array
    dms: DepthMap
    pcs: PointCloud
    owners: tuple | None = None  # per-row owning sessions
    key: VariantKey | None = None  # compiled-variant identity of the sweep
    dispatched_t: float = 0.0  # host perf_counter at dispatch
    unshadowed: bool = False  # dispatched onto an otherwise idle device


class SweepDispatcher:
    """Shared segment-sweep scheduler for N streaming sessions.

    Construction mirrors the single-stream engine: the sharded backend
    rounds every S bucket up to a multiple of the mesh's segment-axis
    size so dispatch shapes stay shard-stable; the batched backend
    rejects a stray `mesh=`. `cam`, `dsi_cfg`, `opts` and `stream_cfg`
    are shared by every session on the dispatcher — one compiled sweep
    program per (S bucket, capacity) serves them all, which is exactly
    what makes cross-stream coalescing possible.
    """

    def __init__(self, cam: CameraModel, dsi_cfg: DSIConfig,
                 opts: EMVSOptions = EMVSOptions(),
                 stream_cfg=None, *, mesh=None, cost_model=None,
                 profiler=None):
        if stream_cfg is None:
            from repro.serving.emvs_stream import StreamConfig

            stream_cfg = StreamConfig()
        self.cam = cam
        self.dsi_cfg = dsi_cfg
        if getattr(stream_cfg, "kernel_interpret", None) is not None:
            # serving-level interpret/compiled override for the fused
            # kernel formulation; EMVSOptions stays the single source the
            # sweep body reads (and jit keys on — both are static/hashable)
            import dataclasses as _dc

            opts = _dc.replace(opts, kernel_interpret=stream_cfg.kernel_interpret)
        self.opts = opts
        self.stream_cfg = stream_cfg
        if stream_cfg.sweep == "sharded":
            from repro.distributed.emvs import (
                make_segment_mesh,
                segment_axis_size,
            )

            self.mesh = mesh if mesh is not None else make_segment_mesh()
            n = segment_axis_size(self.mesh)
            # shard-stable S buckets: every dispatch's segment axis must
            # divide the mesh, so round each bucket up to a multiple of n
            # (deduplicated, still ascending — the compiled-variant bound
            # only shrinks).
            self._segment_buckets = tuple(sorted(
                {-(-b // n) * n for b in stream_cfg.segment_buckets}))
        else:
            if mesh is not None:
                raise ValueError(
                    "mesh= is only meaningful with "
                    "StreamConfig(sweep='sharded'); the batched sweep "
                    "would silently ignore it")
            self.mesh = None
            self._segment_buckets = stream_cfg.segment_buckets
        # Cost-aware planning (docs/dispatch_planning.md): the planner
        # owns the partition rules; `cost_model` (duck-typed:
        # predict_sweep_s(key) -> float | None) lets the SLO-aware
        # adaptive policy predict queue-drain time, `profiler` (a
        # repro.profiling.SweepProfiler) opts into online cost-table
        # recording + dispatch-trace capture. Both default off — the
        # scheduler is then bitwise-identical to the pre-cost-model
        # engine.
        self.cost_model = cost_model
        self.profiler = profiler
        self.planner = DispatchPlanner(
            self._segment_buckets, cost_model=cost_model,
            variant_of=self._variant_key)
        self._sessions: list = []  # registration = round-robin order
        self._rr_cursor = 0
        self.default_owner = None  # harvest target for untagged in-flight
        # tagged coalescing queue: (session, (start, end)) in arrival order
        self._pending: list = []
        self._inflight: deque[_InFlight] = deque()
        # Counter invariants (asserted by tests/test_adaptive_dispatch.py
        # via the N=1 engine): segments == sum of dispatched group sizes;
        # coalesced_segments counts segments that left in a group of >= 2,
        # so segments == coalesced_segments + (dispatches -
        # coalesced_dispatches); pending_segments is the live tagged-queue
        # depth (0 after all sessions flush), max_pending its high-water
        # mark; cross_stream_dispatches counts groups whose rows span more
        # than one session — the coalescing the multi-tenant benchmark
        # gates on.
        # slo_dispatches / slo_holds count the SLO-aware adaptive
        # policy's decisions (0 unless target_latency_s + a cost model
        # are both active); queue_wait_s / sweep_time_s are _LatencyHist
        # snapshots (enqueue->dispatch per segment, dispatch->harvest
        # per sweep) refreshed on every observation.
        self._queue_wait_hist = _LatencyHist()
        self._sweep_time_hist = _LatencyHist()
        self._session_wait_hists: dict[int, _LatencyHist] = {}
        self._enqueued_t: dict[tuple[int, tuple[int, int]], float] = {}
        self.stats = {"segments": 0, "dispatches": 0, "padded_segments": 0,
                      "pending_segments": 0, "max_pending": 0,
                      "coalesced_dispatches": 0, "coalesced_segments": 0,
                      "cross_stream_dispatches": 0,
                      "slo_dispatches": 0, "slo_holds": 0,
                      "queue_wait_s": self._queue_wait_hist.snapshot(),
                      "sweep_time_s": self._sweep_time_hist.snapshot()}

    def _variant_key(self, s_bucket: int, capacity: int) -> VariantKey:
        """The compiled-variant identity of a padded dispatch shape —
        the cost table's key axes (repro.profiling.cost_table).

        The backend axis folds in the voting formulation
        (`backend_name`): "batched" is the default matmul program,
        "batched+kernel" the fused Pallas sweep, etc. — distinct compiled
        programs with very different costs, so the DispatchPlanner must
        price them separately."""
        from repro.profiling.cost_table import backend_name

        return VariantKey(
            s_bucket=s_bucket, capacity=capacity,
            backend=backend_name(self.stream_cfg.sweep,
                                 self.opts.formulation),
            interpolation=self.opts.voting,
            quantized=self.opts.quantized)

    # --- session plumbing -------------------------------------------------

    def register(self, session) -> None:
        self._sessions.append(session)
        if self.default_owner is None:
            self.default_owner = session
        # per-session queue-wait histogram, mirrored into session stats
        hist = _LatencyHist()
        self._session_wait_hists[id(session)] = hist
        session.stats["queue_wait_s"] = hist.snapshot()

    def enqueue(self, session, closed: list[tuple[int, int]]) -> None:
        """Append one session's newly closed segments to the tagged queue
        (arrival order; they dispatch on the next pump/drain)."""
        t = perf_counter()
        for seg in closed:
            self._enqueued_t[(id(session), seg)] = t
            if self.profiler is not None:
                self.profiler.note_enqueue(t, session, seg)
        self._pending.extend((session, seg) for seg in closed)
        self._note_queue_depth()

    def _note_queue_depth(self) -> None:
        d = len(self._pending)
        self.stats["pending_segments"] = d
        self.stats["max_pending"] = max(self.stats["max_pending"], d)

    def _oldest_pending_start(self, session) -> int | None:
        # per-session FIFO holds in the tagged queue, so a session's first
        # occurrence is its oldest queued segment
        for sess, (start, _) in self._pending:
            if sess is session:
                return start
        return None

    def _evict_all(self) -> None:
        # each session's retention window must cover its segments still
        # waiting in the shared queue, not just its planner's open
        # segment: a queued group references frames the planner already
        # moved past
        for sess in self._sessions:
            floor = self._oldest_pending_start(sess)
            if floor is None:
                floor = sess.planner.open_start
            sess._store.evict_before(floor)
            sess._sync_store_stats()

    def make_room(self, session, blocking: bool) -> bool:
        """Free retained frame-store bytes for `session`'s budget admission.

        Returns True when progress was made (bytes freed, or queued work
        dispatched so the next eviction can free them), False when no
        more room can be made — without blocking when `blocking` is
        False, or at all when True (everything dispatchable is
        dispatched and the store already sits at its retention floor:
        the planner's open segment, which may never be evicted — the
        PR 5 bug class this floor exists to prevent).

        Order of escalation: harvest device-completed sweeps and evict
        behind the floor (free); then dispatch the session's queued
        segments — dispatch stages its rows immediately, so each
        dispatched group RAISES the session's eviction floor past its
        segments; when the in-flight queue is full, dispatching means
        block-harvesting the oldest sweep first, which only the "stall"
        policy (blocking=True) may do."""
        before = session._store.live_bytes
        self._harvest_ready()
        self._evict_all()
        if session._store.live_bytes < before:
            return True
        while True:
            if len(self._inflight) >= self.stream_cfg.max_inflight:
                self._harvest_ready()  # a sweep may have completed by now
            if len(self._inflight) >= self.stream_cfg.max_inflight:
                # dispatching now would hit _dispatch's blocking
                # back-pressure on the oldest in-flight sweep
                if not blocking:
                    return False
                self._harvest(self._inflight.popleft(), block=True)
            group = self._pop_group(final=True, only=session)
            if group is None:
                return False
            self._dispatch(*group)
            self._note_queue_depth()
            self._evict_all()
            if session._store.live_bytes < before:
                return True
            # dispatched but nothing freed yet (the floor is still
            # pinned by further queued segments): keep dispatching

    # --- dispatch (double-buffered, policy- and fairness-scheduled) -------

    def pump(self) -> None:
        """One scheduler turn: harvest device-completed sweeps (routing
        results to their owning sessions), drain the tagged queue per the
        dispatch policy and fairness anchor rule, harvest again, evict."""
        self._harvest_ready()
        self._drain(final=False)
        self._harvest_ready()
        self._evict_all()

    def drain_session(self, session) -> None:
        """End of one session's stream: dispatch every queued segment of
        `session` (same-capacity segments of other sessions ride along),
        then block until all sweeps carrying its rows have harvested.
        Other sessions' queued work stays put."""
        while True:
            group = self._pop_group(final=True, only=session)
            if group is None:
                break
            self._dispatch(*group)
            self._note_queue_depth()
        self._evict_all()
        while any(inf.owners is None or session in inf.owners
                  for inf in self._inflight):
            self._harvest(self._inflight.popleft(), block=True)

    def _drain(self, final: bool) -> None:
        """Dispatch groups while the policy allows. With `final` every
        policy drains the whole queue — back-pressure blocking in
        `_dispatch` paces the device."""
        while self._pending:
            if not final:
                # harvest completed sweeps first: results surface sooner
                # and the freed slots un-deepen the in-flight queue the
                # adaptive policy reads
                self._harvest_ready()
            group = self._pop_group(final)
            if group is None:
                break
            self._dispatch(*group)
            self._note_queue_depth()
        self._evict_all()

    def _anchor_candidates(self, only) -> list:
        """Sessions eligible to anchor the next group, in try order."""
        if only is not None:
            return [only]
        if self.stream_cfg.fairness == "fifo" or len(self._sessions) == 1:
            # strict arrival order: only the global queue head ever anchors
            return [self._pending[0][0]]
        # round_robin: rotate over registered sessions, skipping those
        # with nothing queued; trying each once per turn means a session
        # whose anchored group is policy-held (unsealed throughput group)
        # does not head-of-line block a neighbor with a dispatchable one
        present = {id(sess) for sess, _ in self._pending}
        n = len(self._sessions)
        return [self._sessions[(self._rr_cursor + k) % n] for k in range(n)
                if id(self._sessions[(self._rr_cursor + k) % n]) in present]

    def _pop_group(self, final: bool, only=None):
        """Pop the next dispatchable group off the tagged queue, or None
        when the policy says to keep coalescing. Anchors follow the
        fairness rule; each anchored group obeys per-stream FIFO, so a
        session's results release in its segment-close order under every
        policy and fairness setting."""
        if not self._pending:
            return None
        policy = self.stream_cfg.dispatch_policy
        # SLO mode (docs/dispatch_planning.md): with a deadline AND a
        # cost model that can price the whole queue, the adaptive policy
        # schedules against predicted drain time instead of in-flight
        # depth — dispatch now iff draining everything (in-flight sweeps
        # + the planned partition of the pending queue) is predicted to
        # blow the deadline, else keep coalescing. `slo_urgent is None`
        # means SLO inactive (no deadline, null model, or an
        # out-of-distribution variant): fall back to the depth rule, so
        # the schedule is bitwise-identical to the pre-SLO engine.
        slo_urgent = None
        if policy == "adaptive" and not final:
            if self.stream_cfg.target_latency_s is not None:
                drain = self.predict_drain_s()
                if drain is not None:
                    slo_urgent = drain > self.stream_cfg.target_latency_s
            if (slo_urgent is None
                    and len(self._inflight) >= self.stream_cfg.max_inflight):
                return None  # device saturated: coalesce until a slot frees
        for sess in self._anchor_candidates(only):
            if only is not None and self._oldest_pending_start(sess) is None:
                return None  # the drained session has nothing queued
            anchor = next(i for i, (s, _) in enumerate(self._pending)
                          if s is sess)
            idx, cap, sealed = self.planner.head_tagged(
                self._pending, anchor=anchor)
            if policy == "latency":
                idx = idx[:1]  # one sweep per segment — the baseline
            elif policy == "throughput" and not (final or sealed):
                continue  # this anchor's group can still grow: try the next
            elif slo_urgent is not None and not (slo_urgent or sealed):
                # SLO slack and the group can still grow: hold it (a
                # sealed group gains nothing by waiting, so it goes)
                continue
            group = [self._pending[i] for i in idx]
            for i in reversed(idx):
                self._pending.pop(i)
            if self._sessions:
                # fairness bookkeeping: the dispatched session goes to the
                # back of the rotation
                try:
                    self._rr_cursor = ((self._sessions.index(sess) + 1)
                                       % len(self._sessions))
                except ValueError:
                    pass
            if slo_urgent:
                self.stats["slo_dispatches"] += 1
            return group, cap
        if slo_urgent is False:
            self.stats["slo_holds"] += 1
        return None

    def predict_drain_s(self) -> float | None:
        """Predicted serial time to complete every in-flight sweep and
        drain the whole pending queue under the cost model. In-flight
        sweeps count at full predicted cost (their progress is not
        observable without a device sync — the estimate is deliberately
        conservative). None when any component is unpredictable."""
        if self.cost_model is None:
            return None
        total = 0.0
        for inf in self._inflight:
            if inf.key is None:
                return None
            cost = self.cost_model.predict_sweep_s(inf.key)
            if cost is None:
                return None
            total += cost
        pending = self.planner.predict_drain_s(
            self._pending, fairness=self.stream_cfg.fairness)
        if pending is None:
            return None
        return total + pending

    def _s_bucket(self, n: int) -> int:
        for b in self._segment_buckets:
            if b >= n:
                return b
        raise AssertionError(f"group of {n} exceeds top segment bucket")

    def variant_space(self, max_segment_frames: int) -> dict:
        """The live dispatcher's compiled-variant space (see
        `enumerate_variant_space`), using the actual mesh segment-axis
        size when the sharded backend is active."""
        if self.mesh is not None:
            from repro.distributed.emvs import segment_axis_size
            mesh_segments = segment_axis_size(self.mesh)
        else:
            mesh_segments = 1
        return enumerate_variant_space(self.stream_cfg, max_segment_frames,
                                       mesh_segments=mesh_segments)

    def _sweep(self, batch) -> tuple[Array, DepthMap]:
        if self.stream_cfg.sweep == "sharded":
            from repro.distributed.emvs import process_segments_sharded

            return process_segments_sharded(self.cam, self.dsi_cfg, batch,
                                            self.opts, mesh=self.mesh)
        return process_segments_batched(self.cam, self.dsi_cfg, batch,
                                        self.opts)

    def _dispatch(self, group, cap: int) -> None:
        """Stage and asynchronously dispatch one tagged group: gather each
        row from its owning session's frame store, pad the segment axis to
        the smallest fitting S bucket, enqueue the sweep."""
        # groups are only formed from non-empty closed-segment runs, so an
        # empty dispatch is a planner/grouping bug, not a stream condition
        # — and pad_segment_rows would reject it anyway.
        assert group, "_dispatch requires at least one closed segment"
        s_pad = self._s_bucket(len(group))
        # padded rows repeat the last real segment: the sweep body is
        # per-segment independent, so they are pure discarded work
        padded = list(group) + [group[-1]] * (s_pad - len(group))
        rows = [(sess._store.window(start, end), (0, end - start))
                for sess, (start, end) in padded]
        batch = pad_segment_rows(rows, cap)
        # async dispatch: both calls below return with the sweep enqueued,
        # so the caller stages the next batch while this one votes
        unshadowed = not self._inflight  # nothing older occupies the device
        t_disp = perf_counter()
        key = self._variant_key(s_pad, cap)
        for sess, seg in group:
            t_enq = self._enqueued_t.pop((id(sess), seg), None)
            if t_enq is not None:
                self._queue_wait_hist.observe(t_enq, t_disp)
                sess_hist = self._session_wait_hists.get(id(sess))
                if sess_hist is not None:
                    sess_hist.observe(t_enq, t_disp)
                    sess.stats["queue_wait_s"] = sess_hist.snapshot()
        self.stats["queue_wait_s"] = self._queue_wait_hist.snapshot()
        if self.profiler is not None:
            self.profiler.note_dispatch(t_disp, group, key)
        dsis, dms = self._sweep(batch)
        pcs = depth_maps_to_points(self.cam, dms, SE3(batch.ref_R, batch.ref_t))
        self._inflight.append(_InFlight(
            [seg for _, seg in group], batch.ref_R, batch.ref_t, dsis, dms,
            pcs, owners=tuple(sess for sess, _ in group), key=key,
            dispatched_t=t_disp, unshadowed=unshadowed))
        self.stats["segments"] += len(group)
        self.stats["dispatches"] += 1
        self.stats["padded_segments"] += s_pad - len(group)
        if len(group) > 1:
            self.stats["coalesced_dispatches"] += 1
            self.stats["coalesced_segments"] += len(group)
        if len({id(sess) for sess, _ in group}) > 1:
            self.stats["cross_stream_dispatches"] += 1
        for sess, _ in group:
            sess.stats["segments"] += 1
        while len(self._inflight) > self.stream_cfg.max_inflight:
            # back-pressure: block on the oldest sweep; its results are
            # routed for the owning sessions' next poll
            self._harvest(self._inflight.popleft(), block=True)

    # --- harvest ----------------------------------------------------------

    def _harvest_ready(self) -> None:
        """Pop and harvest every device-completed sweep at the head of the
        in-flight queue (non-blocking, dispatch order)."""
        while self._inflight and self._inflight[0].dms.depth.is_ready():
            self._harvest(self._inflight.popleft(), block=False)

    def _harvest(self, inf: _InFlight, block: bool) -> None:
        if block:
            inf.dms.depth.block_until_ready()
        t_harv = perf_counter()
        if inf.key is not None:
            self._sweep_time_hist.observe(inf.dispatched_t, t_harv)
            self.stats["sweep_time_s"] = self._sweep_time_hist.snapshot()
            if self.profiler is not None:
                self.profiler.note_harvest(
                    inf.key, inf.dispatched_t, t_harv,
                    unshadowed=inf.unshadowed)
        owners = inf.owners
        if owners is None:
            owners = (self.default_owner,) * len(inf.segs)
        for k, ((start, end), sess) in enumerate(zip(inf.segs, owners)):
            # per-segment fraction of DSI voxels at the int16 store limits,
            # feeding the owning session's "dsi_saturation_peak" monitor
            # (the live check of the paper's "16 bits never saturate"
            # claim). Computed on results that are already device-complete,
            # so this adds one tiny reduction, not a per-chunk round-trip.
            sat = float(dsi_lib.store_saturation_fraction(inf.dsis[k]))
            sess.stats["dsi_saturation_peak"] = max(
                sess.stats.get("dsi_saturation_peak", 0.0), sat)
            dm = DepthMap(inf.dms.depth[k], inf.dms.mask[k],
                          inf.dms.confidence[k])
            res = SegmentResult(dm, inf.dsis[k],
                                SE3(inf.ref_R[k], inf.ref_t[k]), (start, end))
            pc = PointCloud(inf.pcs.points[k], inf.pcs.weights[k],
                            inf.pcs.valid[k])
            sess._done[(start, end)] = (res, pc)
            sess._fresh.append(res)
