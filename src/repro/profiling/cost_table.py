"""Persisted, schema-versioned table of measured sweep wall times.

One table entry aggregates warm wall-time observations for one compiled
sweep variant.  The key axes mirror the jit-cache axes exactly
(``enumerate_variant_space``): segment bucket, frame capacity, sweep
backend, plus the datapath flags that select a distinct program
(interpolation, quantized).  Writes are atomic (tempfile + ``os.replace``)
like ``benchmarks/_emvs_common.update_bench_json`` so a crashed recorder
can never leave a torn table behind.
"""

from __future__ import annotations

import json
import os
import tempfile
from dataclasses import dataclass

COST_TABLE_SCHEMA_VERSION = 1

COST_TABLE_JSON = "cost_table.json"

# The backend axis is "{sweep}" for the default matmul formulation and
# "{sweep}+{formulation}" otherwise — the voting formulation selects a
# distinct compiled program (the fused Pallas kernel most importantly),
# so it must be a cost-table key axis or the DispatchPlanner would price
# the fused kernel sweep with matmul-sweep timings.
_SWEEPS = ("batched", "sharded")
_FORMULATION_SUFFIXES = ("", "+scatter", "+kernel")
_BACKENDS = tuple(s + f for s in _SWEEPS for f in _FORMULATION_SUFFIXES)
_INTERPOLATIONS = ("nearest", "bilinear")


def backend_name(sweep: str, formulation: str = "matmul") -> str:
    """Canonical VariantKey.backend for a (sweep, formulation) pair."""
    if sweep not in _SWEEPS:
        raise CostTableError(f"sweep must be one of {_SWEEPS}, got {sweep!r}")
    if formulation == "matmul":
        return sweep
    name = f"{sweep}+{formulation}"
    if name not in _BACKENDS:
        raise CostTableError(
            f"unknown formulation {formulation!r} (no backend {name!r})")
    return name


class CostTableError(ValueError):
    """A cost-table payload violates the schema."""


@dataclass(frozen=True)
class VariantKey:
    """Identity of one compiled sweep variant.

    The tuple of axes is exactly the jit-cache identity of a sweep
    program plus the datapath flags: two dispatches with equal keys hit
    the same compiled executable, so their warm wall times are samples
    of the same cost.
    """

    s_bucket: int
    capacity: int
    backend: str
    interpolation: str
    quantized: bool

    def __post_init__(self) -> None:
        if self.s_bucket < 1:
            raise CostTableError(f"s_bucket must be >= 1, got {self.s_bucket}")
        if self.capacity < 1:
            raise CostTableError(f"capacity must be >= 1, got {self.capacity}")
        if self.backend not in _BACKENDS:
            raise CostTableError(
                f"backend must be one of {_BACKENDS}, got {self.backend!r}"
            )
        if self.interpolation not in _INTERPOLATIONS:
            raise CostTableError(
                f"interpolation must be one of {_INTERPOLATIONS}, "
                f"got {self.interpolation!r}"
            )

    @property
    def rows(self) -> int:
        """Padded segment-rows of work this variant sweeps per dispatch."""
        return self.s_bucket * self.capacity

    def to_str(self) -> str:
        q = "q1" if self.quantized else "q0"
        return (
            f"s{self.s_bucket}/c{self.capacity}/{self.backend}/"
            f"{self.interpolation}/{q}"
        )

    @classmethod
    def from_str(cls, text: str) -> "VariantKey":
        parts = text.split("/")
        if len(parts) != 5 or not parts[0].startswith("s") or not parts[1].startswith("c"):
            raise CostTableError(f"malformed variant key {text!r}")
        s_part, c_part, backend, interpolation, q_part = parts
        if q_part not in ("q0", "q1"):
            raise CostTableError(f"malformed quantized flag in key {text!r}")
        try:
            s_bucket = int(s_part[1:])
            capacity = int(c_part[1:])
        except ValueError as exc:
            raise CostTableError(f"malformed variant key {text!r}") from exc
        return cls(
            s_bucket=s_bucket,
            capacity=capacity,
            backend=backend,
            interpolation=interpolation,
            quantized=(q_part == "q1"),
        )


@dataclass
class _Entry:
    """Aggregated warm wall-time samples for one variant."""

    count: int = 0
    mean_s: float = 0.0
    min_s: float = float("inf")
    max_s: float = 0.0

    def observe(self, wall_s: float) -> None:
        self.count += 1
        # running mean keeps the table append-only under merge
        self.mean_s += (wall_s - self.mean_s) / self.count
        self.min_s = min(self.min_s, wall_s)
        self.max_s = max(self.max_s, wall_s)

    def to_json(self) -> dict:
        return {
            "count": self.count,
            "mean_s": self.mean_s,
            "min_s": self.min_s,
            "max_s": self.max_s,
        }

    @classmethod
    def from_json(cls, payload: dict, *, key: str) -> "_Entry":
        if not isinstance(payload, dict):
            raise CostTableError(f"entry for {key!r} is not an object")
        missing = {"count", "mean_s", "min_s", "max_s"} - payload.keys()
        if missing:
            raise CostTableError(
                f"entry for {key!r} missing fields {sorted(missing)}"
            )
        count = payload["count"]
        if not isinstance(count, int) or count < 1:
            raise CostTableError(
                f"entry for {key!r} has invalid count {count!r}"
            )
        stats = {}
        for field in ("mean_s", "min_s", "max_s"):
            val = payload[field]
            if not isinstance(val, (int, float)) or isinstance(val, bool) or val < 0:
                raise CostTableError(
                    f"entry for {key!r} has invalid {field} {val!r}"
                )
            stats[field] = float(val)
        if not stats["min_s"] <= stats["mean_s"] <= stats["max_s"]:
            raise CostTableError(
                f"entry for {key!r} violates min <= mean <= max: {stats}"
            )
        return cls(count=count, mean_s=stats["mean_s"],
                   min_s=stats["min_s"], max_s=stats["max_s"])


class CostTable:
    """Warm sweep wall times keyed by :class:`VariantKey`.

    The table is a measurement artifact, not config: benchmarks and the
    opt-in :class:`~repro.profiling.recorder.SweepProfiler` populate it,
    ``python -m repro.profiling.calibrate`` fits a model from it, and CI
    validates its schema without ever executing a sweep.
    """

    def __init__(self) -> None:
        self._entries: dict[VariantKey, _Entry] = {}

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: VariantKey) -> bool:
        return key in self._entries

    def keys(self):
        return self._entries.keys()

    def record(self, key: VariantKey, wall_s: float) -> None:
        if wall_s < 0:
            raise CostTableError(f"negative wall time {wall_s!r}")
        self._entries.setdefault(key, _Entry()).observe(float(wall_s))

    def mean_s(self, key: VariantKey) -> float | None:
        entry = self._entries.get(key)
        return entry.mean_s if entry is not None else None

    def entry_stats(self, key: VariantKey) -> dict | None:
        entry = self._entries.get(key)
        return entry.to_json() if entry is not None else None

    def to_json(self) -> dict:
        return {
            "schema_version": COST_TABLE_SCHEMA_VERSION,
            "entries": {
                key.to_str(): entry.to_json()
                for key, entry in sorted(
                    self._entries.items(), key=lambda kv: kv[0].to_str()
                )
            },
        }

    @classmethod
    def from_json(cls, payload: dict) -> "CostTable":
        if not isinstance(payload, dict):
            raise CostTableError("cost table payload is not an object")
        version = payload.get("schema_version")
        if version != COST_TABLE_SCHEMA_VERSION:
            raise CostTableError(
                f"unsupported cost-table schema version {version!r} "
                f"(expected {COST_TABLE_SCHEMA_VERSION})"
            )
        entries = payload.get("entries")
        if not isinstance(entries, dict):
            raise CostTableError("cost table 'entries' is not an object")
        table = cls()
        for key_str, entry_payload in entries.items():
            key = VariantKey.from_str(key_str)
            table._entries[key] = _Entry.from_json(entry_payload, key=key_str)
        return table

    def save(self, path: str) -> None:
        """Atomically persist the table (tempfile + ``os.replace``)."""
        payload = self.to_json()
        directory = os.path.dirname(os.path.abspath(path)) or "."
        fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as fh:
                json.dump(payload, fh, indent=2, sort_keys=True)
                fh.write("\n")
            os.replace(tmp, path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise

    @classmethod
    def load(cls, path: str) -> "CostTable":
        with open(path) as fh:
            return cls.from_json(json.load(fh))

    def merge(self, other: "CostTable") -> None:
        """Fold another table's samples into this one (count-weighted)."""
        for key, entry in other._entries.items():
            mine = self._entries.get(key)
            if mine is None:
                self._entries[key] = _Entry(
                    count=entry.count, mean_s=entry.mean_s,
                    min_s=entry.min_s, max_s=entry.max_s,
                )
            else:
                total = mine.count + entry.count
                mine.mean_s = (
                    mine.mean_s * mine.count + entry.mean_s * entry.count
                ) / total
                mine.count = total
                mine.min_s = min(mine.min_s, entry.min_s)
                mine.max_s = max(mine.max_s, entry.max_s)
