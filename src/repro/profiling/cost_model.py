"""Cost models consumed by the dispatch planner.

A cost model is anything with a ``predict_sweep_s(key) -> float | None``
method (duck-typed so :mod:`repro.core.pipeline` never imports this
package).  ``None`` means "no prediction" — the planner then falls back
to the pre-cost-model heuristics, which is exactly how the null model
preserves bitwise-identical schedules.

The affine model is per-backend: a sweep's wall time is modeled as a
fixed dispatch overhead plus a per-segment-row rate,

    cost(key) ~= overhead[backend] + rate[backend] * s_bucket * capacity

which matches how the padded ``lax.map`` / ``shard_map`` programs scale
(every padded row back-projects the same number of planes regardless of
real occupancy).  The table model prefers the measured mean when the
exact variant was profiled and falls back to the affine fit otherwise.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.profiling.cost_table import CostTable, VariantKey


class NullCostModel:
    """Predicts nothing: the planner keeps its pre-cost-model behavior."""

    def predict_sweep_s(self, key: VariantKey) -> float | None:
        return None

    def to_json(self) -> dict:
        return {"kind": "null"}


@dataclass(frozen=True)
class AffineCostModel:
    """Per-backend affine fit: ``overhead_b + rate_b * rows``."""

    # backend -> (overhead_s, rate_s_per_row)
    params: dict[str, tuple[float, float]] = field(default_factory=dict)

    def predict_sweep_s(self, key: VariantKey) -> float | None:
        fit = self.params.get(key.backend)
        if fit is None:
            return None
        overhead, rate = fit
        # a fit can extrapolate below zero outside its support; a sweep
        # can never take negative time
        return max(0.0, overhead + rate * key.rows)

    def to_json(self) -> dict:
        return {
            "kind": "affine",
            "params": {
                backend: {"overhead_s": overhead, "rate_s_per_row": rate}
                for backend, (overhead, rate) in sorted(self.params.items())
            },
        }


@dataclass(frozen=True)
class TableCostModel:
    """Measured lookup with affine fallback for out-of-distribution keys."""

    table: CostTable
    fallback: AffineCostModel

    def predict_sweep_s(self, key: VariantKey) -> float | None:
        measured = self.table.mean_s(key)
        if measured is not None:
            return measured
        return self.fallback.predict_sweep_s(key)

    def to_json(self) -> dict:
        return {
            "kind": "table",
            "entries": len(self.table),
            "fallback": self.fallback.to_json(),
        }


def _lstsq_affine(points: list[tuple[float, float]]) -> tuple[float, float]:
    """Least-squares fit of ``y = a + b*x`` without importing numpy.

    The normal equations for a 2-parameter fit are closed-form; keeping
    this dependency-free lets the calibration CLI run in CI legs that
    only need schema validation.
    """
    n = len(points)
    sx = sum(x for x, _ in points)
    sy = sum(y for _, y in points)
    sxx = sum(x * x for x, _ in points)
    sxy = sum(x * y for x, y in points)
    denom = n * sxx - sx * sx
    if denom == 0:
        # all rows equal: degenerate — model it as pure overhead
        return (sy / n, 0.0)
    b = (n * sxy - sx * sy) / denom
    a = (sy - b * sx) / n
    return (a, b)


def fit_affine_model(table: CostTable) -> tuple[AffineCostModel, dict]:
    """Fit the per-backend affine model and report calibration error.

    Returns ``(model, report)`` where ``report`` carries, per backend,
    the fitted parameters, sample count, and the mean / max relative
    error of the fit against the measured means it was fitted on.
    """
    by_backend: dict[str, list[tuple[VariantKey, float]]] = {}
    for key in table.keys():
        mean = table.mean_s(key)
        if mean is not None:
            by_backend.setdefault(key.backend, []).append((key, mean))

    params: dict[str, tuple[float, float]] = {}
    report: dict = {"backends": {}}
    for backend, samples in sorted(by_backend.items()):
        points = [(float(key.rows), mean) for key, mean in samples]
        overhead, rate = _lstsq_affine(points)
        params[backend] = (overhead, rate)
        rel_errors = []
        for key, mean in samples:
            pred = max(0.0, overhead + rate * key.rows)
            if mean > 0:
                rel_errors.append(abs(pred - mean) / mean)
        report["backends"][backend] = {
            "overhead_s": overhead,
            "rate_s_per_row": rate,
            "variants": len(samples),
            "mean_rel_error": (
                sum(rel_errors) / len(rel_errors) if rel_errors else 0.0
            ),
            "max_rel_error": max(rel_errors) if rel_errors else 0.0,
        }
    model = AffineCostModel(params=params)
    report["model"] = model.to_json()
    return model, report


def model_from_table(table: CostTable) -> TableCostModel:
    """Convenience: measured-table model with a freshly fitted fallback."""
    fallback, _ = fit_affine_model(table)
    return TableCostModel(table=table, fallback=fallback)
