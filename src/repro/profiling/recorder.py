"""Opt-in online profiler for `SweepDispatcher`.

The dispatcher calls three hooks — enqueue, dispatch, harvest — and the
profiler turns them into (a) warm per-variant wall-time samples for the
cost table and (b) a deterministic dispatch trace
(:class:`TraceArrival` / :class:`TraceDispatch`) that
:mod:`repro.serving.dispatch_replay` re-simulates against a cost model.

Wall times are harvested-minus-dispatched host timestamps, which is the
honest observable for an async sweep: it includes device queueing, so
the profiler only records a sample when the sweep was at the head of
the in-flight queue with the device otherwise idle ("unshadowed"), and
skips the first observation of each variant (cold compile).
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.profiling.cost_table import CostTable, VariantKey


@dataclass(frozen=True)
class TraceArrival:
    """One segment joining the tagged queue, in virtual arrival order."""

    t: float            # host timestamp (perf_counter) of enqueue
    tag: int            # stable session index
    seg: tuple[int, int]


@dataclass(frozen=True)
class TraceDispatch:
    """One dispatched group as the scheduler formed it."""

    t: float                      # host timestamp of dispatch
    segs: tuple[tuple[int, tuple[int, int]], ...]  # (tag, seg) rows
    key: VariantKey


class SweepProfiler:
    """Collects cost-table samples and the dispatch trace.

    Attach one to a dispatcher via ``dispatcher.profiler = profiler``
    (or ``MultiStreamEngine(..., profiler=)``); detached operation is
    zero-cost — the dispatcher's hook sites guard on ``profiler is
    None``.
    """

    def __init__(self, table: CostTable | None = None):
        self.table = table if table is not None else CostTable()
        self.arrivals: list[TraceArrival] = []
        self.dispatches: list[TraceDispatch] = []
        self._seen_variants: set[VariantKey] = set()
        self._tags: dict[int, int] = {}  # id(session) -> stable index
        self.skipped_cold = 0
        self.skipped_shadowed = 0

    def _tag(self, session) -> int:
        return self._tags.setdefault(id(session), len(self._tags))

    # --- dispatcher hooks -------------------------------------------------

    def note_enqueue(self, t: float, session, seg: tuple[int, int]) -> None:
        self.arrivals.append(TraceArrival(t=t, tag=self._tag(session), seg=seg))

    def note_dispatch(self, t: float, group, key: VariantKey) -> None:
        self.dispatches.append(TraceDispatch(
            t=t,
            segs=tuple((self._tag(sess), seg) for sess, seg in group),
            key=key,
        ))

    def note_harvest(self, key: VariantKey, dispatched_t: float,
                     harvested_t: float, *, unshadowed: bool) -> None:
        """Record one completed sweep's wall time.

        `unshadowed` means the sweep ran with no older sweep occupying
        the device (it was the in-flight head for its whole life), so
        harvest - dispatch measures the sweep itself rather than queue
        wait. The first observation per variant is the cold compile and
        is skipped.
        """
        if not unshadowed:
            self.skipped_shadowed += 1
            return
        if key not in self._seen_variants:
            self._seen_variants.add(key)
            self.skipped_cold += 1
            return
        self.table.record(key, max(0.0, harvested_t - dispatched_t))

    # --- export -----------------------------------------------------------

    def trace_json(self) -> dict:
        """The recorded trace in the replayer's input format."""
        t0 = self.arrivals[0].t if self.arrivals else 0.0
        return {
            "arrivals": [
                {"t": a.t - t0, "tag": a.tag, "seg": list(a.seg)}
                for a in self.arrivals
            ],
            "dispatches": [
                {
                    "t": d.t - t0,
                    "key": d.key.to_str(),
                    "segs": [[tag, list(seg)] for tag, seg in d.segs],
                }
                for d in self.dispatches
            ],
        }
