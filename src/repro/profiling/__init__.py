"""Profile-then-plan support for dispatch planning.

The package closes the ROADMAP's "measured cost model for dispatch
planning" loop in three pieces:

- :mod:`repro.profiling.cost_table` — a persisted, schema-versioned
  table of warm per-variant sweep wall times, keyed by
  ``(s_bucket, capacity, backend, interpolation, quantized)``.
- :mod:`repro.profiling.cost_model` — cost models consumed by the
  planner: a null model (pre-cost-model behavior), an affine
  per-backend fit (dispatch overhead + per-segment-row cost), and a
  measured-table lookup that falls back to the affine fit when a key
  is out of distribution.
- :mod:`repro.profiling.recorder` — an opt-in online recorder wired
  into ``SweepDispatcher`` that feeds the table from live traffic and
  captures the dispatch trace the replayer
  (:mod:`repro.serving.dispatch_replay`) re-simulates.

``python -m repro.profiling.calibrate`` fits the model from a table
and emits a calibration report; see docs/dispatch_planning.md.
"""

from repro.profiling.cost_table import (
    COST_TABLE_SCHEMA_VERSION,
    CostTable,
    CostTableError,
    VariantKey,
)
from repro.profiling.cost_model import (
    AffineCostModel,
    NullCostModel,
    TableCostModel,
    fit_affine_model,
)
from repro.profiling.recorder import SweepProfiler

__all__ = [
    "COST_TABLE_SCHEMA_VERSION",
    "CostTable",
    "CostTableError",
    "VariantKey",
    "AffineCostModel",
    "NullCostModel",
    "TableCostModel",
    "fit_affine_model",
    "SweepProfiler",
]
