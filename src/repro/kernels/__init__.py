"""Pallas TPU kernels for the compute hot spots.

Each kernel package ships three files:
  kernel.py — pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper (padding, layout, dtype plumbing)
  ref.py    — pure-jnp oracle; tests assert allclose across shape/dtype sweeps

Kernels:
  backproject_vote — the paper's P(Z0->Zi)+G+V fused (Proportional
                     Projection Module): one-hot matmul voting on the MXU.
  local_max        — scene-structure detection (D): fused max/argmax-over-z
                     + sub-voxel parabola refinement.
  flash_attention  — blockwise online-softmax attention for the LM
                     substrate (train + prefill long-seq path).
"""
