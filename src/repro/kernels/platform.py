"""Single decision point for interpret-vs-compiled Pallas execution.

Every Pallas wrapper in `repro.kernels` takes `interpret: bool | None`
and resolves it HERE, so exactly one place in the tree decides whether a
kernel runs compiled (Mosaic/Triton lowering on TPU/GPU) or under the
Pallas interpreter (everywhere else, e.g. the CPU CI leg):

  * `interpret=None`  — capability-probed default: compiled on TPU/GPU,
    interpreter fallback elsewhere. This is what production call sites
    (`sweep_segment_batch`, the streaming dispatcher) pass through from
    `EMVSOptions.kernel_interpret` / `StreamConfig.kernel_interpret`.
  * `interpret=True`  — force the interpreter (tests pin this for
    bitwise interpret-vs-compiled parity checks).
  * `interpret=False` — force the compiled kernel; raises `ValueError`
    on a platform without a Pallas compile path rather than silently
    falling back to the interpreter, so a serving config that *believes*
    it is running the fused compiled kernel cannot quietly run the
    ~100x-slower interpreted one.
"""
from __future__ import annotations

import jax

# Backends with a Pallas compile path (Mosaic on TPU, Triton on GPU).
_COMPILED_BACKENDS = ("tpu", "gpu")


def compiled_kernels_supported() -> bool:
    """True iff the default JAX backend can lower `pallas_call` natively."""
    return jax.default_backend() in _COMPILED_BACKENDS


def resolve_interpret(interpret: bool | None) -> bool:
    """Resolve a tri-state `interpret` knob to the concrete pallas flag.

    None  -> probed default (compiled where supported, else interpreter)
    True  -> interpreter, always
    False -> compiled; ValueError if the platform cannot compile Pallas
    """
    if interpret is None:
        return not compiled_kernels_supported()
    if interpret is False and not compiled_kernels_supported():
        raise ValueError(
            "interpret=False requests the compiled Pallas kernel, but the "
            f"active JAX backend {jax.default_backend()!r} has no Pallas "
            "compile path (supported: tpu, gpu). Pass interpret=None for "
            "the capability-probed default or interpret=True to force the "
            "interpreter."
        )
    return bool(interpret)
