"""Public wrapper: (B, H, S, D) layout + GQA plumbing for the flash kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas

Array = jax.Array


@partial(jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention(
    q: Array,  # (B, Hq, Sq, D)
    k: Array,  # (B, Hkv, Skv, D)
    v: Array,
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
) -> Array:
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    assert hq % hkv == 0
    g = hq // hkv
    out = flash_attention_pallas(
        q.reshape(b * hq, sq, d),
        k.reshape(b * hkv, skv, d),
        v.reshape(b * hkv, skv, d),
        causal=causal,
        block_q=block_q,
        block_k=block_k,
        q_per_kv=g,
        interpret=interpret,
    )
    return out.reshape(b, hq, sq, d)
