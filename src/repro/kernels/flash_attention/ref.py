"""Pure-jnp oracle: causal (optionally GQA) attention, fp32 softmax."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

Array = jax.Array


@partial(jax.jit, static_argnames=("causal",))
def attention_ref(q: Array, k: Array, v: Array, *, causal: bool = True) -> Array:
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D); Hq % Hkv == 0.

    Returns (B, Hq, Sq, D) in q.dtype. Softmax in fp32.
    """
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    g = hq // hkv
    k = jnp.repeat(k, g, axis=1)
    v = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / jnp.sqrt(jnp.float32(d))
    if causal:
        qpos = jnp.arange(sq)[:, None] + (skv - sq)
        kpos = jnp.arange(skv)[None, :]
        s = jnp.where(kpos <= qpos, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
