"""Pallas TPU flash attention (forward): online-softmax over KV blocks.

Grid: (B*Hq, Sq/BQ, Skv/BK) with the KV axis innermost; the (m, l, acc)
running statistics live in VMEM scratch and persist across KV steps —
the same accumulate-while-resident pattern as the backproject_vote
kernel's DSI block (and the FPGA's Buf_V double buffering).

Causal blocks that are entirely above the diagonal are skipped with
pl.when (no MXU work issued). GQA is handled by index-mapping the KV
block to `bh // q_per_kv` — queries in a group share the KV stream, so
no KV duplication in HBM or VMEM.

Used for serving/prefill forward. Training uses the differentiable
blockwise-jnp path in `repro.models.attention` (same math; autodiff).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.platform import resolve_interpret

Array = jax.Array

NEG_INF = -1e30


def _kernel(
    q_ref,  # (1, BQ, D)
    k_ref,  # (1, BK, D)
    v_ref,  # (1, BK, D)
    o_ref,  # (1, BQ, D)
    m_ref,  # scratch (BQ, STATS)
    l_ref,  # scratch (BQ, STATS)
    acc_ref,  # scratch (BQ, D)
    *,
    scale: float,
    causal: bool,
    bq: int,
    bk: int,
    num_kv_blocks: int,
    q_offset: int,
):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal: query global pos = qi*bq + r + q_offset; kv pos = kj*bk + c.
    # block fully masked iff smallest qpos < largest kvpos strictly below
    # diagonal for ALL pairs: qi*bq + q_offset + (bq-1) < kj*bk
    run = True
    if causal:
        run = qi * bq + q_offset + (bq - 1) >= kj * bk

    @pl.when(run)
    def _update():
        q = q_ref[0].astype(jnp.float32)  # (BQ, D)
        k = k_ref[0].astype(jnp.float32)  # (BK, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (BQ, BK)
        if causal:
            qpos = qi * bq + q_offset + jax.lax.broadcasted_iota(
                jnp.int32, (bq, bk), 0)
            kpos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[:, 0:1]  # (BQ, 1)
        l_prev = l_ref[:, 0:1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)  # (BQ, BK)
        corr = jnp.exp(m_prev - m_new)  # (BQ, 1)
        l_new = corr * l_prev + jnp.sum(p, axis=1, keepdims=True)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )  # (BQ, D)
        acc_ref[...] = acc_ref[...] * corr + pv
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(kj == num_kv_blocks - 1)
    def _finalize():
        l = l_ref[:, 0:1]
        o_ref[0] = (acc_ref[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "q_per_kv", "interpret"),
)
def flash_attention_pallas(
    q: Array,  # (BHq, Sq, D)
    k: Array,  # (BHkv, Skv, D)
    v: Array,  # (BHkv, Skv, D)
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    q_per_kv: int = 1,
    interpret: bool | None = None,
) -> Array:
    bh, sq, d = q.shape
    skv = k.shape[1]
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    assert sq % bq == 0 and skv % bk == 0, (sq, skv, bq, bk)
    grid = (bh, sq // bq, skv // bk)
    q_offset = skv - sq  # decode/prefill alignment (q block ends at kv end)
    stats = 128  # lane-width scratch for (m, l)

    kern = functools.partial(
        _kernel,
        scale=1.0 / (d ** 0.5),
        causal=causal,
        bq=bq,
        bk=bk,
        num_kv_blocks=skv // bk,
        q_offset=q_offset,
    )
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, qi, kj: (b, qi, 0)),
            pl.BlockSpec((1, bk, d), lambda b, qi, kj, g=q_per_kv: (b // g, kj, 0)),
            pl.BlockSpec((1, bk, d), lambda b, qi, kj, g=q_per_kv: (b // g, kj, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, qi, kj: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, stats), jnp.float32),
            pltpu.VMEM((bq, stats), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=resolve_interpret(interpret),
    )(q, k, v)
