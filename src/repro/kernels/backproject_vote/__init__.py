from repro.kernels.backproject_vote.ops import backproject_vote, backproject_vote_frames  # noqa: F401
