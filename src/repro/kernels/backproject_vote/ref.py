"""Pure-jnp oracle for the fused backproject+vote kernel.

Semantics: given canonical-plane event coords xy0 (F, E, 2), validity
(F, E), and per-frame plane-sweep coefficients phi (F, Nz, 3) =
(alpha, beta_x, beta_y), produce the DSI (Nz, h, w):

    x_i = alpha[z] * (x0 - cx) + beta_x[z] + cx
    y_i = alpha[z] * (y0 - cy) + beta_y[z] + cy
    DSI[z] += sum_e onehot(y_i[e]) ⊗ onehot(x_i[e])     (nearest)
    DSI[z] += sum_e twohot(y_i[e]) ⊗ twohot(x_i[e])     (bilinear)

with out-of-bounds projections dropped (bounds are the *logical* w, h,
not the padded kernel tile).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

Array = jax.Array


@partial(jax.jit, static_argnames=("w", "h", "mode", "quantize_plane_coords"))
def backproject_vote_ref(
    xy0: Array,  # (F, E, 2) float32 canonical coords
    valid: Array,  # (F, E) bool or float
    phi: Array,  # (F, Nz, 3) float32: alpha, beta_x, beta_y
    *,
    cx: float,
    cy: float,
    w: int,
    h: int,
    mode: str = "nearest",
    quantize_plane_coords: bool = False,
) -> Array:
    """`quantize_plane_coords` applies the Table-1 int8 plane-coord
    contract (via the policy object itself, NOT the kernel's in-body
    replica — so kernel-vs-ref tests cross-check the two
    implementations) before the vote sanitize, mirroring the quantized
    nearest datapath of `pipeline.project_frame`."""
    F, E, _ = xy0.shape
    nz = phi.shape[1]

    def frame(dsi, inputs):
        xy, v, ph = inputs
        alpha, beta_x, beta_y = ph[:, 0], ph[:, 1], ph[:, 2]
        x_i = alpha[:, None] * (xy[None, :, 0] - cx) + beta_x[:, None] + cx
        y_i = alpha[:, None] * (xy[None, :, 1] - cy) + beta_y[:, None] + cy
        if quantize_plane_coords:
            from repro.quant.policies import TABLE1

            x_i, y_i = TABLE1.quantize_plane_coords(x_i, y_i)
        x_i = jnp.clip(jnp.where(jnp.isfinite(x_i), x_i, -1e6), -1e6, 1e6)
        y_i = jnp.clip(jnp.where(jnp.isfinite(y_i), y_i, -1e6), -1e6, 1e6)
        vf = v.astype(jnp.float32)
        if mode == "nearest":
            # RTL convention: round half up (floor(x+0.5)), as in the kernel
            xr, yr = jnp.floor(x_i + 0.5), jnp.floor(y_i + 0.5)
            ok = (xr >= 0) & (xr <= w - 1) & (yr >= 0) & (yr <= h - 1)
            wt = vf[None, :] * ok.astype(jnp.float32)
            ox = (xr[..., None] == jnp.arange(w)).astype(jnp.float32)
            oy = (yr[..., None] == jnp.arange(h)).astype(jnp.float32)
            ox = ox * wt[..., None]
        else:
            x0f, y0f = jnp.floor(x_i), jnp.floor(y_i)
            ok = (x0f >= 0) & (x0f + 1 <= w - 1) & (y0f >= 0) & (y0f + 1 <= h - 1)
            wt = vf[None, :] * ok.astype(jnp.float32)
            fx = x_i - x0f
            fy = y_i - y0f
            gx = jnp.arange(w, dtype=jnp.float32)
            gy = jnp.arange(h, dtype=jnp.float32)
            ox = ((x0f[..., None] == gx) * (1 - fx)[..., None]
                  + ((x0f + 1)[..., None] == gx) * fx[..., None])
            oy = ((y0f[..., None] == gy) * (1 - fy)[..., None]
                  + ((y0f + 1)[..., None] == gy) * fy[..., None])
            ox = ox * wt[..., None]
        votes = jnp.einsum("zeh,zew->zhw", oy, ox)
        return dsi + votes, None

    dsi0 = jnp.zeros((nz, h, w), dtype=jnp.float32)
    dsi, _ = jax.lax.scan(frame, dsi0, (xy0, valid, phi))
    return dsi
