"""Pallas TPU kernel: fused proportional back-projection + DSI voting
+ int16 saturating store + depth max/argmax detection reduction.

This is the Proportional Projection Module of the paper (PE_Zi array +
Vote Execute Unit), re-architected for the TPU memory hierarchy:

  FPGA                                  TPU (this kernel)
  ----------------------------------    ----------------------------------
  multiple PE_Zi, one depth plane 	    grid axis 0 = depth-plane blocks
    each                                  (BZ planes per step)
  Buf_I double buffering of event       grid axis 1 = event frames, minor;
    frames                                Pallas pipelines HBM->VMEM DMAs
                                          of frame f+1 under compute of f
  Scalar MAC units (P(Z0->Zi))          VPU multiply-add on (E,) vectors
  Nearest Voxel Finder + miss judge     int8 plane-coord quantization +
                                          round/floor + bounds mask
  Vote Address Generator + Vote         one-hot/two-hot row construction +
    Execute Unit (DRAM RMW scatter)       MXU matmul  votes = Oy^T @ Ox,
                                          accumulated in a VMEM-resident
                                          (BZ, h_pad, w_pad) scratch block
  DSI store (on-chip BRAM, int16)       in-VMEM clip to the int16 range +
                                          cast, written back to HBM once
  Ray Counter -> depth map readout      streaming max/argmax + parabola
                                          state carried across z-blocks in
                                          VMEM scratch (the local_max
                                          reduction, fused)

Tiling: the full (h_pad, w_pad) plane tile lives in VMEM
(184*256*4 B = 188 KiB) — the DAVIS-scale DSI plane is small relative to
VMEM (~16 MiB), so we tile over depth, not space. Votes accumulate in a
float32 VMEM scratch block revisited across all frames (axis 1 minor);
on the last frame step the block is stored (int16 saturating when
quantized) and folded into the detection state, so the stored DSI makes
exactly one HBM trip and the max/argmax never reads it back — the no-
DRAM-round-trip datapath the paper's speedup comes from
(docs/kernel_fusion.md walks the stages and the VMEM budget).

The event-index contraction (E or F_STEP*E) feeds the MXU with a
(h_pad, E) x (E, w_pad) matmul per plane — systolic-friendly dims
(multiples of 8/128 via padding).

Detection semantics are bitwise those of `kernels/local_max` (and hence
of `core/detection.detect_structure`): first-max-wins streaming argmax
with running (c[z*-1], c[z*], c[z*+1]) capture, clamped-index boundary
conventions, and the clipped parabola offset. The z-block grid axis is
MAJOR (frames minor), so blocks complete in ascending global-z order and
the streaming scan across grid steps is valid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.platform import resolve_interpret

Array = jax.Array

LANE = 128
SUBLANE = 8

# int16 saturating-store range (Table 1 'dsi' format; the in-kernel clamp
# literals must equal EMVSQuantPolicy.sanctioned_clip_bounds() entries or
# the quantization-contract linter flags the float->int16 cast)
from repro.core.dsi import store_clip_bounds

DSI_STORE_MIN, DSI_STORE_MAX = store_clip_bounds()

def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _kernel(
    x_ref,  # (FS, E) raw canonical x coords for FS frames
    y_ref,  # (FS, E)
    valid_ref,  # (FS, E) float32 1/0
    phi_ref,  # (FS, BZ, 3) alpha, beta_x, beta_y  (per frame, per plane)
    dsi_ref,  # (BZ, h_pad, w_pad) stored DSI block (int16 when quantized)
    conf_ref,  # (h_pad, w_pad) float32 running max over z (output)
    zf_ref,  # (h_pad, w_pad) float32 argmax, parabola-refined at the end
    acc_ref,  # VMEM scratch (BZ, h_pad, w_pad) float32 vote accumulator
    prev_ref,  # VMEM scratch (h_pad, w_pad) value at z-1
    cprev_ref,  # VMEM scratch (h_pad, w_pad) value at z*-1
    cnext_ref,  # VMEM scratch (h_pad, w_pad) value at z*+1
    pwb_ref,  # VMEM scratch (h_pad, w_pad) 1.0 iff z-1 set a new best
    *,
    cx: float,
    cy: float,
    w: int,
    h: int,
    nz: int,
    bz: int,
    fs: int,
    nf: int,
    mode: str,
    quantized: bool,
    onehot_dtype,
):
    zb = pl.program_id(0)
    f = pl.program_id(1)

    @pl.when(f == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when((zb == 0) & (f == 0))
    def _init_detect():
        # DSI scores are >= 0; -1 never wins, so z=0 always sets a best
        conf_ref[...] = jnp.full_like(conf_ref, -1.0)
        zf_ref[...] = jnp.zeros_like(zf_ref)
        prev_ref[...] = jnp.zeros_like(prev_ref)
        cprev_ref[...] = jnp.zeros_like(cprev_ref)
        cnext_ref[...] = jnp.zeros_like(cnext_ref)
        pwb_ref[...] = jnp.zeros_like(pwb_ref)

    e = x_ref.shape[1]
    w_pad = acc_ref.shape[2]
    h_pad = acc_ref.shape[1]

    # flatten the frame-step axis into the event contraction axis
    x0 = x_ref[...].reshape(fs * e) - cx  # (FS*E,) centred canonical coords
    y0 = y_ref[...].reshape(fs * e) - cy
    vv = valid_ref[...].reshape(fs * e)

    col_x = jax.lax.broadcasted_iota(jnp.float32, (fs * e, w_pad), 1)
    col_y = jax.lax.broadcasted_iota(jnp.float32, (fs * e, h_pad), 1)

    for p in range(bz):
        # P(Z0 -> Zi): one multiply-add per coordinate (the PE_Zi scalar MACs)
        # phi is per-frame; broadcast each frame's coeffs over its events.
        alpha = phi_ref[:, p, 0:1]  # (FS, 1)
        bx = phi_ref[:, p, 1:2]
        by = phi_ref[:, p, 2:3]
        a_e = jnp.broadcast_to(alpha, (fs, e)).reshape(fs * e)
        bx_e = jnp.broadcast_to(bx, (fs, e)).reshape(fs * e)
        by_e = jnp.broadcast_to(by, (fs, e)).reshape(fs * e)
        xi = a_e * x0 + bx_e + cx
        yi = a_e * y0 + by_e + cy
        if quantized and mode == "nearest":
            # Table 1: plane coords carry int8 — the SAME policy method as
            # the XLA datapath (project_frame), applied in the same order
            # (quantize BEFORE the vote sanitize), so the formulations
            # agree bitwise by construction
            from repro.quant.policies import TABLE1

            xi = TABLE1.quantize_plane_coord_values(xi)
            yi = TABLE1.quantize_plane_coord_values(yi)
        xi = jnp.clip(jnp.where(jnp.isfinite(xi), xi, -1e6), -1e6, 1e6)
        yi = jnp.clip(jnp.where(jnp.isfinite(yi), yi, -1e6), -1e6, 1e6)

        if mode == "nearest":
            xr = jnp.floor(xi + 0.5)
            yr = jnp.floor(yi + 0.5)
            # miss judgement against the LOGICAL sensor bounds
            ok = (xr >= 0) & (xr <= w - 1) & (yr >= 0) & (yr <= h - 1)
            wt = vv * ok.astype(jnp.float32)
            ox = (xr[:, None] == col_x).astype(onehot_dtype)
            oy = (yr[:, None] == col_y).astype(onehot_dtype)
            # int8 rows (§Perf E1): 0/1 one-hots and the 0/1 validity mask
            # are exact in int8; the MXU's int8 path runs 2x bf16 rate
            ox = ox * wt[:, None].astype(onehot_dtype)
        else:  # bilinear: separable two-hot rows
            xf = jnp.floor(xi)
            yf = jnp.floor(yi)
            ok = (xf >= 0) & (xf + 1 <= w - 1) & (yf >= 0) & (yf + 1 <= h - 1)
            wt = (vv * ok.astype(jnp.float32)).astype(onehot_dtype)
            fx = (xi - xf).astype(onehot_dtype)
            fy = (yi - yf).astype(onehot_dtype)
            ox = ((xf[:, None] == col_x).astype(onehot_dtype) * (1 - fx)[:, None]
                  + ((xf + 1)[:, None] == col_x).astype(onehot_dtype) * fx[:, None])
            oy = ((yf[:, None] == col_y).astype(onehot_dtype) * (1 - fy)[:, None]
                  + ((yf + 1)[:, None] == col_y).astype(onehot_dtype) * fy[:, None])
            ox = ox * wt[:, None]

        # votes = Oy^T @ Ox on the MXU; int8 operands accumulate in int32
        # (exact: counts <= E), float in fp32 (exact: counts << 2^24)
        acc_type = jnp.int32 if onehot_dtype == jnp.int8 else jnp.float32
        votes = jax.lax.dot_general(
            oy, ox,
            dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=acc_type,
        )  # (h_pad, w_pad)
        acc_ref[p, :, :] += votes.astype(jnp.float32)

    @pl.when(f == nf - 1)
    def _store_and_detect():
        # All frames voted into this z-block: store it (once) and fold it
        # into the streaming detection state while it is still VMEM-resident.
        for p in range(bz):
            acc = acc_ref[p, :, :]
            if quantized:
                # int16 saturating store (core/dsi.to_storage semantics);
                # the clamp sanctions the float->int cast for the linter
                stored = jnp.clip(acc, DSI_STORE_MIN, DSI_STORE_MAX).astype(
                    jnp.int16)
                dsi_ref[p, :, :] = stored
                # detection sees the POST-store values — same order as the
                # XLA path (storage_roundtrip, then detect)
                cur = stored.astype(jnp.float32)
            else:
                dsi_ref[p, :, :] = acc
                cur = acc

            # streaming max/argmax update (bitwise kernels/local_max):
            # capture c[z*+1] one step after the argmax was set
            zg = (zb * bz + p).astype(jnp.float32)  # global plane index
            cnext_new = jnp.where(pwb_ref[...] > 0.0, cur, cnext_ref[...])
            is_new_best = cur > conf_ref[...]
            cprev_ref[...] = jnp.where(is_new_best, prev_ref[...],
                                       cprev_ref[...])
            zf_ref[...] = jnp.where(is_new_best, zg, zf_ref[...])
            conf_ref[...] = jnp.where(is_new_best, cur, conf_ref[...])
            # z*+1 unseen yet for a fresh best: default to 0 until captured
            cnext_ref[...] = jnp.where(is_new_best, jnp.zeros_like(cur),
                                       cnext_new)
            pwb_ref[...] = is_new_best.astype(jnp.float32)
            prev_ref[...] = cur

    @pl.when((zb == pl.num_programs(0) - 1) & (f == nf - 1))
    def _finalize_parabola():
        # boundary conventions match the ref oracle's index clamping:
        #   z*=0    -> cm = c0 (clip(z-1))     z*=nz-1 -> cp = c0
        best = conf_ref[...]
        zbest = zf_ref[...]
        cm = jnp.where(zbest == 0.0, best, cprev_ref[...])
        cp = jnp.where(zbest == float(nz - 1), best, cnext_ref[...])
        denom = cm - 2.0 * best + cp
        offset = jnp.where(jnp.abs(denom) > 1e-6, 0.5 * (cm - cp) / denom, 0.0)
        offset = jnp.clip(offset, -0.5, 0.5)
        zf_ref[...] = zbest + offset


@functools.partial(
    jax.jit,
    static_argnames=("cx", "cy", "w", "h", "block_z", "frames_per_step", "mode",
                     "quantized", "onehot_dtype", "interpret"),
)
def backproject_vote_pallas(
    x0: Array,  # (F, E) canonical-plane x coords
    y0: Array,  # (F, E)
    valid: Array,  # (F, E) float32
    phi: Array,  # (F, Nz, 3)
    *,
    cx: float,
    cy: float,
    w: int,
    h: int,
    block_z: int = 8,
    frames_per_step: int = 1,
    mode: str = "nearest",
    quantized: bool = False,
    onehot_dtype=jnp.bfloat16,
    interpret: bool | None = None,
) -> tuple[Array, Array, Array]:
    """Fused sweep: returns padded `(dsi, conf, zf)`.

    dsi  — (Nz, h_pad, w_pad) int16 when `quantized` (saturating store
           applied in-kernel), float32 otherwise
    conf — (h_pad, w_pad) float32 depth-axis max of the STORED DSI
    zf   — (h_pad, w_pad) float32 parabola-refined argmax

    `interpret` resolves via `repro.kernels.platform.resolve_interpret`
    (None = compiled on TPU/GPU, interpreter elsewhere; False raises on
    platforms without a Pallas compile path).
    """
    F, E = x0.shape
    nz = phi.shape[1]
    assert nz % block_z == 0, (nz, block_z)
    assert F % frames_per_step == 0, (F, frames_per_step)
    w_pad = _round_up(w, LANE)
    h_pad = _round_up(h, SUBLANE)
    fs = frames_per_step
    nf = F // fs
    grid = (nz // block_z, nf)
    store_dtype = jnp.int16 if quantized else jnp.float32

    kern = functools.partial(
        _kernel, cx=cx, cy=cy, w=w, h=h, nz=nz, bz=block_z, fs=fs, nf=nf,
        mode=mode, quantized=quantized, onehot_dtype=onehot_dtype,
    )
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((fs, E), lambda z, f: (f, 0)),
            pl.BlockSpec((fs, E), lambda z, f: (f, 0)),
            pl.BlockSpec((fs, E), lambda z, f: (f, 0)),
            pl.BlockSpec((fs, block_z, 3), lambda z, f: (f, z, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_z, h_pad, w_pad), lambda z, f: (z, 0, 0)),
            # conf/zf blocks are revisited by every grid step: constant
            # index map keeps them VMEM-resident for the whole sweep
            pl.BlockSpec((h_pad, w_pad), lambda z, f: (0, 0)),
            pl.BlockSpec((h_pad, w_pad), lambda z, f: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nz, h_pad, w_pad), store_dtype),
            jax.ShapeDtypeStruct((h_pad, w_pad), jnp.float32),
            jax.ShapeDtypeStruct((h_pad, w_pad), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_z, h_pad, w_pad), jnp.float32),  # acc
            pltpu.VMEM((h_pad, w_pad), jnp.float32),  # prev
            pltpu.VMEM((h_pad, w_pad), jnp.float32),  # c_prev_of_best
            pltpu.VMEM((h_pad, w_pad), jnp.float32),  # c_next_of_best
            pltpu.VMEM((h_pad, w_pad), jnp.float32),  # prev_was_best
        ],
        interpret=resolve_interpret(interpret),
    )(x0, y0, valid, phi)
