"""Pallas TPU kernel: fused proportional back-projection + DSI voting.

This is the Proportional Projection Module of the paper (PE_Zi array +
Vote Execute Unit), re-architected for the TPU memory hierarchy:

  FPGA                                  TPU (this kernel)
  ----------------------------------    ----------------------------------
  multiple PE_Zi, one depth plane 	    grid axis 0 = depth-plane blocks
    each                                  (BZ planes per step)
  Buf_I double buffering of event       grid axis 1 = event frames, minor;
    frames                                Pallas pipelines HBM->VMEM DMAs
                                          of frame f+1 under compute of f
  Scalar MAC units (P(Z0->Zi))          VPU multiply-add on (E,) vectors
  Nearest Voxel Finder + miss judge     round/floor + bounds mask
  Vote Address Generator + Vote         one-hot/two-hot row construction +
    Execute Unit (DRAM RMW scatter)       MXU matmul  votes = Oy^T @ Ox,
                                          accumulated in a VMEM-resident
                                          (BZ, h_pad, w_pad) output block

Tiling: the full (h_pad, w_pad) plane tile lives in VMEM
(184*256*4 B = 188 KiB) — the DAVIS-scale DSI plane is small relative to
VMEM (~16 MiB), so we tile over depth, not space. The output z-block is
revisited across all frames (axis 1 minor) and written back to HBM once.

The event-index contraction (E or F_STEP*E) feeds the MXU with a
(h_pad, E) x (E, w_pad) matmul per plane — systolic-friendly dims
(multiples of 8/128 via padding).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

LANE = 128
SUBLANE = 8


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _kernel(
    x_ref,  # (FS, E) raw canonical x coords for FS frames
    y_ref,  # (FS, E)
    valid_ref,  # (FS, E) float32 1/0
    phi_ref,  # (FS, BZ, 3) alpha, beta_x, beta_y  (per frame, per plane)
    out_ref,  # (BZ, h_pad, w_pad) float32 accumulator block
    *,
    cx: float,
    cy: float,
    w: int,
    h: int,
    bz: int,
    fs: int,
    mode: str,
    onehot_dtype,
):
    f = pl.program_id(1)

    @pl.when(f == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    e = x_ref.shape[1]
    w_pad = out_ref.shape[2]
    h_pad = out_ref.shape[1]

    # flatten the frame-step axis into the event contraction axis
    x0 = x_ref[...].reshape(fs * e) - cx  # (FS*E,) centred canonical coords
    y0 = y_ref[...].reshape(fs * e) - cy
    vv = valid_ref[...].reshape(fs * e)

    col_x = jax.lax.broadcasted_iota(jnp.float32, (fs * e, w_pad), 1)
    col_y = jax.lax.broadcasted_iota(jnp.float32, (fs * e, h_pad), 1)

    for p in range(bz):
        # P(Z0 -> Zi): one multiply-add per coordinate (the PE_Zi scalar MACs)
        # phi is per-frame; broadcast each frame's coeffs over its events.
        alpha = phi_ref[:, p, 0:1]  # (FS, 1)
        bx = phi_ref[:, p, 1:2]
        by = phi_ref[:, p, 2:3]
        a_e = jnp.broadcast_to(alpha, (fs, e)).reshape(fs * e)
        bx_e = jnp.broadcast_to(bx, (fs, e)).reshape(fs * e)
        by_e = jnp.broadcast_to(by, (fs, e)).reshape(fs * e)
        xi = a_e * x0 + bx_e + cx
        yi = a_e * y0 + by_e + cy
        xi = jnp.clip(jnp.where(jnp.isfinite(xi), xi, -1e6), -1e6, 1e6)
        yi = jnp.clip(jnp.where(jnp.isfinite(yi), yi, -1e6), -1e6, 1e6)

        if mode == "nearest":
            xr = jnp.floor(xi + 0.5)
            yr = jnp.floor(yi + 0.5)
            # miss judgement against the LOGICAL sensor bounds
            ok = (xr >= 0) & (xr <= w - 1) & (yr >= 0) & (yr <= h - 1)
            wt = vv * ok.astype(jnp.float32)
            ox = (xr[:, None] == col_x).astype(onehot_dtype)
            oy = (yr[:, None] == col_y).astype(onehot_dtype)
            # int8 rows (§Perf E1): 0/1 one-hots and the 0/1 validity mask
            # are exact in int8; the MXU's int8 path runs 2x bf16 rate
            ox = ox * wt[:, None].astype(onehot_dtype)
        else:  # bilinear: separable two-hot rows
            xf = jnp.floor(xi)
            yf = jnp.floor(yi)
            ok = (xf >= 0) & (xf + 1 <= w - 1) & (yf >= 0) & (yf + 1 <= h - 1)
            wt = (vv * ok.astype(jnp.float32)).astype(onehot_dtype)
            fx = (xi - xf).astype(onehot_dtype)
            fy = (yi - yf).astype(onehot_dtype)
            ox = ((xf[:, None] == col_x).astype(onehot_dtype) * (1 - fx)[:, None]
                  + ((xf + 1)[:, None] == col_x).astype(onehot_dtype) * fx[:, None])
            oy = ((yf[:, None] == col_y).astype(onehot_dtype) * (1 - fy)[:, None]
                  + ((yf + 1)[:, None] == col_y).astype(onehot_dtype) * fy[:, None])
            ox = ox * wt[:, None]

        # votes = Oy^T @ Ox on the MXU; int8 operands accumulate in int32
        # (exact: counts <= E), float in fp32 (exact: counts << 2^24)
        acc_type = jnp.int32 if onehot_dtype == jnp.int8 else jnp.float32
        votes = jax.lax.dot_general(
            oy, ox,
            dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=acc_type,
        )  # (h_pad, w_pad)
        out_ref[p, :, :] += votes.astype(jnp.float32)


@functools.partial(
    jax.jit,
    static_argnames=("cx", "cy", "w", "h", "block_z", "frames_per_step", "mode",
                     "onehot_dtype", "interpret"),
)
def backproject_vote_pallas(
    x0: Array,  # (F, E) canonical-plane x coords
    y0: Array,  # (F, E)
    valid: Array,  # (F, E) float32
    phi: Array,  # (F, Nz, 3)
    *,
    cx: float,
    cy: float,
    w: int,
    h: int,
    block_z: int = 8,
    frames_per_step: int = 1,
    mode: str = "nearest",
    onehot_dtype=jnp.bfloat16,
    interpret: bool = True,
) -> Array:
    """Returns the padded DSI (Nz, h_pad, w_pad) float32."""
    F, E = x0.shape
    nz = phi.shape[1]
    assert nz % block_z == 0, (nz, block_z)
    assert F % frames_per_step == 0, (F, frames_per_step)
    w_pad = _round_up(w, LANE)
    h_pad = _round_up(h, SUBLANE)
    fs = frames_per_step
    grid = (nz // block_z, F // fs)

    kern = functools.partial(
        _kernel, cx=cx, cy=cy, w=w, h=h, bz=block_z, fs=fs, mode=mode,
        onehot_dtype=onehot_dtype,
    )
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((fs, E), lambda z, f: (f, 0)),
            pl.BlockSpec((fs, E), lambda z, f: (f, 0)),
            pl.BlockSpec((fs, E), lambda z, f: (f, 0)),
            pl.BlockSpec((fs, block_z, 3), lambda z, f: (f, z, 0)),
        ],
        out_specs=pl.BlockSpec((block_z, h_pad, w_pad), lambda z, f: (z, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nz, h_pad, w_pad), jnp.float32),
        interpret=interpret,
    )(x0, y0, valid, phi)
