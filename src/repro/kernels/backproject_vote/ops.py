"""Public jit'd wrapper for the fused backproject+vote kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.camera import CameraModel
from repro.core.dsi import DSIConfig
from repro.core.geometry import apply_homography
from repro.kernels.backproject_vote.kernel import backproject_vote_pallas
from repro.quant.fixed_point import Q11_21, quantize_roundtrip
from repro.quant.policies import TABLE1

Array = jax.Array


@partial(jax.jit, static_argnames=("cx", "cy", "w", "h", "mode", "block_z",
                                   "frames_per_step", "onehot_dtype", "interpret"))
def backproject_vote(
    xy0: Array,  # (F, E, 2) canonical coords
    valid: Array,  # (F, E) bool/float
    phi: Array,  # (F, Nz, 3)
    *,
    cx: float,
    cy: float,
    w: int,
    h: int,
    mode: str = "nearest",
    block_z: int = 8,
    frames_per_step: int = 1,
    onehot_dtype=None,
    interpret: bool = True,
) -> Array:
    """DSI (Nz, h, w) float32 from canonical coords (kernel-backed).

    One-hot dtype: nearest voting uses bf16 rows (0/1 exact, 2x MXU
    throughput); bilinear defaults to fp32 rows so fractional weights are
    exact — pass bf16 explicitly to trade ~2^-9 weight error for speed.
    """
    if onehot_dtype is None:
        onehot_dtype = jnp.bfloat16 if mode == "nearest" else jnp.float32
    dsi_pad = backproject_vote_pallas(
        xy0[..., 0].astype(jnp.float32),
        xy0[..., 1].astype(jnp.float32),
        valid.astype(jnp.float32),
        phi.astype(jnp.float32),
        cx=cx, cy=cy, w=w, h=h, block_z=block_z,
        frames_per_step=frames_per_step, mode=mode, onehot_dtype=onehot_dtype,
        interpret=interpret,
    )
    return dsi_pad[:, :h, :w]


def backproject_vote_frames(
    xy: Array,  # (F, E, 2) rectified raw event coords
    valid: Array,  # (F, E)
    H: Array,  # (F, 3, 3)
    phi: Array,  # (F, Nz, 3)
    *,
    cam: CameraModel,
    dsi_cfg: DSIConfig,
    mode: str = "nearest",
    quantized: bool = False,
    block_z: int = 8,
    frames_per_step: int = 1,
    interpret: bool = True,
    frame_valid: Array | None = None,  # (F,) 1/0 — padded frames vote weight 0
) -> Array:
    """Full P + R for a frame batch: P(Z0) in XLA, fused kernel for the rest.

    Mirrors the FPGA module split: the Canonical Projection Module
    (homography + normalization) is a cheap batched op; the Proportional
    Projection Module (the hot loop) is the Pallas kernel.

    `frame_valid` supports the padded batched segment sweep: segments are
    padded to a fixed frame capacity, and padded frames (repeats of a real
    frame, so their geometry stays finite) are masked out of the vote by
    zeroing every event weight of that frame.
    """
    if frame_valid is not None:
        valid = valid.astype(jnp.float32) * frame_valid.astype(jnp.float32)[:, None]
    if quantized:
        pol = TABLE1
        xy = pol.quantize_events(xy)
        H = pol.quantize_homography(H)
        phi = quantize_roundtrip(phi, Q11_21)  # alpha/beta share the phi format
    xy0 = jax.vmap(apply_homography)(H, xy)
    if quantized:
        xy0 = TABLE1.quantize_canonical(xy0)
    return backproject_vote(
        xy0, valid, phi,
        cx=cam.cx, cy=cam.cy, w=cam.width, h=cam.height,
        mode=mode, block_z=block_z, frames_per_step=frames_per_step,
        interpret=interpret,
    )


# ---------------------------------------------------------------------------
# Static-analysis entry point (repro.analysis)
# ---------------------------------------------------------------------------

# worst-case input bounds the linter may assume for the kernel datapath —
# same semantic contracts as `pipeline.SWEEP_INPUT_CONTRACTS` but over the
# kernel's own (xy, valid, H, phi, frame_valid) signature
KERNEL_INPUT_CONTRACTS = {
    "xy": (-4096.0, 4096.0, False),
    "valid": (0.0, 1.0, True),
    "H": (-1e4, 1e4, False),
    "phi": (-1e4, 1e4, False),
    "frame_valid": (0.0, 1.0, True),
}


def kernel_trace_spec(
    *,
    cam: CameraModel,
    dsi_cfg: DSIConfig,
    frames: int = 4,
    events: int = 64,
    mode: str = "nearest",
    quantized: bool = False,
):
    """Traceable kernel entry for `repro.analysis`: `(fn, args, contracts)`.

    Stages `backproject_vote_frames` — including the Pallas kernel body —
    on `ShapeDtypeStruct` inputs so `jax.make_jaxpr` can walk it without
    executing. The interpreter recurses into the `pallas_call` equation
    and checks the same float->int contracts inside the kernel.
    """
    f, e, nz = frames, events, dsi_cfg.num_planes
    f32 = jnp.float32
    args = (
        jax.ShapeDtypeStruct((f, e, 2), f32),  # xy
        jax.ShapeDtypeStruct((f, e), f32),  # valid
        jax.ShapeDtypeStruct((f, 3, 3), f32),  # H
        jax.ShapeDtypeStruct((f, nz, 3), f32),  # phi
        jax.ShapeDtypeStruct((f,), f32),  # frame_valid
    )

    def fn(xy, valid, H, phi, frame_valid):
        return backproject_vote_frames(
            xy, valid, H, phi, cam=cam, dsi_cfg=dsi_cfg, mode=mode,
            quantized=quantized, frame_valid=frame_valid,
        )

    return fn, args, dict(KERNEL_INPUT_CONTRACTS)
