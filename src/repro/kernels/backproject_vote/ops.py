"""Public jit'd wrappers for the fused backproject+vote(+detect) kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.camera import CameraModel
from repro.core.dsi import DSIConfig
from repro.core.geometry import apply_homography
from repro.kernels.backproject_vote.kernel import backproject_vote_pallas
from repro.quant.fixed_point import Q11_21, quantize_roundtrip
from repro.quant.policies import TABLE1

Array = jax.Array


@partial(jax.jit, static_argnames=("cx", "cy", "w", "h", "mode", "block_z",
                                   "frames_per_step", "quantized",
                                   "onehot_dtype", "interpret"))
def backproject_vote(
    xy0: Array,  # (F, E, 2) canonical coords
    valid: Array,  # (F, E) bool/float
    phi: Array,  # (F, Nz, 3)
    *,
    cx: float,
    cy: float,
    w: int,
    h: int,
    mode: str = "nearest",
    block_z: int = 8,
    frames_per_step: int = 1,
    quantized: bool = False,
    onehot_dtype=None,
    interpret: bool | None = None,
) -> Array:
    """DSI (Nz, h, w) from canonical coords (kernel-backed).

    int16 when `quantized` (the in-kernel saturating store), float32
    otherwise. The fused conf/zf detection outputs are discarded here —
    use `backproject_vote_detect` to keep them.

    One-hot dtype: nearest voting uses bf16 rows (0/1 exact, 2x MXU
    throughput); bilinear defaults to fp32 rows so fractional weights are
    exact — pass bf16 explicitly to trade ~2^-9 weight error for speed.
    """
    dsi, _, _ = backproject_vote_detect(
        xy0, valid, phi, cx=cx, cy=cy, w=w, h=h, mode=mode, block_z=block_z,
        frames_per_step=frames_per_step, quantized=quantized,
        onehot_dtype=onehot_dtype, interpret=interpret,
    )
    return dsi


@partial(jax.jit, static_argnames=("cx", "cy", "w", "h", "mode", "block_z",
                                   "frames_per_step", "quantized",
                                   "onehot_dtype", "interpret"))
def backproject_vote_detect(
    xy0: Array,  # (F, E, 2) canonical coords
    valid: Array,  # (F, E) bool/float
    phi: Array,  # (F, Nz, 3)
    *,
    cx: float,
    cy: float,
    w: int,
    h: int,
    mode: str = "nearest",
    block_z: int = 8,
    frames_per_step: int = 1,
    quantized: bool = False,
    onehot_dtype=None,
    interpret: bool | None = None,
) -> tuple[Array, Array, Array]:
    """Fused sweep from canonical coords: `(dsi, conf, zf)`, all cropped.

    dsi (Nz, h, w) — int16 when `quantized`, else float32; conf/zf (h, w)
    float32 are the depth-axis max and parabola-refined argmax of the
    STORED DSI, computed against the VMEM-resident block (no HBM
    round-trip between store and detection).
    """
    if onehot_dtype is None:
        onehot_dtype = jnp.bfloat16 if mode == "nearest" else jnp.float32
    dsi_pad, conf_pad, zf_pad = backproject_vote_pallas(
        xy0[..., 0].astype(jnp.float32),
        xy0[..., 1].astype(jnp.float32),
        valid.astype(jnp.float32),
        phi.astype(jnp.float32),
        cx=cx, cy=cy, w=w, h=h, block_z=block_z,
        frames_per_step=frames_per_step, mode=mode, quantized=quantized,
        onehot_dtype=onehot_dtype, interpret=interpret,
    )
    return dsi_pad[:, :h, :w], conf_pad[:h, :w], zf_pad[:h, :w]


def backproject_vote_frames(
    xy: Array,  # (F, E, 2) rectified raw event coords
    valid: Array,  # (F, E)
    H: Array,  # (F, 3, 3)
    phi: Array,  # (F, Nz, 3)
    *,
    cam: CameraModel,
    dsi_cfg: DSIConfig,
    mode: str = "nearest",
    quantized: bool = False,
    block_z: int = 8,
    frames_per_step: int = 1,
    interpret: bool | None = None,
    frame_valid: Array | None = None,  # (F,) 1/0 — padded frames vote weight 0
) -> tuple[Array, Array, Array]:
    """Full P + R + store + detect for a frame batch: `(dsi, conf, zf)`.

    Mirrors the FPGA module split: the Canonical Projection Module
    (homography + normalization) is a cheap batched op; the Proportional
    Projection Module plus the vote/store/detect datapath (the hot loop)
    is the fused Pallas kernel. Under `quantized` the Table-1 contract is
    applied end to end — including the int8 plane-coord quantization
    (in-kernel, matching `project_frame`) and the int16 saturating DSI
    store (in-kernel, so the stored volume makes exactly one HBM trip and
    detection reads the VMEM-resident block, never HBM).

    `frame_valid` supports the padded batched segment sweep: segments are
    padded to a fixed frame capacity, and padded frames (repeats of a real
    frame, so their geometry stays finite) are masked out of the vote by
    zeroing every event weight of that frame.
    """
    if frame_valid is not None:
        valid = valid.astype(jnp.float32) * frame_valid.astype(jnp.float32)[:, None]
    if quantized:
        pol = TABLE1
        xy = pol.quantize_events(xy)
        H = pol.quantize_homography(H)
        phi = quantize_roundtrip(phi, Q11_21)  # alpha/beta share the phi format
    xy0 = jax.vmap(apply_homography)(H, xy)
    if quantized:
        xy0 = TABLE1.quantize_canonical(xy0)
    return backproject_vote_detect(
        xy0, valid, phi,
        cx=cam.cx, cy=cam.cy, w=cam.width, h=cam.height,
        mode=mode, block_z=block_z, frames_per_step=frames_per_step,
        quantized=quantized, interpret=interpret,
    )


# ---------------------------------------------------------------------------
# Static-analysis entry point (repro.analysis)
# ---------------------------------------------------------------------------

# worst-case input bounds the linter may assume for the kernel datapath —
# same semantic contracts as `pipeline.SWEEP_INPUT_CONTRACTS` but over the
# kernel's own (xy, valid, H, phi, frame_valid) signature
KERNEL_INPUT_CONTRACTS = {
    "xy": (-4096.0, 4096.0, False),
    "valid": (0.0, 1.0, True),
    "H": (-1e4, 1e4, False),
    "phi": (-1e4, 1e4, False),
    "frame_valid": (0.0, 1.0, True),
}


def kernel_trace_spec(
    *,
    cam: CameraModel,
    dsi_cfg: DSIConfig,
    frames: int = 4,
    events: int = 64,
    mode: str = "nearest",
    quantized: bool = False,
):
    """Traceable kernel entry for `repro.analysis`: `(fn, args, contracts)`.

    Stages `backproject_vote_frames` — including the FUSED Pallas kernel
    body (vote accumulate, int8 plane-coord quantization, in-kernel
    float->int16 saturating store, detection reduction) — on
    `ShapeDtypeStruct` inputs so `jax.make_jaxpr` can walk it without
    executing. The interpreter recurses into the `pallas_call` equation
    and checks the same float->int contracts inside the kernel: the
    in-VMEM int16 store must carry clamp provenance matching
    `EMVSQuantPolicy.sanctioned_clip_bounds()`.
    """
    f, e, nz = frames, events, dsi_cfg.num_planes
    f32 = jnp.float32
    args = (
        jax.ShapeDtypeStruct((f, e, 2), f32),  # xy
        jax.ShapeDtypeStruct((f, e), f32),  # valid
        jax.ShapeDtypeStruct((f, 3, 3), f32),  # H
        jax.ShapeDtypeStruct((f, nz, 3), f32),  # phi
        jax.ShapeDtypeStruct((f,), f32),  # frame_valid
    )

    def fn(xy, valid, H, phi, frame_valid):
        return backproject_vote_frames(
            xy, valid, H, phi, cam=cam, dsi_cfg=dsi_cfg, mode=mode,
            quantized=quantized, frame_valid=frame_valid,
        )

    return fn, args, dict(KERNEL_INPUT_CONTRACTS)
