"""Pure-jnp oracle for the detection kernel: fused max/argmax over depth
with sub-voxel parabola refinement (stage D hot loop).

Outputs, per pixel:
  conf — max_z DSI
  zf   — argmax_z refined by a 3-point parabola fit, clamped to ±0.5
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


@jax.jit
def depth_argmax_ref(dsi: Array) -> tuple[Array, Array]:
    """dsi: (Nz, h, w) -> (conf (h,w) f32, zf (h,w) f32)."""
    dsi_f = dsi.astype(jnp.float32)
    nz = dsi.shape[0]
    conf = jnp.max(dsi_f, axis=0)
    zidx = jnp.argmax(dsi_f, axis=0)
    zm = jnp.clip(zidx - 1, 0, nz - 1)
    zp = jnp.clip(zidx + 1, 0, nz - 1)
    hh, ww = jnp.meshgrid(jnp.arange(dsi.shape[1]), jnp.arange(dsi.shape[2]),
                          indexing="ij")
    cm = dsi_f[zm, hh, ww]
    c0 = dsi_f[zidx, hh, ww]
    cp = dsi_f[zp, hh, ww]
    denom = cm - 2.0 * c0 + cp
    offset = jnp.where(jnp.abs(denom) > 1e-6, 0.5 * (cm - cp) / denom, 0.0)
    offset = jnp.clip(offset, -0.5, 0.5)
    return conf, zidx.astype(jnp.float32) + offset
