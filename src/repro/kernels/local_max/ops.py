"""Public wrapper for the detection kernel."""
from __future__ import annotations

import jax

from repro.kernels.local_max.kernel import depth_argmax_pallas

Array = jax.Array


def depth_argmax(dsi: Array, *, interpret: bool | None = None
                 ) -> tuple[Array, Array]:
    """Fused (conf, refined argmax) over the depth axis of a DSI.

    `interpret=None` is the capability-probed default (compiled on
    TPU/GPU, interpreter elsewhere); `interpret=False` raises on
    platforms without a Pallas compile path.
    """
    return depth_argmax_pallas(dsi, interpret=interpret)
