from repro.kernels.local_max.ops import depth_argmax  # noqa: F401
