"""Pallas TPU kernel for stage D: fused depth max/argmax + parabola refine.

Gather-free formulation: TPU vector units have no efficient per-lane
gather along the depth axis, so instead of `dsi[z*±1]` lookups the kernel
tracks, in one streaming pass over depth blocks, the running triple
(c[z*-1], c[z*], c[z*+1]) around the argmax using select ops only:

  prev  — value at z-1 (shifted-by-one running value)
  best  — running max, zbest — its index
  next_ — value at zbest+1, captured on the step after a new max

Grid: (h tiles, w tiles); each step loads a (Nz, TH, TW) VMEM column
block and reduces it along depth with an unrolled loop over SUBLANE-sized
depth slabs (depth is the major axis, so slabs are contiguous).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.platform import resolve_interpret

Array = jax.Array

LANE = 128
SUBLANE = 8


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _kernel(dsi_ref, conf_ref, zf_ref, *, nz: int):
    th, tw = conf_ref.shape

    neg = jnp.float32(-1.0)  # DSI scores are >= 0; -1 never wins
    best = jnp.full((th, tw), neg, dtype=jnp.float32)
    zbest = jnp.zeros((th, tw), dtype=jnp.float32)
    c_prev_of_best = jnp.zeros((th, tw), dtype=jnp.float32)  # value at z*-1
    c_next_of_best = jnp.zeros((th, tw), dtype=jnp.float32)  # value at z*+1
    prev = jnp.zeros((th, tw), dtype=jnp.float32)  # value at z-1
    prev_was_best = jnp.zeros((th, tw), dtype=jnp.bool_)

    # stream depth; plain python loop (nz is static, modest: 64..512)
    for z in range(nz):
        cur = dsi_ref[z, :, :].astype(jnp.float32)
        # capture c[z*+1] one step after the argmax was set
        c_next_of_best = jnp.where(prev_was_best, cur, c_next_of_best)
        is_new_best = cur > best
        c_prev_of_best = jnp.where(is_new_best, prev, c_prev_of_best)
        zbest = jnp.where(is_new_best, jnp.float32(z), zbest)
        best = jnp.where(is_new_best, cur, best)
        # z*+1 unseen yet for a fresh best: default to 0 until captured
        c_next_of_best = jnp.where(is_new_best, jnp.zeros_like(cur), c_next_of_best)
        prev_was_best = is_new_best
        prev = cur

    # boundary conventions match the ref oracle's index clamping:
    #   z*=0    -> cm = c0 (clip(z-1))     z*=nz-1 -> cp = c0
    c0 = best
    cm = jnp.where(zbest == 0, c0, c_prev_of_best)
    cp = jnp.where(zbest == nz - 1, c0, c_next_of_best)
    denom = cm - 2.0 * c0 + cp
    offset = jnp.where(jnp.abs(denom) > 1e-6, 0.5 * (cm - cp) / denom, 0.0)
    offset = jnp.clip(offset, -0.5, 0.5)
    conf_ref[...] = best
    zf_ref[...] = zbest + offset


@functools.partial(jax.jit, static_argnames=("tile_h", "tile_w", "interpret"))
def depth_argmax_pallas(
    dsi: Array, *, tile_h: int = 8, tile_w: int = 128,
    interpret: bool | None = None,
) -> tuple[Array, Array]:
    """dsi (Nz, h, w) -> (conf (h,w), zf (h,w)). h, w padded to tiles."""
    nz, h, w = dsi.shape
    h_pad = _round_up(h, tile_h)
    w_pad = _round_up(w, tile_w)
    if (h_pad, w_pad) != (h, w):
        dsi = jnp.pad(dsi, ((0, 0), (0, h_pad - h), (0, w_pad - w)))
    grid = (h_pad // tile_h, w_pad // tile_w)
    conf, zf = pl.pallas_call(
        functools.partial(_kernel, nz=nz),
        grid=grid,
        in_specs=[pl.BlockSpec((nz, tile_h, tile_w), lambda i, j: (0, i, j))],
        out_specs=[
            pl.BlockSpec((tile_h, tile_w), lambda i, j: (i, j)),
            pl.BlockSpec((tile_h, tile_w), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((h_pad, w_pad), jnp.float32),
            jax.ShapeDtypeStruct((h_pad, w_pad), jnp.float32),
        ],
        interpret=resolve_interpret(interpret),
    )(dsi)
    return conf[:h, :w], zf[:h, :w]
