"""Post-partitioning HLO text analyzer: trip-count-aware FLOPs, HBM
traffic, and collective payloads.

Why not ``compiled.cost_analysis()``: on this backend it counts each
``while`` (scan) body ONCE, so a 61-layer scanned model under-reports
flops and collective bytes by ~n_layers x microbatches. This analyzer
parses the compiled module text instead:

  1. split into computations; build a per-computation symbol table
     (every ``%name = dtype[dims]`` definition + signature params);
  2. recover loop trip counts from each ``while`` condition computation
     (the scan bound is the integer constant compared against the
     induction variable);
  3. propagate multiplicities through the call graph (while bodies
     multiply by trip count; fusions/calls inherit the caller's);
  4. FLOPs: every ``dot`` = 2 * prod(result dims) * prod(contracting
     dims), times multiplicity (plus cheap-op flops ignored — matmuls
     dominate every workload here);
  5. collective bytes: payload (result shape) of all-reduce/all-gather/
     reduce-scatter/all-to-all/collective-permute, times multiplicity;
  6. HBM bytes: sum of (result + operand) bytes of op lines in
     non-fusion computations (fusion internals are register/VMEM-local;
     the fusion call line itself carries its memory traffic).

All numbers are per-device: the compiled module is the per-device SPMD
program.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
_CALLED_RE = re.compile(
    r"(?:calls=|to_apply=|body=|condition=)%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _shapes_in(text: str) -> list[tuple[str, tuple[int, ...]]]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype in _DTYPE_BYTES:
            out.append((dtype, tuple(int(d) for d in dims.split(",") if d)))
    return out


def _bytes_of(shapes: list[tuple[str, tuple[int, ...]]]) -> int:
    total = 0
    for dtype, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class Op:
    name: str
    kind: str  # opcode-ish token
    result_text: str
    body_text: str  # full RHS
    operands: list[str]
    called: list[str]
    is_while: bool
    while_body: str | None
    while_cond: str | None


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    symbols: dict  # %name -> list[(dtype, dims)]
    ops: list[Op]


_OPERAND_RE = re.compile(r"%([\w.\-]+)")

# opcode = first bare token after the result shape
_OPCODE_RE = re.compile(r"^(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
                        r"([a-z][\w\-]*)")


def parse_module(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        hdr = _COMP_HDR_RE.match(line.strip())
        if hdr and line.rstrip().endswith("{"):
            name = hdr.group(1)
            cur = Computation(name=name, is_entry=line.startswith("ENTRY"),
                              symbols={}, ops=[])
            comps[name] = cur
            # signature params: "param_0.8: s32[]"
            for pname, ptype in re.findall(r"([\w.\-]+)\s*:\s*([a-z0-9]+\[[0-9,]*\])",
                                           hdr.group(2)):
                cur.symbols[pname] = _shapes_in(ptype)
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        # result shape(s) = leading "(...)" tuple or "dtype[dims]"
        if rhs.startswith("("):
            depth, i = 0, 0
            for i, ch in enumerate(rhs):
                depth += ch == "("
                depth -= ch == ")"
                if depth == 0:
                    break
            result_text = rhs[:i + 1]
            rest = rhs[i + 1:]
        else:
            sp = rhs.find(" ")
            result_text = rhs[:sp] if sp > 0 else rhs
            rest = rhs[sp:] if sp > 0 else ""
        opm = _OPCODE_RE.match(rhs)
        kind = opm.group(1) if opm else ""
        # operands: %names inside the first parenthesized arg list of `rest`
        paren = rest.find("(")
        operands: list[str] = []
        if paren >= 0:
            depth = 0
            j = paren
            for j in range(paren, len(rest)):
                depth += rest[j] == "("
                depth -= rest[j] == ")"
                if depth == 0:
                    break
            operands = _OPERAND_RE.findall(rest[paren:j + 1])
        called = _CALLED_RE.findall(rest)
        is_while = kind == "while"
        wb = wc = None
        if is_while:
            mb = re.search(r"body=%?([\w.\-]+)", rest)
            mc = re.search(r"condition=%?([\w.\-]+)", rest)
            wb = mb.group(1) if mb else None
            wc = mc.group(1) if mc else None
        cur.symbols[name] = _shapes_in(result_text)
        cur.ops.append(Op(name=name, kind=kind, result_text=result_text,
                          body_text=rhs, operands=operands, called=called,
                          is_while=is_while, while_body=wb, while_cond=wc))
    return comps


def trip_count(comps: dict[str, Computation], cond_name: str) -> int:
    """Scan bound = the max integer constant in the condition computation."""
    comp = comps.get(cond_name)
    if comp is None:
        return 1
    best = 1
    for op in comp.ops:
        for c in _CONST_RE.findall(op.body_text):
            best = max(best, int(c))
    return best


def multiplicities(comps: dict[str, Computation]) -> dict[str, float]:
    entry = next((c.name for c in comps.values() if c.is_entry), None)
    mult: dict[str, float] = defaultdict(float)
    if entry is None:
        return mult
    mult[entry] = 1.0
    # topological-ish propagation: iterate until fixpoint (call graph is a DAG)
    for _ in range(64):
        changed = False
        new = defaultdict(float)
        new[entry] = 1.0
        for cname, comp in comps.items():
            m = mult.get(cname, 0.0)
            if m == 0.0:
                continue
            for op in comp.ops:
                if op.is_while and op.while_body:
                    t = trip_count(comps, op.while_cond or "")
                    new[op.while_body] += m * t
                    if op.while_cond:
                        new[op.while_cond] += m * (t + 1)
                else:
                    for callee in op.called:
                        new[callee] += m
        new[entry] = 1.0
        if dict(new) != dict(mult):
            mult = new
            changed = True
        if not changed:
            break
    return mult


def _dot_flops(comp: Computation, op: Op) -> float:
    """2 * prod(result dims) * prod(lhs contracting dims)."""
    res = _shapes_in(op.result_text)
    if not res:
        return 0.0
    n_res = 1
    for d in res[0][1]:
        n_res *= d
    mcon = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.body_text)
    if not mcon or not op.operands:
        return 0.0
    lhs = comp.symbols.get(op.operands[0])
    if not lhs or not lhs[0][1] and mcon.group(1):
        return 0.0
    contract = 1
    dims = lhs[0][1]
    for ix in mcon.group(1).split(","):
        if ix:
            i = int(ix)
            if i < len(dims):
                contract *= dims[i]
    return 2.0 * n_res * contract


# Memory model: assume TPU-grade elementwise fusion — only ops that force a
# materialization boundary count toward HBM traffic (result + operands).
# Elementwise/shape ops (add, exp, select, convert, broadcast, reshape, ...)
# fuse into their consumers and contribute zero incremental traffic; this
# matches XLA:TPU far better than the CPU backend's literal op list.
_MATERIALIZING_KINDS = {
    "dot", "convolution", "reduce", "reduce-window", "scatter", "gather",
    "dynamic-slice", "dynamic-update-slice", "sort", "concatenate",
    "pad", "transpose", "fusion", "cumsum",
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "reduce-scatter-start", "collective-permute-start", "rng",
    "rng-bit-generator",
    # NOTE: plain "copy" is excluded — XLA:CPU materializes while-loop
    # carry copies that TPU elides in place; counting them inflates the
    # memory term by ~n_layers x the carry size (documented bias choice).
}


@dataclasses.dataclass
class HloStats:
    flops: float
    hbm_bytes: float
    collective_bytes: float
    collective_counts: dict[str, float]
    collective_bytes_by_kind: dict[str, float]
    n_while: int
    trip_counts: list[int]

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def analyze_text(text: str) -> HloStats:
    comps = parse_module(text)
    mult = multiplicities(comps)
    # fusion computations: referenced via calls= -> memory-internal
    fused: set[str] = set()
    for comp in comps.values():
        for op in comp.ops:
            if op.kind == "fusion":
                fused.update(op.called)
    # fusions whose body reduces (input > output traffic is real)
    reducing: set[str] = {
        name for name in fused
        if name in comps and any(o.kind in ("reduce", "dot", "scatter")
                                 for o in comps[name].ops)
    }

    flops = 0.0
    hbm = 0.0
    col_bytes: dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    col_counts: dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    n_while = 0
    trips: list[int] = []

    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        in_fusion = cname in fused
        for op in comp.ops:
            if op.kind == "dot":
                flops += m * _dot_flops(comp, op)
            if op.is_while:
                n_while += 1
                trips.append(trip_count(comps, op.while_cond or ""))
            base = op.kind.replace("-start", "")
            if base in _COLLECTIVES and not op.kind.endswith("-done"):
                payload = _bytes_of(_shapes_in(op.result_text))
                col_bytes[base] += m * payload
                col_counts[base] += m
            if not in_fusion and op.kind in _MATERIALIZING_KINDS \
                    and not op.kind.endswith("-done"):
                res_b = _bytes_of(_shapes_in(op.result_text))
                if op.kind in ("dynamic-slice", "gather"):
                    b = 2 * res_b  # reads only the slice, writes the result
                elif op.kind == "dynamic-update-slice":
                    upd = (_bytes_of(comp.symbols.get(op.operands[1], []))
                           if len(op.operands) > 1 else res_b)
                    b = 3 * upd  # read update + RMW the target region
                elif op.kind == "scatter":
                    upd = (_bytes_of(comp.symbols.get(op.operands[2], []))
                           if len(op.operands) > 2 else res_b)
                    b = 3 * upd
                elif op.kind == "fusion" and not any(c in reducing
                                                     for c in op.called):
                    # kLoop fusion: each operand is read as-needed — a
                    # slicing/elementwise body touches at most
                    # result-size per operand (a full-cache operand of a
                    # slice fusion is NOT read wholesale)
                    b = res_b
                    for o in op.operands:
                        b += min(_bytes_of(comp.symbols.get(o, [])), res_b)
                else:
                    b = res_b
                    for o in op.operands:
                        b += _bytes_of(comp.symbols.get(o, []))
                hbm += m * b
    return HloStats(flops=flops, hbm_bytes=hbm,
                    collective_bytes=sum(col_bytes.values()),
                    collective_counts=col_counts,
                    collective_bytes_by_kind=col_bytes,
                    n_while=n_while, trip_counts=sorted(set(trips)))
