"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module must
never touch jax device state (the dry-run pins the device count via
XLA_FLAGS before any jax initialization).

Mesh semantics:
  single-pod: (data=16, model=16)            — 256 chips (one v5e pod)
  multi-pod:  (pod=2, data=16, model=16)     — 512 chips (2 pods)

`model` is the TP/EP axis (intra-pod, fastest ICI); `data` is in-pod
data parallel + FSDP; `pod` is cross-pod data parallel (params
replicated per pod; one cross-pod gradient all-reduce per step).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — tests/examples."""
    n = len(jax.devices())
    data = min(data, n // max(model, 1))
    return jax.make_mesh((max(data, 1), max(model, 1)), ("data", "model"))
