"""Training launcher: end-to-end driver with fault tolerance.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --reduced \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Features exercised (the large-scale story at laptop scale — the same
code paths the dry-run proves at 512 chips):
  * deterministic restartable data stream (resume = replay step counter)
  * checkpoint/restart (rolling, atomic) + preemption drain (SIGTERM)
  * straggler watchdog on step times
  * optional small host mesh (--devices N via XLA host devices is the
    dry-run's job; here we use whatever jax.devices() offers)
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.distributed.fault_tolerance import PreemptionHandler, StragglerMonitor
from repro.training import checkpoint as ckpt
from repro.training.data import DataConfig, TokenStream
from repro.training.optimizer import AdamWConfig
from repro.training.train_step import TrainOptions, init_train_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    opts = TrainOptions(
        microbatches=args.microbatches,
        remat=True,
        opt=AdamWConfig(peak_lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                        total_steps=args.steps),
    )
    state = init_train_state(jax.random.PRNGKey(args.seed), cfg, opts)
    start_step = 0
    if args.ckpt_dir:
        last = ckpt.latest(args.ckpt_dir)
        if last is not None:
            print(f"[restore] resuming from step {last}")
            state = ckpt.restore(args.ckpt_dir, last, state)
            start_step = last

    step_fn = jax.jit(make_train_step(cfg, opts), donate_argnums=(0,))
    data = TokenStream(DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                                  global_batch=args.batch, seed=args.seed))
    drain = PreemptionHandler()
    watchdog = StragglerMonitor()

    t_last = time.time()
    for step in range(start_step, args.steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        state, metrics = step_fn(state, batch)
        if (step + 1) % args.log_every == 0 or step == start_step:
            loss = float(metrics["loss"])
            dt = time.time() - t_last
            t_last = time.time()
            tok_s = args.batch * args.seq * args.log_every / max(dt, 1e-9)
            print(f"step {step + 1:5d}  loss {loss:.4f}  "
                  f"lr {float(metrics['lr']):.2e}  "
                  f"gnorm {float(metrics['grad_norm']):.2f}  "
                  f"{tok_s:,.0f} tok/s", flush=True)
            action = watchdog.observe(dt)
            if action:
                print(f"[straggler] {action}: step time {dt:.2f}s")
        want_ckpt = args.ckpt_dir and (step + 1) % args.ckpt_every == 0
        if want_ckpt or (drain.should_drain and args.ckpt_dir):
            path = ckpt.save(args.ckpt_dir, step + 1, state)
            print(f"[ckpt] step {step + 1} -> {path}")
        if drain.should_drain:
            print("[drain] preemption signal received; exiting cleanly")
            return
    if args.ckpt_dir:
        ckpt.save(args.ckpt_dir, args.steps, state)
    print("done")


if __name__ == "__main__":
    main()
