"""Serving launcher: continuous-batching engine demo.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --reduced \
        --requests 12 --slots 4 --max-new 16 [--int8-kv]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import model as M
from repro.serving.engine import Engine, EngineConfig, Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--int8-kv", action="store_true")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = M.init_params(jax.random.PRNGKey(args.seed), cfg)
    eng = Engine(cfg, params,
                 EngineConfig(slots=args.slots, max_len=args.max_len,
                              temperature=args.temperature,
                              kv_quantized=args.int8_kv,
                              prefill_buckets=(32, 64, 128)),
                 eos_id=-1)  # random weights never "finish"; run to budget
    rng = np.random.default_rng(args.seed)
    reqs = []
    for i in range(args.requests):
        plen = int(rng.integers(4, 24))
        req = Request(rid=i,
                      prompt=rng.integers(1, cfg.vocab_size, plen).astype(np.int32),
                      max_new_tokens=args.max_new)
        reqs.append(req)
        eng.submit(req)

    t0 = time.time()
    steps = 0
    while True:
        st = eng.step()
        steps += 1
        if st["active"] == 0 and st["queued"] == 0:
            break
        if steps > 100000:
            raise RuntimeError("engine did not drain")
    dt = time.time() - t0
    total_new = sum(len(r.generated) for r in reqs)
    print(f"served {len(reqs)} requests / {total_new} tokens in {dt:.2f}s "
          f"({total_new / dt:,.1f} tok/s, {steps} engine steps, "
          f"int8_kv={args.int8_kv})")
    for r in reqs[:3]:
        print(f"  req {r.rid}: prompt[:6]={r.prompt[:6].tolist()} "
              f"-> generated[:8]={r.generated[:8]}")


if __name__ == "__main__":
    main()
