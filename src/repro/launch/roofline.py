"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (TPU v5e constants):

    compute    = HLO_FLOPs_per_device / 197 TFLOP/s (bf16)
    memory     = HLO_bytes_per_device / 819 GB/s (HBM)
    collective = collective_bytes_per_device / 50 GB/s/link (ICI)

FLOPs/bytes come from ``compiled.cost_analysis()`` — the compiled module
is already the per-device SPMD program, so its counts are per-chip.
collective_bytes is parsed from the post-partitioning HLO text: the sum
of operand sizes of every all-reduce / all-gather / reduce-scatter /
all-to-all / collective-permute (per assignment). We additionally report
a link-time estimate that weights each kind by its ring cost
(all-gather/reduce-scatter move (n-1)/n of the result per link;
all-reduce 2x that) — the number the §Perf loop optimizes when the
collective term dominates.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

# TPU v5e-like chip (per assignment)
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9
ICI_BW = 50e9  # per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# result shape(s) + op name, e.g.:
#   %ar = bf16[128,1024] all-reduce(%x), replica_groups=...
#   %ag = (f32[8,4], f32[8,4]) all-gather(...)
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    """Total bytes of every dtype[dims] shape in `text`."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: dict[str, int]
    operand_bytes: dict[str, int]  # per kind, summed result-shape bytes
    total_bytes: int
    link_time_s: float  # ring-model link-time estimate

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def parse_collectives(hlo_text: str, *, axis_size_hint: int = 16
                      ) -> CollectiveStats:
    """Sum collective payload bytes from post-partitioning HLO text.

    Uses the op *result* shape as the payload proxy (for all-reduce /
    all-to-all / collective-permute result == operand; for all-gather the
    result is the gathered payload each device must receive; for
    reduce-scatter the operand == result * n is what each device sends
    through the ring in (n-1)/n pieces — we use result * ring factor).
    `-start/-done` async pairs are counted once (on -start; bare ops too).
    """
    counts: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    by_kind: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    link_time = 0.0
    for m in _OP_RE.finditer(hlo_text):
        shape_txt, kind, phase = m.group(1), m.group(2), m.group(3)
        if phase == "-done":
            continue  # counted at -start
        payload = _shape_bytes(shape_txt)
        counts[kind] += 1
        by_kind[kind] += payload
        n = axis_size_hint
        ring = (n - 1) / n
        if kind == "all-reduce":
            t = 2 * ring * payload / ICI_BW
        elif kind in ("all-gather", "reduce-scatter"):
            t = ring * payload / ICI_BW
        elif kind == "all-to-all":
            t = ring * payload / ICI_BW  # bisection-limited approximation
        else:  # collective-permute: one hop
            t = payload / ICI_BW
        link_time += t
    return CollectiveStats(counts=counts, operand_bytes=by_kind,
                           total_bytes=sum(by_kind.values()),
                           link_time_s=link_time)


@dataclasses.dataclass
class Roofline:
    flops: float  # per device (trip-count-aware HLO dot flops)
    bytes_hbm: float  # per device (op-level result+operand traffic)
    collective_bytes: float  # per device
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float  # 6*N_active*D tokens-based useful flops (global)
    model_flops_per_device: float
    useful_fraction: float  # model_flops_per_device / hlo flops
    collectives: dict[str, Any]
    cost_analysis_raw: dict[str, float]  # backend numbers (scan bodies x1!)
    n_while: int
    trip_counts: list

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def analyze(cost: dict, hlo_text: str, *, n_devices: int,
            model_flops_global: float, axis_size_hint: int = 16) -> Roofline:
    """Three-term roofline from the compiled per-device module.

    FLOPs / HBM / collective bytes come from the trip-count-aware HLO
    analyzer (launch/hlo_analysis.py) — the backend's cost_analysis()
    counts scan bodies once and is kept only as a cross-check.
    """
    from repro.launch import hlo_analysis as ha

    st = ha.analyze_text(hlo_text)
    compute_s = st.flops / PEAK_FLOPS
    memory_s = st.hbm_bytes / HBM_BW
    collective_s = st.collective_bytes / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    mf_dev = model_flops_global / n_devices
    return Roofline(
        flops=st.flops, bytes_hbm=st.hbm_bytes,
        collective_bytes=st.collective_bytes,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant,
        model_flops=model_flops_global, model_flops_per_device=mf_dev,
        useful_fraction=(mf_dev / st.flops if st.flops else 0.0),
        collectives={"counts": st.collective_counts,
                     "bytes": st.collective_bytes_by_kind},
        cost_analysis_raw={"flops": float(cost.get("flops", 0.0)),
                           "bytes_accessed": float(cost.get("bytes accessed", 0.0))},
        n_while=st.n_while, trip_counts=st.trip_counts,
    )


def model_flops_for_cell(cfg, cell) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE); D = tokens processed this step.

    decode cells process batch*1 new tokens but read the KV cache —
    model_flops uses 2*N_active*tokens (fwd only) for serve cells and
    6*N_active*tokens for train (fwd+bwd)."""
    n_active = cfg.active_params()
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n_active * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence + attention over the cache
    tokens = cell.global_batch * 1
    flops = 2.0 * n_active * tokens
    if cfg.n_heads:
        # KV-cache attention reads: 2 * 2 * Hq * hd * S per token (qk + pv)
        n_attn_layers = sum(1 for k in cfg.pattern() if k == "attn") \
            * cfg.n_superblocks()
        flops += (4.0 * cfg.n_heads * cfg.head_dim * cell.seq_len
                  * n_attn_layers * tokens)
    return flops


# ---------------------------------------------------------------------------
# EMVS sweep fusion ladder (analytic)
#
# The fused Pallas sweep cannot be costed from compiled HLO on the CPU CI
# leg (the interpreter lowers to scalar loops with meaningless traffic),
# so the kernel-fusion win is modeled analytically from first principles:
# every term is a tensor the stage MUST move through HBM, at its contract
# dtype width (docs/quantization_contracts.md). FLOPs are identical across
# stages — fusion only deletes data movement — so each rung strictly
# raises arithmetic intensity and strictly shrinks the modeled time's
# distance to the compute roofline bound.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SweepStageRoofline:
    """One rung of the fusion ladder under the two-term roofline."""

    name: str
    hbm_bytes: float
    flops: float
    compute_s: float
    memory_s: float
    time_s: float  # max(compute, memory); single-chip sweep, no collectives
    intensity: float  # flops / hbm_bytes
    bound_gap: float  # time_s / compute_s; 1.0 == sitting on the roofline

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def emvs_fusion_ladder(*, nz: int, h: int, w: int, events: int, frames: int,
                       quantized: bool = True) -> list[SweepStageRoofline]:
    """Model the three fusion stages of one quantized sweep dispatch.

    unfused        — the pre-fusion pipeline: the vote kernel writes a
                     float32 DSI to HBM, ``storage_roundtrip`` reads it
                     back and re-writes it int16 (Table-1 store), and
                     detection reads the whole stored volume once more.
    fused-store    — the saturating int16 store runs in-VMEM against the
                     resident block: the float32 spill and the roundtrip
                     read disappear; detection still re-reads the volume.
    fused-detect   — detection's streaming argmax consumes each stored
                     plane while it is still VMEM-resident, so the DSI is
                     written exactly once and never read back.
    """
    f32, i16 = 4.0, 2.0
    store = i16 if quantized else f32
    vox = float(nz) * h * w
    # tensors every stage reads exactly once, at contract dtype widths
    inputs = frames * events * (2 * f32 + f32) + frames * nz * 3 * f32
    outputs = 2.0 * h * w * f32  # conf + zf maps
    # identical math on every rung: projection (~10 flop/event/plane),
    # one-hot vote matmuls (2EH + 2EW MACs per plane per frame), and the
    # streaming argmax + parabola (~6 flop/voxel)
    flops = (frames * nz * events * 10.0
             + frames * nz * 2.0 * events * (h + w)
             + vox * 6.0)

    def rung(name: str, traffic: float) -> SweepStageRoofline:
        hbm = inputs + outputs + traffic
        compute_s = flops / PEAK_FLOPS
        memory_s = hbm / HBM_BW
        time_s = max(compute_s, memory_s)
        return SweepStageRoofline(
            name=name, hbm_bytes=hbm, flops=flops, compute_s=compute_s,
            memory_s=memory_s, time_s=time_s, intensity=flops / hbm,
            bound_gap=time_s / compute_s,
        )

    if quantized:
        unfused = vox * (f32 + f32 + store + store)  # spill, re-read, store, detect-read
    else:
        unfused = vox * (f32 + f32)  # spill + detect re-read (no roundtrip)
    return [
        rung("unfused", unfused),
        rung("fused-store", vox * (store + store)),
        rung("fused-detect", vox * store),
    ]
