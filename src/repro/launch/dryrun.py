"""Multi-pod dry-run: lower + compile every (arch x shape) on the
production meshes and extract memory/cost/collective evidence.

    python -m repro.launch.dryrun --arch qwen3-8b --cell train_4k --mesh single
    python -m repro.launch.dryrun --all --mesh both --out results/dryrun

The driver mode (--all) runs each cell in a subprocess: one cell's
failure (or RAM spike) cannot take down the sweep, and each compile gets
a fresh XLA. Results land in one JSON per cell + an aggregate table.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
#   Set ONLY here — tests and benches see the real (single) device.

import argparse
import dataclasses
import json
import subprocess
import sys
import time
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig, get_config
from repro.configs.shapes import EMVS_CELLS, LM_CELLS, ShapeCell, cell_skipped, input_specs
from repro.launch import roofline as rf
from repro.launch.mesh import make_production_mesh
from repro.models import model as M

ARCHS = [
    "kimi-k2-1t-a32b", "deepseek-moe-16b", "musicgen-large", "stablelm-3b",
    "qwen3-8b", "starcoder2-15b", "qwen1.5-4b", "jamba-1.5-large-398b",
    "llava-next-mistral-7b", "mamba2-2.7b", "eventor-davis240",
]

MAX_TOKENS_PER_DEV_MB = 16384  # microbatch sizing target (activation memory)


def _pick_microbatches(cell: ShapeCell, batch_shards: int) -> int:
    tokens_per_dev = cell.global_batch * cell.seq_len // batch_shards
    mb = 1
    while (tokens_per_dev // mb > MAX_TOKENS_PER_DEV_MB
           and (cell.global_batch // (mb * 2)) % batch_shards == 0
           and cell.global_batch // (mb * 2) >= batch_shards):
        mb *= 2
    return mb


def _batch_shards(mesh) -> int:
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n


# ---------------------------------------------------------------------------
# Per-kind lowering
# ---------------------------------------------------------------------------


def lower_cell(cfg: ArchConfig, cell: ShapeCell, mesh, opt_flags: frozenset = frozenset()):
    from repro.distributed import sharding as shd

    plan = shd.ShardingPlan.for_mesh(mesh)
    specs = input_specs(cfg, cell)

    if cfg.family == "emvs":
        return _lower_emvs(cfg, cell, mesh, opt_flags)

    # §Perf beyond-paper optimizations (opt-in; baseline = paper-faithful)
    if "pad_heads" in opt_flags and cfg.n_heads:
        cfg = cfg.pad_heads_to(mesh.shape.get("model", 1))

    if cell.kind == "train":
        from repro.training.train_step import TrainOptions, lower_train_step

        mb = _pick_microbatches(cell, _batch_shards(mesh))
        opts = TrainOptions(
            microbatches=mb, remat=True,
            grad_acc_sharded="grad_acc_spec" in opt_flags,
            moe_combine_bf16="bf16_combine" in opt_flags,
            ep_dispatch="a2a" if "ep_a2a" in opt_flags else "psum",
            ep_zero3="ep_zero3" in opt_flags,
            seq_parallel="seq_parallel" in opt_flags,
        )
        lowered, _ = lower_train_step(cfg, opts, mesh, plan, specs)
        return lowered, {"microbatches": mb}

    # Serving sharding policy: replicate params over `data` (no FSDP) when
    # the TP-sharded copy fits comfortably in HBM — per-step weight
    # all-gathers are pure decode latency. Fall back to FSDP only when a
    # replica cannot fit (kimi-1t, jamba-398b).
    params_shape = jax.eval_shape(
        partial(M.init_params, cfg=cfg, dtype=jnp.bfloat16),
        jax.random.PRNGKey(0))
    tp = mesh.shape.get("model", 1)
    param_bytes_tp = sum(
        l.size * l.dtype.itemsize for l in jax.tree.leaves(params_shape)) / tp
    serve_fsdp = param_bytes_tp > 8e9  # > 8 GiB/chip replica -> shard over data
    plan = shd.ShardingPlan.for_mesh(mesh, fsdp=serve_fsdp)
    p_shard = shd.param_shardings(cfg, params_shape, mesh, plan)
    in_shard_inputs = shd.input_shardings(specs, mesh, plan)
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bs = _batch_shards(mesh)
    act_batch_axes = batch_axes if cell.global_batch % bs == 0 else ()

    ep = None
    if (cfg.moe is not None and cfg.moe.num_experts % mesh.shape["model"] == 0
            and act_batch_axes):
        from repro.distributed.expert_parallel import EPShard

        ep = EPShard(mesh, token_axes=act_batch_axes)

    if cell.kind == "prefill":
        ctx = M.ModelCtx(mesh=mesh, batch_axes=act_batch_axes, ep_shard=ep)

        def prefill_step(params, batch):
            return M.prefill(params, batch["tokens"], cfg, cell.seq_len,
                             frontend_embed=batch.get("frontend_embed"),
                             ctx=ctx)

        jitted = jax.jit(prefill_step,
                         in_shardings=(p_shard, in_shard_inputs))
        with mesh:
            return jitted.lower(params_shape, specs), {}

    # decode
    ctx = M.ModelCtx(mesh=mesh, batch_axes=act_batch_axes, ep_shard=ep)
    if cell.name == "long_500k" and cfg.family == "hybrid":
        from repro.distributed.flash_decode import SeqShard

        ctx = M.ModelCtx(seq_shard=SeqShard(mesh), mesh=mesh,
                         batch_axes=act_batch_axes, ep_shard=ep)
    state_shape = jax.eval_shape(
        partial(M.init_decode_state, cfg=cfg, batch=cell.global_batch,
                max_len=cell.seq_len, ctx=ctx))
    s_specs = shd.decode_state_specs(cfg, state_shape, mesh, plan)
    s_shard = shd.tree_shardings(s_specs, mesh)

    def serve_step(params, state, batch):
        return M.decode_step(params, state, batch["tokens"],
                             jnp.int32(cell.seq_len - 1), cfg,
                             frontend_embed=batch.get("frontend_embed"),
                             ctx=ctx)

    jitted = jax.jit(serve_step,
                     in_shardings=(p_shard, s_shard, in_shard_inputs),
                     out_shardings=(None, s_shard),
                     donate_argnums=(1,))
    with mesh:
        return jitted.lower(params_shape, state_shape, specs), {}


def _lower_emvs(cfg: ArchConfig, cell: ShapeCell, mesh,
                opt_flags: frozenset = frozenset()):
    from repro.core.camera import CameraModel
    from repro.core.dsi import DSIConfig
    from repro.distributed.emvs import emvs_input_specs, make_emvs_step

    cam = CameraModel()
    dsi_cfg = DSIConfig.for_camera(cam, num_planes=256)
    multi = "pod" in mesh.axis_names
    data = mesh.shape["data"]
    if cell.name == "emvs_rt":
        # one 1024-event packet, split into pose-identical slices so the
        # event axis shards over `data` (votes are additive => exact)
        frames, events = data, cell.seq_len // data
    else:
        frames, events = cell.global_batch, cell.seq_len
    segments = 2 if multi else None
    import jax.numpy as _jnp

    step = make_emvs_step(
        cam, dsi_cfg, mesh, pod_axis="pod" if multi else None,
        vote_dtype=_jnp.int16 if "int16_votes" in opt_flags else _jnp.int32)
    specs = emvs_input_specs(dsi_cfg, frames=frames, events=events,
                             segments=segments)
    from repro.distributed import sharding as shd

    with mesh:
        lowered = jax.jit(step).lower(specs["xy"], specs["valid"],
                                      specs["frame_valid"], specs["H"],
                                      specs["phi"])
    n_votes = (segments or 1) * frames * events * dsi_cfg.num_planes
    return lowered, {"emvs_votes": n_votes,
                     "model_flops_override": 5.0 * n_votes}


# ---------------------------------------------------------------------------
# Run one cell
# ---------------------------------------------------------------------------


def run_cell(arch: str, cell_name: str, mesh_kind: str,
             opt_flags: frozenset = frozenset()) -> dict:
    cfg = get_config(arch)
    table = EMVS_CELLS if cfg.family == "emvs" else LM_CELLS
    cell = table[cell_name]
    skip = cell_skipped(cfg, cell)
    rec: dict = {"arch": arch, "cell": cell_name, "mesh": mesh_kind,
                 "opts": sorted(opt_flags)}
    if skip:
        rec["skipped"] = skip
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = mesh.size
    t0 = time.time()
    lowered, extra = lower_cell(cfg, cell, mesh, opt_flags)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()

    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older API returns [dict]
        cost = cost[0]
    try:
        mem = compiled.memory_analysis()
        mem_rec = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # CPU backend may not support it
        mem_rec = {"error": str(e)}

    hlo = compiled.as_text()
    mf = extra.get("model_flops_override")
    if mf is None:
        mf = rf.model_flops_for_cell(cfg, cell)
    roof = rf.analyze(cost, hlo, n_devices=n_dev, model_flops_global=mf,
                      axis_size_hint=16)

    rec.update({
        "devices": n_dev,
        "lower_s": round(t1 - t0, 2),
        "compile_s": round(t2 - t1, 2),
        "memory": mem_rec,
        "roofline": roof.to_json(),
        **{k: v for k, v in extra.items() if k != "model_flops_override"},
    })
    return rec


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--cell")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--json", help="write single-cell record here")
    ap.add_argument("--opts", default="", help="comma-separated beyond-paper optimizations: pad_heads,grad_acc_spec,bf16_combine,ep_a2a,int8_votes,seq_parallel")
    args = ap.parse_args()

    if args.all:
        os.makedirs(args.out, exist_ok=True)
        meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
        failures = 0
        for arch in ARCHS:
            cfg = get_config(arch)
            table = EMVS_CELLS if cfg.family == "emvs" else LM_CELLS
            for cell_name in table:
                for mk in meshes:
                    tag = f"{arch}__{cell_name}__{mk}".replace("/", "_")
                    out_json = os.path.join(args.out, tag + ".json")
                    if os.path.exists(out_json):
                        print(f"[skip-cached] {tag}")
                        continue
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", arch, "--cell", cell_name,
                           "--mesh", mk, "--json", out_json]
                    print(f"[run] {tag}", flush=True)
                    r = subprocess.run(cmd, capture_output=True, text=True,
                                       timeout=3600)
                    if r.returncode != 0:
                        failures += 1
                        with open(out_json + ".err", "w") as f:
                            f.write(r.stdout[-4000:] + "\n" + r.stderr[-8000:])
                        print(f"[FAIL] {tag}: see {out_json}.err")
        print(f"done; {failures} failures")
        return 1 if failures else 0

    opt_flags = frozenset(x for x in args.opts.split(",") if x)
    rec = run_cell(args.arch, args.cell, args.mesh, opt_flags)
    out = json.dumps(rec, indent=1, default=str)
    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as f:
            f.write(out)
    print(out)
    if "skipped" not in rec:
        print(f"\nmemory_analysis: {rec['memory']}")
        print(f"cost_analysis: flops={rec['roofline']['flops']:.3e} "
              f"bytes={rec['roofline']['bytes_hbm']:.3e}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
