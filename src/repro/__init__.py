"""repro: Eventor (event-based monocular multi-view stereo) on TPU, in JAX.

A production-grade training/inference framework reproducing and extending

    "Eventor: An Efficient Event-Based Monocular Multi-View Stereo
     Accelerator on FPGA Platform" (Li et al., 2022)

with a TPU-native reformulation of the event back-projection (P) and
volumetric ray-counting (R) stages, plus a multi-architecture LM substrate
sharing the same distributed runtime. See DESIGN.md.
"""

__version__ = "1.0.0"
