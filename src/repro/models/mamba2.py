"""Mamba-2 SSD (state-space duality) layer — chunked dual form.

The SSD recurrence (Dao & Gu, arXiv:2405.21060) for one head:

    h_t = a_t * h_{t-1} + b_t x_t^T        h in R^{N x P}
    y_t = C_t h_t + D x_t

with a_t = exp(-dt_t * A), b_t = dt_t * B_t. The *chunked dual form*
splits the sequence into chunks of length Q and computes, per chunk:

  intra-chunk (quadratic, runs on the MXU):
      y_intra = ((C B^T) ∘ L) (dt · X)     L = causal decay mask
  inter-chunk (linear recurrence over chunk states):
      S_c   = sum_t decay_to_end(t) * b_t x_t^T    (chunk state, N x P)
      h_c   = a_chunk * h_{c-1} + S_c              (scan over chunks)
      y_inter = C_t * decay_from_start(t) * h_{c-1}

Both terms are batched matmuls — MXU friendly — while the sequential
scan runs only over S/Q chunk steps: the TPU-native analogue of the
paper's DSI-level parallelism (parallel within a tile, tiny serial
chain across tiles).

Tensor parallelism: projections are SPLIT per stream (w_z, w_x, w_B,
w_C, w_dt) rather than one fused in_proj, so z/x/dt (head-aligned) can
shard over the `model` axis while B/C (shared across heads) replicate.
A fused projection would force one sharding onto all five segments.
out_proj is row-parallel (the same all-reduce as attention's wo).

Decode path: explicit single-step recurrence on a carried (H, N, P)
state — O(1) per token, which is why `mamba2-2.7b` and the Jamba
hybrid run the `long_500k` cell while pure-attention archs skip it.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.models.layers import dense, init_dense, rms_norm

Array = jax.Array


class SSMState(NamedTuple):
    """Decode-time carried state for one Mamba-2 layer."""

    conv_x: Array  # (B, K-1, d_inner) rolling conv window of x
    conv_B: Array  # (B, K-1, N)
    conv_C: Array  # (B, K-1, N)
    ssd: Array  # (B, H, N, P)  SSD recurrent state


def _dims(cfg: ArchConfig) -> tuple[int, int, int, int]:
    sc = cfg.ssm
    d_in = sc.d_inner(cfg.d_model)
    nh = sc.num_heads(cfg.d_model)
    return d_in, nh, sc.d_state, sc.head_dim


def init_mamba2(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> dict:
    sc = cfg.ssm
    d = cfg.d_model
    d_in, nh, n, _ = _dims(cfg)
    ks = jax.random.split(key, 6)

    def conv_w(k, c):
        return (jax.random.normal(k, (sc.conv_kernel, c), jnp.float32)
                * (1.0 / sc.conv_kernel) ** 0.5).astype(dtype)

    kc = jax.random.split(ks[5], 3)
    return {
        "w_z": init_dense(ks[0], d, d_in, dtype=dtype),
        "w_x": init_dense(ks[1], d, d_in, dtype=dtype),
        "w_B": init_dense(ks[2], d, n, dtype=dtype),
        "w_C": init_dense(ks[3], d, n, dtype=dtype),
        "w_dt": init_dense(ks[4], d, nh, dtype=dtype),
        "conv_x_w": conv_w(kc[0], d_in), "conv_x_b": jnp.zeros((d_in,), dtype),
        "conv_B_w": conv_w(kc[1], n), "conv_B_b": jnp.zeros((n,), dtype),
        "conv_C_w": conv_w(kc[2], n), "conv_C_b": jnp.zeros((n,), dtype),
        # per-head A (negative; stored as log), dt bias, D skip
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "norm": jnp.ones((d_in,), jnp.float32),
        "out_proj": init_dense(kc[0], d_in, d,
                               scale=d_in ** -0.5 / (2 * max(cfg.n_layers, 1)) ** 0.5,
                               dtype=dtype),
    }


def _causal_conv(x: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv, kernel K: (B, S, C) -> (B, S, C)."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(k):  # K is 4: unrolled adds, no gather
        out = out + pad[:, i:i + x.shape[1], :].astype(jnp.float32) * w[i].astype(jnp.float32)
    return jax.nn.silu(out + b.astype(jnp.float32)).astype(x.dtype)


def ssd_chunked(x: Array, dt: Array, A: Array, B: Array, C: Array, D: Array,
                chunk: int, h0: Array | None = None) -> tuple[Array, Array]:
    """Chunked SSD core.

    x:  (Bt, S, H, P)   dt: (Bt, S, H) pre-softplus   A: (H,) decay rates
    B, C: (Bt, S, N)    D: (H,)
    Returns (y (Bt, S, H, P), h_final (Bt, H, N, P) fp32).
    """
    bt, s, h, p = x.shape
    n = B.shape[-1]
    s_orig = s
    if s % chunk:  # pad to a chunk multiple; padded steps are inert:
        pad = chunk - s % chunk  # dt=-1e4 -> softplus ~ 0 -> decay 1, no input
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)), constant_values=-1e4)
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
        s = s + pad
    nc = s // chunk

    dt = jax.nn.softplus(dt.astype(jnp.float32))  # (Bt,S,H) positive
    # discretized log-decay per step: log a_t = -dt * A
    la = -dt * A[None, None, :]  # (Bt,S,H) negative

    xc = x.reshape(bt, nc, chunk, h, p)
    dtc = dt.reshape(bt, nc, chunk, h)
    lac = la.reshape(bt, nc, chunk, h)
    Bc = B.reshape(bt, nc, chunk, n)
    Cc = C.reshape(bt, nc, chunk, n)

    # cumulative log decay within each chunk (inclusive)
    cum = jnp.cumsum(lac, axis=2)  # (Bt,nc,Q,H)
    total = cum[:, :, -1:, :]  # (Bt,nc,1,H) full-chunk decay

    # --- intra-chunk: ((C B^T) ∘ L) (dt*x) -----------------------------
    # L[t,u] = exp(cum[t] - cum[u]) for t >= u  (decay over u+1..t)
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (Bt,nc,Q,Q,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))[None, None, :, :, None]
    # mask BEFORE exp: upper-triangle diff is positive and can overflow to
    # inf; where(mask, inf, 0) is fine forward but 0*inf => NaN in backward
    L = jnp.exp(jnp.where(tri, diff, -jnp.inf))
    scores = jnp.einsum("bcqn,bckn->bcqk", Cc.astype(jnp.float32),
                        Bc.astype(jnp.float32))  # (Bt,nc,Q,Q)
    xdt = xc.astype(jnp.float32) * dtc[..., None]  # (Bt,nc,Q,H,P)
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", scores[..., None] * L, xdt)

    # --- chunk states and inter-chunk scan ------------------------------
    # state contribution of step u: decay over u+1..end  *  b_u x_u^T
    decay_to_end = jnp.exp(total - cum)  # (Bt,nc,Q,H)
    S_c = jnp.einsum("bckn,bckh,bckhp->bchnp", Bc.astype(jnp.float32),
                     decay_to_end * dtc, xc.astype(jnp.float32))  # (Bt,nc,H,N,P)
    a_chunk = jnp.exp(total[:, :, 0, :])  # (Bt,nc,H)

    def scan_fn(hprev, inp):
        a_c, s_c = inp  # (Bt,H), (Bt,H,N,P)
        hnew = hprev * a_c[..., None, None] + s_c
        return hnew, hprev  # emit state *entering* the chunk

    hinit = (jnp.zeros((bt, h, n, p), jnp.float32) if h0 is None
             else h0.astype(jnp.float32))
    h_final, h_enter = jax.lax.scan(
        scan_fn, hinit, (a_chunk.swapaxes(0, 1), S_c.swapaxes(0, 1)))
    h_enter = h_enter.swapaxes(0, 1)  # (Bt,nc,H,N,P)

    # --- inter-chunk output: C_t decay(start..t) h_enter ----------------
    decay_from_start = jnp.exp(cum)  # (Bt,nc,Q,H)
    y_inter = jnp.einsum("bcqn,bcqh,bchnp->bcqhp", Cc.astype(jnp.float32),
                         decay_from_start, h_enter)

    y = (y_intra + y_inter).reshape(bt, s, h, p)
    y = y + x.astype(jnp.float32) * D[None, None, :, None]
    return y[:, :s_orig].astype(x.dtype), h_final


def _project(params: dict, x: Array) -> tuple[Array, Array, Array, Array, Array]:
    return (dense(x, params["w_z"]["w"]),
            dense(x, params["w_x"]["w"]),
            dense(x, params["w_B"]["w"]),
            dense(x, params["w_C"]["w"]),
            dense(x, params["w_dt"]["w"]))


def _finish(params: dict, y: Array, z: Array, cfg: ArchConfig) -> Array:
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 params["norm"], cfg.norm_eps)
    return dense(y, params["out_proj"]["w"])


def mamba2_forward(params: dict, x: Array, cfg: ArchConfig) -> Array:
    """Full Mamba-2 layer (training). x: (B, S, D) -> (B, S, D)."""
    out, _ = mamba2_prefill(params, x, cfg, want_state=False)
    return out


def mamba2_prefill(params: dict, x: Array, cfg: ArchConfig, *,
                   want_state: bool = True) -> tuple[Array, SSMState | None]:
    """Forward returning the decode-ready state (conv windows + SSD state)."""
    sc = cfg.ssm
    d_in, nh, n, p = _dims(cfg)
    bt, s = x.shape[:2]
    z, xs, B, C, dt = _project(params, x)
    km1 = sc.conv_kernel - 1
    if want_state:
        def tail(a: Array) -> Array:  # last K-1 pre-conv inputs (pad short seqs)
            a = a if s >= km1 else jnp.pad(a, ((0, 0), (km1 - s, 0), (0, 0)))
            return a[:, -km1:, :]
        tails = (tail(xs), tail(B), tail(C))
    xs = _causal_conv(xs, params["conv_x_w"], params["conv_x_b"])
    B = _causal_conv(B, params["conv_B_w"], params["conv_B_b"])
    C = _causal_conv(C, params["conv_C_w"], params["conv_C_b"])
    A = jnp.exp(params["A_log"])
    y, h_fin = ssd_chunked(
        xs.reshape(bt, s, nh, p),
        dt + params["dt_bias"][None, None, :],
        A, B, C, params["D"], chunk=min(sc.chunk_size, s),
    )
    out = _finish(params, y.reshape(bt, s, d_in), z, cfg)
    if not want_state:
        return out, None
    state = SSMState(conv_x=tails[0], conv_B=tails[1], conv_C=tails[2],
                     ssd=h_fin.astype(jnp.float32))
    return out, state


def mamba2_init_state(cfg: ArchConfig, batch: int, dtype=jnp.float32) -> SSMState:
    sc = cfg.ssm
    d_in, nh, n, p = _dims(cfg)
    km1 = sc.conv_kernel - 1
    return SSMState(
        conv_x=jnp.zeros((batch, km1, d_in), dtype),
        conv_B=jnp.zeros((batch, km1, n), dtype),
        conv_C=jnp.zeros((batch, km1, n), dtype),
        ssd=jnp.zeros((batch, nh, n, p), jnp.float32),
    )


def _conv_step(window: Array, x_t: Array, w: Array, b: Array
               ) -> tuple[Array, Array]:
    """One causal-conv step. window (B,K-1,C) + x_t (B,C) -> (out, new window)."""
    full = jnp.concatenate([window, x_t[:, None, :]], axis=1)  # (B,K,C)
    out = jnp.einsum("bkc,kc->bc", full.astype(jnp.float32), w.astype(jnp.float32))
    out = jax.nn.silu(out + b.astype(jnp.float32))
    return out, full[:, 1:, :].astype(window.dtype)


def mamba2_decode_step(params: dict, x: Array, state: SSMState, cfg: ArchConfig
                       ) -> tuple[Array, SSMState]:
    """One-token decode. x: (B, 1, D); O(1) state update (no KV growth)."""
    d_in, nh, n, p = _dims(cfg)
    z, xs, B, C, dt = _project(params, x)
    xs_t, new_cx = _conv_step(state.conv_x, xs[:, 0], params["conv_x_w"],
                              params["conv_x_b"])
    B_t, new_cb = _conv_step(state.conv_B, B[:, 0], params["conv_B_w"],
                             params["conv_B_b"])
    C_t, new_cc = _conv_step(state.conv_C, C[:, 0], params["conv_C_w"],
                             params["conv_C_b"])
    xs_t = xs_t.reshape(-1, nh, p)  # (B,H,P)
    dt_t = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                           + params["dt_bias"][None, :])  # (B,H)
    A = jnp.exp(params["A_log"])
    a_t = jnp.exp(-dt_t * A[None, :])  # (B,H)

    h = state.ssd.astype(jnp.float32)
    h = (h * a_t[..., None, None]
         + jnp.einsum("bn,bh,bhp->bhnp", B_t, dt_t, xs_t))
    y = jnp.einsum("bn,bhnp->bhp", C_t, h) + xs_t * params["D"][None, :, None]
    y = y.reshape(-1, 1, d_in).astype(x.dtype)
    out = _finish(params, y, z, cfg)
    return out, SSMState(conv_x=new_cx, conv_B=new_cb, conv_C=new_cc,
                         ssd=h.astype(state.ssd.dtype))
