"""LM model assembly: init / forward / prefill / decode for all 10 archs.

Structure (DESIGN.md §6): the layer stack is a `lax.scan` over
*super-blocks* — the arch's repeating pattern (dense/MoE: 1 layer;
Jamba: 8 layers, attention at position 4; Mamba-2: 1 SSM layer). Params
for pattern position i are stacked with a leading (n_superblocks,) axis,
so HLO size is constant in depth and GSPMD shards every layer
identically.

Decode state is a tuple over pattern positions: KVCache for "attn"
positions, SSMState for "mamba" positions — both stacked over
super-blocks and scanned through (xs in, updated ys out).

MoE execution: `ep_shard` in ModelCtx selects the shard_map
expert-parallel path (production); ep_shard=None runs the single-device
path (smoke tests).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.models import mamba2 as m2
from repro.models.attention import (
    attention_core,
    attention_decode,
    attention_out,
    init_attention,
    mask_padded_heads,
    qkv_project,
)
from repro.models.kv_cache import (
    KVCache,
    init_cache,
    read_cache,
    write_cache,
    write_cache_batched,
)
from repro.models.layers import (
    embed,
    init_embed,
    init_mlp,
    init_rms_norm,
    mlp,
    rms_norm,
    unembed,
)
from repro.models.moe import init_moe, moe_apply

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ModelCtx:
    """Execution context: distribution + cache policy knobs."""

    ep_shard: Optional[Any] = None  # distributed.EPShard | None
    seq_shard: Optional[Any] = None  # distributed.SeqShard | None (flash-decode)
    kv_quantized: bool = False
    remat: bool = False  # checkpoint each super-block (training)
    mesh: Optional[Any] = None  # sharding-constraint anchor mesh
    batch_axes: tuple = ()  # activation batch-dim mesh axes
    seq_axis: Optional[str] = None  # sequence-parallel axis (perf option)

    def constrain(self, x: Array) -> Array:
        """Pin activation sharding: (B, S, D) batch over batch_axes.

        Without this anchor GSPMD is free to replicate activations over
        the data axis through the layer stack (observed: 16x redundant
        attention compute + full-batch S^2 score tensors per device).
        Optionally shards S over `seq_axis` (sequence parallelism).
        """
        if self.mesh is None or x.ndim != 3:
            return x
        from jax.sharding import NamedSharding, PartitionSpec as P

        spec = P(self.batch_axes if self.batch_axes else None,
                 self.seq_axis, None)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_block(key, cfg: ArchConfig, kind: str, pos_in_pattern: int,
                dtype) -> dict:
    """One layer (pattern position): mixer + MLP/MoE + norms."""
    ks = jax.random.split(key, 2)
    p: dict = {"norm1": init_rms_norm(cfg.d_model)}
    if kind == "attn":
        p["attn"] = init_attention(ks[0], cfg, dtype)
        p["norm2"] = init_rms_norm(cfg.d_model)
        p["ffn"] = _init_ffn(ks[1], cfg, pos_in_pattern, dtype)
    elif kind == "mamba":
        p["mamba"] = m2.init_mamba2(ks[0], cfg, dtype)
        if cfg.family == "hybrid":  # Jamba: every layer has its own MLP/MoE
            p["norm2"] = init_rms_norm(cfg.d_model)
            p["ffn"] = _init_ffn(ks[1], cfg, pos_in_pattern, dtype)
    else:
        raise ValueError(kind)
    return p


def _init_ffn(key, cfg: ArchConfig, pos_in_pattern: int, dtype) -> dict:
    if cfg.moe is not None and not (
            cfg.moe.layout == "alternate" and pos_in_pattern % 2 == 1):
        return {"moe": init_moe(key, cfg, dtype)}
    return {"dense": init_mlp(key, cfg.d_model, cfg.d_ff, cfg.mlp_variant, dtype)}


def init_params(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> dict:
    """Full parameter tree. Super-block params stacked on axis 0."""
    pat = cfg.pattern()
    n_sb = cfg.n_superblocks()
    k_embed, k_blocks, k_head = jax.random.split(key, 3)

    def one_superblock(k):
        kk = jax.random.split(k, len(pat))
        return tuple(
            _init_block(kk[i], cfg, kind, i, dtype) for i, kind in enumerate(pat)
        )

    blocks = jax.vmap(one_superblock)(jax.random.split(k_blocks, n_sb))
    params = {
        "embed": init_embed(k_embed, cfg.vocab_size, cfg.d_model, dtype),
        "blocks": blocks,
        "final_norm": init_rms_norm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (
            jax.random.normal(k_head, (cfg.vocab_size, cfg.d_model), jnp.float32)
            * cfg.d_model ** -0.5).astype(dtype)
    return params


# ---------------------------------------------------------------------------
# Blocks (training / prefill path)
# ---------------------------------------------------------------------------


def _apply_ffn(p_ffn: dict, x: Array, cfg: ArchConfig, ctx: ModelCtx
               ) -> tuple[Array, dict]:
    b, s, d = x.shape
    if "dense" in p_ffn:
        return mlp(p_ffn["dense"], x, cfg.mlp_variant), {}
    xt = x.reshape(b * s, d)
    if ctx.ep_shard is not None:
        y, metrics = ctx.ep_shard.moe(p_ffn["moe"], xt, cfg)
    else:
        y, metrics = moe_apply(p_ffn["moe"], xt, cfg)
    return y.reshape(b, s, d), metrics


def _block_forward(p: dict, x: Array, kind: str, cfg: ArchConfig,
                   positions: Array, ctx: ModelCtx) -> tuple[Array, dict]:
    metrics: dict = {}
    h = rms_norm(x, p["norm1"]["scale"], cfg.norm_eps)
    if kind == "attn":
        qkv = qkv_project(p["attn"], h, cfg, positions)
        att = mask_padded_heads(
            attention_core(qkv.q, qkv.k, qkv.v, causal=True), cfg)
        x = x + attention_out(p["attn"], att)
    else:
        x = x + m2.mamba2_forward(p["mamba"], h, cfg)
    if "ffn" in p:
        h2 = rms_norm(x, p["norm2"]["scale"], cfg.norm_eps)
        y, metrics = _apply_ffn(p["ffn"], h2, cfg, ctx)
        x = x + y
    return x, metrics


def _superblock_forward(sb_params: tuple, x: Array, cfg: ArchConfig,
                        positions: Array, ctx: ModelCtx) -> tuple[Array, Array]:
    aux = jnp.float32(0.0)
    for i, kind in enumerate(cfg.pattern()):
        x = ctx.constrain(x)
        x, metrics = _block_forward(sb_params[i], x, kind, cfg, positions, ctx)
        aux = aux + metrics.get("moe_aux", 0.0)
    return ctx.constrain(x), aux


def _embed_inputs(params: dict, tokens: Array, cfg: ArchConfig,
                  frontend_embed: Array | None) -> Array:
    x = embed(tokens, params["embed"]["table"])
    if frontend_embed is not None:
        fe = frontend_embed.astype(x.dtype)
        if cfg.frontend == "vision_patches":
            # patch embeddings occupy the first n_front positions (anyres stub)
            x = jax.lax.dynamic_update_slice_in_dim(x, fe, 0, axis=1)
        elif cfg.frontend == "audio_frames":
            # EnCodec frame embeddings added to code-token embeddings (stub)
            x = x + fe
    return x


def forward(params: dict, tokens: Array, cfg: ArchConfig,
            *, frontend_embed: Array | None = None,
            ctx: ModelCtx = ModelCtx()) -> tuple[Array, Array]:
    """Training/prefill forward. tokens (B, S) -> (logits (B,S,V) fp32, aux)."""
    x = ctx.constrain(_embed_inputs(params, tokens, cfg, frontend_embed))
    positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)[None, :]

    def body(carry, sb_params):
        x, aux = carry
        x, a = _superblock_forward(sb_params, x, cfg, positions, ctx)
        return (x, aux + a), None

    if ctx.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), params["blocks"])
    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    table = params["embed"]["table"] if cfg.tie_embeddings else params["lm_head"]
    return unembed(x, table), aux / max(cfg.n_layers, 1)


def loss_fn(params: dict, tokens: Array, targets: Array, cfg: ArchConfig,
            *, frontend_embed: Array | None = None,
            ctx: ModelCtx = ModelCtx()) -> tuple[Array, dict]:
    """Next-token cross-entropy (+ MoE aux + z-loss). targets = shifted ids."""
    logits, aux = forward(params, tokens, cfg, frontend_embed=frontend_embed,
                          ctx=ctx)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (logz - gold).mean()
    zloss = 1e-4 * (logz ** 2).mean()
    moe_w = cfg.moe.router_aux_weight if cfg.moe is not None else 0.0
    loss = nll + zloss + moe_w * aux
    return loss, {"nll": nll, "zloss": zloss, "moe_aux": aux}


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------


def init_decode_state(cfg: ArchConfig, batch: int, max_len: int,
                      ctx: ModelCtx = ModelCtx(), dtype=jnp.bfloat16) -> tuple:
    """Per-pattern-position state, stacked over super-blocks (axis 0)."""
    n_sb = cfg.n_superblocks()

    def stack(tree):
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (n_sb,) + a.shape), tree)

    state = []
    for kind in cfg.pattern():
        if kind == "attn":
            state.append(stack(init_cache(batch, max_len, cfg.n_kv_heads_eff,
                                          cfg.head_dim, quantized=ctx.kv_quantized,
                                          dtype=dtype)))
        else:
            state.append(stack(m2.mamba2_init_state(cfg, batch, dtype=jnp.float32)))
    return tuple(state)


def prefill(params: dict, tokens: Array, cfg: ArchConfig, max_len: int,
            *, frontend_embed: Array | None = None,
            ctx: ModelCtx = ModelCtx(),
            logit_index: Array | None = None) -> tuple[Array, tuple]:
    """Process the prompt; return (logits at one position, decode state).

    `logit_index`: position whose logits to return (default: last). Lets
    the serving engine right-pad prompts to a compile bucket and still
    read the logits of the true last prompt token.
    """
    x = _embed_inputs(params, tokens, cfg, frontend_embed)
    b, s = tokens.shape
    positions = jnp.arange(s, dtype=jnp.int32)[None, :]
    state0 = init_decode_state(cfg, b, max_len, ctx, dtype=x.dtype)

    def body(x, scanned):
        sb_params, sb_state = scanned
        new_state = []
        for i, kind in enumerate(cfg.pattern()):
            p = sb_params[i]
            x = ctx.constrain(x)
            h = rms_norm(x, p["norm1"]["scale"], cfg.norm_eps)
            if kind == "attn":
                qkv = qkv_project(p["attn"], h, cfg, positions)
                att = mask_padded_heads(
                    attention_core(qkv.q, qkv.k, qkv.v, causal=True), cfg)
                x = x + attention_out(p["attn"], att)
                new_state.append(write_cache(sb_state[i], qkv.k, qkv.v,
                                             jnp.int32(0)))
            else:
                y, st = m2.mamba2_prefill(p["mamba"], h, cfg)
                x = x + y
                old = sb_state[i]
                new_state.append(jax.tree.map(
                    lambda new, o: new.astype(o.dtype), st, old))
            if "ffn" in p:
                h2 = rms_norm(x, p["norm2"]["scale"], cfg.norm_eps)
                y2, _ = _apply_ffn(p["ffn"], h2, cfg, ctx)
                x = x + y2
        return x, tuple(new_state)

    x, state = jax.lax.scan(body, x, (params["blocks"], state0))
    if logit_index is None:
        x = x[:, -1:]
    else:
        x = jax.lax.dynamic_slice_in_dim(x, jnp.asarray(logit_index), 1, axis=1)
    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    table = params["embed"]["table"] if cfg.tie_embeddings else params["lm_head"]
    return unembed(x, table), state


def decode_step(params: dict, state: tuple, tokens: Array, cur_len: Array,
                cfg: ArchConfig, *, frontend_embed: Array | None = None,
                ctx: ModelCtx = ModelCtx()) -> tuple[Array, tuple]:
    """One-token decode. tokens (B, 1); cur_len scalar int32 = tokens so far.

    Attention positions: the new token sits at index cur_len; its KV is
    written there and attends to cache[:cur_len+1].
    """
    x = _embed_inputs(params, tokens, cfg, frontend_embed)
    positions = jnp.full((1, 1), cur_len, jnp.int32)

    def body(x, scanned):
        sb_params, sb_state = scanned
        new_state = []
        for i, kind in enumerate(cfg.pattern()):
            p = sb_params[i]
            x = ctx.constrain(x)
            h = rms_norm(x, p["norm1"]["scale"], cfg.norm_eps)
            if kind == "attn":
                qkv = qkv_project(p["attn"], h, cfg, positions)
                cache = write_cache(sb_state[i], qkv.k, qkv.v, cur_len)
                k, v = read_cache(cache, x.dtype)
                if ctx.seq_shard is not None:
                    att = ctx.seq_shard.decode_attention(qkv.q, k, v, cur_len + 1)
                else:
                    att = attention_decode(qkv.q, k, v, cur_len + 1)
                att = mask_padded_heads(att, cfg)
                x = x + attention_out(p["attn"], att)
                new_state.append(cache)
            else:
                y, st = m2.mamba2_decode_step(p["mamba"], h, sb_state[i], cfg)
                x = x + y
                new_state.append(st)
            if "ffn" in p:
                h2 = rms_norm(x, p["norm2"]["scale"], cfg.norm_eps)
                y2, _ = _apply_ffn(p["ffn"], h2, cfg, ctx)
                x = x + y2
        return x, tuple(new_state)

    x, state = jax.lax.scan(body, x, (params["blocks"], state))
    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    table = params["embed"]["table"] if cfg.tie_embeddings else params["lm_head"]
    return unembed(x, table), state


def decode_step_batched(params: dict, state: tuple, tokens: Array,
                        lengths: Array, cfg: ArchConfig, *,
                        frontend_embed: Array | None = None,
                        ctx: ModelCtx = ModelCtx()) -> tuple[Array, tuple]:
    """Continuous-batching decode: per-slot lengths (B,).

    Each slot's new KV is written at its own position (one-hot masked
    update) and attends to its own `lengths[b]+1` valid cache entries.
    """
    x = _embed_inputs(params, tokens, cfg, frontend_embed)
    positions = lengths[:, None].astype(jnp.int32)  # (B,1) per-slot RoPE pos

    def body(x, scanned):
        sb_params, sb_state = scanned
        new_state = []
        for i, kind in enumerate(cfg.pattern()):
            p = sb_params[i]
            x = ctx.constrain(x)
            h = rms_norm(x, p["norm1"]["scale"], cfg.norm_eps)
            if kind == "attn":
                qkv = qkv_project(p["attn"], h, cfg, positions)
                cache = write_cache_batched(sb_state[i], qkv.k, qkv.v, lengths)
                k, v = read_cache(cache, x.dtype)
                att = mask_padded_heads(
                    attention_decode(qkv.q, k, v, lengths + 1), cfg)
                x = x + attention_out(p["attn"], att)
                new_state.append(cache)
            else:
                y, st = m2.mamba2_decode_step(p["mamba"], h, sb_state[i], cfg)
                x = x + y
                new_state.append(st)
            if "ffn" in p:
                h2 = rms_norm(x, p["norm2"]["scale"], cfg.norm_eps)
                y2, _ = _apply_ffn(p["ffn"], h2, cfg, ctx)
                x = x + y2
        return x, tuple(new_state)

    x, state = jax.lax.scan(body, x, (params["blocks"], state))
    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    table = params["embed"]["table"] if cfg.tie_embeddings else params["lm_head"]
    return unembed(x, table), state


@partial(jax.jit, donate_argnums=(0,))
def splice_slot(state: tuple, pstate: tuple, slot: Array) -> tuple:
    """Copy a prefilled batch-1 decode state into slot `slot` of a batched
    engine state (continuous-batching admission)."""

    def put(s, p):
        return jax.lax.dynamic_update_slice_in_dim(
            s, p.astype(s.dtype), slot, axis=1)

    return jax.tree.map(put, state, pstate)


def param_count(params: dict) -> int:
    return sum(a.size for a in jax.tree.leaves(params))
