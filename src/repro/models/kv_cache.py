"""KV cache with optional int8 quantization.

The int8 path instantiates the paper's hybrid-quantization principle
(Table 1) for the LM substrate: K/V are stored as int8 with a per
(position, kv-head) fp32 scale — an asymmetric-free, symmetric linear
quantizer, matching the paper's linear fixed-point scheme. Memory per
cached token drops 2x vs bf16 (the paper's "up to 50%" claim, ported).

Layout: (B, Smax, Hkv, D) — sequence-major so the sequence axis can be
sharded for distributed flash-decoding (long_500k cell).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class KVCache(NamedTuple):
    k: Array  # (B, Smax, Hkv, D) bf16 — or int8 when quantized
    v: Array
    k_scale: Array | None = None  # (B, Smax, Hkv, 1) fp32 when quantized
    v_scale: Array | None = None

    @property
    def quantized(self) -> bool:
        return self.k_scale is not None


def init_cache(batch: int, max_len: int, n_kv: int, d_head: int, *,
               quantized: bool = False, dtype=jnp.bfloat16) -> KVCache:
    shape = (batch, max_len, n_kv, d_head)
    if quantized:
        return KVCache(
            k=jnp.zeros(shape, jnp.int8), v=jnp.zeros(shape, jnp.int8),
            k_scale=jnp.zeros((batch, max_len, n_kv, 1), jnp.float32),
            v_scale=jnp.zeros((batch, max_len, n_kv, 1), jnp.float32),
        )
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


def _quantize(x: Array) -> tuple[Array, Array]:
    """Symmetric int8 per (pos, head): x ≈ q * scale."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = amax / 127.0
    q = jnp.round(x.astype(jnp.float32) / jnp.maximum(scale, 1e-12))
    return jnp.clip(q, -127, 127).astype(jnp.int8), scale


def dequantize(q: Array, scale: Array, dtype=jnp.bfloat16) -> Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def write_cache(cache: KVCache, k_new: Array, v_new: Array, pos: Array) -> KVCache:
    """Insert (B, S_new, Hkv, D) at sequence offset `pos` (scalar int32)."""
    if cache.quantized:
        kq, ks = _quantize(k_new)
        vq, vs = _quantize(v_new)
        return KVCache(
            k=jax.lax.dynamic_update_slice_in_dim(cache.k, kq, pos, axis=1),
            v=jax.lax.dynamic_update_slice_in_dim(cache.v, vq, pos, axis=1),
            k_scale=jax.lax.dynamic_update_slice_in_dim(cache.k_scale, ks, pos, axis=1),
            v_scale=jax.lax.dynamic_update_slice_in_dim(cache.v_scale, vs, pos, axis=1),
        )
    return KVCache(
        k=jax.lax.dynamic_update_slice_in_dim(cache.k, k_new.astype(cache.k.dtype),
                                              pos, axis=1),
        v=jax.lax.dynamic_update_slice_in_dim(cache.v, v_new.astype(cache.v.dtype),
                                              pos, axis=1),
    )


def write_cache_batched(cache: KVCache, k_new: Array, v_new: Array,
                        pos: Array) -> KVCache:
    """Insert one token per slot at per-slot positions `pos` (B,).

    One-hot masked update (shape-stable, scatter-free): traffic is one
    full cache read+write — the same order as the decode attention read.
    """
    b, smax = cache.k.shape[:2]
    hot = (jnp.arange(smax)[None, :] == pos[:, None])[..., None, None]  # (B,S,1,1)

    def put(old: Array, new: Array) -> Array:
        return jnp.where(hot, new.astype(old.dtype), old)

    if cache.quantized:
        kq, ks = _quantize(k_new)
        vq, vs = _quantize(v_new)
        return KVCache(k=put(cache.k, kq), v=put(cache.v, vq),
                       k_scale=put(cache.k_scale, ks),
                       v_scale=put(cache.v_scale, vs))
    return KVCache(k=put(cache.k, k_new), v=put(cache.v, v_new))


def read_cache(cache: KVCache, dtype=jnp.bfloat16) -> tuple[Array, Array]:
    """Materialize dequantized K, V (full length; mask handles validity)."""
    if cache.quantized:
        return (dequantize(cache.k, cache.k_scale, dtype),
                dequantize(cache.v, cache.v_scale, dtype))
    return cache.k, cache.v


def cache_bytes(cache: KVCache) -> int:
    return sum(a.size * a.dtype.itemsize for a in jax.tree.leaves(cache))
