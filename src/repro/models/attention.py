"""GQA attention: RoPE, qk-norm, QKV-bias; three execution paths.

  * `full`      — einsum attention, S <= FULL_ATTN_MAX_SEQ (training default)
  * `blockwise` — online-softmax over KV chunks (differentiable flash in
                  jnp): peak memory O(S * chunk) instead of O(S^2); used
                  for long-sequence prefill/training
  * Pallas      — `repro.kernels.flash_attention` (serving fast path)

Decode-step attention (one query against a KV cache) lives here too; its
sequence-sharded distributed variant (flash-decoding over the `data`
axis) is in `repro.distributed.sharding`.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.models.layers import dense, init_dense, rms_norm

Array = jax.Array

FULL_ATTN_MAX_SEQ = 8192
BLOCKWISE_CHUNK = 1024


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_sincos(positions: Array, head_dim: int, theta: float) -> tuple[Array, Array]:
    """positions (...,S) -> sin/cos (...,S, head_dim/2) fp32."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: Array, sin: Array, cos: Array) -> Array:
    """x: (B, S, H, D); sin/cos: (B?, S, D/2) broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    s = sin[..., None, :] if sin.ndim == x.ndim - 1 else sin
    c = cos[..., None, :] if cos.ndim == x.ndim - 1 else cos
    # rotate-half convention (Llama/Qwen)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> dict:
    d, hd = cfg.d_model, cfg.head_dim
    hq, hkv = cfg.n_heads_eff, cfg.n_kv_heads_eff  # incl. sharding pad
    ks = jax.random.split(key, 4)
    p = {
        "wq": init_dense(ks[0], d, hq * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wk": init_dense(ks[1], d, hkv * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wv": init_dense(ks[2], d, hkv * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wo": init_dense(ks[3], hq * hd, d, scale=(hq * hd) ** -0.5 / (2 * cfg.n_layers) ** 0.5,
                         dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def mask_padded_heads(att: Array, cfg: ArchConfig) -> Array:
    """Zero the padded heads' outputs: keeps the model function AND its
    gradients identical to the unpadded arch (padded wo columns get zero
    cotangents; padded q/k/v projections get zero gradients through the
    mask), while head counts divide the TP degree."""
    if cfg.head_pad == 0:
        return att
    mask = (jnp.arange(cfg.n_heads_eff) < cfg.n_heads).astype(att.dtype)
    return att * mask[None, None, :, None]


class QKV(NamedTuple):
    q: Array  # (B, S, Hq, D)
    k: Array  # (B, S, Hkv, D)
    v: Array  # (B, S, Hkv, D)


def qkv_project(params: dict, x: Array, cfg: ArchConfig, positions: Array) -> QKV:
    b, s, _ = x.shape
    hq, hkv, hd = cfg.n_heads_eff, cfg.n_kv_heads_eff, cfg.head_dim
    q = dense(x, params["wq"]["w"], params["wq"].get("b")).reshape(b, s, hq, hd)
    k = dense(x, params["wk"]["w"], params["wk"].get("b")).reshape(b, s, hkv, hd)
    v = dense(x, params["wv"]["w"], params["wv"].get("b")).reshape(b, s, hkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    sin, cos = rope_sincos(positions, hd, cfg.rope_theta)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    return QKV(q, k, v)


# ---------------------------------------------------------------------------
# Attention cores
# ---------------------------------------------------------------------------


def _repeat_kv(k: Array, groups: int) -> Array:
    if groups == 1:
        return k
    return jnp.repeat(k, groups, axis=2)


def attention_full(q: Array, k: Array, v: Array, *, causal: bool = True) -> Array:
    """(B, S, H, D) layout; einsum core; fp32 softmax."""
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    k = _repeat_kv(k, hq // hkv)
    v = _repeat_kv(v, hq // hkv)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) / d ** 0.5
    if causal:
        qpos = jnp.arange(sq)[:, None] + (skv - sq)
        kpos = jnp.arange(skv)[None, :]
        s = jnp.where(kpos <= qpos, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


def attention_blockwise(q: Array, k: Array, v: Array, *, causal: bool = True,
                        chunk: int = BLOCKWISE_CHUNK) -> Array:
    """Online-softmax over KV chunks; O(S*chunk) live scores; differentiable.

    Rectangular schedule (no triangle skip): every (q, kv-chunk) pair is
    computed and masked — 2x FLOP overhead vs the Pallas kernel's block
    skipping, traded for a dense, scan-friendly HLO (see EXPERIMENTS §Perf).
    """
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    assert skv % chunk == 0, (skv, chunk)
    nk = skv // chunk
    kc = k.reshape(b, nk, chunk, hkv, d)
    vc = v.reshape(b, nk, chunk, hkv, d)
    qpos = jnp.arange(sq)[:, None] + (skv - sq)
    scale = 1.0 / d ** 0.5

    # carry (m, l) stats and acc in (B, Hq, Sq, ...) layout
    m0 = jnp.full((b, hq, sq, 1), -1e30, jnp.float32)
    l0 = jnp.zeros((b, hq, sq, 1), jnp.float32)
    a0 = jnp.zeros((b, hq, sq, d), jnp.float32)

    def step(carry, blk):
        m, l, acc = carry
        kb, vb, j = blk
        kb = _repeat_kv(kb, g)
        vb = _repeat_kv(vb, g)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kb).astype(jnp.float32) * scale
        if causal:
            kpos = j * chunk + jnp.arange(chunk)[None, :]
            s = jnp.where(kpos <= qpos, s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        l_new = corr * l + jnp.sum(p, axis=-1, keepdims=True)
        pv = jnp.einsum("bhqk,bkhd->bhqd", p.astype(q.dtype), vb).astype(jnp.float32)
        acc_new = acc * corr + pv
        return (m_new, l_new, acc_new), None

    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0),
        (kc.swapaxes(0, 1), vc.swapaxes(0, 1), jnp.arange(nk)),
    )
    out = (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)  # (B, Hq, Sq, D)
    return out.swapaxes(1, 2)  # (B, Sq, Hq, D)


def attention_core(q: Array, k: Array, v: Array, *, causal: bool = True) -> Array:
    """Dispatch on sequence length (full vs blockwise)."""
    if k.shape[1] <= FULL_ATTN_MAX_SEQ:
        return attention_full(q, k, v, causal=causal)
    return attention_blockwise(q, k, v, causal=causal)


def attention_decode(q: Array, k_cache: Array, v_cache: Array, length: Array) -> Array:
    """One-token decode: q (B, 1, Hq, D); caches (B, Smax, Hkv, D).

    `length` (B,) or scalar: number of valid cache entries (including the
    token being decoded).
    """
    b, _, hq, d = q.shape
    smax, hkv = k_cache.shape[1], k_cache.shape[2]
    g = hq // hkv
    qh = q[:, 0].reshape(b, hkv, g, d)  # group queries onto their kv head
    # keep the cache in its storage dtype; fp32 happens in the MXU
    # accumulator (preferred_element_type) — avoids materializing an
    # fp32 copy of the (huge) cache
    s = jnp.einsum("bhgd,bkhd->bhgk", qh, k_cache,
                   preferred_element_type=jnp.float32) / d ** 0.5
    pos = jnp.arange(smax)[None, None, None, :]
    ln = jnp.asarray(length)
    ln = ln[:, None, None, None] if ln.ndim == 1 else ln
    s = jnp.where(pos < ln, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p.astype(q.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, hq * d).astype(q.dtype).reshape(b, 1, hq, d)


def attention_out(params: dict, attn: Array) -> Array:
    b, s = attn.shape[:2]
    return dense(attn.reshape(b, s, -1), params["wo"]["w"])
