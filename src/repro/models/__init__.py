"""LM model zoo substrate: layers, attention, MoE, SSD, composed models."""
