"""Shared layers: norms, embeddings, MLPs. Pure functions over param dicts.

Convention: params are nested dicts of jax arrays; layer-stacked variants
carry a leading super-block axis for `lax.scan`. Compute dtype follows the
inputs (bf16 in production); normalization statistics and softmax run in
fp32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def rms_norm(x: Array, scale: Array, eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def init_rms_norm(d: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((d,), dtype=dtype)}


def dense(x: Array, w: Array, b: Array | None = None) -> Array:
    y = jnp.einsum("...d,df->...f", x, w.astype(x.dtype))
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def init_dense(key, d_in: int, d_out: int, *, bias: bool = False,
               scale: float | None = None, dtype=jnp.bfloat16) -> dict:
    scale = scale if scale is not None else d_in ** -0.5
    p = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def mlp(params: dict, x: Array, variant: str = "swiglu") -> Array:
    """Position-wise feed-forward. swiglu: 3 matrices; gelu: 2 matrices."""
    if variant == "swiglu":
        gate = dense(x, params["w_gate"])
        up = dense(x, params["w_up"])
        act = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
        return dense(act, params["w_down"])
    if variant == "gelu":
        up = dense(x, params["w_up"], params.get("b_up"))
        act = jax.nn.gelu(up.astype(jnp.float32)).astype(x.dtype)
        return dense(act, params["w_down"], params.get("b_down"))
    raise ValueError(variant)


def init_mlp(key, d_model: int, d_ff: int, variant: str = "swiglu",
             dtype=jnp.bfloat16) -> dict:
    ks = jax.random.split(key, 3)
    if variant == "swiglu":
        return {
            "w_gate": init_dense(ks[0], d_model, d_ff, dtype=dtype)["w"],
            "w_up": init_dense(ks[1], d_model, d_ff, dtype=dtype)["w"],
            "w_down": init_dense(ks[2], d_ff, d_model, dtype=dtype)["w"],
        }
    return {
        "w_up": init_dense(ks[0], d_model, d_ff, dtype=dtype)["w"],
        "w_down": init_dense(ks[1], d_ff, d_model, dtype=dtype)["w"],
    }


def embed(tokens: Array, table: Array) -> Array:
    return jnp.take(table, tokens, axis=0)


def init_embed(key, vocab: int, d_model: int, dtype=jnp.bfloat16) -> dict:
    return {"table": (jax.random.normal(key, (vocab, d_model), jnp.float32)
                      * 0.02).astype(dtype)}


def unembed(x: Array, table_or_head: Array) -> Array:
    """Logits in fp32 (loss-critical)."""
    return jnp.einsum("...d,vd->...v", x.astype(jnp.float32),
                      table_or_head.astype(jnp.float32))
