"""Mixture-of-Experts: top-k router, shared experts, expert parallelism.

Production path (`moe_apply` under a mesh): activations are replicated
across the `model` axis at block boundaries (Megatron-style TP), so each
model-axis device holds the full local token set AND a 1/|model| slice of
the experts. Expert parallelism then needs NO all_to_all: every device

  1. routes identically (router weights replicated, tokens identical),
  2. gathers the tokens destined for ITS experts into a capacity-bounded
     (E_local, C) table,
  3. runs its experts' FFN,
  4. scatter-adds weighted outputs into a local (T, D) buffer,
  5. one psum over `model` completes the combine — the same single
     all-reduce a dense Megatron MLP layer would issue.

This trades dispatch all_to_all bandwidth (2 * T * k * D / |model|) for
the layer-output all-reduce the TP block already pays — a good default
when activations are TP-replicated. The all_to_all dispatch variant is
evaluated as a perf iteration in EXPERIMENTS.md §Perf.

Capacity: C = ceil(T * top_k * capacity_factor / E) tokens per expert;
overflow drops (GShard-style) — the approximate-computing lever the paper
applies to voting (nearest vs bilinear), instantiated for routing; the
dropped-token fraction is monitored in metrics.

The same code runs without a mesh (smoke tests): axis_name=None makes the
psum a no-op and every "device" holds all experts.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig, MoEConfig
from repro.models.layers import init_dense, init_mlp, mlp

Array = jax.Array


def init_moe(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> dict:
    mc = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    n_mats = 3 if cfg.mlp_variant == "swiglu" else 2
    del n_mats
    scale = d ** -0.5

    def expert_stack(k):
        kk = jax.random.split(k, 3)
        return {
            "w_gate": (jax.random.normal(kk[0], (mc.num_experts, d, mc.d_ff_expert),
                                         jnp.float32) * scale).astype(dtype),
            "w_up": (jax.random.normal(kk[1], (mc.num_experts, d, mc.d_ff_expert),
                                       jnp.float32) * scale).astype(dtype),
            "w_down": (jax.random.normal(kk[2], (mc.num_experts, mc.d_ff_expert, d),
                                         jnp.float32) * scale
                       / (2 * cfg.n_layers) ** 0.5).astype(dtype),
        }

    p = {
        "router": init_dense(ks[0], d, mc.num_experts, dtype=jnp.float32),
        "experts": expert_stack(ks[1]),
    }
    if mc.num_shared_experts:
        p["shared"] = init_mlp(ks[2], d, mc.d_ff_expert * mc.num_shared_experts,
                               cfg.mlp_variant, dtype=dtype)
    return p


def router_probs(params: dict, x: Array, mc: MoEConfig) -> tuple[Array, Array, Array]:
    """Return (top-k gates (T,k), top-k expert ids (T,k), aux loss scalar)."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32),
                        params["router"]["w"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, mc.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)  # renorm
    # Switch-style load-balance loss: E * sum_e f_e * p_e
    e = mc.num_experts
    counts = jnp.zeros((e,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    f = counts / jnp.maximum(counts.sum(), 1.0)
    p_mean = probs.mean(0)
    aux = e * jnp.sum(f * p_mean)
    return gates, idx, aux


def _capacity(tokens: int, mc: MoEConfig) -> int:
    c = int(tokens * mc.top_k * mc.capacity_factor / mc.num_experts) + 1
    return max(c, 4)


def moe_apply(
    params: dict,
    x: Array,  # (T, D) local tokens (flattened batch*seq)
    cfg: ArchConfig,
    *,
    axis_name: str | None = None,
    ep_size: int = 1,
    ep_index: Array | int = 0,
    combine_dtype=jnp.float32,  # bf16 halves the combine-psum payload
) -> tuple[Array, dict]:
    """Expert-parallel MoE forward. Returns (y (T, D), metrics)."""
    mc = cfg.moe
    t, d = x.shape
    e = mc.num_experts
    assert e % ep_size == 0, (e, ep_size)
    e_loc = e // ep_size
    cap = _capacity(t, mc)

    gates, idx, aux = router_probs(params, x, mc)  # (T,k), (T,k)

    # --- dispatch table: for each expert, up to `cap` (token, gate) slots ---
    flat_e = idx.reshape(-1)  # (T*k,)
    flat_t = jnp.repeat(jnp.arange(t), mc.top_k)
    flat_g = gates.reshape(-1)
    # stable sort by expert id groups tokens per expert
    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    # position within expert group = running index - group start
    grp_start = jnp.searchsorted(se, jnp.arange(e), side="left")  # (E,)
    pos = jnp.arange(t * mc.top_k) - grp_start[se]
    keep = pos < cap
    drop_frac = 1.0 - keep.mean()
    # scatter into (E, C+1) tables; column `cap` is a trash slot so dropped
    # tokens never collide with a kept token's slot; sentinel row index = t
    table_t = jnp.full((e, cap + 1), t, jnp.int32)
    table_g = jnp.zeros((e, cap + 1), jnp.float32)
    pos_c = jnp.minimum(pos, cap)
    table_t = table_t.at[se, pos_c].set(jnp.where(keep, st, t))
    table_g = table_g.at[se, pos_c].set(jnp.where(keep, sg, 0.0))
    table_t = table_t[:, :cap]
    table_g = table_g[:, :cap]

    # --- this device's expert slice ---
    if ep_size > 1:
        offset = (jnp.asarray(ep_index) * e_loc).astype(jnp.int32)
        tt = jax.lax.dynamic_slice_in_dim(table_t, offset, e_loc, 0)
        tg = jax.lax.dynamic_slice_in_dim(table_g, offset, e_loc, 0)
        we_g = params["experts"]["w_gate"]  # already (E_loc, D, F) under shard_map
        we_u = params["experts"]["w_up"]
        we_d = params["experts"]["w_down"]
    else:
        tt, tg = table_t, table_g
        we_g = params["experts"]["w_gate"]
        we_u = params["experts"]["w_up"]
        we_d = params["experts"]["w_down"]

    x_pad = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)], axis=0)  # sentinel row
    xe = x_pad[tt]  # (E_loc, C, D)

    # expert FFN (grouped einsum over the expert axis)
    gate_act = jnp.einsum("ecd,edf->ecf", xe, we_g.astype(xe.dtype))
    if cfg.mlp_variant == "swiglu":
        up = jnp.einsum("ecd,edf->ecf", xe, we_u.astype(xe.dtype))
        h = jax.nn.silu(gate_act.astype(jnp.float32)).astype(xe.dtype) * up
    else:
        h = jax.nn.gelu(gate_act.astype(jnp.float32)).astype(xe.dtype)
    ye = jnp.einsum("ecf,efd->ecd", h, we_d.astype(xe.dtype))  # (E_loc, C, D)

    # combine: weighted scatter-add back to the token buffer
    y = jnp.zeros((t + 1, d), jnp.float32)
    y = y.at[tt].add(ye.astype(jnp.float32) * tg[..., None])
    y = y[:t]
    if axis_name is not None:
        y = jax.lax.psum(y.astype(combine_dtype), axis_name).astype(jnp.float32)

    if mc.num_shared_experts:
        y = y + mlp(params["shared"], x, cfg.mlp_variant).astype(jnp.float32)

    metrics = {"moe_aux": aux, "moe_drop_frac": drop_frac}
    return y.astype(x.dtype), metrics


def init_moe_or_dense(key, cfg: ArchConfig, layer_idx_in_pattern: int,
                      dtype=jnp.bfloat16) -> dict:
    """MoE or dense MLP params depending on the MoE layout."""
    if cfg.moe is not None and not (
            cfg.moe.layout == "alternate" and layer_idx_in_pattern % 2 == 1):
        return {"kind_moe": init_moe(key, cfg, dtype)}
    return {"kind_dense": init_mlp(key, cfg.d_model, cfg.d_ff, cfg.mlp_variant, dtype)}
