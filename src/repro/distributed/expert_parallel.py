"""Expert parallelism via shard_map (the production MoE path).

Strategy (see models/moe.py docstring): activations replicated over the
`model` axis, experts sharded over it. Every model-rank routes the same
local token set, gathers tokens for ITS expert slice into a capacity
table, runs its experts, scatter-adds, and one psum over `model`
completes the combine — the same all-reduce a Megatron TP block already
pays, so EP adds no extra collective.

The all_to_all dispatch alternative (tokens physically exchanged between
expert shards) is implemented as `a2a` for the §Perf comparison: it
moves 2*T*k*D/|model| bytes through all_to_all instead of T*D through
the psum, which wins when top_k << |model| and loses when activations
were TP-replicated anyway.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import ArchConfig
from repro.models.moe import _capacity, moe_apply, router_probs

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class EPShard:
    """shard_map-based MoE executor bound to a mesh."""

    mesh: Mesh
    model_axis: str = "model"
    token_axes: tuple[str, ...] = ("data",)
    dispatch: str = "psum"  # psum | a2a
    combine_dtype: Any = jnp.float32  # bf16 halves the combine-psum bytes
    # §Perf H2: ZeRO-3 expert weights. Experts arrive FSDP-sharded over
    # `data` and are all-gathered *inside* the shard_map body in their
    # storage dtype (bf16) — half the gather bytes of the GSPMD boundary
    # reshard (which gathers in fp32 on this backend). The AD transpose
    # of all_gather is psum_scatter, so expert-weight gradients leave as
    # reduce-scatters instead of full all-reduces.
    zero3: bool = False

    def _fsdp_dim(self, shape: tuple[int, ...]) -> int | None:
        from repro.distributed.sharding import fsdp_dim

        fs = self.mesh.shape.get("data", 1)
        if fs <= 1 or not self.zero3:
            return None
        # dim 0 (experts) carries `model`; FSDP picks among the rest
        return fsdp_dim(shape, fs, taken=(0,))

    def _specs(self, params: dict) -> dict:
        m = self.model_axis

        def leaf(path, x):
            pstr = "/".join(str(getattr(p, "key", getattr(p, "idx", ""))) for p in path)
            if "experts" in pstr:
                spec: list = [m] + [None] * (len(x.shape) - 1)
                d = self._fsdp_dim(x.shape)
                if d is not None:
                    spec[d] = "data"
                return P(*spec)
            return P(*([None] * len(x.shape)))

        return jax.tree_util.tree_map_with_path(leaf, params)

    def _gather_dims(self, params: dict) -> dict:
        """Per expert-weight gather dim, from GLOBAL shapes (pre-shard_map)."""
        if not self.zero3:
            return {}
        return {name: self._fsdp_dim(w.shape)
                for name, w in params["experts"].items()}

    def moe(self, params: dict, x: Array, cfg: ArchConfig) -> tuple[Array, dict]:
        """x: (T, D) logical-global tokens. Returns (y, metrics)."""
        m = self.model_axis
        ep_size = self.mesh.shape[m]
        x_spec = P(self.token_axes, None)
        p_specs = self._specs(params)
        gather_dims = self._gather_dims(params)

        def zero3_gather(p: dict) -> dict:
            if not gather_dims:
                return p
            experts = {
                name: (jax.lax.all_gather(w, "data", axis=gather_dims[name],
                                          tiled=True)
                       if gather_dims[name] is not None else w)
                for name, w in p["experts"].items()
            }
            return {**p, "experts": experts}

        if self.dispatch == "psum":
            def body(p, xt):
                p = zero3_gather(p)
                idx = jax.lax.axis_index(m)
                y, metrics = moe_apply(p, xt, cfg, axis_name=m,
                                       ep_size=ep_size, ep_index=idx,
                                       combine_dtype=self.combine_dtype)
                metrics = {k: jax.lax.pmean(v, m) for k, v in metrics.items()}
                return y, metrics

            fn = shard_map(body, mesh=self.mesh,
                           in_specs=(p_specs, x_spec),
                           out_specs=(x_spec, {"moe_aux": P(), "moe_drop_frac": P()}),
                           check_rep=False)
            return fn(params, x)

        def body_a2a(p, xt):
            return _moe_all_to_all(zero3_gather(p), xt, cfg, m, ep_size)

        fn = shard_map(body_a2a, mesh=self.mesh,
                       in_specs=(p_specs, P((self.token_axes + (m,)), None)),
                       out_specs=(P((self.token_axes + (m,)), None),
                                  {"moe_aux": P(), "moe_drop_frac": P()}),
                       check_rep=False)
        return fn(params, x)


def _moe_all_to_all(params: dict, x: Array, cfg: ArchConfig, axis: str,
                    ep_size: int) -> tuple[Array, dict]:
    """GShard-style dispatch: tokens travel to their experts via all_to_all.

    Local tokens are packed into (E, C_loc) capacity tables, all_to_all
    swaps the expert axis for the rank axis, experts run on gathered
    tokens, and a second all_to_all returns outputs to their owners.
    """
    mc = cfg.moe
    t, d = x.shape
    e = mc.num_experts
    e_loc = e // ep_size
    cap = _capacity(t, mc) // ep_size + 1  # per-source-rank slots per expert

    gates, idx, aux = router_probs(params, x, mc)
    flat_e = idx.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(t), mc.top_k)
    flat_g = gates.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    grp = jnp.searchsorted(se, jnp.arange(e), side="left")
    pos = jnp.arange(t * mc.top_k) - grp[se]
    keep = pos < cap
    drop_frac = 1.0 - keep.mean()
    pos_c = jnp.minimum(pos, cap)

    table_t = jnp.full((e, cap + 1), t, jnp.int32).at[se, pos_c].set(
        jnp.where(keep, st, t))[:, :cap]
    table_g = jnp.zeros((e, cap + 1), jnp.float32).at[se, pos_c].set(
        jnp.where(keep, sg, 0.0))[:, :cap]

    x_pad = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)], axis=0)
    xe = x_pad[table_t]  # (E, C, D) tokens this rank sends per expert

    # (E, C, D) -> (ep, E_loc, C, D) -> all_to_all over ranks
    xe = xe.reshape(ep_size, e_loc, cap, d)
    xr = jax.lax.all_to_all(xe, axis, split_axis=0, concat_axis=0, tiled=False)
    # xr: (ep, E_loc, C, D) — slot [r] = tokens from rank r for MY experts
    xr = xr.transpose(1, 0, 2, 3).reshape(e_loc, ep_size * cap, d)

    we_g = params["experts"]["w_gate"]  # (E_loc, D, F) under shard_map
    we_u = params["experts"]["w_up"]
    we_d = params["experts"]["w_down"]
    h = jnp.einsum("ecd,edf->ecf", xr, we_g.astype(xr.dtype))
    if cfg.mlp_variant == "swiglu":
        up = jnp.einsum("ecd,edf->ecf", xr, we_u.astype(xr.dtype))
        h = jax.nn.silu(h.astype(jnp.float32)).astype(xr.dtype) * up
    else:
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(xr.dtype)
    ye = jnp.einsum("ecf,efd->ecd", h, we_d.astype(xr.dtype))

    # return trip
    ye = ye.reshape(e_loc, ep_size, cap, d).transpose(1, 0, 2, 3)
    yb = jax.lax.all_to_all(ye, axis, split_axis=0, concat_axis=0, tiled=False)
    yb = yb.reshape(e, cap, d)  # (E, C, D) aligned with table_t

    y = jnp.zeros((t + 1, d), jnp.float32)
    y = y.at[table_t].add(yb.astype(jnp.float32) * table_g[..., None])
    y = y[:t]

    if mc.num_shared_experts:
        from repro.models.layers import mlp

        y = y + mlp(params["shared"], x, cfg.mlp_variant).astype(jnp.float32)
    metrics = {"moe_aux": jax.lax.pmean(aux, axis),
               "moe_drop_frac": jax.lax.pmean(drop_frac, axis)}
    return y.astype(x.dtype), metrics
