"""Fault tolerance: checkpoint/restart, preemption drain, elastic
re-meshing, straggler detection.

Design for 1000+ nodes (DESIGN.md):

* **Checkpoints are logical, not physical**: saved as full (unsharded)
  arrays + a JSON manifest, so a restore may use a *different* mesh —
  that is what makes elastic restart work (lose a pod, re-mesh, resume).
  Writes are atomic (tmp dir + rename) and rolling (keep_last).
* **Preemption drain**: SIGTERM flips a flag; the training loop finishes
  the in-flight step, checkpoints, and exits 0 — the scheduler restarts
  on fresh capacity and `latest_step` resumes.
* **Elastic re-mesh**: `elastic_mesh_shape` picks the largest supported
  (pod, data, model) shape for the surviving device count, preferring
  to shrink the data axis (batch scales down; TP degree is typically a
  hard constraint of the model's memory footprint).
* **Straggler mitigation**: per-step wall times are tracked; a step
  slower than `factor` x rolling median flags the step. On TPU SPMD a
  straggler stalls everyone at the next collective, so mitigation =
  drain + restart without the slow host (policy emitted as an action
  string; actual host exclusion is the scheduler's job).
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import signal
import time
from typing import Any, Iterable

import jax
import numpy as np

Array = jax.Array

MANIFEST = "manifest.json"


# ---------------------------------------------------------------------------
# Checkpoint save/restore
# ---------------------------------------------------------------------------


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path)
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype.kind == "V":  # bfloat16 & friends: store raw uint view
            arr = arr.view(np.uint16 if arr.dtype.itemsize == 2 else np.uint8)
        flat[key] = arr
    return flat


def save_checkpoint(ckpt_dir: str, step: int, tree: Any, *,
                    extra: dict | None = None, keep_last: int = 3) -> str:
    """Atomic rolling checkpoint. Returns the final step directory."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    true_dtypes = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path)
        true_dtypes[key] = str(jax.numpy.asarray(leaf).dtype) \
            if not hasattr(leaf, "dtype") else str(leaf.dtype)
    manifest = {
        "step": step,
        "time": time.time(),
        "keys": sorted(flat),
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "dtypes": true_dtypes,
        "extra": extra or {},
    }
    with open(os.path.join(tmp, MANIFEST), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    # rolling cleanup
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep_last]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:010d}"), ignore_errors=True)
    return final


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp") and \
                os.path.exists(os.path.join(ckpt_dir, name, MANIFEST)):
            out.append(int(name.split("_")[1]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> int | None:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, like: Any,
                       shardings: Any = None) -> Any:
    """Restore into the structure of `like` (values or ShapeDtypeStructs).

    `shardings`: optional matching pytree of NamedShardings — this is the
    elastic path: the mesh used here may differ from the one that saved.
    """
    d = os.path.join(ckpt_dir, f"step_{step:010d}")
    data = np.load(os.path.join(d, "arrays.npz"))
    with open(os.path.join(d, MANIFEST)) as f:
        manifest = json.load(f)
    flat_like = jax.tree_util.tree_flatten_with_path(like)
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else None)
    leaves = []
    for i, (path, leaf) in enumerate(flat_like[0]):
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path)
        arr = data[key]
        true_dtype = manifest.get("dtypes", {}).get(key)
        if true_dtype == "bfloat16" and arr.dtype == np.uint16:
            import ml_dtypes

            arr = arr.view(ml_dtypes.bfloat16)
        assert tuple(arr.shape) == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        if shard_leaves is not None:
            leaves.append(jax.device_put(arr, shard_leaves[i]))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(flat_like[1], leaves)


# ---------------------------------------------------------------------------
# Preemption drain
# ---------------------------------------------------------------------------


class PreemptionHandler:
    """SIGTERM/SIGINT -> drain flag. The train loop checkpoints and exits."""

    def __init__(self, signals: Iterable[int] = (signal.SIGTERM,)):
        self._flag = False
        self._installed = []
        for s in signals:
            try:
                prev = signal.signal(s, self._handle)
                self._installed.append((s, prev))
            except (ValueError, OSError):  # non-main thread
                pass

    def _handle(self, signum, frame):
        self._flag = True

    @property
    def should_drain(self) -> bool:
        return self._flag

    def restore(self) -> None:
        for s, prev in self._installed:
            signal.signal(s, prev)


# ---------------------------------------------------------------------------
# Elastic re-meshing
# ---------------------------------------------------------------------------


def elastic_mesh_shape(n_devices: int, *, model: int = 16,
                       pod_size: int = 256) -> tuple[dict[str, int], int]:
    """Largest (pod, data, model) mesh for the surviving device count.

    TP degree (`model`) is held fixed (model-memory constraint); the data
    axis shrinks first, then pods. Returns (axes dict, devices used).
    Unused survivors become hot spares.
    """
    if n_devices < model:
        raise ValueError(f"need >= {model} devices for TP={model}")
    pods = max(n_devices // pod_size, 1)
    while pods >= 1:
        per_pod = n_devices // pods
        data = per_pod // model
        if data >= 1:
            used = pods * data * model
            axes = {"pod": pods, "data": data, "model": model}
            if pods == 1:
                axes = {"data": data, "model": model}
            return axes, used
        pods -= 1
    raise ValueError("no viable mesh")


@dataclasses.dataclass
class ElasticPlan:
    """What a restart after failure does: re-mesh + resume from step."""

    old_devices: int
    new_devices: int
    new_axes: dict[str, int]
    resume_step: int | None
    spares: int

    def describe(self) -> str:
        return (f"re-mesh {self.old_devices}->{self.new_devices} devices as "
                f"{self.new_axes} (+{self.spares} spares), resume at step "
                f"{self.resume_step}")


def plan_elastic_restart(ckpt_dir: str, old_devices: int, surviving: int,
                         *, model: int = 16, pod_size: int = 256) -> ElasticPlan:
    axes, used = elastic_mesh_shape(surviving, model=model, pod_size=pod_size)
    return ElasticPlan(
        old_devices=old_devices, new_devices=used, new_axes=axes,
        resume_step=latest_step(ckpt_dir), spares=surviving - used)


# ---------------------------------------------------------------------------
# Straggler detection
# ---------------------------------------------------------------------------


class StragglerMonitor:
    """Rolling-median step-time watchdog.

    `observe(dt)` returns an action string when dt exceeds factor x the
    rolling median (None otherwise). Two graded responses:
      * "warn"  — single slow step (transient: host GC, network blip)
      * "drain" — `patience` consecutive slow steps (persistent straggler:
        checkpoint + restart without the slow host)
    """

    def __init__(self, window: int = 32, factor: float = 2.0, patience: int = 3):
        self.window = window
        self.factor = factor
        self.patience = patience
        self.times: list[float] = []
        self.slow_streak = 0

    def observe(self, dt: float) -> str | None:
        med = float(np.median(self.times)) if len(self.times) >= 8 else None
        self.times.append(dt)
        if len(self.times) > self.window:
            self.times.pop(0)
        if med is None:
            return None
        if dt > self.factor * med:
            self.slow_streak += 1
            if self.slow_streak >= self.patience:
                self.slow_streak = 0
                return "drain"
            return "warn"
        self.slow_streak = 0
        return None
