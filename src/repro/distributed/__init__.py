"""Distributed runtime: sharding rules, expert parallelism, flash-decode,
distributed EMVS, gradient compression, fault tolerance."""
