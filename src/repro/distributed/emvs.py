"""Distributed EMVS: the paper's technique as a first-class multi-pod feature.

Parallelism mapping (DESIGN.md §2, mirrors the FPGA's three levels):

  axis     paper's level                 here
  -----    -------------------------     ------------------------------
  model    DSI-level (multiple PE_Zi)    depth planes sharded; each rank
                                         votes its own plane slice —
                                         ZERO communication during R
  data     event-level (pipelining)      event frames sharded; partial
                                         DSIs merged by ONE integer psum
                                         (votes are additive => exact)
  pod      key-frame level (new)         segments processed concurrently;
                                         local DSIs independent by
                                         construction (DSI resets per
                                         key frame) — pods only exchange
                                         final depth maps

Two entry points:

  * `make_emvs_step` — the data/model(/pod)-sharded step over
    precomputed geometry (H, phi), used by the production dry-run. The
    vote merge is an integer psum ONLY on the nearest datapath, where
    votes are integral counts — the lossless counterpart of the int8
    gradient compression in `compression.py` (the paper's bandwidth
    insight: narrow integer payloads on the links). Bilinear votes carry
    fractional weights and stay float32 through the merge.

  * `process_segments_sharded` — the key-frame-level production backend:
    consumes the exact `SegmentBatch` of
    `repro.core.pipeline.process_segments_batched` (frame padding votes
    zero via `frame_valid`) and runs the same sweep body with the
    segment axis sharded across mesh devices, so concurrent segments
    vote on different devices. Selectable via `run_emvs(sweep="sharded")`
    and `StreamConfig(sweep="sharded")`; per-segment outputs are
    bit-identical to the batched backend on the integer/nearest
    datapaths and allclose on bilinear (tests/test_sharded_sweep.py).
"""
from __future__ import annotations

from functools import lru_cache

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.camera import CameraModel
from repro.core.detection import DepthMap, detect_structure
from repro.core.dsi import DSIConfig
from repro.core.geometry import PlaneSweepCoeffs, apply_homography, propagate_to_planes
from repro.core.pipeline import EMVSOptions, SegmentBatch, sweep_segment_batch
from repro.core.voting import vote_onehot_matmul

Array = jax.Array

# Mesh axis name of the key-frame-segment axis (the `pod` level above,
# spelled out: independent segments shard across devices).
SEGMENT_AXIS = "segments"


def _vote_local(cam: CameraModel, xy: Array, valid: Array, frame_valid: Array,
                H: Array, phi: Array, nz_local: int, mode: str) -> Array:
    """Vote local frames into a local (Nz_loc, h, w) plane slice (scan).

    `frame_valid` is the per-frame padding mask of `SegmentBatch`: padded
    frames repeat a real frame (finite geometry) and vote with weight 0,
    so callers no longer need F to divide the data axis exactly.
    """
    dsi0 = jnp.zeros((nz_local, cam.height, cam.width), jnp.float32)

    def body(dsi, frame):
        xy_f, valid_f, fv_f, H_f, phi_f = frame
        xy0 = apply_homography(H_f, xy_f)
        coeffs = PlaneSweepCoeffs(phi_f[:, 0], phi_f[:, 1], phi_f[:, 2])
        x_i, y_i = propagate_to_planes(cam, xy0, coeffs)
        w = valid_f.astype(jnp.float32) * fv_f.astype(jnp.float32)
        w = jnp.broadcast_to(w[None, :], x_i.shape)
        return vote_onehot_matmul(dsi, x_i, y_i, w=cam.width, h=cam.height,
                                  mode=mode, weights=w), None

    dsi, _ = jax.lax.scan(body, dsi0, (xy, valid, frame_valid, H, phi))
    return dsi


def make_emvs_step(cam: CameraModel, dsi_cfg: DSIConfig, mesh: Mesh, *,
                   mode: str = "nearest", data_axis: str = "data",
                   model_axis: str = "model", pod_axis: str | None = None,
                   vote_dtype=jnp.int32):
    """Build the sharded EMVS segment step for `mesh`.

    Inputs (global logical shapes; leading G = segments when pod_axis —
    see `emvs_input_specs`, which mirrors them):
        xy          (G?, F, E, 2)   valid (G?, F, E)
        frame_valid (G?, F)         H     (G?, F, 3, 3)
        phi         (G?, F, Nz, 3)
    Returns (dsi (G?, Nz, h, w) z-sharded, depth, mask, conf (G?, h, w)).
    dsi is int32 for nearest voting, float32 for bilinear.
    """
    nz = dsi_cfg.num_planes
    n_model = mesh.shape[model_axis]
    assert nz % n_model == 0, (nz, n_model)
    nz_loc = nz // n_model
    planes_all = dsi_cfg.planes()

    def seg_body(xy, valid, frame_valid, H, phi):
        # local: xy (F_loc, E, 2), phi (F_loc, Nz_loc, 3)
        dsi = _vote_local(cam, xy, valid, frame_valid, H, phi, nz_loc, mode)
        if mode == "nearest":
            # event-level merge: ONE integer all-reduce (exact for the
            # integral nearest counts). §Perf E2: int16 (the paper's
            # Table-1 DSI width) halves the link payload; per-shard
            # partial counts <= events/shard << 32767, and the int32
            # upcast after the psum keeps downstream math exact.
            dsi = jax.lax.psum(dsi.astype(vote_dtype), data_axis)
            dsi = dsi.astype(jnp.int32)
        else:
            # bilinear votes are fractional weights: narrowing the link
            # payload to an integer dtype would silently truncate them,
            # so the merge stays float32 (still one all-reduce).
            dsi = jax.lax.psum(dsi, data_axis)
        # detection needs full-z per pixel: gather plane slices over model
        dsi_full = jax.lax.all_gather(dsi, model_axis, axis=0, tiled=True)
        dm = detect_structure(dsi_full.astype(jnp.float32), planes_all)
        return dsi, dm.depth, dm.mask, dm.confidence

    if pod_axis is None:
        in_specs = (P(data_axis, None, None), P(data_axis, None),
                    P(data_axis),
                    P(data_axis, None, None), P(data_axis, model_axis, None))
        out_specs = (P(model_axis, None, None), P(), P(), P())
        body = seg_body
    else:
        # key-frame-level parallelism: leading segment axis over pods
        def body(xy, valid, frame_valid, H, phi):
            return jax.vmap(seg_body)(xy, valid, frame_valid, H, phi)

        in_specs = (P(pod_axis, data_axis, None, None), P(pod_axis, data_axis, None),
                    P(pod_axis, data_axis),
                    P(pod_axis, data_axis, None, None),
                    P(pod_axis, data_axis, model_axis, None))
        out_specs = (P(pod_axis, model_axis, None, None), P(pod_axis),
                     P(pod_axis), P(pod_axis))

    return shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


def emvs_input_specs(dsi_cfg: DSIConfig, *, frames: int, events: int,
                     segments: int | None = None) -> dict:
    """ShapeDtypeStruct stand-ins for the distributed EMVS step (dry-run).

    Regenerated from the `SegmentBatch`-shaped pipeline inputs: `xy`,
    `valid` and `frame_valid` are exactly the event-side fields of
    `repro.core.pipeline.SegmentBatch` (frame padding votes zero through
    `frame_valid`); `H`/`phi` replace the batch's raw poses because the
    distributed step consumes precomputed ARM-side geometry.

    Segment axis: when `segments` is not None the specs gain the leading
    G axis consumed by the pod path (`make_emvs_step(pod_axis=...)`),
    which shards whole key-frame segments across pods — G must divide
    the pod axis size. The order of the returned dict is the positional
    argument order of the step.
    """
    lead = () if segments is None else (segments,)
    f32 = jnp.float32
    return {
        "xy": jax.ShapeDtypeStruct(lead + (frames, events, 2), f32),
        "valid": jax.ShapeDtypeStruct(lead + (frames, events), f32),
        "frame_valid": jax.ShapeDtypeStruct(lead + (frames,), f32),
        "H": jax.ShapeDtypeStruct(lead + (frames, 3, 3), f32),
        "phi": jax.ShapeDtypeStruct(lead + (frames, dsi_cfg.num_planes, 3), f32),
    }


# ---------------------------------------------------------------------------
# Key-frame-level segment sharding: the production `sweep="sharded"` backend
# ---------------------------------------------------------------------------


def make_segment_mesh(devices=None) -> Mesh:
    """1-D mesh over all (or the given) devices with the segment axis.

    The default backend mesh for `run_emvs(sweep="sharded")` and
    `EMVSStreamEngine` with `StreamConfig(sweep="sharded")`.
    """
    devs = list(jax.devices()) if devices is None else list(devices)
    return jax.make_mesh((len(devs),), (SEGMENT_AXIS,), devices=devs)


def segment_axis_size(mesh: Mesh, axis_name: str = SEGMENT_AXIS) -> int:
    """Size of the mesh's segment axis, with a clear error when absent.

    A user-supplied mesh must name its segment axis `axis_name` (default
    "segments"); without this check a mismatched mesh would surface as an
    opaque KeyError deep inside the sweep wiring.
    """
    if axis_name not in mesh.axis_names:
        raise ValueError(
            f"mesh has axes {mesh.axis_names} but the sharded sweep needs a "
            f"'{axis_name}' axis — build the mesh with make_segment_mesh() "
            f"or name its segment axis '{axis_name}'")
    return mesh.shape[axis_name]


@lru_cache(maxsize=None)
def _sharded_sweep_fn(cam: CameraModel, dsi_cfg: DSIConfig, opts: EMVSOptions,
                      mesh: Mesh, axis_name: str):
    """jit(shard_map(sweep body)) for one (options, mesh) combination.

    The shard_map body is `sweep_segment_batch` — the identical traced
    program `process_segments_batched` jits — applied to each device's
    local (S/n, ...) slice of the batch. Segments are independent by
    construction (the DSI resets per key frame), so there are ZERO
    collectives: the only communication is the output gather jit inserts
    when the caller reads the sharded result.
    """
    spec = P(axis_name)

    def local(batch: SegmentBatch):
        return sweep_segment_batch(cam, dsi_cfg, batch, opts)

    # A single PartitionSpec acts as a pytree prefix: every SegmentBatch
    # leaf (and every output leaf) shards its leading segment axis.
    return jax.jit(shard_map(local, mesh=mesh, in_specs=(spec,),
                             out_specs=spec, check_rep=False))


def process_segments_sharded(
    cam: CameraModel,
    dsi_cfg: DSIConfig,
    batch: SegmentBatch,
    opts: EMVSOptions,
    *,
    mesh: Mesh | None = None,
    axis_name: str = SEGMENT_AXIS,
) -> tuple[Array, DepthMap]:
    """`process_segments_batched` with the segment axis sharded over `mesh`.

    Drop-in `sweep="sharded"` backend: consumes the same `SegmentBatch`
    (padded frames vote zero via `frame_valid`), applies the same
    `EMVSOptions` surface (all three formulations, nearest/bilinear,
    quantized int16 store, detection thresholds, median filter), and
    returns the same stacked (S, ...) outputs. The batch's segment count
    S must be a multiple of the mesh's segment-axis size; callers pad S
    by repeating a real segment (`run_emvs` and the streaming engine's
    S-bucketing both do) — padded rows are discarded work, never a
    numerics change.

    Per-segment outputs are bit-identical to the batched sweep on the
    integer/nearest datapaths and allclose on bilinear: both backends
    trace the exact same per-segment program; only the axis the segments
    are laid out over differs.
    """
    if mesh is None:
        mesh = make_segment_mesh()
    n = segment_axis_size(mesh, axis_name)
    s = batch.xy.shape[0]
    if s % n != 0:
        raise ValueError(
            f"segment count {s} is not a multiple of the mesh's "
            f"'{axis_name}' axis size {n}; pad the segment list (repeat a "
            f"real segment) before calling process_segments_sharded")
    return _sharded_sweep_fn(cam, dsi_cfg, opts, mesh, axis_name)(batch)
