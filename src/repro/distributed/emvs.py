"""Distributed EMVS: the paper's technique as a first-class multi-pod feature.

Parallelism mapping (DESIGN.md §2, mirrors the FPGA's three levels):

  axis     paper's level                 here
  -----    -------------------------     ------------------------------
  model    DSI-level (multiple PE_Zi)    depth planes sharded; each rank
                                         votes its own plane slice —
                                         ZERO communication during R
  data     event-level (pipelining)      event frames sharded; partial
                                         DSIs merged by ONE integer psum
                                         (votes are additive => exact)
  pod      key-frame level (new)         segments processed concurrently;
                                         local DSIs independent by
                                         construction (DSI resets per
                                         key frame) — pods only exchange
                                         final depth maps

The vote merge is an int32 psum — the lossless counterpart of the int8
gradient compression in `compression.py` (the paper's bandwidth insight:
narrow integer payloads on the links).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.camera import CameraModel
from repro.core.detection import DepthMap, detect_structure
from repro.core.dsi import DSIConfig
from repro.core.geometry import PlaneSweepCoeffs, apply_homography, propagate_to_planes
from repro.core.voting import vote_onehot_matmul

Array = jax.Array


def _vote_local(cam: CameraModel, xy: Array, valid: Array, H: Array,
                phi: Array, nz_local: int, mode: str) -> Array:
    """Vote local frames into a local (Nz_loc, h, w) plane slice (scan)."""
    dsi0 = jnp.zeros((nz_local, cam.height, cam.width), jnp.float32)

    def body(dsi, frame):
        xy_f, valid_f, H_f, phi_f = frame
        xy0 = apply_homography(H_f, xy_f)
        coeffs = PlaneSweepCoeffs(phi_f[:, 0], phi_f[:, 1], phi_f[:, 2])
        x_i, y_i = propagate_to_planes(cam, xy0, coeffs)
        w = jnp.broadcast_to(valid_f.astype(jnp.float32)[None, :], x_i.shape)
        return vote_onehot_matmul(dsi, x_i, y_i, w=cam.width, h=cam.height,
                                  mode=mode, weights=w), None

    dsi, _ = jax.lax.scan(body, dsi0, (xy, valid, H, phi))
    return dsi


def make_emvs_step(cam: CameraModel, dsi_cfg: DSIConfig, mesh: Mesh, *,
                   mode: str = "nearest", data_axis: str = "data",
                   model_axis: str = "model", pod_axis: str | None = None,
                   vote_dtype=jnp.int32):
    """Build the sharded EMVS segment step for `mesh`.

    Inputs (global logical shapes; leading G = segments when pod_axis):
        xy    (G?, F, E, 2)   valid (G?, F, E)
        H     (G?, F, 3, 3)   phi   (G?, F, Nz, 3)
    Returns (dsi (G?, Nz, h, w) int32 z-sharded, depth, mask, conf (G?, h, w)).
    """
    nz = dsi_cfg.num_planes
    n_model = mesh.shape[model_axis]
    assert nz % n_model == 0, (nz, n_model)
    nz_loc = nz // n_model
    planes_all = dsi_cfg.planes()

    def seg_body(xy, valid, H, phi):
        # local: xy (F_loc, E, 2), phi (F_loc, Nz_loc, 3)
        dsi = _vote_local(cam, xy, valid, H, phi, nz_loc, mode)
        # event-level merge: ONE integer all-reduce (exact). §Perf E2:
        # int16 (the paper's Table-1 DSI width) halves the link payload;
        # per-shard partial counts <= events/shard << 32767, and the
        # int32 upcast after the psum keeps downstream math exact.
        dsi = jax.lax.psum(dsi.astype(vote_dtype), data_axis).astype(jnp.int32)
        # detection needs full-z per pixel: gather plane slices over model
        dsi_full = jax.lax.all_gather(dsi, model_axis, axis=0, tiled=True)
        dm = detect_structure(dsi_full.astype(jnp.float32), planes_all)
        return dsi, dm.depth, dm.mask, dm.confidence

    if pod_axis is None:
        in_specs = (P(data_axis, None, None), P(data_axis, None),
                    P(data_axis, None, None), P(data_axis, model_axis, None))
        out_specs = (P(model_axis, None, None), P(), P(), P())
        body = seg_body
    else:
        # key-frame-level parallelism: leading segment axis over pods
        def body(xy, valid, H, phi):
            return jax.vmap(seg_body)(xy, valid, H, phi)

        in_specs = (P(pod_axis, data_axis, None, None), P(pod_axis, data_axis, None),
                    P(pod_axis, data_axis, None, None),
                    P(pod_axis, data_axis, model_axis, None))
        out_specs = (P(pod_axis, model_axis, None, None), P(pod_axis),
                     P(pod_axis), P(pod_axis))

    return shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


def emvs_input_specs(dsi_cfg: DSIConfig, *, frames: int, events: int,
                     segments: int | None = None) -> dict:
    """ShapeDtypeStruct stand-ins for the distributed EMVS step (dry-run)."""
    lead = () if segments is None else (segments,)
    f32 = jnp.float32
    return {
        "xy": jax.ShapeDtypeStruct(lead + (frames, events, 2), f32),
        "valid": jax.ShapeDtypeStruct(lead + (frames, events), f32),
        "H": jax.ShapeDtypeStruct(lead + (frames, 3, 3), f32),
        "phi": jax.ShapeDtypeStruct(lead + (frames, dsi_cfg.num_planes, 3), f32),
    }
