"""Gradient compression for data-parallel all-reduce, with error feedback.

The paper's hybrid-quantization insight (§2.3: short fixed-point halves
memory AND bandwidth) applied to the dominant cross-pod collective of
large-scale training: the gradient all-reduce. Gradients are quantized
to int8 with a per-block fp32 scale before the psum and dequantized
after; the quantization residual is carried into the next step (error
feedback), which keeps SGD-style convergence unbiased in the long run
[Seide'14, Karimireddy'19].

Exactness note mirroring the paper: DSI votes are integers, so the
EMVS vote all-reduce (distributed/emvs.py) compresses to int32/int16
*losslessly*; LM gradients are real-valued, so compression there is
lossy + error-fed-back. Both halve (or better) link bytes.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array

BLOCK = 256  # per-block scaling granularity (channels folded into blocks)


class CompressionState(NamedTuple):
    """Error-feedback residual, same pytree structure as the gradients."""

    residual: Any


def init_state(grads_like: Any) -> CompressionState:
    return CompressionState(
        residual=jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads_like))


def _quantize_blockwise(g: Array) -> tuple[Array, Array, tuple[int, ...]]:
    """g -> (int8 q, fp32 per-block scale, original shape)."""
    shape = g.shape
    flat = g.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(blocks / jnp.maximum(scale, 1e-30)), -127, 127)
    return q.astype(jnp.int8), scale, shape


def _dequantize_blockwise(q: Array, scale: Array, shape: tuple[int, ...]) -> Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def compress_decompress(g: Array) -> Array:
    """Round-trip quantization (the lossy view each rank contributes)."""
    q, scale, shape = _quantize_blockwise(g)
    return _dequantize_blockwise(q, scale, shape)


def compressed_psum(grads: Any, state: CompressionState, axis: str
                    ) -> tuple[Any, CompressionState]:
    """int8-compressed gradient all-reduce with error feedback.

    Usage inside shard_map over the data/pod axes: each rank holds its
    local gradient; returns the mean gradient (approximate) and the new
    residual state. Wire format per tensor: int8 payload + fp32 scale
    per 256-block = ~8.25 bits/val vs 32 (3.9x link-byte reduction).
    """

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        q, scale, shape = _quantize_blockwise(gf)
        sent = _dequantize_blockwise(q, scale, shape)
        new_r = gf - sent  # residual stays local (error feedback)
        # the all-reduce runs over the DEQUANTIZED int8 payload: on real
        # hardware the int8+scale pair is what crosses the links; psum of
        # the dequantized view is numerically identical to scale-aligned
        # int accumulation and keeps the HLO a single all-reduce.
        total = jax.lax.psum(sent, axis)
        return total / jax.lax.psum(1.0, axis), new_r

    g_leaves, treedef = jax.tree.flatten(grads)
    r_leaves = jax.tree.leaves(state.residual)
    out = [one(g, r) for g, r in zip(g_leaves, r_leaves)]
    mean = jax.tree.unflatten(treedef, [o[0] for o in out])
    res = jax.tree.unflatten(treedef, [o[1] for o in out])
    return mean, CompressionState(residual=res)


def compression_error(g: Array) -> Array:
    """Relative L2 error of one round trip (diagnostics/tests)."""
    d = compress_decompress(g) - g.astype(jnp.float32)
    return jnp.linalg.norm(d) / jnp.maximum(jnp.linalg.norm(g), 1e-30)
