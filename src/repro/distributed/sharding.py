"""Sharding rules: logical roles -> PartitionSpecs (MaxText-style).

One rule table maps parameter *roles* (inferred from tree paths) to mesh
axes, with divisibility guards, so a mesh change (16x16 single-pod vs
2x16x16 multi-pod) or an arch change (kv heads 4..32, experts 16..384,
vocab divisible or not) is config-only — no per-model spec tables.

Axes semantics (launch/mesh.py):
  pod    cross-pod data parallelism (params replicated across pods;
         gradient all-reduce crosses the pod axis once per step)
  data   in-pod data parallelism + FSDP param sharding
  model  tensor/expert parallelism (Megatron-style within a pod)

Key guards (DESIGN.md §6):
  * heads shard over `model` only when the head count divides |model|;
    GQA K/V heads replicate when kv_heads < |model| (standard GQA-TP).
  * vocab shards over `model` only when divisible (mamba2's 50280 is
    not: embed/lm_head replicate over model, shard over data via FSDP).
  * MoE experts shard over `model` (EP); expert count always divides.
  * FSDP shards the largest remaining dim of each big leaf over `data`.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ArchConfig

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ShardingPlan:
    """Which mesh axes play which role for one run."""

    batch_axes: tuple[str, ...]  # e.g. ("pod", "data") — batch dim sharding
    model_axis: str | None  # tensor/expert parallel axis
    fsdp_axes: tuple[str, ...] = ("data",)  # param-shard axes (within pod)
    fsdp: bool = True  # shard params/opt-state over fsdp_axes

    @staticmethod
    def for_mesh(mesh: Mesh, fsdp: bool = True) -> "ShardingPlan":
        names = mesh.axis_names
        model = "model" if "model" in names else None
        batch = tuple(n for n in names if n in ("pod", "data"))
        return ShardingPlan(batch_axes=batch, model_axis=model,
                            fsdp_axes=("data",) if "data" in names else (),
                            fsdp=fsdp)


def _axis_size(mesh: Mesh, name: str | None) -> int:
    if name is None or name not in mesh.axis_names:
        return 1
    return mesh.shape[name]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
    return "/".join(parts)


# ---------------------------------------------------------------------------
# Parameter sharding
# ---------------------------------------------------------------------------


def _param_rule(path: str, shape: tuple[int, ...], cfg: ArchConfig,
                mesh: Mesh, plan: ShardingPlan) -> P:
    """Logical TP/EP spec for one parameter leaf (no FSDP yet)."""
    tp = _axis_size(mesh, plan.model_axis)
    m = plan.model_axis
    none = P()

    def last_dim_over_model(div: int) -> P:
        if tp > 1 and div % tp == 0:
            return P(*([None] * (len(shape) - 1) + [m]))
        return none

    def dim_over_model(axis: int, div: int) -> P:
        if tp > 1 and div % tp == 0:
            spec: list = [None] * len(shape)
            spec[axis] = m
            return P(*spec)
        return none

    in_blocks = path.startswith("blocks/")

    # --- embeddings / head ---
    if path.endswith("embed/table") or path == "lm_head":
        return dim_over_model(0, shape[0])  # vocab

    if not in_blocks:
        return none  # final_norm etc.

    # --- attention ---
    if "/attn/" in path:
        hq, hkv = cfg.n_heads_eff, cfg.n_kv_heads_eff
        if path.endswith(("wq/w", "wq/b")):
            return last_dim_over_model(hq) if hq % max(tp, 1) == 0 else none
        if path.endswith(("wk/w", "wk/b", "wv/w", "wv/b")):
            return last_dim_over_model(hkv) if hkv % max(tp, 1) == 0 else none
        if path.endswith("wo/w"):
            return dim_over_model(1, hq) if hq % max(tp, 1) == 0 else none
        return none  # qk-norm scales, wo bias

    # --- MoE ---
    if "/moe/" in path:
        if "/experts/" in path:
            return dim_over_model(1, shape[1])  # (n_sb, E, ..): EP over experts
        if "/shared/" in path:
            if path.endswith(("w_gate", "w_up")):
                return last_dim_over_model(shape[-1])
            if path.endswith("w_down"):
                return dim_over_model(1, shape[1])
        return none  # router

    # --- dense MLP ---
    if "/dense/" in path or "/ffn/" in path:
        if path.endswith(("w_gate", "w_up", "b_up")):
            return last_dim_over_model(shape[-1])
        if path.endswith("w_down"):
            return dim_over_model(1, shape[1])
        return none  # b_down (output-dim bias stays replicated)

    # --- Mamba-2 (head-aligned streams shard; B/C replicate) ---
    if "/mamba/" in path:
        nh = cfg.ssm.num_heads(cfg.d_model) if cfg.ssm else 0
        head_ok = tp > 1 and nh % tp == 0
        if not head_ok:
            return none
        if path.endswith(("w_z/w", "w_x/w", "w_dt/w")):
            return P(*([None] * (len(shape) - 1) + [m]))
        if path.endswith(("conv_x_w", "conv_x_b", "norm")):
            return P(*([None] * (len(shape) - 1) + [m]))
        if path.endswith(("A_log", "dt_bias", "D")):
            return P(None, m)  # (n_sb, nh)
        if path.endswith("out_proj/w"):
            return P(None, m, None)
        return none  # w_B, w_C, conv_B*, conv_C*, biases

    return none


def _add_fsdp(spec: P, shape: tuple[int, ...], mesh: Mesh,
              plan: ShardingPlan, min_size: int = 2 ** 16) -> P:
    """Shard the largest unsharded dim over the fsdp axes (if divisible)."""
    if not plan.fsdp or not plan.fsdp_axes:
        return spec
    import numpy as np

    if int(np.prod(shape)) < min_size:
        return spec  # tiny leaves stay replicated
    fs = 1
    for a in plan.fsdp_axes:
        fs *= _axis_size(mesh, a)
    if fs <= 1:
        return spec
    entries = list(spec) + [None] * (len(shape) - len(spec))
    # candidate dims: unsharded, divisible; prefer the largest
    cands = [i for i in range(len(shape))
             if entries[i] is None and shape[i] % fs == 0]
    if not cands:
        return spec
    best = max(cands, key=lambda i: shape[i])
    ax = plan.fsdp_axes if len(plan.fsdp_axes) > 1 else plan.fsdp_axes[0]
    entries[best] = ax
    return P(*entries)


def fsdp_dim(shape: tuple[int, ...], fs: int, taken: tuple[int, ...] = ()
             ) -> int | None:
    """Which dim _add_fsdp would shard: the largest free, divisible one."""
    cands = [i for i in range(len(shape))
             if i not in taken and shape[i] % fs == 0]
    return max(cands, key=lambda i: shape[i]) if cands else None


def param_specs(cfg: ArchConfig, params_shape: Any, mesh: Mesh,
                plan: ShardingPlan) -> Any:
    """PartitionSpec tree for the parameter pytree (shapes via eval_shape)."""

    def leaf(path, x):
        spec = _param_rule(_path_str(path), x.shape, cfg, mesh, plan)
        return _add_fsdp(spec, x.shape, mesh, plan)

    return jax.tree_util.tree_map_with_path(leaf, params_shape)


def param_shardings(cfg: ArchConfig, params_shape: Any, mesh: Mesh,
                    plan: ShardingPlan) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(cfg, params_shape, mesh, plan),
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Batch / activation / decode-state sharding
# ---------------------------------------------------------------------------


def batch_spec(shape: tuple[int, ...], mesh: Mesh, plan: ShardingPlan) -> P:
    """Shard dim 0 (global batch) over the batch axes, if divisible."""
    bs = 1
    for a in plan.batch_axes:
        bs *= _axis_size(mesh, a)
    if shape and bs > 1 and shape[0] % bs == 0:
        return P(plan.batch_axes, *([None] * (len(shape) - 1)))
    return P(*([None] * len(shape)))


def input_shardings(specs: dict, mesh: Mesh, plan: ShardingPlan) -> dict:
    return {k: NamedSharding(mesh, batch_spec(v.shape, mesh, plan))
            for k, v in specs.items()}


def decode_state_specs(cfg: ArchConfig, state_shape: Any, mesh: Mesh,
                       plan: ShardingPlan) -> Any:
    """Decode-state sharding.

    KV caches (n_sb, B, S, Hkv, D) are the dominant serving footprint
    (e.g. qwen3 decode_32k: 618 GB global) — batch sharding alone leaves
    38 GB/chip. So: batch over the batch axes AND sequence over `model`
    (kv heads rarely divide |model|); when batch=1 (long-context) the
    sequence takes BOTH data and model axes — GSPMD then computes the
    partial-softmax combine, i.e. distributed flash-decoding falls out
    of the sharding. SSD states shard heads over `model`.
    """
    tp = _axis_size(mesh, plan.model_axis)
    m = plan.model_axis

    def leaf(path, x):
        p = _path_str(path)
        shape = x.shape  # leading (n_sb,)
        bs = 1
        for a in plan.batch_axes:
            bs *= _axis_size(mesh, a)
        batch = shape[1] if len(shape) > 1 else 1
        batch_ok = bs > 1 and batch % bs == 0
        is_kv = ("/k" in p or "/v" in p) and len(shape) == 5
        if is_kv:
            seq = shape[2]
            if batch_ok:  # batch over (pod, data); sequence over model
                if tp > 1 and seq % tp == 0:
                    return P(None, plan.batch_axes, m, None, None)
                return P(None, plan.batch_axes, None, None, None)
            # batch=1: sequence over every batch axis + model
            seq_axes = tuple(a for a in (plan.batch_axes + ((m,) if m else ()))
                             if _axis_size(mesh, a) > 1)
            total = 1
            for a in seq_axes:
                total *= _axis_size(mesh, a)
            if seq_axes and seq % total == 0:
                return P(None, None, seq_axes, None, None)
            return P(*([None] * len(shape)))
        if batch_ok and len(shape) > 1:
            return P(None, plan.batch_axes, *([None] * (len(shape) - 2)))
        # SSD state (n_sb, B, H, N, P): heads over model
        if p.endswith("ssd") and len(shape) == 5 and tp > 1 and shape[2] % tp == 0:
            return P(None, None, m, None, None)
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(leaf, state_shape)


def tree_shardings(spec_tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def constrain(x: Array, mesh: Mesh, spec: P) -> Array:
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
