"""Distributed flash-decoding: KV-sequence-sharded one-token attention.

For long-context decode (the `long_500k` cell: batch 1, KV 524288) the
batch axis cannot absorb the `data` mesh axis, so the KV *sequence* is
sharded instead. Each rank computes a partial online-softmax triple
(m, l, acc) over its KV slice; the combine is three tiny collectives
(pmax + 2 psum) of O(B*H*D) — the distributed analogue of split-K
flash-decoding, and the beyond-paper counterpart of the paper's
DSI-level parallelism (partial results merged by an exact reduction,
like partial DSI votes merged by psum).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SeqShard:
    """shard_map flash-decode bound to a mesh. KV sharded over `seq_axis`."""

    mesh: Mesh
    seq_axis: str = "data"

    def decode_attention(self, q: Array, k: Array, v: Array, length: Array
                         ) -> Array:
        """q (B,1,Hq,D) replicated; k/v (B,S,Hkv,D) sharded on S. length:
        scalar int32 — number of valid cache entries."""
        ax = self.seq_axis
        nshards = self.mesh.shape[ax]
        s_global = k.shape[1]
        s_local = s_global // nshards

        def body(q, k, v, length):
            r = jax.lax.axis_index(ax)
            b, _, hq, d = q.shape
            hkv = k.shape[2]
            g = hq // hkv
            qh = q[:, 0].reshape(b, hkv, g, d)
            s = jnp.einsum("bhgd,bkhd->bhgk", qh, k,
                           preferred_element_type=jnp.float32) / d ** 0.5
            pos = r * s_local + jnp.arange(s_local)[None, None, None, :]
            s = jnp.where(pos < length, s, -jnp.inf)
            m_loc = jnp.max(s, axis=-1, keepdims=True)  # (b,hkv,g,1)
            m_loc = jnp.maximum(m_loc, -1e30)  # rank with no valid keys
            p = jnp.exp(s - m_loc)
            p = jnp.where(pos < length, p, 0.0)
            l_loc = jnp.sum(p, axis=-1, keepdims=True)
            acc_loc = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v.dtype), v,
                                 preferred_element_type=jnp.float32)
            # exact combine across shards
            m = jax.lax.pmax(m_loc, ax)
            corr = jnp.exp(m_loc - m)
            l = jax.lax.psum(l_loc * corr, ax)
            acc = jax.lax.psum(acc_loc * corr, ax)
            out = acc / jnp.maximum(l, 1e-30)
            return out.reshape(b, 1, hq, d).astype(q.dtype)

        fn = shard_map(
            body, mesh=self.mesh,
            in_specs=(P(), P(None, self.seq_axis, None, None),
                      P(None, self.seq_axis, None, None), P()),
            out_specs=P(),
            check_rep=False,
        )
        return fn(q, k, v, jnp.asarray(length))
