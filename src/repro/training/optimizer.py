"""AdamW + cosine schedule, from scratch (no optax dependency).

Low-bit optimizer state is a first-class option (`state_dtype`):
m/v stored in bf16 halves optimizer memory — the paper's
hybrid-quantization principle (Table 1: keep precision where it matters,
shorten it where it does not) applied to the largest memory consumer of
large-scale training. For the 1T-param config this is the difference
between fitting and not fitting a 512-chip v5e pod pair (see
EXPERIMENTS.md §Dry-run).

The update math always runs in fp32; only the *stored* state is cast.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    min_lr_frac: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: Any = jnp.float32  # bf16 halves optimizer memory


class OptState(NamedTuple):
    step: Array  # () int32
    m: Any  # pytree like params
    v: Any


def cosine_lr(cfg: AdamWConfig, step: Array) -> Array:
    """Linear warmup -> cosine decay to min_lr_frac * peak."""
    s = step.astype(jnp.float32)
    warm = cfg.peak_lr * s / max(cfg.warmup_steps, 1)
    prog = jnp.clip((s - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(s < cfg.warmup_steps, warm, cfg.peak_lr * cos)


def init_opt_state(params: Any, cfg: AdamWConfig) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
    return OptState(step=jnp.zeros((), jnp.int32),
                    m=jax.tree.map(zeros, params),
                    v=jax.tree.map(zeros, params))


def global_norm(tree: Any) -> Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def _is_matrix(p: Array) -> bool:
    """Weight decay applies to matrices only (norms/biases/scalars exempt)."""
    return p.ndim >= 2


def adamw_update(params: Any, grads: Any, state: OptState, cfg: AdamWConfig
                 ) -> tuple[Any, OptState, dict]:
    step = state.step + 1
    lr = cosine_lr(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))

    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if _is_matrix(p):
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return (newp.astype(p.dtype), m32.astype(cfg.state_dtype),
                v32.astype(cfg.state_dtype))

    # flatten explicitly: the params tree itself contains tuples, so a
    # tuple-is_leaf unzip would mistake structure for leaves
    p_leaves, treedef = jax.tree.flatten(params)
    g_leaves = jax.tree.leaves(grads)
    m_leaves = jax.tree.leaves(state.m)
    v_leaves = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(p_leaves, g_leaves, m_leaves, v_leaves)]
    newp = jax.tree.unflatten(treedef, [o[0] for o in out])
    newm = jax.tree.unflatten(treedef, [o[1] for o in out])
    newv = jax.tree.unflatten(treedef, [o[2] for o in out])
    return newp, OptState(step=step, m=newm, v=newv), {
        "lr": lr, "grad_norm": gnorm}
