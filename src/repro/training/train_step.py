"""Train step builder: loss -> grads -> AdamW, with remat, microbatch
gradient accumulation, mixed precision, and mesh-aware sharding.

The returned step is a single jit-compiled program. Distribution is
declared, not hand-written: in_shardings/out_shardings come from
`distributed.sharding` rules and GSPMD inserts the collectives (the
compute/comm overlap then comes from XLA's async collectives — the
latency-hiding scheduler overlaps the gradient reduce-scatter/all-gather
with backward compute, the TPU analogue of the paper's double-buffered
overlap of DMA and PE compute).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ArchConfig
from repro.distributed import sharding as shd
from repro.models import model as M
from repro.training.optimizer import AdamWConfig, OptState, adamw_update, init_opt_state

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class TrainOptions:
    microbatches: int = 1  # gradient-accumulation steps
    remat: bool = True  # checkpoint each super-block
    param_dtype: Any = jnp.bfloat16
    opt: AdamWConfig = AdamWConfig()
    use_ep: bool = True  # shard_map expert parallelism for MoE archs
    # §Perf knobs (beyond-paper; baseline = defaults)
    grad_acc_sharded: bool = False  # pin grad accumulator to param sharding
    moe_combine_bf16: bool = False  # MoE combine psum in bf16 (halves bytes)
    ep_dispatch: str = "psum"  # psum | a2a
    ep_zero3: bool = False  # bf16 expert-weight gather inside the EP body
    seq_parallel: bool = False  # shard S over `model` between blocks (SP)


class TrainState(NamedTuple):
    params: Any
    opt: OptState


def make_model_ctx(cfg: ArchConfig, mesh: Optional[Mesh], opts: TrainOptions
                   ) -> M.ModelCtx:
    ep = None
    batch_axes: tuple = ()
    if mesh is not None:
        batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if (mesh is not None and opts.use_ep and cfg.moe is not None
            and "model" in mesh.axis_names and mesh.shape["model"] > 1
            and cfg.moe.num_experts % mesh.shape["model"] == 0):
        from repro.distributed.expert_parallel import EPShard

        ep = EPShard(mesh, token_axes=batch_axes, dispatch=opts.ep_dispatch,
                     combine_dtype=jnp.bfloat16 if opts.moe_combine_bf16
                     else jnp.float32,
                     zero3=opts.ep_zero3 and "data" in mesh.axis_names)
    seq_axis = None
    if (opts.seq_parallel and mesh is not None and "model" in mesh.axis_names
            and mesh.shape["model"] > 1):
        # §Perf: sequence parallelism — activations between blocks carry
        # (batch over data) x (sequence over model); the TP block-output
        # all-reduce decomposes into reduce-scatter + all-gather (half the
        # link bytes) and norm/residual residency shards over `model`.
        seq_axis = "model"
    return M.ModelCtx(ep_shard=ep, remat=opts.remat, mesh=mesh,
                      batch_axes=batch_axes, seq_axis=seq_axis)


def init_train_state(key, cfg: ArchConfig, opts: TrainOptions) -> TrainState:
    params = M.init_params(key, cfg, dtype=opts.param_dtype)
    return TrainState(params=params, opt=init_opt_state(params, opts.opt))


def _loss_for_microbatch(params, batch, cfg, ctx):
    return M.loss_fn(params, batch["tokens"], batch["targets"], cfg,
                     frontend_embed=batch.get("frontend_embed"), ctx=ctx)


def make_train_step(cfg: ArchConfig, opts: TrainOptions,
                    mesh: Optional[Mesh] = None) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    batch["tokens"/"targets"]: (global_batch, seq). With microbatching the
    leading dim is split into (microbatches, global_batch // microbatches).
    """
    ctx = make_model_ctx(cfg, mesh, opts)

    def train_step(state: TrainState, batch: dict) -> tuple[TrainState, dict]:
        grad_fn = jax.value_and_grad(_loss_for_microbatch, has_aux=True)

        if opts.microbatches == 1:
            (loss, aux), grads = grad_fn(state.params, batch, cfg, ctx)
        else:
            def split(x):
                """(B, ...) -> (mb, B/mb, ...) with an interleaved layout:
                each device keeps its own examples across microbatches (no
                cross-device resharding at the reshape)."""
                mb = opts.microbatches
                y = x.reshape((x.shape[0] // mb, mb) + x.shape[1:])
                y = jnp.swapaxes(y, 0, 1)
                if mesh is not None:
                    from jax.sharding import NamedSharding, PartitionSpec as P

                    batch_axes = tuple(a for a in ("pod", "data")
                                       if a in mesh.axis_names)
                    spec = P(None, batch_axes, *([None] * (x.ndim - 1)))
                    y = jax.lax.with_sharding_constraint(
                        y, NamedSharding(mesh, spec))
                return y

            mbatch = {k: split(v) for k, v in batch.items()}

            def acc_body(carry, mb):
                g_acc, loss_acc, aux_acc = carry
                (loss, aux), g = grad_fn(state.params, mb, cfg, ctx)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, loss_acc + loss,
                        jax.tree.map(lambda a, b: a + b, aux_acc, aux)), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              state.params)
            if opts.grad_acc_sharded and mesh is not None:
                # §Perf H2: without this anchor GSPMD replicates the fp32
                # accumulator -> per-microbatch gradient ALL-reduces and a
                # full fp32 copy per device; pinned to the param sharding
                # the backward emits reduce-scatters into shards instead.
                from jax.sharding import NamedSharding

                from repro.distributed import sharding as shd

                plan = shd.ShardingPlan.for_mesh(mesh)
                specs = shd.param_specs(cfg, g0, mesh, plan)
                g0 = jax.tree.map(
                    lambda g, s: jax.lax.with_sharding_constraint(
                        g, NamedSharding(mesh, s)),
                    g0, specs, is_leaf=lambda x: hasattr(x, "shape"))
            aux0 = {"nll": 0.0, "zloss": 0.0, "moe_aux": 0.0}
            (grads, loss, aux), _ = jax.lax.scan(
                acc_body, (g0, jnp.float32(0.0), aux0), mbatch)
            n = opts.microbatches
            grads = jax.tree.map(lambda g: g / n, grads)
            loss = loss / n
            aux = jax.tree.map(lambda a: a / n, aux)

        params, opt, opt_metrics = adamw_update(state.params, grads, state.opt,
                                                opts.opt)
        metrics = {"loss": loss, **aux, **opt_metrics}
        return TrainState(params=params, opt=opt), metrics

    return train_step


# ---------------------------------------------------------------------------
# Sharded (AOT) compilation for a mesh
# ---------------------------------------------------------------------------


def state_specs(cfg: ArchConfig, state_shapes: TrainState, mesh: Mesh,
                plan: shd.ShardingPlan) -> TrainState:
    p_specs = shd.param_specs(cfg, state_shapes.params, mesh, plan)
    return TrainState(
        params=p_specs,
        opt=OptState(step=P(),
                     m=jax.tree.map(lambda s: s, p_specs,
                                    is_leaf=lambda x: isinstance(x, P)),
                     v=jax.tree.map(lambda s: s, p_specs,
                                    is_leaf=lambda x: isinstance(x, P))))


def lower_train_step(cfg: ArchConfig, opts: TrainOptions, mesh: Mesh,
                     plan: shd.ShardingPlan, input_specs: dict):
    """AOT-lower the sharded train step for ShapeDtypeStruct inputs."""
    step = make_train_step(cfg, opts, mesh)

    state_shapes = jax.eval_shape(
        partial(init_train_state, cfg=cfg, opts=opts), jax.random.PRNGKey(0))
    sspec = state_specs(cfg, state_shapes, mesh, plan)
    state_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), sspec,
                            is_leaf=lambda x: isinstance(x, P))
    batch_sh = shd.input_shardings(input_specs, mesh, plan)

    jitted = jax.jit(step,
                     in_shardings=(state_sh, batch_sh),
                     out_shardings=(state_sh, None),
                     donate_argnums=(0,))
    with mesh:
        lowered = jitted.lower(state_shapes, input_specs)
    return lowered, state_shapes
