"""Data pipeline: deterministic synthetic streams for LM training + the
EMVS event pipeline adapter.

The LM stream is a seeded Zipfian token sampler with a shifted-target
layout — deterministic in (seed, step, shard), so a restarted/elastic
job resumes **exactly** where it left off by replaying from the step
counter alone (no data-state checkpoint needed). Per-host sharding
follows jax.process_index() in real multi-host runs.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2  # token-frequency skew (realistic rank-frequency)


def _zipf_probs(cfg: DataConfig) -> np.ndarray:
    ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
    p = ranks ** (-cfg.zipf_a)
    return p / p.sum()


class TokenStream:
    """Deterministic batches: batch(step) is a pure function of config."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._probs = _zipf_probs(cfg)

    def batch(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        # sample via inverse-CDF on a coarse alias-free grid (fast enough
        # for synthetic data; a production pipeline would memory-map shards)
        u = rng.random((cfg.global_batch, cfg.seq_len + 1))
        cdf = np.cumsum(self._probs)
        toks = np.searchsorted(cdf, u).astype(np.int32)
        toks = np.clip(toks, 0, cfg.vocab_size - 1)
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1
