"""TrainState checkpointing on top of distributed.fault_tolerance.

Logical (mesh-independent) checkpoints: save full arrays + manifest;
restore with the *current* mesh's shardings — the elastic-restart path.
"""
from __future__ import annotations

from typing import Any

from repro.distributed import fault_tolerance as ft
from repro.training.train_step import TrainState


def save(ckpt_dir: str, step: int, state: TrainState, *, keep_last: int = 3,
         extra: dict | None = None) -> str:
    return ft.save_checkpoint(ckpt_dir, step, state._asdict(),
                              extra=extra, keep_last=keep_last)


def restore(ckpt_dir: str, step: int, like: TrainState,
            shardings: Any = None) -> TrainState:
    d = ft.restore_checkpoint(
        ckpt_dir, step, like._asdict(),
        shardings._asdict() if shardings is not None else None)
    return TrainState(**d)


def latest(ckpt_dir: str) -> int | None:
    return ft.latest_step(ckpt_dir)
