"""`python -m repro.analysis.lint` — the quantization-contract linter CLI.

Traces every sweep program in the formulation x backend x interpolation
x quantization grid (plus the kernel-level entry points) on tiny
`ShapeDtypeStruct` shapes, runs the dtype-flow and host-sync rules over
each jaxpr, audits the streaming dispatcher's compiled-variant space,
and reports findings against the checked-in baseline
(`analysis_baseline.json` at the repo root).

Exit status is 0 iff no *new* (non-suppressed) findings; suppressed
findings are listed but do not fail the lint. `--write-baseline`
regenerates the baseline from the current findings (the suppression
workflow — see docs/quantization_contracts.md). `--json` dumps the full
findings, summaries and overflow proofs for the CI artifact.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Sequence

import jax

from repro.analysis.dtype_flow import AbsVal, absval_from_aval, analyze_program
from repro.analysis.findings import Finding, load_baseline, split_by_baseline, write_baseline
from repro.analysis.rules import audit_variant_space, default_rules

FORMULATIONS = ("scatter", "matmul", "kernel")
BACKENDS = ("batched", "sharded")
VOTINGS = ("nearest", "bilinear")
QUANTIZED = (False, True)

# tiny trace shapes: static analysis cost is per-program, not per-element.
# The proof target is the paper-scale worst case the int32 accumulator
# must survive — a full segment capacity of frames with every event
# landing in one voxel — so the frame capacity is traced at the real
# streaming bound (the scan closed form makes the length free).
TRACE_W, TRACE_H, TRACE_NZ = 32, 24, 8
PROOF_CAPACITY_FRAMES = 64
TRACE_SEGMENTS, TRACE_CAPACITY, TRACE_EVENTS = 2, PROOF_CAPACITY_FRAMES, 64


def _absvals_from_contracts(
    leaves: Sequence[Any], bounds: Sequence[tuple[float, float, bool]]
) -> list[AbsVal]:
    from jax._src import core as jcore

    out = []
    for leaf, (lo, hi, integral) in zip(leaves, bounds):
        base = absval_from_aval(jcore.ShapedArray(leaf.shape, leaf.dtype))
        out.append(base.with_(lo=float(lo), hi=float(hi), integral=bool(integral), known=True))
    return out


def build_entries(grid: str = "full") -> list[dict[str, Any]]:
    """The lint grid: one dict per traced program.

    Each entry carries `fn`, `args` (ShapeDtypeStructs), `contracts`
    (flattened input AbsVals) and the policy's sanctioned clamp bounds.
    """
    from repro.core.camera import CameraModel
    from repro.core.dsi import DSIConfig
    from repro.core.pipeline import EMVSOptions, SegmentBatch, sweep_trace_spec
    from repro.kernels.backproject_vote import ops as bpv_ops

    cam = CameraModel(width=TRACE_W, height=TRACE_H, cx=TRACE_W / 2 - 0.5,
                      cy=TRACE_H / 2 - 0.5)
    dsi_cfg = DSIConfig.for_camera(cam, num_planes=TRACE_NZ)

    entries: list[dict[str, Any]] = []
    formulations = FORMULATIONS if grid == "full" else ("matmul",)
    backends = BACKENDS if grid == "full" else ("batched",)
    for formulation in formulations:
        for backend in backends:
            for voting in VOTINGS:
                for quantized in QUANTIZED:
                    opts = EMVSOptions(
                        voting=voting, formulation=formulation, quantized=quantized
                    )
                    name = (
                        f"sweep[{formulation},{backend},{voting},"
                        f"{'quant' if quantized else 'float'}]"
                    )
                    fn, args, contracts = sweep_trace_spec(
                        cam,
                        dsi_cfg,
                        opts,
                        segments=TRACE_SEGMENTS,
                        capacity=TRACE_CAPACITY,
                        events=TRACE_EVENTS,
                        sweep=backend,
                    )
                    leaves = jax.tree_util.tree_leaves(args)
                    bounds = [tuple(contracts[f]) for f in SegmentBatch._fields]
                    entries.append(
                        {
                            "name": name,
                            "fn": fn,
                            "args": args,
                            "contracts": _absvals_from_contracts(leaves, bounds),
                            "policy": opts.policy,
                        }
                    )
    if grid == "full":
        # kernel-level entries exercise the ops.py datapath (and the
        # pallas_call body) outside the full segment sweep
        for voting in VOTINGS:
            for quantized in QUANTIZED:
                fn, args, contracts = bpv_ops.kernel_trace_spec(
                    cam=cam,
                    dsi_cfg=dsi_cfg,
                    frames=TRACE_CAPACITY,
                    events=TRACE_EVENTS,
                    mode=voting,
                    quantized=quantized,
                )
                from repro.quant.policies import TABLE1

                entries.append(
                    {
                        "name": f"kernel[{voting},{'quant' if quantized else 'float'}]",
                        "fn": fn,
                        "args": args,
                        "contracts": _absvals_from_contracts(
                            jax.tree_util.tree_leaves(args), list(contracts.values())
                        ),
                        "policy": TABLE1,
                    }
                )
    return entries


def lint_entry(entry: dict[str, Any]) -> tuple[list[Finding], dict[str, Any]]:
    ctx = analyze_program(
        entry["fn"],
        entry["args"],
        entry["contracts"],
        entry=entry["name"],
        rules=default_rules(),
        sanctioned_clips=entry["policy"].sanctioned_clip_bounds(),
    )
    return ctx.findings, dict(ctx.facts)


def lint_variant_space() -> tuple[list[Finding], dict[str, Any]]:
    """The recompilation audit across the supported StreamConfigs."""
    from repro.serving.emvs_stream import StreamConfig

    findings: list[Finding] = []
    summaries: dict[str, Any] = {}
    for name, cfg, mesh_segments in (
        ("variant-space[batched]", StreamConfig(), 1),
        ("variant-space[sharded,x8]", StreamConfig(sweep="sharded"), 8),
    ):
        fs, summary = audit_variant_space(
            cfg, PROOF_CAPACITY_FRAMES, mesh_segments=mesh_segments, entry=name
        )
        findings.extend(fs)
        summaries[name] = summary
    return findings, summaries


def _dedupe(findings: list[Finding]) -> list[Finding]:
    seen: set[tuple[str, str, str]] = set()
    out = []
    for f in findings:
        key = (f.fingerprint, f.provenance.source, f.message)
        if key in seen:
            continue
        seen.add(key)
        out.append(f)
    return out


def run_lint(grid: str = "full") -> tuple[list[Finding], dict[str, Any]]:
    """Run every rule over every entry; returns (findings, report)."""
    findings: list[Finding] = []
    int_bounds: dict[str, tuple[float, float]] = {}
    entries_run: list[str] = []
    for entry in build_entries(grid):
        fs, facts = lint_entry(entry)
        findings.extend(fs)
        entries_run.append(entry["name"])
        for dtype, (lo, hi) in facts.get("int_bounds", {}).items():
            plo, phi = int_bounds.get(dtype, (0.0, 0.0))
            int_bounds[dtype] = (min(plo, lo), max(phi, hi))
    vfindings, vsummaries = lint_variant_space()
    findings.extend(vfindings)
    import numpy as np

    proofs = {}
    for dtype, (lo, hi) in sorted(int_bounds.items()):
        info = np.iinfo(np.dtype(dtype))
        proofs[dtype] = {
            "worst_case_lo": lo,
            "worst_case_hi": hi,
            "dtype_min": float(info.min),
            "dtype_max": float(info.max),
            "headroom": min(lo - float(info.min), float(info.max) - hi),
        }
    report = {
        "entries": entries_run,
        "int_bound_proofs": proofs,
        "variant_space": vsummaries,
    }
    return _dedupe(findings), report


def main(argv: Sequence[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="quantization-contract linter over the sweep program grid",
    )
    ap.add_argument("--baseline", default=None, help="suppression baseline JSON")
    ap.add_argument("--json", dest="json_out", default=None, help="findings JSON artifact path")
    ap.add_argument(
        "--write-baseline",
        action="store_true",
        help="regenerate the baseline from current findings and exit 0",
    )
    ap.add_argument(
        "--grid",
        choices=("full", "quick"),
        default="full",
        help="'quick' lints only the matmul/batched column (fast smoke)",
    )
    args = ap.parse_args(argv)

    findings, report = run_lint(args.grid)

    if args.write_baseline:
        path = args.baseline or "analysis_baseline.json"
        write_baseline(path, findings)
        print(f"wrote {len(findings)} fingerprint(s) to {path}")
        return 0

    baseline = load_baseline(args.baseline) if args.baseline else set()
    new, suppressed = split_by_baseline(findings, baseline)

    for f in suppressed:
        print(f"SUPPRESSED {f.render()}")
    for f in new:
        print(f"NEW {f.render()}")

    for dtype, proof in report["int_bound_proofs"].items():
        print(
            f"proof: worst-case {dtype} values in "
            f"[{proof['worst_case_lo']:.0f}, {proof['worst_case_hi']:.0f}] within "
            f"[{proof['dtype_min']:.0f}, {proof['dtype_max']:.0f}] "
            f"(headroom {proof['headroom']:.0f})"
        )
    for name, summary in report["variant_space"].items():
        print(
            f"{name}: {summary['variants']} compiled variants "
            f"(S buckets {summary['s_buckets']} x capacities {summary['capacities']}, "
            f"bound {summary['bound']}, "
            f"{summary.get('planner_groups_checked', 0)} planner-emitted "
            f"group(s) audited)"
        )
    print(
        f"{len(report['entries'])} program(s) linted: "
        f"{len(new)} new finding(s), {len(suppressed)} suppressed"
    )

    if args.json_out:
        with open(args.json_out, "w") as fh:
            json.dump(
                {
                    "new": [f.to_json() for f in new],
                    "suppressed": [f.to_json() for f in suppressed],
                    "report": report,
                },
                fh,
                indent=2,
            )
            fh.write("\n")

    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
