"""The typed lint rules driven by the dtype-flow interpreter.

Three rule families, matching docs/quantization_contracts.md:

- :class:`DtypeFlowRule` — quantization/overflow contracts over value
  flow: fractional float->int casts without a sanctioned clamp (the
  PR 3 bilinear-truncation class), proven integer overflow, f64
  promotions, weak_type leaks.
- :class:`HostSyncRule` — host round-trip primitives (callbacks,
  infeed/outfeed) inside streaming-dispatched programs, enforcing the
  "no per-chunk device round-trips" docstring contract.
- :func:`audit_variant_space` — the recompilation audit: enumerates the
  dispatcher's compiled-variant space from ``StreamConfig`` buckets and
  verifies the |S buckets| x |capacities| bound and its coverage.
"""
from __future__ import annotations

import math
from typing import Any

import numpy as np

from repro.analysis.dtype_flow import AbsVal, Context, Rule, int_range
from repro.analysis.findings import Finding, Provenance

_INT_MAX_TRACKED = ("int8", "int16", "int32", "int64")


def _is_int(dtype: Any) -> bool:
    return np.issubdtype(np.dtype(dtype), np.integer)


def _is_float(dtype: Any) -> bool:
    return np.issubdtype(np.dtype(dtype), np.floating)


class DtypeFlowRule(Rule):
    """Quantization-contract checks on dtype and value flow."""

    rule_id = "dtype-flow"

    def on_eqn(self, ctx: Context, eqn: Any, ins: list[AbsVal], outs: list[AbsVal]) -> None:
        name = eqn.primitive.name
        if name == "convert_element_type":
            self._check_convert(ctx, eqn, ins[0], outs[0])
        else:
            self._check_int_growth(ctx, eqn, outs)
        for out in outs:
            if np.dtype(out.dtype) in (np.dtype(np.float64), np.dtype(np.complex128)):
                ctx.report(
                    eqn,
                    self.rule_id,
                    "f64-promotion",
                    f"{name} produces {np.dtype(out.dtype).name}; the datapaths "
                    "are f32/int — an f64 promotion doubles bandwidth and "
                    "breaks the fixed-point contracts",
                )
                break
        self._track_int_bounds(ctx, outs)

    def _check_convert(self, ctx: Context, eqn: Any, a: AbsVal, out: AbsVal) -> None:
        src = np.dtype(a.dtype)
        dst = np.dtype(out.dtype)
        if not (_is_float(src) and _is_int(dst)):
            return
        # (1) fractional truncation: the PR 3 bug class.  A float->int
        # cast of a possibly-fractional value is only sanctioned when the
        # operand was just clamped to a range some quant policy declares
        # (clamp provenance), i.e. it is the Table 1 saturating store.
        if not a.integral and a.clip not in ctx.sanctioned_clips:
            ctx.report(
                eqn,
                self.rule_id,
                "float-to-int-truncation",
                f"cast {src.name}->{dst.name} of a possibly-fractional value "
                f"(bounds [{a.lo}, {a.hi}], clamp={a.clip}) discards the "
                "fractional part; either round-and-clamp to a declared "
                "fixed-point format first, or keep the accumulator float "
                "(bilinear votes carry fractional weights — see PR 3)",
            )
        # (2) proven wrap: the *mathematical* interval of the operand
        # exceeds the target integer range.  Only claimed when the
        # interval was actually propagated (known) and finite — dtype
        # defaults for unconstrained inputs are not proofs.
        rlo, rhi = int_range(dst)
        if (
            a.known
            and math.isfinite(a.lo)
            and math.isfinite(a.hi)
            and (math.floor(a.lo) < rlo or math.ceil(a.hi) > rhi)
            and a.clip not in ctx.sanctioned_clips
        ):
            ctx.report(
                eqn,
                self.rule_id,
                "int-overflow",
                f"cast to {dst.name} can wrap: worst-case value in "
                f"[{a.lo}, {a.hi}] exceeds [{rlo:.0f}, {rhi:.0f}]; clamp to a "
                "declared format before the cast (saturating store)",
            )

    def _check_int_growth(self, ctx: Context, eqn: Any, outs: list[AbsVal]) -> None:
        # integer arithmetic whose propagated worst case exceeds the dtype
        # range — the accumulate-side wrap (e.g. int16 += votes)
        for out in outs:
            dtype = np.dtype(out.dtype)
            if not _is_int(dtype):
                continue
            if not (out.known and math.isfinite(out.lo) and math.isfinite(out.hi)):
                continue
            rlo, rhi = int_range(dtype)
            if out.lo < rlo or out.hi > rhi:
                ctx.report(
                    eqn,
                    self.rule_id,
                    "int-overflow",
                    f"{eqn.primitive.name} on {dtype.name} can wrap: worst-case "
                    f"value in [{out.lo}, {out.hi}] exceeds [{rlo:.0f}, {rhi:.0f}] "
                    "(accumulate in a wider dtype, or lower the segment capacity)",
                )
                return

    def _track_int_bounds(self, ctx: Context, outs: list[AbsVal]) -> None:
        # publish the proven worst-case [lo, hi] per integer dtype so the
        # CLI can print "int32 accumulator bounded within range" proofs
        bounds = ctx.facts.setdefault("int_bounds", {})
        for out in outs:
            dtype = np.dtype(out.dtype)
            if not _is_int(dtype) or dtype.name not in _INT_MAX_TRACKED:
                continue
            if not (out.known and math.isfinite(out.lo) and math.isfinite(out.hi)):
                continue
            lo, hi = bounds.get(dtype.name, (0.0, 0.0))
            bounds[dtype.name] = (min(lo, out.lo), max(hi, out.hi))

    def on_outputs(self, ctx: Context, outs: list[AbsVal]) -> None:
        for i, out in enumerate(outs):
            if out.weak_type:
                ctx.findings.append(
                    Finding(
                        rule=self.rule_id,
                        kind="weak-type-leak",
                        entry=ctx.entry,
                        message=(
                            f"program output {i} has weak_type=True; weakly-typed "
                            "outputs re-promote downstream consumers and change "
                            "dtypes silently — anchor with an explicit astype"
                        ),
                        provenance=Provenance(primitive="<output>", source="<jaxpr outputs>"),
                        severity="warning",
                    )
                )


# primitives that force a host round-trip / sync inside a traced program
HOST_SYNC_PRIMITIVES = frozenset(
    {
        "pure_callback",
        "io_callback",
        "debug_callback",
        "callback",
        "host_callback_call",
        "outside_call",
        "infeed",
        "outfeed",
    }
)


class HostSyncRule(Rule):
    """No host round-trips inside streaming-dispatched sweep programs."""

    rule_id = "host-sync"

    def on_eqn(self, ctx: Context, eqn: Any, ins: list[AbsVal], outs: list[AbsVal]) -> None:
        if eqn.primitive.name in HOST_SYNC_PRIMITIVES:
            ctx.report(
                eqn,
                self.rule_id,
                "host-round-trip",
                f"{eqn.primitive.name} forces a host sync inside a "
                "streaming-dispatched program; the dispatch layer relies on "
                "sweeps being enqueued asynchronously (no per-chunk device "
                "round-trips) — move host I/O outside the traced sweep",
            )


def default_rules() -> list[Rule]:
    return [DtypeFlowRule(), HostSyncRule()]


def audit_variant_space(
    stream_cfg: Any,
    max_segment_frames: int | None,
    *,
    mesh_segments: int = 1,
    entry: str = "variant-space",
) -> tuple[list[Finding], dict[str, Any]]:
    """Recompilation audit over the dispatcher's compiled-variant space.

    Statically enumerates every (S bucket, frame capacity) entry shape the
    dispatcher can stage for ``stream_cfg`` (via
    :func:`repro.serving.sweep_dispatcher.enumerate_variant_space`) and
    checks:

    - the space is bounded at all (``max_segment_frames`` declared);
    - |variants| == |S buckets| x |capacities| and the shard-rounded S
      buckets never exceed the configured bucket count — the jit-cache
      bound the streaming docs promise;
    - coverage: every dispatchable group size and frame count maps into
      an enumerated variant (no cache-key fragmentation at runtime);
    - planner usage: the actual ``DispatchPlanner`` is driven over
      exhaustive single-capacity and mixed loads (every frame count up
      to ``max_segment_frames``, every queue depth up to one past the
      top S bucket, both fairness policies) and every group it emits
      must land on an enumerated (S bucket, capacity) variant — the
      planner can never be the component that fragments the jit cache.
    """
    from repro.core.pipeline import DispatchPlanner, bucket_capacity
    from repro.serving.sweep_dispatcher import enumerate_variant_space

    findings: list[Finding] = []

    def report(kind: str, message: str) -> None:
        findings.append(
            Finding(
                rule="recompilation",
                kind=kind,
                entry=entry,
                message=message,
                provenance=Provenance(
                    primitive="<StreamConfig>",
                    source=f"segment_buckets={tuple(stream_cfg.segment_buckets)} "
                    f"sweep={stream_cfg.sweep} mesh_segments={mesh_segments}",
                ),
            )
        )

    if not max_segment_frames or max_segment_frames <= 0:
        report(
            "unbounded-variant-space",
            "no max_segment_frames declared: the capacity axis of the "
            "compiled-variant space is unbounded, so a long-running service "
            "can grow the jit cache without limit",
        )
        return findings, {
            "s_buckets": (),
            "capacities": (),
            "variants": 0,
            "bound": None,
        }

    space = enumerate_variant_space(
        stream_cfg, max_segment_frames, mesh_segments=mesh_segments
    )
    s_buckets = space["s_buckets"]
    capacities = space["capacities"]
    variants = space["variants"]
    bound = len(stream_cfg.segment_buckets) * len(capacities)

    if len(variants) != len(s_buckets) * len(capacities):
        report(
            "variant-bound-violated",
            f"enumerated {len(variants)} variants but |S buckets| x "
            f"|capacities| = {len(s_buckets) * len(capacities)}",
        )
    if len(s_buckets) > len(stream_cfg.segment_buckets):
        report(
            "variant-bound-violated",
            f"shard rounding produced {len(s_buckets)} S buckets from "
            f"{len(stream_cfg.segment_buckets)} configured — rounding must "
            "only merge buckets, never split them",
        )

    # coverage: the dispatcher's bucket lookup and capacity padding must
    # land inside the enumerated space for every feasible input
    top = max(s_buckets)
    for n in range(1, top + 1):
        b = next((x for x in s_buckets if x >= n), None)
        if b is None or b not in s_buckets:
            report(
                "variant-coverage-gap",
                f"group of {n} segments does not map to an enumerated S bucket",
            )
            break
    for f in range(1, max_segment_frames + 1):
        if bucket_capacity(f) not in capacities:
            report(
                "variant-coverage-gap",
                f"{f} frames pads to capacity {bucket_capacity(f)}, which is "
                "not in the enumerated capacity set",
            )
            break

    # planner usage: drive the real DispatchPlanner (the partition the
    # dispatcher stages) over exhaustive loads; every emitted group's
    # (padded S bucket, capacity) must be an enumerated variant
    planner = DispatchPlanner(tuple(s_buckets))
    variant_set = {(s, c) for s in s_buckets for c in capacities}
    groups_checked = 0

    def check_groups(groups) -> bool:
        nonlocal groups_checked
        for group, cap in groups:
            groups_checked += 1
            b = next((x for x in s_buckets if x >= len(group)), None)
            if b is None or (b, cap) not in variant_set:
                report(
                    "variant-coverage-gap",
                    f"planner emitted a group of {len(group)} segments at "
                    f"capacity {cap} -> variant ({b}, {cap}) outside the "
                    f"enumerated space",
                )
                return False
        return True

    ok = True
    for f in range(1, max_segment_frames + 1):
        for n in range(1, top + 2):  # one past the top bucket: must split
            segs = [(k * f, (k + 1) * f) for k in range(n)]
            ok = ok and check_groups(planner.plan(segs))
        if not ok:
            break
    if ok:
        # mixed load: every frame count in one queue (capacity changes
        # seal groups), fanned over two sessions under both fairness
        # policies through the tagged planner the multi-stream engine uses
        segs, frame = [], 0
        for f in range(1, max_segment_frames + 1):
            segs.append((frame, frame + f))
            frame += f
        items = [(k % 2, seg) for k, seg in enumerate(segs)]
        for fairness in ("fifo", "round_robin"):
            check_groups(planner.plan_tagged(items, fairness=fairness))

    summary = {
        "s_buckets": tuple(s_buckets),
        "capacities": tuple(capacities),
        "variants": len(variants),
        "bound": bound,
        "planner_groups_checked": groups_checked,
    }
    return findings, summary
