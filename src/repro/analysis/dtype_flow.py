"""Abstract interpretation of jaxprs for quantization-contract linting.

The analyzer traces a program with ``jax.make_jaxpr`` on
``ShapeDtypeStruct`` arguments (nothing executes) and walks the jaxpr
propagating, per intermediate value:

- ``dtype`` / ``weak_type`` — from the abstract value;
- ``[lo, hi]`` — a sound worst-case interval for the value, seeded from
  per-input contracts (e.g. "``valid`` is a 0/1 mask") and propagated
  through arithmetic, reductions, ``dot_general`` (interval x
  contraction size), ``scan`` (closed-form linear accumulation growth),
  and ``psum`` (interval x mesh axis size);
- ``integral`` — whether the value is provably integer-valued, the bit
  that distinguishes a lossless int cast from one that discards
  fractional bilinear vote weights (the PR 3 bug class);
- ``clip`` — literal min/max clamp bounds the value just passed
  through, giving casts *clamp provenance*: a float->int store is
  sanctioned only when its operand was clamped to a range a quant
  policy declares (e.g. int16's (-32768, 32767));
- ``known`` — whether the interval came from real propagation rather
  than the dtype-range default, so overflow findings are proofs, not
  guesses about unconstrained inputs.

Control-flow and staging primitives (pjit, scan, while, cond,
shard_map, pallas_call, custom_jvp/vjp) are recursed into with the
enclosing call stack recorded for finding provenance.  Pallas kernel
bodies are interpreted best-effort over a Ref environment (``get`` /
``swap`` / ``addupdate``).

Rules observe every equation via ``Rule.on_eqn`` and the program
outputs via ``Rule.on_outputs``; the interpreter itself raises nothing.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Sequence

import numpy as np

import jax
from jax._src import core as jcore
from jax._src import source_info_util

from repro.analysis.findings import Finding, Provenance

Inf = float("inf")


@dataclasses.dataclass(frozen=True)
class AbsVal:
    """Abstract state of one jaxpr value."""

    dtype: Any  # numpy dtype
    shape: tuple[int, ...] = ()
    weak_type: bool = False
    lo: float = -Inf
    hi: float = Inf
    integral: bool = False  # provably integer-valued
    known: bool = False  # interval from propagation, not the dtype default
    clip: tuple[float, float] | None = None  # literal clamp bounds just applied

    def with_(self, **kw: Any) -> "AbsVal":
        return dataclasses.replace(self, **kw)


def _is_int(dtype: Any) -> bool:
    return np.issubdtype(np.dtype(dtype), np.integer)


def _is_float(dtype: Any) -> bool:
    return np.issubdtype(np.dtype(dtype), np.floating)


def _is_bool(dtype: Any) -> bool:
    return np.dtype(dtype) == np.bool_


def int_range(dtype: Any) -> tuple[float, float]:
    info = np.iinfo(np.dtype(dtype))
    return float(info.min), float(info.max)


def _inner_aval(aval: Any) -> Any:
    # Pallas Refs wrap the array aval; state AbstractRef exposes inner_aval.
    return getattr(aval, "inner_aval", aval)


def absval_from_aval(aval: Any) -> AbsVal:
    aval = _inner_aval(aval)
    dtype = np.dtype(aval.dtype)
    shape = tuple(int(d) for d in getattr(aval, "shape", ()))
    weak = bool(getattr(aval, "weak_type", False))
    if _is_bool(dtype):
        return AbsVal(dtype, shape, weak, 0.0, 1.0, integral=True, known=True)
    if _is_int(dtype):
        lo, hi = int_range(dtype)
        # dtype-range default: sound but *not* "known" — overflow rules
        # must not claim proofs about unconstrained inputs.
        return AbsVal(dtype, shape, weak, lo, hi, integral=True, known=False)
    return AbsVal(dtype, shape, weak, -Inf, Inf, integral=False, known=False)


def absval_from_literal(val: Any) -> AbsVal:
    arr = np.asarray(val)
    dtype = arr.dtype
    weak = np.isscalar(val) or getattr(val, "weak_type", arr.ndim == 0)
    if arr.size == 0:
        return AbsVal(dtype, tuple(arr.shape), bool(weak), 0.0, 0.0, True, True)
    lo = float(np.min(arr))
    hi = float(np.max(arr))
    integral = _is_int(dtype) or _is_bool(dtype) or bool(
        np.all(np.isfinite(arr)) and np.all(arr == np.floor(arr))
    )
    return AbsVal(dtype, tuple(arr.shape), bool(weak), lo, hi, integral, True)


def _hull(vals: Sequence[AbsVal], dtype: Any, shape: tuple[int, ...]) -> AbsVal:
    lo = min((v.lo for v in vals), default=-Inf)
    hi = max((v.hi for v in vals), default=Inf)
    return AbsVal(
        np.dtype(dtype),
        shape,
        False,
        lo,
        hi,
        integral=all(v.integral for v in vals),
        known=all(v.known for v in vals),
    )


def _mul_bounds(a: AbsVal, b: AbsVal) -> tuple[float, float]:
    cands = []
    for x in (a.lo, a.hi):
        for y in (b.lo, b.hi):
            p = x * y
            if math.isnan(p):  # 0 * inf
                p = 0.0
            cands.append(p)
    return min(cands), max(cands)


class Rule:
    """Base class for lint rules driven by the interpreter."""

    rule_id = "rule"

    def on_eqn(self, ctx: "Context", eqn: Any, ins: list[AbsVal], outs: list[AbsVal]) -> None:
        pass

    def on_outputs(self, ctx: "Context", outs: list[AbsVal]) -> None:
        pass


@dataclasses.dataclass
class Context:
    """Mutable interpreter state shared with the rules."""

    entry: str
    rules: list[Rule]
    sanctioned_clips: frozenset[tuple[float, float]] = frozenset()
    findings: list[Finding] = dataclasses.field(default_factory=list)
    call_stack: list[str] = dataclasses.field(default_factory=list)
    # True while probing loop bodies for carry growth: rules are not fed,
    # so the same equation is reported once, from the final widest pass.
    muted: bool = False
    axis_sizes: dict[str, int] = dataclasses.field(default_factory=dict)
    # summary facts rules can publish (e.g. proved accumulator bounds)
    facts: dict[str, Any] = dataclasses.field(default_factory=dict)

    def provenance(self, eqn: Any) -> Provenance:
        try:
            src = source_info_util.summarize(eqn.source_info)
        except Exception:
            src = "<unknown>"
        try:
            pretty = str(eqn)
            pretty = pretty if len(pretty) <= 160 else pretty[:157] + "..."
        except Exception:
            pretty = ""
        return Provenance(
            primitive=eqn.primitive.name,
            source=src,
            call_stack=tuple(self.call_stack),
            eqn=pretty,
        )

    def report(self, eqn: Any, rule: str, kind: str, message: str, severity: str = "error") -> None:
        self.findings.append(
            Finding(
                rule=rule,
                kind=kind,
                entry=self.entry,
                message=message,
                provenance=self.provenance(eqn),
                severity=severity,
            )
        )


class DtypeFlowAnalyzer:
    """Interprets one jaxpr, feeding every equation to the rules."""

    def __init__(self, ctx: Context):
        self.ctx = ctx

    # -- driving ---------------------------------------------------------

    def run(self, closed_jaxpr: Any, in_absvals: Sequence[AbsVal]) -> list[AbsVal]:
        consts = [absval_from_literal(c) for c in closed_jaxpr.consts]
        outs = self.eval_jaxpr(closed_jaxpr.jaxpr, consts, list(in_absvals))
        for rule in self.ctx.rules:
            rule.on_outputs(self.ctx, outs)
        return outs

    def eval_jaxpr(self, jaxpr: Any, consts: list[AbsVal], args: list[AbsVal]) -> list[AbsVal]:
        env: dict[Any, AbsVal] = {}

        def read(atom: Any) -> AbsVal:
            if isinstance(atom, jcore.Literal):
                return absval_from_literal(atom.val)
            got = env.get(atom)
            if got is None:
                got = absval_from_aval(atom.aval)
            return got

        def write(var: Any, val: AbsVal) -> None:
            env[var] = val

        for v, c in zip(jaxpr.constvars, consts):
            write(v, c)
        for v, a in zip(jaxpr.invars, args):
            # Re-anchor the contract interval on the inner aval's dtype and
            # shape (shard_map narrows shapes; pjit may differ in weak_type).
            inner = absval_from_aval(v.aval)
            write(
                v,
                inner.with_(
                    lo=a.lo, hi=a.hi, integral=a.integral, known=a.known, clip=a.clip
                ),
            )
        for eqn in jaxpr.eqns:
            ins = [read(x) for x in eqn.invars]
            outs = self.eval_eqn(eqn, ins)
            if not self.ctx.muted:
                for rule in self.ctx.rules:
                    rule.on_eqn(self.ctx, eqn, ins, outs)
            for var, out in zip(eqn.outvars, outs):
                write(var, out)
        return [read(x) for x in jaxpr.outvars]

    # -- equation dispatch ----------------------------------------------

    def eval_eqn(self, eqn: Any, ins: list[AbsVal]) -> list[AbsVal]:
        name = eqn.primitive.name
        handler = getattr(self, "_prim_" + name.replace("-", "_"), None)
        try:
            if handler is not None:
                outs = handler(eqn, ins)
                if outs is not None:
                    return outs
        except Exception:
            pass  # fall through to the conservative default
        return self.default_outs(eqn)

    def default_outs(self, eqn: Any) -> list[AbsVal]:
        return [absval_from_aval(v.aval) for v in eqn.outvars]

    def _out_aval(self, eqn: Any, i: int = 0) -> Any:
        return _inner_aval(eqn.outvars[i].aval)

    def _shaped(self, eqn: Any, base: AbsVal, i: int = 0, **kw: Any) -> list[AbsVal]:
        aval = self._out_aval(eqn, i)
        dtype = np.dtype(aval.dtype)
        integral = base.integral or _is_int(dtype) or _is_bool(dtype)
        out = AbsVal(
            dtype,
            tuple(int(d) for d in aval.shape),
            bool(getattr(aval, "weak_type", False)),
            base.lo,
            base.hi,
            integral=integral,
            known=base.known,
            clip=base.clip,
        )
        return [out.with_(**kw)] if kw else [out]

    # -- structural pass-throughs ---------------------------------------

    def _passthrough(self, eqn: Any, ins: list[AbsVal]) -> list[AbsVal]:
        return self._shaped(eqn, ins[0])

    _prim_broadcast_in_dim = _passthrough
    _prim_reshape = _passthrough
    _prim_transpose = _passthrough
    _prim_squeeze = _passthrough
    _prim_expand_dims = _passthrough
    _prim_rev = _passthrough
    _prim_slice = _passthrough
    _prim_copy = _passthrough
    _prim_stop_gradient = _passthrough
    _prim_gather = _passthrough
    _prim_dynamic_slice = _passthrough
    _prim_reduce_max = _passthrough
    _prim_reduce_min = _passthrough
    _prim_real = _passthrough
    _prim_device_put = _passthrough
    _prim_reduce_precision = _passthrough
    _prim_optimization_barrier = _passthrough

    def _prim_concatenate(self, eqn, ins):
        aval = self._out_aval(eqn)
        return [_hull(ins, aval.dtype, tuple(int(d) for d in aval.shape))]

    def _prim_pad(self, eqn, ins):
        aval = self._out_aval(eqn)
        return [_hull(ins[:2], aval.dtype, tuple(int(d) for d in aval.shape))]

    def _prim_select_n(self, eqn, ins):
        aval = self._out_aval(eqn)
        out = _hull(ins[1:], aval.dtype, tuple(int(d) for d in aval.shape))
        # a select between identically-clamped branches keeps clamp provenance
        clips = {v.clip for v in ins[1:]}
        if len(clips) == 1:
            out = out.with_(clip=clips.pop())
        return [out]

    def _prim_dynamic_update_slice(self, eqn, ins):
        aval = self._out_aval(eqn)
        return [_hull(ins[:2], aval.dtype, tuple(int(d) for d in aval.shape))]

    def _prim_sort(self, eqn, ins):
        return [self._shaped(eqn, v, i)[0] for i, v in enumerate(ins)]

    def _prim_iota(self, eqn, ins):
        aval = self._out_aval(eqn)
        dim = int(eqn.params.get("dimension", 0))
        n = int(aval.shape[dim]) if aval.shape else 1
        return self._shaped(
            eqn, AbsVal(aval.dtype, lo=0.0, hi=float(max(n - 1, 0)), integral=True, known=True)
        )

    # -- comparisons / logic --------------------------------------------

    def _bool_out(self, eqn, ins):
        base = AbsVal(np.dtype(np.bool_), lo=0.0, hi=1.0, integral=True, known=True)
        return self._shaped(eqn, base)

    _prim_eq = _bool_out
    _prim_ne = _bool_out
    _prim_lt = _bool_out
    _prim_le = _bool_out
    _prim_gt = _bool_out
    _prim_ge = _bool_out
    _prim_and = _bool_out
    _prim_or = _bool_out
    _prim_xor = _bool_out
    _prim_not = _bool_out
    _prim_is_finite = _bool_out
    _prim_reduce_and = _bool_out
    _prim_reduce_or = _bool_out

    # -- arithmetic ------------------------------------------------------

    def _prim_add(self, eqn, ins):
        a, b = ins
        return self._shaped(
            eqn,
            AbsVal(
                a.dtype,
                lo=a.lo + b.lo,
                hi=a.hi + b.hi,
                integral=a.integral and b.integral,
                known=a.known and b.known,
            ),
        )

    def _prim_sub(self, eqn, ins):
        a, b = ins
        return self._shaped(
            eqn,
            AbsVal(
                a.dtype,
                lo=a.lo - b.hi,
                hi=a.hi - b.lo,
                integral=a.integral and b.integral,
                known=a.known and b.known,
            ),
        )

    def _prim_mul(self, eqn, ins):
        a, b = ins
        lo, hi = _mul_bounds(a, b)
        return self._shaped(
            eqn,
            AbsVal(
                a.dtype,
                lo=lo,
                hi=hi,
                integral=a.integral and b.integral,
                known=a.known and b.known,
            ),
        )

    def _prim_div(self, eqn, ins):
        a, b = ins
        out_dtype = self._out_aval(eqn).dtype
        if b.lo > 0 or b.hi < 0:
            cands = [a.lo / b.lo, a.lo / b.hi, a.hi / b.lo, a.hi / b.hi]
            lo, hi = min(cands), max(cands)
        else:
            lo, hi = -Inf, Inf
        return self._shaped(
            eqn,
            AbsVal(out_dtype, lo=lo, hi=hi, integral=_is_int(out_dtype), known=a.known and b.known),
        )

    def _prim_rem(self, eqn, ins):
        a, b = ins
        mag = max(abs(b.lo), abs(b.hi))
        if not math.isfinite(mag):
            return self.default_outs(eqn)
        return self._shaped(
            eqn,
            AbsVal(a.dtype, lo=-mag, hi=mag, integral=a.integral and b.integral, known=a.known and b.known),
        )

    def _prim_neg(self, eqn, ins):
        a = ins[0]
        return self._shaped(eqn, a.with_(lo=-a.hi, hi=-a.lo, clip=None))

    def _prim_abs(self, eqn, ins):
        a = ins[0]
        lo = 0.0 if a.lo <= 0.0 <= a.hi else min(abs(a.lo), abs(a.hi))
        hi = max(abs(a.lo), abs(a.hi))
        return self._shaped(eqn, a.with_(lo=lo, hi=hi, clip=None))

    def _prim_sign(self, eqn, ins):
        return self._shaped(eqn, AbsVal(ins[0].dtype, lo=-1.0, hi=1.0, integral=True, known=True))

    def _prim_floor(self, eqn, ins):
        a = ins[0]
        lo = math.floor(a.lo) if math.isfinite(a.lo) else a.lo
        hi = math.floor(a.hi) if math.isfinite(a.hi) else a.hi
        return self._shaped(eqn, a.with_(lo=lo, hi=hi, integral=True, clip=None))

    def _prim_ceil(self, eqn, ins):
        a = ins[0]
        lo = math.ceil(a.lo) if math.isfinite(a.lo) else a.lo
        hi = math.ceil(a.hi) if math.isfinite(a.hi) else a.hi
        return self._shaped(eqn, a.with_(lo=lo, hi=hi, integral=True, clip=None))

    def _prim_round(self, eqn, ins):
        a = ins[0]
        lo = math.floor(a.lo) if math.isfinite(a.lo) else a.lo
        hi = math.ceil(a.hi) if math.isfinite(a.hi) else a.hi
        return self._shaped(eqn, a.with_(lo=lo, hi=hi, integral=True, clip=None))

    def _prim_nextafter(self, eqn, ins):
        return self._shaped(eqn, ins[0].with_(clip=None))

    def _prim_exp(self, eqn, ins):
        a = ins[0]
        lo = math.exp(a.lo) if a.lo < 700 else Inf
        hi = math.exp(a.hi) if a.hi < 700 else Inf
        return self._shaped(eqn, AbsVal(a.dtype, lo=lo, hi=hi, known=a.known))

    def _prim_sqrt(self, eqn, ins):
        a = ins[0]
        lo = math.sqrt(a.lo) if a.lo > 0 else 0.0
        hi = math.sqrt(a.hi) if math.isfinite(a.hi) and a.hi > 0 else (0.0 if a.hi <= 0 else Inf)
        return self._shaped(eqn, AbsVal(a.dtype, lo=lo, hi=hi, known=a.known))

    def _prim_logistic(self, eqn, ins):
        return self._shaped(eqn, AbsVal(ins[0].dtype, lo=0.0, hi=1.0, known=True))

    def _prim_tanh(self, eqn, ins):
        return self._shaped(eqn, AbsVal(ins[0].dtype, lo=-1.0, hi=1.0, known=True))

    def _prim_sin(self, eqn, ins):
        return self._shaped(eqn, AbsVal(ins[0].dtype, lo=-1.0, hi=1.0, known=True))

    _prim_cos = _prim_sin

    def _prim_integer_pow(self, eqn, ins):
        a = ins[0]
        y = int(eqn.params["y"])
        if y < 0 or not (math.isfinite(a.lo) and math.isfinite(a.hi)):
            return self.default_outs(eqn)
        cands = [a.lo**y, a.hi**y]
        lo, hi = min(cands), max(cands)
        if y % 2 == 0 and a.lo <= 0.0 <= a.hi:
            lo = 0.0
        return self._shaped(eqn, AbsVal(a.dtype, lo=lo, hi=hi, integral=a.integral, known=a.known))

    def _prim_square(self, eqn, ins):
        a = ins[0]
        if not (math.isfinite(a.lo) and math.isfinite(a.hi)):
            return self.default_outs(eqn)
        hi = max(a.lo * a.lo, a.hi * a.hi)
        lo = 0.0 if a.lo <= 0.0 <= a.hi else min(a.lo * a.lo, a.hi * a.hi)
        return self._shaped(eqn, AbsVal(a.dtype, lo=lo, hi=hi, integral=a.integral, known=a.known))

    # -- min/max and clamp provenance -----------------------------------

    @staticmethod
    def _literal_bound(v: AbsVal) -> float | None:
        # a literal (or literal-derived broadcast) has a degenerate interval
        if v.known and v.lo == v.hi and math.isfinite(v.lo):
            return v.lo
        return None

    def _prim_max(self, eqn, ins):
        a, b = ins
        out = AbsVal(
            a.dtype,
            lo=max(a.lo, b.lo),
            hi=max(a.hi, b.hi),
            integral=a.integral and b.integral,
            known=a.known and b.known,
        )
        # max(x, lit) starts a clamp chain: records the lower clamp bound
        clip = None
        for x, lit in ((a, self._literal_bound(b)), (b, self._literal_bound(a))):
            if lit is not None:
                prior_hi = x.clip[1] if x.clip else Inf
                clip = (lit, prior_hi)
        return self._shaped(eqn, out.with_(clip=clip))

    def _prim_min(self, eqn, ins):
        a, b = ins
        out = AbsVal(
            a.dtype,
            lo=min(a.lo, b.lo),
            hi=min(a.hi, b.hi),
            integral=a.integral and b.integral,
            known=a.known and b.known,
        )
        clip = None
        for x, lit in ((a, self._literal_bound(b)), (b, self._literal_bound(a))):
            if lit is not None:
                prior_lo = x.clip[0] if x.clip else -Inf
                clip = (prior_lo, lit)
        return self._shaped(eqn, out.with_(clip=clip))

    def _prim_clamp(self, eqn, ins):
        lo_v, x, hi_v = ins
        lo_lit = self._literal_bound(lo_v)
        hi_lit = self._literal_bound(hi_v)
        out = AbsVal(
            x.dtype,
            lo=max(x.lo, lo_v.lo),
            hi=min(x.hi, hi_v.hi),
            integral=x.integral and lo_v.integral and hi_v.integral,
            known=x.known and lo_v.known and hi_v.known,
        )
        clip = (lo_lit, hi_lit) if lo_lit is not None and hi_lit is not None else None
        return self._shaped(eqn, out.with_(clip=clip))

    # -- conversions -----------------------------------------------------

    def _prim_convert_element_type(self, eqn, ins):
        a = ins[0]
        aval = self._out_aval(eqn)
        nd = np.dtype(aval.dtype)
        if _is_bool(nd):
            out = AbsVal(nd, lo=0.0, hi=1.0, integral=True, known=True)
        elif _is_int(nd):
            rlo, rhi = int_range(nd)
            lo = math.floor(a.lo) if math.isfinite(a.lo) else a.lo
            hi = math.ceil(a.hi) if math.isfinite(a.hi) else a.hi
            if lo < rlo or hi > rhi:
                # wrap is possible; the stored state reflects the wrapped range
                lo, hi = rlo, rhi
            out = AbsVal(nd, lo=lo, hi=hi, integral=True, known=a.known, clip=a.clip)
        else:
            out = AbsVal(nd, lo=a.lo, hi=a.hi, integral=a.integral, known=a.known, clip=a.clip)
        return self._shaped(
            eqn, out, known=out.known, clip=out.clip, integral=out.integral,
            lo=out.lo, hi=out.hi,
        )

    # -- contractions / reductions --------------------------------------

    def _prim_dot_general(self, eqn, ins):
        a, b = ins
        (lhs_c, _rhs_c), _batch = eqn.params["dimension_numbers"]
        k = 1
        for d in lhs_c:
            k *= int(a.shape[d]) if a.shape else 1
        plo, phi = _mul_bounds(a, b)
        out_dtype = self._out_aval(eqn).dtype
        return self._shaped(
            eqn,
            AbsVal(
                out_dtype,
                lo=k * plo if math.isfinite(plo) else plo,
                hi=k * phi if math.isfinite(phi) else phi,
                integral=a.integral and b.integral,
                known=a.known and b.known,
            ),
        )

    def _prim_conv_general_dilated(self, eqn, ins):
        a, b = ins
        dn = eqn.params["dimension_numbers"]
        out_c_dim = dn.rhs_spec[0]
        k = 1
        for i, d in enumerate(b.shape):
            if i != out_c_dim:
                k *= int(d)
        plo, phi = _mul_bounds(a, b)
        out_dtype = self._out_aval(eqn).dtype
        return self._shaped(
            eqn,
            AbsVal(
                out_dtype,
                lo=k * plo if math.isfinite(plo) else plo,
                hi=k * phi if math.isfinite(phi) else phi,
                integral=a.integral and b.integral,
                known=a.known and b.known,
            ),
        )

    def _prim_reduce_sum(self, eqn, ins):
        a = ins[0]
        k = 1
        for d in eqn.params["axes"]:
            k *= int(a.shape[d]) if a.shape else 1
        return self._shaped(
            eqn,
            AbsVal(
                a.dtype,
                lo=k * a.lo if math.isfinite(a.lo) else a.lo,
                hi=k * a.hi if math.isfinite(a.hi) else a.hi,
                integral=a.integral,
                known=a.known,
            ),
        )

    def _prim_cumsum(self, eqn, ins):
        a = ins[0]
        axis = int(eqn.params.get("axis", 0))
        n = int(a.shape[axis]) if a.shape else 1
        lo = min(a.lo, n * a.lo) if math.isfinite(a.lo) else a.lo
        hi = max(a.hi, n * a.hi) if math.isfinite(a.hi) else a.hi
        return self._shaped(eqn, a.with_(lo=lo, hi=hi, clip=None))

    def _prim_argmax(self, eqn, ins):
        a = ins[0]
        n = 1
        for d in eqn.params.get("axes", ()):
            n *= int(a.shape[d]) if a.shape else 1
        out_dtype = self._out_aval(eqn).dtype
        return self._shaped(
            eqn, AbsVal(out_dtype, lo=0.0, hi=float(max(n - 1, 0)), integral=True, known=True)
        )

    _prim_argmin = _prim_argmax

    def _prim_scatter_add(self, eqn, ins):
        tgt, _idx, upd = ins
        n = 1
        for d in upd.shape:
            n *= int(d)
        lo = tgt.lo + n * min(0.0, upd.lo)
        hi = tgt.hi + n * max(0.0, upd.hi)
        if not math.isfinite(upd.lo):
            lo = -Inf
        if not math.isfinite(upd.hi):
            hi = Inf
        return self._shaped(
            eqn,
            AbsVal(
                tgt.dtype,
                lo=lo,
                hi=hi,
                integral=tgt.integral and upd.integral,
                known=tgt.known and upd.known,
            ),
        )

    def _prim_scatter(self, eqn, ins):
        aval = self._out_aval(eqn)
        return [_hull([ins[0], ins[2]], aval.dtype, tuple(int(d) for d in aval.shape))]

    # -- collectives -----------------------------------------------------

    def _axis_prod(self, eqn) -> int:
        n = 1
        for ax in eqn.params.get("axes", eqn.params.get("axis_name", ())):
            n *= int(self.ctx.axis_sizes.get(ax, 1))
        return n

    def _prim_psum(self, eqn, ins):
        n = self._axis_prod(eqn)
        outs = []
        for i, a in enumerate(ins):
            lo = n * a.lo if math.isfinite(a.lo) else a.lo
            hi = n * a.hi if math.isfinite(a.hi) else a.hi
            outs.append(self._shaped(eqn, a.with_(lo=lo, hi=hi, clip=None), i)[0])
        return outs

    def _prim_pmax(self, eqn, ins):
        return [self._shaped(eqn, a, i)[0] for i, a in enumerate(ins)]

    _prim_pmin = _prim_pmax
    _prim_all_gather = _prim_pmax

    # -- higher-order / staging -----------------------------------------

    def _recurse(self, tag: str, jaxpr: Any, consts: list[AbsVal], args: list[AbsVal]) -> list[AbsVal]:
        self.ctx.call_stack.append(tag)
        try:
            return self.eval_jaxpr(jaxpr, consts, args)
        finally:
            self.ctx.call_stack.pop()

    def _prim_pjit(self, eqn, ins):
        closed = eqn.params["jaxpr"]
        name = eqn.params.get("name", "pjit")
        consts = [absval_from_literal(c) for c in closed.consts]
        return self._recurse(f"pjit:{name}", closed.jaxpr, consts, ins)

    def _prim_closed_call(self, eqn, ins):
        closed = eqn.params.get("call_jaxpr") or eqn.params.get("jaxpr")
        consts = [absval_from_literal(c) for c in closed.consts]
        return self._recurse("closed_call", closed.jaxpr, consts, ins)

    def _prim_custom_jvp_call(self, eqn, ins):
        closed = eqn.params["call_jaxpr"]
        consts = [absval_from_literal(c) for c in closed.consts]
        return self._recurse("custom_jvp", closed.jaxpr, consts, ins)

    def _prim_custom_vjp_call(self, eqn, ins):
        closed = eqn.params.get("call_jaxpr") or eqn.params.get("fun_jaxpr")
        consts = [absval_from_literal(c) for c in closed.consts]
        return self._recurse("custom_vjp", closed.jaxpr, consts, ins)

    _prim_custom_vjp_call_jaxpr = _prim_custom_vjp_call

    def _prim_remat(self, eqn, ins):
        jaxpr = eqn.params["jaxpr"]
        return self._recurse("remat", jaxpr, [], ins)

    _prim_checkpoint = _prim_remat

    def _prim_cond(self, eqn, ins):
        branches = eqn.params["branches"]
        ops = ins[1:]
        branch_outs = []
        for i, br in enumerate(branches):
            consts = [absval_from_literal(c) for c in br.consts]
            branch_outs.append(self._recurse(f"cond:branch{i}", br.jaxpr, consts, list(ops)))
        outs = []
        for i in range(len(eqn.outvars)):
            aval = self._out_aval(eqn, i)
            outs.append(
                _hull([bo[i] for bo in branch_outs], aval.dtype, tuple(int(d) for d in aval.shape))
            )
        return outs

    def _prim_while(self, eqn, ins):
        cn = eqn.params["cond_nconsts"]
        bn = eqn.params["body_nconsts"]
        body = eqn.params["body_jaxpr"]
        body_consts = ins[cn : cn + bn]
        carry = list(ins[cn + bn :])
        closed_consts = [absval_from_literal(c) for c in body.consts]

        def body(c: list[AbsVal]) -> list[AbsVal]:
            return self._recurse("while:body", body.jaxpr, closed_consts, body_consts + c)

        was_muted = self.ctx.muted
        self.ctx.muted = True
        try:
            carry = self._fixpoint_carry("while:body", body, carry, n_iters=None)
        finally:
            self.ctx.muted = was_muted
        final = body(carry)  # one unmuted pass at the widest carry state
        carry = [
            c.with_(lo=min(c.lo, f.lo), hi=max(c.hi, f.hi))
            for c, f in zip(carry, final)
        ]
        return [self._shaped(eqn, c, i)[0] for i, c in enumerate(carry)]

    def _prim_scan(self, eqn, ins):
        params = eqn.params
        num_consts = params["num_consts"]
        num_carry = params["num_carry"]
        length = int(params["length"])
        closed = params["jaxpr"]
        consts = ins[:num_consts]
        carry0 = list(ins[num_consts : num_consts + num_carry])
        xs = ins[num_consts + num_carry :]
        closed_consts = [absval_from_literal(c) for c in closed.consts]

        # per-iteration slices of xs keep the same interval
        def body(carry: list[AbsVal]) -> list[AbsVal]:
            outs = self._recurse(
                "scan:body", closed.jaxpr, closed_consts, consts + carry + list(xs)
            )
            return outs

        was_muted = self.ctx.muted
        self.ctx.muted = True
        try:
            carry = self._scan_carry(body, carry0, length, num_carry)
        finally:
            self.ctx.muted = was_muted
        final = body(carry)
        carry_out = final[:num_carry]
        ys = final[num_carry:]
        outs = []
        for i in range(len(eqn.outvars)):
            src = carry_out[i] if i < num_carry else ys[i - num_carry]
            outs.append(self._shaped(eqn, src, i)[0])
        return outs

    def _scan_carry(
        self,
        body: Callable[[list[AbsVal]], list[AbsVal]],
        carry0: list[AbsVal],
        length: int,
        num_carry: int,
    ) -> list[AbsVal]:
        """Bound the scan carry after ``length`` iterations.

        Detects linear accumulation: if one body application grows each
        carry interval by a constant increment (d_lo, d_hi) and a second
        application grows it by the same increment, the closed form
        ``carry0 + length * d`` bounds the final carry — this is what
        proves "C frames x E events x max vote <= int32 max" without
        unrolling C iterations.  Nonlinear growth falls back to a short
        fixpoint iteration and then widens to the dtype default.
        """
        if length <= 0 or num_carry == 0:
            return carry0
        c1 = body(carry0)[:num_carry]
        c2 = body(c1)[:num_carry]
        grown: list[AbsVal] = []
        linear = True
        for a0, a1, a2 in zip(carry0, c1, c2):
            d_lo1, d_hi1 = a1.lo - a0.lo, a1.hi - a0.hi
            d_lo2, d_hi2 = a2.lo - a1.lo, a2.hi - a1.hi
            finite = all(
                math.isfinite(x) for x in (d_lo1, d_hi1, d_lo2, d_hi2)
            )
            if finite and math.isclose(d_lo1, d_lo2, abs_tol=1e-6) and math.isclose(
                d_hi1, d_hi2, abs_tol=1e-6
            ):
                grown.append(
                    a0.with_(
                        lo=min(a0.lo, a0.lo + length * d_lo1),
                        hi=max(a0.hi, a0.hi + length * d_hi1),
                    )
                )
            else:
                linear = False
                grown.append(a0)
        if linear:
            return grown
        return self._fixpoint_carry("scan", body, carry0, n_iters=length, num_carry=num_carry)

    def _fixpoint_carry(
        self,
        tag: str,
        body: Callable[[list[AbsVal]], list[AbsVal]],
        carry0: list[AbsVal],
        n_iters: int | None,
        num_carry: int | None = None,
    ) -> list[AbsVal]:
        carry = carry0
        max_steps = min(n_iters, 32) if n_iters is not None else 32
        for _ in range(max_steps):
            nxt = body(carry)
            if num_carry is not None:
                nxt = nxt[:num_carry]
            nxt = [
                c.with_(lo=min(c.lo, n.lo), hi=max(c.hi, n.hi), integral=c.integral and n.integral)
                for c, n in zip(carry, nxt)
            ]
            if all(n.lo == c.lo and n.hi == c.hi for c, n in zip(carry, nxt)):
                return nxt
            carry = nxt
        if n_iters is not None and n_iters <= 32:
            return carry
        # did not converge within budget: widen to the dtype default
        return [
            absval_from_aval_like(c).with_(integral=c.integral) for c in carry
        ]

    def _prim_shard_map(self, eqn, ins):
        jaxpr = eqn.params["jaxpr"]  # raw Jaxpr
        mesh = eqn.params.get("mesh")
        if mesh is not None:
            for name, size in zip(mesh.axis_names, mesh.devices.shape):
                self.ctx.axis_sizes[str(name)] = int(size)
        return self._recurse("shard_map", jaxpr, [], ins)

    def _prim_pallas_call(self, eqn, ins):
        jaxpr = eqn.params["jaxpr"]  # raw Jaxpr over Refs
        n_in = len(ins)
        refs: dict[Any, AbsVal] = {}
        for i, v in enumerate(jaxpr.invars):
            if i < n_in:
                base = ins[i]
                inner = absval_from_aval(v.aval)
                refs[v] = inner.with_(
                    lo=base.lo, hi=base.hi, integral=base.integral, known=base.known
                )
            else:
                # output refs start zero-initialized or undefined; assume 0
                inner = absval_from_aval(v.aval)
                refs[v] = inner.with_(lo=0.0, hi=0.0, integral=True, known=True)
        self.ctx.call_stack.append("pallas_call")
        try:
            self._eval_pallas_body(jaxpr, refs)
        finally:
            self.ctx.call_stack.pop()
        outs = []
        out_refs = jaxpr.invars[n_in:]
        for i in range(len(eqn.outvars)):
            if i < len(out_refs):
                st = refs[out_refs[i]]
                outs.append(self._shaped(eqn, st, i)[0])
            else:
                outs.append(absval_from_aval(self._out_aval(eqn, i)))
        return outs

    def _eval_pallas_body(self, jaxpr: Any, refs: dict[Any, AbsVal]) -> None:
        """Best-effort walk of a Pallas kernel body over a Ref env.

        ``get`` reads the ref state, ``swap`` / ``addupdate`` widen it
        (the grid may revisit a block arbitrarily often, so stores are
        treated as accumulating into an unknown number of slots).  All
        equations are still fed to the rules, so a fractional float->int
        cast inside a kernel body is flagged exactly like one outside.
        """
        env: dict[Any, AbsVal] = dict(refs)

        def read(atom: Any) -> AbsVal:
            if isinstance(atom, jcore.Literal):
                return absval_from_literal(atom.val)
            got = env.get(atom)
            if got is None:
                got = absval_from_aval(atom.aval)
            return got

        for eqn in jaxpr.eqns:
            name = eqn.primitive.name
            ins = [read(x) for x in eqn.invars]
            if name == "get":
                ref_var = eqn.invars[0]
                st = env.get(ref_var, absval_from_aval(ref_var.aval))
                outs = self._shaped(eqn, st)
            elif name in ("swap", "masked_swap"):
                ref_var = eqn.invars[0]
                st = env.get(ref_var, absval_from_aval(ref_var.aval))
                new = ins[1]
                merged = st.with_(
                    lo=min(st.lo, new.lo),
                    hi=max(st.hi, new.hi),
                    integral=st.integral and new.integral,
                    known=st.known and new.known,
                )
                env[ref_var] = merged
                outs = self._shaped(eqn, st) if eqn.outvars else []
            elif name in ("addupdate", "masked_addupdate"):
                ref_var = eqn.invars[0]
                st = env.get(ref_var, absval_from_aval(ref_var.aval))
                new = ins[1]
                if new.lo == 0.0 and new.hi == 0.0:
                    merged = st
                else:
                    # unknown grid revisit count: any nonzero accumulation
                    # widens toward the dtype default
                    widened = absval_from_aval(_inner_aval(ref_var.aval))
                    merged = widened.with_(integral=st.integral and new.integral)
                env[ref_var] = merged
                outs = []
            elif name == "program_id":
                outs = self._shaped(
                    eqn, AbsVal(np.dtype(np.int32), lo=0.0, hi=Inf, integral=True, known=False)
                )
            elif name == "cond":
                outs = self._prim_cond(eqn, ins)
            else:
                outs = self.eval_eqn(eqn, ins)
            for rule in self.ctx.rules:
                rule.on_eqn(self.ctx, eqn, ins, outs)
            for var, out in zip(eqn.outvars, outs):
                env[var] = out


def absval_from_aval_like(v: AbsVal) -> AbsVal:
    dtype = np.dtype(v.dtype)
    if _is_bool(dtype):
        return AbsVal(dtype, v.shape, v.weak_type, 0.0, 1.0, True, True)
    if _is_int(dtype):
        lo, hi = int_range(dtype)
        return AbsVal(dtype, v.shape, v.weak_type, lo, hi, True, False)
    return AbsVal(dtype, v.shape, v.weak_type, -Inf, Inf, False, False)


def analyze_program(
    fn: Callable[..., Any],
    args: Sequence[Any],
    contracts: Sequence[AbsVal] | None,
    *,
    entry: str,
    rules: list[Rule],
    sanctioned_clips: frozenset[tuple[float, float]] = frozenset(),
) -> Context:
    """Trace ``fn(*args)`` (args are ShapeDtypeStructs) and run the rules.

    ``contracts`` — one AbsVal per *flattened* input leaf, or ``None``
    to use the dtype defaults.  Returns the populated :class:`Context`.
    """
    closed = jax.make_jaxpr(fn)(*args)
    leaves = jax.tree_util.tree_leaves(tuple(args))
    if contracts is None:
        in_absvals = [
            absval_from_aval(jcore.ShapedArray(l.shape, l.dtype)) for l in leaves
        ]
    else:
        if len(contracts) != len(closed.jaxpr.invars):
            raise ValueError(
                f"{entry}: {len(contracts)} contracts for {len(closed.jaxpr.invars)} inputs"
            )
        in_absvals = list(contracts)
    ctx = Context(entry=entry, rules=rules, sanctioned_clips=sanctioned_clips)
    DtypeFlowAnalyzer(ctx).run(closed, in_absvals)
    return ctx
