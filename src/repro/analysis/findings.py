"""Finding / Provenance dataclasses and the suppression baseline.

A ``Finding`` is one rule violation with full jaxpr provenance: the
primitive, the source line of the offending equation (via JAX's
``source_info``), and the enclosing call stack the interpreter
maintained while recursing through pjit / scan / shard_map /
pallas_call bodies.

Suppression is baseline-driven: every finding has a stable
``fingerprint`` (rule, kind, entry point, primitive, source function —
deliberately *not* the line number, which churns under unrelated
edits).  Fingerprints listed in the checked-in baseline JSON are
reported as suppressed and do not fail the lint; anything new does.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Iterable


@dataclasses.dataclass(frozen=True)
class Provenance:
    """Where in the traced program a finding was raised."""

    primitive: str  # jaxpr primitive name, e.g. "convert_element_type"
    source: str  # summarized source_info, e.g. "core/voting.py:155 (vote_scatter)"
    call_stack: tuple[str, ...] = ()  # enclosing pjit/scan/shard_map bodies, outermost first
    eqn: str = ""  # pretty-printed equation (truncated)

    def render(self) -> str:
        stack = " > ".join(self.call_stack) if self.call_stack else "<top>"
        return f"{self.primitive} @ {self.source} [{stack}]"


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation in one traced program."""

    rule: str  # rule id, e.g. "dtype-flow"
    kind: str  # finding class within the rule, e.g. "float-to-int-truncation"
    entry: str  # traced program name, e.g. "sweep[matmul,batched,bilinear,quant]"
    message: str
    provenance: Provenance
    severity: str = "error"  # "error" | "warning"

    @property
    def fingerprint(self) -> str:
        # Source *function* (file + defining function), not the line:
        # "voting.py:155 (vote_scatter)" -> "voting.py (vote_scatter)".
        src = self.provenance.source
        if ":" in src:
            head, _, tail = src.partition(":")
            fn = tail.partition(" ")[2] if " " in tail else ""
            src = f"{head.rsplit('/', 1)[-1]} {fn}".strip()
        return ":".join(
            (self.rule, self.kind, self.entry, self.provenance.primitive, src)
        )

    def render(self) -> str:
        return (
            f"[{self.severity}] {self.rule}/{self.kind} in {self.entry}: "
            f"{self.message}\n    at {self.provenance.render()}"
        )

    def to_json(self) -> dict[str, Any]:
        d = dataclasses.asdict(self)
        d["provenance"]["call_stack"] = list(self.provenance.call_stack)
        d["fingerprint"] = self.fingerprint
        return d


def load_baseline(path: str) -> set[str]:
    """Read the suppression baseline: a set of finding fingerprints."""
    with open(path) as fh:
        data = json.load(fh)
    return set(data.get("suppressed", []))


def write_baseline(path: str, findings: Iterable[Finding]) -> None:
    fps = sorted({f.fingerprint for f in findings})
    with open(path, "w") as fh:
        json.dump({"suppressed": fps}, fh, indent=2)
        fh.write("\n")


def split_by_baseline(
    findings: Iterable[Finding], baseline: set[str]
) -> tuple[list[Finding], list[Finding]]:
    """Partition findings into (new, suppressed) against the baseline."""
    new: list[Finding] = []
    suppressed: list[Finding] = []
    for f in findings:
        (suppressed if f.fingerprint in baseline else new).append(f)
    return new, suppressed
