"""Static analysis for the sweep datapaths.

A jaxpr-interpreting linter that traces every sweep program (formulation
x backend x interpolation x quantization) *without executing it* and
enforces the quantization contracts of Table 1:

- ``dtype_flow``: abstract interpretation over jaxprs — worst-case value
  intervals, fractional-value tracking and clamp provenance — proving
  the int32 accumulator / int16 saturating store cannot silently wrap,
  and flagging float->int casts that discard fractional bilinear votes
  (the PR 3 bug class), f64 promotions and weak_type leaks.
- ``rules``: the typed, suppressible rule set (dtype-flow/overflow,
  host-sync detection, recompilation audit).
- ``lint``: the ``python -m repro.analysis.lint`` CLI and the program
  grid it checks, gated against a checked-in baseline.

See docs/quantization_contracts.md for the contract table and how to
suppress a finding.
"""
from repro.analysis.findings import Finding, Provenance, load_baseline, write_baseline
from repro.analysis.dtype_flow import AbsVal, DtypeFlowAnalyzer, analyze_program
from repro.analysis.rules import (
    DtypeFlowRule,
    HostSyncRule,
    audit_variant_space,
    default_rules,
)

__all__ = [
    "AbsVal",
    "DtypeFlowAnalyzer",
    "DtypeFlowRule",
    "Finding",
    "HostSyncRule",
    "Provenance",
    "analyze_program",
    "audit_variant_space",
    "default_rules",
    "load_baseline",
    "write_baseline",
]
