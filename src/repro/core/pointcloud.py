"""Depth-map -> point-cloud conversion and global map merging (M)."""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.camera import CameraModel, unproject
from repro.core.detection import DepthMap
from repro.core.geometry import SE3

Array = jax.Array


class PointCloud(NamedTuple):
    points: Array  # (N, 3) world-frame
    weights: Array  # (N,) confidence (ray-density score)
    valid: Array  # (N,) bool — fixed-size padding mask (jit-friendly)


def depth_map_to_points(cam: CameraModel, dm: DepthMap, T_w_ref: SE3) -> PointCloud:
    """Convert a semi-dense depth map to a fixed-size, masked point cloud."""
    h, w = dm.depth.shape
    ys, xs = jnp.meshgrid(jnp.arange(h, dtype=jnp.float32),
                          jnp.arange(w, dtype=jnp.float32), indexing="ij")
    pix = jnp.stack([xs, ys], axis=-1).reshape(-1, 2)
    pts_cam = unproject(cam, pix, dm.depth.reshape(-1))
    pts_w = T_w_ref.apply(pts_cam[None, :, :])[0]
    return PointCloud(
        points=pts_w,
        weights=dm.confidence.reshape(-1),
        valid=dm.mask.reshape(-1),
    )


@partial(jax.jit, static_argnames=("cam",))
def depth_maps_to_points(cam: CameraModel, dms: DepthMap, T_w_refs: SE3) -> PointCloud:
    """Batched `depth_map_to_points`: one device program for a whole bucket.

    dms carries stacked (S, h, w) fields; T_w_refs is a batched SE3
    ((S, 3, 3), (S, 3)). Returns a PointCloud with (S, h*w, ...) fields —
    one fixed-size masked cloud per key-frame segment.
    """
    return jax.vmap(
        lambda depth, mask, conf, R, t: depth_map_to_points(
            cam, DepthMap(depth, mask, conf), SE3(R, t)
        )
    )(dms.depth, dms.mask, dms.confidence, T_w_refs.R, T_w_refs.t)


def radius_outlier_filter(pc: PointCloud, radius: float = 0.05, min_neighbors: int = 2,
                          max_points: int = 20000) -> PointCloud:
    """Radius outlier removal (as in EMVS post-processing). NumPy host-side.

    O(N^2) over valid points, chunked; N is semi-dense (thousands), fine.
    """
    pts = np.asarray(pc.points)
    valid = np.asarray(pc.valid)
    idx = np.nonzero(valid)[0][:max_points]
    if idx.size == 0:
        return pc
    sub = pts[idx]
    keep = np.zeros(idx.shape[0], dtype=bool)
    chunk = 1024
    r2 = radius * radius
    for s in range(0, sub.shape[0], chunk):
        d2 = ((sub[s:s + chunk, None, :] - sub[None, :, :]) ** 2).sum(-1)
        keep[s:s + chunk] = (d2 < r2).sum(-1) - 1 >= min_neighbors
    new_valid = np.zeros_like(valid)
    new_valid[idx[keep]] = True
    return PointCloud(pc.points, pc.weights, jnp.asarray(new_valid))


def merge(global_pc: list[PointCloud], pc: PointCloud) -> list[PointCloud]:
    """Append a local cloud to the global map (list of fixed-size blocks)."""
    global_pc.append(pc)
    return global_pc


def concatenate(clouds: list[PointCloud]) -> PointCloud:
    return PointCloud(
        points=jnp.concatenate([c.points for c in clouds], axis=0),
        weights=jnp.concatenate([c.weights for c in clouds], axis=0),
        valid=jnp.concatenate([c.valid for c in clouds], axis=0),
    )
