"""Event back-projection (P): P(Z0) + P(Z0 -> Zi).

Pure-JAX reference path. The fused Pallas kernel in
`repro.kernels.backproject_vote` implements the same math tiled for VMEM;
tests assert allclose between the two.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.camera import CameraModel
from repro.core.geometry import (
    SE3,
    PlaneSweepCoeffs,
    apply_homography,
    canonical_homography,
    propagate_to_planes,
    proportional_coeffs,
)

Array = jax.Array


class FrameGeometry(NamedTuple):
    """Per-event-frame geometry computed once on the host side (paper: ARM).

    H:   (3, 3)  canonical homography, quantizable to Q11.21
    phi: PlaneSweepCoeffs with (Nz,) alpha/beta_x/beta_y, quantizable Q11.21
    """

    H: Array
    phi: PlaneSweepCoeffs


def frame_geometry(
    cam: CameraModel, T_w_ref: SE3, T_w_cam: SE3, z0: Array, planes: Array
) -> FrameGeometry:
    """Sub-tasks 1 & 3 of P: compute H_Z0 and phi (once per event frame)."""
    T_ref_cam = T_w_ref.inverse().compose(T_w_cam)
    H = canonical_homography(cam, T_ref_cam, z0)
    phi = proportional_coeffs(cam, T_ref_cam, z0, planes)
    return FrameGeometry(H, phi)


def backproject_canonical(cam: CameraModel, xy: Array, H: Array) -> Array:
    """Sub-task 2, P(Z0): homography + normalization per event. (E,2)->(E,2)."""
    del cam  # kept in the signature for symmetry with the quantized path
    return apply_homography(H, xy)


def backproject_planes(
    cam: CameraModel, xy0: Array, phi: PlaneSweepCoeffs
) -> tuple[Array, Array]:
    """Sub-task 4, P(Z0 -> Zi): (E,2) -> ((Nz,E), (Nz,E))."""
    return propagate_to_planes(cam, xy0, phi)


def backproject_frame(
    cam: CameraModel, xy: Array, geom: FrameGeometry
) -> tuple[Array, Array]:
    """Full P for one event frame: (E,2) raw coords -> per-plane coords."""
    xy0 = backproject_canonical(cam, xy, geom.H)
    return backproject_planes(cam, xy0, geom.phi)
