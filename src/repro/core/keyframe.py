"""Key-frame selection (K).

A new key reference view is declared when the camera has translated more
than `dist_threshold` (a fraction of the mean scene depth, as in EMVS)
from the previous key frame. On key-frame: extract depth (D), merge (M),
reset the DSI, re-anchor the reference pose.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.geometry import SE3

Array = jax.Array


class KeyframeState(NamedTuple):
    T_w_ref: SE3  # current reference (virtual camera) pose
    keyframe_id: Array  # int32 counter
    dist_threshold: Array  # float32


def init_keyframe_state(T_w_ref: SE3, mean_depth: float, frac: float = 0.15) -> KeyframeState:
    return KeyframeState(
        T_w_ref=T_w_ref,
        keyframe_id=jnp.int32(0),
        dist_threshold=jnp.float32(mean_depth * frac),
    )


def is_new_keyframe(state: KeyframeState, T_w_cam: SE3) -> Array:
    """True when the camera moved beyond the threshold from the reference."""
    return jnp.linalg.norm(T_w_cam.t - state.T_w_ref.t) > state.dist_threshold


def advance_keyframe(state: KeyframeState, T_w_cam: SE3, new_kf: Array) -> KeyframeState:
    """Branchless keyframe update (pipeline-friendly, DESIGN.md §2)."""
    sel = lambda a, b: jnp.where(new_kf, a, b)
    T_new = SE3(
        R=sel(T_w_cam.R, state.T_w_ref.R),
        t=sel(T_w_cam.t, state.T_w_ref.t),
    )
    return KeyframeState(
        T_w_ref=T_new,
        keyframe_id=state.keyframe_id + new_kf.astype(jnp.int32),
        dist_threshold=state.dist_threshold,
    )
