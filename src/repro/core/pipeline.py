"""End-to-end EMVS pipeline: A -> P -> R -> (K) -> D -> M.

Key structural choice (mirrors the algorithm, DESIGN.md §2): key-frame
segmentation depends ONLY on the trajectory, not on event content, so the
segment boundaries are computed up front on the host (the ARM side in the
paper). Segments are then padded to a small set of fixed frame capacities
(multiple-of-four buckets) and processed by ONE jit'd device program per
bucket: a `lax.map` over segments whose body votes the segment's DSI
(scan over event frames, or the fused Pallas kernel), applies the int16
store semantics, runs detection and the median filter. Padded frames
repeat a real frame (finite geometry) and carry a validity weight of 0,
so they vote exactly nothing — the padded sweep matches the per-segment
path bitwise on the integer/nearest datapaths and to float tolerance on
the bilinear ones (tests enforce exactly that split).

This replaces the seed's host-side Python loop, which re-traced
`process_segment` for every distinct segment length and round-tripped
host<->device per segment — the "many small dispatches" pathology that
kills event-rate throughput. The looped path survives as
`run_emvs_looped` (a thin loop over `process_segment`, itself a
single-segment call into the batched sweep) for A/B benchmarking.

The voting hot loop supports three interchangeable formulations
(scatter / one-hot matmul / Pallas kernel) and the float vs Table-1
quantized datapaths; all are pairwise-validated by tests, batched and
looped alike.

Sweep backends: `run_emvs(sweep=...)` selects how each bucket runs.
`"batched"` (default) is the serial `lax.map` program above;
`"sharded"` hands the bucket to `repro.distributed.emvs
.process_segments_sharded`, which runs the SAME sweep body
(`sweep_segment_batch`) with the segment axis sharded across mesh
devices — the paper's key-frame-level parallelism. The backends agree
bitwise on the integer/nearest datapaths (tests/test_sharded_sweep.py).

Streaming entry point: `repro.serving.emvs_stream.EMVSStreamEngine`
drives this module online — `SegmentPlanner` (below) applies the K
criterion frame-by-frame as events arrive, closed segments are padded
into the same capacity buckets by `pad_segments`, and
`process_segments_batched` sweeps them with the segment axis padded to a
small fixed set of sizes so the jit cache stays bounded over an
unbounded stream. The engine's coalescing dispatcher groups queued
closed segments with `dispatch_group_head` / `plan_dispatch_groups`
(below): FIFO-order partitioning into same-capacity runs of at most one
S bucket each, so a dispatch policy can trade latency for batch size
without touching the numbers. The multi-tenant serving layer
(`repro.serving.sweep_dispatcher.SweepDispatcher`) generalizes both to
`(session, segment)`-tagged work via `dispatch_group_head_tagged` /
`plan_dispatch_groups_tagged`: per-stream FIFO is preserved while
shape-compatible segments from different sessions fill one S bucket
(`pad_segment_rows` gathers such cross-store groups), under a
FAIRNESS_POLICIES anchor rule (strict "fifo" vs starvation-bounded
"round_robin"). Per-segment outputs are bit-identical to `run_emvs` on
the integer/nearest datapaths for every chunking of the input, every
dispatch policy, and every session interleaving
(tests/test_streaming.py, tests/test_adaptive_dispatch.py,
tests/test_multi_stream.py).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dsi as dsi_lib
from repro.core.backproject import FrameGeometry, frame_geometry
from repro.core.camera import CameraModel
from repro.core.detection import DepthMap, detect_and_filter, detect_and_filter_from
from repro.core.dsi import DSIConfig
from repro.core.geometry import SE3, PlaneSweepCoeffs, apply_homography, propagate_to_planes
from repro.core.pointcloud import PointCloud, depth_map_to_points, depth_maps_to_points
from repro.core.voting import vote_onehot_matmul, vote_scatter
from repro.events.aggregation import EventFrames
from repro.quant.policies import TABLE1, EMVSQuantPolicy

Array = jax.Array

# Smallest fixed segment capacity: keeping a floor bounds the number of
# distinct compiled bucket shapes for trajectories with many tiny segments.
SEGMENT_BUCKET_MIN = 4

# Fairness policies for the TAGGED coalescing queue (multi-tenant serving):
#   * "fifo"        — every dispatch group anchors at the global queue head:
#     strict arrival order across streams. One stream's odd-capacity segment
#     can head-of-line delay the others (their shape-compatible segments
#     still ride along behind it, but a group never *anchors* past the head).
#   * "round_robin" — group anchors rotate over the streams in first-seen
#     order, skipping streams with nothing queued: a stream with queued work
#     is anchored again within at most (#streams) dispatches, so no stream
#     waits more than O(streams) dispatches behind a chatty neighbor.
#     Starvation-bounded; the property tests in tests/test_multi_stream.py
#     pin the bound.
FAIRNESS_POLICIES = ("fifo", "round_robin")


@dataclasses.dataclass(frozen=True)
class EMVSOptions:
    voting: str = "nearest"  # nearest | bilinear       (paper: nearest)
    formulation: str = "matmul"  # scatter | matmul | kernel (TPU-native: matmul)
    quantized: bool = False  # paper Table 1 hybrid quantization
    keyframe_dist_frac: float = 0.15  # threshold as fraction of mean scene depth
    detection_threshold_c: float = 6.0
    detection_min_votes: float = 3.0
    median_filter: bool = True
    policy: EMVSQuantPolicy = TABLE1
    # formulation="kernel" execution mode, resolved in ONE place
    # (repro.kernels.platform.resolve_interpret): None = compiled on
    # TPU/GPU with interpreter fallback elsewhere; True = force the
    # Pallas interpreter; False = require the compiled kernel
    # (ValueError on platforms without a Pallas compile path).
    kernel_interpret: bool | None = None


class SegmentResult(NamedTuple):
    depth_map: DepthMap
    dsi: Array
    T_w_ref: SE3
    frame_range: tuple[int, int]


class EMVSResult(NamedTuple):
    segments: list[SegmentResult]
    clouds: list[PointCloud]


class SegmentBatch(NamedTuple):
    """A bucket of key-frame segments padded to one fixed frame capacity C.

    Padded frame slots repeat the segment's last real frame so their
    geometry stays finite; `frame_valid` zeroes their vote weight.
    """

    xy: Array  # (S, C, E, 2) rectified event coords
    valid: Array  # (S, C, E) float32 per-event validity
    frame_valid: Array  # (S, C) float32 1 for real frames, 0 for padding
    poses_R: Array  # (S, C, 3, 3)
    poses_t: Array  # (S, C, 3)
    ref_R: Array  # (S, 3, 3) reference (key-frame) pose per segment
    ref_t: Array  # (S, 3)


# ---------------------------------------------------------------------------
# Key-frame segmentation (host-side, pose-only)
# ---------------------------------------------------------------------------


class SegmentPlanner:
    """Incremental key-frame segmentation: the K criterion, frame by frame.

    `push` one frame pose translation at a time; a segment closes the
    moment translation from the reference view exceeds the threshold, so
    a streaming caller can start voting a segment before the trajectory
    ends. `flush` closes the trailing segment at end of stream. The
    boundaries are exactly those of the offline `segment_keyframes`
    (which now routes through this planner), and segments shorter than
    `min_frames` are discarded on close — `plan_segments`' parallax
    filter, applied online.
    """

    def __init__(self, threshold: float, min_frames: int = 1):
        self.threshold = float(threshold)
        self.min_frames = int(min_frames)
        self._count = 0
        self._start = 0
        self._ref: np.ndarray | None = None

    @property
    def num_frames(self) -> int:
        """Frames pushed so far."""
        return self._count

    @property
    def open_start(self) -> int:
        """First frame index of the still-open segment (frames before it
        can be released by a streaming caller once dispatched)."""
        return self._start

    def _filtered(self, seg: tuple[int, int]) -> tuple[int, int] | None:
        return seg if seg[1] - seg[0] >= self.min_frames else None

    def push(self, t: np.ndarray) -> tuple[int, int] | None:
        """Feed the next frame's translation; returns a closed segment
        [start, end) the moment the K criterion trips, else None."""
        t = np.asarray(t)
        i = self._count
        self._count = i + 1
        if self._ref is None:
            self._ref = t
            return None
        if np.linalg.norm(t - self._ref) > self.threshold:
            closed = (self._start, i)
            self._start = i
            self._ref = t
            return self._filtered(closed)
        return None

    def flush(self) -> tuple[int, int] | None:
        """End of stream: close (and return) the trailing open segment."""
        if self._count == self._start:
            return None
        seg = (self._start, self._count)
        self._start = self._count
        self._ref = None
        return self._filtered(seg)


def segment_keyframes(poses: SE3, mean_depth: float, frac: float) -> list[tuple[int, int]]:
    """Split frame indices into key-frame segments [(start, end), ...).

    A segment's reference view is the pose of its first frame. A new
    segment begins when translation from the reference exceeds
    frac * mean_depth (the paper's K criterion). Implemented as one
    sweep of the incremental `SegmentPlanner`, so offline and streaming
    segmentation cannot drift apart. Zero frames -> no segments.
    """
    t = np.asarray(poses.t)
    planner = SegmentPlanner(mean_depth * frac, min_frames=1)
    bounds: list[tuple[int, int]] = []
    for i in range(t.shape[0]):
        closed = planner.push(t[i])
        if closed is not None:
            bounds.append(closed)
    tail = planner.flush()
    if tail is not None:
        bounds.append(tail)
    return bounds


def plan_segments(frames: EventFrames, dsi_cfg: DSIConfig,
                  opts: EMVSOptions) -> list[tuple[int, int]]:
    """Key-frame segments that carry enough parallax for a meaningful DSI."""
    mean_depth = 0.5 * (dsi_cfg.z_min + dsi_cfg.z_max)
    segs = segment_keyframes(frames.poses, mean_depth, opts.keyframe_dist_frac)
    return [(a, b) for a, b in segs if b - a >= 2]


def bucket_capacity(num_frames: int, minimum: int = SEGMENT_BUCKET_MIN) -> int:
    """Fixed per-bucket frame capacity: next multiple of `minimum`.

    Multiples of four bound the padding waste at 3 frames per segment
    (power-of-two buckets can waste ~50% of the vote work on long
    segments) while still collapsing the distinct compiled shapes to a
    handful per sequence.
    """
    if num_frames < 1:
        raise ValueError(f"segment must have at least one frame, got {num_frames}")
    return max(minimum, -(-num_frames // minimum) * minimum)


def dispatch_group_head(segs: Sequence[tuple[int, int]], max_group: int,
                        minimum: int = SEGMENT_BUCKET_MIN
                        ) -> tuple[int, int, bool]:
    """Head group of a FIFO queue of closed segments: `(n, capacity, sealed)`.

    The head group is the longest prefix of `segs` whose members share one
    `bucket_capacity`, capped at `max_group` segments (the largest S
    bucket a dispatch may carry). `sealed` means the group can never grow:
    either it already holds `max_group` segments, or the next queued
    segment needs a different frame capacity — a throughput-oriented
    coalescer may keep an unsealed group waiting for more segments, but a
    sealed one gains nothing by waiting.

    One SegmentBatch carries a single frame capacity, and a single
    stream's results must release in segment-close (FIFO) order, so only
    the head of the queue is ever eligible — a group never skips past a
    different-capacity segment queued ahead of it. (Implemented as the
    single-tag case of `dispatch_group_head_tagged`, where the group is
    always a queue prefix.)
    """
    indices, cap, sealed = dispatch_group_head_tagged(
        [(None, seg) for seg in segs], max_group, minimum)
    return len(indices), cap, sealed


def dispatch_group_head_tagged(queue: Sequence[tuple[Any, tuple[int, int]]],
                               max_group: int,
                               minimum: int = SEGMENT_BUCKET_MIN, *,
                               anchor: int = 0
                               ) -> tuple[list[int], int, bool]:
    """Head group of a TAGGED coalescing queue: `(indices, capacity, sealed)`.

    `queue` holds `(tag, (start, end))` work items in arrival order — the
    tag names the stream/session that closed the segment, so one queue can
    multiplex N cameras onto shared device sweeps. The group is anchored
    at `queue[anchor]` (which must be its own tag's oldest queued segment)
    and collects up to `max_group` members of the anchor's
    `bucket_capacity` by walking the queue forward under the per-stream
    FIFO rule: skipping an item blocks every later item of the same tag.
    A stream's results therefore always release in its own close order,
    while OTHER streams' shape-compatible segments may overtake a blocked
    neighbor and fill the S bucket — the cross-stream coalescing the
    multi-tenant engine is built on.

    Returns queue indices (ascending, starting at `anchor`), the shared
    frame capacity, and `sealed` with its `dispatch_group_head` meaning:
    the group can never grow (it is full, or some queued segment was left
    behind). With one tag and `anchor=0` this reduces exactly to the
    untagged head group.
    """
    if not queue:
        raise ValueError("dispatch_group_head needs a non-empty queue")
    if max_group < 1:
        raise ValueError(f"max_group must be >= 1, got {max_group}")
    if not 0 <= anchor < len(queue):
        raise ValueError(
            f"anchor {anchor} outside queue of {len(queue)} item(s)")
    tag0, (s0, e0) = queue[anchor]
    blocked = set()
    for j in range(anchor):
        tag, _ = queue[j]
        if tag == tag0:
            raise ValueError(
                "anchor must be its tag's oldest queued segment: anchoring "
                f"at index {anchor} would overtake an earlier segment of "
                "the same stream (per-stream FIFO)")
        blocked.add(tag)
    cap = bucket_capacity(e0 - s0, minimum)
    indices = [anchor]
    for i in range(anchor + 1, len(queue)):
        if len(indices) == max_group:
            break
        tag, (s, e) = queue[i]
        if tag in blocked or bucket_capacity(e - s, minimum) != cap:
            blocked.add(tag)
            continue
        indices.append(i)
    sealed = len(indices) == max_group or len(indices) < len(queue)
    return indices, cap, sealed


class DispatchPlanner:
    """Dispatch-group planning, optionally cost-aware.

    The partition rules are the streaming coalescer's, unchanged: head
    groups via `dispatch_group_head_tagged`, fairness anchoring via
    `FAIRNESS_POLICIES`. What the class adds over the module-level
    functions (which now delegate here) is *prediction*: given a
    duck-typed cost model — anything with
    ``predict_sweep_s(key) -> float | None`` — and a ``variant_of``
    factory mapping a padded ``(s_bucket, capacity)`` dispatch shape to
    the model's key type, the planner predicts what a group costs and
    how long draining a queue would take. That is the signal the
    SLO-aware adaptive policy (`StreamConfig(target_latency_s=)`) and
    the deterministic replayer (`repro.serving.dispatch_replay`)
    schedule against.

    A cost model NEVER changes which groups form — only when a
    scheduler chooses to dispatch them. With ``cost_model=None`` (or
    one that predicts ``None``) every prediction is ``None`` and
    consumers fall back to the pre-cost-model heuristics, which is how
    the "latency"/"throughput" policies and the null-model adaptive
    policy keep bitwise-identical schedules
    (tests/test_adaptive_dispatch.py pins this). See
    docs/dispatch_planning.md for the full decision table.

    `s_buckets` are the fixed segment-axis pad sizes (ascending; the
    last is the planning `max_group`): predictions must account for the
    PADDED rows a dispatch sweeps, not just the real ones, or the model
    would reward under-filled buckets.
    """

    def __init__(self, s_buckets: Sequence[int],
                 minimum: int = SEGMENT_BUCKET_MIN, *,
                 cost_model=None, variant_of=None):
        s_buckets = tuple(s_buckets)
        if not s_buckets:
            raise ValueError("s_buckets must be non-empty")
        if list(s_buckets) != sorted(set(s_buckets)) or s_buckets[0] < 1:
            raise ValueError(
                f"s_buckets must be strictly ascending positive ints, got "
                f"{s_buckets}")
        self.s_buckets = s_buckets
        self.max_group = s_buckets[-1]
        self.minimum = minimum
        self.cost_model = cost_model
        self.variant_of = variant_of

    # --- partitioning (the PR 5/6 rules, verbatim) ------------------------

    def head(self, segs: Sequence[tuple[int, int]]) -> tuple[int, int, bool]:
        return dispatch_group_head(segs, self.max_group, self.minimum)

    def head_tagged(self, queue: Sequence[tuple[Any, tuple[int, int]]], *,
                    anchor: int = 0) -> tuple[list[int], int, bool]:
        return dispatch_group_head_tagged(queue, self.max_group,
                                          self.minimum, anchor=anchor)

    def plan(self, segs: Sequence[tuple[int, int]]
             ) -> list[tuple[list[tuple[int, int]], int]]:
        groups: list[tuple[list[tuple[int, int]], int]] = []
        i = 0
        while i < len(segs):
            n, cap, _ = self.head(segs[i:])
            groups.append((list(segs[i:i + n]), cap))
            i += n
        return groups

    def plan_tagged(self, items: Sequence[tuple[Any, tuple[int, int]]], *,
                    fairness: str = "fifo"
                    ) -> list[tuple[list[tuple[Any, tuple[int, int]]], int]]:
        if fairness not in FAIRNESS_POLICIES:
            raise ValueError(f"unknown fairness {fairness!r}: expected one "
                             f"of {FAIRNESS_POLICIES}")
        queue = list(items)
        order: list[Any] = []
        for tag, _ in queue:
            if tag not in order:
                order.append(tag)
        cursor = 0
        groups: list[tuple[list[tuple[Any, tuple[int, int]]], int]] = []
        while queue:
            anchor = 0
            if fairness == "round_robin" and len(order) > 1:
                present = {tag for tag, _ in queue}
                for k in range(len(order)):
                    tag = order[(cursor + k) % len(order)]
                    if tag in present:
                        cursor = (cursor + k + 1) % len(order)
                        anchor = next(i for i, (t, _) in enumerate(queue)
                                      if t == tag)
                        break
            idx, cap, _ = self.head_tagged(queue, anchor=anchor)
            groups.append(([queue[i] for i in idx], cap))
            for i in reversed(idx):
                queue.pop(i)
        return groups

    # --- prediction -------------------------------------------------------

    def s_bucket(self, n: int) -> int:
        """Smallest fixed S bucket a group of `n` segments pads to."""
        for b in self.s_buckets:
            if b >= n:
                return b
        raise ValueError(f"group of {n} exceeds top segment bucket "
                         f"{self.s_buckets[-1]}")

    def predict_group_s(self, n_segments: int, capacity: int) -> float | None:
        """Predicted wall time of one dispatched group, or None when the
        model (or the variant factory) has nothing to say."""
        if self.cost_model is None or self.variant_of is None:
            return None
        key = self.variant_of(self.s_bucket(n_segments), capacity)
        return self.cost_model.predict_sweep_s(key)

    def predict_drain_s(self, items: Sequence[tuple[Any, tuple[int, int]]],
                        *, fairness: str = "fifo") -> float | None:
        """Predicted serial time to sweep an entire tagged queue, planned
        exactly as a full drain would partition it. None unless EVERY
        group gets a prediction — a partially predictable drain is not a
        deadline anyone should schedule against."""
        total = 0.0
        for group, cap in self.plan_tagged(items, fairness=fairness):
            cost = self.predict_group_s(len(group), cap)
            if cost is None:
                return None
            total += cost
        return total


def plan_dispatch_groups(segs: Sequence[tuple[int, int]], max_group: int,
                         minimum: int = SEGMENT_BUCKET_MIN
                         ) -> list[tuple[list[tuple[int, int]], int]]:
    """Partition a FIFO list of closed segments into dispatch groups.

    Repeated `dispatch_group_head`, so the partition is exactly what a
    streaming coalescer draining the whole queue would dispatch: each
    group is `(segments, frame_capacity)`, groups concatenate back to
    `segs` in order (nothing dropped, duplicated, or reordered), every
    group holds 1..max_group segments of one shared capacity. This is
    the bucket planning `run_emvs`'s capacity map performs offline,
    restated under the streaming FIFO-release constraint — the
    coalescing-planner property test pins these invariants for any
    segment sequence. (Delegates to a cost-model-free `DispatchPlanner`;
    the partition is identical by construction.)
    """
    return DispatchPlanner(_planner_buckets(max_group), minimum).plan(segs)


def _planner_buckets(max_group: int) -> tuple[int, ...]:
    # module-level planners know only the cap, not the full bucket set —
    # partitioning needs nothing else (prediction, which does, goes
    # through a DispatchPlanner constructed with the real buckets)
    if max_group < 1:
        raise ValueError(f"max_group must be >= 1, got {max_group}")
    return (max_group,)


def plan_dispatch_groups_tagged(
    items: Sequence[tuple[Any, tuple[int, int]]], max_group: int,
    minimum: int = SEGMENT_BUCKET_MIN, *, fairness: str = "fifo"
) -> list[tuple[list[tuple[Any, tuple[int, int]]], int]]:
    """Partition a TAGGED arrival order into dispatch groups.

    Repeated `dispatch_group_head_tagged` over a draining queue — exactly
    what the multi-tenant `SweepDispatcher` dispatches when it drains N
    sessions' closed segments, restated as a pure function for the
    property tests. Each group is `(tagged_segments, frame_capacity)`.
    (Delegates to a cost-model-free `DispatchPlanner`; the partition is
    identical by construction.)

    `fairness` picks how successive groups anchor (FAIRNESS_POLICIES):

      * "fifo" — every group anchors at the current queue head: strict
        global arrival order. A stream whose head-of-queue segment needs
        an odd frame capacity delays the anchors of everyone behind it
        (their shape-compatible segments still ride along as group
        members).
      * "round_robin" — anchors rotate over the tags in first-appearance
        order, skipping tags with nothing queued: a tag with queued work
        is anchored again after at most (#distinct tags) groups, so no
        stream waits more than O(streams) dispatches behind a chatty
        neighbor — at the cost of leaving the global arrival order.

    Invariants under BOTH policies (property-tested in
    tests/test_multi_stream.py): per tag, its segments appear in arrival
    order across the groups (per-stream FIFO); nothing is dropped,
    duplicated, or cross-tagged; every group holds 1..max_group segments
    sharing one `bucket_capacity`. With a single tag both policies
    reduce to `plan_dispatch_groups`.
    """
    return DispatchPlanner(_planner_buckets(max_group),
                           minimum).plan_tagged(items, fairness=fairness)


def _host_frames(frames: EventFrames) -> EventFrames:
    """One device-to-host transfer of the fields pad_segments gathers from."""
    return EventFrames(
        xy=np.asarray(frames.xy),
        valid=np.asarray(frames.valid),
        t_mid=frames.t_mid,
        poses=SE3(np.asarray(frames.poses.R), np.asarray(frames.poses.t)),
    )


def pad_segments(frames: EventFrames, segs: Sequence[tuple[int, int]],
                 capacity: int) -> SegmentBatch:
    """Gather a list of same-bucket segments into one padded SegmentBatch."""
    if not segs:
        raise ValueError(
            "pad_segments needs at least one segment: an empty segment "
            "list has no reference pose and nothing to sweep (callers "
            "must skip dispatch for empty buckets)")
    idx_rows, fv_rows = [], []
    for start, end in segs:
        n = end - start
        if not 0 < n <= capacity:
            raise ValueError(f"segment {(start, end)} does not fit capacity {capacity}")
        idx_rows.append(np.minimum(np.arange(start, start + capacity), end - 1))
        fv_rows.append((np.arange(capacity) < n).astype(np.float32))
    # Gather on the host with numpy: this is one-off ARM-side data staging,
    # and keeping it out of XLA avoids compiling a fleet of tiny gather
    # programs per bucket shape. Callers looping over buckets should pass
    # host-side frames (see _host_frames) so the device-to-host transfer
    # happens once per sequence, not once per bucket.
    idx = np.stack(idx_rows)  # (S, C) frame indices, clamped
    ref = np.array([s for s, _ in segs], dtype=np.int32)
    xy = np.asarray(frames.xy)
    valid = np.asarray(frames.valid)
    poses_R = np.asarray(frames.poses.R)
    poses_t = np.asarray(frames.poses.t)
    return SegmentBatch(
        xy=jnp.asarray(xy[idx]),
        valid=jnp.asarray(valid[idx].astype(np.float32)),
        frame_valid=jnp.asarray(np.stack(fv_rows)),
        poses_R=jnp.asarray(poses_R[idx]),
        poses_t=jnp.asarray(poses_t[idx]),
        ref_R=jnp.asarray(poses_R[ref]),
        ref_t=jnp.asarray(poses_t[ref]),
    )


def pad_segment_rows(rows: Sequence[tuple[EventFrames, tuple[int, int]]],
                     capacity: int) -> SegmentBatch:
    """`pad_segments` for segments that each bring their own frame window.

    The multi-tenant dispatcher coalesces shape-compatible segments from
    DIFFERENT sessions into one S bucket; their frames live in different
    per-session stores, so the batch is gathered row by row: `rows[k]` is
    `(frames_k, (start_k, end_k))` with indices relative to `frames_k`.
    Each row's gather is the same clamp-at-end indexing as
    `pad_segments`, so row k is bitwise what
    `pad_segments(frames_k, [seg_k], capacity)` would produce — grouping
    segments across sessions never changes a segment's numbers (the
    per-segment sweep body is independent).
    """
    if not rows:
        raise ValueError(
            "pad_segment_rows needs at least one segment row: an empty "
            "group has no reference pose and nothing to sweep (callers "
            "must skip dispatch for empty buckets)")
    xy_rows, valid_rows, fv_rows = [], [], []
    pr_rows, pt_rows, ref_r, ref_t = [], [], [], []
    for frames, (start, end) in rows:
        n = end - start
        xy = np.asarray(frames.xy)
        if not 0 < n <= capacity:
            raise ValueError(
                f"segment {(start, end)} does not fit capacity {capacity}")
        if not 0 <= start < end <= xy.shape[0]:
            raise ValueError(f"segment {(start, end)} outside its window of "
                             f"{xy.shape[0]} frame(s)")
        idx = np.minimum(np.arange(start, start + capacity), end - 1)
        valid = np.asarray(frames.valid)
        poses_R = np.asarray(frames.poses.R)
        poses_t = np.asarray(frames.poses.t)
        xy_rows.append(xy[idx])
        valid_rows.append(valid[idx].astype(np.float32))
        fv_rows.append((np.arange(capacity) < n).astype(np.float32))
        pr_rows.append(poses_R[idx])
        pt_rows.append(poses_t[idx])
        ref_r.append(poses_R[start])
        ref_t.append(poses_t[start])
    return SegmentBatch(
        xy=jnp.asarray(np.stack(xy_rows)),
        valid=jnp.asarray(np.stack(valid_rows)),
        frame_valid=jnp.asarray(np.stack(fv_rows)),
        poses_R=jnp.asarray(np.stack(pr_rows)),
        poses_t=jnp.asarray(np.stack(pt_rows)),
        ref_R=jnp.asarray(np.stack(ref_r)),
        ref_t=jnp.asarray(np.stack(ref_t)),
    )


# ---------------------------------------------------------------------------
# Per-frame projection (float + quantized datapaths)
# ---------------------------------------------------------------------------


def project_frame(
    cam: CameraModel,
    xy: Array,
    geom: FrameGeometry,
    opts: EMVSOptions,
) -> tuple[Array, Array]:
    """P for one frame: (E,2) -> per-plane coords ((Nz,E), (Nz,E))."""
    if opts.quantized:
        pol = opts.policy
        xy = pol.quantize_events(xy)
        H = pol.quantize_homography(geom.H)
        phi = pol.quantize_phi(geom.phi)
        xy0 = pol.quantize_canonical(apply_homography(H, xy))
        x_i, y_i = propagate_to_planes(cam, xy0, phi)
        if opts.voting == "nearest":
            # int8 plane-coord quantization (park-at-max for misses)
            x_i, y_i = pol.quantize_plane_coords(x_i, y_i)
        return x_i, y_i
    xy0 = apply_homography(geom.H, xy)
    return propagate_to_planes(cam, xy0, phi=geom.phi)


def vote_frame(
    dsi: Array,
    x_i: Array,
    y_i: Array,
    valid: Array,
    cam: CameraModel,
    opts: EMVSOptions,
) -> Array:
    """R for one frame. `valid` masks padded/invalid events (weight 0)."""
    w, h = cam.width, cam.height
    weights = jnp.broadcast_to(valid.astype(jnp.float32)[None, :], x_i.shape)
    if opts.formulation == "scatter":
        return vote_scatter(dsi, x_i, y_i, w=w, h=h, mode=opts.voting, weights=weights)
    if opts.formulation == "matmul":
        return vote_onehot_matmul(dsi, x_i, y_i, w=w, h=h, mode=opts.voting,
                                  weights=weights)
    if opts.formulation == "kernel":
        raise ValueError(
            "formulation='kernel' fuses projection and voting per segment; "
            "it is driven by process_segments_batched / process_segment, "
            "not per frame"
        )
    raise ValueError(f"unknown formulation {opts.formulation}")


# ---------------------------------------------------------------------------
# Segment processing: batched sweep (one compiled program per bucket)
# ---------------------------------------------------------------------------


def _accum_dtype(opts: EMVSOptions) -> Any:
    if opts.voting == "bilinear":
        return jnp.float32
    return dsi_lib.DSI_ACCUM_DTYPE


def precompute_batch_geometry(
    cam: CameraModel, poses_R: Array, poses_t: Array, T_w_ref: SE3,
    planes: Array, z0: Array
) -> FrameGeometry:
    """Vectorized H/phi for a stack of frame poses (ARM-side work)."""

    def per_frame(R, t):
        return frame_geometry(cam, T_w_ref, SE3(R, t), z0, planes)

    return jax.vmap(per_frame)(poses_R, poses_t)


def precompute_segment_geometry(
    cam: CameraModel, frames: EventFrames, T_w_ref: SE3, planes: Array, z0: Array
) -> FrameGeometry:
    """Vectorized H/phi for all frames of a segment (ARM-side work)."""
    return precompute_batch_geometry(cam, frames.poses.R, frames.poses.t,
                                     T_w_ref, planes, z0)


def sweep_segment_batch(
    cam: CameraModel,
    dsi_cfg: DSIConfig,
    batch: SegmentBatch,
    opts: EMVSOptions,
) -> tuple[Array, DepthMap]:
    """Traceable body of the segment sweep: vote, quantize-store, detect
    and filter a whole `SegmentBatch`.

    Deliberately un-jitted: `process_segments_batched` wraps it in one
    jit per bucket shape, and `repro.distributed.emvs
    .process_segments_sharded` wraps it in a `shard_map` over the segment
    axis — every segment is independent (the DSI resets per key frame),
    so both wrappers run the exact same per-segment program and their
    outputs agree bitwise on the integer/nearest datapaths.
    """
    planes = dsi_cfg.planes()
    z0 = planes[dsi_cfg.num_planes // 2]

    def one_segment(seg: SegmentBatch) -> tuple[Array, DepthMap]:
        T_w_ref = SE3(seg.ref_R, seg.ref_t)
        geoms = precompute_batch_geometry(cam, seg.poses_R, seg.poses_t,
                                          T_w_ref, planes, z0)

        if opts.formulation == "kernel":
            from repro.kernels.backproject_vote import ops as bpv_ops

            # Fused datapath: vote, int16 saturating store (when
            # quantized) and the depth max/argmax reduction all run
            # in-kernel against the VMEM-resident block — the stored DSI
            # makes exactly ONE HBM trip and is never read back for
            # detection (no post-kernel storage_roundtrip here).
            dsi, conf, zf = bpv_ops.backproject_vote_frames(
                seg.xy, seg.valid, geoms.H,
                jnp.stack([geoms.phi.alpha, geoms.phi.beta_x, geoms.phi.beta_y],
                          axis=-1),  # (C, Nz, 3)
                cam=cam, dsi_cfg=dsi_cfg, mode=opts.voting,
                quantized=opts.quantized, frame_valid=seg.frame_valid,
                interpret=opts.kernel_interpret,
            )
            if opts.quantized:
                # widen the int16 stored volume back to the accumulator
                # dtype so downstream consumers (saturation monitors,
                # point-cloud weights) see the same dtype as the XLA path
                dsi = dsi_lib.from_storage(dsi)
            dm = detect_and_filter_from(
                conf, zf, planes,
                threshold_c=opts.detection_threshold_c,
                min_votes=opts.detection_min_votes,
                median_filter=opts.median_filter,
            )
            return dsi, dm

        dsi0 = jnp.zeros(dsi_cfg.shape, dtype=_accum_dtype(opts))

        def body(dsi, frame):
            xy, valid, fv, H, alpha, beta_x, beta_y = frame
            geom = FrameGeometry(H, PlaneSweepCoeffs(alpha, beta_x, beta_y))
            x_i, y_i = project_frame(cam, xy, geom, opts)
            return vote_frame(dsi, x_i, y_i, valid * fv, cam, opts), None

        dsi, _ = jax.lax.scan(
            body,
            dsi0,
            (seg.xy, seg.valid, seg.frame_valid, geoms.H,
             geoms.phi.alpha, geoms.phi.beta_x, geoms.phi.beta_y),
        )

        if opts.quantized:
            dsi = dsi_lib.storage_roundtrip(dsi)  # int16 store semantics

        dm = detect_and_filter(
            dsi, planes,
            threshold_c=opts.detection_threshold_c,
            min_votes=opts.detection_min_votes,
            median_filter=opts.median_filter,
        )
        return dsi, dm

    return jax.lax.map(one_segment, batch)


@partial(jax.jit, static_argnames=("cam", "dsi_cfg", "opts"))
def process_segments_batched(
    cam: CameraModel,
    dsi_cfg: DSIConfig,
    batch: SegmentBatch,
    opts: EMVSOptions,
) -> tuple[Array, DepthMap]:
    """Vote, quantize-store, detect and filter a whole segment bucket.

    One compiled sweep: `lax.map` over the segment axis, so within a
    `run_emvs` call the trace happens once per bucket instead of once per
    segment, and no intermediate leaves the device. (The jit cache is
    keyed on the full batch shape — segment count S, capacity C, events E
    — so distinct sequences can still retrace; a streaming caller should
    pad S to stable sizes.) Returns stacked per-segment DSIs
    (S, Nz, h, w) and a DepthMap with (S, h, w) fields.

    This is the `sweep="batched"` backend of `run_emvs`; the
    `sweep="sharded"` backend (`process_segments_sharded`) runs the same
    body with the segment axis sharded across mesh devices.
    """
    return sweep_segment_batch(cam, dsi_cfg, batch, opts)


def process_segment(
    cam: CameraModel,
    dsi_cfg: DSIConfig,
    frames: EventFrames,
    T_w_ref: SE3,
    opts: EMVSOptions,
) -> tuple[Array, DepthMap]:
    """Vote all frames of one key-frame segment into a fresh DSI; detect.

    Thin wrapper over the batched sweep with a single unpadded segment, so
    per-segment and batched callers share one code path.
    """
    num_frames = frames.xy.shape[0]
    batch = SegmentBatch(
        xy=frames.xy[None],
        valid=frames.valid.astype(jnp.float32)[None],
        frame_valid=jnp.ones((1, num_frames), dtype=jnp.float32),
        poses_R=frames.poses.R[None],
        poses_t=frames.poses.t[None],
        ref_R=T_w_ref.R[None],
        ref_t=T_w_ref.t[None],
    )
    dsis, dms = process_segments_batched(cam, dsi_cfg, batch, opts)
    return dsis[0], DepthMap(dms.depth[0], dms.mask[0], dms.confidence[0])


# ---------------------------------------------------------------------------
# Full pipeline
# ---------------------------------------------------------------------------


def run_emvs(
    cam: CameraModel,
    dsi_cfg: DSIConfig,
    frames: EventFrames,
    opts: EMVSOptions = EMVSOptions(),
    *,
    sweep: str = "batched",
    mesh: Any | None = None,
) -> EMVSResult:
    """Process an aggregated event-frame sequence end to end.

    Segments are grouped into fixed frame-capacity buckets; each
    bucket is one sweep call plus one batched depth-map -> point-cloud
    conversion. Per-segment outputs are numerically identical to
    `run_emvs_looped` (padded frames vote with weight 0).

    sweep: which segment-sweep backend runs each bucket.
      * "batched" — `process_segments_batched`: one `lax.map` device
        program per bucket (serial over segments within the program).
      * "sharded" — `repro.distributed.emvs.process_segments_sharded`:
        the segment axis of each bucket is sharded across the devices of
        `mesh` (default: a 1-D mesh over all local devices), so
        concurrent segments vote on different devices — the paper's
        key-frame-level parallelism. The segment list is padded to a
        multiple of the mesh's segment-axis size by repeating the last
        segment; padded rows are discarded on harvest, and real rows are
        bit-identical to the batched backend on the integer/nearest
        datapaths (allclose on bilinear).
    """
    if sweep not in ("batched", "sharded"):
        raise ValueError(
            f"unknown sweep backend {sweep!r}: expected 'batched' or 'sharded'")
    if mesh is not None and sweep != "sharded":
        raise ValueError(
            "mesh= is only meaningful with sweep='sharded'; the batched "
            "sweep would silently ignore it")
    n_shard = 1
    if sweep == "sharded":
        from repro.distributed.emvs import (
            make_segment_mesh,
            process_segments_sharded,
            segment_axis_size,
        )

        if mesh is None:
            mesh = make_segment_mesh()
        n_shard = segment_axis_size(mesh)

    segs = plan_segments(frames, dsi_cfg, opts)
    if not segs:
        return EMVSResult(segments=[], clouds=[])

    by_cap: dict[int, list[tuple[int, int]]] = {}
    for seg in segs:
        by_cap.setdefault(bucket_capacity(seg[1] - seg[0]), []).append(seg)

    host = _host_frames(frames)
    out: dict[tuple[int, int], tuple[SegmentResult, PointCloud]] = {}
    for cap in sorted(by_cap):
        seg_list = by_cap[cap]
        # sharded sweeps need S divisible by the mesh's segment axis:
        # repeat the last segment (independent rows -> pure discarded work)
        run_list = seg_list + [seg_list[-1]] * (-len(seg_list) % n_shard)
        batch = pad_segments(host, run_list, cap)
        if sweep == "sharded":
            dsis, dms = process_segments_sharded(cam, dsi_cfg, batch, opts,
                                                 mesh=mesh)
        else:
            dsis, dms = process_segments_batched(cam, dsi_cfg, batch, opts)
        pcs = depth_maps_to_points(cam, dms, SE3(batch.ref_R, batch.ref_t))
        for k, (start, end) in enumerate(seg_list):
            dm = DepthMap(dms.depth[k], dms.mask[k], dms.confidence[k])
            T_w_ref = SE3(batch.ref_R[k], batch.ref_t[k])
            out[(start, end)] = (
                SegmentResult(dm, dsis[k], T_w_ref, (start, end)),
                PointCloud(pcs.points[k], pcs.weights[k], pcs.valid[k]),
            )

    ordered = [out[seg] for seg in segs]
    return EMVSResult(segments=[r for r, _ in ordered],
                      clouds=[c for _, c in ordered])


# ---------------------------------------------------------------------------
# Static-analysis entry points (repro.analysis)
# ---------------------------------------------------------------------------


class TensorContract(NamedTuple):
    """Worst-case input bounds the static analyzer may assume.

    `integral=True` asserts the tensor only holds integer *values*
    (whatever its storage dtype) — e.g. the 0/1 validity masks. These are
    semantic promises about what callers feed the sweep, not dtype facts;
    the linter's overflow proofs are conditional on them.
    """

    lo: float
    hi: float
    integral: bool = False


# per-field contracts for SegmentBatch inputs to the sweep programs:
# masks are exact 0/1, rotations are orthonormal (entries in [-1, 1]),
# coords/translations are bounded by any physically plausible rig
SWEEP_INPUT_CONTRACTS: dict[str, TensorContract] = {
    "xy": TensorContract(-4096.0, 4096.0),
    "valid": TensorContract(0.0, 1.0, integral=True),
    "frame_valid": TensorContract(0.0, 1.0, integral=True),
    "poses_R": TensorContract(-1.0, 1.0),
    "poses_t": TensorContract(-1e3, 1e3),
    "ref_R": TensorContract(-1.0, 1.0),
    "ref_t": TensorContract(-1e3, 1e3),
}


def sweep_trace_spec(
    cam: CameraModel,
    dsi_cfg: DSIConfig,
    opts: EMVSOptions,
    *,
    segments: int = 2,
    capacity: int = SEGMENT_BUCKET_MIN,
    events: int = 64,
    sweep: str = "batched",
    mesh=None,
):
    """Traceable sweep entry for `repro.analysis`: `(fn, args, contracts)`.

    `fn(*args)` stages the exact program the `sweep=` backend dispatches
    — `sweep_segment_batch` for "batched", `process_segments_sharded`
    (jit(shard_map(...))) for "sharded" — on `ShapeDtypeStruct` inputs,
    so `jax.make_jaxpr` can lint it without running anything. `contracts`
    maps `SegmentBatch` field names to `TensorContract`s seeding the
    analyzer's worst-case intervals.
    """
    s, c, e = segments, capacity, events
    f32 = jnp.float32
    batch = SegmentBatch(
        xy=jax.ShapeDtypeStruct((s, c, e, 2), f32),
        valid=jax.ShapeDtypeStruct((s, c, e), f32),
        frame_valid=jax.ShapeDtypeStruct((s, c), f32),
        poses_R=jax.ShapeDtypeStruct((s, c, 3, 3), f32),
        poses_t=jax.ShapeDtypeStruct((s, c, 3), f32),
        ref_R=jax.ShapeDtypeStruct((s, 3, 3), f32),
        ref_t=jax.ShapeDtypeStruct((s, 3), f32),
    )
    if sweep == "sharded":
        from repro.distributed.emvs import process_segments_sharded

        def fn(b: SegmentBatch):
            return process_segments_sharded(cam, dsi_cfg, b, opts, mesh=mesh)

    elif sweep == "batched":

        def fn(b: SegmentBatch):
            return sweep_segment_batch(cam, dsi_cfg, b, opts)

    else:
        raise ValueError(f"unknown sweep backend {sweep!r}")
    return fn, (batch,), dict(SWEEP_INPUT_CONTRACTS)


def run_emvs_looped(
    cam: CameraModel,
    dsi_cfg: DSIConfig,
    frames: EventFrames,
    opts: EMVSOptions = EMVSOptions(),
) -> EMVSResult:
    """Reference host-side per-segment loop (the seed's `run_emvs`).

    One device dispatch per segment and one retrace per distinct segment
    length — kept as the numerical baseline and the A/B counterpart for
    `benchmarks/segment_batching.py`.
    """
    results: list[SegmentResult] = []
    clouds: list[PointCloud] = []
    for start, end in plan_segments(frames, dsi_cfg, opts):
        sl = jax.tree.map(lambda a: a[start:end], frames)
        T_w_ref = SE3(frames.poses.R[start], frames.poses.t[start])
        dsi, dm = process_segment(cam, dsi_cfg, sl, T_w_ref, opts)
        results.append(SegmentResult(dm, dsi, T_w_ref, (start, end)))
        clouds.append(depth_map_to_points(cam, dm, T_w_ref))
    return EMVSResult(segments=results, clouds=clouds)
