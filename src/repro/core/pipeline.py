"""End-to-end EMVS pipeline: A -> P -> R -> (K) -> D -> M.

Key structural choice (mirrors the algorithm, DESIGN.md §2): key-frame
segmentation depends ONLY on the trajectory, not on event content, so the
segment boundaries are computed up front on the host (the ARM side in the
paper). Each key-frame segment is then processed by a single jit'd
`lax.scan` over its event frames — votes accumulate into a fresh DSI —
followed by detection and map merge. This is exactly the paper's
"reset DSI on key frame" semantics with a fully-compiled hot loop.

The voting hot loop supports three interchangeable formulations
(scatter / one-hot matmul / Pallas kernel) and the float vs Table-1
quantized datapaths; all are pairwise-validated by tests.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dsi as dsi_lib
from repro.core.backproject import FrameGeometry, frame_geometry
from repro.core.camera import CameraModel
from repro.core.detection import DepthMap, detect_structure, median_filter3
from repro.core.dsi import DSIConfig
from repro.core.geometry import SE3, PlaneSweepCoeffs, apply_homography, propagate_to_planes
from repro.core.pointcloud import PointCloud, depth_map_to_points
from repro.core.voting import vote_onehot_matmul, vote_scatter
from repro.events.aggregation import EventFrames
from repro.quant.policies import TABLE1, EMVSQuantPolicy

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class EMVSOptions:
    voting: str = "nearest"  # nearest | bilinear       (paper: nearest)
    formulation: str = "matmul"  # scatter | matmul | kernel (TPU-native: matmul)
    quantized: bool = False  # paper Table 1 hybrid quantization
    keyframe_dist_frac: float = 0.15  # threshold as fraction of mean scene depth
    detection_threshold_c: float = 6.0
    detection_min_votes: float = 3.0
    median_filter: bool = True
    policy: EMVSQuantPolicy = TABLE1


class SegmentResult(NamedTuple):
    depth_map: DepthMap
    dsi: Array
    T_w_ref: SE3
    frame_range: tuple[int, int]


class EMVSResult(NamedTuple):
    segments: list[SegmentResult]
    clouds: list[PointCloud]


# ---------------------------------------------------------------------------
# Key-frame segmentation (host-side, pose-only)
# ---------------------------------------------------------------------------


def segment_keyframes(poses: SE3, mean_depth: float, frac: float) -> list[tuple[int, int]]:
    """Split frame indices into key-frame segments [(start, end), ...).

    A segment's reference view is the pose of its first frame. A new
    segment begins when translation from the reference exceeds
    frac * mean_depth (the paper's K criterion).
    """
    t = np.asarray(poses.t)
    thresh = mean_depth * frac
    bounds: list[tuple[int, int]] = []
    start = 0
    ref = t[0]
    for i in range(1, t.shape[0]):
        if np.linalg.norm(t[i] - ref) > thresh:
            bounds.append((start, i))
            start = i
            ref = t[i]
    bounds.append((start, t.shape[0]))
    return bounds


# ---------------------------------------------------------------------------
# Per-frame projection (float + quantized datapaths)
# ---------------------------------------------------------------------------


def project_frame(
    cam: CameraModel,
    xy: Array,
    geom: FrameGeometry,
    opts: EMVSOptions,
) -> tuple[Array, Array]:
    """P for one frame: (E,2) -> per-plane coords ((Nz,E), (Nz,E))."""
    if opts.quantized:
        pol = opts.policy
        xy = pol.quantize_events(xy)
        H = pol.quantize_homography(geom.H)
        phi = pol.quantize_phi(geom.phi)
        xy0 = pol.quantize_canonical(apply_homography(H, xy))
        x_i, y_i = propagate_to_planes(cam, xy0, phi)
        if opts.voting == "nearest":
            # int8 plane-coord quantization (park-at-max for misses)
            x_i, y_i = pol.quantize_plane_coords(x_i, y_i)
        return x_i, y_i
    xy0 = apply_homography(geom.H, xy)
    return propagate_to_planes(cam, xy0, phi=geom.phi)


def vote_frame(
    dsi: Array,
    x_i: Array,
    y_i: Array,
    valid: Array,
    cam: CameraModel,
    opts: EMVSOptions,
) -> Array:
    """R for one frame. `valid` masks padded/invalid events (weight 0)."""
    w, h = cam.width, cam.height
    weights = jnp.broadcast_to(valid.astype(jnp.float32)[None, :], x_i.shape)
    if opts.formulation == "scatter":
        return vote_scatter(dsi, x_i, y_i, w=w, h=h, mode=opts.voting, weights=weights)
    if opts.formulation == "matmul":
        return vote_onehot_matmul(dsi, x_i, y_i, w=w, h=h, mode=opts.voting,
                                  weights=weights)
    if opts.formulation == "kernel":
        from repro.kernels.backproject_vote import ops as bpv_ops

        raise ValueError("kernel formulation is driven via process_segment")
    raise ValueError(f"unknown formulation {opts.formulation}")


# ---------------------------------------------------------------------------
# Segment processing (one key frame): scan over event frames
# ---------------------------------------------------------------------------


def _accum_dtype(opts: EMVSOptions) -> Any:
    if opts.voting == "bilinear":
        return jnp.float32
    return dsi_lib.DSI_ACCUM_DTYPE


def precompute_segment_geometry(
    cam: CameraModel, frames: EventFrames, T_w_ref: SE3, planes: Array, z0: Array
) -> FrameGeometry:
    """Vectorized H/phi for all frames of a segment (ARM-side work)."""

    def per_frame(R, t):
        return frame_geometry(cam, T_w_ref, SE3(R, t), z0, planes)

    return jax.vmap(per_frame)(frames.poses.R, frames.poses.t)


def process_segment(
    cam: CameraModel,
    dsi_cfg: DSIConfig,
    frames: EventFrames,
    T_w_ref: SE3,
    opts: EMVSOptions,
) -> tuple[Array, DepthMap]:
    """Vote all frames of one key-frame segment into a fresh DSI; detect."""
    planes = dsi_cfg.planes()
    z0 = planes[dsi_cfg.num_planes // 2]
    geoms = precompute_segment_geometry(cam, frames, T_w_ref, planes, z0)

    if opts.formulation == "kernel":
        from repro.kernels.backproject_vote import ops as bpv_ops

        dsi = bpv_ops.backproject_vote_frames(
            frames.xy, frames.valid, geoms.H,
            jnp.stack([geoms.phi.alpha, geoms.phi.beta_x, geoms.phi.beta_y],
                      axis=-1),  # (F, Nz, 3)
            cam=cam, dsi_cfg=dsi_cfg, mode=opts.voting, quantized=opts.quantized,
        )
    else:
        dsi0 = jnp.zeros(dsi_cfg.shape, dtype=_accum_dtype(opts))

        def body(dsi, frame):
            xy, valid, H, alpha, beta_x, beta_y = frame
            geom = FrameGeometry(H, PlaneSweepCoeffs(alpha, beta_x, beta_y))
            x_i, y_i = project_frame(cam, xy, geom, opts)
            return vote_frame(dsi, x_i, y_i, valid, cam, opts), None

        dsi, _ = jax.lax.scan(
            body,
            dsi0,
            (frames.xy, frames.valid, geoms.H,
             geoms.phi.alpha, geoms.phi.beta_x, geoms.phi.beta_y),
        )

    if opts.quantized:
        dsi = dsi_lib.from_storage(dsi_lib.to_storage(dsi))  # int16 store semantics

    dm = detect_structure(
        dsi, planes,
        threshold_c=opts.detection_threshold_c,
        min_votes=opts.detection_min_votes,
    )
    if opts.median_filter:
        dm = DepthMap(median_filter3(dm.depth, dm.mask), dm.mask, dm.confidence)
    return dsi, dm


# ---------------------------------------------------------------------------
# Full pipeline
# ---------------------------------------------------------------------------


def run_emvs(
    cam: CameraModel,
    dsi_cfg: DSIConfig,
    frames: EventFrames,
    opts: EMVSOptions = EMVSOptions(),
) -> EMVSResult:
    """Process an aggregated event-frame sequence end to end."""
    mean_depth = 0.5 * (dsi_cfg.z_min + dsi_cfg.z_max)
    segs = segment_keyframes(frames.poses, mean_depth, opts.keyframe_dist_frac)
    results: list[SegmentResult] = []
    clouds: list[PointCloud] = []
    for start, end in segs:
        if end - start < 2:  # too little parallax for a meaningful DSI
            continue
        sl = jax.tree.map(lambda a: a[start:end], frames)
        T_w_ref = SE3(frames.poses.R[start], frames.poses.t[start])
        dsi, dm = process_segment(cam, dsi_cfg, sl, T_w_ref, opts)
        results.append(SegmentResult(dm, dsi, T_w_ref, (start, end)))
        clouds.append(depth_map_to_points(cam, dm, T_w_ref))
    return EMVSResult(segments=results, clouds=clouds)
