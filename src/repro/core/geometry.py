"""SE(3) poses and plane-sweep geometry for event-based space-sweep.

Implements the two geometric objects the paper's FPGA computes on the ARM
side once per event frame:

  * the canonical-plane homography  H_Z0  (current camera image -> reference
    camera image via the plane z = Z0 in the reference frame), consumed by
    PE_Z0 for P(Z0);
  * the proportional back-projection coefficients  phi = {alpha_i, beta_i}
    consumed by the PE_Zi scalar MACs for P(Z0 -> Zi).

Derivation of phi (matches the paper's 2-MAC-per-plane structure):
  Let C = (Cx, Cy, Cz) be the current camera's optical centre expressed in
  the *reference* camera frame, and let a point on the canonical plane
  z = Z0 project to reference pixel (x0, y0). The viewing ray through C and
  that point intersects plane z = Zi at

      s_i = (Zi - Cz) / (Z0 - Cz),
      X_i = C + s_i (X_0 - C),            X_i.z = Zi  (exact).

  Projecting X_i with the reference pinhole gives

      x_i = alpha_i * (x0 - cx) + beta_x_i + cx,
      y_i = alpha_i * (y0 - cy) + beta_y_i + cy,

      alpha_i  = s_i * Z0 / Zi,
      beta_x_i = fx * Cx * (1 - s_i) / Zi,
      beta_y_i = fy * Cy * (1 - s_i) / Zi.

  i.e. one multiply-add per coordinate per plane — exactly the workload the
  paper assigns to the Scalar MAC Units inside each PE_Zi.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.camera import CameraModel

Array = jax.Array


class SE3(NamedTuple):
    """Rigid transform X_out = R @ X_in + t. Batched via leading dims."""

    R: Array  # (..., 3, 3)
    t: Array  # (..., 3)

    @staticmethod
    def identity(batch: tuple[int, ...] = ()) -> "SE3":
        R = jnp.broadcast_to(jnp.eye(3, dtype=jnp.float32), batch + (3, 3))
        t = jnp.zeros(batch + (3,), dtype=jnp.float32)
        return SE3(R, t)

    def compose(self, other: "SE3") -> "SE3":
        """self ∘ other: apply `other` first, then `self`."""
        return SE3(self.R @ other.R, (self.R @ other.t[..., None])[..., 0] + self.t)

    def inverse(self) -> "SE3":
        Rt = jnp.swapaxes(self.R, -1, -2)
        return SE3(Rt, -(Rt @ self.t[..., None])[..., 0])

    def apply(self, points: Array) -> Array:
        """points: (..., 3) -> transformed (..., 3)."""
        return jnp.einsum("...ij,...nj->...ni", self.R, points) + self.t[..., None, :]


def so3_exp(w: Array) -> Array:
    """Rodrigues: axis-angle (..., 3) -> rotation matrix (..., 3, 3)."""
    theta = jnp.linalg.norm(w, axis=-1, keepdims=True)[..., None]  # (...,1,1)
    safe = jnp.where(theta < 1e-8, 1.0, theta)
    # build K (normalized cross-product matrix) explicitly for clarity
    wx, wy, wz = w[..., 0], w[..., 1], w[..., 2]
    zeros = jnp.zeros_like(wx)
    K = jnp.stack(
        [
            jnp.stack([zeros, -wz, wy], axis=-1),
            jnp.stack([wz, zeros, -wx], axis=-1),
            jnp.stack([-wy, wx, zeros], axis=-1),
        ],
        axis=-2,
    )
    K = K / safe[..., 0, 0][..., None, None]
    eye = jnp.broadcast_to(jnp.eye(3, dtype=w.dtype), K.shape)
    sin_t, cos_t = jnp.sin(theta[..., 0, 0]), jnp.cos(theta[..., 0, 0])
    R = eye + sin_t[..., None, None] * K + (1.0 - cos_t)[..., None, None] * (K @ K)
    return jnp.where(theta < 1e-8, eye, R)


def interpolate_pose(p0: SE3, p1: SE3, frac: Array) -> SE3:
    """Linear pose interpolation (translation lerp; rotation via axis-angle).

    Used to assign a camera pose to each event timestamp between two
    trajectory samples (events are asynchronous; poses are sampled).
    For the small inter-sample motions of event cameras this matches the
    first-order interpolation used by the EMVS reference implementation.
    """
    t = p0.t + frac * (p1.t - p0.t)
    # relative rotation
    dR = p1.R @ jnp.swapaxes(p0.R, -1, -2)
    w = so3_log(dR)
    R = so3_exp(w * frac) @ p0.R
    return SE3(R, t)


def so3_log(R: Array) -> Array:
    """Rotation matrix -> axis-angle (..., 3)."""
    cos_theta = jnp.clip((jnp.trace(R, axis1=-2, axis2=-1) - 1.0) / 2.0, -1.0, 1.0)
    theta = jnp.arccos(cos_theta)
    sin_theta = jnp.sin(theta)
    v = jnp.stack(
        [
            R[..., 2, 1] - R[..., 1, 2],
            R[..., 0, 2] - R[..., 2, 0],
            R[..., 1, 0] - R[..., 0, 1],
        ],
        axis=-1,
    )
    scale = jnp.where(jnp.abs(sin_theta) < 1e-8, 0.5, theta / (2.0 * sin_theta + 1e-30))
    return v * scale[..., None]


# ---------------------------------------------------------------------------
# Plane sweep: depth planes, canonical homography, proportional coefficients
# ---------------------------------------------------------------------------


def depth_planes(z_min: float, z_max: float, num: int, inverse_depth: bool = True) -> Array:
    """Depth plane placement. EMVS samples uniformly in inverse depth."""
    if inverse_depth:
        inv = jnp.linspace(1.0 / z_max, 1.0 / z_min, num, dtype=jnp.float32)
        return (1.0 / inv)[::-1]  # ascending depth
    return jnp.linspace(z_min, z_max, num, dtype=jnp.float32)


def relative_pose_ref_from_cam(T_w_ref: SE3, T_w_cam: SE3) -> SE3:
    """T_ref_cam: maps points in current-camera frame -> reference frame."""
    return T_w_ref.inverse().compose(T_w_cam)


def canonical_homography(cam: CameraModel, T_ref_cam: SE3, z0: Array) -> Array:
    """H_Z0 (3x3): current-camera pixels -> reference pixels via plane z=Z0.

    The plane z = Z0 in the *reference* frame, expressed in the current
    frame, has normal n_c = R_cr^T e_z and offset d_c = Z0 - e_z . t_rc
    (with T_ref_cam = (R_rc, t_rc) mapping cur -> ref). The induced
    homography cur -> ref is

        H = K (R_rc + t_rc n_c^T / d_c) K^{-1}

    computed once per event frame (ARM-side work in the paper).
    """
    R_rc, t_rc = T_ref_cam.R, T_ref_cam.t
    e_z = jnp.array([0.0, 0.0, 1.0], dtype=jnp.float32)
    n_c = R_rc.T @ e_z  # plane normal in current frame
    d_c = z0 - e_z @ t_rc  # plane offset along ray in current frame
    H_metric = R_rc + jnp.outer(t_rc, n_c) / d_c
    H = cam.K @ H_metric @ cam.K_inv
    return (H / H[2, 2]).astype(jnp.float32)


class PlaneSweepCoeffs(NamedTuple):
    """phi: the proportional back-projection coefficients (paper sub-task 3).

    alpha:  (Nz,)  scale of centred canonical coords
    beta_x: (Nz,)  per-plane x offset
    beta_y: (Nz,)  per-plane y offset
    """

    alpha: Array
    beta_x: Array
    beta_y: Array


def proportional_coeffs(
    cam: CameraModel, T_ref_cam: SE3, z0: Array, planes: Array
) -> PlaneSweepCoeffs:
    """Compute phi = {alpha_i, beta_i} for all depth planes (once per frame)."""
    c_ref = T_ref_cam.t  # current camera centre in reference frame
    cz = c_ref[2]
    s = (planes - cz) / (z0 - cz)  # (Nz,)
    alpha = s * z0 / planes
    beta_x = cam.fx * c_ref[0] * (1.0 - s) / planes
    beta_y = cam.fy * c_ref[1] * (1.0 - s) / planes
    return PlaneSweepCoeffs(
        alpha.astype(jnp.float32), beta_x.astype(jnp.float32), beta_y.astype(jnp.float32)
    )


def apply_homography(H: Array, xy: Array) -> Array:
    """Apply 3x3 homography to pixel coords (..., 2) with normalization.

    This is P(Z0): the PE_Z0 matrix-vector MAC + normalization unit.
    """
    x, y = xy[..., 0], xy[..., 1]
    denom = H[2, 0] * x + H[2, 1] * y + H[2, 2]
    u = (H[0, 0] * x + H[0, 1] * y + H[0, 2]) / denom
    v = (H[1, 0] * x + H[1, 1] * y + H[1, 2]) / denom
    return jnp.stack([u, v], axis=-1)


def propagate_to_planes(
    cam: CameraModel, xy0: Array, phi: PlaneSweepCoeffs
) -> tuple[Array, Array]:
    """P(Z0 -> Zi): centred multiply-add per plane (PE_Zi Scalar MACs).

    xy0: (E, 2) canonical-plane coords. Returns (x_i, y_i): each (Nz, E).
    """
    xc = xy0[..., 0] - cam.cx  # (E,)
    yc = xy0[..., 1] - cam.cy
    x_i = phi.alpha[:, None] * xc[None, :] + phi.beta_x[:, None] + cam.cx
    y_i = phi.alpha[:, None] * yc[None, :] + phi.beta_y[:, None] + cam.cy
    return x_i, y_i


def pose_distance(a: SE3, b: SE3) -> Array:
    """Translation distance between two poses (keyframe criterion)."""
    return jnp.linalg.norm(a.t - b.t)
