"""EMVS core: the paper's algorithm as a composable JAX module.

`EMVSOptions` / `EMVSResult` / `run_emvs` are re-exported lazily:
`repro.core.pipeline` imports `repro.events.aggregation`, which imports
`repro.core.camera` — eager re-export here turned that into a circular
import whenever an `repro.events` module was the first one loaded.
"""

from repro.core.camera import CameraModel  # noqa: F401
from repro.core.dsi import DSIConfig  # noqa: F401

_PIPELINE_EXPORTS = ("EMVSOptions", "EMVSResult", "run_emvs")

__all__ = ["CameraModel", "DSIConfig", *_PIPELINE_EXPORTS]


def __getattr__(name):
    if name in _PIPELINE_EXPORTS:
        from repro.core import pipeline

        return getattr(pipeline, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
