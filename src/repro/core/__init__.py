"""EMVS core: the paper's algorithm as a composable JAX module."""

from repro.core.camera import CameraModel  # noqa: F401
from repro.core.dsi import DSIConfig  # noqa: F401
from repro.core.pipeline import EMVSOptions, EMVSResult, run_emvs  # noqa: F401
